//! Regenerates **Table 3**: SDT vs LoRA on the SSM module of pretrained
//! Mamba (LinProj always tuned with LoRA), across GLUE / DART / SAMSum /
//! Spider analogues.
//!
//! Expected shape (paper): the SDT rows match or beat the LoRA-on-S6 rows
//! at comparable (or smaller) trainable budgets.

use ssm_peft::bench::{bench_cfg, TablePrinter};
use ssm_peft::coordinator::Pipeline;
use ssm_peft::manifest::Manifest;
use ssm_peft::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let engine = Engine::cpu()?;
    let manifest = Manifest::load(ssm_peft::artifacts_dir())?;
    let p = Pipeline::new(&engine, &manifest);

    let rows: &[(&str, &str)] = &[
        ("mamba1_xs_lora_both", "LinProj=LoRA, S6=LoRA"),
        ("mamba1_xs_sdtlora", "LinProj/Wout=LoRA, S6=SDT"),
    ];
    let datasets = ["glue/rte", "dart", "samsum", "spider"];
    let mut table = TablePrinter::new(&[
        "setting", "params%", "rte(acc)", "dart(BLEU)", "dart(MET)",
        "samsum(R1)", "samsum(R2)", "samsum(RL)", "spider(exec)",
    ]);
    for (variant, label) in rows {
        let mut cells = vec![label.to_string()];
        let mut pct = String::new();
        for ds in &datasets {
            let cfg = bench_cfg(variant, ds);
            let out = p.finetune(&cfg)?;
            if pct.is_empty() {
                pct = format!("{:.2}", out.budget_pct);
                cells.push(pct.clone());
            }
            match *ds {
                "dart" => {
                    cells.push(format!("{:.3}", out.scores["bleu"]));
                    cells.push(format!("{:.3}", out.scores["meteor"]));
                }
                "samsum" => {
                    cells.push(format!("{:.3}", out.scores["rouge1"]));
                    cells.push(format!("{:.3}", out.scores["rouge2"]));
                    cells.push(format!("{:.3}", out.scores["rougeL"]));
                }
                "spider" => cells.push(format!("{:.3}", out.scores["exec"])),
                _ => cells.push(format!("{:.3}", out.metric)),
            }
        }
        table.row(cells);
        table.print();
    }
    println!("\n=== Table 3 (reproduction) ===");
    table.print();
    table.save_csv("table3.csv");
    Ok(())
}
