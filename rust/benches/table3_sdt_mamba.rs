//! Regenerates **Table 3**: SDT vs LoRA on the SSM module of pretrained
//! Mamba (LinProj always tuned with LoRA), across GLUE / DART / SAMSum /
//! Spider analogues. Runs as a parallel suite (records in
//! results/table3.jsonl).
//!
//! Expected shape (paper): the SDT rows match or beat the LoRA-on-S6 rows
//! at comparable (or smaller) trainable budgets.

use ssm_peft::bench::bench_template;
use ssm_peft::manifest::Manifest;
use ssm_peft::runtime::Engine;
use ssm_peft::suite::{pivot, worker_count, PivotCol, Suite};

fn main() -> ssm_peft::error::Result<()> {
    let engine = Engine::cpu()?;
    let manifest = Manifest::load(ssm_peft::artifacts_dir())?;

    let rows: &[(&str, &[&str])] = &[
        ("mamba1_xs_lora_both", &["LinProj=LoRA, S6=LoRA"]),
        ("mamba1_xs_sdtlora", &["LinProj/Wout=LoRA, S6=SDT"]),
    ];
    let variants: Vec<&str> = rows.iter().map(|(v, _)| *v).collect();
    let datasets: &[&str] = &["glue/rte", "dart", "samsum", "spider"];

    let workers = worker_count(2);
    let records = Suite::new(&engine, &manifest)
        .named("table3")
        .template(bench_template())
        .grid(&variants, datasets)
        .run(workers)?;

    let cols = [
        PivotCol::main("rte(acc)", "glue/rte"),
        PivotCol::score("dart(BLEU)", "dart", "bleu"),
        PivotCol::score("dart(MET)", "dart", "meteor"),
        PivotCol::score("samsum(R1)", "samsum", "rouge1"),
        PivotCol::score("samsum(R2)", "samsum", "rouge2"),
        PivotCol::score("samsum(RL)", "samsum", "rougeL"),
        PivotCol::main("spider(exec)", "spider"),
    ];
    let table = pivot(&records, &["setting"], rows, &cols);
    println!("\n=== Table 3 (reproduction, {workers} workers) ===");
    table.print();
    table.save_csv("table3.csv");
    println!("[record stream: results/table3.jsonl]");
    Ok(())
}
