//! Regenerates **Figure 3 / Table 15**: the empirical companion to Lemma 1 —
//! fine-tuning only the input projection (W_in) matches or beats fine-tuning
//! the S6 tensors (W_B, W_C, W_Δ↑) in both convergence speed and final
//! metric, across seeds.
//!
//! Expected shape: the W_in (lora_lin) loss curve sits below the S6
//! (lora_ssm) curve for matched budgets; final val metric ≥.

use ssm_peft::bench::{bench_cfg, TablePrinter};
use ssm_peft::coordinator::{save_history, Pipeline};
use ssm_peft::manifest::Manifest;
use ssm_peft::runtime::Engine;
use ssm_peft::tensor::{mean, std_dev};

fn main() -> ssm_peft::error::Result<()> {
    let engine = Engine::cpu()?;
    let manifest = Manifest::load(ssm_peft::artifacts_dir())?;
    let p = Pipeline::new(&engine, &manifest);

    let seeds = [0u64, 1, 2];
    let mut table = TablePrinter::new(&["tuned", "dataset", "mean", "std"]);
    for (variant, label) in [
        ("mamba1_xs_lora_lin", "W_in (LinProj)"),
        ("mamba1_xs_lora_ssm", "W_B/W_C/W_dt (S6)"),
    ] {
        for ds in ["glue/rte", "glue/mrpc"] {
            let mut vals = Vec::new();
            for &seed in &seeds {
                let mut cfg = bench_cfg(variant, ds);
                cfg.seed = seed;
                let out = p.finetune(&cfg)?;
                vals.push(out.metric);
                if seed == 0 {
                    save_history(
                        &format!("fig3_{}_{}.csv", variant, ds.replace('/', "_")),
                        &out.history,
                    );
                }
            }
            table.row(vec![
                label.into(),
                ds.into(),
                format!("{:.3}", mean(&vals)),
                format!("{:.3}", std_dev(&vals)),
            ]);
            table.print();
        }
    }
    println!("\n=== Figure 3 / Table 15 (reproduction) ===");
    table.print();
    table.save_csv("fig3.csv");
    println!("loss curves -> results/fig3_*.csv");
    Ok(())
}
