//! Regenerates **Figure 5**: average training time per batch, SDT vs LoRA,
//! across model sizes (paper also sweeps sequence length; our artifacts fix
//! L per export, so the size axis carries the comparison — L=128 for XS,
//! L=192 for S).
//!
//! Expected shape: SDT&LoRA is consistently faster per batch than LoRA at
//! matched budgets (no low-rank matmuls on the SSM tensors; masked-grad
//! updates touch fewer optimizer slots).

use ssm_peft::bench::{bench_cfg, time, TablePrinter};
use ssm_peft::coordinator::Pipeline;
use ssm_peft::suite::VariantId;
use ssm_peft::data::{tasks, BatchIter};
use ssm_peft::manifest::Manifest;
use ssm_peft::runtime::Engine;
use ssm_peft::tensor::Rng;
use ssm_peft::train::{TrainConfig, Trainer};

fn main() -> ssm_peft::error::Result<()> {
    let engine = Engine::cpu()?;
    let manifest = Manifest::load(ssm_peft::artifacts_dir())?;
    let p = Pipeline::new(&engine, &manifest);

    let mut table = TablePrinter::new(&[
        "model", "L", "method", "s/batch (mean)", "std",
    ]);
    for (variant, label) in [
        ("mamba1_xs_lora_both", "LoRA"),
        ("mamba1_xs_sdtlora", "LoRA & SDT"),
        ("mamba1_s_lora_lin", "LoRA"),
        ("mamba1_s_sdtlora", "LoRA & SDT"),
    ] {
        let arch = VariantId::parse(variant)?.arch;
        let base = p.pretrained(&arch, 150, 0)?;
        let mut tr = Trainer::new(&engine, &manifest, variant, &TrainConfig::default())?;
        tr.load_base(&base);
        if variant.contains("sdt") {
            let cfg = bench_cfg(variant, "dart");
            let ds = tasks::by_name("dart", 0, 64)?;
            let before = tr.train_map();
            let mut rng = Rng::new(1);
            let it = BatchIter::new(&ds.train, &mut rng, tr.variant.batch_b,
                                    tr.variant.batch_l);
            for (batch, _) in it.take(4) {
                tr.step(&batch)?;
            }
            let after = tr.train_map();
            let (masks, _) =
                ssm_peft::peft::select_dimensions(&tr.variant, &before, &after, &cfg.sdt);
            tr.set_masks(masks);
        }
        let ds = tasks::by_name("dart", 0, 64)?;
        let mut rng = Rng::new(3);
        let mut it = BatchIter::new(&ds.train, &mut rng, tr.variant.batch_b,
                                    tr.variant.batch_l);
        let (batch, _) = it.next().unwrap();
        let stats = time(variant, 2, 8, || {
            tr.step(&batch).unwrap();
        });
        table.row(vec![
            arch.clone(),
            tr.variant.batch_l.to_string(),
            label.into(),
            format!("{:.4}", stats.mean_s),
            format!("{:.4}", stats.std_s),
        ]);
        table.print();
    }
    println!("\n=== Figure 5 (reproduction): time per training batch ===");
    table.print();
    table.save_csv("fig5.csv");
    Ok(())
}
