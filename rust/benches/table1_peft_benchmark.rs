//! Regenerates **Table 1**: benchmarking popular PEFT methods on Mamba and
//! the hybrid (Jamba-like) model across the dataset analogues.
//!
//! Declarative suite spec on the parallel runner: the full method×dataset
//! grid fans out over `SSM_PEFT_WORKERS` workers (default 2) sharing the
//! engine's compiled-executable cache, streams machine-readable records to
//! results/table1.jsonl, and pivots them into the paper table.
//!
//! Paper columns: GLUE avg / DART / SAMSum / Spider / CIFAR-10 / CelebA.
//! Testbed subset (CPU budget): GLUE-rte + GLUE-sst2, DART, CIFAR-10 for
//! Mamba; GLUE-rte for the hybrid. The *expected shape* (paper finding):
//! LoRA* > {BitFit, Additional-scan} > {prompt, prefix}; LinProj ≥ Both >
//! SSM-only for LoRA.

use ssm_peft::bench::bench_template;
use ssm_peft::manifest::Manifest;
use ssm_peft::runtime::Engine;
use ssm_peft::suite::{pivot, worker_count, PeftMethod, PivotCol, Suite, VariantId};

fn main() -> ssm_peft::error::Result<()> {
    let engine = Engine::cpu()?;
    let manifest = Manifest::load(ssm_peft::artifacts_dir())?;

    let mamba_variants: &[&str] = &[
        "mamba1_xs_prompt", "mamba1_xs_prefix", "mamba1_xs_initstate",
        "mamba1_xs_bitfit", "mamba1_xs_lora_ssm", "mamba1_xs_lora_lin",
        "mamba1_xs_lora_both", "mamba1_xs_dora_ssm", "mamba1_xs_dora_lin",
        "mamba1_xs_dora_both", "mamba1_xs_addscan", "mamba1_xs_full",
    ];
    let hybrid_variants: &[&str] = &[
        "hybrid_xs_prompt", "hybrid_xs_prefix", "hybrid_xs_bitfit",
        "hybrid_xs_lora_lin", "hybrid_xs_dora_lin", "hybrid_xs_addscan",
    ];
    let datasets: &[&str] = &["glue/rte", "glue/sst2", "dart", "cifar10"];

    let workers = worker_count(2);
    let records = Suite::new(&engine, &manifest)
        .named("table1")
        .template(bench_template())
        .grid(mamba_variants, datasets)
        .grid(hybrid_variants, &["glue/rte"])
        .run(workers)?;

    // row labels (model / method / target) derive from the typed VariantId
    let labels: Vec<(String, Vec<String>)> = mamba_variants
        .iter()
        .map(|v| (*v, "Mamba"))
        .chain(hybrid_variants.iter().map(|v| (*v, "Hybrid")))
        .map(|(v, model)| {
            let vid = VariantId::parse(v).expect("bench variant name");
            // paper's Table 1 nuance: on the hybrid only Mamba-layer biases
            // exist to tune, so BitFit's target reads "Other" there
            let target = if model == "Hybrid" && vid.method == PeftMethod::BitFit {
                "Other"
            } else {
                vid.method.target_label()
            };
            (
                v.to_string(),
                vec![model.to_string(), vid.method.label().to_string(), target.to_string()],
            )
        })
        .collect();
    let label_refs: Vec<Vec<&str>> = labels
        .iter()
        .map(|(_, cells)| cells.iter().map(String::as_str).collect())
        .collect();
    let rows: Vec<(&str, &[&str])> = labels
        .iter()
        .zip(&label_refs)
        .map(|((v, _), cells)| (v.as_str(), cells.as_slice()))
        .collect();

    let cols = [
        PivotCol::main("rte", "glue/rte"),
        PivotCol::main("sst2", "glue/sst2"),
        PivotCol::score("dart(MET)", "dart", "meteor"),
        PivotCol::score("dart(BLEU)", "dart", "bleu"),
        PivotCol::main("cifar10", "cifar10"),
    ];
    let table = pivot(&records, &["model", "method", "target"], &rows, &cols);
    println!("\n=== Table 1 (reproduction, {workers} workers) ===");
    table.print();
    table.save_csv("table1.csv");
    println!("[record stream: results/table1.jsonl]");
    Ok(())
}
