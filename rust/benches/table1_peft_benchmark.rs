//! Regenerates **Table 1**: benchmarking popular PEFT methods on Mamba and
//! the hybrid (Jamba-like) model across the dataset analogues.
//!
//! Paper columns: GLUE avg / DART / SAMSum / Spider / CIFAR-10 / CelebA.
//! Testbed subset (CPU budget): GLUE-rte + GLUE-sst2, DART, CIFAR-10 for
//! Mamba; GLUE-rte for the hybrid. The *expected shape* (paper finding):
//! LoRA* > {BitFit, Additional-scan} > {prompt, prefix}; LinProj ≥ Both >
//! SSM-only for LoRA.

use ssm_peft::bench::{bench_cfg, TablePrinter};
use ssm_peft::coordinator::Pipeline;
use ssm_peft::manifest::Manifest;
use ssm_peft::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let engine = Engine::cpu()?;
    let manifest = Manifest::load(ssm_peft::artifacts_dir())?;
    let p = Pipeline::new(&engine, &manifest);

    let mamba_methods: &[(&str, &str, &str)] = &[
        ("mamba1_xs_prompt", "Prompt Tuning", "Other"),
        ("mamba1_xs_prefix", "Prefix-Tuning", "SSM"),
        ("mamba1_xs_initstate", "Initial-State Tuning", "SSM"),
        ("mamba1_xs_bitfit", "BitFit", "Both"),
        ("mamba1_xs_lora_ssm", "LoRA", "SSM"),
        ("mamba1_xs_lora_lin", "LoRA", "LinProj"),
        ("mamba1_xs_lora_both", "LoRA", "Both"),
        ("mamba1_xs_dora_ssm", "DoRA", "SSM"),
        ("mamba1_xs_dora_lin", "DoRA", "LinProj"),
        ("mamba1_xs_dora_both", "DoRA", "Both"),
        ("mamba1_xs_addscan", "Additional-Scan", "SSM"),
        ("mamba1_xs_full", "Full Fine-Tuning", "Both"),
    ];
    let datasets = ["glue/rte", "glue/sst2", "dart", "cifar10"];

    let mut table = TablePrinter::new(&[
        "model", "method", "target", "params%", "rte", "sst2", "dart(MET)",
        "dart(BLEU)", "cifar10",
    ]);

    for (variant, method, target) in mamba_methods {
        let mut cells = vec!["Mamba".to_string(), method.to_string(), target.to_string()];
        let mut budget = String::new();
        let mut scores: Vec<String> = Vec::new();
        for ds in &datasets {
            let cfg = bench_cfg(variant, ds);
            match p.finetune(&cfg) {
                Ok(out) => {
                    if budget.is_empty() {
                        budget = format!("{:.2}", out.budget_pct);
                    }
                    if *ds == "dart" {
                        scores.push(format!("{:.3}", out.scores["meteor"]));
                        scores.push(format!("{:.3}", out.scores["bleu"]));
                    } else {
                        scores.push(format!("{:.3}", out.metric));
                    }
                }
                Err(e) => {
                    eprintln!("[{variant}/{ds}] failed: {e:#}");
                    scores.push("ERR".into());
                    if *ds == "dart" {
                        scores.push("ERR".into());
                    }
                }
            }
        }
        cells.push(budget);
        cells.extend(scores);
        table.row(cells);
        table.print(); // incremental progress
    }

    // hybrid rows (PEFT on Mamba layers only, attention frozen — Sec. 4.1)
    let hybrid_methods: &[(&str, &str, &str)] = &[
        ("hybrid_xs_prompt", "Prompt Tuning", "Other"),
        ("hybrid_xs_prefix", "Prefix-Tuning", "SSM"),
        ("hybrid_xs_bitfit", "BitFit", "Other"),
        ("hybrid_xs_lora_lin", "LoRA", "LinProj"),
        ("hybrid_xs_dora_lin", "DoRA", "LinProj"),
        ("hybrid_xs_addscan", "Additional-Scan", "SSM"),
    ];
    for (variant, method, target) in hybrid_methods {
        let cfg = bench_cfg(variant, "glue/rte");
        let (acc, pct) = match p.finetune(&cfg) {
            Ok(o) => (format!("{:.3}", o.metric), format!("{:.2}", o.budget_pct)),
            Err(e) => {
                eprintln!("[{variant}] failed: {e:#}");
                ("ERR".into(), "-".into())
            }
        };
        table.row(vec![
            "Hybrid".into(), method.to_string(), target.to_string(), pct, acc,
            "-".into(), "-".into(), "-".into(), "-".into(),
        ]);
    }

    println!("\n=== Table 1 (reproduction) ===");
    table.print();
    table.save_csv("table1.csv");
    Ok(())
}
