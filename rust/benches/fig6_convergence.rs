//! Regenerates **Figure 6**: convergence — MSE vs wall-clock time for SDT
//! vs LoRA on the synthetic deep-S4 task (paper sweeps sequence lengths;
//! the exported regression artifact fixes L=200, the paper's middle
//! setting).
//!
//! Expected shape: the SDT curve reaches lower MSE earlier than LoRA under
//! the same time budget.

use ssm_peft::error::Result;
use ssm_peft::coordinator::Pipeline;
use ssm_peft::eval::eval_regression;
use ssm_peft::manifest::Manifest;
use ssm_peft::peft::{select_dimensions, SdtConfig};
use ssm_peft::runtime::Engine;
use ssm_peft::tensor::Tensor;
use ssm_peft::train::{TrainConfig, Trainer};

const ITERS: usize = 100;
const EVAL_EVERY: usize = 10;

fn main() -> Result<()> {
    let engine = Engine::cpu()?;
    let manifest = Manifest::load(ssm_peft::artifacts_dir())?;
    let p = Pipeline::new(&engine, &manifest);
    let (xs, ys) = p.synthetic_s4_data(0, 10, 200)?;
    let (xs_test, ys_test) = (&xs[8..], &ys[8..]);

    let mut csv = String::from("method,seconds,mse\n");
    for (variant, label, use_sdt) in [
        ("s4reg_s4_lora_ssm", "LoRA", false),
        ("s4reg_sdtlora", "SDT", true),
    ] {
        let tcfg = TrainConfig { lr: 2e-3, schedule_total: ITERS, ..Default::default() };
        let mut tr = Trainer::new(&engine, &manifest, variant, &tcfg)?;
        let mask = Tensor::from_vec(
            &[tr.variant.batch_b, 200],
            vec![1.0; tr.variant.batch_b * 200],
        );
        if use_sdt {
            let cfg = SdtConfig {
                channel_freeze: 0.875,
                state_freeze: 0.75,
                warmup_batches: 4,
                ..Default::default()
            };
            let before = tr.train_map();
            let snap = tr.snapshot_train();
            for i in 0..4 {
                tr.step_reg(&xs[i], &ys[i], &mask)?;
            }
            let after = tr.train_map();
            let (masks, _) = select_dimensions(&tr.variant, &before, &after, &cfg);
            tr.restore_train(snap);
            tr.set_masks(masks);
        }
        let t0 = std::time::Instant::now();
        println!("{label}: wall-clock vs test MSE");
        for it in 0..ITERS {
            tr.step_reg(&xs[it % 8], &ys[it % 8], &mask)?;
            if (it + 1) % EVAL_EVERY == 0 {
                let mse = eval_regression(&tr, xs_test, ys_test)?;
                let secs = t0.elapsed().as_secs_f64();
                println!("  t={secs:7.2}s  iter={:3}  mse={mse:.5}", it + 1);
                csv.push_str(&format!("{label},{secs:.3},{mse:.6}\n"));
            }
        }
    }
    std::fs::write(ssm_peft::results_dir().join("fig6.csv"), csv)?;
    println!("=== Figure 6 (reproduction) saved to results/fig6.csv ===");
    Ok(())
}
