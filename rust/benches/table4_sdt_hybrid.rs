//! Regenerates **Table 4 / 22**: SDT vs DoRA/LoRA on the hybrid
//! (Jamba-like) model's Mamba layers, GLUE analogue subtasks. Runs as a
//! parallel suite (records in results/table4.jsonl).
//!
//! Expected shape (paper): SDT ≥ DoRA on average, with smaller gains than
//! on pure Mamba because attention layers are frozen and Mamba layers hold
//! a smaller parameter share.

use ssm_peft::bench::bench_template;
use ssm_peft::manifest::Manifest;
use ssm_peft::runtime::Engine;
use ssm_peft::suite::{pivot, worker_count, PivotCol, Suite};

fn main() -> ssm_peft::error::Result<()> {
    let engine = Engine::cpu()?;
    let manifest = Manifest::load(ssm_peft::artifacts_dir())?;

    let rows: &[(&str, &[&str])] = &[
        ("hybrid_xs_dora_lin", &["LinProj=DoRA"]),
        ("hybrid_xs_sdtlora", &["Wout=LoRA, S6=SDT"]),
    ];
    let variants: Vec<&str> = rows.iter().map(|(v, _)| *v).collect();
    let datasets: &[&str] = &["glue/rte", "glue/mrpc", "glue/cola", "glue/sst2"];

    let workers = worker_count(2);
    let records = Suite::new(&engine, &manifest)
        .named("table4")
        .template(bench_template())
        .grid(&variants, datasets)
        .run(workers)?;

    let cols = [
        PivotCol::main("rte", "glue/rte"),
        PivotCol::main("mrpc", "glue/mrpc"),
        PivotCol::main("cola", "glue/cola"),
        PivotCol::main("sst2", "glue/sst2"),
    ];
    let mut table = pivot(&records, &["setting"], rows, &cols);
    table.headers.push("avg".to_string());
    for (i, (variant, _)) in rows.iter().enumerate() {
        let vals: Vec<f64> = records
            .iter()
            .filter(|r| r.ok() && r.variant == *variant)
            .map(|r| r.metric)
            .collect();
        // a 4-task average is only honest when all 4 cells succeeded
        let avg = if vals.len() == datasets.len() {
            format!("{:.3}", vals.iter().sum::<f64>() / vals.len() as f64)
        } else {
            "-".to_string()
        };
        table.rows[i].push(avg);
    }
    println!("\n=== Table 4/22 (reproduction, {workers} workers) ===");
    table.print();
    table.save_csv("table4.csv");
    println!("[record stream: results/table4.jsonl]");
    Ok(())
}
