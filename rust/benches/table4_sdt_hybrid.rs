//! Regenerates **Table 4 / 22**: SDT vs DoRA/LoRA on the hybrid
//! (Jamba-like) model's Mamba layers, GLUE analogue subtasks.
//!
//! Expected shape (paper): SDT ≥ DoRA on average, with smaller gains than
//! on pure Mamba because attention layers are frozen and Mamba layers hold
//! a smaller parameter share.

use ssm_peft::bench::{bench_cfg, TablePrinter};
use ssm_peft::coordinator::Pipeline;
use ssm_peft::manifest::Manifest;
use ssm_peft::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let engine = Engine::cpu()?;
    let manifest = Manifest::load(ssm_peft::artifacts_dir())?;
    let p = Pipeline::new(&engine, &manifest);

    let rows: &[(&str, &str)] = &[
        ("hybrid_xs_dora_lin", "LinProj=DoRA"),
        ("hybrid_xs_sdtlora", "Wout=LoRA, S6=SDT"),
    ];
    let subs = ["rte", "mrpc", "cola", "sst2"];
    let mut table = TablePrinter::new(&["setting", "params%", "rte", "mrpc", "cola", "sst2", "avg"]);
    for (variant, label) in rows {
        let mut cells = vec![label.to_string(), String::new()];
        let mut vals = Vec::new();
        for sub in &subs {
            let cfg = bench_cfg(variant, &format!("glue/{sub}"));
            let out = p.finetune(&cfg)?;
            if cells[1].is_empty() {
                cells[1] = format!("{:.2}", out.budget_pct);
            }
            vals.push(out.metric);
            cells.push(format!("{:.3}", out.metric));
        }
        cells.push(format!("{:.3}", vals.iter().sum::<f64>() / vals.len() as f64));
        table.row(cells);
        table.print();
    }
    println!("\n=== Table 4/22 (reproduction) ===");
    table.print();
    table.save_csv("table4.csv");
    Ok(())
}
