//! Ablation (DESIGN.md §ablations): SDT's dimension-selection criterion.
//! ‖ΔĀ‖ after warmup (paper Alg. 1) vs accumulated |grad| magnitude
//! (Song et al. 2024 style) vs random channels/states.
//!
//! Expected shape: ΔĀ ≈ grad-magnitude > random, motivating the paper's
//! warmup-based criterion.

use ssm_peft::bench::{bench_cfg, TablePrinter};
use ssm_peft::coordinator::Pipeline;
use ssm_peft::manifest::Manifest;
use ssm_peft::runtime::Engine;

fn main() -> ssm_peft::error::Result<()> {
    let engine = Engine::cpu()?;
    let manifest = Manifest::load(ssm_peft::artifacts_dir())?;
    let p = Pipeline::new(&engine, &manifest);

    let mut table = TablePrinter::new(&["criterion", "rte(acc)", "dart(RL)"]);
    for crit in ["abar", "grad", "random"] {
        let mut cells = vec![crit.to_string()];
        for ds in ["glue/rte", "dart"] {
            let mut cfg = bench_cfg("mamba1_xs_sdtlora", ds);
            cfg.set("sdt.criterion", &ssm_peft::json::Value::Str(crit.into()))?;
            let out = p.finetune(&cfg)?;
            cells.push(format!(
                "{:.3}",
                if ds == "dart" { out.scores["rougeL"] } else { out.metric }
            ));
        }
        table.row(cells);
        table.print();
    }
    println!("\n=== SDT selection-criterion ablation ===");
    table.print();
    table.save_csv("ablate_selection.csv");
    Ok(())
}
