//! Regenerates **Figure 4 / Table 16**: fine-tuning memory, SDT vs LoRA.
//!
//! The paper sweeps context length on an H100; our artifacts are
//! shape-specialized (one L per export), so we report (a) measured RSS
//! deltas around a real training step at the exported lengths and (b) the
//! analytic training-memory model (params + grads + AdamW moments +
//! activations) across context lengths, which is what actually separates
//! the methods. Expected shape: SDT&LoRA ≤ LoRA at every length (LoRA adds
//! adapter activations + their optimizer state on the SSM path).

use ssm_peft::bench::{bench_cfg, rss_bytes, training_memory_model, TablePrinter};
use ssm_peft::coordinator::Pipeline;
use ssm_peft::suite::VariantId;
use ssm_peft::data::{tasks, BatchIter};
use ssm_peft::manifest::Manifest;
use ssm_peft::peft::Budget;
use ssm_peft::runtime::Engine;
use ssm_peft::tensor::Rng;
use ssm_peft::train::{TrainConfig, Trainer};

fn main() -> ssm_peft::error::Result<()> {
    let engine = Engine::cpu()?;
    let manifest = Manifest::load(ssm_peft::artifacts_dir())?;
    let p = Pipeline::new(&engine, &manifest);

    let mut table = TablePrinter::new(&[
        "model", "method", "L", "trainable", "RSS delta (MB)", "model est (MB)",
    ]);
    for (variant, label) in [
        ("mamba1_xs_lora_both", "LoRA"),
        ("mamba1_xs_sdtlora", "LoRA & SDT"),
        ("mamba1_s_lora_lin", "LoRA"),
        ("mamba1_s_sdtlora", "LoRA & SDT"),
    ] {
        let arch = VariantId::parse(variant)?.arch;
        let base = p.pretrained(&arch, 150, 0)?;
        let tcfg = TrainConfig::default();
        let mut tr = Trainer::new(&engine, &manifest, variant, &tcfg)?;
        tr.load_base(&base);
        if variant.contains("sdt") {
            // apply a 99%-channel-frozen mask so budgets match the paper setup
            let cfg = bench_cfg(variant, "dart");
            let ds = tasks::by_name("dart", 0, 64)?;
            let before = tr.train_map();
            let mut rng = Rng::new(1);
            let it = BatchIter::new(&ds.train, &mut rng, tr.variant.batch_b,
                                    tr.variant.batch_l);
            for (batch, _) in it.take(4) {
                tr.step(&batch)?;
            }
            let after = tr.train_map();
            let (masks, _) =
                ssm_peft::peft::select_dimensions(&tr.variant, &before, &after, &cfg.sdt);
            tr.set_masks(masks);
        }
        let ds = tasks::by_name("dart", 0, 64)?;
        let mut rng = Rng::new(2);
        let mut it = BatchIter::new(&ds.train, &mut rng, tr.variant.batch_b,
                                    tr.variant.batch_l);
        let (batch, _) = it.next().unwrap();
        let rss0 = rss_bytes();
        tr.step(&batch)?;
        let rss1 = rss_bytes();
        let budget = Budget::of(&tr.variant, Some(tr.masks()));
        let l = tr.variant.batch_l;
        // activations ≈ B*L*(2*Di + vocab) per layer for the scan path
        let act = tr.variant.batch_b * l
            * (2 * tr.variant.arch.d_inner + tr.variant.arch.vocab)
            * tr.variant.arch.n_layer;
        let est = training_memory_model(budget.total, budget.trainable, act);
        table.row(vec![
            arch.clone(),
            label.into(),
            l.to_string(),
            budget.trainable.to_string(),
            format!("{:.1}", (rss1.saturating_sub(rss0)) as f64 / 1e6),
            format!("{:.1}", est as f64 / 1e6),
        ]);
        table.print();
    }

    // analytic sweep over context length (the paper's x-axis)
    println!("\nanalytic memory model vs context length (mamba1_s):");
    let v = manifest.variant("mamba1_s_lora_lin")?;
    let vs = manifest.variant("mamba1_s_sdtlora")?;
    let mut sweep = TablePrinter::new(&["L", "LoRA (MB)", "LoRA&SDT @99% frozen (MB)"]);
    for l in [128usize, 256, 512, 1024, 2048] {
        let act = 8 * l * (2 * v.arch.d_inner + v.arch.vocab) * v.arch.n_layer;
        let lora = training_memory_model(v.n_total(), v.n_train(), act);
        // SDT effective trainable ≈ 1% of SSM tensors + LoRA(Wout)
        let sdt_train = vs.n_train() / 50;
        let sdt = training_memory_model(vs.n_total(), sdt_train, act);
        sweep.row(vec![
            l.to_string(),
            format!("{:.1}", lora as f64 / 1e6),
            format!("{:.1}", sdt as f64 / 1e6),
        ]);
    }
    sweep.print();
    sweep.save_csv("fig4_sweep.csv");
    table.save_csv("fig4.csv");
    Ok(())
}
