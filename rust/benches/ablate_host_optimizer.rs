//! Ablation (DESIGN.md §ablations): cost of the grads-in-graph design.
//!
//! We chose to compute gradients inside the AOT artifact and run
//! masking/AdamW on the host so the PEFT engine lives in Rust. This bench
//! measures what that costs: XLA step (device) time vs host optimizer time
//! per training step, with and without SDT masks, at two model sizes —
//! for BOTH host-optimizer implementations: the legacy three-pass
//! reference (mask → clip → AdamW over `Vec<Tensor>`) and the fused
//! arena pass (`FusedAdamW` over a `ParamArena`, §Perf L3).
//!
//! Expected shape: host optimizer time is a small fraction of the XLA step
//! (grads dominate), the fused pass shrinks it further, and the masked
//! update is not slower than the unmasked one.

use ssm_peft::bench::{time, TablePrinter};
use ssm_peft::coordinator::Pipeline;
use ssm_peft::data::{tasks, BatchIter};
use ssm_peft::manifest::Manifest;
use ssm_peft::optim::{AdamW, FusedAdamW, MaskPlan, ParamArena};
use ssm_peft::peft::Masks;
use ssm_peft::runtime::Engine;
use ssm_peft::suite::VariantId;
use ssm_peft::tensor::{Rng, Tensor};
use ssm_peft::train::{TrainConfig, Trainer};

fn main() -> ssm_peft::error::Result<()> {
    let engine = Engine::cpu()?;
    let manifest = Manifest::load(ssm_peft::artifacts_dir())?;
    let p = Pipeline::new(&engine, &manifest);
    let mut table = TablePrinter::new(&[
        "variant", "masked", "full step (s)", "legacy host (s)", "fused host (s)",
        "host share",
    ]);

    for variant in ["mamba1_xs_full", "mamba1_s_full"] {
        let arch = VariantId::parse(variant)?.arch;
        let base = p.pretrained(&arch, 150, 0)?;
        for masked in [false, true] {
            let mut tr = Trainer::new(&engine, &manifest, variant,
                                      &TrainConfig::default())?;
            tr.load_base(&base);
            if masked {
                // half-random masks exercise the masking path
                let mut rng = Rng::new(0);
                tr.set_masks(ssm_peft::peft::random_masks(&tr.variant, 0.5, &mut rng));
            } else {
                tr.set_masks(Masks::none(tr.variant.train_params.len()));
            }
            let ds = tasks::by_name("dart", 0, 64)?;
            let mut rng = Rng::new(2);
            let mut it = BatchIter::new(&ds.train, &mut rng, tr.variant.batch_b,
                                        tr.variant.batch_l);
            let (batch, _) = it.next().unwrap();
            let full = time("step", 1, 6, || {
                tr.step(&batch).unwrap();
            });

            // legacy host-only reference: three passes on fake grads of
            // the same shapes (with the per-step grad clone the old
            // readback path paid)
            let mut params: Vec<Tensor> = tr.snapshot_train();
            let grads: Vec<Tensor> =
                params.iter().map(|t| Tensor::from_vec(&t.shape,
                    vec![0.01; t.numel()])).collect();
            let mut opt = AdamW::new(&params);
            let masks = tr.masks().clone();
            let legacy = time("legacy host", 1, 6, || {
                let mut g = grads.clone();
                masks.apply(&mut g);
                ssm_peft::optim::clip_global_norm(&mut g, 1.0);
                opt.step(&mut params, &g, 1e-3);
            });

            // fused host-only: one pass over the arena, no grad clone
            let mut arena = ParamArena::pack(&tr.snapshot_train());
            let garena: Vec<f32> = vec![0.01; arena.len()];
            let mut fopt = FusedAdamW::new(&arena);
            let (m, v) = (fopt.moments().0.to_vec(), fopt.moments().1.to_vec());
            let plan = MaskPlan::compile(&masks.masks, &arena, &m, &v);
            let workers = ssm_peft::optim::fused_workers();
            let fused = time("fused host", 1, 6, || {
                fopt.step(&mut arena, &garena, &plan, 1e-3, 1.0, workers);
            });

            table.row(vec![
                variant.into(),
                masked.to_string(),
                format!("{:.4}", full.mean_s),
                format!("{:.4}", legacy.mean_s),
                format!("{:.4}", fused.mean_s),
                format!("{:.1}%", 100.0 * fused.mean_s / full.mean_s.max(1e-12)),
            ]);
            table.print();
        }
    }
    println!("\n=== grads-in-graph vs host-optimizer ablation ===");
    table.print();
    table.save_csv("ablate_host_optimizer.csv");
    Ok(())
}
