//! Regenerates **Tables 11/12/20/21**: Mamba-II (scalar state matrix A).
//! LoRA on linear projections vs LoRA on the SSM module vs SDT.
//!
//! Expected shape (paper): LinProj > SSM for LoRA, and LoRA&SDT > LoRA.

use ssm_peft::bench::{bench_cfg, TablePrinter};
use ssm_peft::coordinator::Pipeline;
use ssm_peft::manifest::Manifest;
use ssm_peft::runtime::Engine;

fn main() -> ssm_peft::error::Result<()> {
    let engine = Engine::cpu()?;
    let manifest = Manifest::load(ssm_peft::artifacts_dir())?;
    let p = Pipeline::new(&engine, &manifest);

    let rows: &[(&str, &str)] = &[
        ("mamba2_xs_lora_lin", "LoRA (LinProj)"),
        ("mamba2_xs_lora_ssm", "LoRA (S6)"),
        ("mamba2_xs_sdtlora", "LoRA & SDT"),
        ("mamba2_xs_full", "Full fine-tuning"),
    ];
    let datasets = ["dart", "glue/rte"];
    let mut table = TablePrinter::new(&[
        "method", "params%", "dart(MET)", "dart(BLEU)", "rte(acc)",
    ]);
    for (variant, label) in rows {
        let mut cells = vec![label.to_string(), String::new()];
        for ds in &datasets {
            let cfg = bench_cfg(variant, ds);
            let out = p.finetune(&cfg)?;
            if cells[1].is_empty() {
                cells[1] = format!("{:.2}", out.budget_pct);
            }
            if *ds == "dart" {
                cells.push(format!("{:.3}", out.scores["meteor"]));
                cells.push(format!("{:.3}", out.scores["bleu"]));
            } else {
                cells.push(format!("{:.3}", out.metric));
            }
        }
        table.row(cells);
        table.print();
    }
    println!("\n=== Tables 11/12/20/21 (reproduction, Mamba-II) ===");
    table.print();
    table.save_csv("table11.csv");
    Ok(())
}
