//! Regenerates **Table 14**: input-injection methods vs initial-state
//! tuning vs LoRA, the empirical companion to Proposition 1 (prefix-tuning
//! on an SSM is at most as expressive as tuning the initial hidden state).
//!
//! Expected shape (paper): initial-state tuning ≥ prefix/prompt tuning;
//! LoRA(LinProj) beats all input-injection methods.

use ssm_peft::bench::{bench_cfg, TablePrinter};
use ssm_peft::coordinator::Pipeline;
use ssm_peft::manifest::Manifest;
use ssm_peft::runtime::Engine;

fn main() -> ssm_peft::error::Result<()> {
    let engine = Engine::cpu()?;
    let manifest = Manifest::load(ssm_peft::artifacts_dir())?;
    let p = Pipeline::new(&engine, &manifest);

    let rows: &[(&str, &str)] = &[
        ("mamba1_xs_prompt", "Prompt Tuning"),
        ("mamba1_xs_prefix", "Prefix-Tuning (affix)"),
        ("mamba1_xs_initstate", "Initial State Tuning"),
        ("mamba1_xs_lora_lin", "LoRA (LinProj)"),
    ];
    let subs = ["rte", "sst2", "qnli"];
    let mut table = TablePrinter::new(&["method", "params%", "rte", "sst2", "qnli", "avg"]);
    for (variant, label) in rows {
        let mut cells = vec![label.to_string(), String::new()];
        let mut vals = Vec::new();
        for sub in &subs {
            let cfg = bench_cfg(variant, &format!("glue/{sub}"));
            let out = p.finetune(&cfg)?;
            if cells[1].is_empty() {
                cells[1] = format!("{:.2}", out.budget_pct);
            }
            vals.push(out.metric);
            cells.push(format!("{:.3}", out.metric));
        }
        cells.push(format!("{:.3}", vals.iter().sum::<f64>() / vals.len() as f64));
        table.row(cells);
        table.print();
    }
    println!("\n=== Table 14 (reproduction) ===");
    table.print();
    table.save_csv("table14.csv");
    Ok(())
}
