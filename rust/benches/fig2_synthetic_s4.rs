//! Regenerates **Figure 2**: synthetic deep-S4 regression — MSE vs number
//! of trainable parameters, SDT vs LoRA on the SSM module (LoRA always on
//! the linear projections).
//!
//! Setup mirrors the paper Sec. 6.1: a random 1-layer deep S4 target
//! (H*=4), a 4-layer frozen model (H=16), inputs uniform over integers
//! 0..9, length 200, D=64, MSE over all tokens.
//!
//! Expected shape: the SDT points sit BELOW the LoRA-on-SSM points at equal
//! or smaller parameter counts.

use ssm_peft::error::Result;
use ssm_peft::bench::TablePrinter;
use ssm_peft::coordinator::Pipeline;
use ssm_peft::eval::eval_regression;
use ssm_peft::manifest::Manifest;
use ssm_peft::peft::{select_dimensions, Budget, SdtConfig};
use ssm_peft::runtime::Engine;
use ssm_peft::tensor::Tensor;
use ssm_peft::train::{TrainConfig, Trainer};

const TRAIN_ITERS: usize = 120;
const N_BATCHES: usize = 8;

fn run(engine: &Engine, manifest: &Manifest, variant: &str,
       sdt: Option<SdtConfig>, xs: &[Tensor], ys: &[Tensor],
       xs_test: &[Tensor], ys_test: &[Tensor]) -> Result<(usize, f64)> {
    let tcfg = TrainConfig { lr: 2e-3, schedule_total: TRAIN_ITERS, ..Default::default() };
    let mut tr = Trainer::new(engine, manifest, variant, &tcfg)?;
    let mask = Tensor::from_vec(
        &[tr.variant.batch_b, xs[0].shape[1]],
        vec![1.0; tr.variant.batch_b * xs[0].shape[1]],
    );
    if let Some(cfg) = &sdt {
        // warmup + dimension selection on the regression data
        let before = tr.train_map();
        let snap = tr.snapshot_train();
        for i in 0..cfg.warmup_batches.min(xs.len()) {
            tr.step_reg(&xs[i], &ys[i], &mask)?;
        }
        let after = tr.train_map();
        let (masks, _) = select_dimensions(&tr.variant, &before, &after, cfg);
        tr.restore_train(snap);
        tr.set_masks(masks);
    }
    for it in 0..TRAIN_ITERS {
        let i = it % xs.len();
        tr.step_reg(&xs[i], &ys[i], &mask)?;
    }
    let budget = Budget::of(&tr.variant, Some(tr.masks()));
    let mse = eval_regression(&tr, xs_test, ys_test)?;
    Ok((budget.trainable, mse))
}

fn main() -> Result<()> {
    let engine = Engine::cpu()?;
    let manifest = Manifest::load(ssm_peft::artifacts_dir())?;
    let p = Pipeline::new(&engine, &manifest);

    let (xs, ys) = p.synthetic_s4_data(0, N_BATCHES + 2, 200)?;
    let (xs_test, ys_test) = (&xs[N_BATCHES..], &ys[N_BATCHES..]);
    let (xs, ys) = (&xs[..N_BATCHES], &ys[..N_BATCHES]);

    let mut table = TablePrinter::new(&["method", "trainable", "MSE"]);

    // LoRA on SSM tensors (A/C treated as matrices) + LoRA on projections
    let (n, mse) = run(&engine, &manifest, "s4reg_s4_lora_ssm", None,
                       xs, ys, xs_test, ys_test)?;
    table.row(vec!["LoRA(SSM)+LoRA(proj)".into(), n.to_string(), format!("{mse:.5}")]);
    table.print();

    // LoRA on projections only (control)
    let (n, mse) = run(&engine, &manifest, "s4reg_s4_lora_proj", None,
                       xs, ys, xs_test, ys_test)?;
    table.row(vec!["LoRA(proj) only".into(), n.to_string(), format!("{mse:.5}")]);
    table.print();

    // SDT at several state-freeze ratios -> multiple points on the curve
    for state_freeze in [0.90f32, 0.75, 0.50] {
        let cfg = SdtConfig {
            channel_freeze: 0.875, // 8 of 64 channels trainable
            state_freeze,
            warmup_batches: 4,
            ..Default::default()
        };
        let (n, mse) = run(&engine, &manifest, "s4reg_sdtlora", Some(cfg),
                           xs, ys, xs_test, ys_test)?;
        table.row(vec![
            format!("SDT(sf={state_freeze})+LoRA(proj)"),
            n.to_string(),
            format!("{mse:.5}"),
        ]);
        table.print();
    }

    println!("\n=== Figure 2 (reproduction): MSE vs trainable params ===");
    table.print();
    table.save_csv("fig2.csv");
    Ok(())
}
