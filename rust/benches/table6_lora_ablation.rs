//! Regenerates **Tables 6–10** (condensed): per-module LoRA ablation on
//! Mamba — which weight matrices should LoRA target?
//!
//! Expected shape (paper): LinProj targets (W_in,x/W_in,z/W_out) beat the
//! S6-internal targets (x_proj/dt_proj), and "Both" ≈ "LinProj".

use ssm_peft::bench::{bench_cfg, TablePrinter};
use ssm_peft::coordinator::Pipeline;
use ssm_peft::manifest::Manifest;
use ssm_peft::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let engine = Engine::cpu()?;
    let manifest = Manifest::load(ssm_peft::artifacts_dir())?;
    let p = Pipeline::new(&engine, &manifest);

    let rows: &[(&str, &str)] = &[
        ("mamba1_xs_lora_lin", "W_in,x + W_in,z"),
        ("mamba1_xs_lora_out", "W_out"),
        ("mamba1_xs_lora_ssm", "x_proj + dt_proj (S6)"),
        ("mamba1_xs_lora_both", "LinProj + S6"),
        ("mamba1_xs_bitfit", "bias only (BitFit ref)"),
        ("mamba1_xs_full", "full fine-tuning ref"),
    ];
    let datasets = ["glue/rte", "glue/qnli", "dart"];
    let mut table = TablePrinter::new(&[
        "LoRA target", "params%", "rte", "qnli", "dart(MET)", "dart(BLEU)",
    ]);
    for (variant, label) in rows {
        let mut cells = vec![label.to_string(), String::new()];
        for ds in &datasets {
            let cfg = bench_cfg(variant, ds);
            let out = p.finetune(&cfg)?;
            if cells[1].is_empty() {
                cells[1] = format!("{:.2}", out.budget_pct);
            }
            if *ds == "dart" {
                cells.push(format!("{:.3}", out.scores["meteor"]));
                cells.push(format!("{:.3}", out.scores["bleu"]));
            } else {
                cells.push(format!("{:.3}", out.metric));
            }
        }
        table.row(cells);
        table.print();
    }
    println!("\n=== Tables 6-10 condensed (reproduction) ===");
    table.print();
    table.save_csv("table6.csv");
    Ok(())
}
