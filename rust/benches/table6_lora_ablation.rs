//! Regenerates **Tables 6–10** (condensed): per-module LoRA ablation on
//! Mamba — which weight matrices should LoRA target? Runs as a parallel
//! suite (records in results/table6.jsonl).
//!
//! Expected shape (paper): LinProj targets (W_in,x/W_in,z/W_out) beat the
//! S6-internal targets (x_proj/dt_proj), and "Both" ≈ "LinProj".

use ssm_peft::bench::bench_template;
use ssm_peft::manifest::Manifest;
use ssm_peft::runtime::Engine;
use ssm_peft::suite::{pivot, worker_count, PivotCol, Suite};

fn main() -> ssm_peft::error::Result<()> {
    let engine = Engine::cpu()?;
    let manifest = Manifest::load(ssm_peft::artifacts_dir())?;

    let rows: &[(&str, &[&str])] = &[
        ("mamba1_xs_lora_lin", &["W_in,x + W_in,z"]),
        ("mamba1_xs_lora_out", &["W_out"]),
        ("mamba1_xs_lora_ssm", &["x_proj + dt_proj (S6)"]),
        ("mamba1_xs_lora_both", &["LinProj + S6"]),
        ("mamba1_xs_bitfit", &["bias only (BitFit ref)"]),
        ("mamba1_xs_full", &["full fine-tuning ref"]),
    ];
    let variants: Vec<&str> = rows.iter().map(|(v, _)| *v).collect();
    let datasets: &[&str] = &["glue/rte", "glue/qnli", "dart"];

    let workers = worker_count(2);
    let records = Suite::new(&engine, &manifest)
        .named("table6")
        .template(bench_template())
        .grid(&variants, datasets)
        .run(workers)?;

    let cols = [
        PivotCol::main("rte", "glue/rte"),
        PivotCol::main("qnli", "glue/qnli"),
        PivotCol::score("dart(MET)", "dart", "meteor"),
        PivotCol::score("dart(BLEU)", "dart", "bleu"),
    ];
    let table = pivot(&records, &["LoRA target"], rows, &cols);
    println!("\n=== Tables 6-10 condensed (reproduction, {workers} workers) ===");
    table.print();
    table.save_csv("table6.csv");
    println!("[record stream: results/table6.jsonl]");
    Ok(())
}
