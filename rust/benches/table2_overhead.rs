//! Regenerates **Table 2 / 17 / 18**: SDT overhead — dimension-selection
//! time and per-epoch training time, LoRA vs SDT(&LoRA) at matched budgets,
//! across two Mamba sizes.
//!
//! Expected shape (paper): selection cost ≈ 1–6% of an epoch; SDT&LoRA
//! trains FASTER per epoch than LoRA alone (no low-rank matmul on the SSM
//! tensors).

use ssm_peft::bench::{bench_cfg, TablePrinter};
use ssm_peft::coordinator::Pipeline;
use ssm_peft::manifest::Manifest;
use ssm_peft::runtime::Engine;

fn main() -> ssm_peft::error::Result<()> {
    let engine = Engine::cpu()?;
    let manifest = Manifest::load(ssm_peft::artifacts_dir())?;
    let p = Pipeline::new(&engine, &manifest);

    let rows: &[(&str, &str, &str)] = &[
        ("mamba1_xs_lora_both", "LoRA (SSM+LinProj)", "Mamba-XS"),
        ("mamba1_xs_sdtlora", "LoRA & SDT", "Mamba-XS"),
        ("mamba1_s_lora_lin", "LoRA", "Mamba-S"),
        ("mamba1_s_sdtlora", "LoRA & SDT", "Mamba-S"),
    ];
    let mut table = TablePrinter::new(&[
        "model", "method", "params%", "dim-select (s)", "train/epoch (s)",
        "select/epoch ratio",
    ]);
    for (variant, label, model) in rows {
        let cfg = bench_cfg(variant, "dart");
        let out = p.finetune(&cfg)?;
        let ratio = if out.epoch_s > 0.0 { out.dim_select_s / out.epoch_s } else { 0.0 };
        table.row(vec![
            model.to_string(),
            label.to_string(),
            format!("{:.2}", out.budget_pct),
            format!("{:.2}", out.dim_select_s),
            format!("{:.2}", out.epoch_s),
            format!("{:.3}", ratio),
        ]);
    }
    println!("\n=== Table 2/17/18 (reproduction) ===");
    table.print();
    table.save_csv("table2.csv");
    Ok(())
}
