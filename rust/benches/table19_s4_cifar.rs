//! Regenerates **Table 19**: deep S4 on pixel-sequence classification
//! (CIFAR-10 analogue) — frozen vs LoRA(proj) vs LoRA&SDT vs full FT.
//!
//! Expected shape (paper): LoRA&SDT matches/beats LoRA with fewer trainable
//! parameters; all beat the frozen model.

use ssm_peft::bench::{bench_cfg, TablePrinter};
use ssm_peft::coordinator::Pipeline;
use ssm_peft::eval::eval_classification;
use ssm_peft::manifest::Manifest;
use ssm_peft::runtime::Engine;
use ssm_peft::train::{TrainConfig, Trainer};

fn main() -> ssm_peft::error::Result<()> {
    let engine = Engine::cpu()?;
    let manifest = Manifest::load(ssm_peft::artifacts_dir())?;
    let p = Pipeline::new(&engine, &manifest);
    let mut table = TablePrinter::new(&["method", "params%", "accuracy"]);

    // frozen baseline: pretrained model, no fine-tuning
    {
        let base = p.pretrained("s4lm", 150, 0)?;
        let mut tr = Trainer::new(&engine, &manifest, "s4lm_full", &TrainConfig::default())?;
        tr.load_base(&base);
        let ds = ssm_peft::data::tasks::by_name("cifar10", 0, 8)?;
        let acc = eval_classification(&tr, &ds.test, ds.metric)?;
        table.row(vec!["Frozen".into(), "0.00".into(), format!("{acc:.3}")]);
    }

    for (variant, label) in [
        ("s4lm_s4_lora_proj", "LoRA (Proj)"),
        ("s4lm_sdtlora", "LoRA & SDT"),
        ("s4lm_full", "Full Fine-Tuning"),
    ] {
        let cfg = bench_cfg(variant, "cifar10");
        let out = p.finetune(&cfg)?;
        table.row(vec![
            label.into(),
            format!("{:.2}", out.budget_pct),
            format!("{:.3}", out.metric),
        ]);
        table.print();
    }
    println!("\n=== Table 19 (reproduction) ===");
    table.print();
    table.save_csv("table19.csv");
    Ok(())
}
