//! PJRT runtime: load AOT HLO-text artifacts and execute them.
//!
//! This is the only module that touches the `xla` crate. It wraps:
//!   PjRtClient::cpu() → HloModuleProto::from_text_file → compile → execute
//! behind an `Engine` with an executable cache, plus Tensor↔Literal
//! conversion. Everything above (trainer, PEFT engine, benches) works with
//! plain host tensors.

use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;

use anyhow::{anyhow, Context, Result};

use crate::tensor::{IntTensor, Tensor};

/// Process-wide PJRT engine: one CPU client + compiled-executable cache.
pub struct Engine {
    client: xla::PjRtClient,
    cache: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

// SAFETY: `Engine` is shared by reference across the suite runner's worker
// threads (`std::thread::scope`), so it must be Send + Sync even though the
// wrapped PJRT handles hold raw pointers (which makes the auto traits opt
// out). This is sound because:
// - the PJRT C API guarantees `Compile` and `Execute` are thread-safe on
//   the CPU client (XLA serves them from an internal thread pool; the
//   Python JAX runtime calls them from many threads the same way);
// - the only interior mutability on the Rust side is the executable cache,
//   which is Mutex-guarded;
// - cached executables are handed out as `Arc` clones whose refcount is
//   atomic; dropping the last clone on a different thread only releases
//   the PJRT executable, which is thread-safe to destroy;
// - all per-call state (literals, buffers) is created and consumed on the
//   calling thread.
// Audited for the parallel suite runner (see crate::suite::Suite::run).
unsafe impl Send for Engine {}
unsafe impl Sync for Engine {}

/// A host-side input for an executable: either float or int tensor.
pub enum Input<'a> {
    /// f32 tensor input.
    F(&'a Tensor),
    /// s32 tensor input (token ids).
    I(&'a IntTensor),
}

impl Engine {
    /// Create the PJRT CPU client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine { client, cache: Mutex::new(HashMap::new()) })
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO text file (cached by path).
    pub fn load(&self, path: impl AsRef<Path>) -> Result<Executable> {
        let key = path.as_ref().to_string_lossy().to_string();
        {
            let cache = self.cache.lock().unwrap();
            if let Some(exe) = cache.get(&key) {
                return Ok(Executable { exe: exe.clone() });
            }
        }
        let proto = xla::HloModuleProto::from_text_file(path.as_ref())
            .with_context(|| format!("parsing HLO text {:?}", path.as_ref()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("XLA compile of {:?}", path.as_ref()))?;
        let exe = std::sync::Arc::new(exe);
        self.cache.lock().unwrap().insert(key, exe.clone());
        Ok(Executable { exe })
    }

    /// Number of compiled executables currently cached.
    pub fn cached(&self) -> usize {
        self.cache.lock().unwrap().len()
    }
}

/// A compiled computation, executable with host tensors.
#[derive(Clone)]
pub struct Executable {
    exe: std::sync::Arc<xla::PjRtLoadedExecutable>,
}

/// Convert a float tensor to an XLA literal (one memcpy).
pub fn literal_f32(t: &Tensor) -> Result<xla::Literal> {
    let bytes: &[u8] = unsafe {
        std::slice::from_raw_parts(t.data.as_ptr() as *const u8, t.data.len() * 4)
    };
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::F32, &t.shape, bytes)
        .map_err(|e| anyhow!("literal_f32: {e:?}"))
}

/// Convert an int tensor to an s32 literal.
pub fn literal_i32(t: &IntTensor) -> Result<xla::Literal> {
    let bytes: &[u8] = unsafe {
        std::slice::from_raw_parts(t.data.as_ptr() as *const u8, t.data.len() * 4)
    };
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::S32, &t.shape, bytes)
        .map_err(|e| anyhow!("literal_i32: {e:?}"))
}

/// Read a literal back into a host tensor (shape from the literal).
pub fn tensor_from_literal(lit: &xla::Literal) -> Result<Tensor> {
    let shape = lit.array_shape().map_err(|e| anyhow!("array_shape: {e:?}"))?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data = lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec f32: {e:?}"))?;
    Ok(Tensor::from_vec(&dims, data))
}

impl Executable {
    /// Execute with mixed f32/i32 inputs; returns the flattened output tuple
    /// as host tensors (all exported artifacts return f32 leaves).
    pub fn run(&self, inputs: &[Input]) -> Result<Vec<Tensor>> {
        let lits = self.run_literals(inputs)?;
        lits.iter().map(tensor_from_literal).collect()
    }

    /// Execute with pre-built literals (hot path: the trainer caches the
    /// frozen-parameter literals across steps — §Perf L3 optimization).
    pub fn run_refs(&self, args: &[&xla::Literal]) -> Result<Vec<Tensor>> {
        let bufs = self
            .exe
            .execute::<&xla::Literal>(args)
            .map_err(|e| anyhow!("execute: {e:?}"))?;
        let out = bufs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal_sync: {e:?}"))?;
        let lits = out.to_tuple().map_err(|e| anyhow!("to_tuple: {e:?}"))?;
        lits.iter().map(tensor_from_literal).collect()
    }

    /// Execute and return raw literals (used when outputs are reused as-is).
    pub fn run_literals(&self, inputs: &[Input]) -> Result<Vec<xla::Literal>> {
        let mut args = Vec::with_capacity(inputs.len());
        for inp in inputs {
            args.push(match inp {
                Input::F(t) => literal_f32(t)?,
                Input::I(t) => literal_i32(t)?,
            });
        }
        let bufs = self
            .exe
            .execute::<xla::Literal>(&args)
            .map_err(|e| anyhow!("execute: {e:?}"))?;
        let out = bufs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal_sync: {e:?}"))?;
        // aot.py lowers with return_tuple=True: single tuple output.
        out.to_tuple().map_err(|e| anyhow!("to_tuple: {e:?}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let t = Tensor::from_vec(&[2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let lit = literal_f32(&t).unwrap();
        let back = tensor_from_literal(&lit).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn literal_i32_shape() {
        let t = IntTensor::from_vec(&[4], vec![1, 2, 3, 4]);
        let lit = literal_i32(&t).unwrap();
        assert_eq!(lit.element_count(), 4);
    }
}
