//! PJRT runtime: load AOT HLO-text artifacts and execute them.
//!
//! This is the only module that touches the `xla` crate. It wraps:
//!   PjRtClient::cpu() → HloModuleProto::from_text_file → compile → execute
//! behind an `Engine` with an executable cache, plus Tensor↔Literal
//! conversion. Everything above (trainer, PEFT engine, benches) works with
//! plain host tensors.
//!
//! Buffer-resident execution (§Perf L3/L4, rust/docs/performance.md): the
//! hot paths never rebuild unchanged arguments. [`ResidentArgs`] is a
//! persistent literal table with per-slot dirty tracking — the trainer
//! re-serializes only the leaves the fused optimizer actually touched;
//! [`StatePair`] carries the decode recurrent state from one step's output
//! straight into the next step's input without a Tensor round-trip.

use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;

use crate::err;
use crate::error::{Context, Result};

use crate::tensor::{IntTensor, Tensor};
use crate::xla;

/// Process-wide PJRT engine: one CPU client + compiled-executable cache.
pub struct Engine {
    client: xla::PjRtClient,
    cache: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

// SAFETY: `Engine` is shared by reference across the suite runner's worker
// threads (`std::thread::scope`), so it must be Send + Sync even though the
// wrapped PJRT handles hold raw pointers (which makes the auto traits opt
// out). This is sound because:
// - the PJRT C API guarantees `Compile` and `Execute` are thread-safe on
//   the CPU client (XLA serves them from an internal thread pool; the
//   Python JAX runtime calls them from many threads the same way);
// - the only interior mutability on the Rust side is the executable cache,
//   which is Mutex-guarded;
// - cached executables are handed out as `Arc` clones whose refcount is
//   atomic; dropping the last clone on a different thread only releases
//   the PJRT executable, which is thread-safe to destroy;
// - all per-call state (literals, buffers) is created and consumed on the
//   calling thread.
// Audited for the parallel suite runner (see crate::suite::Suite::run).
unsafe impl Send for Engine {}
unsafe impl Sync for Engine {}

/// A host-side input for an executable: either float or int tensor.
pub enum Input<'a> {
    /// f32 tensor input.
    F(&'a Tensor),
    /// s32 tensor input (token ids).
    I(&'a IntTensor),
}

impl Engine {
    /// Create the PJRT CPU client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine { client, cache: Mutex::new(HashMap::new()) })
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO text file (cached by path).
    pub fn load(&self, path: impl AsRef<Path>) -> Result<Executable> {
        let key = path.as_ref().to_string_lossy().to_string();
        {
            // a worker panicking mid-compile must not wedge every other lane
            let cache = self.cache.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
            if let Some(exe) = cache.get(&key) {
                return Ok(Executable { exe: exe.clone() });
            }
        }
        let proto = xla::HloModuleProto::from_text_file(path.as_ref())
            .with_context(|| format!("parsing HLO text {:?}", path.as_ref()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("XLA compile of {:?}", path.as_ref()))?;
        let exe = std::sync::Arc::new(exe);
        self.cache
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner)
            .insert(key, exe.clone());
        Ok(Executable { exe })
    }

    /// Number of compiled executables currently cached.
    pub fn cached(&self) -> usize {
        self.cache.lock().unwrap_or_else(std::sync::PoisonError::into_inner).len()
    }
}

/// A compiled computation, executable with host tensors.
#[derive(Clone)]
pub struct Executable {
    exe: std::sync::Arc<xla::PjRtLoadedExecutable>,
}

/// Convert a float tensor to an XLA literal (one memcpy).
pub fn literal_f32(t: &Tensor) -> Result<xla::Literal> {
    // SAFETY: viewing `&[f32]` as `&[u8]` of 4x the length: f32 has no
    // invalid bit patterns when read as bytes, the Vec allocation is at
    // least `len * 4` bytes, alignment only decreases (4 -> 1), and the
    // borrow ties the view's lifetime to `t`.
    let bytes: &[u8] = unsafe {
        std::slice::from_raw_parts(t.data.as_ptr() as *const u8, t.data.len() * 4)
    };
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::F32, &t.shape, bytes)
        .map_err(|e| err!("literal_f32: {e:?}"))
}

/// Convert an int tensor to an s32 literal.
pub fn literal_i32(t: &IntTensor) -> Result<xla::Literal> {
    // SAFETY: same argument as `literal_f32` — an `&[i32]` reinterpreted as
    // `&[u8]` of 4x the length is a valid, lifetime-bound byte view.
    let bytes: &[u8] = unsafe {
        std::slice::from_raw_parts(t.data.as_ptr() as *const u8, t.data.len() * 4)
    };
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::S32, &t.shape, bytes)
        .map_err(|e| err!("literal_i32: {e:?}"))
}

/// Convert a shaped f32 slice to an XLA literal (one memcpy) — the arena
/// hot path serializes leaf ranges without materializing a `Tensor`.
pub fn literal_f32_slice(shape: &[usize], data: &[f32]) -> Result<xla::Literal> {
    debug_assert_eq!(shape.iter().product::<usize>(), data.len());
    // SAFETY: same argument as `literal_f32`; `data` is a live `&[f32]`, so
    // the 4x-length byte view stays in bounds and lifetime-bound.
    let bytes: &[u8] = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
    };
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::F32, shape, bytes)
        .map_err(|e| err!("literal_f32_slice: {e:?}"))
}

/// Read a literal back into a host tensor (shape from the literal).
pub fn tensor_from_literal(lit: &xla::Literal) -> Result<Tensor> {
    let shape = lit.array_shape().map_err(|e| err!("array_shape: {e:?}"))?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data = lit.to_vec::<f32>().map_err(|e| err!("to_vec f32: {e:?}"))?;
    Ok(Tensor::from_vec(&dims, data))
}

/// Copy an f32 literal's payload into a caller-owned buffer (the gradient
/// arena / a state mirror) without allocating a `Tensor` or shape vector.
/// (One transient `Vec` still comes from the `xla` wrapper's `to_vec`; the
/// destination storage itself is stable across steps.)
pub fn read_f32_into(lit: &xla::Literal, dst: &mut [f32]) -> Result<()> {
    let v = lit.to_vec::<f32>().map_err(|e| err!("to_vec f32: {e:?}"))?;
    crate::ensure!(
        v.len() == dst.len(),
        "literal has {} elements, destination {}",
        v.len(),
        dst.len()
    );
    dst.copy_from_slice(&v);
    Ok(())
}

/// Read a rank-0/1-element f32 literal (the step artifact's loss output).
pub fn read_scalar_f32(lit: &xla::Literal) -> Result<f32> {
    let v = lit.to_vec::<f32>().map_err(|e| err!("to_vec f32: {e:?}"))?;
    v.first().copied().ok_or_else(|| err!("empty literal where scalar expected"))
}

/// A persistent executable-argument table: literals are uploaded once and
/// re-serialized only for slots the caller marks dirty. The trainer keeps
/// its trainable leaves here; between optimizer steps only the leaves the
/// fused pass actually changed get rebuilt (§Perf L3).
pub struct ResidentArgs {
    lits: Vec<xla::Literal>,
    dirty: Vec<bool>,
}

impl ResidentArgs {
    /// Build the table from initial literals (all slots clean).
    pub fn new(lits: Vec<xla::Literal>) -> ResidentArgs {
        let dirty = vec![false; lits.len()];
        ResidentArgs { lits, dirty }
    }

    /// Build the table by serializing host tensors.
    pub fn from_tensors(ts: &[Tensor]) -> Result<ResidentArgs> {
        Ok(Self::new(ts.iter().map(literal_f32).collect::<Result<Vec<_>>>()?))
    }

    /// Slot count.
    pub fn len(&self) -> usize {
        self.lits.len()
    }

    /// True when the table has no slots.
    pub fn is_empty(&self) -> bool {
        self.lits.is_empty()
    }

    /// Mark one slot stale (its literal no longer matches the host data).
    pub fn mark_dirty(&mut self, i: usize) {
        self.dirty[i] = true;
    }

    /// Mark every slot stale.
    pub fn mark_all_dirty(&mut self) {
        self.dirty.iter_mut().for_each(|d| *d = true);
    }

    /// Whether a slot is stale.
    pub fn is_dirty(&self, i: usize) -> bool {
        self.dirty[i]
    }

    /// True when any slot is stale.
    pub fn any_dirty(&self) -> bool {
        self.dirty.iter().any(|&d| d)
    }

    /// Replace a slot's literal and mark it clean.
    pub fn install(&mut self, i: usize, lit: xla::Literal) {
        self.lits[i] = lit;
        self.dirty[i] = false;
    }

    /// A slot's literal. Callers must refresh dirty slots first — or route
    /// around them with a scratch literal on `&self` paths; asking for a
    /// dirty slot's literal would silently execute with stale parameters,
    /// so debug builds refuse.
    pub fn literal(&self, i: usize) -> &xla::Literal {
        debug_assert!(
            !self.dirty[i],
            "ResidentArgs::literal({i}): slot is dirty — refresh it (install) \
             or serialize a scratch literal instead of reading a stale one"
        );
        &self.lits[i]
    }

    /// All literals in slot order (same freshness contract as
    /// [`ResidentArgs::literal`]: refresh dirty slots first).
    pub fn literals(&self) -> &[xla::Literal] {
        debug_assert!(!self.any_dirty(), "ResidentArgs::literals() with dirty slots");
        &self.lits
    }
}

/// The decode recurrent state as a pair of ready-to-execute literals: the
/// previous step's `(conv', ssm')` outputs fed back as the next step's
/// inputs with zero host round-trips (§Perf L4).
pub struct StatePair {
    /// Conv-state literal `(n_layer, B, d_conv-1, d_inner)`.
    pub conv: xla::Literal,
    /// SSM-state literal `(n_layer, B, d_inner, d_state)`.
    pub ssm: xla::Literal,
}

impl Executable {
    /// Execute with mixed f32/i32 inputs; returns the flattened output tuple
    /// as host tensors (all exported artifacts return f32 leaves).
    pub fn run(&self, inputs: &[Input]) -> Result<Vec<Tensor>> {
        let lits = self.run_literals(inputs)?;
        lits.iter().map(tensor_from_literal).collect()
    }

    /// Execute with pre-built literals (hot path: the trainer caches the
    /// frozen + trainable parameter literals across steps — §Perf L2/L3).
    pub fn run_refs(&self, args: &[&xla::Literal]) -> Result<Vec<Tensor>> {
        let lits = self.run_refs_literals(args)?;
        lits.iter().map(tensor_from_literal).collect()
    }

    /// Execute with pre-built literals and return raw output literals —
    /// the zero-churn paths read gradients straight into the arena and
    /// feed decode state outputs back as the next step's inputs.
    pub fn run_refs_literals(&self, args: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        let bufs = self
            .exe
            .execute::<&xla::Literal>(args)
            .map_err(|e| err!("execute: {e:?}"))?;
        let out = bufs[0][0]
            .to_literal_sync()
            .map_err(|e| err!("to_literal_sync: {e:?}"))?;
        // aot.py lowers with return_tuple=True: single tuple output.
        out.to_tuple().map_err(|e| err!("to_tuple: {e:?}"))
    }

    /// Execute and return raw literals (used when outputs are reused as-is).
    pub fn run_literals(&self, inputs: &[Input]) -> Result<Vec<xla::Literal>> {
        let mut args = Vec::with_capacity(inputs.len());
        for inp in inputs {
            args.push(match inp {
                Input::F(t) => literal_f32(t)?,
                Input::I(t) => literal_i32(t)?,
            });
        }
        let bufs = self
            .exe
            .execute::<xla::Literal>(&args)
            .map_err(|e| err!("execute: {e:?}"))?;
        let out = bufs[0][0]
            .to_literal_sync()
            .map_err(|e| err!("to_literal_sync: {e:?}"))?;
        // aot.py lowers with return_tuple=True: single tuple output.
        out.to_tuple().map_err(|e| err!("to_tuple: {e:?}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let t = Tensor::from_vec(&[2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let lit = literal_f32(&t).unwrap();
        let back = tensor_from_literal(&lit).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn literal_i32_shape() {
        let t = IntTensor::from_vec(&[4], vec![1, 2, 3, 4]);
        let lit = literal_i32(&t).unwrap();
        assert_eq!(lit.element_count(), 4);
    }

    #[test]
    fn literal_slice_and_read_into_roundtrip() {
        let data = [1.5f32, -2.0, 3.25, 0.0, 7.0, 8.0];
        let lit = literal_f32_slice(&[2, 3], &data).unwrap();
        let mut back = [0.0f32; 6];
        read_f32_into(&lit, &mut back).unwrap();
        assert_eq!(back, data);
        assert_eq!(read_scalar_f32(&lit).unwrap(), 1.5);
        let mut wrong = [0.0f32; 4];
        assert!(read_f32_into(&lit, &mut wrong).is_err());
    }

    #[test]
    fn resident_args_dirty_tracking() {
        let t = Tensor::from_vec(&[2], vec![1.0, 2.0]);
        let mut args = ResidentArgs::from_tensors(std::slice::from_ref(&t)).unwrap();
        assert_eq!(args.len(), 1);
        assert!(!args.any_dirty());
        args.mark_dirty(0);
        assert!(args.is_dirty(0));
        let lit = literal_f32_slice(&[2], &[3.0, 4.0]).unwrap();
        args.install(0, lit);
        assert!(!args.any_dirty());
        let back = tensor_from_literal(args.literal(0)).unwrap();
        assert_eq!(back.data, vec![3.0, 4.0]);
    }
}
