//! Timing/memory harness for the `cargo bench` targets, plus the
//! [`hotpath`] telemetry bench behind the `bench hotpath` CLI subcommand
//! and the [`serving`] SLO load harness behind `bench serving`.
//!
//! `criterion` is not available in the offline vendor set, so benches are
//! `harness = false` binaries built on this module: warmup + timed
//! iterations with mean/std, plus RSS sampling from /proc for the memory
//! figures (Fig. 4 / Table 16).

pub mod hotpath;
pub mod serving;

use std::time::Instant;

use crate::tensor::{mean, std_dev};

/// Timing summary of one benchmarked closure.
#[derive(Debug, Clone)]
pub struct Stats {
    /// Bench name (table row label).
    pub name: String,
    /// Mean seconds per iteration.
    pub mean_s: f64,
    /// Sample standard deviation (seconds).
    pub std_s: f64,
    /// Fastest iteration (seconds).
    pub min_s: f64,
    /// Recorded iterations.
    pub iters: usize,
}

impl Stats {
    /// One aligned, human-readable summary line.
    pub fn row(&self) -> String {
        format!(
            "{:<40} {:>10.4}s ± {:>8.4}s (min {:>8.4}s, n={})",
            self.name, self.mean_s, self.std_s, self.min_s, self.iters
        )
    }
}

/// Time a closure: `warmup` unrecorded runs, then `iters` recorded runs.
pub fn time<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    Stats {
        name: name.to_string(),
        mean_s: mean(&samples),
        std_s: std_dev(&samples),
        min_s: samples.iter().cloned().fold(f64::INFINITY, f64::min),
        iters,
    }
}

/// Current resident set size in bytes (Linux).
pub fn rss_bytes() -> usize {
    read_status_kb("VmRSS:") * 1024
}

/// Peak resident set size in bytes (Linux, monotone per process).
pub fn peak_rss_bytes() -> usize {
    read_status_kb("VmHWM:") * 1024
}

fn read_status_kb(key: &str) -> usize {
    let Ok(s) = std::fs::read_to_string("/proc/self/status") else {
        return 0;
    };
    for line in s.lines() {
        if let Some(rest) = line.strip_prefix(key) {
            return rest
                .trim()
                .trim_end_matches(" kB")
                .trim()
                .parse()
                .unwrap_or(0);
        }
    }
    0
}

/// Analytic fine-tuning memory model (bytes): parameters + gradients over
/// trainable + AdamW moments (2×trainable) + activation estimate. Used for
/// the Fig. 4 memory comparison where same-process RSS is too noisy to
/// attribute (documented in EXPERIMENTS.md).
pub fn training_memory_model(total_params: usize, trainable: usize,
                             act_floats: usize) -> usize {
    4 * (total_params + 3 * trainable + act_floats)
}

/// Simple aligned table printer for bench outputs that mirror paper tables.
pub struct TablePrinter {
    /// Column headers.
    pub headers: Vec<String>,
    /// Table rows (cells as strings).
    pub rows: Vec<Vec<String>>,
}

impl TablePrinter {
    /// Table with the given column headers.
    pub fn new(headers: &[&str]) -> Self {
        TablePrinter { headers: headers.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }
    /// Append one row.
    pub fn row(&mut self, cells: Vec<String>) {
        self.rows.push(cells);
    }
    /// Print the table, columns aligned to the widest cell.
    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(String::len).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                if i < widths.len() {
                    widths[i] = widths[i].max(c.len());
                }
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!("{:<w$}  ", c, w = widths.get(i).copied().unwrap_or(8)));
            }
            println!("{}", s.trim_end());
        };
        line(&self.headers);
        println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        for r in &self.rows {
            line(r);
        }
    }
    /// Write as CSV into results/ for EXPERIMENTS.md.
    pub fn save_csv(&self, name: &str) {
        let mut s = self.headers.join(",") + "\n";
        for r in &self.rows {
            s.push_str(&r.join(","));
            s.push('\n');
        }
        let path = crate::results_dir().join(name);
        std::fs::write(&path, s).ok();
        println!("[saved {}]", path.display());
    }
}

/// Shared bench defaults (no variant/dataset): the template the Suite-based
/// table benches hand to `Suite::template`. Small-but-real runs sized for
/// the 1-core CPU testbed; `SSM_PEFT_BENCH_SCALE` (float) scales
/// epochs/batches up or down.
pub fn bench_template() -> crate::config::ExperimentConfig {
    let scale: f32 = crate::knobs::bench_scale();
    let mut cfg = crate::config::ExperimentConfig::default();
    cfg.n_train = 256;
    cfg.epochs = ((2.0 * scale).round() as usize).max(1);
    cfg.max_batches_per_epoch = ((12.0 * scale).round() as usize).max(2);
    cfg.pretrain_steps = 150;
    cfg.lr_grid = vec![3e-3];
    cfg.sdt.warmup_batches = 6;
    cfg.gen_max_new = 48;
    cfg
}

/// One-cell bench config (single-experiment benches; the table benches go
/// through `Suite` instead).
pub fn bench_cfg(variant: &str, dataset: &str) -> crate::config::ExperimentConfig {
    let mut cfg = bench_template();
    cfg.variant = variant.into();
    cfg.dataset = dataset.into();
    cfg
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_measures() {
        let st = time("sleep", 1, 3, || std::thread::sleep(std::time::Duration::from_millis(5)));
        assert!(st.mean_s >= 0.004, "{}", st.mean_s);
        assert_eq!(st.iters, 3);
    }

    #[test]
    fn rss_positive() {
        assert!(rss_bytes() > 0);
        assert!(peak_rss_bytes() >= rss_bytes() / 2);
    }

    #[test]
    fn memory_model_monotone_in_trainable() {
        let a = training_memory_model(1000, 10, 0);
        let b = training_memory_model(1000, 500, 0);
        assert!(b > a);
    }

    #[test]
    fn table_printer_csv() {
        let mut t = TablePrinter::new(&["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        assert_eq!(t.rows.len(), 1);
    }
}
