//! `bench serving`: the SLO load harness behind the `bench serving` CLI
//! subcommand, emitted as `results/BENCH_serving.json`.
//!
//! Drives the in-process continuous-batching [`Scheduler`] with host mock
//! models under a seeded open-loop workload — Poisson arrivals, mixed
//! prompt/output lengths, a skewed adapter mix (8:4:2:1 over four
//! adapters) — at two offered-load points, plus a closed-loop multi-turn
//! session-reuse point over the durable session store. Per-request
//! latency comes from the scheduler's span traces; TTFT and inter-token
//! percentiles are exact (computed from the raw sorted samples, not the
//! log2 histogram buckets).
//!
//! The whole harness runs on a [`VirtualClock`] advanced one
//! [`TICK_NS`] tick per scheduler tick, so every emitted number is a pure
//! function of the seed (`SSM_PEFT_SERVING_SEED`) and the scale
//! (`SSM_PEFT_BENCH_SCALE`): the same seed produces a byte-identical
//! `BENCH_serving.json`, run to run and across worker counts. The JSON
//! schema is documented in rust/docs/observability.md.

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::error::Result;

use crate::eval::testing::Accum;
use crate::json::{self, Value};
use crate::obs::{rate_per_s, VirtualClock, TICK_NS};
use crate::serve::{LaneModel, Request, Scheduler, ServeModel, SessionStore};
use crate::tensor::Rng;

/// `BENCH_serving.json` schema version. The lint pins this against the
/// example payload in rust/docs/observability.md, so bumping it without a
/// docs update fails `cargo run -- lint`.
pub const BENCH_SERVING_SCHEMA: u32 = 1;

/// Number of adapters in the skewed mix.
const ADAPTERS: usize = 4;

/// Uniform draw in (0, 1] — never 0, so `ln` is always finite.
fn unit(rng: &mut Rng) -> f64 {
    ((rng.next_u64() >> 11) + 1) as f64 / (1u64 << 53) as f64
}

/// Exponential inter-arrival gap in whole ticks for an offered load of
/// `lambda` requests/tick (Poisson process), floored at 1 tick.
pub(crate) fn poisson_gap_ticks(rng: &mut Rng, lambda: f64) -> u64 {
    let gap = (-unit(rng).ln() / lambda).ceil();
    (gap as u64).max(1)
}

/// Draw an adapter index with 8:4:2:1 skew over [`ADAPTERS`] adapters.
fn skewed_adapter(rng: &mut Rng) -> usize {
    match rng.next_u64() % 15 {
        0..=7 => 0,
        8..=11 => 1,
        12..=13 => 2,
        _ => 3,
    }
}

/// Exact percentile (nearest-rank) of an ascending-sorted sample set.
pub(crate) fn percentile(sorted: &[u64], q: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let rank = ((q.clamp(0.0, 1.0) * sorted.len() as f64).ceil() as usize)
        .clamp(1, sorted.len());
    sorted[rank - 1]
}

fn pctl_obj(mut samples: Vec<u64>) -> Value {
    samples.sort_unstable();
    json::obj(vec![
        ("p50", json::num(percentile(&samples, 0.50) as f64)),
        ("p95", json::num(percentile(&samples, 0.95) as f64)),
        ("p99", json::num(percentile(&samples, 0.99) as f64)),
        ("max", json::num(samples.last().copied().unwrap_or(0) as f64)),
        ("samples", json::num(samples.len() as f64)),
    ])
}

/// Per-adapter mock factory: each adapter gets a distinct hash offset so
/// outputs differ per adapter (as real per-adapter deltas would).
fn mock_factory() -> crate::serve::ServeFactory<'static> {
    Box::new(move |adapter: &str| {
        let idx = adapter.bytes().map(u64::from).sum::<u64>() % ADAPTERS as u64;
        let model = Arc::new(Accum::with_off(1, &[8, 32], 1.0 + idx as f32));
        Ok(ServeModel::Merged(LaneModel { model, h0: None }))
    })
}

/// Aggregate one load point's responses + traces into its JSON record.
fn aggregate(
    label: &str,
    offered_rps: f64,
    requests: usize,
    sched: &Scheduler,
    clean: usize,
    output_bytes: usize,
    elapsed_s: f64,
) -> Value {
    let mut ttft = Vec::new();
    let mut itl = Vec::new();
    let mut queued = Vec::new();
    for t in sched.traces().iter() {
        queued.push(t.span.queued_ns());
        if t.span.first_token_ns > 0 {
            ttft.push(t.span.ttft_ns());
            if t.new_tokens >= 2 {
                itl.push(t.span.decode_ns() / (t.new_tokens as u64 - 1));
            }
        }
    }
    json::obj(vec![
        ("label", json::s(label)),
        ("offered_rps", json::num(offered_rps)),
        ("requests", json::num(requests as f64)),
        ("completed_clean", json::num(clean as f64)),
        ("failed", json::num((requests - clean) as f64)),
        ("elapsed_s", json::num(elapsed_s)),
        ("output_bytes", json::num(output_bytes as f64)),
        ("ttft_ns", pctl_obj(ttft)),
        ("itl_ns", pctl_obj(itl)),
        ("queued_ns", pctl_obj(queued)),
        ("tok_per_s", json::num(rate_per_s(output_bytes as f64, elapsed_s))),
        ("goodput_rps", json::num(rate_per_s(clean as f64, elapsed_s))),
        ("resurrections", json::num(sched.session_resurrections as f64)),
        ("demotions", json::num(sched.demotions as f64)),
    ])
}

/// One open-loop Poisson point: `requests` arrivals at `lambda` req/tick,
/// run to drain on a virtual clock.
fn run_open_loop(label: &str, lambda: f64, requests: usize, seed: u64) -> Result<Value> {
    let mut rng = Rng::new(seed ^ 0x5E11);
    // pre-generate the arrival schedule so the load is independent of
    // scheduler behavior (open loop)
    let mut arrivals: Vec<(u64, Request)> = Vec::with_capacity(requests);
    let mut at = 0u64;
    for id in 0..requests {
        at += poisson_gap_ticks(&mut rng, lambda);
        let prompt_len = 4 + (rng.next_u64() % 29) as usize;
        let prompt: Vec<u8> =
            (0..prompt_len).map(|i| ((id * 31 + i * 7) % 199 + 1) as u8).collect();
        let max_new = 2 + (rng.next_u64() % 9) as usize;
        let req = Request {
            id: id as u64,
            adapter: format!("a{}", skewed_adapter(&mut rng)),
            prompt,
            max_new,
            stop_byte: 0,
            beam: 1,
            deadline: 0,
            session: None,
        };
        arrivals.push((at, req));
    }

    let clock = Arc::new(VirtualClock::new());
    let mut sched = Scheduler::new(mock_factory(), ADAPTERS);
    sched.set_clock(clock.clone());
    sched.set_trace_capacity(requests + 16);
    let backstop = arrivals.last().map_or(0, |(t, _)| *t) + (requests as u64 + 8) * 64;
    let mut responses = Vec::with_capacity(requests);
    let mut next = 0usize;
    let mut tick = 0u64;
    while next < arrivals.len() || !sched.is_idle() {
        while next < arrivals.len() && arrivals[next].0 <= tick {
            let (_, req) = arrivals[next].clone();
            sched.submit(req);
            next += 1;
        }
        clock.advance_ticks(1);
        responses.append(&mut sched.tick());
        tick += 1;
        if tick > backstop {
            crate::bail!("bench serving point {label:?} did not drain in {backstop} ticks");
        }
    }
    let clean = responses.iter().filter(|r| r.error.is_none()).count();
    let bytes: usize = responses.iter().map(|r| r.output.len()).sum();
    let elapsed_s = clock.now_ns() as f64 * 1e-9;
    // offered load in req/s of virtual time: lambda per tick, TICK_NS ticks
    let offered_rps = lambda * (1e9 / TICK_NS as f64);
    Ok(aggregate(label, offered_rps, requests, &sched, clean, bytes, elapsed_s))
}

/// The closed-loop session-reuse point: a pool of conversations, each run
/// turn by turn over the durable session store (turn N+1's prompt = full
/// prior history + fresh bytes), so later turns resurrect state instead
/// of re-prefilling.
fn run_session_reuse(pool: usize, turns: usize, seed: u64) -> Result<Value> {
    let mut rng = Rng::new(seed ^ 0x5E55);
    let clock = Arc::new(VirtualClock::new());
    let mut sched = Scheduler::new(mock_factory(), ADAPTERS);
    sched.set_clock(clock.clone());
    sched.set_trace_capacity(pool * turns + 16);
    sched.set_session_store(Arc::new(SessionStore::new(pool * 2)));
    let mut histories: Vec<Vec<u8>> = (0..pool)
        .map(|s| (0..8).map(|i| ((s * 47 + i * 7 + 3) % 199 + 1) as u8).collect())
        .collect();
    let requests = pool * turns;
    let mut clean = 0usize;
    let mut bytes = 0usize;
    let mut id = 0u64;
    for t in 0..turns {
        for s in 0..pool {
            sched.submit(Request {
                id,
                adapter: format!("a{}", s % ADAPTERS),
                prompt: histories[s].clone(),
                max_new: 2 + (rng.next_u64() % 4) as usize,
                stop_byte: 0,
                beam: 1,
                deadline: 0,
                session: Some(format!("conv-{s}")),
            });
            id += 1;
            // closed loop: run this turn to completion before the next
            let mut got = Vec::new();
            while !sched.is_idle() {
                clock.advance_ticks(1);
                got.append(&mut sched.tick());
            }
            let Some(r) = got.pop() else {
                crate::bail!("session turn {t}/{s} did not retire");
            };
            if r.error.is_none() {
                clean += 1;
            }
            bytes += r.output.len();
            histories[s].extend_from_slice(&r.output);
            histories[s].extend((0..3).map(|i| ((t * 29 + i * 7 + 11) % 199 + 1) as u8));
        }
    }
    let elapsed_s = clock.now_ns() as f64 * 1e-9;
    let offered = rate_per_s(requests as f64, elapsed_s);
    Ok(aggregate("session_reuse", offered, requests, &sched, clean, bytes, elapsed_s))
}

/// Run the serving load harness and write `results/BENCH_serving.json`.
pub fn run(_kvs: &BTreeMap<String, String>) -> Result<()> {
    let scale = crate::knobs::bench_scale();
    let seed = crate::knobs::serving_seed();
    let requests = ((48.0 * scale).round() as usize).max(12);
    let turns = ((6.0 * scale).round() as usize).max(3);

    // two offered-load points (req/tick of the 1 ms virtual tick):
    // moderate load, then pressure well past the mock's service rate
    let points = vec![
        run_open_loop("load_low", 0.05, requests, seed)?,
        run_open_loop("load_high", 0.25, requests, seed)?,
        run_session_reuse(3, turns, seed)?,
    ];

    println!("\n=== bench serving (scale {scale}, seed {seed}) ===");
    for p in &points {
        let gets = |k: &str| p.get(k).and_then(Value::as_str).unwrap_or("?").to_string();
        let get = |k: &str| p.get(k).and_then(Value::as_f64).unwrap_or(0.0);
        let sub = |k: &str, q: &str| {
            p.get(k).and_then(|v| v.get(q)).and_then(Value::as_f64).unwrap_or(0.0)
        };
        println!(
            "{:<14} offered {:>7.1} rps | goodput {:>7.1} rps | {:>8.0} tok/s | \
             TTFT p50/p95/p99 {:.1}/{:.1}/{:.1} ms | ITL p50 {:.2} ms | {} ok / {} req",
            gets("label"),
            get("offered_rps"),
            get("goodput_rps"),
            get("tok_per_s"),
            sub("ttft_ns", "p50") / 1e6,
            sub("ttft_ns", "p95") / 1e6,
            sub("ttft_ns", "p99") / 1e6,
            sub("itl_ns", "p50") / 1e6,
            get("completed_clean"),
            get("requests"),
        );
    }

    let root = json::obj(vec![
        ("schema", json::num(BENCH_SERVING_SCHEMA as f64)),
        ("scale", json::num(scale as f64)),
        ("seed", json::num(seed as f64)),
        ("tick_ns", json::num(TICK_NS as f64)),
        ("points", Value::Arr(points)),
    ]);
    let path = crate::results_dir().join("BENCH_serving.json");
    std::fs::write(&path, json::emit(&root))?;
    println!("[saved {}]", path.display());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_gaps_are_positive_and_seeded() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        let ga: Vec<u64> = (0..200).map(|_| poisson_gap_ticks(&mut a, 0.1)).collect();
        let gb: Vec<u64> = (0..200).map(|_| poisson_gap_ticks(&mut b, 0.1)).collect();
        assert_eq!(ga, gb, "same seed, same schedule");
        assert!(ga.iter().all(|&g| g >= 1));
        // mean gap ~ 1/lambda = 10 ticks; allow wide slack, the point is
        // "roughly exponential", not a statistical test
        let mean = ga.iter().sum::<u64>() as f64 / ga.len() as f64;
        assert!((3.0..30.0).contains(&mean), "mean gap {mean}");
    }

    #[test]
    fn percentile_is_exact_nearest_rank() {
        let v: Vec<u64> = (1..=100).collect();
        assert_eq!(percentile(&v, 0.50), 50);
        assert_eq!(percentile(&v, 0.95), 95);
        assert_eq!(percentile(&v, 0.99), 99);
        assert_eq!(percentile(&v, 1.0), 100);
        assert_eq!(percentile(&v, 0.0), 1, "rank floors at the first sample");
        assert_eq!(percentile(&[], 0.5), 0, "empty = 0");
        assert_eq!(percentile(&[42], 0.99), 42);
    }

    #[test]
    fn open_loop_point_is_byte_identical_across_runs() {
        // acceptance: virtual clock + fixed seed => identical JSON bytes
        let a = run_open_loop("t", 0.2, 12, 99).unwrap();
        let b = run_open_loop("t", 0.2, 12, 99).unwrap();
        assert_eq!(json::emit(&a), json::emit(&b));
        // and the shape carries the SLO aggregates the CI smoke asserts
        for k in ["ttft_ns", "itl_ns", "tok_per_s", "goodput_rps", "offered_rps"] {
            assert!(a.get(k).is_some(), "missing {k}");
        }
        for q in ["p50", "p95", "p99"] {
            assert!(a.get("ttft_ns").unwrap().get(q).is_some(), "ttft {q}");
        }
        let req = a.get("requests").unwrap().as_usize().unwrap();
        let clean = a.get("completed_clean").unwrap().as_usize().unwrap();
        assert_eq!((req, clean), (12, 12), "mock load completes cleanly");
        assert!(a.get("tok_per_s").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn session_reuse_point_resurrects_and_is_deterministic() {
        let a = run_session_reuse(2, 3, 5).unwrap();
        let b = run_session_reuse(2, 3, 5).unwrap();
        assert_eq!(json::emit(&a), json::emit(&b));
        let res = a.get("resurrections").unwrap().as_usize().unwrap();
        assert!(res >= 2, "later turns resume from the store (got {res})");
        assert_eq!(
            a.get("requests").unwrap().as_usize(),
            a.get("completed_clean").unwrap().as_usize(),
        );
    }

    #[test]
    fn different_seeds_change_the_schedule_not_the_shape() {
        let a = run_open_loop("t", 0.2, 12, 1).unwrap();
        let b = run_open_loop("t", 0.2, 12, 2).unwrap();
        assert_ne!(json::emit(&a), json::emit(&b), "seed actually feeds the load");
        assert_eq!(a.get("requests"), b.get("requests"));
    }
}
