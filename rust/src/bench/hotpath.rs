//! `bench hotpath`: per-step latency breakdown + decode tokens/sec for the
//! fused parameter-arena hot path, emitted as `results/BENCH_hotpath.json`.
//!
//! Two modes, chosen automatically:
//!
//! - **mock** (always available, used by the CI `bench-smoke` job): a
//!   synthetic Mamba-shaped parameter set compares the legacy three-pass
//!   host optimizer (per-step grad clone → mask → clip → AdamW) against
//!   the fused arena pass, across mask scenarios and worker counts.
//! - **artifacts** (when `make artifacts` has run): real [`Trainer`] steps
//!   on the smallest step-capable variant with the per-phase
//!   [`StepTimings`] breakdown, a measured legacy-host reconstruction on
//!   the same shapes, and greedy-decode throughput with resident vs
//!   reference (re-serializing) parameter/state handling.
//!
//! Schema 2 adds a `prefill` section (§Perf L5): chunked-vs-stepwise
//! prompt ingestion — dispatches/request and tok/s — measured on a host
//! mock in every run (dispatch counts are the durable signal there) and
//! on the real prefill executables in artifacts mode.
//!
//! Schema 3 adds an `adapters` section: unmerged batched multi-adapter
//! decode — one shared batch carrying distinct per-row deltas (ONE
//! dispatch per step) against per-adapter merged lanes (one dispatch per
//! adapter per step), plus the resident-KB cost of a raw delta vs a
//! whole-model merged copy. Host mocks; the dispatch counts and byte
//! sizes are the durable signal.
//!
//! Schema 4 adds a `faults` section: the serve scheduler under
//! deterministic seeded exec faults (transient kind), comparing a healthy
//! pass against a degraded pass of the same request mix — injected
//! faults, in-place retries, request outcomes, and the throughput cost of
//! recovery. The fault/retry counters are the durable signal.
//!
//! Schema 5 adds a `sessions` section: multi-turn conversation latency
//! with the durable session store (state resurrected at admission, zero
//! prefill after turn 1) against stateless full-history re-prefill, plus
//! a simulated crash — drain to disk, drop everything, recover, resume —
//! pinned byte-identical to a fresh replay. The prefill-chunk and
//! resurrection counters are the durable signal.
//!
//! `SSM_PEFT_BENCH_SCALE` scales iteration counts and the synthetic model
//! size (0.1 = tiny CI mode). The JSON schema is documented in
//! rust/docs/performance.md; every number is a mean over timed iterations.

use std::collections::BTreeMap;
use std::time::Instant;

use crate::error::{Context, Result};

use crate::bench::{time, TablePrinter};
use crate::data::tasks;
use crate::eval::{greedy_decode, DecodeCore, DecodeState, StepDecode};
use crate::json::{self, Value};
use crate::manifest::Manifest;
use crate::optim::{
    clip_global_norm, fused_workers, AdamW, FusedAdamW, MaskPlan, ParamArena,
};
use crate::peft::Masks;
use crate::runtime::Engine;
use crate::tensor::{IntTensor, Rng, Tensor};
use crate::train::{StepTimings, TrainConfig, Trainer};

/// `BENCH_hotpath.json` schema version. The lint pins this against the
/// example payload in rust/docs/performance.md, so bumping it without a
/// docs update fails `cargo run -- lint`.
pub const BENCH_HOTPATH_SCHEMA: u32 = 5;

fn bench_scale() -> f32 {
    crate::knobs::bench_scale()
}

/// Synthetic Mamba-shaped trainable leaves (per layer: A_log, xproj, out).
fn synth_leaves(scale: f32, rng: &mut Rng) -> Vec<Tensor> {
    let di = ((256.0 * scale.sqrt()).round() as usize).max(16);
    let (h, r, layers) = (16usize, 8usize, 4usize);
    let mut leaves = Vec::new();
    for _ in 0..layers {
        for shape in [vec![di, h], vec![di, r + 2 * h], vec![di, di]] {
            let n: usize = shape.iter().product();
            let data: Vec<f32> = (0..n).map(|_| rng.normal() * 0.1).collect();
            leaves.push(Tensor::from_vec(&shape, data));
        }
    }
    leaves
}

fn synth_grads(leaves: &[Tensor], rng: &mut Rng) -> Vec<Tensor> {
    leaves
        .iter()
        .map(|t| {
            let data: Vec<f32> = (0..t.numel()).map(|_| rng.normal() * 0.01).collect();
            Tensor::from_vec(&t.shape, data)
        })
        .collect()
}

/// Mask scenario: `None` = unmasked, `Some(keep_every)` = binary mask with
/// one active entry per `keep_every` (SDT-like sparsity).
fn scenario_masks(leaves: &[Tensor], keep_every: Option<usize>) -> Masks {
    match keep_every {
        None => Masks::none(leaves.len()),
        Some(k) => Masks {
            masks: leaves
                .iter()
                .map(|t| {
                    Some(
                        (0..t.numel())
                            .map(|j| if j % k == 0 { 1.0 } else { 0.0 })
                            .collect(),
                    )
                })
                .collect(),
        },
    }
}

/// One mock scenario: legacy three-pass vs fused pass (1 and N workers).
fn mock_scenario(
    name: &str,
    leaves: &[Tensor],
    grads: &[Tensor],
    masks: &Masks,
    iters: usize,
    workers: usize,
    table: &mut TablePrinter,
) -> (String, Value) {
    let mut params = leaves.to_vec();
    let mut opt = AdamW::new(&params);
    opt.weight_decay = 0.01;
    let legacy = time("legacy", 1, iters, || {
        // the legacy readback path materialized fresh grad tensors every
        // step; the clone reproduces that cost
        let mut g = grads.to_vec();
        masks.apply(&mut g);
        clip_global_norm(&mut g, 1.0);
        opt.step(&mut params, &g, 1e-3);
    });

    let mut fused_means = Vec::new();
    let wlist: Vec<usize> = if workers > 1 { vec![1, workers] } else { vec![1] };
    for w in wlist {
        let mut arena = ParamArena::pack(leaves);
        let garena = ParamArena::pack(grads);
        let mut fopt = FusedAdamW::new(&arena);
        fopt.weight_decay = 0.01;
        let (m, v) = (fopt.moments().0.to_vec(), fopt.moments().1.to_vec());
        let plan = MaskPlan::compile(&masks.masks, &arena, &m, &v);
        let st = time(&format!("fused w{w}"), 1, iters, || {
            fopt.step(&mut arena, garena.data(), &plan, 1e-3, 1.0, w);
        });
        fused_means.push((w, st.mean_s));
    }
    let fused_best = fused_means.iter().map(|&(_, s)| s).fold(f64::INFINITY, f64::min);
    let speedup = legacy.mean_s / fused_best.max(1e-12);
    table.row(vec![
        name.into(),
        leaves.iter().map(Tensor::numel).sum::<usize>().to_string(),
        format!("{:.6}", legacy.mean_s),
        format!("{:.6}", fused_means[0].1),
        format!("{:.6}", fused_means.last().map_or(f64::NAN, |&(_, s)| s)),
        format!("{speedup:.1}x"),
    ]);
    let mut fields = vec![
        ("n_params", json::num(leaves.iter().map(Tensor::numel).sum::<usize>() as f64)),
        ("legacy_host_s", json::num(legacy.mean_s)),
        ("speedup", json::num(speedup)),
    ];
    for (w, s) in &fused_means {
        fields.push(match w {
            1 => ("fused_host_s_w1", json::num(*s)),
            _ => ("fused_host_s_wn", json::num(*s)),
        });
    }
    (name.to_string(), json::obj(fields))
}

/// Real-artifact training telemetry: fused per-phase means plus a measured
/// legacy-host reconstruction (serialize ALL leaves + materialize grad
/// tensors + three passes) on the same shapes.
fn bench_train(engine: &Engine, manifest: &Manifest, scale: f32)
    -> Result<(String, Value)> {
    // smallest step-capable variant; prefer the canonical full model
    let variant = if manifest.variants.contains_key("mamba1_xs_full") {
        "mamba1_xs_full".to_string()
    } else {
        manifest
            .variants
            .iter()
            .find(|(_, v)| v.step_file.is_some() && v.fwd_file.is_some() && !v.reg)
            .map(|(k, _)| k.clone())
            .ok_or_else(|| crate::err!("no step-capable variant in manifest"))?
    };
    let steps = ((12.0 * scale).round() as usize).max(4);
    let mut tr = Trainer::new(engine, manifest, &variant, &TrainConfig::default())?;
    let ds = tasks::by_name("dart", 0, 64)?;
    let mut rng = Rng::new(0);
    let mut it = crate::data::BatchIter::new(
        &ds.train, &mut rng, tr.variant.batch_b, tr.variant.batch_l,
    );
    let (batch, _) = it.next().context("empty dart dataset for hotpath bench")?;
    for _ in 0..2 {
        tr.step(&batch)?; // warmup (compile caches, allocator)
    }
    let before = tr.timings_total();
    let c0 = tr.step_count;
    for _ in 0..steps {
        tr.step(&batch)?;
    }
    let mut totals = tr.timings_total();
    totals.accumulate(&before.scaled(-1.0));
    let mean: StepTimings = totals.scaled(1.0 / (tr.step_count - c0) as f64);

    // legacy host reconstruction on the live shapes
    let params = tr.snapshot_train();
    let grads = tr.last_grads();
    let masks = tr.masks().clone();
    let mut lparams = params.clone();
    let mut lopt = AdamW::new(&lparams);
    let legacy = time("legacy host", 1, steps.max(3), || {
        // upload: serialize every trainable leaf
        let _lits: Vec<_> = lparams
            .iter()
            .filter_map(|t| crate::runtime::literal_f32(t).ok())
            .collect();
        // readback: materialize fresh grad tensors
        let mut g: Vec<Tensor> = grads
            .iter()
            .map(|t| Tensor::from_vec(&t.shape, t.data.clone()))
            .collect();
        // three host passes
        masks.apply(&mut g);
        clip_global_norm(&mut g, 1.0);
        lopt.step(&mut lparams, &g, 1e-3);
    });
    let fused_host = mean.host_s();
    let fields = vec![
        ("variant", json::s(&variant)),
        ("steps", json::num(steps as f64)),
        ("upload_s", json::num(mean.upload_s)),
        ("execute_s", json::num(mean.execute_s)),
        ("readback_s", json::num(mean.readback_s)),
        ("optim_s", json::num(mean.optim_s)),
        ("host_s", json::num(fused_host)),
        ("total_s", json::num(mean.total_s())),
        ("legacy_host_s", json::num(legacy.mean_s)),
        ("host_overhead_reduction", json::num(legacy.mean_s / fused_host.max(1e-12))),
    ];
    Ok((variant, json::obj(fields)))
}

/// Reference decode model: re-serializes parameters and round-trips the
/// state through the host every token (the pre-arena behavior).
struct ReferenceDecode<'a>(&'a DecodeCore);

impl StepDecode for ReferenceDecode<'_> {
    fn arch_b(&self) -> usize {
        self.0.arch_b()
    }
    fn dims(&self) -> crate::eval::StateDims {
        self.0.dims()
    }
    fn step(&self, tokens: &IntTensor, state: &mut DecodeState) -> Result<Tensor> {
        self.0.step_reference(tokens, state)
    }
}

/// Resident decode model with chunked prefill masked off: the stepwise
/// prompt-ingestion baseline for the `prefill` section (inherits the
/// default `chunk_prefill() -> None`).
struct StepwiseOnly<'a>(&'a DecodeCore);

impl StepDecode for StepwiseOnly<'_> {
    fn arch_b(&self) -> usize {
        self.0.arch_b()
    }
    fn dims(&self) -> crate::eval::StateDims {
        self.0.dims()
    }
    fn step(&self, tokens: &IntTensor, state: &mut DecodeState) -> Result<Tensor> {
        self.0.step(tokens, state)
    }
}

/// Bench prompts: `b` rows of deterministic bytes, `plen` long.
fn bench_prompts(b: usize, plen: usize) -> Vec<Vec<u8>> {
    (0..b)
        .map(|r| (0..plen).map(|i| ((i * 7 + r * 13 + 3) % 251) as u8).collect())
        .collect()
}

/// One timed greedy pass; returns (mean seconds, tokens per pass).
fn time_greedy(model: &dyn StepDecode, prompts: &[Vec<u8>], max_new: usize,
               iters: usize) -> Result<(f64, usize)> {
    let outs = crate::eval::greedy_decode(model, prompts, max_new, b'\n', None)?;
    let tokens: usize =
        prompts.iter().map(Vec::len).sum::<usize>() + outs.iter().map(Vec::len).sum::<usize>();
    let mut err = None;
    let st = time("greedy", 0, iters, || {
        if let Err(e) = crate::eval::greedy_decode(model, prompts, max_new, b'\n', None) {
            err = Some(e);
        }
    });
    match err {
        Some(e) => Err(e),
        None => Ok((st.mean_s, tokens)),
    }
}

/// The `prefill` section's mock half: chunked-vs-stepwise prompt
/// ingestion on the host mock. Times only say "the harness works" here;
/// the dispatch counts are the durable telemetry (they cannot drift
/// without a planner change).
fn bench_prefill_mock(scale: f32) -> Result<Value> {
    use std::sync::atomic::Ordering;
    let b = 4usize;
    let plen = ((96.0 * scale).round() as usize).max(24);
    let max_new = 4usize;
    let iters = ((10.0 * scale).round() as usize).max(3);
    let prompts = bench_prompts(b, plen);
    let widths = [16usize, 64];

    let chunked = crate::eval::testing::Accum::new(b, &widths);
    let (chunked_s, tokens) = time_greedy(&chunked, &prompts, max_new, iters)?;
    let runs = (iters + 1) as u64; // count-establishing run + timed runs
    let chunk_d = chunked.chunks.load(Ordering::Relaxed) / runs;
    let chunk_steps = chunked.steps.load(Ordering::Relaxed) / runs;

    let stepwise = crate::eval::testing::Accum::new(b, &[]);
    let (stepwise_s, _) = time_greedy(&stepwise, &prompts, max_new, iters)?;
    let step_d = stepwise.steps.load(Ordering::Relaxed) / runs;

    let chunked_total = chunk_d + chunk_steps;
    Ok(json::obj(vec![
        ("widths", Value::Arr(widths.iter().map(|&w| json::num(w as f64)).collect())),
        ("prompt_len", json::num(plen as f64)),
        ("requests", json::num(b as f64)),
        ("max_new", json::num(max_new as f64)),
        ("dispatches_chunked", json::num(chunked_total as f64)),
        ("dispatches_stepwise", json::num(step_d as f64)),
        ("dispatches_per_request_chunked", json::num(chunked_total as f64 / b as f64)),
        ("dispatches_per_request_stepwise", json::num(step_d as f64 / b as f64)),
        ("tok_per_s_chunked", json::num(tokens as f64 / chunked_s.max(1e-12))),
        ("tok_per_s_stepwise", json::num(tokens as f64 / stepwise_s.max(1e-12))),
        ("speedup", json::num(stepwise_s / chunked_s.max(1e-12))),
    ]))
}

/// A realistically shaped [`crate::eval::AdapterDelta`] over the
/// synthetic leaves: rank-8 LoRA pairs on the square projection leaves,
/// ~1% SDT sparse offsets on the rest — the paper's recipe, sized for
/// the resident-KB comparison against a whole-model merged copy.
fn synth_adapter_delta(leaves: &[Tensor]) -> crate::eval::AdapterDelta {
    use crate::eval::{AdapterDelta, LoraOp, SparseOffset};
    let rank = 8usize;
    let mut lora = Vec::new();
    let mut sparse = Vec::new();
    for (i, t) in leaves.iter().enumerate() {
        if t.shape.len() == 2 && t.shape[0] == t.shape[1] {
            lora.push(LoraOp {
                target: format!("leaf{i}"),
                a: Tensor::zeros(&[t.shape[0], rank]),
                b: Tensor::zeros(&[rank, t.shape[1]]),
            });
        } else {
            let n = (t.numel() / 100).max(1);
            sparse.push(SparseOffset {
                param: format!("leaf{i}"),
                idx: (0..n).map(|j| j * 100).collect(),
                val: vec![0.0; n],
            });
        }
    }
    AdapterDelta {
        meta: crate::manifest::PeftMeta {
            method: crate::suite::PeftMethod::Sdt,
            rank,
            alpha: rank,
            targets: Vec::new(),
            n_tokens: 0,
        },
        lora,
        sparse,
        h0: BTreeMap::new(),
    }
}

/// Schema 3's `adapters` section: unmerged batched multi-adapter decode
/// on the host mocks. One [`crate::eval::testing::AccumAdapters`] batch
/// carries four distinct per-row deltas in ONE dispatch per step; the
/// merged baseline decodes the same four adapters as four dedicated
/// single-row lanes (four dispatches per step). The dispatch counts are
/// the durable telemetry; the resident-KB pair quantifies why the
/// registry keeps raw deltas instead of whole-model merged copies.
fn bench_adapters_mock(scale: f32) -> Result<Value> {
    use std::sync::atomic::Ordering;

    use crate::eval::testing::{mock_delta, Accum, AccumAdapters};
    use crate::eval::{AdapterRow, AdapterStepDecode};

    let offs = [3.0f32, 5.0, 7.0, 11.0];
    let b = offs.len();
    let steps = ((96.0 * scale).round() as usize).max(16);
    let iters = ((10.0 * scale).round() as usize).max(3);
    let tok = |s: usize, r: usize| ((s * 7 + r * 13 + 3) % 251) as i32;

    // unmerged: one shared batch, per-row deltas, one dispatch per step
    let shared = AccumAdapters::new(b);
    let rows: Vec<AdapterRow> = offs.iter().map(|&o| Some(mock_delta(o))).collect();
    let run_shared = || -> Result<()> {
        let mut state = shared.new_state(None);
        let mut toks = IntTensor::from_vec(&[b], vec![0i32; b]);
        for s in 0..steps {
            for r in 0..b {
                toks.data[r] = tok(s, r);
            }
            shared.step_rows(&toks, &mut state, &rows)?;
        }
        Ok(())
    };
    run_shared()?; // count-establishing run
    let mut err = None;
    let shared_st = time("unmerged", 0, iters, || {
        if let Err(e) = run_shared() {
            err = Some(e);
        }
    });
    if let Some(e) = err {
        return Err(e);
    }
    let runs = (iters + 1) as u64;
    let shared_d = shared.steps.load(Ordering::Relaxed) / runs;

    // merged baseline: one dedicated single-row lane per adapter
    let merged: Vec<Accum> = offs.iter().map(|&o| Accum::with_off(1, &[], o)).collect();
    let run_merged = || -> Result<()> {
        for (r, m) in merged.iter().enumerate() {
            let mut state = m.new_state(None);
            let mut t1 = IntTensor::from_vec(&[1], vec![0i32]);
            for s in 0..steps {
                t1.data[0] = tok(s, r);
                m.step(&t1, &mut state)?;
            }
        }
        Ok(())
    };
    run_merged()?;
    let mut err = None;
    let merged_st = time("merged", 0, iters, || {
        if let Err(e) = run_merged() {
            err = Some(e);
        }
    });
    if let Some(e) = err {
        return Err(e);
    }
    let merged_d: u64 = merged
        .iter()
        .map(|m| m.steps.load(Ordering::Relaxed))
        .sum::<u64>()
        / runs;

    // residency: raw delta vs whole-model merged copy, on the same
    // synthetic Mamba shapes the optimizer scenarios use
    let mut rng = Rng::new(0x5D7);
    let leaves = synth_leaves(scale, &mut rng);
    let full_copy_bytes =
        leaves.iter().map(Tensor::numel).sum::<usize>() * std::mem::size_of::<f32>();
    let delta_bytes = synth_adapter_delta(&leaves).resident_bytes();

    let tokens = (b * steps) as f64;
    Ok(json::obj(vec![
        ("requests", json::num(b as f64)),
        ("steps", json::num(steps as f64)),
        ("adapters_per_batch", json::num(b as f64)),
        ("dispatches_unmerged", json::num(shared_d as f64)),
        ("dispatches_merged", json::num(merged_d as f64)),
        ("tok_per_s_unmerged", json::num(tokens / shared_st.mean_s.max(1e-12))),
        ("tok_per_s_merged", json::num(tokens / merged_st.mean_s.max(1e-12))),
        ("speedup", json::num(merged_st.mean_s / shared_st.mean_s.max(1e-12))),
        ("resident_kb_per_adapter", json::num(delta_bytes as f64 / 1024.0)),
        ("resident_kb_full_copy", json::num(full_copy_bytes as f64 / 1024.0)),
        (
            "residency_ratio",
            json::num(full_copy_bytes as f64 / (delta_bytes as f64).max(1.0)),
        ),
    ]))
}

/// Schema 4's `faults` section: the serve scheduler under deterministic
/// seeded exec faults, on the host mocks. A healthy pass and a degraded
/// pass (fixed-seed transient [`crate::fault::FaultSite::ExecRun`] faults)
/// run the same request mix through [`Scheduler::run_to_completion`]; the
/// injected/retry counters and the recovery-overhead ratio are the
/// durable telemetry — transient faults must cost retried ticks, not
/// failed requests.
fn bench_faults_mock(scale: f32) -> Result<Value> {
    use std::sync::Arc;

    use crate::eval::testing::Accum;
    use crate::fault::{FaultInject, FaultPlan, FaultSite};
    use crate::serve::{LaneModel, Request, Response, Scheduler, ServeModel};

    /// Merged-lane mock whose exec site consults the fault plan BEFORE
    /// touching state (the real `DecodeCore::run_exec` ordering), so a
    /// faulted step is retryable byte-for-byte after rollback.
    struct FaultyStep {
        inner: Accum,
        plan: Arc<FaultPlan>,
    }

    impl StepDecode for FaultyStep {
        fn arch_b(&self) -> usize {
            self.inner.arch_b()
        }
        fn dims(&self) -> crate::eval::StateDims {
            self.inner.dims()
        }
        fn step(&self, tokens: &IntTensor, state: &mut DecodeState)
            -> Result<Tensor> {
            self.plan.check(FaultSite::ExecRun)?;
            self.inner.step(tokens, state)
        }
    }

    const FAULT_RATE: f64 = 0.05;
    let adapters = 4usize;
    let requests = ((16.0 * scale).round() as usize).max(8);
    let max_new = ((24.0 * scale).round() as usize).max(8);
    let iters = ((6.0 * scale).round() as usize).max(2);

    // one request mix, replayed under a healthy and a faulty exec site;
    // the generous tick budget is a hang backstop, never hit in practice
    let run = |plan: Option<Arc<FaultPlan>>| -> (Vec<Response>, u64, u64, u64) {
        let fplan = plan.clone();
        let factory: crate::serve::ServeFactory = Box::new(move |_adapter: &str| {
            let inner = Accum::with_off(1, &[], 2.0);
            let model: Arc<dyn StepDecode> = match &fplan {
                Some(p) => Arc::new(FaultyStep { inner, plan: p.clone() }),
                None => Arc::new(inner),
            };
            Ok(ServeModel::Merged(LaneModel { model, h0: None }))
        });
        let mut sched = Scheduler::new(factory, adapters);
        if let Some(p) = plan {
            sched.set_fault_inject(p);
        }
        sched.set_max_run_ticks(requests * (max_new + 8) * 8 + 64);
        for id in 0..requests {
            sched.submit(Request {
                id: id as u64,
                adapter: format!("a{}", id % adapters),
                prompt: vec![((id * 17) % 200 + 1) as u8],
                max_new,
                stop_byte: 0,
                beam: 1,
                deadline: 0,
                session: None,
            });
        }
        let out = sched.run_to_completion();
        (out, sched.step_faults, sched.step_retries, sched.demotions)
    };
    // fresh plan per run: same seed => identical fault pattern every run
    let mk_plan =
        || Arc::new(FaultPlan::seeded(0xFA17).with_rate(FaultSite::ExecRun, FAULT_RATE));

    let (resps, _, _, _) = run(None); // count-establishing healthy run
    let tokens: usize = resps.iter().map(|r| r.output.len()).sum();
    let completed_healthy = resps.iter().filter(|r| r.error.is_none()).count();
    let healthy_st = time("serve_healthy", 0, iters, || {
        let _ = run(None);
    });

    let plan = mk_plan();
    let (dresps, step_faults, step_retries, demotions) = run(Some(plan.clone()));
    let injected = plan.injected(FaultSite::ExecRun);
    let dtokens: usize = dresps.iter().map(|r| r.output.len()).sum();
    let completed_degraded = dresps.iter().filter(|r| r.error.is_none()).count();
    let failed_degraded = dresps.len() - completed_degraded;
    let degraded_st = time("serve_degraded", 0, iters, || {
        let _ = run(Some(mk_plan()));
    });

    Ok(json::obj(vec![
        ("requests", json::num(requests as f64)),
        ("max_new", json::num(max_new as f64)),
        ("fault_rate_exec", json::num(FAULT_RATE)),
        ("injected_exec_faults", json::num(injected as f64)),
        ("step_faults", json::num(step_faults as f64)),
        ("step_retries", json::num(step_retries as f64)),
        ("demotions", json::num(demotions as f64)),
        ("completed_healthy", json::num(completed_healthy as f64)),
        ("completed_degraded", json::num(completed_degraded as f64)),
        ("failed_degraded", json::num(failed_degraded as f64)),
        ("tok_per_s_healthy", json::num(tokens as f64 / healthy_st.mean_s.max(1e-12))),
        (
            "tok_per_s_degraded",
            json::num(dtokens as f64 / degraded_st.mean_s.max(1e-12)),
        ),
        (
            "recovery_overhead",
            json::num(degraded_st.mean_s / healthy_st.mean_s.max(1e-12)),
        ),
    ]))
}

/// Schema 5's `sessions` section: multi-turn conversation serving with
/// the durable session store against stateless full-history re-prefill,
/// on the host mocks. One conversation runs turn by turn; with the store,
/// every turn after the first resurrects the retired row's `(conv, ssm)`
/// state at admission and skips prefill entirely, so the prefill-chunk
/// counter stays flat while the stateless baseline re-ingests the whole
/// growing history each turn. A simulated crash (drain to a spill dir,
/// drop everything, recover with a fresh store) then pins disk-resumed
/// output byte-identical to a fresh stateless replay with zero prefill
/// chunks. Counters are the durable telemetry; times say "TTFT scales
/// with history" vs "TTFT is O(1)".
fn bench_sessions_mock(scale: f32) -> Result<Value> {
    use std::sync::atomic::Ordering;
    use std::sync::Arc;

    use crate::eval::testing::Accum;
    use crate::serve::{LaneModel, Request, Scheduler, ServeModel, SessionStore};

    let turns = ((8.0 * scale).round() as usize).max(4);
    let grow = 3usize; // fresh user bytes appended per turn
    let max_new = 3usize;
    let iters = ((6.0 * scale).round() as usize).max(2);
    let widths = [8usize, 32];
    let first: Vec<u8> = (0..12).map(|i| ((i * 7 + 3) % 199 + 1) as u8).collect();

    // turn t+1's prompt = turn t's prompt ++ turn t's output ++ fresh bytes
    let next_turn = |prev: &[u8], out: &[u8], t: usize| -> Vec<u8> {
        let mut p = prev.to_vec();
        p.extend_from_slice(out);
        p.extend((0..grow).map(|i| ((t * 29 + i * 7 + 11) % 199 + 1) as u8));
        p
    };
    let accum_factory = |model: Arc<Accum>| -> crate::serve::ServeFactory<'static> {
        Box::new(move |_adapter: &str| {
            Ok(ServeModel::Merged(LaneModel { model: model.clone(), h0: None }))
        })
    };
    let mk_req = |id: u64, prompt: Vec<u8>, session: Option<&str>| Request {
        id,
        adapter: "chat".into(),
        prompt,
        max_new,
        stop_byte: 0,
        beam: 1,
        deadline: 0,
        session: session.map(str::to_string),
    };

    // the whole conversation, turn by turn, on one scheduler; with_store
    // uses a memory-tier store (explicit cap — independent of the
    // SSM_PEFT_SESSIONS_* knobs), without re-prefills the full history
    let run_pass = |with_store: bool| -> Result<(Vec<Vec<u8>>, u64, u64, u64)> {
        let model = Arc::new(Accum::new(1, &widths));
        let mut sched = Scheduler::new(accum_factory(model.clone()), 2);
        if with_store {
            sched.set_session_store(Arc::new(SessionStore::new(8)));
        }
        let mut outputs = Vec::new();
        let mut prompt = first.clone();
        for t in 0..turns {
            let sid = with_store.then_some("bench-conv");
            sched.submit(mk_req(t as u64, prompt.clone(), sid));
            let r = sched
                .run_to_completion()
                .pop()
                .ok_or_else(|| crate::err!("turn {t} did not retire"))?;
            if let Some(e) = r.error {
                crate::bail!("turn {t} failed: {e}");
            }
            prompt = next_turn(&prompt, &r.output, t);
            outputs.push(r.output);
        }
        let chunks = model.chunks.load(Ordering::Relaxed);
        Ok((outputs, chunks, sched.session_resurrections, sched.session_fallbacks))
    };

    let (outs_store, chunks_store, resurrections, fallbacks) = run_pass(true)?;
    let (outs_replay, chunks_replay, _, _) = run_pass(false)?;
    let transcripts_match = outs_store == outs_replay;
    let gen_tokens: usize = outs_store.iter().map(Vec::len).sum();
    let final_len = first.len() + gen_tokens + turns * grow;
    let mut err = None;
    let store_st = time("sessions_store", 0, iters, || {
        if let Err(e) = run_pass(true) {
            err = Some(e);
        }
    });
    let replay_st = time("sessions_reprefill", 0, iters, || {
        if let Err(e) = run_pass(false) {
            err = Some(e);
        }
    });
    if let Some(e) = err {
        return Err(e);
    }

    // simulated crash: turn 1 drains its snapshot to a spill dir, the
    // process "dies" (scheduler, store, and model drop), a fresh store
    // recovers the record, and turn 2 resumes from disk
    let dir = std::env::temp_dir()
        .join(format!("ssm-peft-bench-sessions-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let (prompt2, flushed) = {
        let model = Arc::new(Accum::new(1, &widths));
        let mut sched = Scheduler::new(accum_factory(model), 2);
        sched.set_session_store(Arc::new(SessionStore::new(8).with_dir(&dir)));
        sched.submit(mk_req(0, first.clone(), Some("crash-conv")));
        let (mut resps, flushed, _fail) = sched.drain();
        let r = resps.pop().ok_or_else(|| crate::err!("crash turn 1 lost"))?;
        (next_turn(&first, &r.output, 0), flushed)
    };
    let store = Arc::new(SessionStore::new(8).with_dir(&dir));
    let rec = store.recover();
    let model = Arc::new(Accum::new(1, &widths));
    let mut sched = Scheduler::new(accum_factory(model.clone()), 2);
    sched.set_session_store(store);
    sched.submit(mk_req(1, prompt2.clone(), Some("crash-conv")));
    let resumed = sched
        .run_to_completion()
        .pop()
        .ok_or_else(|| crate::err!("crash turn 2 did not retire"))?;
    let resume_chunks = model.chunks.load(Ordering::Relaxed);
    // ground truth: the same turn as a fresh stateless request
    let ref_model = Arc::new(Accum::new(1, &widths));
    let mut sref = Scheduler::new(accum_factory(ref_model), 2);
    sref.submit(mk_req(2, prompt2.clone(), None));
    let want = sref
        .run_to_completion()
        .pop()
        .ok_or_else(|| crate::err!("crash replay did not retire"))?;
    let crash_matches = resumed.output == want.output && resumed.error.is_none();
    let _ = std::fs::remove_dir_all(&dir);

    Ok(json::obj(vec![
        ("turns", json::num(turns as f64)),
        ("prompt_len_first", json::num(first.len() as f64)),
        ("prompt_len_final", json::num(final_len as f64)),
        ("max_new", json::num(max_new as f64)),
        ("prefill_chunks_store", json::num(chunks_store as f64)),
        ("prefill_chunks_reprefill", json::num(chunks_replay as f64)),
        ("resurrections", json::num(resurrections as f64)),
        ("fallbacks", json::num(fallbacks as f64)),
        ("transcripts_match", json::num(f64::from(u8::from(transcripts_match)))),
        ("turn_s_store", json::num(store_st.mean_s / turns as f64)),
        ("turn_s_reprefill", json::num(replay_st.mean_s / turns as f64)),
        ("speedup", json::num(replay_st.mean_s / store_st.mean_s.max(1e-12))),
        (
            "tok_per_s_store",
            json::num(gen_tokens as f64 / store_st.mean_s.max(1e-12)),
        ),
        ("drain_flushed", json::num(flushed as f64)),
        ("recovered_records", json::num(rec.valid as f64)),
        ("recovery_quarantined", json::num(rec.quarantined as f64)),
        ("crash_resume_prefill_chunks", json::num(resume_chunks as f64)),
        ("crash_resume_matches", json::num(f64::from(u8::from(crash_matches)))),
    ]))
}

/// The `prefill` section's artifact half: the same comparison through the
/// real prefill executables (None when the manifest has no prefill
/// entries — pre-v2 artifacts).
fn bench_prefill_artifacts(engine: &Engine, manifest: &Manifest, scale: f32)
    -> Result<Option<Value>> {
    let Some((name, v)) = manifest
        .variants
        .iter()
        .find(|(_, v)| v.decode_file.is_some() && !v.prefill_files.is_empty() && !v.reg)
        .map(|(k, v)| (k.clone(), v.clone()))
    else {
        return Ok(None);
    };
    let params = manifest.load_params(&v)?;
    let core = DecodeCore::new(engine, manifest, &name, &params)?;
    let b = core.arch_b();
    let plen = ((96.0 * scale).round() as usize).max(24);
    let max_new = 4usize;
    let iters = ((6.0 * scale).round() as usize).max(2);
    let prompts = bench_prompts(b, plen);

    // warmup compiles every chunk executable once
    crate::eval::greedy_decode(&core, &prompts, max_new, b'\n', None)?;
    let d0 = core.dispatch_count();
    let (chunked_s, tokens) = time_greedy(&core, &prompts, max_new, iters)?;
    let runs = (iters + 1) as u64;
    let chunked_d = (core.dispatch_count() - d0) / runs;

    let stepwise = StepwiseOnly(&core);
    let d1 = core.dispatch_count();
    let (stepwise_s, _) = time_greedy(&stepwise, &prompts, max_new, iters)?;
    let stepwise_d = (core.dispatch_count() - d1) / runs;

    Ok(Some(json::obj(vec![
        ("variant", json::s(&name)),
        (
            "widths",
            Value::Arr(
                core.prefill_widths().iter().map(|&w| json::num(w as f64)).collect(),
            ),
        ),
        ("prompt_len", json::num(plen as f64)),
        ("requests", json::num(b as f64)),
        ("max_new", json::num(max_new as f64)),
        ("dispatches_chunked", json::num(chunked_d as f64)),
        ("dispatches_stepwise", json::num(stepwise_d as f64)),
        ("dispatches_per_request_chunked", json::num(chunked_d as f64 / b as f64)),
        ("dispatches_per_request_stepwise", json::num(stepwise_d as f64 / b as f64)),
        ("tok_per_s_chunked", json::num(tokens as f64 / chunked_s.max(1e-12))),
        ("tok_per_s_stepwise", json::num(tokens as f64 / stepwise_s.max(1e-12))),
        ("speedup", json::num(stepwise_s / chunked_s.max(1e-12))),
    ])))
}

/// Greedy-decode throughput: resident vs reference parameter/state paths.
fn bench_decode(engine: &Engine, manifest: &Manifest, scale: f32)
    -> Result<Option<Value>> {
    let Some((name, v)) = manifest
        .variants
        .iter()
        .find(|(_, v)| v.decode_file.is_some() && !v.reg)
        .map(|(k, v)| (k.clone(), v.clone()))
    else {
        return Ok(None);
    };
    let params = manifest.load_params(&v)?;
    // for-reference build keeps host params so the baseline can replay the
    // pre-arena per-token serialization; the resident path is unaffected
    let core = DecodeCore::new_for_reference(engine, manifest, &name, &params)?;
    let max_new = ((48.0 * scale).round() as usize).max(8);
    let prompts: Vec<Vec<u8>> = (0..core.arch_b())
        .map(|i| format!("name=row{i}|team=red").into_bytes())
        .collect();
    let run = |model: &dyn StepDecode| -> Result<(f64, usize)> {
        let t0 = Instant::now();
        let outs = greedy_decode(model, &prompts, max_new, b'\n', None)?;
        Ok((t0.elapsed().as_secs_f64(), outs.iter().map(Vec::len).sum()))
    };
    // warmup (XLA compile happens on first execute)
    run(&core)?;
    let (res_s, res_toks) = run(&core)?;
    let reference = ReferenceDecode(&core);
    let (ref_s, ref_toks) = run(&reference)?;
    let res_tps = res_toks as f64 / res_s.max(1e-12);
    let ref_tps = ref_toks as f64 / ref_s.max(1e-12);
    Ok(Some(json::obj(vec![
        ("variant", json::s(&name)),
        ("batch", json::num(core.arch_b() as f64)),
        ("max_new", json::num(max_new as f64)),
        ("tok_per_s_resident", json::num(res_tps)),
        ("tok_per_s_reference", json::num(ref_tps)),
        ("speedup", json::num(res_tps / ref_tps.max(1e-12))),
    ])))
}

/// Run the hot-path bench and write `results/BENCH_hotpath.json`.
pub fn run(_kvs: &BTreeMap<String, String>) -> Result<()> {
    let scale = bench_scale();
    let iters = ((20.0 * scale).round() as usize).max(5);
    let workers = fused_workers();
    let mut rng = Rng::new(0x407);
    let leaves = synth_leaves(scale, &mut rng);
    let grads = synth_grads(&leaves, &mut rng);

    let mut table = TablePrinter::new(&[
        "scenario", "params", "legacy (s)", "fused w1 (s)", "fused wN (s)", "speedup",
    ]);
    let mut mock_fields = Vec::new();
    let mut headline = 0.0;
    for (name, keep) in [("none", None), ("sdt", Some(100)), ("half", Some(2))] {
        let masks = scenario_masks(&leaves, keep);
        let (key, val) =
            mock_scenario(name, &leaves, &grads, &masks, iters, workers, &mut table);
        if name == "sdt" {
            headline = val.get("speedup").and_then(Value::as_f64).unwrap_or(0.0);
        }
        mock_fields.push((key, val));
    }

    // artifact mode when the AOT artifacts exist
    let mut mode = "mock";
    let mut train_val = None;
    let mut decode_val = None;
    let mut prefill_fields = vec![("mock", bench_prefill_mock(scale)?)];
    let adapters_val = bench_adapters_mock(scale)?;
    let faults_val = bench_faults_mock(scale)?;
    let sessions_val = bench_sessions_mock(scale)?;
    if crate::artifacts_dir().join("manifest.json").exists() {
        let engine = Engine::cpu()?;
        let manifest = Manifest::load(crate::artifacts_dir())?;
        mode = "artifacts";
        let (_variant, tv) = bench_train(&engine, &manifest, scale)?;
        // the measured end-to-end reduction supersedes the mock headline
        headline = tv
            .get("host_overhead_reduction")
            .and_then(Value::as_f64)
            .unwrap_or(headline);
        train_val = Some(tv);
        decode_val = bench_decode(&engine, &manifest, scale)?;
        if let Some(pv) = bench_prefill_artifacts(&engine, &manifest, scale)? {
            prefill_fields.push(("artifacts", pv));
        } else {
            eprintln!(
                "[bench hotpath] artifacts lack prefill entries; \
                 re-run `python -m compile.aot` for the artifact prefill bench"
            );
        }
    } else {
        eprintln!("[bench hotpath] no artifacts; mock mode only (run `make artifacts`)");
    }

    println!("\n=== bench hotpath (scale {scale}, {workers} workers, mode {mode}) ===");
    table.print();
    for (kind, pv) in &prefill_fields {
        let get = |k: &str| pv.get(k).and_then(Value::as_f64).unwrap_or(0.0);
        println!(
            "prefill ({kind}): {:.1} dispatches/request chunked vs {:.1} stepwise \
             ({:.0} vs {:.0} tok/s)",
            get("dispatches_per_request_chunked"),
            get("dispatches_per_request_stepwise"),
            get("tok_per_s_chunked"),
            get("tok_per_s_stepwise"),
        );
    }
    {
        let get = |k: &str| adapters_val.get(k).and_then(Value::as_f64).unwrap_or(0.0);
        println!(
            "adapters (mock): {:.0}/batch, {:.0} vs {:.0} dispatches \
             (unmerged vs merged lanes), {:.1} vs {:.1} KB resident/adapter",
            get("adapters_per_batch"),
            get("dispatches_unmerged"),
            get("dispatches_merged"),
            get("resident_kb_per_adapter"),
            get("resident_kb_full_copy"),
        );
    }
    {
        let get = |k: &str| faults_val.get(k).and_then(Value::as_f64).unwrap_or(0.0);
        println!(
            "faults (mock): {:.0} injected exec faults -> {:.0} retries, \
             {:.0}/{:.0} requests completed degraded ({:.2}x healthy cost)",
            get("injected_exec_faults"),
            get("step_retries"),
            get("completed_degraded"),
            get("requests"),
            get("recovery_overhead"),
        );
    }
    {
        let get = |k: &str| sessions_val.get(k).and_then(Value::as_f64).unwrap_or(0.0);
        println!(
            "sessions (mock): {:.0} turns, {:.0} resurrected, {:.0} vs {:.0} \
             prefill chunks (store vs re-prefill), crash recovery {}",
            get("turns"),
            get("resurrections"),
            get("prefill_chunks_store"),
            get("prefill_chunks_reprefill"),
            if get("crash_resume_matches") == 1.0 { "ok" } else { "FAILED" },
        );
    }

    let mock_obj = Value::Obj(
        mock_fields.into_iter().collect::<BTreeMap<String, Value>>(),
    );
    let mut root = vec![
        // schema 5: adds the `sessions` section (durable session store);
        // schema 4 added `faults` (serve under injected faults); schema 3
        // added `adapters` (unmerged multi-adapter decode); schema 2
        // added `prefill` (§Perf L5)
        ("schema", json::num(BENCH_HOTPATH_SCHEMA as f64)),
        ("scale", json::num(scale as f64)),
        ("mode", json::s(mode)),
        ("workers", json::num(workers as f64)),
        ("optimizer_mock", mock_obj),
        ("prefill", json::obj(prefill_fields)),
        ("adapters", adapters_val),
        ("faults", faults_val),
        ("sessions", sessions_val),
        ("host_overhead_reduction", json::num(headline)),
    ];
    if let Some(tv) = train_val {
        root.push(("train", tv));
    }
    if let Some(dv) = decode_val {
        root.push(("decode", dv));
    }
    let path = crate::results_dir().join("BENCH_hotpath.json");
    std::fs::write(&path, json::emit(&json::obj(root)))?;
    println!("host-overhead reduction vs pre-arena baseline: {headline:.1}x");
    println!("[saved {}]", path.display());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synth_leaves_scale_down() {
        let mut rng = Rng::new(1);
        let small = synth_leaves(0.1, &mut rng);
        let big = synth_leaves(1.0, &mut rng);
        let n = |ls: &[Tensor]| ls.iter().map(Tensor::numel).sum::<usize>();
        assert!(n(&small) < n(&big));
        assert_eq!(small.len(), 12, "3 leaves x 4 layers");
    }

    #[test]
    fn prefill_mock_section_dispatch_accounting() {
        let v = bench_prefill_mock(0.1).unwrap();
        let get = |k: &str| v.get(k).and_then(Value::as_f64).unwrap();
        assert!(get("dispatches_chunked") < get("dispatches_stepwise"));
        // each covered token replaces one step dispatch; each chunk adds one
        let plen = get("prompt_len") as usize;
        let (plan, _rem) = crate::eval::plan_chunks(&[16, 64], plen);
        let covered: usize = plan.iter().sum();
        assert_eq!(
            get("dispatches_chunked") as usize,
            plan.len() + get("dispatches_stepwise") as usize - covered,
        );
        assert!(get("tok_per_s_chunked") > 0.0);
        assert!(get("tok_per_s_stepwise") > 0.0);
    }

    #[test]
    fn adapters_mock_section_accounting() {
        let v = bench_adapters_mock(0.1).unwrap();
        let get = |k: &str| v.get(k).and_then(Value::as_f64).unwrap();
        // one dispatch per step for the whole mixed batch, vs one per
        // adapter per step on dedicated merged lanes
        assert_eq!(get("dispatches_unmerged"), get("steps"));
        assert_eq!(
            get("dispatches_merged"),
            get("adapters_per_batch") * get("dispatches_unmerged"),
        );
        assert!(get("tok_per_s_unmerged") > 0.0);
        assert!(get("tok_per_s_merged") > 0.0);
        // a raw delta must be materially smaller than a merged copy
        assert!(get("residency_ratio") > 2.0, "{}", get("residency_ratio"));
        assert!(get("resident_kb_per_adapter") < get("resident_kb_full_copy"));
    }

    #[test]
    fn faults_mock_section_accounting() {
        let v = bench_faults_mock(0.1).unwrap();
        let get = |k: &str| v.get(k).and_then(Value::as_f64).unwrap();
        // the healthy pass must be fault-free and complete everything
        assert_eq!(get("completed_healthy"), get("requests"));
        // every injected exec fault surfaces as exactly one step fault
        assert_eq!(get("step_faults"), get("injected_exec_faults"));
        // retries never exceed faults, and every request terminates
        assert!(get("step_retries") <= get("step_faults"));
        assert_eq!(
            get("completed_degraded") + get("failed_degraded"),
            get("requests"),
        );
        assert!(get("tok_per_s_healthy") > 0.0);
        assert!(get("tok_per_s_degraded") > 0.0);
    }

    #[test]
    fn sessions_mock_section_accounting() {
        let v = bench_sessions_mock(0.1).unwrap();
        let get = |k: &str| v.get(k).and_then(Value::as_f64).unwrap();
        // every turn after the first resumes from the store — no fallback
        assert_eq!(get("resurrections"), get("turns") - 1.0);
        assert_eq!(get("fallbacks"), 0.0);
        // O(1) resume: the store pass prefills once, the stateless
        // baseline re-ingests the growing history every turn
        assert!(
            get("prefill_chunks_store") < get("prefill_chunks_reprefill"),
            "{} vs {}",
            get("prefill_chunks_store"),
            get("prefill_chunks_reprefill"),
        );
        // resuming must not change a single output byte
        assert_eq!(get("transcripts_match"), 1.0);
        // crash sim: one drained record recovered clean, resumed with
        // ZERO prefill chunks, byte-identical to a fresh replay
        assert_eq!(get("drain_flushed"), 1.0);
        assert_eq!(get("recovered_records"), 1.0);
        assert_eq!(get("recovery_quarantined"), 0.0);
        assert_eq!(get("crash_resume_prefill_chunks"), 0.0);
        assert_eq!(get("crash_resume_matches"), 1.0);
        assert!(get("turn_s_store") > 0.0 && get("turn_s_reprefill") > 0.0);
    }

    #[test]
    fn scenario_masks_shapes() {
        let mut rng = Rng::new(2);
        let leaves = synth_leaves(0.1, &mut rng);
        let m = scenario_masks(&leaves, Some(100));
        for (t, mk) in leaves.iter().zip(&m.masks) {
            let mk = mk.as_ref().unwrap();
            assert_eq!(mk.len(), t.numel());
            let active = mk.iter().filter(|&&x| x != 0.0).count();
            assert!(active >= 1 && active <= t.numel() / 50);
        }
        assert!(scenario_masks(&leaves, None).masks.iter().all(Option::is_none));
    }
}
