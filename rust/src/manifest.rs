//! AOT manifest loader: the contract between the Python compile path (L2)
//! and the Rust runtime (L3).
//!
//! `artifacts/manifest.json` is written once by `python -m compile.aot`; the
//! Rust side is completely layout-agnostic — parameter names, shapes, order
//! and the initial values (`<variant>.params.bin`, f32 little-endian,
//! train-then-frozen order) all come from here.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::{bail, err};
use crate::error::{Context, Result};

use crate::json::{self, Value};
use crate::suite::PeftMethod;
use crate::tensor::Tensor;

/// One named parameter slot in an artifact's flat argument list.
#[derive(Debug, Clone)]
pub struct ParamMeta {
    /// Parameter name (python pytree path).
    pub name: String,
    /// Tensor shape.
    pub shape: Vec<usize>,
    /// byte offset into params.bin
    pub offset: usize,
    /// Element count (`shape` product; cross-checked at load).
    pub numel: usize,
}

/// Architecture hyperparameters (mirrors python ArchSpec).
#[derive(Debug, Clone)]
pub struct Arch {
    /// Architecture family ("mamba1", "mamba2", "s4", "hybrid").
    pub kind: String,
    /// Vocabulary size (256 bytes + BOS + PAD).
    pub vocab: usize,
    /// Residual-stream width.
    pub d_model: usize,
    /// Layer count.
    pub n_layer: usize,
    /// Expanded inner width (Mamba expansion).
    pub d_inner: usize,
    /// SSM state dimension per channel.
    pub d_state: usize,
    /// Depthwise conv kernel width.
    pub d_conv: usize,
    /// Δ-projection rank (S6).
    pub dt_rank: usize,
    /// Head count (Mamba-2 / hybrid attention).
    pub n_head: usize,
    /// Additional-scan extra state dims (paper Sec. 4.3).
    pub h_add: usize,
}

/// PEFT description for budget accounting and SDT column layouts. The
/// method is parsed once at manifest load; all downstream dispatch is on
/// the [`PeftMethod`] enum.
#[derive(Debug, Clone)]
pub struct PeftMeta {
    /// The typed PEFT method (parsed once at manifest load).
    pub method: PeftMethod,
    /// LoRA rank (0 for non-LoRA methods).
    pub rank: usize,
    /// LoRA merge numerator: scale = alpha / rank (mirrors the scale baked
    /// into the compiled forward by python/compile/peft.py::make_eff).
    /// Defaults to `rank` (scale 1.0) when the manifest omits it, matching
    /// python's `peft.get("alpha", rank)`.
    pub alpha: usize,
    /// Raw target-module list as python wrote it.
    pub targets: Vec<String>,
    /// Prompt/prefix virtual-token count.
    pub n_tokens: usize,
}

/// Element dtype of one adapter operand slot.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OperandDtype {
    /// 32-bit float operand (LoRA factors, scales, offset values).
    F32,
    /// 32-bit int operand (sparse-offset index sets).
    I32,
}

/// One per-row adapter operand of the `decode_adapters` artifact, in the
/// exact position the executable takes it after (params..., token,
/// conv_st, ssm_st).
#[derive(Debug, Clone)]
pub struct OperandMeta {
    /// Operand name (`scale`, `<w>.lora_a/.lora_b`, `<p>.sdt_idx/.sdt_val`).
    pub name: String,
    /// Operand shape (leading dim is the batch B).
    pub shape: Vec<usize>,
    /// Element dtype.
    pub dtype: OperandDtype,
}

/// Layout of the `decode_adapters` artifact's trailing operand list
/// (manifest v3): the compiled LoRA slot rank, the sparse-offset capacity
/// per SSM tensor, and the canonical operand order.
#[derive(Debug, Clone)]
pub struct AdapterOperands {
    /// LoRA slot rank R the artifact was compiled with (smaller adapter
    /// ranks are zero-padded up to R).
    pub rank: usize,
    /// Sparse-offset capacity K per SDT-trained SSM tensor per layer.
    pub k: usize,
    /// Operands in executable argument order.
    pub operands: Vec<OperandMeta>,
}

/// One exported (architecture × PEFT) variant.
#[derive(Debug, Clone)]
pub struct Variant {
    /// Variant name (`<arch>_<peft_suffix>`).
    pub name: String,
    /// Architecture hyperparameters.
    pub arch: Arch,
    /// PEFT description.
    pub peft: PeftMeta,
    /// Compiled batch size B.
    pub batch_b: usize,
    /// Compiled sequence length L.
    pub batch_l: usize,
    /// Regression variant (Fig. 2 synthetic S4) instead of LM.
    pub reg: bool,
    /// Train-step HLO artifact, when exported.
    pub step_file: Option<String>,
    /// Forward-pass HLO artifact, when exported.
    pub fwd_file: Option<String>,
    /// Stepwise-decode HLO artifact, when exported.
    pub decode_file: Option<String>,
    /// Chunked-prefill HLO artifacts as `(chunk_width, file)`, ascending by
    /// width; empty when the variant has no prefill export (pre-v2
    /// manifests, non-decode variants).
    pub prefill_files: Vec<(usize, String)>,
    /// Unmerged multi-adapter decode HLO artifact (manifest v3, decode
    /// variants only): same base batch plus per-row delta operands.
    pub decode_adapters_file: Option<String>,
    /// Operand layout of `decode_adapters_file`; present iff the artifact is.
    pub adapter_operands: Option<AdapterOperands>,
    /// Initial parameter values file (f32 LE, train-then-frozen).
    pub params_bin: String,
    /// Trainable parameters, in artifact argument order.
    pub train_params: Vec<ParamMeta>,
    /// Frozen parameters, in artifact argument order.
    pub frozen_params: Vec<ParamMeta>,
}

impl Variant {
    /// Trainable parameter count.
    pub fn n_train(&self) -> usize {
        self.train_params.iter().map(|p| p.numel).sum()
    }
    /// Total parameter count (trainable + frozen).
    pub fn n_total(&self) -> usize {
        self.n_train() + self.frozen_params.iter().map(|p| p.numel).sum::<usize>()
    }
    /// Trainable fraction — the paper's parameter-budget column.
    pub fn train_fraction(&self) -> f64 {
        self.n_train() as f64 / self.n_total() as f64
    }
    /// Metadata for a parameter by name (trainable or frozen).
    pub fn param(&self, name: &str) -> Option<&ParamMeta> {
        self.train_params
            .iter()
            .chain(self.frozen_params.iter())
            .find(|p| p.name == name)
    }
    /// Index of a parameter inside the trainable list.
    pub fn train_index(&self, name: &str) -> Option<usize> {
        self.train_params.iter().position(|p| p.name == name)
    }
}

/// The whole manifest plus its directory (for resolving file names).
#[derive(Debug)]
pub struct Manifest {
    /// Artifacts directory (resolves relative file names).
    pub dir: PathBuf,
    /// Exported variants by name.
    pub variants: BTreeMap<String, Variant>,
}

fn parse_params(v: &Value) -> Result<Vec<ParamMeta>> {
    let arr = v.as_arr().ok_or_else(|| err!("params not an array"))?;
    arr.iter()
        .map(|p| {
            Ok(ParamMeta {
                name: p.path("name").and_then(Value::as_str).unwrap_or("").to_string(),
                shape: p
                    .path("shape")
                    .and_then(Value::as_arr)
                    .map(|a| a.iter().filter_map(Value::as_usize).collect())
                    .unwrap_or_default(),
                offset: p.path("offset").and_then(Value::as_usize).unwrap_or(0),
                numel: p.path("numel").and_then(Value::as_usize).unwrap_or(0),
            })
        })
        .collect()
}

fn get_usize(v: &Value, key: &str) -> usize {
    v.path(key).and_then(Value::as_usize).unwrap_or(0)
}

fn parse_adapter_operands(v: &Value) -> Result<AdapterOperands> {
    let arr = v
        .path("operands")
        .and_then(Value::as_arr)
        .ok_or_else(|| err!("adapter_operands missing operands array"))?;
    let operands = arr
        .iter()
        .map(|o| {
            let name = o
                .path("name")
                .and_then(Value::as_str)
                .ok_or_else(|| err!("adapter operand missing name"))?
                .to_string();
            let dtype = match o.path("dtype").and_then(Value::as_str) {
                Some("f32") => OperandDtype::F32,
                Some("i32") => OperandDtype::I32,
                other => bail!("operand {name}: bad dtype {other:?}"),
            };
            Ok(OperandMeta {
                name,
                shape: o
                    .path("shape")
                    .and_then(Value::as_arr)
                    .map(|a| a.iter().filter_map(Value::as_usize).collect())
                    .unwrap_or_default(),
                dtype,
            })
        })
        .collect::<Result<Vec<_>>>()?;
    Ok(AdapterOperands { rank: get_usize(v, "rank"), k: get_usize(v, "k"), operands })
}

impl Manifest {
    /// Parse `<dir>/manifest.json` (written by `python -m compile.aot`).
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let src = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let root = json::parse(&src).map_err(|e| err!("manifest parse: {e}"))?;
        let mut variants = BTreeMap::new();
        for v in root
            .path("variants")
            .and_then(Value::as_arr)
            .ok_or_else(|| err!("manifest missing variants"))?
        {
            let name = v
                .path("name")
                .and_then(Value::as_str)
                .ok_or_else(|| err!("variant missing name"))?
                .to_string();
            let arch = v.path("arch").ok_or_else(|| err!("missing arch"))?;
            let peft = v.path("peft").ok_or_else(|| err!("missing peft"))?;
            let var = Variant {
                name: name.clone(),
                arch: Arch {
                    kind: arch.path("kind").and_then(Value::as_str).unwrap_or("").into(),
                    vocab: get_usize(arch, "vocab"),
                    d_model: get_usize(arch, "d_model"),
                    n_layer: get_usize(arch, "n_layer"),
                    d_inner: get_usize(arch, "d_inner"),
                    d_state: get_usize(arch, "d_state"),
                    d_conv: get_usize(arch, "d_conv"),
                    dt_rank: get_usize(arch, "dt_rank"),
                    n_head: get_usize(arch, "n_head"),
                    h_add: get_usize(arch, "h_add"),
                },
                peft: {
                    let targets: Vec<String> = peft
                        .path("targets")
                        .and_then(Value::as_arr)
                        .map(|a| {
                            a.iter().filter_map(Value::as_str).map(String::from).collect()
                        })
                        .unwrap_or_default();
                    let method_str =
                        peft.path("method").and_then(Value::as_str).unwrap_or("");
                    let method = PeftMethod::from_manifest(method_str, &targets)
                        .with_context(|| format!("variant {name}"))?;
                    let rank = get_usize(peft, "rank");
                    let alpha =
                        peft.path("alpha").and_then(Value::as_usize).unwrap_or(rank);
                    PeftMeta { method, rank, alpha, targets, n_tokens: get_usize(peft, "n_tokens") }
                },
                batch_b: get_usize(v, "batch.B"),
                batch_l: get_usize(v, "batch.L"),
                reg: v.path("reg").and_then(Value::as_bool).unwrap_or(false),
                step_file: v.path("files.step").and_then(Value::as_str).map(String::from),
                fwd_file: v.path("files.fwd").and_then(Value::as_str).map(String::from),
                decode_file: v.path("files.decode").and_then(Value::as_str).map(String::from),
                prefill_files: {
                    let mut pf: Vec<(usize, String)> = Vec::new();
                    if let Some(Value::Obj(m)) = v.path("files.prefill") {
                        for (w, f) in m {
                            let width: usize = w.parse().map_err(|_| {
                                err!("variant {name}: bad prefill width key {w:?}")
                            })?;
                            let file = f.as_str().ok_or_else(|| {
                                err!("variant {name}: prefill.{w} not a string")
                            })?;
                            pf.push((width, file.to_string()));
                        }
                    }
                    pf.sort_unstable();
                    pf
                },
                decode_adapters_file: v
                    .path("files.decode_adapters")
                    .and_then(Value::as_str)
                    .map(String::from),
                adapter_operands: match v.path("adapter_operands") {
                    None => None,
                    Some(ao) => Some(parse_adapter_operands(ao)
                        .with_context(|| format!("variant {name}"))?),
                },
                params_bin: v
                    .path("params_bin")
                    .and_then(Value::as_str)
                    .unwrap_or("")
                    .to_string(),
                train_params: parse_params(
                    v.path("train_params").ok_or_else(|| err!("missing train_params"))?,
                )?,
                frozen_params: parse_params(
                    v.path("frozen_params").ok_or_else(|| err!("missing frozen_params"))?,
                )?,
            };
            variants.insert(name, var);
        }
        Ok(Manifest { dir, variants })
    }

    /// A variant by name; the error lists available names.
    pub fn variant(&self, name: &str) -> Result<&Variant> {
        self.variants
            .get(name)
            .ok_or_else(|| err!("variant {name:?} not in manifest (have: {:?})",
                self.variants.keys().take(8).collect::<Vec<_>>()))
    }

    /// Load initial parameter values for a variant, keyed by name.
    pub fn load_params(&self, v: &Variant) -> Result<BTreeMap<String, Tensor>> {
        let raw = std::fs::read(self.dir.join(&v.params_bin))
            .with_context(|| format!("reading {}", v.params_bin))?;
        let mut out = BTreeMap::new();
        for p in v.train_params.iter().chain(v.frozen_params.iter()) {
            let bytes = raw
                .get(p.offset..p.offset + 4 * p.numel)
                .with_context(|| {
                    format!("{}: offsets out of bounds in {}", p.name, v.params_bin)
                })?;
            let mut data = Vec::with_capacity(p.numel);
            for c in bytes.chunks_exact(4) {
                data.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
            }
            if p.shape.iter().product::<usize>() != p.numel {
                bail!("{}: shape/numel mismatch", p.name);
            }
            out.insert(p.name.clone(), Tensor::from_vec(&p.shape, data));
        }
        Ok(out)
    }

    /// Absolute path of an artifact file.
    pub fn hlo_path(&self, file: &str) -> PathBuf {
        self.dir.join(file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn fake_manifest(dir: &Path) {
        // one variant, two params
        let bin: Vec<f32> = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let bytes: Vec<u8> = bin.iter().flat_map(|f| f.to_le_bytes()).collect();
        std::fs::write(dir.join("v.params.bin"), &bytes).unwrap();
        let m = r#"{"version":1,"variants":[{
            "name":"v","arch":{"kind":"mamba1","vocab":8,"d_model":2,"n_layer":1,
            "d_inner":4,"d_state":2,"d_conv":4,"dt_rank":1,"n_head":1,"h_add":1},
            "peft":{"method":"lora","rank":2,"targets":["linproj"],"n_tokens":0},
            "batch":{"B":2,"L":4},"reg":false,
            "files":{"step":"v.step.hlo.txt","fwd":"v.fwd.hlo.txt",
                     "decode":"v.decode.hlo.txt",
                     "prefill":{"4":"v.prefill4.hlo.txt","16":"v.prefill16.hlo.txt"},
                     "decode_adapters":"v.decode_adapters.hlo.txt"},
            "adapter_operands":{"rank":8,"k":16,"operands":[
                {"name":"scale","shape":[2],"dtype":"f32"},
                {"name":"layers.0.Win_x.lora_a","shape":[2,2,8],"dtype":"f32"},
                {"name":"layers.0.A_log.sdt_idx","shape":[2,16],"dtype":"i32"}]},
            "params_bin":"v.params.bin",
            "train_params":[{"name":"a","shape":[2,2],"offset":0,"numel":4}],
            "frozen_params":[{"name":"b","shape":[2],"offset":16,"numel":2}]
        }]}"#;
        let mut f = std::fs::File::create(dir.join("manifest.json")).unwrap();
        f.write_all(m.as_bytes()).unwrap();
    }

    #[test]
    fn load_roundtrip() {
        let dir = std::env::temp_dir().join(format!("ssmpeft_mani_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        fake_manifest(&dir);
        let m = Manifest::load(&dir).unwrap();
        let v = m.variant("v").unwrap();
        assert_eq!(v.peft.method, PeftMethod::Lora(crate::suite::Target::LinProj));
        assert_eq!(v.peft.rank, 2);
        assert_eq!(v.peft.alpha, 2, "alpha defaults to rank when absent");
        assert_eq!(v.batch_b, 2);
        assert_eq!(v.n_train(), 4);
        assert_eq!(v.n_total(), 6);
        assert!((v.train_fraction() - 4.0 / 6.0).abs() < 1e-12);
        assert_eq!(v.train_index("a"), Some(0));
        // prefill entries are sorted by numeric width ("16" sorts before
        // "4" lexicographically — the manifest object order must not leak)
        assert_eq!(
            v.prefill_files,
            vec![(4, "v.prefill4.hlo.txt".to_string()),
                 (16, "v.prefill16.hlo.txt".to_string())]
        );
        let params = m.load_params(v).unwrap();
        assert_eq!(params["a"].data, vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(params["b"].data, vec![5.0, 6.0]);
        // v3: unmerged-decode artifact + operand layout table
        assert_eq!(v.decode_adapters_file.as_deref(),
                   Some("v.decode_adapters.hlo.txt"));
        let ao = v.adapter_operands.as_ref().unwrap();
        assert_eq!((ao.rank, ao.k), (8, 16));
        assert_eq!(ao.operands.len(), 3);
        assert_eq!(ao.operands[0].name, "scale");
        assert_eq!(ao.operands[1].shape, vec![2, 2, 8]);
        assert_eq!(ao.operands[2].dtype, OperandDtype::I32);
        assert_eq!(ao.operands[1].dtype, OperandDtype::F32);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_variant_errors() {
        let dir = std::env::temp_dir().join(format!("ssmpeft_mani2_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        fake_manifest(&dir);
        let m = Manifest::load(&dir).unwrap();
        assert!(m.variant("nope").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
