//! Crate-wide error taxonomy: a zero-dependency `Result`/`Error` pair that
//! replaces the external `anyhow` crate everywhere in the workspace.
//!
//! The offline build vendors no third-party crates (see rust/Cargo.toml),
//! and the serve layer needs *classified* errors — a malformed request must
//! degrade that one request, not kill a scheduler lane — so the crate owns
//! its error type:
//!
//! - [`Error`] carries an [`ErrorKind`], a message, and an optional cause
//!   chain built up by [`Context::context`] / [`Context::with_context`].
//! - `{e}` prints the outermost message; `{e:#}` prints the whole chain
//!   (`outer: inner: root`), matching the convention the suite runner and
//!   serve responses already rely on.
//! - The [`err!`](crate::err!), [`bail!`](crate::bail!) and
//!   [`ensure!`](crate::ensure!) macros cover the construction patterns the
//!   code used from `anyhow`.

use std::fmt;

/// Coarse classification of an [`Error`], for programmatic handling at the
/// layer boundaries (the serve loop maps `Request` errors to a per-request
/// JSON error response and everything else to a lane failure).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[non_exhaustive]
pub enum ErrorKind {
    /// I/O failure (filesystem, sockets).
    Io,
    /// Malformed input: JSON, config, manifest, checkpoint, suite spec.
    Parse,
    /// A malformed or unsatisfiable client request (serve layer).
    Request,
    /// Artifact execution / accelerator-backend failure.
    Runtime,
    /// A violated internal invariant surfaced as an error instead of a
    /// panic (the no-panic lint converts "impossible" states to these).
    Invariant,
    /// A budget ran out: request deadline, retry budget, or the
    /// scheduler's max-tick budget. Always terminal — retrying an
    /// exhausted request would just re-spend the budget it already spent.
    Exhausted,
    /// Anything else.
    Other,
}

impl ErrorKind {
    /// Whether the serve layer's retry policy treats this kind as
    /// transient (worth a bounded retry with backoff) rather than
    /// terminal. I/O and runtime/accelerator failures are the two
    /// classes that plausibly succeed on a second attempt; malformed
    /// requests, parse errors, violated invariants and exhausted budgets
    /// never do.
    pub fn is_transient(self) -> bool {
        matches!(self, ErrorKind::Io | ErrorKind::Runtime)
    }
}

/// The crate-wide error type. See the [module docs](self) for the display
/// and chaining conventions.
pub struct Error {
    kind: ErrorKind,
    msg: String,
    cause: Option<Box<Error>>,
}

/// Crate-wide result alias (defaults the error type to [`Error`]).
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// An error with an explicit kind.
    pub fn new(kind: ErrorKind, msg: impl Into<String>) -> Error {
        Error { kind, msg: msg.into(), cause: None }
    }

    /// An [`ErrorKind::Other`] error from a message (what [`err!`](crate::err!)
    /// expands to).
    pub fn msg(msg: impl Into<String>) -> Error {
        Error::new(ErrorKind::Other, msg)
    }

    /// Reclassify this error (outermost kind wins; the chain keeps the
    /// original as its cause kind).
    pub fn with_kind(mut self, kind: ErrorKind) -> Error {
        self.kind = kind;
        self
    }

    /// The error's classification.
    pub fn kind(&self) -> ErrorKind {
        self.kind
    }

    /// Wrap this error with an outer context message. The wrapper inherits
    /// the inner kind so classification survives `.context(...)`.
    pub fn context(self, msg: impl Into<String>) -> Error {
        Error { kind: self.kind, msg: msg.into(), cause: Some(Box::new(self)) }
    }

    /// Iterate the chain from outermost to root cause.
    pub fn chain(&self) -> impl Iterator<Item = &Error> {
        let mut next = Some(self);
        std::iter::from_fn(move || {
            let cur = next?;
            next = cur.cause.as_deref();
            Some(cur)
        })
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{e:#}`: the whole chain, outermost first.
            for (i, e) in self.chain().enumerate() {
                if i > 0 {
                    write!(f, ": ")?;
                }
                write!(f, "{}", e.msg)?;
            }
            Ok(())
        } else {
            write!(f, "{}", self.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `main() -> Result<()>` exits through this; show the full story.
        write!(f, "{}", self.msg)?;
        let mut rest = self.cause.as_deref();
        if rest.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(e) = rest {
            write!(f, "\n    {}", e.msg)?;
            rest = e.cause.as_deref();
        }
        Ok(())
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        self.cause.as_deref().map(|e| e as _)
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error::new(ErrorKind::Io, e.to_string())
    }
}

impl From<std::num::ParseIntError> for Error {
    fn from(e: std::num::ParseIntError) -> Error {
        Error::new(ErrorKind::Parse, e.to_string())
    }
}

impl From<std::num::ParseFloatError> for Error {
    fn from(e: std::num::ParseFloatError) -> Error {
        Error::new(ErrorKind::Parse, e.to_string())
    }
}

impl From<std::str::Utf8Error> for Error {
    fn from(e: std::str::Utf8Error) -> Error {
        Error::new(ErrorKind::Parse, e.to_string())
    }
}

impl From<std::string::FromUtf8Error> for Error {
    fn from(e: std::string::FromUtf8Error) -> Error {
        Error::new(ErrorKind::Parse, e.to_string())
    }
}

impl From<crate::xla::XlaError> for Error {
    fn from(e: crate::xla::XlaError) -> Error {
        Error::new(ErrorKind::Runtime, e.to_string())
    }
}

impl From<String> for Error {
    fn from(msg: String) -> Error {
        Error::msg(msg)
    }
}

impl From<&str> for Error {
    fn from(msg: &str) -> Error {
        Error::msg(msg)
    }
}

/// Fallible-chain extension: attach context to `Result`/`Option`, exactly
/// the two methods the codebase used from `anyhow::Context`.
pub trait Context<T> {
    /// Wrap the error (or `None`) with a fixed context message.
    fn context<C: fmt::Display>(self, msg: C) -> Result<T>;
    /// Wrap the error (or `None`) with a lazily-built context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, msg: C) -> Result<T> {
        self.map_err(|e| e.into().context(msg.to_string()))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f().to_string()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, msg: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(msg.to_string()))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

/// Build an [`Error`](crate::error::Error) from a format string:
/// `err!("variant {name:?} not found")`.
#[macro_export]
macro_rules! err {
    ($($arg:tt)*) => {
        $crate::error::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`](crate::error::Error) built from a format
/// string: `bail!("unknown adapter {id}")`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::err!($($arg)*))
    };
}

/// Return early with an error unless a condition holds:
/// `ensure!(a == b, "mismatch {a} vs {b}")`.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_thing(s: &str) -> Result<u32> {
        s.parse::<u32>().with_context(|| format!("parsing {s:?}"))
    }

    #[test]
    fn display_plain_vs_alternate() {
        let e = parse_thing("zz").unwrap_err().context("loading config");
        assert_eq!(format!("{e}"), "loading config");
        let chain = format!("{e:#}");
        assert!(chain.starts_with("loading config: parsing \"zz\": "), "{chain}");
    }

    #[test]
    fn kind_survives_context() {
        let e = Error::new(ErrorKind::Request, "missing field")
            .context("handling request");
        assert_eq!(e.kind(), ErrorKind::Request);
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("empty").unwrap_err();
        assert_eq!(format!("{e}"), "empty");
        assert_eq!(e.kind(), ErrorKind::Other);
    }

    #[test]
    fn macros_build_and_bail() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 7 {
                bail!("unlucky {x}");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(format!("{}", f(7).unwrap_err()), "unlucky 7");
        assert_eq!(format!("{}", f(11).unwrap_err()), "x too big: 11");
        let e = err!("plain {}", 1);
        assert_eq!(format!("{e}"), "plain 1");
    }

    #[test]
    fn transient_classification() {
        assert!(ErrorKind::Io.is_transient());
        assert!(ErrorKind::Runtime.is_transient());
        assert!(!ErrorKind::Request.is_transient());
        assert!(!ErrorKind::Parse.is_transient());
        assert!(!ErrorKind::Invariant.is_transient());
        assert!(!ErrorKind::Exhausted.is_transient());
        assert!(!ErrorKind::Other.is_transient());
    }

    #[test]
    fn io_from_sets_kind() {
        let e: Error = std::io::Error::new(std::io::ErrorKind::NotFound, "gone").into();
        assert_eq!(e.kind(), ErrorKind::Io);
    }

    #[test]
    fn debug_shows_cause_chain() {
        let e = Error::msg("root").context("mid").context("top");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("top"), "{dbg}");
        assert!(dbg.contains("Caused by:"), "{dbg}");
        assert!(dbg.contains("root"), "{dbg}");
        assert_eq!(e.chain().count(), 3);
    }
}
