//! Training engine: drives the AOT `step` artifact — upload params+batch,
//! read back (loss, grads), apply PEFT masks, clip, optimizer update.
//!
//! Python is never invoked here; the full fine-tuning loop is Rust + the
//! compiled XLA executable.

pub mod checkpoint;

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use crate::data::Batch;
use crate::manifest::{Manifest, Variant};
use crate::optim::{clip_global_norm, AdamW, Schedule};
use crate::peft::Masks;
use crate::runtime::{Engine, Executable, Input};
use crate::tensor::Tensor;

/// Training-loop configuration.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Peak learning rate.
    pub lr: f32,
    /// AdamW decoupled weight decay.
    pub weight_decay: f32,
    /// Global gradient-norm clip.
    pub clip_norm: f32,
    /// Total steps the LR schedule decays over.
    pub schedule_total: usize,
    /// LR warmup steps.
    pub warmup_steps: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            lr: 1e-3,
            weight_decay: 0.01,
            clip_norm: 1.0,
            schedule_total: 1000,
            warmup_steps: 0,
        }
    }
}

/// A live training session for one artifact variant.
pub struct Trainer {
    /// The artifact variant being trained.
    pub variant: Variant,
    step_exe: Executable,
    fwd_exe: Executable,
    /// Live trainable tensors (variant.train_params order).
    pub train_params: Vec<Tensor>,
    /// Frozen tensors (variant.frozen_params order).
    pub frozen_params: Vec<Tensor>,
    /// frozen-parameter literals, built once and reused every step
    /// (§Perf L3: avoids re-serializing the (large) frozen set per step)
    frozen_lits: Vec<xla::Literal>,
    /// Gradient masks (SDT); identity by default.
    pub masks: Masks,
    opt: AdamW,
    /// Learning-rate schedule.
    pub sched: Schedule,
    /// Optimizer steps taken so far.
    pub step_count: usize,
    /// (step, loss) history for loss-curve output.
    pub history: Vec<(usize, f32)>,
    /// scratch for gradient tensors (allocation reuse on the hot path)
    grad_buf: Vec<Tensor>,
}

impl Trainer {
    /// Load artifacts + initial parameters for a variant and build the
    /// optimizer state.
    pub fn new(engine: &Engine, manifest: &Manifest, variant_name: &str,
               cfg: &TrainConfig) -> Result<Self> {
        let variant = manifest.variant(variant_name)?.clone();
        let step_file = variant.step_file.clone()
            .with_context(|| format!("{variant_name} has no step artifact"))?;
        let fwd_file = variant.fwd_file.clone()
            .with_context(|| format!("{variant_name} has no fwd artifact"))?;
        let step_exe = engine.load(manifest.hlo_path(&step_file))?;
        let fwd_exe = engine.load(manifest.hlo_path(&fwd_file))?;
        let params = manifest.load_params(&variant)?;
        let train_params: Vec<Tensor> = variant.train_params.iter()
            .map(|p| params[&p.name].clone()).collect();
        let frozen_params: Vec<Tensor> = variant.frozen_params.iter()
            .map(|p| params[&p.name].clone()).collect();
        let mut opt = AdamW::new(&train_params);
        opt.weight_decay = cfg.weight_decay;
        let n = variant.train_params.len();
        let frozen_lits = frozen_params
            .iter()
            .map(crate::runtime::literal_f32)
            .collect::<Result<Vec<_>>>()?;
        Ok(Trainer {
            variant,
            step_exe,
            fwd_exe,
            train_params,
            frozen_params,
            frozen_lits,
            masks: Masks::none(n),
            opt,
            sched: Schedule::linear(cfg.lr, cfg.warmup_steps, cfg.schedule_total),
            step_count: 0,
            history: Vec::new(),
            grad_buf: Vec::new(),
        })
    }

    /// Overlay pretrained base weights by name (PEFT-specific leaves that
    /// don't exist in the checkpoint keep their fresh initialization).
    pub fn load_base(&mut self, ckpt: &BTreeMap<String, Tensor>) {
        for (i, meta) in self.variant.train_params.iter().enumerate() {
            if let Some(t) = ckpt.get(&meta.name) {
                assert_eq!(t.shape, meta.shape, "{} shape drift", meta.name);
                self.train_params[i] = t.clone();
            }
        }
        for (i, meta) in self.variant.frozen_params.iter().enumerate() {
            if let Some(t) = ckpt.get(&meta.name) {
                assert_eq!(t.shape, meta.shape, "{} shape drift", meta.name);
                self.frozen_params[i] = t.clone();
            }
        }
        self.refresh_frozen_lits();
    }

    /// Rebuild the cached frozen-parameter literals (call after mutating
    /// `frozen_params` directly).
    pub fn refresh_frozen_lits(&mut self) {
        self.frozen_lits = self
            .frozen_params
            .iter()
            .map(|t| crate::runtime::literal_f32(t).expect("frozen literal"))
            .collect();
    }

    /// Current parameters as a name-keyed map (checkpointing / merging).
    pub fn params_map(&self) -> BTreeMap<String, Tensor> {
        let mut m = BTreeMap::new();
        for (meta, t) in self.variant.train_params.iter().zip(&self.train_params) {
            m.insert(meta.name.clone(), t.clone());
        }
        for (meta, t) in self.variant.frozen_params.iter().zip(&self.frozen_params) {
            m.insert(meta.name.clone(), t.clone());
        }
        m
    }

    /// Snapshot just the trainable tensors (SDT warmup bookkeeping).
    pub fn snapshot_train(&self) -> Vec<Tensor> {
        self.train_params.clone()
    }
    /// Restore a snapshot taken by [`Trainer::snapshot_train`] and reset
    /// the optimizer (SDT revert step).
    pub fn restore_train(&mut self, snap: Vec<Tensor>) {
        assert_eq!(snap.len(), self.train_params.len());
        self.train_params = snap;
        self.opt.reset();
    }

    /// Map of trainable tensors keyed by name (for SDT selection input).
    pub fn train_map(&self) -> BTreeMap<String, Tensor> {
        self.variant.train_params.iter().zip(&self.train_params)
            .map(|(m, t)| (m.name.clone(), t.clone())).collect()
    }

    /// Build the full literal argument list: fresh literals for the
    /// (mutating) trainable params and the batch, cached literals for the
    /// frozen set.
    fn exec(&self, exe: &crate::runtime::Executable, batch_inputs: &[Input])
        -> Result<Vec<Tensor>> {
        let train_lits = self
            .train_params
            .iter()
            .map(crate::runtime::literal_f32)
            .collect::<Result<Vec<_>>>()?;
        let batch_lits = batch_inputs
            .iter()
            .map(|b| match b {
                Input::F(t) => crate::runtime::literal_f32(t),
                Input::I(t) => crate::runtime::literal_i32(t),
            })
            .collect::<Result<Vec<_>>>()?;
        let refs: Vec<&xla::Literal> = train_lits
            .iter()
            .chain(self.frozen_lits.iter())
            .chain(batch_lits.iter())
            .collect();
        exe.run_refs(&refs)
    }

    fn step_impl(&mut self, batch_inputs: &[Input]) -> Result<f32> {
        let mut outs = self.exec(&self.step_exe.clone(), batch_inputs)?;
        if outs.len() != 1 + self.train_params.len() {
            bail!("step returned {} outputs, expected {}", outs.len(),
                  1 + self.train_params.len());
        }
        let loss = outs[0].data[0];
        let mut grads: Vec<Tensor> = outs.drain(1..).collect();
        self.masks.apply(&mut grads);
        clip_global_norm(&mut grads, 1.0);
        let lr = self.sched.lr_at(self.step_count);
        self.opt.step(&mut self.train_params, &grads, lr);
        self.grad_buf = grads; // keep allocation for reuse-by-inspection
        self.step_count += 1;
        self.history.push((self.step_count, loss));
        Ok(loss)
    }

    /// One optimization step on a token batch.
    pub fn step(&mut self, batch: &Batch) -> Result<f32> {
        self.step_impl(&[Input::I(&batch.tokens), Input::I(&batch.targets),
                         Input::F(&batch.mask)])
    }

    /// One optimization step on a regression batch (s4reg variants).
    pub fn step_reg(&mut self, x: &Tensor, y: &Tensor, mask: &Tensor) -> Result<f32> {
        self.step_impl(&[Input::F(x), Input::F(y), Input::F(mask)])
    }

    /// Forward pass: logits (B, L, V) for a token batch.
    pub fn logits(&self, batch: &Batch) -> Result<Tensor> {
        let outs = self.exec(&self.fwd_exe, &[Input::I(&batch.tokens)])?;
        Ok(outs.into_iter().next().unwrap())
    }

    /// Forward pass for regression variants: y (B, L, D).
    pub fn forward_reg(&self, x: &Tensor) -> Result<Tensor> {
        let outs = self.exec(&self.fwd_exe, &[Input::F(x)])?;
        Ok(outs.into_iter().next().unwrap())
    }

    /// Eval loss on a batch without updating (runs step, discards grads).
    pub fn eval_loss(&self, batch: &Batch) -> Result<f32> {
        let outs = self.exec(&self.step_exe, &[Input::I(&batch.tokens),
                                               Input::I(&batch.targets),
                                               Input::F(&batch.mask)])?;
        Ok(outs[0].data[0])
    }

    /// Last gradient set (profiling/diagnostics).
    pub fn last_grads(&self) -> &[Tensor] {
        &self.grad_buf
    }
}
