//! Training engine: drives the AOT `step` artifact — upload params+batch,
//! read back (loss, grads), apply PEFT masks, clip, optimizer update.
//!
//! Python is never invoked here; the full fine-tuning loop is Rust + the
//! compiled XLA executable.
//!
//! The step hot path is **zero-churn** (§Perf L3, rust/docs/performance.md):
//! trainable leaves live in a [`ParamArena`], their literals persist in a
//! [`ResidentArgs`] table and only the leaves the fused optimizer touched
//! are re-serialized; gradients read back into a reused flat arena (no
//! per-step `Vec<Tensor>`); mask + clip + AdamW run as ONE fused pass over
//! arena chunks ([`FusedAdamW`]). Per-step phase timings (upload / execute
//! / readback / host-optimizer) are recorded in [`StepTimings`] and feed
//! the `bench hotpath` telemetry.

pub mod checkpoint;

use std::collections::BTreeMap;
// Instant feeds the BENCH step-latency telemetry (upload/execute/readback/
// optim breakdown), never a suite record's payload — lint: allow(determinism)
use std::time::Instant;

use crate::bail;
use crate::error::{Context, Result};

use crate::data::Batch;
use crate::manifest::{Manifest, Variant};
use crate::optim::{fused_workers, FusedAdamW, MaskPlan, ParamArena, Schedule};
use crate::peft::Masks;
use crate::xla;
use crate::runtime::{
    literal_f32_slice, read_f32_into, read_scalar_f32, Engine, Executable, Input,
    ResidentArgs,
};
use crate::tensor::Tensor;

/// Training-loop configuration.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Peak learning rate.
    pub lr: f32,
    /// AdamW decoupled weight decay.
    pub weight_decay: f32,
    /// Global gradient-norm clip.
    pub clip_norm: f32,
    /// Total steps the LR schedule decays over.
    pub schedule_total: usize,
    /// LR warmup steps.
    pub warmup_steps: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            lr: 1e-3,
            weight_decay: 0.01,
            clip_norm: 1.0,
            schedule_total: 1000,
            warmup_steps: 0,
        }
    }
}

/// Wall-clock breakdown of one training step's phases.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct StepTimings {
    /// Host→literal serialization: dirty trainable leaves + the batch.
    pub upload_s: f64,
    /// XLA execute (includes the device→host output transfer).
    pub execute_s: f64,
    /// Gradient copy into the reused grad arena + loss read.
    pub readback_s: f64,
    /// The fused mask+clip+AdamW pass.
    pub optim_s: f64,
}

impl StepTimings {
    /// Host-side per-step overhead: everything except the XLA execute.
    pub fn host_s(&self) -> f64 {
        self.upload_s + self.readback_s + self.optim_s
    }

    /// Whole-step wall clock.
    pub fn total_s(&self) -> f64 {
        self.host_s() + self.execute_s
    }

    /// Add another step's phases into this accumulator.
    pub fn accumulate(&mut self, o: &StepTimings) {
        self.upload_s += o.upload_s;
        self.execute_s += o.execute_s;
        self.readback_s += o.readback_s;
        self.optim_s += o.optim_s;
    }

    /// Phase-wise scaling (e.g. `totals.scaled(1.0 / steps)` for means).
    pub fn scaled(&self, k: f64) -> StepTimings {
        StepTimings {
            upload_s: self.upload_s * k,
            execute_s: self.execute_s * k,
            readback_s: self.readback_s * k,
            optim_s: self.optim_s * k,
        }
    }
}

/// A live training session for one artifact variant.
pub struct Trainer {
    /// The artifact variant being trained.
    pub variant: Variant,
    step_exe: Executable,
    fwd_exe: Executable,
    /// Trainable leaves, flattened (variant.train_params order).
    arena: ParamArena,
    /// Frozen tensors (variant.frozen_params order).
    pub frozen_params: Vec<Tensor>,
    /// frozen-parameter literals, built once and reused every step
    /// (§Perf L2: avoids re-serializing the (large) frozen set per step)
    frozen_lits: Vec<xla::Literal>,
    /// Trainable-leaf literals with dirty tracking: only leaves the fused
    /// optimizer touched are re-serialized (§Perf L3).
    resident: ResidentArgs,
    /// Gradient masks (SDT); identity by default. Installed via
    /// [`Trainer::set_masks`] so the fused plan stays in sync.
    masks: Masks,
    /// Compiled fused-pass plan (sparse index sets for SDT masks).
    plan: MaskPlan,
    opt: FusedAdamW,
    /// Learning-rate schedule.
    pub sched: Schedule,
    /// Global gradient-norm clip threshold (from [`TrainConfig`]).
    pub clip_norm: f32,
    /// Optimizer steps taken so far.
    pub step_count: usize,
    /// (step, loss) history for loss-curve output.
    pub history: Vec<(usize, f32)>,
    /// (step, pre-clip global grad norm) diagnostics, parallel to
    /// `history` — exposes the clip behavior the old hardcoded threshold
    /// silently hid.
    pub norm_history: Vec<(usize, f32)>,
    /// Reused flat gradient buffer (arena layout) — no per-step allocs.
    grads: Vec<f32>,
    /// Clip scale of the last step (for [`Trainer::last_grads`]).
    last_clip_scale: f32,
    workers: usize,
    last_timings: StepTimings,
    total_timings: StepTimings,
}

impl Trainer {
    /// Load artifacts + initial parameters for a variant and build the
    /// optimizer state.
    pub fn new(engine: &Engine, manifest: &Manifest, variant_name: &str,
               cfg: &TrainConfig) -> Result<Self> {
        let variant = manifest.variant(variant_name)?.clone();
        let step_file = variant.step_file.clone()
            .with_context(|| format!("{variant_name} has no step artifact"))?;
        let fwd_file = variant.fwd_file.clone()
            .with_context(|| format!("{variant_name} has no fwd artifact"))?;
        let step_exe = engine.load(manifest.hlo_path(&step_file))?;
        let fwd_exe = engine.load(manifest.hlo_path(&fwd_file))?;
        let params = manifest.load_params(&variant)?;
        let train_params: Vec<Tensor> = variant.train_params.iter()
            .map(|p| params[&p.name].clone()).collect();
        let frozen_params: Vec<Tensor> = variant.frozen_params.iter()
            .map(|p| params[&p.name].clone()).collect();
        let arena = ParamArena::pack(&train_params);
        let mut opt = FusedAdamW::new(&arena);
        opt.weight_decay = cfg.weight_decay;
        let n = variant.train_params.len();
        let frozen_lits = frozen_params
            .iter()
            .map(crate::runtime::literal_f32)
            .collect::<Result<Vec<_>>>()?;
        let resident = ResidentArgs::from_tensors(&train_params)?;
        let plan = MaskPlan::full(&arena);
        let grads = vec![0.0; arena.len()];
        Ok(Trainer {
            variant,
            step_exe,
            fwd_exe,
            arena,
            frozen_params,
            frozen_lits,
            resident,
            masks: Masks::none(n),
            plan,
            opt,
            sched: Schedule::linear(cfg.lr, cfg.warmup_steps, cfg.schedule_total),
            clip_norm: cfg.clip_norm,
            step_count: 0,
            history: Vec::new(),
            norm_history: Vec::new(),
            grads,
            last_clip_scale: 1.0,
            workers: fused_workers(),
            last_timings: StepTimings::default(),
            total_timings: StepTimings::default(),
        })
    }

    /// Overlay pretrained base weights by name (PEFT-specific leaves that
    /// don't exist in the checkpoint keep their fresh initialization).
    pub fn load_base(&mut self, ckpt: &BTreeMap<String, Tensor>) {
        for (i, meta) in self.variant.train_params.iter().enumerate() {
            if let Some(t) = ckpt.get(&meta.name) {
                assert_eq!(t.shape, meta.shape, "{} shape drift", meta.name);
                self.arena.write_leaf(i, &t.data);
                self.resident.mark_dirty(i);
            }
        }
        for (i, meta) in self.variant.frozen_params.iter().enumerate() {
            if let Some(t) = ckpt.get(&meta.name) {
                assert_eq!(t.shape, meta.shape, "{} shape drift", meta.name);
                self.frozen_params[i] = t.clone();
            }
        }
        self.refresh_frozen_lits();
    }

    /// Rebuild the cached frozen-parameter literals (call after mutating
    /// `frozen_params` directly).
    pub fn refresh_frozen_lits(&mut self) {
        self.frozen_lits = self
            .frozen_params
            .iter()
            .map(|t| crate::runtime::literal_f32(t).expect("frozen literal"))
            .collect();
    }

    /// Install gradient masks (SDT) and recompile the fused-pass plan.
    /// Install masks right after an optimizer reset (the SDT revert path
    /// does) so frozen leaves take the sparse O(active) path.
    pub fn set_masks(&mut self, masks: Masks) {
        assert_eq!(masks.masks.len(), self.arena.n_leaves(), "mask count mismatch");
        self.masks = masks;
        self.recompile_plan();
    }

    /// The installed gradient masks.
    pub fn masks(&self) -> &Masks {
        &self.masks
    }

    fn recompile_plan(&mut self) {
        let (m, v) = self.opt.moments();
        self.plan = MaskPlan::compile(&self.masks.masks, &self.arena, m, v);
    }

    /// Current parameters as a name-keyed map (checkpointing / merging).
    pub fn params_map(&self) -> BTreeMap<String, Tensor> {
        let mut m = BTreeMap::new();
        for (i, meta) in self.variant.train_params.iter().enumerate() {
            m.insert(meta.name.clone(), self.arena.leaf_tensor(i));
        }
        for (meta, t) in self.variant.frozen_params.iter().zip(&self.frozen_params) {
            m.insert(meta.name.clone(), t.clone());
        }
        m
    }

    /// Snapshot just the trainable tensors (SDT warmup bookkeeping,
    /// early-stopping best-epoch capture).
    pub fn snapshot_train(&self) -> Vec<Tensor> {
        self.arena.unpack()
    }

    /// Overwrite the trainable tensors (early stopping restores the best
    /// epoch this way). Optimizer state is kept; use
    /// [`Trainer::restore_train`] for the SDT revert, which also resets it.
    pub fn set_train_params(&mut self, snap: Vec<Tensor>) {
        assert_eq!(snap.len(), self.arena.n_leaves());
        for (i, t) in snap.iter().enumerate() {
            self.arena.write_leaf(i, &t.data);
            self.resident.mark_dirty(i);
        }
    }

    /// Restore a snapshot taken by [`Trainer::snapshot_train`] and reset
    /// the optimizer (SDT revert step).
    pub fn restore_train(&mut self, snap: Vec<Tensor>) {
        self.set_train_params(snap);
        self.opt.reset();
        self.recompile_plan();
    }

    /// Map of trainable tensors keyed by name (for SDT selection input).
    pub fn train_map(&self) -> BTreeMap<String, Tensor> {
        self.variant.train_params.iter().enumerate()
            .map(|(i, m)| (m.name.clone(), self.arena.leaf_tensor(i))).collect()
    }

    /// Refresh the resident literal cache for any dirty leaves. The step
    /// path does this automatically; call it before a *batch* of `&self`
    /// evaluation calls ([`Trainer::logits`] / [`Trainer::eval_loss`]) so
    /// they hit the cache instead of re-serializing dirty leaves into
    /// scratch literals on every call.
    pub fn sync_device(&mut self) -> Result<()> {
        self.refresh_dirty_lits()
    }

    /// Re-serialize the literals of leaves the optimizer dirtied since the
    /// last upload.
    fn refresh_dirty_lits(&mut self) -> Result<()> {
        if !self.resident.any_dirty() {
            return Ok(());
        }
        for i in 0..self.resident.len() {
            if self.resident.is_dirty(i) {
                let leaf = &self.arena.leaves()[i];
                let lit = literal_f32_slice(&leaf.shape, self.arena.leaf(i))?;
                self.resident.install(i, lit);
            }
        }
        Ok(())
    }

    /// Execute on `&self` paths (fwd / eval): resident literals for clean
    /// leaves, one-off scratch literals for any still-dirty ones (the
    /// cache itself can't be updated without `&mut`).
    fn exec(&self, exe: &Executable, batch_inputs: &[Input]) -> Result<Vec<Tensor>> {
        let batch_lits = Self::batch_literals(batch_inputs)?;
        let mut scratch = Vec::new();
        for i in 0..self.resident.len() {
            if self.resident.is_dirty(i) {
                let leaf = &self.arena.leaves()[i];
                scratch.push(literal_f32_slice(&leaf.shape, self.arena.leaf(i))?);
            }
        }
        let mut si = 0;
        let mut refs: Vec<&xla::Literal> = Vec::with_capacity(
            self.resident.len() + self.frozen_lits.len() + batch_lits.len(),
        );
        for i in 0..self.resident.len() {
            if self.resident.is_dirty(i) {
                refs.push(&scratch[si]);
                si += 1;
            } else {
                refs.push(self.resident.literal(i));
            }
        }
        refs.extend(self.frozen_lits.iter());
        refs.extend(batch_lits.iter());
        exe.run_refs(&refs)
    }

    fn batch_literals(batch_inputs: &[Input]) -> Result<Vec<xla::Literal>> {
        batch_inputs
            .iter()
            .map(|b| match b {
                Input::F(t) => crate::runtime::literal_f32(t),
                Input::I(t) => crate::runtime::literal_i32(t),
            })
            .collect()
    }

    fn step_impl(&mut self, batch_inputs: &[Input]) -> Result<f32> {
        // ---- upload: dirty leaves + batch --------------------------------
        let t0 = Instant::now(); // lint: allow(determinism) telemetry
        self.refresh_dirty_lits()?;
        let batch_lits = Self::batch_literals(batch_inputs)?;
        let upload_s = t0.elapsed().as_secs_f64();

        // ---- execute -----------------------------------------------------
        let t1 = Instant::now(); // lint: allow(determinism) telemetry
        let outs = {
            let mut refs: Vec<&xla::Literal> = Vec::with_capacity(
                self.resident.len() + self.frozen_lits.len() + batch_lits.len(),
            );
            refs.extend(self.resident.literals().iter());
            refs.extend(self.frozen_lits.iter());
            refs.extend(batch_lits.iter());
            self.step_exe.run_refs_literals(&refs)?
        };
        let execute_s = t1.elapsed().as_secs_f64();

        let n = self.arena.n_leaves();
        if outs.len() != 1 + n {
            bail!("step returned {} outputs, expected {}", outs.len(), 1 + n);
        }

        // ---- readback: loss + grads into the reused arena ----------------
        let t2 = Instant::now(); // lint: allow(determinism) telemetry
        let loss = read_scalar_f32(&outs[0])?;
        for i in 0..n {
            let (off, len) = {
                let l = &self.arena.leaves()[i];
                (l.offset, l.len)
            };
            read_f32_into(&outs[1 + i], &mut self.grads[off..off + len])?;
        }
        let readback_s = t2.elapsed().as_secs_f64();

        // ---- fused mask + clip + AdamW -----------------------------------
        let t3 = Instant::now(); // lint: allow(determinism) telemetry
        let lr = self.sched.lr_at(self.step_count);
        let rep = self.opt.step(
            &mut self.arena,
            &self.grads,
            &self.plan,
            lr,
            self.clip_norm,
            self.workers,
        );
        for (i, &d) in rep.dirty.iter().enumerate() {
            if d {
                self.resident.mark_dirty(i);
            }
        }
        self.last_clip_scale = rep.clip_scale;
        let optim_s = t3.elapsed().as_secs_f64();

        self.step_count += 1;
        self.history.push((self.step_count, loss));
        self.norm_history.push((self.step_count, rep.pre_clip_norm));
        let t = StepTimings { upload_s, execute_s, readback_s, optim_s };
        self.last_timings = t;
        self.total_timings.accumulate(&t);
        Ok(loss)
    }

    /// One optimization step on a token batch.
    pub fn step(&mut self, batch: &Batch) -> Result<f32> {
        self.step_impl(&[Input::I(&batch.tokens), Input::I(&batch.targets),
                         Input::F(&batch.mask)])
    }

    /// One optimization step on a regression batch (s4reg variants).
    pub fn step_reg(&mut self, x: &Tensor, y: &Tensor, mask: &Tensor) -> Result<f32> {
        self.step_impl(&[Input::F(x), Input::F(y), Input::F(mask)])
    }

    /// Forward pass: logits (B, L, V) for a token batch.
    pub fn logits(&self, batch: &Batch) -> Result<Tensor> {
        let outs = self.exec(&self.fwd_exe, &[Input::I(&batch.tokens)])?;
        outs.into_iter().next().context("fwd executable returned no outputs")
    }

    /// Forward pass for regression variants: y (B, L, D).
    pub fn forward_reg(&self, x: &Tensor) -> Result<Tensor> {
        let outs = self.exec(&self.fwd_exe, &[Input::F(x)])?;
        outs.into_iter().next().context("fwd executable returned no outputs")
    }

    /// Eval loss on a batch without updating (runs step, discards grads).
    pub fn eval_loss(&self, batch: &Batch) -> Result<f32> {
        let outs = self.exec(&self.step_exe, &[Input::I(&batch.tokens),
                                               Input::I(&batch.targets),
                                               Input::F(&batch.mask)])?;
        Ok(outs[0].data[0])
    }

    /// Last gradient set as shaped tensors, masked and clipped exactly as
    /// the optimizer saw them (profiling / the SDT grad-magnitude
    /// criterion). Materialized on demand — the hot path keeps gradients
    /// flat in the arena.
    pub fn last_grads(&self) -> Vec<Tensor> {
        (0..self.arena.n_leaves())
            .map(|i| {
                let leaf = &self.arena.leaves()[i];
                let g = &self.grads[leaf.offset..leaf.offset + leaf.len];
                let s = self.last_clip_scale;
                let data: Vec<f32> = match &self.masks.masks[i] {
                    None => g.iter().map(|&x| x * s).collect(),
                    Some(m) => g.iter().zip(m).map(|(&x, &k)| x * k * s).collect(),
                };
                Tensor::from_vec(&leaf.shape, data)
            })
            .collect()
    }

    /// Phase breakdown of the most recent step.
    pub fn last_timings(&self) -> StepTimings {
        self.last_timings
    }

    /// Accumulated phase totals across all steps taken (divide by
    /// [`Trainer::step_count`] for means).
    pub fn timings_total(&self) -> StepTimings {
        self.total_timings
    }

    /// The compiled fused-pass plan (diagnostics: sparse vs dense leaves).
    pub fn plan(&self) -> &MaskPlan {
        &self.plan
    }
}
