//! Checkpoint format: JSON header (names/shapes/offsets) + raw f32-LE blob,
//! in one file. Used for the pretrained base models and fine-tuned results.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use crate::{bail, err};
use crate::error::{Context, Result};

use crate::json::{self, Value};
use crate::tensor::Tensor;

const MAGIC: &[u8; 8] = b"SSMPEFT1";

/// Write a named-tensor checkpoint (self-describing binary format).
pub fn save(params: &BTreeMap<String, Tensor>, path: impl AsRef<Path>) -> Result<()> {
    let mut header = Vec::new();
    let mut blob: Vec<u8> = Vec::new();
    for (name, t) in params {
        header.push(json::obj(vec![
            ("name", json::s(name)),
            ("shape", Value::Arr(t.shape.iter().map(|&d| json::num(d as f64)).collect())),
            ("offset", json::num(blob.len() as f64)),
        ]));
        for &x in &t.data {
            blob.extend_from_slice(&x.to_le_bytes());
        }
    }
    let header = json::emit(&Value::Arr(header));
    let mut f = std::fs::File::create(path.as_ref())
        .with_context(|| format!("creating {:?}", path.as_ref()))?;
    f.write_all(MAGIC)?;
    f.write_all(&(header.len() as u64).to_le_bytes())?;
    f.write_all(header.as_bytes())?;
    f.write_all(&blob)?;
    Ok(())
}

/// Read a checkpoint written by [`save`].
pub fn load(path: impl AsRef<Path>) -> Result<BTreeMap<String, Tensor>> {
    let mut f = std::fs::File::open(path.as_ref())
        .with_context(|| format!("opening {:?}", path.as_ref()))?;
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("not a ssm-peft checkpoint: {:?}", path.as_ref());
    }
    let mut len8 = [0u8; 8];
    f.read_exact(&mut len8)?;
    let hlen = u64::from_le_bytes(len8) as usize;
    let mut hbytes = vec![0u8; hlen];
    f.read_exact(&mut hbytes)?;
    let header = json::parse(std::str::from_utf8(&hbytes)?)
        .map_err(|e| err!("checkpoint header: {e}"))?;
    let mut blob = Vec::new();
    f.read_to_end(&mut blob)?;
    let mut out = BTreeMap::new();
    for ent in header.as_arr().ok_or_else(|| err!("bad header"))? {
        let name = ent
            .path("name")
            .and_then(Value::as_str)
            .context("checkpoint header entry missing name")?
            .to_string();
        let shape: Vec<usize> = ent
            .path("shape")
            .and_then(Value::as_arr)
            .with_context(|| format!("checkpoint entry {name:?} missing shape"))?
            .iter()
            .filter_map(Value::as_usize)
            .collect();
        let off = ent
            .path("offset")
            .and_then(Value::as_usize)
            .with_context(|| format!("checkpoint entry {name:?} missing offset"))?;
        let numel: usize = shape.iter().product();
        let bytes = blob
            .get(off..off + 4 * numel)
            .with_context(|| format!("checkpoint entry {name:?} payload out of bounds"))?;
        let data = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        out.insert(name, Tensor::from_vec(&shape, data));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let mut m = BTreeMap::new();
        m.insert("a.b".to_string(), Tensor::from_vec(&[2, 2], vec![1.0, -2.0, 3.5, 0.0]));
        m.insert("c".to_string(), Tensor::from_vec(&[3], vec![9.0, 8.0, 7.0]));
        let p = std::env::temp_dir().join(format!("ckpt_test_{}.bin", std::process::id()));
        save(&m, &p).unwrap();
        let back = load(&p).unwrap();
        assert_eq!(m, back);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn rejects_non_checkpoint() {
        let p = std::env::temp_dir().join(format!("ckpt_bad_{}.bin", std::process::id()));
        std::fs::write(&p, b"not a checkpoint").unwrap();
        assert!(load(&p).is_err());
        std::fs::remove_file(&p).ok();
    }
}
