//! Host tensor type + deterministic RNG + small stats helpers.
//!
//! The coordinator keeps all parameters, gradients and optimizer state in
//! host `Tensor`s (f32, row-major). The PJRT CPU client shares the same
//! address space, so uploads are cheap copies; the runtime module converts
//! to/from `xla::Literal`.

/// Row-major f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    /// Dimension sizes (row-major).
    pub shape: Vec<usize>,
    /// Flat element storage.
    pub data: Vec<f32>,
}

impl Tensor {
    /// All-zeros tensor of the given shape.
    pub fn zeros(shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }
    /// Tensor over existing storage (asserts shape/len agreement).
    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor { shape: shape.to_vec(), data }
    }
    /// Rank-0 tensor holding one value.
    pub fn scalar(v: f32) -> Self {
        Tensor { shape: vec![], data: vec![v] }
    }
    /// Total element count.
    pub fn numel(&self) -> usize {
        self.data.len()
    }
    /// Squared L2 norm.
    pub fn sq_norm(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum()
    }
    /// Row view for a 2-D tensor.
    pub fn row(&self, r: usize) -> &[f32] {
        let cols = *self.shape.last().unwrap();
        &self.data[r * cols..(r + 1) * cols]
    }
}

/// Integer tensor (token ids). PJRT side is s32.
#[derive(Debug, Clone, PartialEq)]
pub struct IntTensor {
    /// Dimension sizes (row-major).
    pub shape: Vec<usize>,
    /// Flat element storage.
    pub data: Vec<i32>,
}

impl IntTensor {
    /// All-zeros tensor of the given shape.
    pub fn zeros(shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        IntTensor { shape: shape.to_vec(), data: vec![0; n] }
    }
    /// Tensor over existing storage (asserts shape/len agreement).
    pub fn from_vec(shape: &[usize], data: Vec<i32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        IntTensor { shape: shape.to_vec(), data }
    }
}

/// xorshift128+ PRNG — deterministic, dependency-free, splittable by stream.
#[derive(Debug, Clone)]
pub struct Rng {
    s0: u64,
    s1: u64,
}

impl Rng {
    /// Seeded stream (splitmix64-expanded so nearby seeds decorrelate).
    pub fn new(seed: u64) -> Self {
        // splitmix64 expansion so nearby seeds give unrelated streams
        fn mix(mut z: u64) -> u64 {
            z = z.wrapping_add(0x9e3779b97f4a7c15);
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
            z ^ (z >> 31)
        }
        let s0 = mix(seed);
        Rng { s0, s1: mix(s0) }
    }
    /// Independent sub-stream (for per-task / per-epoch shuffles).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9e3779b97f4a7c15))
    }
    /// Next raw 64-bit draw.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.s0;
        let y = self.s1;
        self.s0 = y;
        x ^= x << 23;
        self.s1 = x ^ y ^ (x >> 17) ^ (y >> 26);
        self.s1.wrapping_add(y)
    }
    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }
    /// Uniform integer in [0, n), without modulo bias.
    ///
    /// Lemire's widening-multiply rejection method: map a 64-bit draw to
    /// [0, n) via the high half of a 128-bit product, rejecting the few
    /// draws that land in the partial bucket (at most one expected retry,
    /// and none at all when n divides 2^64).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0, "below(0)");
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut lo = m as u64;
        if lo < n {
            // threshold = 2^64 mod n; draws with lo below it are the
            // over-represented remainder and must be rejected
            let t = n.wrapping_neg() % n;
            while lo < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as usize
    }
    /// Standard normal (Box–Muller).
    pub fn normal(&mut self) -> f32 {
        let u1 = self.uniform().max(1e-7);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }
    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            v.swap(i, self.below(i + 1));
        }
    }
    /// Uniformly random element of a non-empty slice.
    pub fn choice<'a, T>(&mut self, v: &'a [T]) -> &'a T {
        &v[self.below(v.len())]
    }
}

/// Mean of a slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Argmax over a slice (first max wins).
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_basics() {
        let t = Tensor::zeros(&[2, 3]);
        assert_eq!(t.numel(), 6);
        let t2 = Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(t2.row(1), &[3.0, 4.0]);
        assert!((t2.sq_norm() - 30.0).abs() < 1e-9);
    }

    #[test]
    #[should_panic]
    fn tensor_shape_mismatch_panics() {
        Tensor::from_vec(&[2, 2], vec![1.0]);
    }

    #[test]
    fn rng_deterministic_and_distinct() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        let mut c = Rng::new(43);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        let vc: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_eq!(va, vb);
        assert_ne!(va, vc);
    }

    #[test]
    fn rng_uniform_bounds_and_moments() {
        let mut r = Rng::new(1);
        let xs: Vec<f32> = (0..20_000).map(|_| r.uniform()).collect();
        assert!(xs.iter().all(|&x| (0.0..1.0).contains(&x)));
        let m = xs.iter().sum::<f32>() / xs.len() as f32;
        assert!((m - 0.5).abs() < 0.02, "mean {m}");
    }

    #[test]
    fn rng_normal_moments() {
        let mut r = Rng::new(2);
        let xs: Vec<f64> = (0..20_000).map(|_| r.normal() as f64).collect();
        assert!(mean(&xs).abs() < 0.05);
        assert!((std_dev(&xs) - 1.0).abs() < 0.05);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn below_is_unbiased_for_non_power_of_two() {
        // n = 6 does not divide 2^64, so the old `% n` mapping was biased;
        // with Lemire rejection every bucket should sit within 5% of the
        // expected count (60k draws, expected 10k per bucket, ~3σ ≈ 280)
        let mut r = Rng::new(7);
        let n = 6usize;
        let draws = 60_000;
        let mut counts = vec![0usize; n];
        for _ in 0..draws {
            let x = r.below(n);
            assert!(x < n);
            counts[x] += 1;
        }
        let expect = draws / n;
        for (i, &c) in counts.iter().enumerate() {
            let dev = (c as f64 - expect as f64).abs() / expect as f64;
            assert!(dev < 0.05, "bucket {i}: {c} vs {expect} (dev {dev:.3})");
        }
    }

    #[test]
    fn below_covers_full_range_and_is_deterministic() {
        let mut a = Rng::new(11);
        let mut b = Rng::new(11);
        let va: Vec<usize> = (0..500).map(|_| a.below(10)).collect();
        let vb: Vec<usize> = (0..500).map(|_| b.below(10)).collect();
        assert_eq!(va, vb);
        for want in 0..10 {
            assert!(va.iter().any(|&x| x == want), "value {want} never drawn");
        }
    }

    #[test]
    fn stats() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert!((std_dev(&[1.0, 2.0, 3.0]) - 1.0).abs() < 1e-12);
        assert_eq!(argmax(&[0.1, 0.9, 0.3]), 1);
    }
}
