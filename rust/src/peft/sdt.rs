//! SDT dimension selection (paper Alg. 1) and SDT-P (Alg. 2).
//!
//! Operates on parameter snapshots taken before/after a short warmup phase
//! run by the trainer. The selection criterion follows the paper: channels
//! (and, within trainable channels, state dims) are ranked by the change of
//! ‖Ābar^{(d)}‖ — we use |exp(A_log_after) − exp(A_log_before)| as the
//! discretization-free magnitude of the Ā change, summed per channel.
//!
//! Masks are emitted for the SSM tensors the paper's update scheme trains:
//!   S6:  A_log (Di,H)   — entry trainable iff channel ∧ state trainable
//!        xproj (Di,R+2H) — B/C columns gated per channel (rows); the Δ-low
//!                          columns are always frozen under SDT
//!   S4:  A_log, C (D,H) — same channel ∧ state gating
//! LoRA factors and other trainable leaves in the same variant (sdtlora)
//! pass through unmasked.

use std::collections::BTreeMap;

use crate::manifest::Variant;
use crate::tensor::{Rng, Tensor};

use super::Masks;

/// Selection criterion; `AbarChange` is the paper's, the others are
/// ablation baselines (DESIGN.md §ablations, `ablate_selection` bench).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Criterion {
    /// ‖ΔĀ‖ between warmup snapshots (paper Alg. 1).
    AbarChange,
    /// Accumulated |grad| magnitude (Song et al. 2024 style).
    GradMagnitude,
    /// Uniform random channels/states (control).
    Random,
}

/// SDT selection hyperparameters (paper Sec. 5.4 defaults).
#[derive(Debug, Clone)]
pub struct SdtConfig {
    /// Fraction of channels frozen (paper uses 0.99 in Sec. 6.2).
    pub channel_freeze: f32,
    /// Fraction of state dims frozen within trainable channels (α).
    pub state_freeze: f32,
    /// Number of warmup batches for the selection phase.
    pub warmup_batches: usize,
    /// Learning rate during the warmup phase.
    pub warmup_lr: f32,
    /// Ranking criterion (paper vs ablation baselines).
    pub criterion: Criterion,
    /// SDT-P: additionally prune (set to zero) the bottom `prune_frac` of
    /// channels by |Ābar| magnitude. 0.0 = plain SDT.
    pub prune_frac: f32,
    /// Seed for the Random criterion.
    pub seed: u64,
}

impl Default for SdtConfig {
    fn default() -> Self {
        SdtConfig {
            channel_freeze: 0.99,
            state_freeze: 0.90,
            warmup_batches: 16,
            warmup_lr: 1e-2,
            criterion: Criterion::AbarChange,
            prune_frac: 0.0,
            seed: 0,
        }
    }
}

/// Per-channel score: Σ_h |exp(after) − exp(before)| for one layer's A_log.
fn channel_scores(before: &Tensor, after: &Tensor) -> Vec<f64> {
    let (d, h) = (before.shape[0], before.shape[1]);
    let mut scores = vec![0.0f64; d];
    for di in 0..d {
        for hi in 0..h {
            let b = before.data[di * h + hi].exp() as f64;
            let a = after.data[di * h + hi].exp() as f64;
            scores[di] += (a - b).abs();
        }
    }
    scores
}

/// Indices of the top-k entries by score, ties broken by index (the same
/// order the original stable full sort produced). Uses an O(d + k log k)
/// partial selection instead of sorting all d scores — selection runs once
/// per layer over `d_inner` channels, so this keeps the SDT stage cheap on
/// wide models.
fn top_k(scores: &[f64], k: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..scores.len()).collect();
    let k = k.min(idx.len());
    if k == 0 {
        return Vec::new();
    }
    // strict total order (score desc, index asc): makes the unstable
    // partial selection reproduce the stable sort's output exactly
    let by = |a: &usize, b: &usize| scores[*b].total_cmp(&scores[*a]).then(a.cmp(b));
    if k < idx.len() {
        idx.select_nth_unstable_by(k - 1, by);
        idx.truncate(k);
    }
    idx.sort_unstable_by(by);
    idx
}

/// The selection result for one layer, exposed for tests/reporting.
#[derive(Debug, Clone)]
pub struct LayerSelection {
    /// Channels kept trainable in this layer.
    pub trainable_channels: Vec<usize>,
    /// per trainable channel: trainable state dims
    pub trainable_states: Vec<Vec<usize>>,
    /// SDT-P only: channels whose dims get pruned to zero
    pub pruned_channels: Vec<usize>,
}

/// Run Alg. 1 (and the Alg. 2 pruning step if `prune_frac > 0`) from the two
/// parameter snapshots. Returns gradient masks aligned with
/// `variant.train_params`, plus per-layer selections for reporting.
pub fn select_dimensions(
    variant: &Variant,
    before: &BTreeMap<String, Tensor>,
    after: &BTreeMap<String, Tensor>,
    cfg: &SdtConfig,
) -> (Masks, Vec<LayerSelection>) {
    let mut rng = Rng::new(cfg.seed ^ 0x5d7_ea51);
    let mut masks: Vec<Option<Vec<f32>>> = vec![None; variant.train_params.len()];
    let mut selections = Vec::new();

    for layer in 0..variant.arch.n_layer {
        let a_name = format!("layers.{layer}.A_log");
        let Some(a_idx) = variant.train_index(&a_name) else { continue };
        let b_t = &before[&a_name];
        let a_t = &after[&a_name];
        let (d, h) = (b_t.shape[0], b_t.shape[1]);

        // ---- channel selection ---------------------------------------------
        let ch_scores = match cfg.criterion {
            Criterion::AbarChange | Criterion::GradMagnitude => channel_scores(b_t, a_t),
            Criterion::Random => (0..d).map(|_| rng.uniform() as f64).collect(),
        };
        let n_train_ch = ((1.0 - cfg.channel_freeze) * d as f32).ceil().max(1.0) as usize;
        let train_ch = top_k(&ch_scores, n_train_ch);

        // ---- SDT-P pruning: bottom channels by |Ābar| magnitude -------------
        let pruned: Vec<usize> = if cfg.prune_frac > 0.0 {
            let mag: Vec<f64> = (0..d)
                .map(|di| {
                    (0..h).map(|hi| a_t.data[di * h + hi].exp() as f64).sum()
                })
                .collect();
            let n_prune = (cfg.prune_frac * d as f32).floor() as usize;
            let mut idx: Vec<usize> = (0..d).collect();
            idx.sort_by(|&x, &y| mag[x].total_cmp(&mag[y]));
            idx.truncate(n_prune);
            idx.into_iter().filter(|i| !train_ch.contains(i)).collect()
        } else {
            Vec::new()
        };

        // ---- state selection within trainable channels ----------------------
        let n_train_st = ((1.0 - cfg.state_freeze) * h as f32).ceil().max(1.0) as usize;
        let mut states_per_ch = Vec::with_capacity(train_ch.len());
        let mut a_mask = vec![0.0f32; d * h];
        for &di in &train_ch {
            let st_scores: Vec<f64> = match cfg.criterion {
                Criterion::AbarChange | Criterion::GradMagnitude => (0..h)
                    .map(|hi| {
                        let bb = b_t.data[di * h + hi].exp() as f64;
                        let aa = a_t.data[di * h + hi].exp() as f64;
                        (aa - bb).abs()
                    })
                    .collect(),
                Criterion::Random => (0..h).map(|_| rng.uniform() as f64).collect(),
            };
            let train_st = top_k(&st_scores, n_train_st);
            for &hi in &train_st {
                a_mask[di * h + hi] = 1.0;
            }
            states_per_ch.push(train_st);
        }
        masks[a_idx] = Some(a_mask);

        // ---- companion tensors gated by channel ------------------------------
        // S6: xproj rows (channels); only the B/C columns train.
        let x_name = format!("layers.{layer}.xproj");
        // train_index and param are both keyed on the variant's param list,
        // so a present index implies present metadata
        if let (Some(x_idx), Some(meta)) =
            (variant.train_index(&x_name), variant.param(&x_name))
        {
            let cols = meta.shape[1];
            let r = variant.arch.dt_rank;
            let mut m = vec![0.0f32; meta.numel];
            for &di in &train_ch {
                for c in r..cols {
                    m[di * cols + c] = 1.0;
                }
            }
            masks[x_idx] = Some(m);
        }
        // S4: C gated like A_log (channel ∧ state).
        let c_name = format!("layers.{layer}.C");
        if let (Some(c_idx), Some(meta)) =
            (variant.train_index(&c_name), variant.param(&c_name))
        {
            let mut m = vec![0.0f32; meta.numel];
            for (ci, &di) in train_ch.iter().enumerate() {
                for &hi in &states_per_ch[ci] {
                    m[di * h + hi] = 1.0;
                }
            }
            masks[c_idx] = Some(m);
        }

        selections.push(LayerSelection {
            trainable_channels: train_ch,
            trainable_states: states_per_ch,
            pruned_channels: pruned,
        });
    }

    (Masks { masks }, selections)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::manifest::{Arch, ParamMeta, PeftMeta};

    fn variant(d: usize, h: usize, r: usize) -> Variant {
        Variant {
            name: "t".into(),
            arch: Arch {
                kind: "mamba1".into(), vocab: 8, d_model: 4, n_layer: 1,
                d_inner: d, d_state: h, d_conv: 4, dt_rank: r, n_head: 1, h_add: 1,
            },
            peft: PeftMeta {
                method: crate::suite::PeftMethod::Sdt,
                rank: 0, alpha: 0, targets: vec![], n_tokens: 0,
            },
            batch_b: 1, batch_l: 4, reg: false,
            step_file: None, fwd_file: None, decode_file: None,
            prefill_files: vec![],
            decode_adapters_file: None, adapter_operands: None,
            params_bin: String::new(),
            train_params: vec![
                ParamMeta { name: "layers.0.A_log".into(), shape: vec![d, h], offset: 0, numel: d * h },
                ParamMeta { name: "layers.0.xproj".into(), shape: vec![d, r + 2 * h],
                            offset: 0, numel: d * (r + 2 * h) },
            ],
            frozen_params: vec![],
        }
    }

    fn snapshots(d: usize, h: usize, hot_ch: usize, hot_st: usize)
        -> (BTreeMap<String, Tensor>, BTreeMap<String, Tensor>) {
        let before = Tensor::zeros(&[d, h]);
        let mut after = Tensor::zeros(&[d, h]);
        // channel hot_ch moved a lot, mostly at state hot_st
        after.data[hot_ch * h + hot_st] = 1.0;
        after.data[hot_ch * h + (hot_st + 1) % h] = 0.2;
        let mut b = BTreeMap::new();
        let mut a = BTreeMap::new();
        b.insert("layers.0.A_log".into(), before);
        a.insert("layers.0.A_log".into(), after);
        (b, a)
    }

    #[test]
    fn top_k_matches_stable_sort_reference() {
        // the partial selection must reproduce the old stable full sort
        // exactly, including tie order (ties keep ascending index)
        let scores = vec![0.5, 0.5, 1.0, 0.0, 0.5, 1.0, 0.25];
        let mut reference: Vec<usize> = (0..scores.len()).collect();
        reference.sort_by(|&a, &b| scores[b].partial_cmp(&scores[a]).unwrap());
        for k in 0..=scores.len() {
            assert_eq!(top_k(&scores, k), reference[..k].to_vec(), "k={k}");
        }
    }

    #[test]
    fn picks_the_changed_channel_and_state() {
        let v = variant(8, 4, 2);
        let (b, a) = snapshots(8, 4, 5, 2);
        let cfg = SdtConfig {
            channel_freeze: 0.875, // keep 1 of 8
            state_freeze: 0.75,    // keep 1 of 4
            ..Default::default()
        };
        let (masks, sel) = select_dimensions(&v, &b, &a, &cfg);
        assert_eq!(sel[0].trainable_channels, vec![5]);
        assert_eq!(sel[0].trainable_states[0], vec![2]);
        // A mask: exactly one entry on
        let am = masks.masks[0].as_ref().unwrap();
        assert_eq!(am.iter().filter(|&&x| x == 1.0).count(), 1);
        assert_eq!(am[5 * 4 + 2], 1.0);
        // xproj mask: row 5, columns r..r+2h on
        let xm = masks.masks[1].as_ref().unwrap();
        let cols = 2 + 8;
        assert_eq!(xm.iter().filter(|&&x| x == 1.0).count(), 8);
        assert_eq!(xm[5 * cols + 2], 1.0); // first B column
        assert_eq!(xm[5 * cols], 0.0); // Δ-low column frozen
    }

    #[test]
    fn respects_freeze_ratios() {
        let v = variant(16, 8, 2);
        let (b, mut a) = snapshots(16, 8, 3, 1);
        // make every channel move a little so ordering is total
        for (i, x) in a.get_mut("layers.0.A_log").unwrap().data.iter_mut().enumerate() {
            *x += 1e-4 * (i as f32);
        }
        let cfg = SdtConfig { channel_freeze: 0.75, state_freeze: 0.5, ..Default::default() };
        let (_, sel) = select_dimensions(&v, &b, &a, &cfg);
        assert_eq!(sel[0].trainable_channels.len(), 4); // 25% of 16
        assert!(sel[0].trainable_states.iter().all(|s| s.len() == 4)); // 50% of 8
    }

    #[test]
    fn random_criterion_is_deterministic_per_seed() {
        let v = variant(8, 4, 2);
        let (b, a) = snapshots(8, 4, 0, 0);
        let cfg = SdtConfig { criterion: Criterion::Random, seed: 9, ..Default::default() };
        let (_, s1) = select_dimensions(&v, &b, &a, &cfg);
        let (_, s2) = select_dimensions(&v, &b, &a, &cfg);
        assert_eq!(s1[0].trainable_channels, s2[0].trainable_channels);
    }

    #[test]
    fn prune_marks_low_magnitude_channels() {
        let v = variant(8, 4, 2);
        let (b, mut a) = snapshots(8, 4, 5, 2);
        // give channels distinct magnitudes
        for di in 0..8 {
            for hi in 0..4 {
                a.get_mut("layers.0.A_log").unwrap().data[di * 4 + hi] += di as f32 * 0.1 - 2.0;
            }
        }
        let cfg = SdtConfig { prune_frac: 0.25, channel_freeze: 0.875, ..Default::default() };
        let (_, sel) = select_dimensions(&v, &b, &a, &cfg);
        // bottom 25% of 8 = 2 channels, minus any overlap with the trainable set
        let n = sel[0].pruned_channels.len();
        assert!((1..=2).contains(&n), "pruned {n}");
        // pruned channels must be disjoint from trainable ones
        for c in &sel[0].pruned_channels {
            assert!(!sel[0].trainable_channels.contains(c));
        }
    }
}
