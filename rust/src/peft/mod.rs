//! PEFT engine: trainable masks, **SDT dimension selection** (paper Alg. 1/2),
//! LoRA merging, and parameter-budget accounting.
//!
//! The AOT `step` artifacts compute gradients over whole trainable tensors;
//! sparse methods (SDT, SDT-P) are realized here by masking gradients before
//! the optimizer — mathematically identical to freezing the masked entries,
//! and it lets ONE artifact serve every channel/state selection.
//!
//! SDT pipeline (paper Sec. 5.4, Alg. 1):
//!   1. warmup: fully update the SSM tensors on a small data subset;
//!   2. rank channels d by the change of ‖Ābar^{(d)}‖ between the pre- and
//!      post-warmup snapshots; freeze the bottom `channel_freeze` fraction;
//!   3. within trainable channels, rank state dims the same way and freeze
//!      the bottom `state_freeze` fraction;
//!   4. revert parameters to the pre-warmup snapshot and fine-tune with the
//!      masks applied (plus optional pruning = SDT-P: masked dims set to 0).

use std::collections::BTreeMap;

use crate::manifest::Variant;
use crate::tensor::{Rng, Tensor};

pub mod sdt;

pub use sdt::{select_dimensions, Criterion, SdtConfig};

/// Per-trainable-parameter gradient masks, aligned with
/// `variant.train_params` order. `None` = fully trainable.
#[derive(Debug, Clone)]
pub struct Masks {
    /// One optional 0/1 mask per trainable tensor.
    pub masks: Vec<Option<Vec<f32>>>,
}

impl Masks {
    /// No masking: all `n` tensors fully trainable.
    pub fn none(n: usize) -> Self {
        Masks { masks: vec![None; n] }
    }

    /// Zero out masked gradient entries (in place). This dense multiply is
    /// the **legacy reference** pass: the training hot path now compiles
    /// masks into a [`crate::optim::MaskPlan`] (sparse index sets) and
    /// fuses masking with clip + update — see `rust/docs/performance.md`.
    /// Kept for the fused-vs-reference equivalence tests and cold paths.
    pub fn apply(&self, grads: &mut [Tensor]) {
        for (g, m) in grads.iter_mut().zip(self.masks.iter()) {
            if let Some(m) = m {
                debug_assert_eq!(g.data.len(), m.len());
                for (x, &k) in g.data.iter_mut().zip(m.iter()) {
                    *x *= k;
                }
            }
        }
    }

    /// (active, total) entry counts across all masked tensors — `None`
    /// masks count as fully active. The active fraction decides whether
    /// the fused pass compiles a leaf to a sparse index set.
    pub fn sparsity(&self, variant: &Variant) -> (usize, usize) {
        let total = variant.train_params.iter().map(|p| p.numel).sum();
        let active = variant
            .train_params
            .iter()
            .zip(self.masks.iter())
            .map(|(p, m)| match m {
                None => p.numel,
                Some(m) => m.iter().filter(|&&x| x != 0.0).count(),
            })
            .sum();
        (active, total)
    }

    /// Effective trainable parameter count under the masks.
    pub fn effective_params(&self, variant: &Variant) -> usize {
        variant
            .train_params
            .iter()
            .zip(self.masks.iter())
            .map(|(p, m)| match m {
                None => p.numel,
                Some(m) => m.iter().filter(|&&x| x != 0.0).count(),
            })
            .sum()
    }

    /// SDT-P pruning: zero the *parameter values* wherever the mask freezes
    /// an A entry AND the paper's Alg. 2 marked it as a zero dimension.
    pub fn prune(&self, params: &mut [Tensor], prune_masks: &[Option<Vec<f32>>]) {
        for (p, m) in params.iter_mut().zip(prune_masks.iter()) {
            if let Some(m) = m {
                for (x, &k) in p.data.iter_mut().zip(m.iter()) {
                    if k == 0.0 {
                        *x = 0.0;
                    }
                }
            }
        }
    }
}

/// Parameter-budget report (the paper's "# Params (%)" column).
#[derive(Debug, Clone)]
pub struct Budget {
    /// Effective trainable parameter count.
    pub trainable: usize,
    /// Total model parameters.
    pub total: usize,
}

impl Budget {
    /// Budget of a variant, with masks applied when given.
    pub fn of(variant: &Variant, masks: Option<&Masks>) -> Self {
        let trainable = match masks {
            Some(m) => m.effective_params(variant),
            None => variant.n_train(),
        };
        Budget { trainable, total: variant.n_total() }
    }
    /// Trainable fraction in [0, 1].
    pub fn fraction(&self) -> f64 {
        self.trainable as f64 / self.total.max(1) as f64
    }
    /// Trainable fraction as a percentage.
    pub fn percent(&self) -> f64 {
        100.0 * self.fraction()
    }
}

/// Small row-major matmul: (m,k)·(k,n) -> (m,n). Used by LoRA merging only
/// (not on the training hot path, which stays inside XLA).
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.shape.len(), 2);
    assert_eq!(b.shape.len(), 2);
    let (m, k) = (a.shape[0], a.shape[1]);
    let (k2, n) = (b.shape[0], b.shape[1]);
    assert_eq!(k, k2, "matmul inner dim");
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        for p in 0..k {
            let av = a.data[i * k + p];
            if av == 0.0 {
                continue;
            }
            let brow = &b.data[p * n..(p + 1) * n];
            let orow = &mut out[i * n..(i + 1) * n];
            for j in 0..n {
                orow[j] += av * brow[j];
            }
        }
    }
    Tensor::from_vec(&[m, n], out)
}

/// Fold trained LoRA/DoRA factors into their base weights so the (adapter-
/// free) decode artifact can serve the fine-tuned model. Mirrors
/// python/compile/peft.py::merge_lora: scale = alpha / rank, both taken
/// from the variant's [`PeftMeta`] (no more guessing alpha from rank at
/// call sites). A no-op when the map holds no `.lora_a` keys.
pub fn merge_lora(params: &mut BTreeMap<String, Tensor>, peft: &crate::manifest::PeftMeta) {
    let scale =
        if peft.rank == 0 { 1.0 } else { peft.alpha as f32 / peft.rank as f32 };
    let names: Vec<String> = params
        .keys()
        .filter(|k| k.ends_with(".lora_a"))
        .map(|k| k.trim_end_matches(".lora_a").to_string())
        .collect();
    for base in names {
        let a = params[&format!("{base}.lora_a")].clone();
        let b = params[&format!("{base}.lora_b")].clone();
        let delta = matmul(&a, &b);
        let dora_m = params.get(&format!("{base}.dora_m")).cloned();
        // `base` was derived from a present `.lora_a` key; a missing base
        // weight means a malformed checkpoint, which we skip rather than kill
        let Some(w) = params.get_mut(&base) else { continue };
        for (x, d) in w.data.iter_mut().zip(delta.data.iter()) {
            *x += scale * d;
        }
        if let Some(m) = dora_m {
            // column-normalize then scale by magnitude vector (DoRA)
            let (rows, cols) = (w.shape[0], w.shape[1]);
            for j in 0..cols {
                let mut norm = 0.0f64;
                for i in 0..rows {
                    let v = w.data[i * cols + j] as f64;
                    norm += v * v;
                }
                let norm = (norm.sqrt() as f32) + 1e-6;
                let s = m.data[j] / norm;
                for i in 0..rows {
                    w.data[i * cols + j] *= s;
                }
            }
        }
    }
    params.retain(|k, _| {
        !k.ends_with(".lora_a") && !k.ends_with(".lora_b") && !k.ends_with(".dora_m")
    });
}

/// Random masks with a given keep-fraction (ablation baseline for SDT's
/// selection criterion — DESIGN.md §ablations).
pub fn random_masks(variant: &Variant, keep: f32, rng: &mut Rng) -> Masks {
    let masks = variant
        .train_params
        .iter()
        .map(|p| {
            Some(
                (0..p.numel)
                    .map(|_| if rng.uniform() < keep { 1.0 } else { 0.0 })
                    .collect(),
            )
        })
        .collect();
    Masks { masks }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::manifest::{Arch, ParamMeta, PeftMeta};
    use crate::suite::PeftMethod;

    fn dummy_variant() -> Variant {
        Variant {
            name: "t".into(),
            arch: Arch {
                kind: "mamba1".into(), vocab: 8, d_model: 4, n_layer: 1,
                d_inner: 4, d_state: 2, d_conv: 4, dt_rank: 1, n_head: 1, h_add: 1,
            },
            peft: PeftMeta {
                method: PeftMethod::Sdt, rank: 0, alpha: 0, targets: vec![], n_tokens: 0,
            },
            batch_b: 1, batch_l: 4, reg: false,
            step_file: None, fwd_file: None, decode_file: None,
            prefill_files: vec![],
            decode_adapters_file: None, adapter_operands: None,
            params_bin: String::new(),
            train_params: vec![
                ParamMeta { name: "layers.0.A_log".into(), shape: vec![4, 2], offset: 0, numel: 8 },
            ],
            frozen_params: vec![
                ParamMeta { name: "embed".into(), shape: vec![8, 4], offset: 32, numel: 32 },
            ],
        }
    }

    #[test]
    fn mask_apply_zeros() {
        let masks = Masks { masks: vec![Some(vec![1.0, 0.0, 1.0, 0.0])] };
        let mut g = vec![Tensor::from_vec(&[4], vec![1.0, 2.0, 3.0, 4.0])];
        masks.apply(&mut g);
        assert_eq!(g[0].data, vec![1.0, 0.0, 3.0, 0.0]);
    }

    #[test]
    fn budget_counts_masked() {
        let v = dummy_variant();
        let m = Masks { masks: vec![Some(vec![1.0, 1.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0])] };
        let b = Budget::of(&v, Some(&m));
        assert_eq!(b.trainable, 2);
        assert_eq!(b.total, 40);
        let b2 = Budget::of(&v, None);
        assert_eq!(b2.trainable, 8);
        assert_eq!(m.sparsity(&v), (2, 8));
        assert_eq!(Masks::none(1).sparsity(&v), (8, 8));
    }

    #[test]
    fn matmul_known() {
        let a = Tensor::from_vec(&[2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let b = Tensor::from_vec(&[3, 2], vec![7.0, 8.0, 9.0, 10.0, 11.0, 12.0]);
        let c = matmul(&a, &b);
        assert_eq!(c.shape, vec![2, 2]);
        assert_eq!(c.data, vec![58.0, 64.0, 139.0, 154.0]);
    }

    fn lora_meta(rank: usize, alpha: usize) -> PeftMeta {
        PeftMeta {
            method: PeftMethod::Lora(crate::suite::Target::LinProj),
            rank,
            alpha,
            targets: vec![],
            n_tokens: 0,
        }
    }

    #[test]
    fn merge_lora_adds_delta() {
        let mut p = BTreeMap::new();
        p.insert("W".to_string(), Tensor::from_vec(&[2, 2], vec![1.0, 0.0, 0.0, 1.0]));
        p.insert("W.lora_a".to_string(), Tensor::from_vec(&[2, 1], vec![1.0, 2.0]));
        p.insert("W.lora_b".to_string(), Tensor::from_vec(&[1, 2], vec![3.0, 4.0]));
        merge_lora(&mut p, &lora_meta(1, 1));
        assert!(!p.contains_key("W.lora_a"));
        assert_eq!(p["W"].data, vec![4.0, 4.0, 6.0, 9.0]);
    }

    #[test]
    fn merge_lora_scales_by_alpha_over_rank() {
        let mut p = BTreeMap::new();
        p.insert("W".to_string(), Tensor::from_vec(&[2, 2], vec![0.0; 4]));
        p.insert("W.lora_a".to_string(), Tensor::from_vec(&[2, 1], vec![1.0, 2.0]));
        p.insert("W.lora_b".to_string(), Tensor::from_vec(&[1, 2], vec![3.0, 4.0]));
        merge_lora(&mut p, &lora_meta(2, 4)); // scale = 2.0
        assert_eq!(p["W"].data, vec![6.0, 8.0, 12.0, 16.0]);
    }

    #[test]
    fn random_mask_keep_fraction() {
        let v = dummy_variant();
        let mut rng = Rng::new(0);
        let m = random_masks(&v, 0.5, &mut rng);
        let kept = m.effective_params(&v);
        assert!(kept > 0 && kept < 8);
    }
}
