//! Evaluation: classification scoring via the `fwd` artifact and
//! autoregressive generation (greedy + beam) via the stepwise `decode`
//! artifact, with the Mamba recurrent state held in Rust buffers.

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use crate::data::{make_batch, Dataset, Example, BOS, PAD};
use crate::data::minidb::exec_match;
use crate::data::tasks::spider_table;
use crate::data::words_to_ids;
use crate::manifest::{Manifest, Variant};
use crate::metrics;
use crate::runtime::{Engine, Executable, Input};
use crate::suite::Metric;
use crate::tensor::{argmax, IntTensor, Tensor};
use crate::train::Trainer;

/// Classification accuracy/metric over a split using the fwd artifact:
/// logits at the label position, restricted to the task's label bytes.
pub fn eval_classification(trainer: &Trainer, split: &[Example], metric: Metric) -> Result<f64> {
    let b = trainer.variant.batch_b;
    let l = trainer.variant.batch_l;
    let mut preds = Vec::new();
    let mut golds = Vec::new();
    let mut i = 0;
    while i < split.len() {
        let end = (i + b).min(split.len());
        let mut refs: Vec<&Example> = split[i..end].iter().collect();
        while refs.len() < b {
            refs.push(&split[0]); // pad batch; extra rows ignored below
        }
        let batch = make_batch(&refs, b, l);
        let logits = trainer.logits(&batch)?; // (B, L, V)
        let v = logits.shape[2];
        for (r, ex) in split[i..end].iter().enumerate() {
            let pos = batch.label_pos[r];
            let row = &logits.data[(r * l + pos) * v..(r * l + pos + 1) * v];
            let scores: Vec<f32> =
                ex.label_bytes.iter().map(|&bb| row[bb as usize]).collect();
            preds.push(argmax(&scores));
            golds.push(ex.label.unwrap());
        }
        i = end;
    }
    Ok(match metric {
        Metric::Matthews => metrics::matthews_corr(&preds, &golds),
        _ => metrics::accuracy(&preds, &golds),
    })
}

/// Regression MSE over generated (x, y) pairs (Fig. 2 synthetic setting).
pub fn eval_regression(trainer: &Trainer, xs: &[Tensor], ys: &[Tensor]) -> Result<f64> {
    let mut total = 0.0;
    let mut n = 0usize;
    for (x, y) in xs.iter().zip(ys) {
        let pred = trainer.forward_reg(x)?;
        total += metrics::mse(&pred.data, &y.data) * pred.numel() as f64;
        n += pred.numel();
    }
    Ok(total / n.max(1) as f64)
}

/// Batched greedy generator over the stepwise decode artifact.
pub struct Generator {
    decode: Executable,
    /// parameter tensors in the decode variant's argument order
    params: Vec<Tensor>,
    pub arch_b: usize,
    n_layer: usize,
    d_conv: usize,
    d_inner: usize,
    d_state: usize,
}

impl Generator {
    /// `params_map` must contain every base parameter of the decode variant
    /// (merge LoRA first: `peft::merge_lora`). Initial-state tuning passes
    /// its trained h0 via the ssm-state input automatically when the map
    /// contains "layers.{i}.h0".
    pub fn new(engine: &Engine, manifest: &Manifest, decode_variant: &str,
               params_map: &BTreeMap<String, Tensor>) -> Result<Self> {
        let v: &Variant = manifest.variant(decode_variant)?;
        let file = v.decode_file.clone()
            .with_context(|| format!("{decode_variant} has no decode artifact"))?;
        let decode = engine.load(manifest.hlo_path(&file))?;
        let mut params = Vec::new();
        for meta in v.train_params.iter().chain(v.frozen_params.iter()) {
            let t = params_map.get(&meta.name).with_context(|| {
                format!("merged params missing {} for decode", meta.name)
            })?;
            params.push(t.clone());
        }
        Ok(Generator {
            decode,
            params,
            arch_b: v.batch_b,
            n_layer: v.arch.n_layer,
            d_conv: v.arch.d_conv,
            d_inner: v.arch.d_inner,
            d_state: v.arch.d_state,
        })
    }

    fn init_states(&self, h0: Option<&BTreeMap<String, Tensor>>) -> (Tensor, Tensor) {
        let conv = Tensor::zeros(&[self.n_layer, self.arch_b, self.d_conv - 1, self.d_inner]);
        let mut ssm = Tensor::zeros(&[self.n_layer, self.arch_b, self.d_inner, self.d_state]);
        if let Some(map) = h0 {
            for layer in 0..self.n_layer {
                if let Some(h) = map.get(&format!("layers.{layer}.h0")) {
                    let per = self.d_inner * self.d_state;
                    for b in 0..self.arch_b {
                        let dst = (layer * self.arch_b + b) * per;
                        ssm.data[dst..dst + per].copy_from_slice(&h.data);
                    }
                }
            }
        }
        (conv, ssm)
    }

    fn step(&self, tokens: &IntTensor, conv: &Tensor, ssm: &Tensor)
        -> Result<(Tensor, Tensor, Tensor)> {
        let mut inputs: Vec<Input> = self.params.iter().map(Input::F).collect();
        inputs.push(Input::I(tokens));
        inputs.push(Input::F(conv));
        inputs.push(Input::F(ssm));
        let mut outs = self.decode.run(&inputs)?;
        let ssm_out = outs.pop().unwrap();
        let conv_out = outs.pop().unwrap();
        let logits = outs.pop().unwrap();
        Ok((logits, conv_out, ssm_out))
    }

    /// Greedy generation for up to `arch_b` prompts at once. Rows still in
    /// prefill keep consuming their prompt; finished rows emit until
    /// `stop_byte` or `max_new`.
    pub fn greedy(&self, prompts: &[Vec<u8>], max_new: usize, stop_byte: u8,
                  h0: Option<&BTreeMap<String, Tensor>>) -> Result<Vec<Vec<u8>>> {
        assert!(prompts.len() <= self.arch_b);
        let b = self.arch_b;
        let (mut conv, mut ssm) = self.init_states(h0);
        let max_prompt = prompts.iter().map(Vec::len).max().unwrap_or(0);
        let mut outs: Vec<Vec<u8>> = vec![Vec::new(); prompts.len()];
        let mut done = vec![false; prompts.len()];
        let mut cur = IntTensor::from_vec(&[b], vec![BOS; b]);
        for t in 0..max_prompt + max_new {
            let (logits, c2, s2) = self.step(&cur, &conv, &ssm)?;
            conv = c2;
            ssm = s2;
            let v = logits.shape[1];
            for r in 0..prompts.len() {
                let next: i32 = if t < prompts[r].len() {
                    prompts[r][t] as i32 // still prefilling
                } else if done[r] || outs[r].len() >= max_new {
                    PAD
                } else {
                    let row = &logits.data[r * v..(r + 1) * v];
                    // generate over byte vocabulary only (no BOS/PAD)
                    let tok = argmax(&row[..256]) as u8;
                    if tok == stop_byte {
                        done[r] = true;
                        PAD
                    } else {
                        outs[r].push(tok);
                        tok as i32
                    }
                };
                cur.data[r] = next;
            }
            for r in prompts.len()..b {
                cur.data[r] = PAD;
            }
            if (0..prompts.len()).all(|r| t >= prompts[r].len()
                && (done[r] || outs[r].len() >= max_new)) {
                break;
            }
        }
        Ok(outs)
    }

    /// Beam search for ONE prompt, packing beams into the batch dimension
    /// (beam width ≤ arch_b). Length-normalized log-prob scoring. `h0`
    /// seeds the SSM state as in [`Generator::greedy`] (initial-state
    /// tuning).
    pub fn beam(&self, prompt: &[u8], width: usize, max_new: usize, stop_byte: u8,
                h0: Option<&BTreeMap<String, Tensor>>) -> Result<Vec<u8>> {
        let width = width.min(self.arch_b);
        let b = self.arch_b;
        let (mut conv, mut ssm) = self.init_states(h0);
        // prefill all rows with the same prompt
        let mut cur = IntTensor::from_vec(&[b], vec![BOS; b]);
        let mut logits = Tensor::zeros(&[b, 256]);
        for t in 0..=prompt.len() {
            let (lg, c2, s2) = self.step(&cur, &conv, &ssm)?;
            conv = c2;
            ssm = s2;
            logits = lg;
            if t < prompt.len() {
                for r in 0..b {
                    cur.data[r] = prompt[t] as i32;
                }
            }
        }
        #[derive(Clone)]
        struct Beam {
            toks: Vec<u8>,
            score: f64,
            done: bool,
        }
        let v = logits.shape[1];
        let lp0 = log_softmax(&logits.data[..v]);
        let mut order: Vec<usize> = (0..256).collect();
        order.sort_by(|&a, &bb| lp0[bb].partial_cmp(&lp0[a]).unwrap());
        let mut beams: Vec<Beam> = order[..width]
            .iter()
            .map(|&t| Beam {
                toks: vec![t as u8],
                score: lp0[t],
                done: t as u8 == stop_byte,
            })
            .collect();
        for r in 0..b {
            cur.data[r] = beams[r.min(width - 1)].toks.last().map(|&t| t as i32).unwrap_or(PAD);
        }
        // replicate states across beams (identical after same prefill)
        for _ in 1..max_new {
            if beams.iter().all(|bm| bm.done) {
                break;
            }
            let (lg, c2, s2) = self.step(&cur, &conv, &ssm)?;
            let mut cand: Vec<(usize, u8, f64)> = Vec::new(); // (beam, tok, score)
            for (bi, bm) in beams.iter().enumerate() {
                if bm.done {
                    cand.push((bi, stop_byte, bm.score));
                    continue;
                }
                let lp = log_softmax(&lg.data[bi * v..bi * v + 256]);
                let mut idx: Vec<usize> = (0..256).collect();
                idx.sort_by(|&a, &bb| lp[bb].partial_cmp(&lp[a]).unwrap());
                for &t in &idx[..width] {
                    cand.push((bi, t as u8, bm.score + lp[t]));
                }
            }
            cand.sort_by(|a, bc| {
                let la = (beams[a.0].toks.len() + 1) as f64;
                let lb = (beams[bc.0].toks.len() + 1) as f64;
                (bc.2 / lb).partial_cmp(&(a.2 / la)).unwrap()
            });
            let mut new_beams = Vec::with_capacity(width);
            let mut new_conv = c2.clone();
            let mut new_ssm = s2.clone();
            let conv_per = (self.d_conv - 1) * self.d_inner;
            let ssm_per = self.d_inner * self.d_state;
            for (slot, &(bi, tok, score)) in cand.iter().take(width).enumerate() {
                let src = beams[bi].clone();
                let done = src.done || tok == stop_byte;
                let mut toks = src.toks;
                if !src.done && tok != stop_byte {
                    toks.push(tok);
                }
                new_beams.push(Beam { toks, score, done });
                // copy parent state into this slot
                for layer in 0..self.n_layer {
                    let cfrom = (layer * b + bi) * conv_per;
                    let cto = (layer * b + slot) * conv_per;
                    let tmp: Vec<f32> = c2.data[cfrom..cfrom + conv_per].to_vec();
                    new_conv.data[cto..cto + conv_per].copy_from_slice(&tmp);
                    let sfrom = (layer * b + bi) * ssm_per;
                    let sto = (layer * b + slot) * ssm_per;
                    let tmp: Vec<f32> = s2.data[sfrom..sfrom + ssm_per].to_vec();
                    new_ssm.data[sto..sto + ssm_per].copy_from_slice(&tmp);
                }
            }
            beams = new_beams;
            conv = new_conv;
            ssm = new_ssm;
            for r in 0..b {
                let bm = &beams[r.min(width - 1)];
                cur.data[r] = if bm.done { PAD } else { *bm.toks.last().unwrap() as i32 };
            }
        }
        Ok(beams.into_iter().next().map(|bm| bm.toks).unwrap_or_default())
    }
}

fn log_softmax(row: &[f32]) -> Vec<f64> {
    let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
    let z: f64 = row.iter().map(|&x| ((x as f64) - m).exp()).sum();
    row.iter().map(|&x| (x as f64) - m - z.ln()).collect()
}

/// Generation metrics over a test split: ROUGE / BLEU+METEOR / exec-match.
pub struct GenScores {
    pub rouge1: f64,
    pub rouge2: f64,
    pub rougel: f64,
    pub bleu: f64,
    pub meteor: f64,
    pub exec_acc: f64,
}

pub fn eval_generation(gen: &Generator, ds: &Dataset, split: &[Example],
                       max_new: usize, seed: u64,
                       h0: Option<&BTreeMap<String, Tensor>>) -> Result<GenScores> {
    let mut outs: Vec<Vec<u8>> = Vec::with_capacity(split.len());
    let mut i = 0;
    while i < split.len() {
        let end = (i + gen.arch_b).min(split.len());
        let prompts: Vec<Vec<u8>> = split[i..end].iter().map(|e| e.prompt.clone()).collect();
        outs.extend(gen.greedy(&prompts, max_new, b'\n', h0)?);
        i = end;
    }
    Ok(score_generation(ds, split, &outs, seed))
}

/// Beam-search generation metrics: one beam search per example (beams pack
/// the batch dimension, so examples run serially). Used when
/// `ExperimentConfig::beam > 1`.
pub fn eval_generation_beam(gen: &Generator, ds: &Dataset, split: &[Example],
                            width: usize, max_new: usize, seed: u64,
                            h0: Option<&BTreeMap<String, Tensor>>) -> Result<GenScores> {
    let mut outs: Vec<Vec<u8>> = Vec::with_capacity(split.len());
    for ex in split {
        outs.push(gen.beam(&ex.prompt, width, max_new, b'\n', h0)?);
    }
    Ok(score_generation(ds, split, &outs, seed))
}

/// Score generated outputs against a split's targets (shared by the
/// greedy and beam paths).
fn score_generation(ds: &Dataset, split: &[Example], outs: &[Vec<u8>], seed: u64)
    -> GenScores {
    let mut preds_ids = Vec::new();
    let mut golds_ids = Vec::new();
    let mut r1 = Vec::new();
    let mut r2 = Vec::new();
    let mut rl = Vec::new();
    let mut met = Vec::new();
    let mut exec_hits = 0usize;
    let table = spider_table(seed);
    for (ex, out) in split.iter().zip(outs) {
        let p_ids = words_to_ids(out);
        let g_ids = words_to_ids(&ex.target);
        r1.push(metrics::rouge_n(&p_ids, &g_ids, 1));
        r2.push(metrics::rouge_n(&p_ids, &g_ids, 2));
        rl.push(metrics::rouge_l(&p_ids, &g_ids));
        met.push(metrics::meteor(&p_ids, &g_ids));
        if ds.metric == Metric::Exec {
            let pred_s = String::from_utf8_lossy(out).to_string();
            let gold_s = String::from_utf8_lossy(&ex.target).to_string();
            if exec_match(&table, &pred_s, &gold_s) {
                exec_hits += 1;
            }
        }
        preds_ids.push(p_ids);
        golds_ids.push(g_ids);
    }
    let n = preds_ids.len().max(1) as f64;
    GenScores {
        rouge1: crate::tensor::mean(&r1),
        rouge2: crate::tensor::mean(&r2),
        rougel: crate::tensor::mean(&rl),
        bleu: metrics::bleu(&preds_ids, &golds_ids),
        meteor: crate::tensor::mean(&met),
        exec_acc: exec_hits as f64 / n,
    }
}

/// Convenience: eval loss over a split (early-stopping signal shared by all
/// task types).
pub fn eval_split_loss(trainer: &Trainer, split: &[Example], rng_seed: u64) -> Result<f64> {
    let b = trainer.variant.batch_b;
    let l = trainer.variant.batch_l;
    let mut rng = crate::tensor::Rng::new(rng_seed);
    let mut losses = Vec::new();
    let it = crate::data::BatchIter::new(split, &mut rng, b, l);
    for (batch, _) in it.take(8) {
        losses.push(trainer.eval_loss(&batch)? as f64);
    }
    Ok(crate::tensor::mean(&losses))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn log_softmax_normalizes() {
        let lp = log_softmax(&[1.0, 2.0, 3.0]);
        let total: f64 = lp.iter().map(|x| x.exp()).sum();
        assert!((total - 1.0).abs() < 1e-9);
        assert!(lp[2] > lp[0]);
    }
}
