//! Evaluation + generation core: classification scoring via the `fwd`
//! artifact and autoregressive generation via the stepwise `decode`
//! artifact, with the Mamba recurrent state held in Rust buffers.
//!
//! The generation core is split in two layers so the offline suite and the
//! online server ([`crate::serve`]) share one implementation:
//!
//! - [`StepDecode`] — the minimal stepwise-decode interface: batch width,
//!   state geometry ([`StateDims`]), and one `(tokens, state) → logits`
//!   step that advances a [`DecodeState`] in place. Implemented by
//!   [`DecodeCore`] over the real XLA executable, and by mock models in
//!   scheduler unit tests.
//! - [`greedy_decode`] / [`beam_search`] — decoding strategies written
//!   against `dyn StepDecode`. [`Generator`] is the thin offline wrapper
//!   (build a core from merged params, then greedy/beam over a split);
//!   [`crate::serve::Scheduler`] drives the same trait online, packing
//!   many independent requests into the batch dimension.
//!
//! Hot-path residency (§Perf L4, rust/docs/performance.md): a
//! [`DecodeState`] keeps the recurrent `(conv, ssm)` state as the
//! *literals* the previous step produced, feeding them back as the next
//! step's inputs with no Tensor round-trip; [`DecodeCore`] serializes its
//! parameter literals once at construction instead of once per token. The
//! host mirror is materialized lazily, only when a caller actually touches
//! rows (scheduler admission, beam re-parenting).
//!
//! Chunked prefill (§Perf L5): prompt ingestion is sequence-level, not
//! token-level. The [`ChunkPrefill`] trait exposes the `prefill` artifacts
//! (one `(B, C)`-token scan per dispatch); [`plan_chunks`] covers a prompt
//! with the largest-fitting chunks, and [`chunk_prefill_cover`] executes
//! the plan while the state stays literal-resident across chunk→chunk and
//! chunk→decode transitions. [`greedy_decode`] and [`beam_search`] route
//! prompts through it automatically when the model advertises support;
//! beam search prefills ONE row and broadcasts its state
//! ([`DecodeState::broadcast_row`]) instead of scanning the same prompt
//! across every row.
//!
//! Unmerged multi-adapter decode: a single continuous batch can mix
//! adapters. An adapter is held as its raw [`AdapterDelta`] (LoRA factors,
//! SDT sparse offsets, h0 seeds) instead of a merged whole-model copy;
//! [`AdapterStepDecode::step_rows`] advances the batch with a per-row
//! adapter assignment, either through the compiled `decode_adapters`
//! artifact (one base dispatch + per-row delta operands) or through a
//! host-side fallback that groups rows by adapter and replays the exact
//! merged path — byte-identical to per-adapter merged cores, which is what
//! lets the serving scheduler collapse per-adapter lanes into one shared
//! batch. [`PinnedAdapter`] adapts the shared core back to a plain
//! single-adapter [`StepDecode`] for beam search.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex, Weak};

use crate::error::{Context, Result};

use crate::data::minidb::exec_match;
use crate::xla;
use crate::data::tasks::spider_table;
use crate::data::words_to_ids;
use crate::data::{make_batch, Dataset, Example, BOS, PAD};
use crate::manifest::{Manifest, OperandDtype, OperandMeta, PeftMeta, Variant};
use crate::metrics;
use crate::runtime::{Engine, Executable};
use crate::suite::Metric;
use crate::tensor::{argmax, IntTensor, Tensor};
use crate::train::Trainer;

/// Classification accuracy/metric over a split using the fwd artifact:
/// logits at the label position, restricted to the task's label bytes.
pub fn eval_classification(trainer: &Trainer, split: &[Example], metric: Metric) -> Result<f64> {
    let b = trainer.variant.batch_b;
    let l = trainer.variant.batch_l;
    let mut preds = Vec::new();
    let mut golds = Vec::new();
    let mut i = 0;
    while i < split.len() {
        let end = (i + b).min(split.len());
        let mut refs: Vec<&Example> = split[i..end].iter().collect();
        while refs.len() < b {
            refs.push(&split[0]); // pad batch; extra rows ignored below
        }
        let batch = make_batch(&refs, b, l);
        let logits = trainer.logits(&batch)?; // (B, L, V)
        let v = logits.shape[2];
        for (r, ex) in split[i..end].iter().enumerate() {
            let pos = batch.label_pos[r];
            let row = &logits.data[(r * l + pos) * v..(r * l + pos + 1) * v];
            let scores: Vec<f32> =
                ex.label_bytes.iter().map(|&bb| row[bb as usize]).collect();
            // generation-style examples carry no class label; skip them
            // rather than panic if one leaks into a classification split
            let Some(gold) = ex.label else { continue };
            preds.push(argmax(&scores));
            golds.push(gold);
        }
        i = end;
    }
    Ok(match metric {
        Metric::Matthews => metrics::matthews_corr(&preds, &golds),
        _ => metrics::accuracy(&preds, &golds),
    })
}

/// Regression MSE over generated (x, y) pairs (Fig. 2 synthetic setting).
pub fn eval_regression(trainer: &Trainer, xs: &[Tensor], ys: &[Tensor]) -> Result<f64> {
    let mut total = 0.0;
    let mut n = 0usize;
    for (x, y) in xs.iter().zip(ys) {
        let pred = trainer.forward_reg(x)?;
        total += metrics::mse(&pred.data, &y.data) * pred.numel() as f64;
        n += pred.numel();
    }
    Ok(total / n.max(1) as f64)
}

/// Recurrent-state geometry of a stepwise decode model: everything needed
/// to allocate, seed (initial-state tuning h0), and per-row reset the conv
/// and SSM state tensors.
///
/// State layout matches the decode artifact contract (python aot.py):
/// conv state `(n_layer, B, d_conv-1, d_inner)`, SSM state
/// `(n_layer, B, d_inner, d_state)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StateDims {
    /// Number of SSM layers.
    pub n_layer: usize,
    /// Conv kernel width (state holds `d_conv - 1` positions).
    pub d_conv: usize,
    /// Inner (expanded) channel count.
    pub d_inner: usize,
    /// SSM state dimension per channel.
    pub d_state: usize,
}

impl StateDims {
    /// Read the geometry off a manifest variant.
    pub fn of(v: &Variant) -> StateDims {
        StateDims {
            n_layer: v.arch.n_layer,
            d_conv: v.arch.d_conv,
            d_inner: v.arch.d_inner,
            d_state: v.arch.d_state,
        }
    }

    /// Floats per (layer, row) in the conv state tensor.
    pub fn conv_per_row(&self) -> usize {
        (self.d_conv - 1) * self.d_inner
    }

    /// Floats per (layer, row) in the SSM state tensor.
    pub fn ssm_per_row(&self) -> usize {
        self.d_inner * self.d_state
    }

    /// Fresh `(conv, ssm)` state for a batch of `b` rows. When `h0`
    /// contains trained `layers.{i}.h0` tensors (initial-state tuning),
    /// every row's SSM state is seeded with them.
    pub fn init_states(&self, b: usize, h0: Option<&BTreeMap<String, Tensor>>)
        -> (Tensor, Tensor) {
        let conv = Tensor::zeros(&[self.n_layer, b, self.d_conv - 1, self.d_inner]);
        let mut ssm = Tensor::zeros(&[self.n_layer, b, self.d_inner, self.d_state]);
        if h0.is_some() {
            for r in 0..b {
                self.reset_row(None, Some(&mut ssm), b, r, h0);
            }
        }
        (conv, ssm)
    }

    /// Reset one batch row's state in place: conv to zeros, SSM to the
    /// adapter's h0 (or zeros). Used by the serving scheduler when a slot
    /// is recycled for a newly admitted request mid-stream.
    pub fn reset_row(&self, conv: Option<&mut Tensor>, ssm: Option<&mut Tensor>,
                     b: usize, row: usize, h0: Option<&BTreeMap<String, Tensor>>) {
        if let Some(conv) = conv {
            let per = self.conv_per_row();
            for layer in 0..self.n_layer {
                let at = (layer * b + row) * per;
                conv.data[at..at + per].fill(0.0);
            }
        }
        if let Some(ssm) = ssm {
            let per = self.ssm_per_row();
            for layer in 0..self.n_layer {
                let at = (layer * b + row) * per;
                let seed = h0.and_then(|m| m.get(&format!("layers.{layer}.h0")));
                match seed {
                    Some(h) => ssm.data[at..at + per].copy_from_slice(&h.data),
                    None => ssm.data[at..at + per].fill(0.0),
                }
            }
        }
    }

    /// Copy row `from` of a source `(conv, ssm)` pair into row `to` of a
    /// destination pair (all layers) — beam search re-parents surviving
    /// beams this way each step, reading the step output and writing the
    /// next state.
    pub fn copy_row(&self, src_conv: &Tensor, src_ssm: &Tensor,
                    dst_conv: &mut Tensor, dst_ssm: &mut Tensor, b: usize,
                    from: usize, to: usize) {
        let cper = self.conv_per_row();
        let sper = self.ssm_per_row();
        for layer in 0..self.n_layer {
            let cfrom = (layer * b + from) * cper;
            let cto = (layer * b + to) * cper;
            dst_conv.data[cto..cto + cper]
                .copy_from_slice(&src_conv.data[cfrom..cfrom + cper]);
            let sfrom = (layer * b + from) * sper;
            let sto = (layer * b + to) * sper;
            dst_ssm.data[sto..sto + sper]
                .copy_from_slice(&src_ssm.data[sfrom..sfrom + sper]);
        }
    }
}

/// The recurrent decode state of one batched stream: a host `(conv, ssm)`
/// mirror plus, when the model runs on XLA, the *literals* the previous
/// step produced ([`crate::runtime::StatePair`]).
///
/// On the steady-state decode path the state stays literal-resident: step
/// outputs feed straight back as the next step's inputs and the host
/// mirror is never materialized. Callers that need to touch rows
/// (scheduler admission, beam re-parenting, h0 seeding) go through
/// [`DecodeState::host_mut`], which lazily syncs the mirror and marks the
/// literals stale so the next step re-serializes — the cost is paid only
/// when rows actually change (§Perf L4).
pub struct DecodeState {
    conv: Tensor,
    ssm: Tensor,
    resident: Option<crate::runtime::StatePair>,
    host_fresh: bool,
}

impl DecodeState {
    /// Fresh state for `b` rows; `h0` seeds every row's SSM state
    /// (initial-state tuning).
    pub fn new(dims: StateDims, b: usize, h0: Option<&BTreeMap<String, Tensor>>)
        -> DecodeState {
        let (conv, ssm) = dims.init_states(b, h0);
        DecodeState { conv, ssm, resident: None, host_fresh: true }
    }

    fn sync_host(&mut self) -> Result<()> {
        if self.host_fresh {
            return Ok(());
        }
        let pair = self
            .resident
            .as_ref()
            .context("decode-state invariant: stale host mirror without resident literals")?;
        crate::runtime::read_f32_into(&pair.conv, &mut self.conv.data)?;
        crate::runtime::read_f32_into(&pair.ssm, &mut self.ssm.data)?;
        self.host_fresh = true;
        Ok(())
    }

    /// Read access to the host `(conv, ssm)` mirror (synced on demand; the
    /// resident literals stay valid).
    pub fn host(&mut self) -> Result<(&Tensor, &Tensor)> {
        self.sync_host()?;
        Ok((&self.conv, &self.ssm))
    }

    /// Mutable access to the host mirror. Syncs on demand and invalidates
    /// the resident literals — the next step serializes from host. Pay
    /// this only when a row genuinely changes.
    pub fn host_mut(&mut self) -> Result<(&mut Tensor, &mut Tensor)> {
        self.sync_host()?;
        self.resident = None;
        Ok((&mut self.conv, &mut self.ssm))
    }

    /// Reset one row (conv to zeros, SSM to `h0` or zeros) — scheduler
    /// slot recycling. See [`StateDims::reset_row`].
    pub fn reset_row(&mut self, dims: &StateDims, b: usize, row: usize,
                     h0: Option<&BTreeMap<String, Tensor>>) -> Result<()> {
        let (conv, ssm) = self.host_mut()?;
        dims.reset_row(Some(conv), Some(ssm), b, row, h0);
        Ok(())
    }

    /// Copy row `from` of another state into row `to` of this one (all
    /// layers) — the serve scheduler splices a finished out-of-band
    /// prefill row into the lane's live state this way. Syncs `src`'s host
    /// mirror (its residency stays valid) and invalidates this state's.
    pub fn splice_row_from(&mut self, dims: &StateDims, b: usize,
                           src: &mut DecodeState, from: usize, to: usize)
        -> Result<()> {
        src.sync_host()?;
        let (conv, ssm) = self.host_mut()?;
        dims.copy_row(&src.conv, &src.ssm, conv, ssm, b, from, to);
        Ok(())
    }

    /// Copy row `from` into every other row — beam search prefills one row
    /// and broadcasts its state before the beams diverge.
    pub fn broadcast_row(&mut self, dims: &StateDims, b: usize, from: usize)
        -> Result<()> {
        let (src_conv, src_ssm) = {
            let (c, s) = self.host()?;
            (c.clone(), s.clone())
        };
        let (conv, ssm) = self.host_mut()?;
        for to in 0..b {
            if to != from {
                dims.copy_row(&src_conv, &src_ssm, conv, ssm, b, from, to);
            }
        }
        Ok(())
    }

    /// Literals for the next execute: the previous step's outputs when
    /// resident, else a fresh serialization of the host mirror (cached, so
    /// repeated calls don't re-serialize).
    pub(crate) fn exec_literals(&mut self)
        -> Result<(&xla::Literal, &xla::Literal)> {
        if self.resident.is_none() {
            debug_assert!(self.host_fresh, "no resident state and stale host");
            self.resident = Some(crate::runtime::StatePair {
                conv: crate::runtime::literal_f32(&self.conv)?,
                ssm: crate::runtime::literal_f32(&self.ssm)?,
            });
        }
        let pair = self
            .resident
            .as_ref()
            .context("decode-state invariant: resident literals just installed")?;
        Ok((&pair.conv, &pair.ssm))
    }

    /// Adopt a step's output literals as the new state (host mirror goes
    /// stale until someone asks for it).
    pub(crate) fn install(&mut self, pair: crate::runtime::StatePair) {
        self.resident = Some(pair);
        self.host_fresh = false;
    }

    /// Build a batch-`b` state holding the given per-row `(conv, ssm)`
    /// buffers in row `row` (every other row zero) — the bridge a
    /// resurrected session snapshot takes back into a live batch via
    /// [`DecodeState::splice_row_from`]. The buffers must be exactly one
    /// row across every layer (the shape [`StateCheckpoint::row`]
    /// produces); anything else is a typed geometry error.
    pub fn with_row(dims: &StateDims, b: usize, row: usize, conv_row: &[f32],
                    ssm_row: &[f32]) -> Result<DecodeState> {
        let cper = dims.conv_per_row();
        let sper = dims.ssm_per_row();
        crate::ensure!(
            row < b
                && conv_row.len() == dims.n_layer * cper
                && ssm_row.len() == dims.n_layer * sper,
            "row-state geometry mismatch: conv {} (want {}), ssm {} (want {})",
            conv_row.len(),
            dims.n_layer * cper,
            ssm_row.len(),
            dims.n_layer * sper,
        );
        let mut state = DecodeState::new(*dims, b, None);
        {
            let (conv, ssm) = state.host_mut()?;
            for layer in 0..dims.n_layer {
                let cat = (layer * b + row) * cper;
                conv.data[cat..cat + cper]
                    .copy_from_slice(&conv_row[layer * cper..(layer + 1) * cper]);
                let sat = (layer * b + row) * sper;
                ssm.data[sat..sat + sper]
                    .copy_from_slice(&ssm_row[layer * sper..(layer + 1) * sper]);
            }
        }
        Ok(state)
    }

    /// Read one row's `(conv, ssm)` back through the checkpoint path —
    /// one host sync, residency left intact (same contract as
    /// [`DecodeState::checkpoint`]).
    pub fn row_snapshot(&mut self, dims: &StateDims, b: usize, row: usize)
        -> Result<(Vec<f32>, Vec<f32>)> {
        self.checkpoint()?.row(dims, b, row)
    }

    /// Capture a host-side snapshot of the full `(conv, ssm)` state.
    ///
    /// Syncs the host mirror (one device→host readback when the state was
    /// resident-only) but leaves residency intact, so checkpointing
    /// between steps does not change the dispatch/serialization pattern.
    /// The serve scheduler captures one of these before each fault-guarded
    /// step so a mid-tick failure can [`rollback`](Self::rollback) instead
    /// of poisoning every row in the batch.
    pub fn checkpoint(&mut self) -> Result<StateCheckpoint> {
        self.sync_host()?;
        Ok(StateCheckpoint { conv: self.conv.data.clone(), ssm: self.ssm.data.clone() })
    }

    /// Restore the state captured by [`checkpoint`](Self::checkpoint):
    /// every row's `(conv, ssm)` reverts to the snapshot and the next step
    /// re-serializes from host (resident literals from the failed step are
    /// dropped).
    pub fn rollback(&mut self, ck: &StateCheckpoint) -> Result<()> {
        crate::ensure!(
            ck.conv.len() == self.conv.data.len() && ck.ssm.len() == self.ssm.data.len(),
            "checkpoint geometry mismatch: conv {} vs {}, ssm {} vs {}",
            ck.conv.len(),
            self.conv.data.len(),
            ck.ssm.len(),
            self.ssm.data.len(),
        );
        self.conv.data.copy_from_slice(&ck.conv);
        self.ssm.data.copy_from_slice(&ck.ssm);
        self.host_fresh = true;
        self.resident = None;
        Ok(())
    }
}

/// An opaque host-side snapshot of a [`DecodeState`]'s `(conv, ssm)`
/// buffers, produced by [`DecodeState::checkpoint`] and consumed by
/// [`DecodeState::rollback`]. The same primitive the ROADMAP's
/// speculative-decoding item needs for rejected drafts, and the readback
/// path the serve session store rides for per-row snapshots
/// ([`StateCheckpoint::row`]).
pub struct StateCheckpoint {
    conv: Vec<f32>,
    ssm: Vec<f32>,
}

impl StateCheckpoint {
    /// The captured conv-state buffer (layout `(n_layer, B, d_conv-1,
    /// d_inner)`, row-major).
    pub fn conv(&self) -> &[f32] {
        &self.conv
    }

    /// The captured SSM-state buffer (layout `(n_layer, B, d_inner,
    /// d_state)`, row-major).
    pub fn ssm(&self) -> &[f32] {
        &self.ssm
    }

    /// Extract one batch row's `(conv, ssm)` slices across every layer —
    /// the per-session payload the serve session store persists. Errors
    /// when the checkpoint's geometry cannot hold `(b, row)`.
    pub fn row(&self, dims: &StateDims, b: usize, row: usize)
        -> Result<(Vec<f32>, Vec<f32>)> {
        let cper = dims.conv_per_row();
        let sper = dims.ssm_per_row();
        crate::ensure!(
            row < b
                && self.conv.len() == dims.n_layer * b * cper
                && self.ssm.len() == dims.n_layer * b * sper,
            "checkpoint row extraction out of geometry: row {row} of b {b}, \
             conv {} ssm {}",
            self.conv.len(),
            self.ssm.len(),
        );
        let mut conv = Vec::with_capacity(dims.n_layer * cper);
        let mut ssm = Vec::with_capacity(dims.n_layer * sper);
        for layer in 0..dims.n_layer {
            let cat = (layer * b + row) * cper;
            conv.extend_from_slice(&self.conv[cat..cat + cper]);
            let sat = (layer * b + row) * sper;
            ssm.extend_from_slice(&self.ssm[sat..sat + sper]);
        }
        Ok((conv, ssm))
    }
}

/// The stepwise decode interface shared by offline eval ([`Generator`]) and
/// the online serving scheduler ([`crate::serve::Scheduler`]).
///
/// One call advances every batch row by one token: rows are fully
/// independent (each carries its own O(1) recurrent state), which is what
/// makes continuous batching possible — the scheduler can retire a finished
/// row and admit a fresh request into it between any two steps.
pub trait StepDecode {
    /// Fixed batch width of the compiled decode executable.
    fn arch_b(&self) -> usize;

    /// Recurrent-state geometry (for allocating/seeding/resetting rows).
    fn dims(&self) -> StateDims;

    /// Fresh state for this model's geometry (`h0` = initial-state tuning
    /// seed applied to every row).
    fn new_state(&self, h0: Option<&BTreeMap<String, Tensor>>) -> DecodeState {
        DecodeState::new(self.dims(), self.arch_b(), h0)
    }

    /// Advance one token: `tokens (B,)` → `logits (B, V)`, advancing
    /// `state` in place. `V ≥ 256`; generation samples from the byte
    /// sub-vocabulary `[..256]`.
    fn step(&self, tokens: &IntTensor, state: &mut DecodeState) -> Result<Tensor>;

    /// Sequence-level prefill support, when the model has it (§Perf L5).
    /// `None` (the default) means prompts are ingested token-by-token.
    fn chunk_prefill(&self) -> Option<&dyn ChunkPrefill> {
        None
    }
}

/// Sequence-level prompt ingestion: one dispatch scans a whole `(B, C)`
/// token chunk through the recurrence, advancing the [`DecodeState`]
/// exactly as `C` calls of [`StepDecode::step`] would (§Perf L5).
///
/// Implemented by [`DecodeCore`] over the compiled `prefill` artifacts and
/// by mock models in tests. Only the last position's logits come back —
/// prefill consumes prompts, it does not generate.
pub trait ChunkPrefill {
    /// Supported chunk widths, ascending and non-empty.
    fn chunk_widths(&self) -> &[usize];

    /// Scan `tokens (B, C)` (`C` must be a supported width), advancing
    /// `state` in place; returns the last position's `logits (B, V)`.
    fn prefill_chunk(&self, tokens: &IntTensor, state: &mut DecodeState)
        -> Result<Tensor>;
}

/// Cover `n` prefill iterations with the largest-fitting chunks: the
/// dispatch plan (widths, in order) plus the step-wise remainder. Greedy
/// largest-first is optimal for the exported width ladder (each width
/// divides the next).
pub fn plan_chunks(widths: &[usize], n: usize) -> (Vec<usize>, usize) {
    let mut plan = Vec::new();
    let mut rem = n;
    while let Some(&w) = widths.iter().rev().find(|&&w| w <= rem) {
        plan.push(w);
        rem -= w;
    }
    (plan, rem)
}

/// Execute the chunked part of a prefill plan: dispatch largest-fitting
/// chunks until fewer than the smallest width remains of `n`, feeding row
/// `r` the token `tok(r, t)` at stream position `t`. Returns the covered
/// position count and the final chunk's logits (`None` when nothing
/// fit). The state stays literal-resident from chunk to chunk; callers
/// finish the remainder step-wise (or hand it to a decode loop).
pub fn chunk_prefill_cover(pf: &dyn ChunkPrefill, b: usize,
                           state: &mut DecodeState, n: usize,
                           tok: &dyn Fn(usize, usize) -> i32)
    -> Result<(usize, Option<Tensor>)> {
    let (plan, _rem) = plan_chunks(pf.chunk_widths(), n);
    let mut pos = 0usize;
    let mut last = None;
    for w in plan {
        let mut toks = IntTensor::from_vec(&[b, w], vec![PAD; b * w]);
        for r in 0..b {
            for i in 0..w {
                toks.data[r * w + i] = tok(r, pos + i);
            }
        }
        last = Some(pf.prefill_chunk(&toks, state)?);
        pos += w;
    }
    Ok((pos, last))
}

/// One unmerged low-rank update against a named base weight:
/// `W_target += scale · a · b` (scale from the owning delta's
/// [`PeftMeta`], exactly as [`crate::peft::merge_lora`] applies it).
pub struct LoraOp {
    /// Base-weight key the factors target (e.g. `layers.0.Win_x`).
    pub target: String,
    /// Left factor, `(d_in, r)`.
    pub a: Tensor,
    /// Right factor, `(r, d_out)`.
    pub b: Tensor,
}

/// Trained values replacing a sparse index set of one base parameter
/// (SDT-style ~1% masks, BitFit-ish scalar tweaks). Stores the trained
/// VALUES, not additive offsets: replacement reproduces the merged
/// parameter map bit-for-bit, where `base + (trained − base)` would round.
pub struct SparseOffset {
    /// Base-parameter key the offsets target.
    pub param: String,
    /// Flat indices into the parameter's data (strictly within bounds).
    pub idx: Vec<usize>,
    /// Trained replacement values, parallel to `idx`.
    pub val: Vec<f32>,
}

/// An adapter held unmerged: everything that distinguishes a fine-tuned
/// variant from the shared base model, in KBs instead of a whole-model
/// copy. This is what the serving registry keeps resident per adapter and
/// what [`AdapterStepDecode::step_rows`] binds per batch row.
pub struct AdapterDelta {
    /// PEFT description (supplies the LoRA merge scale `alpha / rank`).
    pub meta: PeftMeta,
    /// Low-rank factor pairs, one per adapted weight.
    pub lora: Vec<LoraOp>,
    /// Sparse trained-value replacements, one per adapted parameter.
    pub sparse: Vec<SparseOffset>,
    /// Trained initial SSM states (`layers.{i}.h0`), if any.
    pub h0: BTreeMap<String, Tensor>,
}

impl AdapterDelta {
    /// Bytes this delta keeps resident — the registry's memory accounting.
    /// Scales with rank × adapted weights + sparse nnz + h0, not with the
    /// base model.
    pub fn resident_bytes(&self) -> usize {
        let f = std::mem::size_of::<f32>();
        let mut n = 0usize;
        for op in &self.lora {
            n += (op.a.numel() + op.b.numel()) * f;
        }
        for s in &self.sparse {
            n += s.idx.len() * std::mem::size_of::<usize>() + s.val.len() * f;
        }
        for t in self.h0.values() {
            n += t.numel() * f;
        }
        n
    }

    /// Merge this delta into a clone of `base`, reproducing the adapter's
    /// merged parameter map bit-for-bit: sparse entries REPLACE (they hold
    /// trained values), LoRA factors go through the exact same
    /// [`crate::peft::merge_lora`] the merged path uses, and h0 keys ride
    /// along for initial-state seeding.
    pub fn apply(&self, base: &BTreeMap<String, Tensor>)
        -> Result<BTreeMap<String, Tensor>> {
        let mut m = base.clone();
        for s in &self.sparse {
            crate::ensure!(s.idx.len() == s.val.len(),
                           "sparse offset for {} has {} indices but {} values",
                           s.param, s.idx.len(), s.val.len());
            let t = m.get_mut(&s.param).with_context(|| {
                format!("sparse offset targets unknown param {}", s.param)
            })?;
            for (&i, &v) in s.idx.iter().zip(&s.val) {
                let slot = t.data.get_mut(i).with_context(|| {
                    format!("sparse index {i} out of bounds for {}", s.param)
                })?;
                *slot = v;
            }
        }
        for op in &self.lora {
            crate::ensure!(m.contains_key(&op.target),
                           "lora target {} not in base params", op.target);
            m.insert(format!("{}.lora_a", op.target), op.a.clone());
            m.insert(format!("{}.lora_b", op.target), op.b.clone());
        }
        crate::peft::merge_lora(&mut m, &self.meta);
        for (k, v) in &self.h0 {
            m.insert(k.clone(), v.clone());
        }
        Ok(m)
    }
}

/// Per-row adapter assignment of an unmerged batched step: `None` decodes
/// the unmodified base, `Some(delta)` applies that adapter's deltas to the
/// row. The `Arc` identity doubles as the row-grouping / literal-cache key.
pub type AdapterRow = Option<Arc<AdapterDelta>>;

/// A stepwise decode model that can mix adapters within one batch: the
/// serving scheduler's shared-lane interface. `step_rows` must be
/// byte-identical, row for row, to stepping each row through a core bound
/// to that row's merged parameters — the equivalence harness in this
/// module and `serve::scheduler` pins exactly that.
pub trait AdapterStepDecode: StepDecode {
    /// Advance one token with a per-row adapter assignment (`rows.len()`
    /// must equal `arch_b()`), advancing `state` in place.
    fn step_rows(&self, tokens: &IntTensor, state: &mut DecodeState,
                 rows: &[AdapterRow]) -> Result<Tensor>;
}

/// Adapter-pinned view of a shared unmerged model: a [`StepDecode`] whose
/// every row decodes with one fixed adapter. Lets single-adapter consumers
/// (beam search, offline eval) reuse the shared batched core without a
/// merged whole-model copy.
///
/// No [`ChunkPrefill`] passthrough: adapter deltas change the prefill math
/// too, and the prefill artifacts take no delta operands — prompts go
/// stepwise through `step_rows`, which keeps the pinned path exactly as
/// correct (if slower on long prompts) as a merged core.
pub struct PinnedAdapter {
    model: Arc<dyn AdapterStepDecode>,
    delta: AdapterRow,
}

impl PinnedAdapter {
    /// Pin `delta` (or the plain base, when `None`) across every row of
    /// `model`'s batch.
    pub fn new(model: Arc<dyn AdapterStepDecode>, delta: AdapterRow) -> Self {
        PinnedAdapter { model, delta }
    }
}

impl StepDecode for PinnedAdapter {
    fn arch_b(&self) -> usize {
        self.model.arch_b()
    }

    fn dims(&self) -> StateDims {
        self.model.dims()
    }

    fn step(&self, tokens: &IntTensor, state: &mut DecodeState) -> Result<Tensor> {
        let rows: Vec<AdapterRow> = vec![self.delta.clone(); self.model.arch_b()];
        self.model.step_rows(tokens, state, &rows)
    }
}

/// A decode-ready model: the compiled stepwise `decode` executable bound to
/// one merged parameter set. This is the unit the adapter registry caches —
/// same executable, different parameters per fine-tuned variant. Parameter
/// literals are serialized ONCE here, not once per token (§Perf L4).
pub struct DecodeCore {
    decode: Executable,
    /// Chunked-prefill executables as `(width, exe)`, ascending width —
    /// empty when the manifest has no `files.prefill` entries (§Perf L5).
    prefill: Vec<(usize, Executable)>,
    /// The widths of `prefill`, cached for [`ChunkPrefill::chunk_widths`].
    widths: Vec<usize>,
    /// Parameters pre-serialized in the decode variant's argument order
    /// (reused every step).
    param_lits: Vec<xla::Literal>,
    /// Host parameter copies — retained ONLY by
    /// [`DecodeCore::new_for_reference`] for the bench baseline; the
    /// serving path keeps a single (literal) copy per cached adapter.
    params: Option<Vec<Tensor>>,
    /// Executable dispatches issued (decode steps + prefill chunks) —
    /// telemetry for `bench hotpath` and the dispatch-count tests.
    dispatches: std::sync::atomic::AtomicU64,
    /// Unmerged multi-adapter support ([`DecodeCore::new_unmerged`]);
    /// `None` for plain merged cores, whose `step_rows` errors.
    unmerged: Option<UnmergedCore>,
    /// Fault-injection hook consulted before each executable dispatch
    /// ([`crate::fault::FaultSite::ExecRun`]). `None` in production —
    /// the no-fault cost is one branch per dispatch.
    faults: Option<Arc<dyn crate::fault::FaultInject>>,
    arch_b: usize,
    dims: StateDims,
}

/// The compiled `decode_adapters` executable plus the operand layout the
/// manifest recorded for it (per-row LoRA factor slots zero-padded to
/// `rank`, per-row sparse-offset slots of capacity `k`).
struct AdapterArtifact {
    exe: Executable,
    rank: usize,
    k: usize,
    operands: Vec<OperandMeta>,
}

/// Fallback merged-literal cache entries kept per unmerged core: enough to
/// cover the handful of adapters resident in one shared batch without
/// re-merging every step, small enough that memory stays bounded by a few
/// whole-model literal sets even under adapter churn.
const FALLBACK_CACHE_CAP: usize = 4;

/// State of the unmerged multi-adapter path: the shared base parameter map
/// (for host-side fallback merging), the decode argument order (to
/// serialize merged fallbacks), the optional compiled `decode_adapters`
/// artifact, and an MRU cache of fallback parameter literals keyed by
/// adapter identity.
struct UnmergedCore {
    base: Arc<BTreeMap<String, Tensor>>,
    /// Decode-executable parameter argument order (train then frozen).
    order: Vec<String>,
    artifact: Option<AdapterArtifact>,
    /// `Weak` keys make the cache ABA-safe: an entry resolves only while
    /// its delta is alive AND the upgraded `Arc` is pointer-equal, and the
    /// weak count keeps the allocation itself alive — so a dead delta's
    /// address cannot be reused by a new one while its entry remains.
    /// MRU-ordered, last = most recent.
    cache: Mutex<Vec<(Weak<AdapterDelta>, Arc<Vec<xla::Literal>>)>>,
}

impl DecodeCore {
    /// Bind the decode executable of `decode_variant` to a merged parameter
    /// map. `params_map` must contain every base parameter of the decode
    /// variant (merge LoRA first: [`crate::peft::merge_lora`]); extra keys
    /// (adapter leaves, `h0`) are ignored.
    pub fn new(engine: &Engine, manifest: &Manifest, decode_variant: &str,
               params_map: &BTreeMap<String, Tensor>) -> Result<Self> {
        Self::build(engine, manifest, decode_variant, params_map, false)
    }

    /// Like [`DecodeCore::new`] but also retains host parameter copies so
    /// [`DecodeCore::step_reference`] can replay the pre-arena per-token
    /// serialization cost. Bench use only.
    pub fn new_for_reference(engine: &Engine, manifest: &Manifest, decode_variant: &str,
                             params_map: &BTreeMap<String, Tensor>) -> Result<Self> {
        Self::build(engine, manifest, decode_variant, params_map, true)
    }

    fn build(engine: &Engine, manifest: &Manifest, decode_variant: &str,
             params_map: &BTreeMap<String, Tensor>, keep_host: bool) -> Result<Self> {
        let v: &Variant = manifest.variant(decode_variant)?;
        let file = v.decode_file.clone()
            .with_context(|| format!("{decode_variant} has no decode artifact"))?;
        let decode = engine.load(manifest.hlo_path(&file))?;
        let mut prefill = Vec::new();
        for (w, f) in &v.prefill_files {
            prefill.push((*w, engine.load(manifest.hlo_path(f))?));
        }
        let widths: Vec<usize> = prefill.iter().map(|&(w, _)| w).collect();
        let mut param_lits = Vec::new();
        let mut params = Vec::new();
        for meta in v.train_params.iter().chain(v.frozen_params.iter()) {
            let t = params_map.get(&meta.name).with_context(|| {
                format!("merged params missing {} for decode", meta.name)
            })?;
            param_lits.push(crate::runtime::literal_f32(t)?);
            if keep_host {
                params.push(t.clone());
            }
        }
        let params = keep_host.then_some(params);
        Ok(DecodeCore {
            decode,
            prefill,
            widths,
            param_lits,
            params,
            dispatches: std::sync::atomic::AtomicU64::new(0),
            unmerged: None,
            faults: None,
            arch_b: v.batch_b,
            dims: StateDims::of(v),
        })
    }

    /// Install a fault-injection hook checked before every executable
    /// dispatch. Serving wires this when the fault knobs are set; cores
    /// without a hook behave exactly as before.
    pub fn set_fault_inject(&mut self, faults: Arc<dyn crate::fault::FaultInject>) {
        self.faults = Some(faults);
    }

    /// Like [`DecodeCore::new`], but the core additionally implements
    /// [`AdapterStepDecode`]: one core bound to the shared BASE parameters
    /// serves every adapter, taking per-row [`AdapterDelta`]s at step time.
    /// When the manifest carries a `decode_adapters` artifact it is used
    /// for fitting deltas (one dispatch per step regardless of adapter
    /// mix); otherwise — and for deltas exceeding the artifact's rank/k
    /// slots — rows are grouped by adapter and dispatched through the
    /// plain decode executable with host-merged parameters, byte-identical
    /// to per-adapter merged cores.
    pub fn new_unmerged(engine: &Engine, manifest: &Manifest, decode_variant: &str,
                        base: Arc<BTreeMap<String, Tensor>>) -> Result<Self> {
        let mut core = Self::build(engine, manifest, decode_variant, &base, false)?;
        let v: &Variant = manifest.variant(decode_variant)?;
        let order: Vec<String> = v
            .train_params
            .iter()
            .chain(v.frozen_params.iter())
            .map(|m| m.name.clone())
            .collect();
        let artifact = match (&v.decode_adapters_file, &v.adapter_operands) {
            (Some(f), Some(ops)) => Some(AdapterArtifact {
                exe: engine.load(manifest.hlo_path(f))?,
                rank: ops.rank,
                k: ops.k,
                operands: ops.operands.clone(),
            }),
            _ => None,
        };
        core.unmerged = Some(UnmergedCore {
            base,
            order,
            artifact,
            cache: Mutex::new(Vec::new()),
        });
        Ok(core)
    }

    /// Whether the compiled `decode_adapters` artifact is loaded (vs the
    /// host-side grouped fallback only).
    pub fn has_adapter_artifact(&self) -> bool {
        self.unmerged
            .as_ref()
            .is_some_and(|u| u.artifact.is_some())
    }

    /// Chunk widths of the loaded prefill artifacts (empty = none).
    pub fn prefill_widths(&self) -> &[usize] {
        &self.widths
    }

    /// Executable dispatches issued so far (decode steps + prefill chunks).
    pub fn dispatch_count(&self) -> u64 {
        self.dispatches.load(std::sync::atomic::Ordering::Relaxed)
    }

    /// Publish this core's dispatch counter into a metrics registry as
    /// `core.dispatches` (rust/docs/observability.md § Registry).
    pub fn publish_metrics(&self, m: &crate::obs::Metrics) {
        m.counter("core.dispatches").set(self.dispatch_count());
    }

    /// Reference step that re-serializes every parameter literal and
    /// forces the state through the host (the pre-arena behavior). Kept
    /// ONLY as the `bench hotpath` baseline — never use it to serve.
    /// Errors unless the core was built with
    /// [`DecodeCore::new_for_reference`].
    pub fn step_reference(&self, tokens: &IntTensor, state: &mut DecodeState)
        -> Result<Tensor> {
        state.host_mut()?; // drop residency: state re-serializes from host
        self.step_inner(tokens, state, false)
    }

    fn step_inner(&self, tokens: &IntTensor, state: &mut DecodeState,
                  resident_params: bool) -> Result<Tensor> {
        self.run_exec(&self.decode, tokens, state, resident_params, &[])
    }

    /// Shared execute path for the decode, prefill, and decode_adapters
    /// artifacts: all take `(params..., tokens, conv, ssm, extra...)` and
    /// return `(logits, conv', ssm')`, and all feed the output state
    /// literals straight back as the next dispatch's inputs (§Perf L4/L5).
    /// `extra` carries the per-row adapter operands of the unmerged path
    /// (empty for decode/prefill).
    fn run_exec(&self, exe: &Executable, tokens: &IntTensor,
                state: &mut DecodeState, resident_params: bool,
                extra: &[xla::Literal])
        -> Result<Tensor> {
        if let Some(f) = &self.faults {
            f.check(crate::fault::FaultSite::ExecRun)?;
        }
        self.dispatches.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let tok_lit = crate::runtime::literal_i32(tokens)?;
        let fresh: Vec<xla::Literal> = if resident_params {
            Vec::new()
        } else {
            self.params
                .as_ref()
                .context("step_reference needs DecodeCore::new_for_reference")?
                .iter()
                .map(crate::runtime::literal_f32)
                .collect::<Result<Vec<_>>>()?
        };
        let mut outs = {
            let (conv_lit, ssm_lit) = state.exec_literals()?;
            let mut refs: Vec<&xla::Literal> =
                Vec::with_capacity(self.param_lits.len() + 3 + extra.len());
            if resident_params {
                refs.extend(self.param_lits.iter());
            } else {
                refs.extend(fresh.iter());
            }
            refs.push(&tok_lit);
            refs.push(conv_lit);
            refs.push(ssm_lit);
            refs.extend(extra.iter());
            exe.run_refs_literals(&refs)?
        };
        let ssm_out = outs.pop().context("decode returned no ssm state")?;
        let conv_out = outs.pop().context("decode returned no conv state")?;
        let logits = outs.pop().context("decode returned no logits")?;
        let logits = crate::runtime::tensor_from_literal(&logits)?;
        state.install(crate::runtime::StatePair { conv: conv_out, ssm: ssm_out });
        Ok(logits)
    }

    /// Whether `delta` fits the artifact's per-row operand slots: every
    /// LoRA pair has a slot of its target's shape with rank ≤ the baked
    /// slot rank, and every sparse offset has a slot with nnz ≤ k. Deltas
    /// that don't fit (oversized rank, non-slot target, dense-ish sparse
    /// set) take the grouped host fallback instead.
    fn delta_fits(delta: &AdapterDelta, art: &AdapterArtifact) -> bool {
        let find = |name: &str| art.operands.iter().find(|o| o.name == name);
        for op in &delta.lora {
            if op.a.shape.len() != 2 || op.b.shape.len() != 2 {
                return false;
            }
            let (Some(ma), Some(mb)) = (find(&format!("{}.lora_a", op.target)),
                                        find(&format!("{}.lora_b", op.target)))
            else {
                return false;
            };
            let r = op.a.shape[1];
            if op.a.shape[0] != ma.shape[1] || r > art.rank
                || op.b.shape[0] != r || op.b.shape[1] != mb.shape[2] {
                return false;
            }
        }
        for s in &delta.sparse {
            let Some(mi) = find(&format!("{}.sdt_idx", s.param)) else {
                return false;
            };
            if s.idx.len() > mi.shape[1].min(art.k) {
                return false;
            }
        }
        true
    }

    /// Unmerged step through the `decode_adapters` artifact: ONE dispatch
    /// advances the whole mixed batch — per-row LoRA factors zero-padded
    /// to the slot rank, per-row sparse offsets as additive
    /// `(index, trained − base)` pairs (unused slots index 0 with value 0,
    /// a no-op add).
    fn step_rows_artifact(&self, un: &UnmergedCore, art: &AdapterArtifact,
                          tokens: &IntTensor, state: &mut DecodeState,
                          rows: &[AdapterRow]) -> Result<Tensor> {
        let b = self.arch_b;
        let mut extra: Vec<xla::Literal> = Vec::with_capacity(art.operands.len());
        for meta in &art.operands {
            crate::ensure!(meta.shape.first() == Some(&b),
                           "adapter operand {} batch dim {:?} != arch B {b}",
                           meta.name, meta.shape.first());
            let lit = match meta.dtype {
                OperandDtype::I32 => {
                    let mut t = IntTensor::zeros(&meta.shape);
                    if let Some(param) = meta.name.strip_suffix(".sdt_idx") {
                        let k = meta.shape[1];
                        for (r, row) in rows.iter().enumerate() {
                            let Some(s) = row.as_ref()
                                .and_then(|d| d.sparse.iter().find(|s| s.param == param))
                            else { continue };
                            for (j, &i) in s.idx.iter().enumerate() {
                                t.data[r * k + j] = i as i32;
                            }
                        }
                    }
                    crate::runtime::literal_i32(&t)?
                }
                OperandDtype::F32 => {
                    let mut t = Tensor::zeros(&meta.shape);
                    if meta.name == "scale" {
                        for (r, row) in rows.iter().enumerate() {
                            t.data[r] = match row {
                                Some(d) if d.meta.rank > 0 => {
                                    d.meta.alpha as f32 / d.meta.rank as f32
                                }
                                _ => 1.0,
                            };
                        }
                    } else if let Some(target) = meta.name.strip_suffix(".lora_a") {
                        let (din, rank) = (meta.shape[1], meta.shape[2]);
                        for (r, row) in rows.iter().enumerate() {
                            let Some(op) = row.as_ref()
                                .and_then(|d| d.lora.iter().find(|o| o.target == target))
                            else { continue };
                            let rr = op.a.shape[1];
                            for i in 0..din {
                                let at = (r * din + i) * rank;
                                t.data[at..at + rr]
                                    .copy_from_slice(&op.a.data[i * rr..(i + 1) * rr]);
                            }
                        }
                    } else if let Some(target) = meta.name.strip_suffix(".lora_b") {
                        let (rank, dout) = (meta.shape[1], meta.shape[2]);
                        for (r, row) in rows.iter().enumerate() {
                            let Some(op) = row.as_ref()
                                .and_then(|d| d.lora.iter().find(|o| o.target == target))
                            else { continue };
                            let rr = op.b.shape[0];
                            let at = r * rank * dout;
                            t.data[at..at + rr * dout].copy_from_slice(&op.b.data);
                        }
                    } else if let Some(param) = meta.name.strip_suffix(".sdt_val") {
                        let k = meta.shape[1];
                        let base_t = un.base.get(param).with_context(|| {
                            format!("adapter operand {} has no base param", meta.name)
                        })?;
                        for (r, row) in rows.iter().enumerate() {
                            let Some(s) = row.as_ref()
                                .and_then(|d| d.sparse.iter().find(|s| s.param == param))
                            else { continue };
                            for (j, (&i, &v)) in s.idx.iter().zip(&s.val).enumerate() {
                                let bv = *base_t.data.get(i).with_context(|| {
                                    format!("sparse index {i} out of bounds for {param}")
                                })?;
                                t.data[r * k + j] = v - bv;
                            }
                        }
                    }
                    crate::runtime::literal_f32(&t)?
                }
            };
            extra.push(lit);
        }
        self.run_exec(&art.exe, tokens, state, true, &extra)
    }

    /// Serialized merged-parameter literals for one adapter delta, through
    /// the MRU fallback cache (keyed by `Arc` identity via `Weak` — see
    /// [`UnmergedCore::cache`]). A miss merges the delta against the base
    /// map and serializes in decode argument order, outside the lock.
    fn group_literals(&self, un: &UnmergedCore, delta: &Arc<AdapterDelta>)
        -> Result<Arc<Vec<xla::Literal>>> {
        {
            let mut cache = un.cache.lock()
                .unwrap_or_else(std::sync::PoisonError::into_inner);
            if let Some(pos) = cache.iter().position(|(w, _)| {
                w.upgrade().is_some_and(|a| Arc::ptr_eq(&a, delta))
            }) {
                let entry = cache.remove(pos);
                let lits = entry.1.clone();
                cache.push(entry); // most-recent to the back
                return Ok(lits);
            }
            cache.retain(|(w, _)| w.strong_count() > 0);
        }
        let merged = delta.apply(&un.base)?;
        let mut lits = Vec::with_capacity(un.order.len());
        for name in &un.order {
            let t = merged.get(name).with_context(|| {
                format!("merged adapter params missing {name} for decode")
            })?;
            lits.push(crate::runtime::literal_f32(t)?);
        }
        let lits = Arc::new(lits);
        let mut cache = un.cache.lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner);
        if cache.len() >= FALLBACK_CACHE_CAP {
            cache.remove(0); // least-recent at the front
        }
        cache.push((Arc::downgrade(delta), lits.clone()));
        Ok(lits)
    }

    /// Unmerged step without (or past) the artifact: group rows by adapter
    /// identity and dispatch the plain decode executable once per group
    /// with that group's host-merged parameters. Batch rows are computed
    /// independently by the executable, so each row's slice of its group's
    /// output is exactly what a dedicated merged core would produce —
    /// byte-identical, which is what the equivalence harness pins.
    fn step_rows_fallback(&self, un: &UnmergedCore, tokens: &IntTensor,
                          state: &mut DecodeState, rows: &[AdapterRow])
        -> Result<Tensor> {
        let b = self.arch_b;
        let mut groups: Vec<(AdapterRow, Vec<usize>)> = Vec::new();
        for (r, row) in rows.iter().enumerate() {
            let found = groups.iter_mut().find(|(g, _)| match (g, row) {
                (None, None) => true,
                (Some(a), Some(bb)) => Arc::ptr_eq(a, bb),
                _ => false,
            });
            match found {
                Some((_, idxs)) => idxs.push(r),
                None => groups.push((row.clone(), vec![r])),
            }
        }
        let tok_lit = crate::runtime::literal_i32(tokens)?;
        let mut parts: Vec<(&Vec<usize>, Tensor, Tensor, Tensor)> =
            Vec::with_capacity(groups.len());
        {
            let (conv_lit, ssm_lit) = state.exec_literals()?;
            for (delta, idxs) in &groups {
                let lits = match delta {
                    Some(d) => Some(self.group_literals(un, d)?),
                    None => None,
                };
                self.dispatches.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                let mut refs: Vec<&xla::Literal> =
                    Vec::with_capacity(self.param_lits.len() + 3);
                match &lits {
                    Some(l) => refs.extend(l.iter()),
                    None => refs.extend(self.param_lits.iter()),
                }
                refs.push(&tok_lit);
                refs.push(conv_lit);
                refs.push(ssm_lit);
                let mut outs = self.decode.run_refs_literals(&refs)?;
                let ssm_out = outs.pop().context("decode returned no ssm state")?;
                let conv_out = outs.pop().context("decode returned no conv state")?;
                let lg = outs.pop().context("decode returned no logits")?;
                parts.push((idxs,
                            crate::runtime::tensor_from_literal(&lg)?,
                            crate::runtime::tensor_from_literal(&conv_out)?,
                            crate::runtime::tensor_from_literal(&ssm_out)?));
            }
        }
        let dims = self.dims;
        let v = parts.first().map(|(_, lg, _, _)| lg.shape[1])
            .context("unmerged step produced no groups")?;
        let (cper, sper) = (dims.conv_per_row(), dims.ssm_per_row());
        let mut logits = Tensor::zeros(&[b, v]);
        // every row belongs to exactly one group, so overwriting all rows
        // leaves the state fully post-step (the pre-step mirror synced by
        // host_mut is a scaffold, not a leak)
        let (conv, ssm) = state.host_mut()?;
        for (idxs, glog, gconv, gssm) in &parts {
            for &r in idxs.iter() {
                logits.data[r * v..(r + 1) * v]
                    .copy_from_slice(&glog.data[r * v..(r + 1) * v]);
                for layer in 0..dims.n_layer {
                    let c = (layer * b + r) * cper;
                    conv.data[c..c + cper].copy_from_slice(&gconv.data[c..c + cper]);
                    let s = (layer * b + r) * sper;
                    ssm.data[s..s + sper].copy_from_slice(&gssm.data[s..s + sper]);
                }
            }
        }
        Ok(logits)
    }
}

impl AdapterStepDecode for DecodeCore {
    fn step_rows(&self, tokens: &IntTensor, state: &mut DecodeState,
                 rows: &[AdapterRow]) -> Result<Tensor> {
        crate::ensure!(rows.len() == self.arch_b,
                       "step_rows needs one adapter slot per batch row ({} != {})",
                       rows.len(), self.arch_b);
        let un = self.unmerged.as_ref()
            .context("DecodeCore was not built with new_unmerged")?;
        if rows.iter().all(Option::is_none) {
            // pure-base batch: identical to the plain resident step
            return self.step_inner(tokens, state, true);
        }
        if let Some(art) = &un.artifact {
            if rows.iter().flatten().all(|d| Self::delta_fits(d, art)) {
                return self.step_rows_artifact(un, art, tokens, state, rows);
            }
        }
        self.step_rows_fallback(un, tokens, state, rows)
    }
}

impl ChunkPrefill for DecodeCore {
    fn chunk_widths(&self) -> &[usize] {
        &self.widths
    }

    fn prefill_chunk(&self, tokens: &IntTensor, state: &mut DecodeState)
        -> Result<Tensor> {
        let w = *tokens.shape.get(1).context("prefill tokens must be (B, C)")?;
        let exe = self
            .prefill
            .iter()
            .find(|&&(pw, _)| pw == w)
            .map(|(_, e)| e)
            .with_context(|| format!("no prefill artifact for chunk width {w}"))?;
        self.run_exec(exe, tokens, state, true, &[])
    }
}

impl StepDecode for DecodeCore {
    fn arch_b(&self) -> usize {
        self.arch_b
    }

    fn dims(&self) -> StateDims {
        self.dims
    }

    fn step(&self, tokens: &IntTensor, state: &mut DecodeState) -> Result<Tensor> {
        self.step_inner(tokens, state, true)
    }

    fn chunk_prefill(&self) -> Option<&dyn ChunkPrefill> {
        (!self.widths.is_empty()).then_some(self as &dyn ChunkPrefill)
    }
}

/// Batched greedy decoding for up to `arch_b` prompts at once. Rows still
/// in prefill keep consuming their prompt; finished rows emit until
/// `stop_byte` or `max_new`. `h0` seeds the SSM state (initial-state
/// tuning).
///
/// When the model supports [`ChunkPrefill`], the iterations whose logits
/// every row discards (the shortest prompt's prefix) are scanned as
/// chunks instead of one dispatch per token; the remainder and all
/// generation run step-wise, byte-identical to the pure step-wise path.
pub fn greedy_decode(model: &dyn StepDecode, prompts: &[Vec<u8>], max_new: usize,
                     stop_byte: u8, h0: Option<&BTreeMap<String, Tensor>>)
    -> Result<Vec<Vec<u8>>> {
    assert!(prompts.len() <= model.arch_b());
    let b = model.arch_b();
    // greedy never touches rows mid-stream, so the state stays
    // literal-resident for the whole generation (§Perf L4)
    let mut state = model.new_state(h0);
    let max_prompt = prompts.iter().map(Vec::len).max().unwrap_or(0);
    let mut outs: Vec<Vec<u8>> = vec![Vec::new(); prompts.len()];
    let mut done = vec![false; prompts.len()];
    let mut cur = IntTensor::from_vec(&[b], vec![BOS; b]);
    let mut start_t = 0usize;
    if let Some(pf) = model.chunk_prefill() {
        // iteration t consumes stream[t] = [BOS, p[0], p[1], ...][t]; its
        // logits are used only once t reaches a row's prompt length, so
        // the first min-prompt-len iterations are pure ingestion and can
        // be covered by chunks (§Perf L5)
        let m = prompts.iter().map(Vec::len).min().unwrap_or(0);
        let stream = |r: usize, t: usize| -> i32 {
            if r >= prompts.len() {
                PAD
            } else if t == 0 {
                BOS
            } else {
                prompts[r][t - 1] as i32
            }
        };
        let (covered, _) = chunk_prefill_cover(pf, b, &mut state, m, &stream)?;
        if covered > 0 {
            start_t = covered;
            for r in 0..b {
                cur.data[r] = stream(r, covered);
            }
        }
    }
    for t in start_t..max_prompt + max_new {
        let logits = model.step(&cur, &mut state)?;
        let v = logits.shape[1];
        for r in 0..prompts.len() {
            let next: i32 = if t < prompts[r].len() {
                prompts[r][t] as i32 // still prefilling
            } else if done[r] || outs[r].len() >= max_new {
                PAD
            } else {
                let row = &logits.data[r * v..(r + 1) * v];
                // generate over byte vocabulary only (no BOS/PAD)
                let tok = argmax(&row[..256]) as u8;
                if tok == stop_byte {
                    done[r] = true;
                    PAD
                } else {
                    outs[r].push(tok);
                    tok as i32
                }
            };
            cur.data[r] = next;
        }
        for r in prompts.len()..b {
            cur.data[r] = PAD;
        }
        if (0..prompts.len()).all(|r| t >= prompts[r].len()
            && (done[r] || outs[r].len() >= max_new)) {
            break;
        }
    }
    Ok(outs)
}

#[derive(Clone)]
struct Beam {
    toks: Vec<u8>,
    score: f64,
    done: bool,
}

impl Beam {
    /// Generated-token count for length normalization. The stop byte is
    /// not in `toks` but its log-prob is in `score`, so it counts here —
    /// keeping a beam's normalized score identical at finish time and on
    /// every later carry.
    fn gen_len(&self) -> usize {
        self.toks.len() + self.done as usize
    }
}

/// Length-normalized beam score: mean log-prob per generated token
/// (including the stop byte for finished beams — see [`Beam::gen_len`]).
fn beam_norm(score: f64, len: usize) -> f64 {
    score / len.max(1) as f64
}

/// Beam search for ONE prompt, packing beams into the batch dimension
/// (beam width ≤ `arch_b`). Length-normalized log-prob scoring. `h0` seeds
/// the SSM state as in [`greedy_decode`] (initial-state tuning).
///
/// Finished beams are carried over verbatim each round — they are skipped
/// when forming expansion candidates, so their length-normalized score is
/// frozen at finish time instead of being renormalized (and drifting) on
/// every subsequent step.
pub fn beam_search(model: &dyn StepDecode, prompt: &[u8], width: usize,
                   max_new: usize, stop_byte: u8,
                   h0: Option<&BTreeMap<String, Tensor>>) -> Result<Vec<u8>> {
    if max_new == 0 {
        return Ok(Vec::new());
    }
    let width = width.min(model.arch_b()).max(1);
    let b = model.arch_b();
    let dims = model.dims();
    let mut state = model.new_state(h0);
    // prefill ONE row (chunked when the model supports it) instead of
    // scanning the same prompt redundantly across all `b` rows; row 0's
    // state is broadcast below before the beams diverge (§Perf L5). The
    // broadcast costs one host round-trip per request — beam re-parenting
    // pays that every step anyway, so it never dominates.
    let n = prompt.len() + 1; // BOS + prompt
    let stream = |r: usize, t: usize| -> i32 {
        if r != 0 {
            PAD
        } else if t == 0 {
            BOS
        } else {
            prompt[t - 1] as i32
        }
    };
    let mut covered = 0usize;
    let mut last = None;
    if let Some(pf) = model.chunk_prefill() {
        let (c, lg) = chunk_prefill_cover(pf, b, &mut state, n, &stream)?;
        covered = c;
        if c == n {
            last = lg; // the final chunk's logits ARE the first-expansion logits
        }
    }
    let mut cur = IntTensor::from_vec(&[b], vec![PAD; b]);
    for t in covered..n {
        for r in 0..b {
            cur.data[r] = stream(r, t);
        }
        last = Some(model.step(&cur, &mut state)?);
    }
    let logits = last.context("beam prefill produced no logits (empty prompt stream)")?;
    state.broadcast_row(&dims, b, 0)?;
    let v = logits.shape[1];
    let lp0 = log_softmax(&logits.data[..v]);
    let mut order: Vec<usize> = (0..256).collect();
    order.sort_by(|&a, &bb| lp0[bb].total_cmp(&lp0[a]));
    let mut beams: Vec<Beam> = order[..width]
        .iter()
        .map(|&t| Beam {
            toks: if t as u8 == stop_byte { Vec::new() } else { vec![t as u8] },
            score: lp0[t],
            done: t as u8 == stop_byte,
        })
        .collect();
    for r in 0..b {
        let bm = &beams[r.min(width - 1)];
        // a live beam always holds its expansion token; PAD is safe either way
        cur.data[r] = if bm.done { PAD } else { bm.toks.last().map_or(PAD, |&t| t as i32) };
    }
    // replicate states across beams (identical after same prefill)
    for _ in 1..max_new {
        if beams.iter().all(|bm| bm.done) {
            break;
        }
        let lg = model.step(&cur, &mut state)?;
        // candidate = (parent beam, Some(expansion token) | None for a
        // carried finished beam, raw score, normalized score)
        let mut cand: Vec<(usize, Option<u8>, f64, f64)> = Vec::new();
        for (bi, bm) in beams.iter().enumerate() {
            if bm.done {
                // finished beams compete for slots at their frozen score
                // but are never expanded or renormalized
                cand.push((bi, None, bm.score, beam_norm(bm.score, bm.gen_len())));
                continue;
            }
            let lp = log_softmax(&lg.data[bi * v..bi * v + 256]);
            let mut idx: Vec<usize> = (0..256).collect();
            idx.sort_by(|&a, &bb| lp[bb].total_cmp(&lp[a]));
            for &t in &idx[..width] {
                // the expansion token counts toward the normalized length
                // whether it extends the beam or finishes it (stop byte),
                // so this norm IS the frozen norm if the beam finishes
                let s = bm.score + lp[t];
                cand.push((bi, Some(t as u8), s, beam_norm(s, bm.toks.len() + 1)));
            }
        }
        cand.sort_by(|a, bc| bc.3.total_cmp(&a.3));
        let mut new_beams = Vec::with_capacity(width);
        // re-parent surviving beams: snapshot the post-step state, then
        // permute rows in the host mirror (slots beyond `width` keep their
        // post-step values, matching the old clone-then-copy behavior)
        let (src_conv, src_ssm) = {
            let (c, s) = state.host()?;
            (c.clone(), s.clone())
        };
        let (conv, ssm) = state.host_mut()?;
        for (slot, &(bi, tok, score, _)) in cand.iter().take(width).enumerate() {
            let src = beams[bi].clone();
            let (toks, done) = match tok {
                None => (src.toks, true),
                Some(t) if t == stop_byte => (src.toks, true),
                Some(t) => {
                    let mut ts = src.toks;
                    ts.push(t);
                    (ts, false)
                }
            };
            new_beams.push(Beam { toks, score, done });
            // copy parent state into this slot
            dims.copy_row(&src_conv, &src_ssm, conv, ssm, b, bi, slot);
        }
        beams = new_beams;
        for r in 0..b {
            let bm = &beams[r.min(width - 1)];
            // a live beam always holds its expansion token; PAD is safe either way
        cur.data[r] = if bm.done { PAD } else { bm.toks.last().map_or(PAD, |&t| t as i32) };
        }
    }
    Ok(beams
        .into_iter()
        .max_by(|a, bm| {
            beam_norm(a.score, a.gen_len()).total_cmp(&beam_norm(bm.score, bm.gen_len()))
        })
        .map(|bm| bm.toks)
        .unwrap_or_default())
}

/// Offline generator: a [`DecodeCore`] plus the greedy/beam entry points
/// the coordinator and examples use.
pub struct Generator {
    core: DecodeCore,
}

impl Generator {
    /// `params_map` must contain every base parameter of the decode variant
    /// (merge LoRA first: [`crate::peft::merge_lora`]). Initial-state
    /// tuning passes its trained h0 via the ssm-state input automatically
    /// when the map contains "layers.{i}.h0".
    pub fn new(engine: &Engine, manifest: &Manifest, decode_variant: &str,
               params_map: &BTreeMap<String, Tensor>) -> Result<Self> {
        Ok(Generator { core: DecodeCore::new(engine, manifest, decode_variant, params_map)? })
    }

    /// Fixed batch width of the underlying decode executable.
    pub fn arch_b(&self) -> usize {
        self.core.arch_b()
    }

    /// Greedy generation for up to `arch_b` prompts at once — see
    /// [`greedy_decode`].
    pub fn greedy(&self, prompts: &[Vec<u8>], max_new: usize, stop_byte: u8,
                  h0: Option<&BTreeMap<String, Tensor>>) -> Result<Vec<Vec<u8>>> {
        greedy_decode(&self.core, prompts, max_new, stop_byte, h0)
    }

    /// Beam search for one prompt — see [`beam_search`].
    pub fn beam(&self, prompt: &[u8], width: usize, max_new: usize, stop_byte: u8,
                h0: Option<&BTreeMap<String, Tensor>>) -> Result<Vec<u8>> {
        beam_search(&self.core, prompt, width, max_new, stop_byte, h0)
    }
}

fn log_softmax(row: &[f32]) -> Vec<f64> {
    let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
    let z: f64 = row.iter().map(|&x| ((x as f64) - m).exp()).sum();
    row.iter().map(|&x| (x as f64) - m - z.ln()).collect()
}

/// Generation metrics over a test split: ROUGE / BLEU+METEOR / exec-match.
pub struct GenScores {
    /// ROUGE-1 F1 (unigram overlap).
    pub rouge1: f64,
    /// ROUGE-2 F1 (bigram overlap).
    pub rouge2: f64,
    /// ROUGE-L F1 (longest common subsequence).
    pub rougel: f64,
    /// Corpus BLEU.
    pub bleu: f64,
    /// METEOR-lite (unigram F-mean with fragmentation penalty).
    pub meteor: f64,
    /// Execution-match accuracy against the mini database (Spider).
    pub exec_acc: f64,
}

/// Greedy-decode a test split in arch-batch chunks and score it.
pub fn eval_generation(gen: &Generator, ds: &Dataset, split: &[Example],
                       max_new: usize, seed: u64,
                       h0: Option<&BTreeMap<String, Tensor>>) -> Result<GenScores> {
    let mut outs: Vec<Vec<u8>> = Vec::with_capacity(split.len());
    let mut i = 0;
    while i < split.len() {
        let end = (i + gen.arch_b()).min(split.len());
        let prompts: Vec<Vec<u8>> = split[i..end].iter().map(|e| e.prompt.clone()).collect();
        outs.extend(gen.greedy(&prompts, max_new, b'\n', h0)?);
        i = end;
    }
    Ok(score_generation(ds, split, &outs, seed))
}

/// Beam-search generation metrics: one beam search per example (beams pack
/// the batch dimension, so examples run serially). Used when
/// `ExperimentConfig::beam > 1`.
pub fn eval_generation_beam(gen: &Generator, ds: &Dataset, split: &[Example],
                            width: usize, max_new: usize, seed: u64,
                            h0: Option<&BTreeMap<String, Tensor>>) -> Result<GenScores> {
    let mut outs: Vec<Vec<u8>> = Vec::with_capacity(split.len());
    for ex in split {
        outs.push(gen.beam(&ex.prompt, width, max_new, b'\n', h0)?);
    }
    Ok(score_generation(ds, split, &outs, seed))
}

/// Score generated outputs against a split's targets (shared by the
/// greedy and beam paths).
fn score_generation(ds: &Dataset, split: &[Example], outs: &[Vec<u8>], seed: u64)
    -> GenScores {
    let mut preds_ids = Vec::new();
    let mut golds_ids = Vec::new();
    let mut r1 = Vec::new();
    let mut r2 = Vec::new();
    let mut rl = Vec::new();
    let mut met = Vec::new();
    let mut exec_hits = 0usize;
    let table = spider_table(seed);
    for (ex, out) in split.iter().zip(outs) {
        let p_ids = words_to_ids(out);
        let g_ids = words_to_ids(&ex.target);
        r1.push(metrics::rouge_n(&p_ids, &g_ids, 1));
        r2.push(metrics::rouge_n(&p_ids, &g_ids, 2));
        rl.push(metrics::rouge_l(&p_ids, &g_ids));
        met.push(metrics::meteor(&p_ids, &g_ids));
        if ds.metric == Metric::Exec {
            let pred_s = String::from_utf8_lossy(out).to_string();
            let gold_s = String::from_utf8_lossy(&ex.target).to_string();
            if exec_match(&table, &pred_s, &gold_s) {
                exec_hits += 1;
            }
        }
        preds_ids.push(p_ids);
        golds_ids.push(g_ids);
    }
    let n = preds_ids.len().max(1) as f64;
    GenScores {
        rouge1: crate::tensor::mean(&r1),
        rouge2: crate::tensor::mean(&r2),
        rougel: crate::tensor::mean(&rl),
        bleu: metrics::bleu(&preds_ids, &golds_ids),
        meteor: crate::tensor::mean(&met),
        exec_acc: exec_hits as f64 / n,
    }
}

/// Convenience: eval loss over a split (early-stopping signal shared by all
/// task types).
pub fn eval_split_loss(trainer: &Trainer, split: &[Example], rng_seed: u64) -> Result<f64> {
    let b = trainer.variant.batch_b;
    let l = trainer.variant.batch_l;
    let mut rng = crate::tensor::Rng::new(rng_seed);
    let mut losses = Vec::new();
    let it = crate::data::BatchIter::new(split, &mut rng, b, l);
    for (batch, _) in it.take(8) {
        losses.push(trainer.eval_loss(&batch)? as f64);
    }
    Ok(crate::tensor::mean(&losses))
}

/// Deterministic mock [`StepDecode`] models needing no artifacts. Shared
/// by this module's tests, the serving scheduler's
/// ([`crate::serve::scheduler`]), and the mock mode of `bench hotpath`
/// ([`crate::bench::hotpath`] uses [`testing::Accum`] for the prefill
/// dispatch accounting) — hence compiled outside `cfg(test)` too.
#[allow(dead_code)] // Counter is test-only; the bench uses Accum
pub(crate) mod testing {
    use super::*;

    /// Counter model: next byte = input byte + 1 (BOS → 1). Counts steps
    /// so scheduler tests can assert execution behavior.
    pub(crate) struct Counter {
        pub(crate) b: usize,
        pub(crate) steps: std::sync::atomic::AtomicU64,
    }

    impl Counter {
        pub(crate) fn new(b: usize) -> Counter {
            Counter { b, steps: std::sync::atomic::AtomicU64::new(0) }
        }
    }

    impl StepDecode for Counter {
        fn arch_b(&self) -> usize {
            self.b
        }
        fn dims(&self) -> StateDims {
            StateDims { n_layer: 1, d_conv: 2, d_inner: 1, d_state: 1 }
        }
        fn step(&self, tokens: &IntTensor, state: &mut DecodeState) -> Result<Tensor> {
            self.steps.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            let mut logits = Tensor::zeros(&[self.b, 256]);
            for r in 0..self.b {
                let t = tokens.data[r];
                let next = if (0..256).contains(&t) { ((t + 1) % 256) as usize } else { 1 };
                logits.data[r * 256 + next] = 10.0;
            }
            // the counter is stateless: zero the mirror like the old mock
            // returned fresh zero tensors
            let (conv, ssm) = state.host_mut()?;
            conv.data.fill(0.0);
            ssm.data.fill(0.0);
            Ok(logits)
        }
    }

    /// Stateful mock with optional chunked prefill: each row's SSM state
    /// is a rolling hash of every token it consumed (the conv state holds
    /// the previous token's value), and the next byte is a function of
    /// that hash — so ANY state discontinuity across chunk→chunk or
    /// chunk→decode transitions changes the generated bytes. Counts step
    /// and chunk dispatches for the dispatch-count assertions.
    pub(crate) struct Accum {
        pub(crate) b: usize,
        /// Advertised chunk widths (ascending); empty = stepwise-only.
        pub(crate) widths: Vec<usize>,
        /// Model-wide hash offset: stands in for "merged adapter weights"
        /// — an `Accum::with_off(_, _, o)` is the merged counterpart of an
        /// [`AccumAdapters`] row whose delta carries `o`.
        pub(crate) off: f32,
        pub(crate) steps: std::sync::atomic::AtomicU64,
        pub(crate) chunks: std::sync::atomic::AtomicU64,
    }

    impl Accum {
        pub(crate) fn new(b: usize, widths: &[usize]) -> Accum {
            Self::with_off(b, widths, 0.0)
        }

        pub(crate) fn with_off(b: usize, widths: &[usize], off: f32) -> Accum {
            Accum {
                b,
                widths: widths.to_vec(),
                off,
                steps: std::sync::atomic::AtomicU64::new(0),
                chunks: std::sync::atomic::AtomicU64::new(0),
            }
        }

        fn val(tok: i32) -> f32 {
            match tok {
                t if (0..256).contains(&t) => t as f32,
                BOS => 1.0,
                _ => 0.0, // PAD
            }
        }

        /// One token of the rolling hash (all values stay < 2^13, so every
        /// f32 op here is exact — chunked and stepwise agree bitwise, and
        /// so do the merged (`off` baked in) and unmerged (`off` from a
        /// row's delta) paths).
        fn advance(a: f32, prev: f32, tok: i32, off: f32) -> (f32, f32) {
            let v = Self::val(tok);
            ((a * 31.0 + v + prev + off) % 257.0, v)
        }

        fn logits_from(&self, hashes: &[f32]) -> Tensor {
            let mut logits = Tensor::zeros(&[self.b, 256]);
            for r in 0..self.b {
                logits.data[r * 256 + (hashes[r] as usize) % 256] = 10.0;
            }
            logits
        }
    }

    impl StepDecode for Accum {
        fn arch_b(&self) -> usize {
            self.b
        }
        fn dims(&self) -> StateDims {
            StateDims { n_layer: 1, d_conv: 2, d_inner: 1, d_state: 1 }
        }
        fn step(&self, tokens: &IntTensor, state: &mut DecodeState) -> Result<Tensor> {
            self.steps.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            let (conv, ssm) = state.host_mut()?;
            let mut hashes = vec![0.0f32; self.b];
            for r in 0..self.b {
                let (a, v) = Self::advance(ssm.data[r], conv.data[r],
                                           tokens.data[r], self.off);
                ssm.data[r] = a;
                conv.data[r] = v;
                hashes[r] = a;
            }
            Ok(self.logits_from(&hashes))
        }
        fn chunk_prefill(&self) -> Option<&dyn ChunkPrefill> {
            (!self.widths.is_empty()).then_some(self as &dyn ChunkPrefill)
        }
    }

    impl ChunkPrefill for Accum {
        fn chunk_widths(&self) -> &[usize] {
            &self.widths
        }
        fn prefill_chunk(&self, tokens: &IntTensor, state: &mut DecodeState)
            -> Result<Tensor> {
            self.chunks.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            let w = tokens.shape[1];
            crate::ensure!(self.widths.contains(&w), "unsupported chunk width {w}");
            let (conv, ssm) = state.host_mut()?;
            let mut hashes = vec![0.0f32; self.b];
            for r in 0..self.b {
                let (mut a, mut prev) = (ssm.data[r], conv.data[r]);
                for i in 0..w {
                    (a, prev) = Self::advance(a, prev, tokens.data[r * w + i],
                                              self.off);
                }
                ssm.data[r] = a;
                conv.data[r] = prev;
                hashes[r] = a;
            }
            Ok(self.logits_from(&hashes))
        }
    }

    /// A mock [`AdapterDelta`] whose whole payload is one sparse value:
    /// [`AccumAdapters`] reads it as the row's hash offset, so `off`
    /// plays the role "which adapter" in the equivalence tests.
    pub(crate) fn mock_delta(off: f32) -> Arc<AdapterDelta> {
        Arc::new(AdapterDelta {
            meta: PeftMeta {
                method: crate::suite::PeftMethod::Sdt,
                rank: 0,
                alpha: 0,
                targets: Vec::new(),
                n_tokens: 0,
            },
            lora: Vec::new(),
            sparse: vec![SparseOffset {
                param: "off".to_string(),
                idx: vec![0],
                val: vec![off],
            }],
            h0: BTreeMap::new(),
        })
    }

    /// Unmerged-adapter mock: the same rolling hash as [`Accum`], but each
    /// row's offset comes from that row's [`AdapterDelta`] (its first
    /// sparse value; `None` rows run the plain base, offset 0). A mixed
    /// batch through [`AdapterStepDecode::step_rows`] must therefore be
    /// byte-identical, row for row, to dedicated [`Accum::with_off`]
    /// models — the mock mirror of "per-row deltas == per-row merged
    /// weights". Counts batched steps for the dispatch-count pins.
    pub(crate) struct AccumAdapters {
        pub(crate) b: usize,
        pub(crate) steps: std::sync::atomic::AtomicU64,
    }

    impl AccumAdapters {
        pub(crate) fn new(b: usize) -> AccumAdapters {
            AccumAdapters { b, steps: std::sync::atomic::AtomicU64::new(0) }
        }

        fn row_off(row: &AdapterRow) -> f32 {
            row.as_ref()
                .and_then(|d| d.sparse.first())
                .and_then(|s| s.val.first())
                .copied()
                .unwrap_or(0.0)
        }
    }

    impl StepDecode for AccumAdapters {
        fn arch_b(&self) -> usize {
            self.b
        }
        fn dims(&self) -> StateDims {
            StateDims { n_layer: 1, d_conv: 2, d_inner: 1, d_state: 1 }
        }
        fn step(&self, tokens: &IntTensor, state: &mut DecodeState)
            -> Result<Tensor> {
            let rows: Vec<AdapterRow> = vec![None; self.b];
            self.step_rows(tokens, state, &rows)
        }
    }

    impl AdapterStepDecode for AccumAdapters {
        fn step_rows(&self, tokens: &IntTensor, state: &mut DecodeState,
                     rows: &[AdapterRow]) -> Result<Tensor> {
            crate::ensure!(rows.len() == self.b,
                           "step_rows needs {} adapter slots, got {}",
                           self.b, rows.len());
            self.steps.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            let (conv, ssm) = state.host_mut()?;
            let mut hashes = vec![0.0f32; self.b];
            for r in 0..self.b {
                let (a, v) = Accum::advance(ssm.data[r], conv.data[r],
                                            tokens.data[r],
                                            Self::row_off(&rows[r]));
                ssm.data[r] = a;
                conv.data[r] = v;
                hashes[r] = a;
            }
            let mut logits = Tensor::zeros(&[self.b, 256]);
            for r in 0..self.b {
                logits.data[r * 256 + (hashes[r] as usize) % 256] = 10.0;
            }
            Ok(logits)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::testing::{Accum, Counter};
    use super::*;
    use std::sync::atomic::Ordering;

    #[test]
    fn plan_chunks_largest_fit() {
        assert_eq!(plan_chunks(&[16, 64], 150), (vec![64, 64, 16], 6));
        assert_eq!(plan_chunks(&[16, 64], 37), (vec![16, 16], 5));
        assert_eq!(plan_chunks(&[16, 64], 15), (vec![], 15));
        assert_eq!(plan_chunks(&[16, 64], 0), (vec![], 0));
        assert_eq!(plan_chunks(&[4], 9), (vec![4, 4], 1));
    }

    #[test]
    fn chunked_greedy_matches_stepwise_and_counts_dispatches() {
        // acceptance: chunked output byte-identical to stepwise, chunk
        // dispatches == the plan over the shortest prompt, stepwise
        // dispatches reduced by exactly the covered iterations
        let p0: Vec<u8> = (0..23).map(|i| (i * 7 + 3) as u8).collect();
        let p1: Vec<u8> = (0..9).map(|i| (i * 11 + 5) as u8).collect();
        let prompts = vec![p0, p1];
        let max_new = 6;

        let plain = Accum::new(2, &[]);
        let want = greedy_decode(&plain, &prompts, max_new, 255, None).unwrap();
        let plain_steps = plain.steps.load(Ordering::Relaxed);

        let chunked = Accum::new(2, &[4, 16]);
        let got = greedy_decode(&chunked, &prompts, max_new, 255, None).unwrap();
        assert_eq!(got, want, "chunked greedy must be byte-identical");

        // shortest prompt is 9 bytes → 9 coverable iterations → [4, 4] + 1
        let (plan, _rem) = plan_chunks(&[4, 16], 9);
        assert_eq!(chunked.chunks.load(Ordering::Relaxed), plan.len() as u64);
        let covered: usize = plan.iter().sum();
        assert_eq!(
            chunked.steps.load(Ordering::Relaxed),
            plain_steps - covered as u64,
            "every covered iteration replaces one step dispatch"
        );
        assert!(!want[0].is_empty() && !want[1].is_empty(), "mock generated");
    }

    #[test]
    fn chunked_beam_matches_stepwise() {
        let prompt: Vec<u8> = (0..21).map(|i| (i * 5 + 2) as u8).collect();
        let plain = Accum::new(3, &[]);
        let want = beam_search(&plain, &prompt, 3, 7, 255, None).unwrap();
        let chunked = Accum::new(3, &[4, 16]);
        let got = beam_search(&chunked, &prompt, 3, 7, 255, None).unwrap();
        assert_eq!(got, want, "chunked beam must be byte-identical");
        // stream = BOS + prompt = 22 → [16, 4] chunks + 2 stepwise prefill
        let (plan, rem) = plan_chunks(&[4, 16], prompt.len() + 1);
        assert_eq!(chunked.chunks.load(Ordering::Relaxed), plan.len() as u64);
        let covered: usize = plan.iter().sum();
        assert_eq!(
            plain.steps.load(Ordering::Relaxed)
                - chunked.steps.load(Ordering::Relaxed),
            covered as u64
        );
        assert_eq!(rem, 2);
    }

    #[test]
    fn chunk_exact_cover_uses_chunk_logits_for_beam() {
        // stream length exactly chunk-coverable: the first-expansion
        // logits come from the final chunk, zero stepwise prefill steps
        let prompt: Vec<u8> = (0..7).map(|i| (i * 3 + 1) as u8).collect();
        let plain = Accum::new(2, &[]);
        let want = beam_search(&plain, &prompt, 2, 5, 255, None).unwrap();
        let chunked = Accum::new(2, &[4]);
        let got = beam_search(&chunked, &prompt, 2, 5, 255, None).unwrap();
        assert_eq!(got, want);
        assert_eq!(chunked.chunks.load(Ordering::Relaxed), 2, "8 = 4 + 4");
        // prefill did zero step dispatches: all remaining steps generate
        assert_eq!(
            plain.steps.load(Ordering::Relaxed)
                - chunked.steps.load(Ordering::Relaxed),
            8
        );
    }

    #[test]
    fn short_prompt_skips_chunking() {
        let chunked = Accum::new(2, &[16]);
        let plain = Accum::new(2, &[]);
        let prompts = vec![vec![5u8, 6, 7]];
        let want = greedy_decode(&plain, &prompts, 4, 255, None).unwrap();
        let got = greedy_decode(&chunked, &prompts, 4, 255, None).unwrap();
        assert_eq!(got, want);
        assert_eq!(chunked.chunks.load(Ordering::Relaxed), 0);
        assert_eq!(
            chunked.steps.load(Ordering::Relaxed),
            plain.steps.load(Ordering::Relaxed)
        );
    }

    #[test]
    fn splice_and_broadcast_rows() {
        let d = StateDims { n_layer: 1, d_conv: 2, d_inner: 1, d_state: 1 };
        let b = 3;
        let mut src = DecodeState::new(d, b, None);
        {
            let (conv, ssm) = src.host_mut().unwrap();
            conv.data.copy_from_slice(&[1.0, 2.0, 3.0]);
            ssm.data.copy_from_slice(&[4.0, 5.0, 6.0]);
        }
        let mut dst = DecodeState::new(d, b, None);
        dst.splice_row_from(&d, b, &mut src, 1, 2).unwrap();
        {
            let (conv, ssm) = dst.host().unwrap();
            assert_eq!(conv.data, vec![0.0, 0.0, 2.0]);
            assert_eq!(ssm.data, vec![0.0, 0.0, 5.0]);
        }
        src.broadcast_row(&d, b, 0).unwrap();
        let (conv, ssm) = src.host().unwrap();
        assert_eq!(conv.data, vec![1.0, 1.0, 1.0]);
        assert_eq!(ssm.data, vec![4.0, 4.0, 4.0]);
    }

    #[test]
    fn checkpoint_rollback_restores_state() {
        let d = StateDims { n_layer: 1, d_conv: 2, d_inner: 1, d_state: 1 };
        let b = 2;
        let mut st = DecodeState::new(d, b, None);
        {
            let (conv, ssm) = st.host_mut().unwrap();
            conv.data.copy_from_slice(&[1.0, 2.0]);
            ssm.data.copy_from_slice(&[3.0, 4.0]);
        }
        let ck = st.checkpoint().unwrap();
        {
            let (conv, ssm) = st.host_mut().unwrap();
            conv.data.copy_from_slice(&[9.0, 9.0]);
            ssm.data.copy_from_slice(&[9.0, 9.0]);
        }
        st.rollback(&ck).unwrap();
        let (conv, ssm) = st.host().unwrap();
        assert_eq!(conv.data, vec![1.0, 2.0]);
        assert_eq!(ssm.data, vec![3.0, 4.0]);
    }

    #[test]
    fn rollback_rejects_mismatched_geometry() {
        let d = StateDims { n_layer: 1, d_conv: 2, d_inner: 1, d_state: 1 };
        let ck = DecodeState::new(d, 2, None).checkpoint().unwrap();
        let mut other = DecodeState::new(d, 3, None);
        assert!(other.rollback(&ck).is_err());
    }

    #[test]
    fn checkpointed_steps_stay_byte_identical() {
        // a checkpoint between steps must not perturb the decode stream
        let toks = crate::tensor::IntTensor::from_vec(&[2], vec![3, 7]);
        let model = Accum::new(2, &[]);
        let mut state = model.new_state(None);
        let mut logits_plain = Vec::new();
        for _ in 0..4 {
            logits_plain.push(model.step(&toks, &mut state).unwrap().data.clone());
        }
        let model2 = Accum::new(2, &[]);
        let mut state2 = model2.new_state(None);
        let mut logits_ck = Vec::new();
        for _ in 0..4 {
            let _ck = state2.checkpoint().unwrap();
            logits_ck.push(model2.step(&toks, &mut state2).unwrap().data.clone());
        }
        assert_eq!(logits_plain, logits_ck, "checkpointing changed the stream");
    }

    #[test]
    fn log_softmax_normalizes() {
        let lp = log_softmax(&[1.0, 2.0, 3.0]);
        let total: f64 = lp.iter().map(|x| x.exp()).sum();
        assert!((total - 1.0).abs() < 1e-9);
        assert!(lp[2] > lp[0]);
    }

    #[test]
    fn greedy_counts_up_and_stops() {
        let m = Counter::new(2);
        let outs =
            greedy_decode(&m, &[vec![10u8], vec![40u8, 41u8]], 8, 44, None).unwrap();
        // row 0: 11,12,... capped by max_new; row 1: 42,43 then 44 = stop
        assert_eq!(outs[0], vec![11, 12, 13, 14, 15, 16, 17, 18]);
        assert_eq!(outs[1], vec![42, 43]);
    }

    #[test]
    fn beam_agrees_with_greedy_on_deterministic_model() {
        let m = Counter::new(3);
        let beam = beam_search(&m, &[10u8], 3, 6, 15, None).unwrap();
        let greedy = greedy_decode(&m, &[vec![10u8]], 6, 15, None).unwrap();
        assert_eq!(beam, greedy[0]);
        assert_eq!(beam, vec![11, 12, 13, 14]); // 15 is the stop byte
    }

    #[test]
    fn beam_finished_score_is_frozen() {
        // stop byte is the immediate argmax: the best beam finishes on the
        // first expansion and must survive later rounds unchanged
        let m = Counter::new(2);
        let beam = beam_search(&m, &[20u8], 2, 8, 21, None).unwrap();
        assert_eq!(beam, Vec::<u8>::new(), "argmax hits stop immediately");
    }

    #[test]
    fn beam_zero_budget_generates_nothing() {
        let m = Counter::new(2);
        let beam = beam_search(&m, &[10u8], 2, 0, 0, None).unwrap();
        assert_eq!(beam, Vec::<u8>::new());
        // and no decode work happened at all
        assert_eq!(m.steps.load(std::sync::atomic::Ordering::Relaxed), 0);
    }

    #[test]
    fn decode_state_residency_roundtrip() {
        // install literals as a step output would, then check the host
        // mirror lazily syncs and host_mut invalidates residency
        let d = StateDims { n_layer: 1, d_conv: 2, d_inner: 2, d_state: 1 };
        let mut st = DecodeState::new(d, 1, None);
        {
            let (c, s) = st.exec_literals().unwrap();
            // freshly-serialized host state: all zeros
            assert_eq!(crate::runtime::tensor_from_literal(c).unwrap().data, vec![0.0, 0.0]);
            assert_eq!(crate::runtime::tensor_from_literal(s).unwrap().data, vec![0.0, 0.0]);
        }
        let pair = crate::runtime::StatePair {
            conv: crate::runtime::literal_f32(
                &Tensor::from_vec(&[1, 1, 1, 2], vec![1.0, 2.0])).unwrap(),
            ssm: crate::runtime::literal_f32(
                &Tensor::from_vec(&[1, 1, 2, 1], vec![3.0, 4.0])).unwrap(),
        };
        st.install(pair);
        // host mirror syncs on demand from the installed literals
        let (c, s) = st.host().unwrap();
        assert_eq!(c.data, vec![1.0, 2.0]);
        assert_eq!(s.data, vec![3.0, 4.0]);
        // mutate a row: residency drops, next exec re-serializes the edit
        st.reset_row(&d, 1, 0, None).unwrap();
        let (c, _s) = st.exec_literals().unwrap();
        assert_eq!(crate::runtime::tensor_from_literal(c).unwrap().data, vec![0.0, 0.0]);
    }

    #[test]
    fn state_dims_reset_and_copy_row() {
        let d = StateDims { n_layer: 2, d_conv: 3, d_inner: 2, d_state: 2 };
        let b = 2;
        let mut h0 = BTreeMap::new();
        h0.insert("layers.1.h0".to_string(),
                  Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]));
        let (mut conv, mut ssm) = d.init_states(b, Some(&h0));
        // layer 0 zero, layer 1 seeded in every row
        let per = d.ssm_per_row();
        assert!(ssm.data[..per * b].iter().all(|&x| x == 0.0));
        assert_eq!(&ssm.data[per * b..per * b + per], &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(&ssm.data[per * b + per..per * b + 2 * per], &[1.0, 2.0, 3.0, 4.0]);
        // dirty row 0, then reset it without h0: back to zeros
        ssm.data[0] = 9.0;
        conv.data[0] = 9.0;
        d.reset_row(Some(&mut conv), Some(&mut ssm), b, 0, None);
        assert_eq!(ssm.data[0], 0.0);
        assert_eq!(conv.data[0], 0.0);
        // copying row 1 → row 0 from a pristine source pair restores the
        // layer-1 seed in the destination's row 0
        let (src_conv, src_ssm) = d.init_states(b, Some(&h0));
        d.copy_row(&src_conv, &src_ssm, &mut conv, &mut ssm, b, 1, 0);
        assert_eq!(&ssm.data[per * b..per * b + per], &[1.0, 2.0, 3.0, 4.0]);
    }

    fn bits(xs: &[f32]) -> Vec<u32> {
        xs.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn unmerged_mixed_rows_match_solo_models() {
        use super::testing::{mock_delta, AccumAdapters};
        // one batch mixing three "adapters" (off 5 / base / off 9): every
        // row must be byte-identical, step for step, to a dedicated
        // single-row merged model with that adapter baked in
        let b = 3;
        let m = AccumAdapters::new(b);
        let rows: Vec<AdapterRow> =
            vec![Some(mock_delta(5.0)), None, Some(mock_delta(9.0))];
        let solos = [
            Accum::with_off(1, &[], 5.0),
            Accum::with_off(1, &[], 0.0),
            Accum::with_off(1, &[], 9.0),
        ];
        let mut state = m.new_state(None);
        let mut solo_states: Vec<DecodeState> =
            solos.iter().map(|s| s.new_state(None)).collect();
        let mut toks = vec![7i32, 11, 13];
        for step in 0..6 {
            let t = IntTensor::from_vec(&[b], toks.clone());
            let lg = m.step_rows(&t, &mut state, &rows).unwrap();
            let v = lg.shape[1];
            for r in 0..b {
                let t1 = IntTensor::from_vec(&[1], vec![toks[r]]);
                let sl = solos[r].step(&t1, &mut solo_states[r]).unwrap();
                assert_eq!(bits(&lg.data[r * v..(r + 1) * v]), bits(&sl.data),
                           "row {r} diverged at step {step}");
                toks[r] = argmax(&lg.data[r * v..r * v + 256]) as i32;
            }
        }
        // one batched dispatch per step, regardless of the adapter mix
        assert_eq!(m.steps.load(Ordering::Relaxed), 6);
        // and a wrong-width row assignment is rejected
        let t = IntTensor::from_vec(&[b], toks);
        assert!(m.step_rows(&t, &mut state, &rows[..2]).is_err());
    }

    #[test]
    fn pinned_adapter_greedy_matches_merged() {
        use super::testing::{mock_delta, AccumAdapters};
        let shared: Arc<dyn AdapterStepDecode> = Arc::new(AccumAdapters::new(2));
        let pinned = PinnedAdapter::new(shared, Some(mock_delta(4.0)));
        let merged = Accum::with_off(2, &[], 4.0);
        let prompts = vec![vec![9u8, 8, 7], vec![1u8, 2]];
        let want = greedy_decode(&merged, &prompts, 6, 255, None).unwrap();
        let got = greedy_decode(&pinned, &prompts, 6, 255, None).unwrap();
        assert_eq!(got, want, "pinned shared core must match a merged core");
        // pinning the base (None) matches the plain off-0 model too
        let shared: Arc<dyn AdapterStepDecode> = Arc::new(AccumAdapters::new(2));
        let base = PinnedAdapter::new(shared, None);
        let plain = Accum::new(2, &[]);
        assert_eq!(greedy_decode(&base, &prompts, 6, 255, None).unwrap(),
                   greedy_decode(&plain, &prompts, 6, 255, None).unwrap());
    }

    #[test]
    fn unmerged_random_churn_stays_row_equivalent() {
        use super::testing::{mock_delta, AccumAdapters};
        // randomized property: random adapter per row, mid-stream
        // retirement/admission (row reset + new adapter), per-row logits
        // bitwise-equal to lockstep single-adapter merged models
        let b = 4;
        let m = AccumAdapters::new(b);
        let offs = [2.0f32, 3.0, 5.0, 7.0];
        let mut rng = crate::tensor::Rng::new(42);
        let pick = |rng: &mut crate::tensor::Rng| -> AdapterRow {
            let i = (rng.uniform() * 5.0) as usize;
            (i < offs.len()).then(|| mock_delta(offs[i]))
        };
        let solo = |row: &AdapterRow| {
            let off = row.as_ref().map_or(0.0, |d| d.sparse[0].val[0]);
            Accum::with_off(1, &[], off)
        };
        let dims = m.dims();
        let mut rows: Vec<AdapterRow> = (0..b).map(|_| pick(&mut rng)).collect();
        let mut state = m.new_state(None);
        let mut solos: Vec<Accum> = rows.iter().map(solo).collect();
        let mut solo_states: Vec<DecodeState> =
            solos.iter().map(|s| s.new_state(None)).collect();
        let mut toks: Vec<i32> = (0..b as i32).map(|r| r * 37 % 256).collect();
        let mut churned = 0usize;
        for step in 0..48 {
            for r in 0..b {
                if rng.uniform() < 0.2 {
                    churned += 1;
                    rows[r] = pick(&mut rng);
                    state.reset_row(&dims, b, r, None).unwrap();
                    solos[r] = solo(&rows[r]);
                    solo_states[r] = solos[r].new_state(None);
                    toks[r] = (rng.uniform() * 256.0) as i32 & 255;
                }
            }
            let t = IntTensor::from_vec(&[b], toks.clone());
            let lg = m.step_rows(&t, &mut state, &rows).unwrap();
            let v = lg.shape[1];
            for r in 0..b {
                let t1 = IntTensor::from_vec(&[1], vec![toks[r]]);
                let sl = solos[r].step(&t1, &mut solo_states[r]).unwrap();
                assert_eq!(bits(&lg.data[r * v..(r + 1) * v]), bits(&sl.data),
                           "row {r} diverged at step {step}");
                toks[r] = argmax(&lg.data[r * v..r * v + 256]) as i32;
            }
        }
        assert!(churned >= 10, "churn probability too low to exercise resets");
    }

    #[test]
    fn adapter_delta_apply_reproduces_merged_map_bitwise() {
        let mut base = BTreeMap::new();
        base.insert("w".to_string(),
                    Tensor::from_vec(&[2, 2], vec![0.1, 0.2, 0.3, 0.4]));
        base.insert("v".to_string(),
                    Tensor::from_vec(&[4], vec![1.0, 2.0, 3.0, 4.0]));
        let meta = PeftMeta {
            method: crate::suite::PeftMethod::SdtLora,
            rank: 1,
            alpha: 3,
            targets: vec!["w".to_string()],
            n_tokens: 0,
        };
        let delta = AdapterDelta {
            meta: meta.clone(),
            lora: vec![LoraOp {
                target: "w".to_string(),
                a: Tensor::from_vec(&[2, 1], vec![0.5, -0.25]),
                b: Tensor::from_vec(&[1, 2], vec![0.125, 8.0]),
            }],
            sparse: vec![SparseOffset {
                param: "v".to_string(),
                idx: vec![1, 3],
                val: vec![0.3, -0.7],
            }],
            h0: BTreeMap::from([("layers.0.h0".to_string(),
                                 Tensor::from_vec(&[1], vec![2.5]))]),
        };
        let got = delta.apply(&base).unwrap();

        // reference: the merged-registry construction (raw map containing
        // trained values + lora leaves, then the same merge_lora)
        let mut want = base.clone();
        want.get_mut("v").unwrap().data[1] = 0.3;
        want.get_mut("v").unwrap().data[3] = -0.7;
        want.insert("w.lora_a".to_string(),
                    Tensor::from_vec(&[2, 1], vec![0.5, -0.25]));
        want.insert("w.lora_b".to_string(),
                    Tensor::from_vec(&[1, 2], vec![0.125, 8.0]));
        crate::peft::merge_lora(&mut want, &meta);
        want.insert("layers.0.h0".to_string(), Tensor::from_vec(&[1], vec![2.5]));

        assert_eq!(got.keys().collect::<Vec<_>>(), want.keys().collect::<Vec<_>>());
        for (k, t) in &want {
            assert_eq!(bits(&got[k].data), bits(&t.data),
                       "param {k} must match bit-for-bit");
        }
        // replacement semantics: the trained value lands exactly, no
        // base + (trained − base) rounding
        assert_eq!(got["v"].data[1].to_bits(), 0.3f32.to_bits());
        // the lora merge really happened (scale = alpha/rank = 3)
        assert_ne!(got["w"].data[0].to_bits(), 0.1f32.to_bits());
        // out-of-bounds sparse index is rejected, not wrapped
        let bad = AdapterDelta {
            meta,
            lora: Vec::new(),
            sparse: vec![SparseOffset {
                param: "v".to_string(),
                idx: vec![9],
                val: vec![0.0],
            }],
            h0: BTreeMap::new(),
        };
        assert!(bad.apply(&base).is_err());
    }

    #[test]
    fn adapter_delta_resident_bytes_are_delta_sized() {
        let meta = PeftMeta {
            method: crate::suite::PeftMethod::SdtLora,
            rank: 8,
            alpha: 8,
            targets: Vec::new(),
            n_tokens: 0,
        };
        let d = AdapterDelta {
            meta,
            lora: vec![LoraOp {
                target: "w".to_string(),
                a: Tensor::zeros(&[64, 8]),
                b: Tensor::zeros(&[8, 64]),
            }],
            sparse: vec![SparseOffset {
                param: "p".to_string(),
                idx: vec![0; 16],
                val: vec![0.0; 16],
            }],
            h0: BTreeMap::from([("layers.0.h0".to_string(),
                                 Tensor::zeros(&[32]))]),
        };
        let expect = (64 * 8 + 8 * 64 + 16 + 32) * 4
            + 16 * std::mem::size_of::<usize>();
        assert_eq!(d.resident_bytes(), expect);
        // a single full copy of one 64×4096 base weight alone dwarfs the
        // whole delta — the registry accounting must scale with KBs
        assert!(d.resident_bytes() * 10 < 64 * 4096 * 4);
    }
}
