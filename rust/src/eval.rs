//! Evaluation + generation core: classification scoring via the `fwd`
//! artifact and autoregressive generation via the stepwise `decode`
//! artifact, with the Mamba recurrent state held in Rust buffers.
//!
//! The generation core is split in two layers so the offline suite and the
//! online server ([`crate::serve`]) share one implementation:
//!
//! - [`StepDecode`] — the minimal stepwise-decode interface: batch width,
//!   state geometry ([`StateDims`]), and one `(tokens, state) → logits`
//!   step that advances a [`DecodeState`] in place. Implemented by
//!   [`DecodeCore`] over the real XLA executable, and by mock models in
//!   scheduler unit tests.
//! - [`greedy_decode`] / [`beam_search`] — decoding strategies written
//!   against `dyn StepDecode`. [`Generator`] is the thin offline wrapper
//!   (build a core from merged params, then greedy/beam over a split);
//!   [`crate::serve::Scheduler`] drives the same trait online, packing
//!   many independent requests into the batch dimension.
//!
//! Hot-path residency (§Perf L4, rust/docs/performance.md): a
//! [`DecodeState`] keeps the recurrent `(conv, ssm)` state as the
//! *literals* the previous step produced, feeding them back as the next
//! step's inputs with no Tensor round-trip; [`DecodeCore`] serializes its
//! parameter literals once at construction instead of once per token. The
//! host mirror is materialized lazily, only when a caller actually touches
//! rows (scheduler admission, beam re-parenting).
//!
//! Chunked prefill (§Perf L5): prompt ingestion is sequence-level, not
//! token-level. The [`ChunkPrefill`] trait exposes the `prefill` artifacts
//! (one `(B, C)`-token scan per dispatch); [`plan_chunks`] covers a prompt
//! with the largest-fitting chunks, and [`chunk_prefill_cover`] executes
//! the plan while the state stays literal-resident across chunk→chunk and
//! chunk→decode transitions. [`greedy_decode`] and [`beam_search`] route
//! prompts through it automatically when the model advertises support;
//! beam search prefills ONE row and broadcasts its state
//! ([`DecodeState::broadcast_row`]) instead of scanning the same prompt
//! across every row.

use std::collections::BTreeMap;

use crate::error::{Context, Result};

use crate::data::minidb::exec_match;
use crate::xla;
use crate::data::tasks::spider_table;
use crate::data::words_to_ids;
use crate::data::{make_batch, Dataset, Example, BOS, PAD};
use crate::manifest::{Manifest, Variant};
use crate::metrics;
use crate::runtime::{Engine, Executable};
use crate::suite::Metric;
use crate::tensor::{argmax, IntTensor, Tensor};
use crate::train::Trainer;

/// Classification accuracy/metric over a split using the fwd artifact:
/// logits at the label position, restricted to the task's label bytes.
pub fn eval_classification(trainer: &Trainer, split: &[Example], metric: Metric) -> Result<f64> {
    let b = trainer.variant.batch_b;
    let l = trainer.variant.batch_l;
    let mut preds = Vec::new();
    let mut golds = Vec::new();
    let mut i = 0;
    while i < split.len() {
        let end = (i + b).min(split.len());
        let mut refs: Vec<&Example> = split[i..end].iter().collect();
        while refs.len() < b {
            refs.push(&split[0]); // pad batch; extra rows ignored below
        }
        let batch = make_batch(&refs, b, l);
        let logits = trainer.logits(&batch)?; // (B, L, V)
        let v = logits.shape[2];
        for (r, ex) in split[i..end].iter().enumerate() {
            let pos = batch.label_pos[r];
            let row = &logits.data[(r * l + pos) * v..(r * l + pos + 1) * v];
            let scores: Vec<f32> =
                ex.label_bytes.iter().map(|&bb| row[bb as usize]).collect();
            // generation-style examples carry no class label; skip them
            // rather than panic if one leaks into a classification split
            let Some(gold) = ex.label else { continue };
            preds.push(argmax(&scores));
            golds.push(gold);
        }
        i = end;
    }
    Ok(match metric {
        Metric::Matthews => metrics::matthews_corr(&preds, &golds),
        _ => metrics::accuracy(&preds, &golds),
    })
}

/// Regression MSE over generated (x, y) pairs (Fig. 2 synthetic setting).
pub fn eval_regression(trainer: &Trainer, xs: &[Tensor], ys: &[Tensor]) -> Result<f64> {
    let mut total = 0.0;
    let mut n = 0usize;
    for (x, y) in xs.iter().zip(ys) {
        let pred = trainer.forward_reg(x)?;
        total += metrics::mse(&pred.data, &y.data) * pred.numel() as f64;
        n += pred.numel();
    }
    Ok(total / n.max(1) as f64)
}

/// Recurrent-state geometry of a stepwise decode model: everything needed
/// to allocate, seed (initial-state tuning h0), and per-row reset the conv
/// and SSM state tensors.
///
/// State layout matches the decode artifact contract (python aot.py):
/// conv state `(n_layer, B, d_conv-1, d_inner)`, SSM state
/// `(n_layer, B, d_inner, d_state)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StateDims {
    /// Number of SSM layers.
    pub n_layer: usize,
    /// Conv kernel width (state holds `d_conv - 1` positions).
    pub d_conv: usize,
    /// Inner (expanded) channel count.
    pub d_inner: usize,
    /// SSM state dimension per channel.
    pub d_state: usize,
}

impl StateDims {
    /// Read the geometry off a manifest variant.
    pub fn of(v: &Variant) -> StateDims {
        StateDims {
            n_layer: v.arch.n_layer,
            d_conv: v.arch.d_conv,
            d_inner: v.arch.d_inner,
            d_state: v.arch.d_state,
        }
    }

    /// Floats per (layer, row) in the conv state tensor.
    pub fn conv_per_row(&self) -> usize {
        (self.d_conv - 1) * self.d_inner
    }

    /// Floats per (layer, row) in the SSM state tensor.
    pub fn ssm_per_row(&self) -> usize {
        self.d_inner * self.d_state
    }

    /// Fresh `(conv, ssm)` state for a batch of `b` rows. When `h0`
    /// contains trained `layers.{i}.h0` tensors (initial-state tuning),
    /// every row's SSM state is seeded with them.
    pub fn init_states(&self, b: usize, h0: Option<&BTreeMap<String, Tensor>>)
        -> (Tensor, Tensor) {
        let conv = Tensor::zeros(&[self.n_layer, b, self.d_conv - 1, self.d_inner]);
        let mut ssm = Tensor::zeros(&[self.n_layer, b, self.d_inner, self.d_state]);
        if h0.is_some() {
            for r in 0..b {
                self.reset_row(None, Some(&mut ssm), b, r, h0);
            }
        }
        (conv, ssm)
    }

    /// Reset one batch row's state in place: conv to zeros, SSM to the
    /// adapter's h0 (or zeros). Used by the serving scheduler when a slot
    /// is recycled for a newly admitted request mid-stream.
    pub fn reset_row(&self, conv: Option<&mut Tensor>, ssm: Option<&mut Tensor>,
                     b: usize, row: usize, h0: Option<&BTreeMap<String, Tensor>>) {
        if let Some(conv) = conv {
            let per = self.conv_per_row();
            for layer in 0..self.n_layer {
                let at = (layer * b + row) * per;
                conv.data[at..at + per].fill(0.0);
            }
        }
        if let Some(ssm) = ssm {
            let per = self.ssm_per_row();
            for layer in 0..self.n_layer {
                let at = (layer * b + row) * per;
                let seed = h0.and_then(|m| m.get(&format!("layers.{layer}.h0")));
                match seed {
                    Some(h) => ssm.data[at..at + per].copy_from_slice(&h.data),
                    None => ssm.data[at..at + per].fill(0.0),
                }
            }
        }
    }

    /// Copy row `from` of a source `(conv, ssm)` pair into row `to` of a
    /// destination pair (all layers) — beam search re-parents surviving
    /// beams this way each step, reading the step output and writing the
    /// next state.
    pub fn copy_row(&self, src_conv: &Tensor, src_ssm: &Tensor,
                    dst_conv: &mut Tensor, dst_ssm: &mut Tensor, b: usize,
                    from: usize, to: usize) {
        let cper = self.conv_per_row();
        let sper = self.ssm_per_row();
        for layer in 0..self.n_layer {
            let cfrom = (layer * b + from) * cper;
            let cto = (layer * b + to) * cper;
            dst_conv.data[cto..cto + cper]
                .copy_from_slice(&src_conv.data[cfrom..cfrom + cper]);
            let sfrom = (layer * b + from) * sper;
            let sto = (layer * b + to) * sper;
            dst_ssm.data[sto..sto + sper]
                .copy_from_slice(&src_ssm.data[sfrom..sfrom + sper]);
        }
    }
}

/// The recurrent decode state of one batched stream: a host `(conv, ssm)`
/// mirror plus, when the model runs on XLA, the *literals* the previous
/// step produced ([`crate::runtime::StatePair`]).
///
/// On the steady-state decode path the state stays literal-resident: step
/// outputs feed straight back as the next step's inputs and the host
/// mirror is never materialized. Callers that need to touch rows
/// (scheduler admission, beam re-parenting, h0 seeding) go through
/// [`DecodeState::host_mut`], which lazily syncs the mirror and marks the
/// literals stale so the next step re-serializes — the cost is paid only
/// when rows actually change (§Perf L4).
pub struct DecodeState {
    conv: Tensor,
    ssm: Tensor,
    resident: Option<crate::runtime::StatePair>,
    host_fresh: bool,
}

impl DecodeState {
    /// Fresh state for `b` rows; `h0` seeds every row's SSM state
    /// (initial-state tuning).
    pub fn new(dims: StateDims, b: usize, h0: Option<&BTreeMap<String, Tensor>>)
        -> DecodeState {
        let (conv, ssm) = dims.init_states(b, h0);
        DecodeState { conv, ssm, resident: None, host_fresh: true }
    }

    fn sync_host(&mut self) -> Result<()> {
        if self.host_fresh {
            return Ok(());
        }
        let pair = self
            .resident
            .as_ref()
            .context("decode-state invariant: stale host mirror without resident literals")?;
        crate::runtime::read_f32_into(&pair.conv, &mut self.conv.data)?;
        crate::runtime::read_f32_into(&pair.ssm, &mut self.ssm.data)?;
        self.host_fresh = true;
        Ok(())
    }

    /// Read access to the host `(conv, ssm)` mirror (synced on demand; the
    /// resident literals stay valid).
    pub fn host(&mut self) -> Result<(&Tensor, &Tensor)> {
        self.sync_host()?;
        Ok((&self.conv, &self.ssm))
    }

    /// Mutable access to the host mirror. Syncs on demand and invalidates
    /// the resident literals — the next step serializes from host. Pay
    /// this only when a row genuinely changes.
    pub fn host_mut(&mut self) -> Result<(&mut Tensor, &mut Tensor)> {
        self.sync_host()?;
        self.resident = None;
        Ok((&mut self.conv, &mut self.ssm))
    }

    /// Reset one row (conv to zeros, SSM to `h0` or zeros) — scheduler
    /// slot recycling. See [`StateDims::reset_row`].
    pub fn reset_row(&mut self, dims: &StateDims, b: usize, row: usize,
                     h0: Option<&BTreeMap<String, Tensor>>) -> Result<()> {
        let (conv, ssm) = self.host_mut()?;
        dims.reset_row(Some(conv), Some(ssm), b, row, h0);
        Ok(())
    }

    /// Copy row `from` of another state into row `to` of this one (all
    /// layers) — the serve scheduler splices a finished out-of-band
    /// prefill row into the lane's live state this way. Syncs `src`'s host
    /// mirror (its residency stays valid) and invalidates this state's.
    pub fn splice_row_from(&mut self, dims: &StateDims, b: usize,
                           src: &mut DecodeState, from: usize, to: usize)
        -> Result<()> {
        src.sync_host()?;
        let (conv, ssm) = self.host_mut()?;
        dims.copy_row(&src.conv, &src.ssm, conv, ssm, b, from, to);
        Ok(())
    }

    /// Copy row `from` into every other row — beam search prefills one row
    /// and broadcasts its state before the beams diverge.
    pub fn broadcast_row(&mut self, dims: &StateDims, b: usize, from: usize)
        -> Result<()> {
        let (src_conv, src_ssm) = {
            let (c, s) = self.host()?;
            (c.clone(), s.clone())
        };
        let (conv, ssm) = self.host_mut()?;
        for to in 0..b {
            if to != from {
                dims.copy_row(&src_conv, &src_ssm, conv, ssm, b, from, to);
            }
        }
        Ok(())
    }

    /// Literals for the next execute: the previous step's outputs when
    /// resident, else a fresh serialization of the host mirror (cached, so
    /// repeated calls don't re-serialize).
    pub(crate) fn exec_literals(&mut self)
        -> Result<(&xla::Literal, &xla::Literal)> {
        if self.resident.is_none() {
            debug_assert!(self.host_fresh, "no resident state and stale host");
            self.resident = Some(crate::runtime::StatePair {
                conv: crate::runtime::literal_f32(&self.conv)?,
                ssm: crate::runtime::literal_f32(&self.ssm)?,
            });
        }
        let pair = self
            .resident
            .as_ref()
            .context("decode-state invariant: resident literals just installed")?;
        Ok((&pair.conv, &pair.ssm))
    }

    /// Adopt a step's output literals as the new state (host mirror goes
    /// stale until someone asks for it).
    pub(crate) fn install(&mut self, pair: crate::runtime::StatePair) {
        self.resident = Some(pair);
        self.host_fresh = false;
    }
}

/// The stepwise decode interface shared by offline eval ([`Generator`]) and
/// the online serving scheduler ([`crate::serve::Scheduler`]).
///
/// One call advances every batch row by one token: rows are fully
/// independent (each carries its own O(1) recurrent state), which is what
/// makes continuous batching possible — the scheduler can retire a finished
/// row and admit a fresh request into it between any two steps.
pub trait StepDecode {
    /// Fixed batch width of the compiled decode executable.
    fn arch_b(&self) -> usize;

    /// Recurrent-state geometry (for allocating/seeding/resetting rows).
    fn dims(&self) -> StateDims;

    /// Fresh state for this model's geometry (`h0` = initial-state tuning
    /// seed applied to every row).
    fn new_state(&self, h0: Option<&BTreeMap<String, Tensor>>) -> DecodeState {
        DecodeState::new(self.dims(), self.arch_b(), h0)
    }

    /// Advance one token: `tokens (B,)` → `logits (B, V)`, advancing
    /// `state` in place. `V ≥ 256`; generation samples from the byte
    /// sub-vocabulary `[..256]`.
    fn step(&self, tokens: &IntTensor, state: &mut DecodeState) -> Result<Tensor>;

    /// Sequence-level prefill support, when the model has it (§Perf L5).
    /// `None` (the default) means prompts are ingested token-by-token.
    fn chunk_prefill(&self) -> Option<&dyn ChunkPrefill> {
        None
    }
}

/// Sequence-level prompt ingestion: one dispatch scans a whole `(B, C)`
/// token chunk through the recurrence, advancing the [`DecodeState`]
/// exactly as `C` calls of [`StepDecode::step`] would (§Perf L5).
///
/// Implemented by [`DecodeCore`] over the compiled `prefill` artifacts and
/// by mock models in tests. Only the last position's logits come back —
/// prefill consumes prompts, it does not generate.
pub trait ChunkPrefill {
    /// Supported chunk widths, ascending and non-empty.
    fn chunk_widths(&self) -> &[usize];

    /// Scan `tokens (B, C)` (`C` must be a supported width), advancing
    /// `state` in place; returns the last position's `logits (B, V)`.
    fn prefill_chunk(&self, tokens: &IntTensor, state: &mut DecodeState)
        -> Result<Tensor>;
}

/// Cover `n` prefill iterations with the largest-fitting chunks: the
/// dispatch plan (widths, in order) plus the step-wise remainder. Greedy
/// largest-first is optimal for the exported width ladder (each width
/// divides the next).
pub fn plan_chunks(widths: &[usize], n: usize) -> (Vec<usize>, usize) {
    let mut plan = Vec::new();
    let mut rem = n;
    while let Some(&w) = widths.iter().rev().find(|&&w| w <= rem) {
        plan.push(w);
        rem -= w;
    }
    (plan, rem)
}

/// Execute the chunked part of a prefill plan: dispatch largest-fitting
/// chunks until fewer than the smallest width remains of `n`, feeding row
/// `r` the token `tok(r, t)` at stream position `t`. Returns the covered
/// position count and the final chunk's logits (`None` when nothing
/// fit). The state stays literal-resident from chunk to chunk; callers
/// finish the remainder step-wise (or hand it to a decode loop).
pub fn chunk_prefill_cover(pf: &dyn ChunkPrefill, b: usize,
                           state: &mut DecodeState, n: usize,
                           tok: &dyn Fn(usize, usize) -> i32)
    -> Result<(usize, Option<Tensor>)> {
    let (plan, _rem) = plan_chunks(pf.chunk_widths(), n);
    let mut pos = 0usize;
    let mut last = None;
    for w in plan {
        let mut toks = IntTensor::from_vec(&[b, w], vec![PAD; b * w]);
        for r in 0..b {
            for i in 0..w {
                toks.data[r * w + i] = tok(r, pos + i);
            }
        }
        last = Some(pf.prefill_chunk(&toks, state)?);
        pos += w;
    }
    Ok((pos, last))
}

/// A decode-ready model: the compiled stepwise `decode` executable bound to
/// one merged parameter set. This is the unit the adapter registry caches —
/// same executable, different parameters per fine-tuned variant. Parameter
/// literals are serialized ONCE here, not once per token (§Perf L4).
pub struct DecodeCore {
    decode: Executable,
    /// Chunked-prefill executables as `(width, exe)`, ascending width —
    /// empty when the manifest has no `files.prefill` entries (§Perf L5).
    prefill: Vec<(usize, Executable)>,
    /// The widths of `prefill`, cached for [`ChunkPrefill::chunk_widths`].
    widths: Vec<usize>,
    /// Parameters pre-serialized in the decode variant's argument order
    /// (reused every step).
    param_lits: Vec<xla::Literal>,
    /// Host parameter copies — retained ONLY by
    /// [`DecodeCore::new_for_reference`] for the bench baseline; the
    /// serving path keeps a single (literal) copy per cached adapter.
    params: Option<Vec<Tensor>>,
    /// Executable dispatches issued (decode steps + prefill chunks) —
    /// telemetry for `bench hotpath` and the dispatch-count tests.
    dispatches: std::sync::atomic::AtomicU64,
    arch_b: usize,
    dims: StateDims,
}

impl DecodeCore {
    /// Bind the decode executable of `decode_variant` to a merged parameter
    /// map. `params_map` must contain every base parameter of the decode
    /// variant (merge LoRA first: [`crate::peft::merge_lora`]); extra keys
    /// (adapter leaves, `h0`) are ignored.
    pub fn new(engine: &Engine, manifest: &Manifest, decode_variant: &str,
               params_map: &BTreeMap<String, Tensor>) -> Result<Self> {
        Self::build(engine, manifest, decode_variant, params_map, false)
    }

    /// Like [`DecodeCore::new`] but also retains host parameter copies so
    /// [`DecodeCore::step_reference`] can replay the pre-arena per-token
    /// serialization cost. Bench use only.
    pub fn new_for_reference(engine: &Engine, manifest: &Manifest, decode_variant: &str,
                             params_map: &BTreeMap<String, Tensor>) -> Result<Self> {
        Self::build(engine, manifest, decode_variant, params_map, true)
    }

    fn build(engine: &Engine, manifest: &Manifest, decode_variant: &str,
             params_map: &BTreeMap<String, Tensor>, keep_host: bool) -> Result<Self> {
        let v: &Variant = manifest.variant(decode_variant)?;
        let file = v.decode_file.clone()
            .with_context(|| format!("{decode_variant} has no decode artifact"))?;
        let decode = engine.load(manifest.hlo_path(&file))?;
        let mut prefill = Vec::new();
        for (w, f) in &v.prefill_files {
            prefill.push((*w, engine.load(manifest.hlo_path(f))?));
        }
        let widths: Vec<usize> = prefill.iter().map(|&(w, _)| w).collect();
        let mut param_lits = Vec::new();
        let mut params = Vec::new();
        for meta in v.train_params.iter().chain(v.frozen_params.iter()) {
            let t = params_map.get(&meta.name).with_context(|| {
                format!("merged params missing {} for decode", meta.name)
            })?;
            param_lits.push(crate::runtime::literal_f32(t)?);
            if keep_host {
                params.push(t.clone());
            }
        }
        let params = keep_host.then_some(params);
        Ok(DecodeCore {
            decode,
            prefill,
            widths,
            param_lits,
            params,
            dispatches: std::sync::atomic::AtomicU64::new(0),
            arch_b: v.batch_b,
            dims: StateDims::of(v),
        })
    }

    /// Chunk widths of the loaded prefill artifacts (empty = none).
    pub fn prefill_widths(&self) -> &[usize] {
        &self.widths
    }

    /// Executable dispatches issued so far (decode steps + prefill chunks).
    pub fn dispatch_count(&self) -> u64 {
        self.dispatches.load(std::sync::atomic::Ordering::Relaxed)
    }

    /// Reference step that re-serializes every parameter literal and
    /// forces the state through the host (the pre-arena behavior). Kept
    /// ONLY as the `bench hotpath` baseline — never use it to serve.
    /// Errors unless the core was built with
    /// [`DecodeCore::new_for_reference`].
    pub fn step_reference(&self, tokens: &IntTensor, state: &mut DecodeState)
        -> Result<Tensor> {
        state.host_mut()?; // drop residency: state re-serializes from host
        self.step_inner(tokens, state, false)
    }

    fn step_inner(&self, tokens: &IntTensor, state: &mut DecodeState,
                  resident_params: bool) -> Result<Tensor> {
        self.run_exec(&self.decode, tokens, state, resident_params)
    }

    /// Shared execute path for the decode and prefill artifacts: both take
    /// `(params..., tokens, conv, ssm)` and return `(logits, conv', ssm')`,
    /// and both feed the output state literals straight back as the next
    /// dispatch's inputs (§Perf L4/L5).
    fn run_exec(&self, exe: &Executable, tokens: &IntTensor,
                state: &mut DecodeState, resident_params: bool)
        -> Result<Tensor> {
        self.dispatches.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let tok_lit = crate::runtime::literal_i32(tokens)?;
        let fresh: Vec<xla::Literal> = if resident_params {
            Vec::new()
        } else {
            self.params
                .as_ref()
                .context("step_reference needs DecodeCore::new_for_reference")?
                .iter()
                .map(crate::runtime::literal_f32)
                .collect::<Result<Vec<_>>>()?
        };
        let mut outs = {
            let (conv_lit, ssm_lit) = state.exec_literals()?;
            let mut refs: Vec<&xla::Literal> =
                Vec::with_capacity(self.param_lits.len() + 3);
            if resident_params {
                refs.extend(self.param_lits.iter());
            } else {
                refs.extend(fresh.iter());
            }
            refs.push(&tok_lit);
            refs.push(conv_lit);
            refs.push(ssm_lit);
            exe.run_refs_literals(&refs)?
        };
        let ssm_out = outs.pop().context("decode returned no ssm state")?;
        let conv_out = outs.pop().context("decode returned no conv state")?;
        let logits = outs.pop().context("decode returned no logits")?;
        let logits = crate::runtime::tensor_from_literal(&logits)?;
        state.install(crate::runtime::StatePair { conv: conv_out, ssm: ssm_out });
        Ok(logits)
    }
}

impl ChunkPrefill for DecodeCore {
    fn chunk_widths(&self) -> &[usize] {
        &self.widths
    }

    fn prefill_chunk(&self, tokens: &IntTensor, state: &mut DecodeState)
        -> Result<Tensor> {
        let w = *tokens.shape.get(1).context("prefill tokens must be (B, C)")?;
        let exe = self
            .prefill
            .iter()
            .find(|&&(pw, _)| pw == w)
            .map(|(_, e)| e)
            .with_context(|| format!("no prefill artifact for chunk width {w}"))?;
        self.run_exec(exe, tokens, state, true)
    }
}

impl StepDecode for DecodeCore {
    fn arch_b(&self) -> usize {
        self.arch_b
    }

    fn dims(&self) -> StateDims {
        self.dims
    }

    fn step(&self, tokens: &IntTensor, state: &mut DecodeState) -> Result<Tensor> {
        self.step_inner(tokens, state, true)
    }

    fn chunk_prefill(&self) -> Option<&dyn ChunkPrefill> {
        (!self.widths.is_empty()).then_some(self as &dyn ChunkPrefill)
    }
}

/// Batched greedy decoding for up to `arch_b` prompts at once. Rows still
/// in prefill keep consuming their prompt; finished rows emit until
/// `stop_byte` or `max_new`. `h0` seeds the SSM state (initial-state
/// tuning).
///
/// When the model supports [`ChunkPrefill`], the iterations whose logits
/// every row discards (the shortest prompt's prefix) are scanned as
/// chunks instead of one dispatch per token; the remainder and all
/// generation run step-wise, byte-identical to the pure step-wise path.
pub fn greedy_decode(model: &dyn StepDecode, prompts: &[Vec<u8>], max_new: usize,
                     stop_byte: u8, h0: Option<&BTreeMap<String, Tensor>>)
    -> Result<Vec<Vec<u8>>> {
    assert!(prompts.len() <= model.arch_b());
    let b = model.arch_b();
    // greedy never touches rows mid-stream, so the state stays
    // literal-resident for the whole generation (§Perf L4)
    let mut state = model.new_state(h0);
    let max_prompt = prompts.iter().map(Vec::len).max().unwrap_or(0);
    let mut outs: Vec<Vec<u8>> = vec![Vec::new(); prompts.len()];
    let mut done = vec![false; prompts.len()];
    let mut cur = IntTensor::from_vec(&[b], vec![BOS; b]);
    let mut start_t = 0usize;
    if let Some(pf) = model.chunk_prefill() {
        // iteration t consumes stream[t] = [BOS, p[0], p[1], ...][t]; its
        // logits are used only once t reaches a row's prompt length, so
        // the first min-prompt-len iterations are pure ingestion and can
        // be covered by chunks (§Perf L5)
        let m = prompts.iter().map(Vec::len).min().unwrap_or(0);
        let stream = |r: usize, t: usize| -> i32 {
            if r >= prompts.len() {
                PAD
            } else if t == 0 {
                BOS
            } else {
                prompts[r][t - 1] as i32
            }
        };
        let (covered, _) = chunk_prefill_cover(pf, b, &mut state, m, &stream)?;
        if covered > 0 {
            start_t = covered;
            for r in 0..b {
                cur.data[r] = stream(r, covered);
            }
        }
    }
    for t in start_t..max_prompt + max_new {
        let logits = model.step(&cur, &mut state)?;
        let v = logits.shape[1];
        for r in 0..prompts.len() {
            let next: i32 = if t < prompts[r].len() {
                prompts[r][t] as i32 // still prefilling
            } else if done[r] || outs[r].len() >= max_new {
                PAD
            } else {
                let row = &logits.data[r * v..(r + 1) * v];
                // generate over byte vocabulary only (no BOS/PAD)
                let tok = argmax(&row[..256]) as u8;
                if tok == stop_byte {
                    done[r] = true;
                    PAD
                } else {
                    outs[r].push(tok);
                    tok as i32
                }
            };
            cur.data[r] = next;
        }
        for r in prompts.len()..b {
            cur.data[r] = PAD;
        }
        if (0..prompts.len()).all(|r| t >= prompts[r].len()
            && (done[r] || outs[r].len() >= max_new)) {
            break;
        }
    }
    Ok(outs)
}

#[derive(Clone)]
struct Beam {
    toks: Vec<u8>,
    score: f64,
    done: bool,
}

impl Beam {
    /// Generated-token count for length normalization. The stop byte is
    /// not in `toks` but its log-prob is in `score`, so it counts here —
    /// keeping a beam's normalized score identical at finish time and on
    /// every later carry.
    fn gen_len(&self) -> usize {
        self.toks.len() + self.done as usize
    }
}

/// Length-normalized beam score: mean log-prob per generated token
/// (including the stop byte for finished beams — see [`Beam::gen_len`]).
fn beam_norm(score: f64, len: usize) -> f64 {
    score / len.max(1) as f64
}

/// Beam search for ONE prompt, packing beams into the batch dimension
/// (beam width ≤ `arch_b`). Length-normalized log-prob scoring. `h0` seeds
/// the SSM state as in [`greedy_decode`] (initial-state tuning).
///
/// Finished beams are carried over verbatim each round — they are skipped
/// when forming expansion candidates, so their length-normalized score is
/// frozen at finish time instead of being renormalized (and drifting) on
/// every subsequent step.
pub fn beam_search(model: &dyn StepDecode, prompt: &[u8], width: usize,
                   max_new: usize, stop_byte: u8,
                   h0: Option<&BTreeMap<String, Tensor>>) -> Result<Vec<u8>> {
    if max_new == 0 {
        return Ok(Vec::new());
    }
    let width = width.min(model.arch_b()).max(1);
    let b = model.arch_b();
    let dims = model.dims();
    let mut state = model.new_state(h0);
    // prefill ONE row (chunked when the model supports it) instead of
    // scanning the same prompt redundantly across all `b` rows; row 0's
    // state is broadcast below before the beams diverge (§Perf L5). The
    // broadcast costs one host round-trip per request — beam re-parenting
    // pays that every step anyway, so it never dominates.
    let n = prompt.len() + 1; // BOS + prompt
    let stream = |r: usize, t: usize| -> i32 {
        if r != 0 {
            PAD
        } else if t == 0 {
            BOS
        } else {
            prompt[t - 1] as i32
        }
    };
    let mut covered = 0usize;
    let mut last = None;
    if let Some(pf) = model.chunk_prefill() {
        let (c, lg) = chunk_prefill_cover(pf, b, &mut state, n, &stream)?;
        covered = c;
        if c == n {
            last = lg; // the final chunk's logits ARE the first-expansion logits
        }
    }
    let mut cur = IntTensor::from_vec(&[b], vec![PAD; b]);
    for t in covered..n {
        for r in 0..b {
            cur.data[r] = stream(r, t);
        }
        last = Some(model.step(&cur, &mut state)?);
    }
    let logits = last.context("beam prefill produced no logits (empty prompt stream)")?;
    state.broadcast_row(&dims, b, 0)?;
    let v = logits.shape[1];
    let lp0 = log_softmax(&logits.data[..v]);
    let mut order: Vec<usize> = (0..256).collect();
    order.sort_by(|&a, &bb| lp0[bb].total_cmp(&lp0[a]));
    let mut beams: Vec<Beam> = order[..width]
        .iter()
        .map(|&t| Beam {
            toks: if t as u8 == stop_byte { Vec::new() } else { vec![t as u8] },
            score: lp0[t],
            done: t as u8 == stop_byte,
        })
        .collect();
    for r in 0..b {
        let bm = &beams[r.min(width - 1)];
        // a live beam always holds its expansion token; PAD is safe either way
        cur.data[r] = if bm.done { PAD } else { bm.toks.last().map_or(PAD, |&t| t as i32) };
    }
    // replicate states across beams (identical after same prefill)
    for _ in 1..max_new {
        if beams.iter().all(|bm| bm.done) {
            break;
        }
        let lg = model.step(&cur, &mut state)?;
        // candidate = (parent beam, Some(expansion token) | None for a
        // carried finished beam, raw score, normalized score)
        let mut cand: Vec<(usize, Option<u8>, f64, f64)> = Vec::new();
        for (bi, bm) in beams.iter().enumerate() {
            if bm.done {
                // finished beams compete for slots at their frozen score
                // but are never expanded or renormalized
                cand.push((bi, None, bm.score, beam_norm(bm.score, bm.gen_len())));
                continue;
            }
            let lp = log_softmax(&lg.data[bi * v..bi * v + 256]);
            let mut idx: Vec<usize> = (0..256).collect();
            idx.sort_by(|&a, &bb| lp[bb].total_cmp(&lp[a]));
            for &t in &idx[..width] {
                // the expansion token counts toward the normalized length
                // whether it extends the beam or finishes it (stop byte),
                // so this norm IS the frozen norm if the beam finishes
                let s = bm.score + lp[t];
                cand.push((bi, Some(t as u8), s, beam_norm(s, bm.toks.len() + 1)));
            }
        }
        cand.sort_by(|a, bc| bc.3.total_cmp(&a.3));
        let mut new_beams = Vec::with_capacity(width);
        // re-parent surviving beams: snapshot the post-step state, then
        // permute rows in the host mirror (slots beyond `width` keep their
        // post-step values, matching the old clone-then-copy behavior)
        let (src_conv, src_ssm) = {
            let (c, s) = state.host()?;
            (c.clone(), s.clone())
        };
        let (conv, ssm) = state.host_mut()?;
        for (slot, &(bi, tok, score, _)) in cand.iter().take(width).enumerate() {
            let src = beams[bi].clone();
            let (toks, done) = match tok {
                None => (src.toks, true),
                Some(t) if t == stop_byte => (src.toks, true),
                Some(t) => {
                    let mut ts = src.toks;
                    ts.push(t);
                    (ts, false)
                }
            };
            new_beams.push(Beam { toks, score, done });
            // copy parent state into this slot
            dims.copy_row(&src_conv, &src_ssm, conv, ssm, b, bi, slot);
        }
        beams = new_beams;
        for r in 0..b {
            let bm = &beams[r.min(width - 1)];
            // a live beam always holds its expansion token; PAD is safe either way
        cur.data[r] = if bm.done { PAD } else { bm.toks.last().map_or(PAD, |&t| t as i32) };
        }
    }
    Ok(beams
        .into_iter()
        .max_by(|a, bm| {
            beam_norm(a.score, a.gen_len()).total_cmp(&beam_norm(bm.score, bm.gen_len()))
        })
        .map(|bm| bm.toks)
        .unwrap_or_default())
}

/// Offline generator: a [`DecodeCore`] plus the greedy/beam entry points
/// the coordinator and examples use.
pub struct Generator {
    core: DecodeCore,
}

impl Generator {
    /// `params_map` must contain every base parameter of the decode variant
    /// (merge LoRA first: [`crate::peft::merge_lora`]). Initial-state
    /// tuning passes its trained h0 via the ssm-state input automatically
    /// when the map contains "layers.{i}.h0".
    pub fn new(engine: &Engine, manifest: &Manifest, decode_variant: &str,
               params_map: &BTreeMap<String, Tensor>) -> Result<Self> {
        Ok(Generator { core: DecodeCore::new(engine, manifest, decode_variant, params_map)? })
    }

    /// Fixed batch width of the underlying decode executable.
    pub fn arch_b(&self) -> usize {
        self.core.arch_b()
    }

    /// Greedy generation for up to `arch_b` prompts at once — see
    /// [`greedy_decode`].
    pub fn greedy(&self, prompts: &[Vec<u8>], max_new: usize, stop_byte: u8,
                  h0: Option<&BTreeMap<String, Tensor>>) -> Result<Vec<Vec<u8>>> {
        greedy_decode(&self.core, prompts, max_new, stop_byte, h0)
    }

    /// Beam search for one prompt — see [`beam_search`].
    pub fn beam(&self, prompt: &[u8], width: usize, max_new: usize, stop_byte: u8,
                h0: Option<&BTreeMap<String, Tensor>>) -> Result<Vec<u8>> {
        beam_search(&self.core, prompt, width, max_new, stop_byte, h0)
    }
}

fn log_softmax(row: &[f32]) -> Vec<f64> {
    let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
    let z: f64 = row.iter().map(|&x| ((x as f64) - m).exp()).sum();
    row.iter().map(|&x| (x as f64) - m - z.ln()).collect()
}

/// Generation metrics over a test split: ROUGE / BLEU+METEOR / exec-match.
pub struct GenScores {
    /// ROUGE-1 F1 (unigram overlap).
    pub rouge1: f64,
    /// ROUGE-2 F1 (bigram overlap).
    pub rouge2: f64,
    /// ROUGE-L F1 (longest common subsequence).
    pub rougel: f64,
    /// Corpus BLEU.
    pub bleu: f64,
    /// METEOR-lite (unigram F-mean with fragmentation penalty).
    pub meteor: f64,
    /// Execution-match accuracy against the mini database (Spider).
    pub exec_acc: f64,
}

/// Greedy-decode a test split in arch-batch chunks and score it.
pub fn eval_generation(gen: &Generator, ds: &Dataset, split: &[Example],
                       max_new: usize, seed: u64,
                       h0: Option<&BTreeMap<String, Tensor>>) -> Result<GenScores> {
    let mut outs: Vec<Vec<u8>> = Vec::with_capacity(split.len());
    let mut i = 0;
    while i < split.len() {
        let end = (i + gen.arch_b()).min(split.len());
        let prompts: Vec<Vec<u8>> = split[i..end].iter().map(|e| e.prompt.clone()).collect();
        outs.extend(gen.greedy(&prompts, max_new, b'\n', h0)?);
        i = end;
    }
    Ok(score_generation(ds, split, &outs, seed))
}

/// Beam-search generation metrics: one beam search per example (beams pack
/// the batch dimension, so examples run serially). Used when
/// `ExperimentConfig::beam > 1`.
pub fn eval_generation_beam(gen: &Generator, ds: &Dataset, split: &[Example],
                            width: usize, max_new: usize, seed: u64,
                            h0: Option<&BTreeMap<String, Tensor>>) -> Result<GenScores> {
    let mut outs: Vec<Vec<u8>> = Vec::with_capacity(split.len());
    for ex in split {
        outs.push(gen.beam(&ex.prompt, width, max_new, b'\n', h0)?);
    }
    Ok(score_generation(ds, split, &outs, seed))
}

/// Score generated outputs against a split's targets (shared by the
/// greedy and beam paths).
fn score_generation(ds: &Dataset, split: &[Example], outs: &[Vec<u8>], seed: u64)
    -> GenScores {
    let mut preds_ids = Vec::new();
    let mut golds_ids = Vec::new();
    let mut r1 = Vec::new();
    let mut r2 = Vec::new();
    let mut rl = Vec::new();
    let mut met = Vec::new();
    let mut exec_hits = 0usize;
    let table = spider_table(seed);
    for (ex, out) in split.iter().zip(outs) {
        let p_ids = words_to_ids(out);
        let g_ids = words_to_ids(&ex.target);
        r1.push(metrics::rouge_n(&p_ids, &g_ids, 1));
        r2.push(metrics::rouge_n(&p_ids, &g_ids, 2));
        rl.push(metrics::rouge_l(&p_ids, &g_ids));
        met.push(metrics::meteor(&p_ids, &g_ids));
        if ds.metric == Metric::Exec {
            let pred_s = String::from_utf8_lossy(out).to_string();
            let gold_s = String::from_utf8_lossy(&ex.target).to_string();
            if exec_match(&table, &pred_s, &gold_s) {
                exec_hits += 1;
            }
        }
        preds_ids.push(p_ids);
        golds_ids.push(g_ids);
    }
    let n = preds_ids.len().max(1) as f64;
    GenScores {
        rouge1: crate::tensor::mean(&r1),
        rouge2: crate::tensor::mean(&r2),
        rougel: crate::tensor::mean(&rl),
        bleu: metrics::bleu(&preds_ids, &golds_ids),
        meteor: crate::tensor::mean(&met),
        exec_acc: exec_hits as f64 / n,
    }
}

/// Convenience: eval loss over a split (early-stopping signal shared by all
/// task types).
pub fn eval_split_loss(trainer: &Trainer, split: &[Example], rng_seed: u64) -> Result<f64> {
    let b = trainer.variant.batch_b;
    let l = trainer.variant.batch_l;
    let mut rng = crate::tensor::Rng::new(rng_seed);
    let mut losses = Vec::new();
    let it = crate::data::BatchIter::new(split, &mut rng, b, l);
    for (batch, _) in it.take(8) {
        losses.push(trainer.eval_loss(&batch)? as f64);
    }
    Ok(crate::tensor::mean(&losses))
}

/// Deterministic mock [`StepDecode`] models needing no artifacts. Shared
/// by this module's tests, the serving scheduler's
/// ([`crate::serve::scheduler`]), and the mock mode of `bench hotpath`
/// ([`crate::bench::hotpath`] uses [`testing::Accum`] for the prefill
/// dispatch accounting) — hence compiled outside `cfg(test)` too.
#[allow(dead_code)] // Counter is test-only; the bench uses Accum
pub(crate) mod testing {
    use super::*;

    /// Counter model: next byte = input byte + 1 (BOS → 1). Counts steps
    /// so scheduler tests can assert execution behavior.
    pub(crate) struct Counter {
        pub(crate) b: usize,
        pub(crate) steps: std::sync::atomic::AtomicU64,
    }

    impl Counter {
        pub(crate) fn new(b: usize) -> Counter {
            Counter { b, steps: std::sync::atomic::AtomicU64::new(0) }
        }
    }

    impl StepDecode for Counter {
        fn arch_b(&self) -> usize {
            self.b
        }
        fn dims(&self) -> StateDims {
            StateDims { n_layer: 1, d_conv: 2, d_inner: 1, d_state: 1 }
        }
        fn step(&self, tokens: &IntTensor, state: &mut DecodeState) -> Result<Tensor> {
            self.steps.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            let mut logits = Tensor::zeros(&[self.b, 256]);
            for r in 0..self.b {
                let t = tokens.data[r];
                let next = if (0..256).contains(&t) { ((t + 1) % 256) as usize } else { 1 };
                logits.data[r * 256 + next] = 10.0;
            }
            // the counter is stateless: zero the mirror like the old mock
            // returned fresh zero tensors
            let (conv, ssm) = state.host_mut()?;
            conv.data.fill(0.0);
            ssm.data.fill(0.0);
            Ok(logits)
        }
    }

    /// Stateful mock with optional chunked prefill: each row's SSM state
    /// is a rolling hash of every token it consumed (the conv state holds
    /// the previous token's value), and the next byte is a function of
    /// that hash — so ANY state discontinuity across chunk→chunk or
    /// chunk→decode transitions changes the generated bytes. Counts step
    /// and chunk dispatches for the dispatch-count assertions.
    pub(crate) struct Accum {
        pub(crate) b: usize,
        /// Advertised chunk widths (ascending); empty = stepwise-only.
        pub(crate) widths: Vec<usize>,
        pub(crate) steps: std::sync::atomic::AtomicU64,
        pub(crate) chunks: std::sync::atomic::AtomicU64,
    }

    impl Accum {
        pub(crate) fn new(b: usize, widths: &[usize]) -> Accum {
            Accum {
                b,
                widths: widths.to_vec(),
                steps: std::sync::atomic::AtomicU64::new(0),
                chunks: std::sync::atomic::AtomicU64::new(0),
            }
        }

        fn val(tok: i32) -> f32 {
            match tok {
                t if (0..256).contains(&t) => t as f32,
                BOS => 1.0,
                _ => 0.0, // PAD
            }
        }

        /// One token of the rolling hash (all values stay < 2^13, so every
        /// f32 op here is exact — chunked and stepwise agree bitwise).
        fn advance(a: f32, prev: f32, tok: i32) -> (f32, f32) {
            let v = Self::val(tok);
            ((a * 31.0 + v + prev) % 257.0, v)
        }

        fn logits_from(&self, hashes: &[f32]) -> Tensor {
            let mut logits = Tensor::zeros(&[self.b, 256]);
            for r in 0..self.b {
                logits.data[r * 256 + (hashes[r] as usize) % 256] = 10.0;
            }
            logits
        }
    }

    impl StepDecode for Accum {
        fn arch_b(&self) -> usize {
            self.b
        }
        fn dims(&self) -> StateDims {
            StateDims { n_layer: 1, d_conv: 2, d_inner: 1, d_state: 1 }
        }
        fn step(&self, tokens: &IntTensor, state: &mut DecodeState) -> Result<Tensor> {
            self.steps.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            let (conv, ssm) = state.host_mut()?;
            let mut hashes = vec![0.0f32; self.b];
            for r in 0..self.b {
                let (a, v) = Self::advance(ssm.data[r], conv.data[r], tokens.data[r]);
                ssm.data[r] = a;
                conv.data[r] = v;
                hashes[r] = a;
            }
            Ok(self.logits_from(&hashes))
        }
        fn chunk_prefill(&self) -> Option<&dyn ChunkPrefill> {
            (!self.widths.is_empty()).then_some(self as &dyn ChunkPrefill)
        }
    }

    impl ChunkPrefill for Accum {
        fn chunk_widths(&self) -> &[usize] {
            &self.widths
        }
        fn prefill_chunk(&self, tokens: &IntTensor, state: &mut DecodeState)
            -> Result<Tensor> {
            self.chunks.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            let w = tokens.shape[1];
            crate::ensure!(self.widths.contains(&w), "unsupported chunk width {w}");
            let (conv, ssm) = state.host_mut()?;
            let mut hashes = vec![0.0f32; self.b];
            for r in 0..self.b {
                let (mut a, mut prev) = (ssm.data[r], conv.data[r]);
                for i in 0..w {
                    (a, prev) = Self::advance(a, prev, tokens.data[r * w + i]);
                }
                ssm.data[r] = a;
                conv.data[r] = prev;
                hashes[r] = a;
            }
            Ok(self.logits_from(&hashes))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::testing::{Accum, Counter};
    use super::*;
    use std::sync::atomic::Ordering;

    #[test]
    fn plan_chunks_largest_fit() {
        assert_eq!(plan_chunks(&[16, 64], 150), (vec![64, 64, 16], 6));
        assert_eq!(plan_chunks(&[16, 64], 37), (vec![16, 16], 5));
        assert_eq!(plan_chunks(&[16, 64], 15), (vec![], 15));
        assert_eq!(plan_chunks(&[16, 64], 0), (vec![], 0));
        assert_eq!(plan_chunks(&[4], 9), (vec![4, 4], 1));
    }

    #[test]
    fn chunked_greedy_matches_stepwise_and_counts_dispatches() {
        // acceptance: chunked output byte-identical to stepwise, chunk
        // dispatches == the plan over the shortest prompt, stepwise
        // dispatches reduced by exactly the covered iterations
        let p0: Vec<u8> = (0..23).map(|i| (i * 7 + 3) as u8).collect();
        let p1: Vec<u8> = (0..9).map(|i| (i * 11 + 5) as u8).collect();
        let prompts = vec![p0, p1];
        let max_new = 6;

        let plain = Accum::new(2, &[]);
        let want = greedy_decode(&plain, &prompts, max_new, 255, None).unwrap();
        let plain_steps = plain.steps.load(Ordering::Relaxed);

        let chunked = Accum::new(2, &[4, 16]);
        let got = greedy_decode(&chunked, &prompts, max_new, 255, None).unwrap();
        assert_eq!(got, want, "chunked greedy must be byte-identical");

        // shortest prompt is 9 bytes → 9 coverable iterations → [4, 4] + 1
        let (plan, _rem) = plan_chunks(&[4, 16], 9);
        assert_eq!(chunked.chunks.load(Ordering::Relaxed), plan.len() as u64);
        let covered: usize = plan.iter().sum();
        assert_eq!(
            chunked.steps.load(Ordering::Relaxed),
            plain_steps - covered as u64,
            "every covered iteration replaces one step dispatch"
        );
        assert!(!want[0].is_empty() && !want[1].is_empty(), "mock generated");
    }

    #[test]
    fn chunked_beam_matches_stepwise() {
        let prompt: Vec<u8> = (0..21).map(|i| (i * 5 + 2) as u8).collect();
        let plain = Accum::new(3, &[]);
        let want = beam_search(&plain, &prompt, 3, 7, 255, None).unwrap();
        let chunked = Accum::new(3, &[4, 16]);
        let got = beam_search(&chunked, &prompt, 3, 7, 255, None).unwrap();
        assert_eq!(got, want, "chunked beam must be byte-identical");
        // stream = BOS + prompt = 22 → [16, 4] chunks + 2 stepwise prefill
        let (plan, rem) = plan_chunks(&[4, 16], prompt.len() + 1);
        assert_eq!(chunked.chunks.load(Ordering::Relaxed), plan.len() as u64);
        let covered: usize = plan.iter().sum();
        assert_eq!(
            plain.steps.load(Ordering::Relaxed)
                - chunked.steps.load(Ordering::Relaxed),
            covered as u64
        );
        assert_eq!(rem, 2);
    }

    #[test]
    fn chunk_exact_cover_uses_chunk_logits_for_beam() {
        // stream length exactly chunk-coverable: the first-expansion
        // logits come from the final chunk, zero stepwise prefill steps
        let prompt: Vec<u8> = (0..7).map(|i| (i * 3 + 1) as u8).collect();
        let plain = Accum::new(2, &[]);
        let want = beam_search(&plain, &prompt, 2, 5, 255, None).unwrap();
        let chunked = Accum::new(2, &[4]);
        let got = beam_search(&chunked, &prompt, 2, 5, 255, None).unwrap();
        assert_eq!(got, want);
        assert_eq!(chunked.chunks.load(Ordering::Relaxed), 2, "8 = 4 + 4");
        // prefill did zero step dispatches: all remaining steps generate
        assert_eq!(
            plain.steps.load(Ordering::Relaxed)
                - chunked.steps.load(Ordering::Relaxed),
            8
        );
    }

    #[test]
    fn short_prompt_skips_chunking() {
        let chunked = Accum::new(2, &[16]);
        let plain = Accum::new(2, &[]);
        let prompts = vec![vec![5u8, 6, 7]];
        let want = greedy_decode(&plain, &prompts, 4, 255, None).unwrap();
        let got = greedy_decode(&chunked, &prompts, 4, 255, None).unwrap();
        assert_eq!(got, want);
        assert_eq!(chunked.chunks.load(Ordering::Relaxed), 0);
        assert_eq!(
            chunked.steps.load(Ordering::Relaxed),
            plain.steps.load(Ordering::Relaxed)
        );
    }

    #[test]
    fn splice_and_broadcast_rows() {
        let d = StateDims { n_layer: 1, d_conv: 2, d_inner: 1, d_state: 1 };
        let b = 3;
        let mut src = DecodeState::new(d, b, None);
        {
            let (conv, ssm) = src.host_mut().unwrap();
            conv.data.copy_from_slice(&[1.0, 2.0, 3.0]);
            ssm.data.copy_from_slice(&[4.0, 5.0, 6.0]);
        }
        let mut dst = DecodeState::new(d, b, None);
        dst.splice_row_from(&d, b, &mut src, 1, 2).unwrap();
        {
            let (conv, ssm) = dst.host().unwrap();
            assert_eq!(conv.data, vec![0.0, 0.0, 2.0]);
            assert_eq!(ssm.data, vec![0.0, 0.0, 5.0]);
        }
        src.broadcast_row(&d, b, 0).unwrap();
        let (conv, ssm) = src.host().unwrap();
        assert_eq!(conv.data, vec![1.0, 1.0, 1.0]);
        assert_eq!(ssm.data, vec![4.0, 4.0, 4.0]);
    }

    #[test]
    fn log_softmax_normalizes() {
        let lp = log_softmax(&[1.0, 2.0, 3.0]);
        let total: f64 = lp.iter().map(|x| x.exp()).sum();
        assert!((total - 1.0).abs() < 1e-9);
        assert!(lp[2] > lp[0]);
    }

    #[test]
    fn greedy_counts_up_and_stops() {
        let m = Counter::new(2);
        let outs =
            greedy_decode(&m, &[vec![10u8], vec![40u8, 41u8]], 8, 44, None).unwrap();
        // row 0: 11,12,... capped by max_new; row 1: 42,43 then 44 = stop
        assert_eq!(outs[0], vec![11, 12, 13, 14, 15, 16, 17, 18]);
        assert_eq!(outs[1], vec![42, 43]);
    }

    #[test]
    fn beam_agrees_with_greedy_on_deterministic_model() {
        let m = Counter::new(3);
        let beam = beam_search(&m, &[10u8], 3, 6, 15, None).unwrap();
        let greedy = greedy_decode(&m, &[vec![10u8]], 6, 15, None).unwrap();
        assert_eq!(beam, greedy[0]);
        assert_eq!(beam, vec![11, 12, 13, 14]); // 15 is the stop byte
    }

    #[test]
    fn beam_finished_score_is_frozen() {
        // stop byte is the immediate argmax: the best beam finishes on the
        // first expansion and must survive later rounds unchanged
        let m = Counter::new(2);
        let beam = beam_search(&m, &[20u8], 2, 8, 21, None).unwrap();
        assert_eq!(beam, Vec::<u8>::new(), "argmax hits stop immediately");
    }

    #[test]
    fn beam_zero_budget_generates_nothing() {
        let m = Counter::new(2);
        let beam = beam_search(&m, &[10u8], 2, 0, 0, None).unwrap();
        assert_eq!(beam, Vec::<u8>::new());
        // and no decode work happened at all
        assert_eq!(m.steps.load(std::sync::atomic::Ordering::Relaxed), 0);
    }

    #[test]
    fn decode_state_residency_roundtrip() {
        // install literals as a step output would, then check the host
        // mirror lazily syncs and host_mut invalidates residency
        let d = StateDims { n_layer: 1, d_conv: 2, d_inner: 2, d_state: 1 };
        let mut st = DecodeState::new(d, 1, None);
        {
            let (c, s) = st.exec_literals().unwrap();
            // freshly-serialized host state: all zeros
            assert_eq!(crate::runtime::tensor_from_literal(c).unwrap().data, vec![0.0, 0.0]);
            assert_eq!(crate::runtime::tensor_from_literal(s).unwrap().data, vec![0.0, 0.0]);
        }
        let pair = crate::runtime::StatePair {
            conv: crate::runtime::literal_f32(
                &Tensor::from_vec(&[1, 1, 1, 2], vec![1.0, 2.0])).unwrap(),
            ssm: crate::runtime::literal_f32(
                &Tensor::from_vec(&[1, 1, 2, 1], vec![3.0, 4.0])).unwrap(),
        };
        st.install(pair);
        // host mirror syncs on demand from the installed literals
        let (c, s) = st.host().unwrap();
        assert_eq!(c.data, vec![1.0, 2.0]);
        assert_eq!(s.data, vec![3.0, 4.0]);
        // mutate a row: residency drops, next exec re-serializes the edit
        st.reset_row(&d, 1, 0, None).unwrap();
        let (c, _s) = st.exec_literals().unwrap();
        assert_eq!(crate::runtime::tensor_from_literal(c).unwrap().data, vec![0.0, 0.0]);
    }

    #[test]
    fn state_dims_reset_and_copy_row() {
        let d = StateDims { n_layer: 2, d_conv: 3, d_inner: 2, d_state: 2 };
        let b = 2;
        let mut h0 = BTreeMap::new();
        h0.insert("layers.1.h0".to_string(),
                  Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]));
        let (mut conv, mut ssm) = d.init_states(b, Some(&h0));
        // layer 0 zero, layer 1 seeded in every row
        let per = d.ssm_per_row();
        assert!(ssm.data[..per * b].iter().all(|&x| x == 0.0));
        assert_eq!(&ssm.data[per * b..per * b + per], &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(&ssm.data[per * b + per..per * b + 2 * per], &[1.0, 2.0, 3.0, 4.0]);
        // dirty row 0, then reset it without h0: back to zeros
        ssm.data[0] = 9.0;
        conv.data[0] = 9.0;
        d.reset_row(Some(&mut conv), Some(&mut ssm), b, 0, None);
        assert_eq!(ssm.data[0], 0.0);
        assert_eq!(conv.data[0], 0.0);
        // copying row 1 → row 0 from a pristine source pair restores the
        // layer-1 seed in the destination's row 0
        let (src_conv, src_ssm) = d.init_states(b, Some(&h0));
        d.copy_row(&src_conv, &src_ssm, &mut conv, &mut ssm, b, 1, 0);
        assert_eq!(&ssm.data[per * b..per * b + per], &[1.0, 2.0, 3.0, 4.0]);
    }
}
