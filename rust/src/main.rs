//! ssm-peft CLI: the leader entrypoint.
//!
//! Subcommands:
//!   info                         list artifact variants + parameter budgets
//!   pretrain arch=<a> steps=<n>  build/cache the frozen base checkpoint
//!   finetune [config=<file>] [key=value ...]
//!                                run one fine-tuning experiment
//!   suite config=<file.json> [par=<n>] [resume=<0|1>]
//!                                run a declarative experiment suite in
//!                                parallel; streams results/<name>.jsonl
//!                                (schema: rust/docs/suite.md)
//!   sdt-report [key=value ...]   run SDT selection and print the chosen
//!                                channels/states per layer
//!   generate variant=<v> prompt=<text>
//!                                greedy generation demo from a checkpoint
//!   serve [arch=<a>] [addr=<host:port>] [stdin=1] [cache=<n>] [lanes=<n>]
//!                                online multi-adapter generation server:
//!                                line-delimited JSON requests over
//!                                stdin/stdout and/or TCP, continuous
//!                                batching across adapters served from one
//!                                staged base (schema: rust/docs/serving.md)
//!   bench hotpath                fused hot-path telemetry: step-latency
//!                                breakdown + decode tokens/sec + chunked-
//!                                prefill dispatches/request, written to
//!                                results/BENCH_hotpath.json (tiny CI mode:
//!                                SSM_PEFT_BENCH_SCALE=0.1; falls back to a
//!                                mock host-optimizer comparison when no
//!                                artifacts exist — rust/docs/performance.md)
//!   bench serving                SLO load harness: seeded Poisson arrivals
//!                                + adapter skew against the in-process
//!                                scheduler on a virtual clock; percentile
//!                                TTFT/ITL + goodput per offered-load point,
//!                                written to results/BENCH_serving.json
//!                                (rust/docs/observability.md)
//!   lint                         repolint: first-party static analysis
//!                                (unsafe-safety, no-panic, determinism,
//!                                knob-registry) + unsafe inventory report,
//!                                written to results/LINT_unsafe.md
//!                                (rules: rust/docs/linting.md)

use std::collections::BTreeMap;

use ssm_peft::err;
use ssm_peft::error::Result;

use ssm_peft::bench::TablePrinter;
use ssm_peft::config::{parse_args, ExperimentConfig};
use ssm_peft::coordinator::Pipeline;
use ssm_peft::data::tasks;
use ssm_peft::eval::Generator;
use ssm_peft::manifest::Manifest;
use ssm_peft::peft::{select_dimensions, Budget};
use ssm_peft::runtime::Engine;
use ssm_peft::suite::{Suite, SuiteSpec, VariantId};
use ssm_peft::tensor::Rng;
use ssm_peft::train::{TrainConfig, Trainer};

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (kvs, pos) = parse_args(&args);
    let cmd = pos.first().map(String::as_str).unwrap_or("info");
    match cmd {
        "info" => info(),
        "pretrain" => pretrain(&kvs),
        "finetune" => finetune(&kvs),
        "suite" => suite(&kvs),
        "sdt-report" => sdt_report(&kvs),
        "generate" => generate(&kvs),
        "serve" => serve(&kvs),
        "bench" => bench(&kvs, &pos),
        "lint" => lint(),
        other => {
            eprintln!("unknown command {other}; see src/main.rs header");
            exit(2);
        }
    }
}

/// The CLI's one sanctioned `process::exit` site (clippy.toml disallows it
/// elsewhere so library code can never kill a suite worker's process).
#[allow(clippy::disallowed_methods)]
fn exit(code: i32) -> ! {
    std::process::exit(code)
}

/// Run repolint over the workspace and write the unsafe inventory
/// (rules and waiver etiquette: rust/docs/linting.md).
fn lint() -> Result<()> {
    let root = ssm_peft::lint::workspace_root();
    let report = ssm_peft::lint::run(&root)?;
    print!("{}", report.render());
    let inv = ssm_peft::results_dir().join("LINT_unsafe.md");
    std::fs::write(&inv, ssm_peft::lint::render_unsafe_inventory(&report.unsafe_sites))?;
    println!("unsafe inventory -> {}", inv.display());
    if report.ok() {
        Ok(())
    } else {
        Err(err!("repolint found problems (see output above)"))
    }
}

fn load_all() -> Result<(Engine, Manifest)> {
    let engine = Engine::cpu()?;
    let manifest = Manifest::load(ssm_peft::artifacts_dir())?;
    Ok((engine, manifest))
}

fn info() -> Result<()> {
    let manifest = Manifest::load(ssm_peft::artifacts_dir())?;
    println!("{:<28} {:>10} {:>12} {:>8}  files", "variant", "trainable", "total", "%");
    for (name, v) in &manifest.variants {
        let b = Budget::of(v, None);
        println!(
            "{:<28} {:>10} {:>12} {:>7.2}%  step={} fwd={} decode={}",
            name,
            b.trainable,
            b.total,
            b.percent(),
            v.step_file.is_some() as u8,
            v.fwd_file.is_some() as u8,
            v.decode_file.is_some() as u8,
        );
    }
    Ok(())
}

fn pretrain(kvs: &BTreeMap<String, String>) -> Result<()> {
    let (engine, manifest) = load_all()?;
    let arch = kvs.get("arch").map(String::as_str).unwrap_or("mamba1_xs");
    let steps: usize = kvs.get("steps").and_then(|s| s.parse().ok()).unwrap_or(300);
    let seed: u64 = kvs.get("seed").and_then(|s| s.parse().ok()).unwrap_or(0);
    let p = Pipeline::new(&engine, &manifest);
    let ckpt = p.pretrained(arch, steps, seed)?;
    println!("pretrained {arch}: {} tensors cached in results/", ckpt.len());
    Ok(())
}

fn finetune(kvs: &BTreeMap<String, String>) -> Result<()> {
    let (engine, manifest) = load_all()?;
    let mut cfg = match kvs.get("config") {
        Some(f) => ExperimentConfig::from_file(f)?,
        None => ExperimentConfig::default(),
    };
    let mut rest = kvs.clone();
    rest.remove("config");
    cfg.apply_overrides(&rest)?;
    let p = Pipeline::new(&engine, &manifest);
    let out = p.finetune(&cfg)?;
    println!("variant={} dataset={} lr={} steps={}", out.variant, out.dataset,
             out.chosen_lr, out.steps);
    println!("trainable budget: {:.3}%", out.budget_pct);
    for (k, v) in &out.scores {
        println!("  {k:<8} {v:.4}");
    }
    ssm_peft::coordinator::save_history(
        &format!("finetune_{}_{}.csv", out.variant, out.dataset.replace('/', "_")),
        &out.history,
    );
    Ok(())
}

/// Run a declarative suite file on the parallel runner; prints a summary
/// table and leaves the machine-readable stream in results/<name>.jsonl.
fn suite(kvs: &BTreeMap<String, String>) -> Result<()> {
    let path = kvs
        .get("config")
        .ok_or_else(|| err!("suite requires config=<file.json>"))?;
    let spec = SuiteSpec::from_file(path)?;
    let par: usize = kvs
        .get("par")
        .and_then(|s| s.parse().ok())
        .unwrap_or(spec.par);
    let mut plan = spec.plan;
    if let Some(r) = kvs.get("resume") {
        plan.resume = r.as_str() != "0" && r.as_str() != "false";
    }
    let name = plan.name.clone();
    let (engine, manifest) = load_all()?;
    let records = Suite::from_plan(&engine, &manifest, plan).run(par)?;

    let mut table = TablePrinter::new(&[
        "variant", "dataset", "params%", "metric", "lr", "steps", "time(s)",
    ]);
    for r in &records {
        if r.ok() {
            table.row(vec![
                r.variant.clone(),
                r.dataset.clone(),
                format!("{:.2}", r.budget_pct),
                format!("{:.4}", r.metric),
                format!("{}", r.chosen_lr),
                r.steps.to_string(),
                format!("{:.1}", r.total_s),
            ]);
        } else {
            table.row(vec![
                r.variant.clone(),
                r.dataset.clone(),
                "-".into(),
                "ERR".into(),
                "-".into(),
                "-".into(),
                format!("{:.1}", r.total_s),
            ]);
        }
    }
    println!("\n=== suite {name} ({par} workers) ===");
    table.print();
    let failed = records.iter().filter(|r| !r.ok()).count();
    println!(
        "{} cells, {} failed; records -> {}",
        records.len(),
        failed,
        ssm_peft::results_dir().join(format!("{name}.jsonl")).display()
    );
    Ok(())
}

/// In-binary benchmarks (currently: `bench hotpath`); the paper-table
/// benches stay as `cargo bench` targets.
fn bench(kvs: &BTreeMap<String, String>, pos: &[String]) -> Result<()> {
    match pos.get(1).map(String::as_str) {
        Some("hotpath") => ssm_peft::bench::hotpath::run(kvs),
        Some("serving") => ssm_peft::bench::serving::run(kvs),
        other => Err(err!("unknown bench target {other:?}; available: hotpath, serving")),
    }
}

/// Run the online generation server (see rust/docs/serving.md).
fn serve(kvs: &BTreeMap<String, String>) -> Result<()> {
    let opts = ssm_peft::serve::ServeOptions::from_kvs(kvs)?;
    let (engine, manifest) = load_all()?;
    ssm_peft::serve::run(&engine, &manifest, &opts)
}

fn sdt_report(kvs: &BTreeMap<String, String>) -> Result<()> {
    let (engine, manifest) = load_all()?;
    let mut cfg = ExperimentConfig::default();
    cfg.variant = "mamba1_xs_sdt".into();
    cfg.apply_overrides(kvs)?;
    let p = Pipeline::new(&engine, &manifest);
    let vid = VariantId::parse(&cfg.variant)?;
    let base = p.pretrained(&vid.arch, cfg.pretrain_steps, cfg.seed)?;
    let ds = tasks::by_name(&cfg.dataset, cfg.seed, cfg.n_train)?;
    let tcfg = TrainConfig { lr: cfg.sdt.warmup_lr, ..Default::default() };
    let mut tr = Trainer::new(&engine, &manifest, &cfg.variant, &tcfg)?;
    tr.load_base(&base);
    let before = tr.train_map();
    let mut rng = Rng::new(cfg.seed);
    let it = ssm_peft::data::BatchIter::new(&ds.train, &mut rng,
                                            tr.variant.batch_b, tr.variant.batch_l);
    for (batch, _) in it.take(cfg.sdt.warmup_batches) {
        tr.step(&batch)?;
    }
    let after = tr.train_map();
    let (masks, sels) = select_dimensions(&tr.variant, &before, &after, &cfg.sdt);
    let b = Budget::of(&tr.variant, Some(&masks));
    println!("SDT selection on {} / {}:", cfg.variant, cfg.dataset);
    println!("effective trainable: {} ({:.3}%)", b.trainable, b.percent());
    for (l, s) in sels.iter().enumerate() {
        println!("layer {l}: channels {:?}", s.trainable_channels);
        for (c, st) in s.trainable_channels.iter().zip(&s.trainable_states) {
            println!("   ch {c}: states {st:?}");
        }
    }
    Ok(())
}

fn generate(kvs: &BTreeMap<String, String>) -> Result<()> {
    let (engine, manifest) = load_all()?;
    let variant = kvs.get("variant").cloned().unwrap_or("mamba1_xs_full".into());
    let prompt = kvs.get("prompt").cloned().unwrap_or("name=ann|team=red".into());
    let steps: usize = kvs.get("pretrain_steps").and_then(|s| s.parse().ok()).unwrap_or(300);
    let p = Pipeline::new(&engine, &manifest);
    let vid = VariantId::parse(&variant)?;
    let base = p.pretrained(&vid.arch, steps, 0)?;
    let gen = Generator::new(&engine, &manifest, &vid.decode_variant(), &base)?;
    let out = gen.greedy(&[prompt.clone().into_bytes()], 48, b'\n', None)?;
    println!("prompt: {prompt}");
    println!("output: {}", String::from_utf8_lossy(&out[0]));
    Ok(())
}
