//! In-tree stand-in for the `xla` PJRT binding, exposing exactly the API
//! surface `crate::runtime` uses.
//!
//! The offline build vendors no third-party crates, so the crate ships its
//! own host-side implementation of the literal layer (shape + bytes
//! storage, fully functional — the trainer's serialization paths and their
//! tests run on it) and a stub of the device layer ([`PjRtClient::compile`]
//! reports that no PJRT backend is vendored). Artifact-gated paths check
//! for `artifacts/manifest.json` before constructing an engine, so the
//! stub only ever reports its absence where execution was actually
//! requested.
//!
//! The module keeps the external crate's names (`PjRtClient`, `Literal`,
//! `ElementType`, …) so `crate::runtime` reads identically against a real
//! vendored binding; swapping one back in is a one-line import change.

use std::path::Path;

/// Error type for the XLA facade (message-only; `crate::error::Error`
/// classifies it as [`ErrorKind::Runtime`](crate::error::ErrorKind)).
#[derive(Debug, Clone)]
pub struct XlaError(pub String);

impl std::fmt::Display for XlaError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for XlaError {}

fn unavailable(op: &str) -> XlaError {
    XlaError(format!(
        "{op}: no PJRT backend is vendored in this build (in-tree xla stub); \
         artifact execution requires a real PJRT plugin"
    ))
}

/// Element dtype of an array [`Literal`] (the artifact ABI uses f32
/// parameters/activations and s32 token ids only).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    /// 32-bit float.
    F32,
    /// 32-bit signed int.
    S32,
}

impl ElementType {
    /// Bytes per element.
    pub fn size(self) -> usize {
        4
    }
}

/// Array dims of a literal, as the binding reports them (i64, row-major).
#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    /// Dimension sizes.
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Sealed dtype marker for [`Literal::to_vec`].
pub trait NativeType: Sized + Copy + private::Sealed {
    /// The dtype tag this native type stores as.
    const TY: ElementType;
    /// Decode one element from little-endian bytes.
    fn from_le(b: [u8; 4]) -> Self;
}

mod private {
    /// Seals [`super::NativeType`] to the two ABI dtypes.
    pub trait Sealed {}
    impl Sealed for f32 {}
    impl Sealed for i32 {}
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
    fn from_le(b: [u8; 4]) -> f32 {
        f32::from_le_bytes(b)
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
    fn from_le(b: [u8; 4]) -> i32 {
        i32::from_le_bytes(b)
    }
}

/// A host-side value: a typed, shaped byte buffer (or a tuple of them).
/// This half of the facade is fully functional — conversions, resident
/// argument tables and their tests all run on it.
#[derive(Debug, Clone)]
pub enum Literal {
    /// A dense array: dtype + dims + row-major little-endian payload.
    Array {
        /// Element dtype.
        ty: ElementType,
        /// Dimension sizes.
        dims: Vec<usize>,
        /// Row-major little-endian payload, `ty.size() * product(dims)`.
        bytes: Vec<u8>,
    },
    /// A tuple of literals (executables return one tuple output).
    Tuple(Vec<Literal>),
}

impl Literal {
    /// Build an array literal from a shape and a raw byte payload (the
    /// binding's untyped-copy constructor; one memcpy).
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Literal, XlaError> {
        let want = dims.iter().product::<usize>() * ty.size();
        if data.len() != want {
            return Err(XlaError(format!(
                "create_from_shape_and_untyped_data: {} bytes for shape {dims:?} \
                 ({want} expected)",
                data.len()
            )));
        }
        Ok(Literal::Array { ty, dims: dims.to_vec(), bytes: data.to_vec() })
    }

    /// The array shape (errors on tuple literals).
    pub fn array_shape(&self) -> Result<ArrayShape, XlaError> {
        match self {
            Literal::Array { dims, .. } => {
                Ok(ArrayShape { dims: dims.iter().map(|&d| d as i64).collect() })
            }
            Literal::Tuple(_) => Err(XlaError("array_shape on tuple literal".into())),
        }
    }

    /// Total element count (0 for tuple literals, as a diagnostic value).
    pub fn element_count(&self) -> usize {
        match self {
            Literal::Array { ty, bytes, .. } => bytes.len() / ty.size(),
            Literal::Tuple(_) => 0,
        }
    }

    /// Decode the payload into native elements (dtype-checked).
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, XlaError> {
        match self {
            Literal::Array { ty, bytes, .. } => {
                if *ty != T::TY {
                    return Err(XlaError(format!(
                        "to_vec: literal is {ty:?}, requested {:?}",
                        T::TY
                    )));
                }
                Ok(bytes
                    .chunks_exact(4)
                    .map(|c| T::from_le([c[0], c[1], c[2], c[3]]))
                    .collect())
            }
            Literal::Tuple(_) => Err(XlaError("to_vec on tuple literal".into())),
        }
    }

    /// Unpack a tuple literal into its elements (errors on array literals —
    /// executables return exactly one tuple, see `aot.py` return_tuple).
    pub fn to_tuple(self) -> Result<Vec<Literal>, XlaError> {
        match self {
            Literal::Tuple(elems) => Ok(elems),
            Literal::Array { .. } => Err(XlaError("to_tuple on array literal".into())),
        }
    }
}

/// Parsed HLO module text (the AOT artifacts are HLO text files).
#[derive(Debug)]
pub struct HloModuleProto {
    text: String,
}

impl HloModuleProto {
    /// Read an HLO text artifact. The stub validates readability and
    /// carries the text; a vendored backend would parse it here.
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<HloModuleProto, XlaError> {
        let text = std::fs::read_to_string(path.as_ref())
            .map_err(|e| XlaError(format!("reading {:?}: {e}", path.as_ref())))?;
        Ok(HloModuleProto { text })
    }

    /// The HLO text length in bytes (diagnostics).
    pub fn text_len(&self) -> usize {
        self.text.len()
    }
}

/// A computation handle wrapping a parsed HLO module.
#[derive(Debug)]
pub struct XlaComputation {
    _proto_len: usize,
}

impl XlaComputation {
    /// Wrap a parsed HLO module as a compilable computation.
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _proto_len: proto.text_len() }
    }
}

/// The PJRT client. Construction succeeds (the host side is real); only
/// [`compile`](PjRtClient::compile) reports the missing device backend.
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// Create the CPU client.
    pub fn cpu() -> Result<PjRtClient, XlaError> {
        Ok(PjRtClient { _private: () })
    }

    /// Platform name for diagnostics.
    pub fn platform_name(&self) -> String {
        "stub-cpu (no PJRT backend vendored)".to_string()
    }

    /// Compile a computation. The stub has no device backend, so this
    /// always reports unavailability; callers gate on artifact presence
    /// before reaching here.
    pub fn compile(
        &self,
        _comp: &XlaComputation,
    ) -> Result<PjRtLoadedExecutable, XlaError> {
        Err(unavailable("compile"))
    }
}

/// A compiled executable. Uninhabited in the stub — [`PjRtClient::compile`]
/// never succeeds, so no code path can hold one; its methods exist only to
/// typecheck the runtime layer.
#[derive(Debug)]
pub enum PjRtLoadedExecutable {}

impl PjRtLoadedExecutable {
    /// Execute with the given argument literals, returning per-device
    /// output buffers.
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        match *self {}
    }
}

/// A device buffer. Uninhabited in the stub (see [`PjRtLoadedExecutable`]).
#[derive(Debug)]
pub enum PjRtBuffer {}

impl PjRtBuffer {
    /// Copy the buffer back to a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        match *self {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_create_checks_size() {
        let ok = Literal::create_from_shape_and_untyped_data(
            ElementType::F32,
            &[2, 2],
            &[0u8; 16],
        );
        assert!(ok.is_ok());
        let bad = Literal::create_from_shape_and_untyped_data(
            ElementType::F32,
            &[2, 2],
            &[0u8; 12],
        );
        assert!(bad.is_err());
    }

    #[test]
    fn literal_roundtrip_and_shape() {
        let vals = [1.5f32, -2.0, 3.25];
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[3], &bytes)
                .unwrap();
        assert_eq!(lit.element_count(), 3);
        assert_eq!(lit.array_shape().unwrap().dims(), &[3i64]);
        assert_eq!(lit.to_vec::<f32>().unwrap(), vals);
        assert!(lit.to_vec::<i32>().is_err(), "dtype mismatch must error");
    }

    #[test]
    fn tuple_literal_unpacks() {
        let a = Literal::create_from_shape_and_untyped_data(
            ElementType::S32,
            &[1],
            &7i32.to_le_bytes(),
        )
        .unwrap();
        let t = Literal::Tuple(vec![a.clone()]);
        assert!(t.array_shape().is_err());
        let elems = t.to_tuple().unwrap();
        assert_eq!(elems.len(), 1);
        assert_eq!(elems[0].to_vec::<i32>().unwrap(), vec![7]);
        assert!(a.to_tuple().is_err(), "array literal is not a tuple");
    }

    #[test]
    fn client_constructs_compile_reports_stub() {
        let c = PjRtClient::cpu().unwrap();
        assert!(c.platform_name().contains("stub"));
        let proto = HloModuleProto { text: "HloModule m".into() };
        let comp = XlaComputation::from_proto(&proto);
        let e = c.compile(&comp).unwrap_err();
        assert!(e.to_string().contains("no PJRT backend"), "{e}");
    }
}
