//! Typed experiment identifiers: the closed vocabulary of the paper's
//! experiment matrix — {PEFT method} × {target modules} × {metric} — as
//! enums, plus [`VariantId`], the parsed form of an artifact variant name.
//!
//! These replace the stringly-typed dispatch the coordinator used to do
//! (`method == "sdt"`, `metric == "rouge"`, `arch_of` longest-suffix
//! matching): every variant name is parsed ONCE into a `VariantId`, and all
//! downstream code matches on enums. The suffix vocabulary mirrors
//! python/compile/configs.py::PEFTS — the two sides share the naming
//! contract `<arch>_<peft_suffix>`.

use crate::{bail, err};
use crate::error::Result;

/// Which weight matrices a LoRA/DoRA adapter targets (paper Sec. 4.2:
/// LinProj ≥ Both > SSM-only).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Target {
    /// S6-internal projections (x_proj, dt_proj).
    Ssm,
    /// Input linear projections (W_in,x / W_in,z).
    LinProj,
    /// Output projection only (W_out).
    Out,
    /// LinProj + SSM.
    Both,
}

impl Target {
    /// Variant-name fragment (`lora_<fragment>`).
    pub fn suffix(self) -> &'static str {
        match self {
            Target::Ssm => "ssm",
            Target::LinProj => "lin",
            Target::Out => "out",
            Target::Both => "both",
        }
    }

    /// Table label (paper's "Target" column).
    pub fn label(self) -> &'static str {
        match self {
            Target::Ssm => "SSM",
            Target::LinProj => "LinProj",
            Target::Out => "Out",
            Target::Both => "Both",
        }
    }

    /// Manifest `peft.targets[0]` vocabulary (python configs.py).
    fn from_manifest(s: &str) -> Option<Target> {
        match s {
            "ssm" => Some(Target::Ssm),
            "linproj" => Some(Target::LinProj),
            "out" => Some(Target::Out),
            "both" => Some(Target::Both),
            _ => None,
        }
    }
}

/// Every PEFT method the artifact set exports (Table 1 rows + the S4
/// variants of Fig. 2 / Table 19).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PeftMethod {
    /// Full fine-tuning (every trainable leaf).
    Full,
    /// LoRA adapters on the target modules.
    Lora(Target),
    /// DoRA (LoRA + magnitude column rescaling).
    Dora(Target),
    /// Bias-only tuning.
    BitFit,
    /// Soft prompt tokens at the input.
    Prompt,
    /// Prefix tokens inside each block.
    Prefix,
    /// Trained initial SSM state h0 (Table 14).
    InitState,
    /// Additional-scan state dims (paper Sec. 4.3).
    AddScan,
    /// Selective-dimension tuning (paper Alg. 1).
    Sdt,
    /// SDT on SSM modules + LoRA on projections (headline recipe).
    SdtLora,
    /// S4-specific LoRA on the projection weights (`s4_lora_proj`).
    S4LoraProj,
    /// S4-specific LoRA on projection + A_log/C (`s4_lora_ssm`).
    S4LoraSsm,
}

/// All methods, in suffix-lookup order.
const ALL_METHODS: &[PeftMethod] = &[
    PeftMethod::Full,
    PeftMethod::Lora(Target::Ssm),
    PeftMethod::Lora(Target::LinProj),
    PeftMethod::Lora(Target::Out),
    PeftMethod::Lora(Target::Both),
    PeftMethod::Dora(Target::Ssm),
    PeftMethod::Dora(Target::LinProj),
    PeftMethod::Dora(Target::Out),
    PeftMethod::Dora(Target::Both),
    PeftMethod::BitFit,
    PeftMethod::Prompt,
    PeftMethod::Prefix,
    PeftMethod::InitState,
    PeftMethod::AddScan,
    PeftMethod::Sdt,
    PeftMethod::SdtLora,
    PeftMethod::S4LoraProj,
    PeftMethod::S4LoraSsm,
];

impl PeftMethod {
    /// Every method, in suffix-lookup order.
    pub fn all() -> &'static [PeftMethod] {
        ALL_METHODS
    }

    /// The variant-name suffix (python configs.py PEFTS key).
    pub fn suffix(self) -> &'static str {
        match self {
            PeftMethod::Full => "full",
            PeftMethod::Lora(Target::Ssm) => "lora_ssm",
            PeftMethod::Lora(Target::LinProj) => "lora_lin",
            PeftMethod::Lora(Target::Out) => "lora_out",
            PeftMethod::Lora(Target::Both) => "lora_both",
            PeftMethod::Dora(Target::Ssm) => "dora_ssm",
            PeftMethod::Dora(Target::LinProj) => "dora_lin",
            PeftMethod::Dora(Target::Out) => "dora_out",
            PeftMethod::Dora(Target::Both) => "dora_both",
            PeftMethod::BitFit => "bitfit",
            PeftMethod::Prompt => "prompt",
            PeftMethod::Prefix => "prefix",
            PeftMethod::InitState => "initstate",
            PeftMethod::AddScan => "addscan",
            PeftMethod::Sdt => "sdt",
            PeftMethod::SdtLora => "sdtlora",
            PeftMethod::S4LoraProj => "s4_lora_proj",
            PeftMethod::S4LoraSsm => "s4_lora_ssm",
        }
    }

    /// Inverse of [`PeftMethod::suffix`].
    pub fn from_suffix(s: &str) -> Option<PeftMethod> {
        ALL_METHODS.iter().find(|m| m.suffix() == s).copied()
    }

    /// Human-readable method name (paper's "Method" column).
    pub fn label(self) -> &'static str {
        match self {
            PeftMethod::Full => "Full Fine-Tuning",
            PeftMethod::Lora(_) => "LoRA",
            PeftMethod::Dora(_) => "DoRA",
            PeftMethod::BitFit => "BitFit",
            PeftMethod::Prompt => "Prompt Tuning",
            PeftMethod::Prefix => "Prefix-Tuning",
            PeftMethod::InitState => "Initial-State Tuning",
            PeftMethod::AddScan => "Additional-Scan",
            PeftMethod::Sdt => "SDT",
            PeftMethod::SdtLora => "SDT & LoRA",
            PeftMethod::S4LoraProj => "LoRA (S4 proj)",
            PeftMethod::S4LoraSsm => "LoRA (S4 SSM)",
        }
    }

    /// Adapter target, when the method is a LoRA family member.
    pub fn target(self) -> Option<Target> {
        match self {
            PeftMethod::Lora(t) | PeftMethod::Dora(t) => Some(t),
            _ => None,
        }
    }

    /// Paper's "Target" column for EVERY method (display only).
    pub fn target_label(self) -> &'static str {
        match self {
            PeftMethod::Lora(t) | PeftMethod::Dora(t) => t.label(),
            PeftMethod::Prefix
            | PeftMethod::InitState
            | PeftMethod::AddScan
            | PeftMethod::Sdt
            | PeftMethod::SdtLora
            | PeftMethod::S4LoraProj
            | PeftMethod::S4LoraSsm => "SSM",
            PeftMethod::Full | PeftMethod::BitFit => "Both",
            PeftMethod::Prompt => "Other",
        }
    }

    /// Methods that run the SDT warmup/selection stage (paper Alg. 1).
    pub fn is_sdt(self) -> bool {
        matches!(self, PeftMethod::Sdt | PeftMethod::SdtLora)
    }

    /// Methods whose trained adapters must be merged before decode.
    pub fn uses_lora(self) -> bool {
        matches!(
            self,
            PeftMethod::Lora(_)
                | PeftMethod::Dora(_)
                | PeftMethod::SdtLora
                | PeftMethod::S4LoraProj
                | PeftMethod::S4LoraSsm
        )
    }

    /// Parse the manifest's `peft` block (`method` string + `targets` list,
    /// python aot.py vocabulary) into the typed method.
    pub fn from_manifest(method: &str, targets: &[String]) -> Result<PeftMethod> {
        let m = match method {
            "full" => PeftMethod::Full,
            "bitfit" => PeftMethod::BitFit,
            "prompt" => PeftMethod::Prompt,
            "prefix" => PeftMethod::Prefix,
            "initstate" => PeftMethod::InitState,
            "addscan" => PeftMethod::AddScan,
            "sdt" => PeftMethod::Sdt,
            "sdtlora" => PeftMethod::SdtLora,
            "lora" | "dora" => {
                let t0 = targets.first().map(String::as_str).unwrap_or("");
                if t0 == "s4w" {
                    // configs.py: ["s4w"] = proj-only, ["s4w","A_log","C"] = ssm
                    if targets.len() > 1 {
                        PeftMethod::S4LoraSsm
                    } else {
                        PeftMethod::S4LoraProj
                    }
                } else {
                    let t = Target::from_manifest(t0)
                        .ok_or_else(|| err!("unknown LoRA target {t0:?}"))?;
                    if method == "lora" {
                        PeftMethod::Lora(t)
                    } else {
                        PeftMethod::Dora(t)
                    }
                }
            }
            other => bail!("unknown PEFT method {other:?}"),
        };
        Ok(m)
    }
}

impl std::fmt::Display for PeftMethod {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.suffix())
    }
}

impl std::str::FromStr for PeftMethod {
    type Err = crate::error::Error;
    fn from_str(s: &str) -> Result<Self> {
        PeftMethod::from_suffix(s).ok_or_else(|| err!("unknown PEFT suffix {s:?}"))
    }
}

/// A parsed `<arch>_<peft_suffix>` variant name. Replaces the old
/// `arch_of` heuristic (longest `_full`-variant prefix match against the
/// manifest): the method suffix vocabulary is closed, so the split is
/// unambiguous and needs no manifest.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct VariantId {
    /// Architecture preset name, e.g. "mamba1_xs".
    pub arch: String,
    /// The PEFT method encoded in the name suffix.
    pub method: PeftMethod,
}

impl VariantId {
    /// Assemble an id from parts.
    pub fn new(arch: impl Into<String>, method: PeftMethod) -> Self {
        VariantId { arch: arch.into(), method }
    }

    /// Split a variant name on its longest known method suffix.
    pub fn parse(name: &str) -> Result<VariantId> {
        let mut best: Option<(usize, PeftMethod)> = None;
        for m in ALL_METHODS {
            let suf = m.suffix();
            if name.len() > suf.len() + 1
                && name.ends_with(suf)
                && name.as_bytes()[name.len() - suf.len() - 1] == b'_'
                && best.map_or(true, |(l, _)| suf.len() > l)
            {
                best = Some((suf.len(), *m));
            }
        }
        let (len, method) =
            best.ok_or_else(|| err!("variant {name:?} has no recognized PEFT suffix"))?;
        Ok(VariantId { arch: name[..name.len() - len - 1].to_string(), method })
    }

    /// Reassemble the artifact variant name.
    pub fn name(&self) -> String {
        format!("{}_{}", self.arch, self.method.suffix())
    }

    /// The decode-capable variant serving this architecture's fine-tuned
    /// weights after adapter merging.
    pub fn decode_variant(&self) -> String {
        format!("{}_full", self.arch)
    }
}

impl std::fmt::Display for VariantId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.name())
    }
}

impl std::str::FromStr for VariantId {
    type Err = crate::error::Error;
    fn from_str(s: &str) -> Result<Self> {
        VariantId::parse(s)
    }
}

/// Main evaluation metric of a dataset. Replaces the `"rouge"`/`"exec"`
/// string ids that eval and the coordinator used to compare against.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Metric {
    /// Classification accuracy.
    Acc,
    /// Matthews correlation (CoLA).
    Matthews,
    /// ROUGE-L (SAMSum).
    Rouge,
    /// BLEU + METEOR (DART); BLEU is the headline number.
    BleuMeteor,
    /// Execution accuracy against the mini database (Spider).
    Exec,
}

impl Metric {
    /// Stable metric id (record `scores` keys, CLI).
    pub fn name(self) -> &'static str {
        match self {
            Metric::Acc => "acc",
            Metric::Matthews => "matthews",
            Metric::Rouge => "rouge",
            Metric::BleuMeteor => "bleu_meteor",
            Metric::Exec => "exec",
        }
    }

    /// Inverse of [`Metric::name`].
    pub fn parse(s: &str) -> Option<Metric> {
        match s {
            "acc" => Some(Metric::Acc),
            "matthews" => Some(Metric::Matthews),
            "rouge" => Some(Metric::Rouge),
            "bleu_meteor" => Some(Metric::BleuMeteor),
            "exec" => Some(Metric::Exec),
            _ => None,
        }
    }

    /// True when the metric is computed from generated text (decode path)
    /// rather than classification logits.
    pub fn generative(self) -> bool {
        matches!(self, Metric::Rouge | Metric::BleuMeteor | Metric::Exec)
    }

    /// Pick the headline number out of a generation-score bundle.
    pub fn main_gen_score(self, g: &crate::eval::GenScores) -> f64 {
        match self {
            Metric::Rouge => g.rougel,
            Metric::Exec => g.exec_acc,
            _ => g.bleu,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Every variant name python/compile/configs.py exports (the manifest
    /// contract). An integration test re-checks this against the real
    /// manifest when artifacts are present.
    const MANIFEST_NAMES: &[&str] = &[
        // mamba1_xs × MAMBA1_PEFTS
        "mamba1_xs_full", "mamba1_xs_lora_lin", "mamba1_xs_lora_ssm",
        "mamba1_xs_lora_both", "mamba1_xs_lora_out", "mamba1_xs_dora_lin",
        "mamba1_xs_dora_ssm", "mamba1_xs_dora_both", "mamba1_xs_bitfit",
        "mamba1_xs_prompt", "mamba1_xs_prefix", "mamba1_xs_initstate",
        "mamba1_xs_addscan", "mamba1_xs_sdt", "mamba1_xs_sdtlora",
        // mamba1_s
        "mamba1_s_full", "mamba1_s_sdtlora", "mamba1_s_lora_lin",
        // mamba2_xs × MAMBA2_PEFTS
        "mamba2_xs_full", "mamba2_xs_lora_lin", "mamba2_xs_lora_ssm",
        "mamba2_xs_sdt", "mamba2_xs_sdtlora",
        // s4reg × S4REG_PEFTS (+ the s4reg_t target model)
        "s4reg_full", "s4reg_s4_lora_proj", "s4reg_s4_lora_ssm",
        "s4reg_sdt", "s4reg_sdtlora", "s4reg_t_full",
        // s4lm × S4LM_PEFTS
        "s4lm_full", "s4lm_s4_lora_proj", "s4lm_sdt", "s4lm_sdtlora",
        // hybrid_xs × HYBRID_PEFTS
        "hybrid_xs_full", "hybrid_xs_lora_lin", "hybrid_xs_dora_lin",
        "hybrid_xs_bitfit", "hybrid_xs_prompt", "hybrid_xs_prefix",
        "hybrid_xs_addscan", "hybrid_xs_sdt", "hybrid_xs_sdtlora",
    ];

    #[test]
    fn variant_id_roundtrips_every_manifest_name() {
        for name in MANIFEST_NAMES {
            let vid = VariantId::parse(name)
                .unwrap_or_else(|e| panic!("{name}: {e}"));
            assert_eq!(vid.name(), *name, "round-trip failed");
            assert!(!vid.arch.is_empty());
        }
    }

    #[test]
    fn variant_id_splits_arch_correctly() {
        let v = VariantId::parse("mamba1_xs_sdtlora").unwrap();
        assert_eq!(v.arch, "mamba1_xs");
        assert_eq!(v.method, PeftMethod::SdtLora);
        assert_eq!(v.decode_variant(), "mamba1_xs_full");
        // longest-suffix: s4_lora_ssm, not lora_ssm
        let v = VariantId::parse("s4reg_s4_lora_ssm").unwrap();
        assert_eq!(v.arch, "s4reg");
        assert_eq!(v.method, PeftMethod::S4LoraSsm);
        // trailing arch segments survive
        assert_eq!(VariantId::parse("s4reg_t_full").unwrap().arch, "s4reg_t");
        assert_eq!(VariantId::parse("mamba1_s_lora_lin").unwrap().arch, "mamba1_s");
    }

    #[test]
    fn variant_id_rejects_unknown() {
        assert!(VariantId::parse("nonexistent_arch_x").is_err());
        assert!(VariantId::parse("full").is_err()); // no arch prefix
        assert!(VariantId::parse("").is_err());
    }

    #[test]
    fn method_suffixes_are_unique_and_roundtrip() {
        for m in PeftMethod::all() {
            assert_eq!(PeftMethod::from_suffix(m.suffix()), Some(*m));
        }
        let mut sufs: Vec<&str> = PeftMethod::all().iter().map(|m| m.suffix()).collect();
        sufs.sort_unstable();
        sufs.dedup();
        assert_eq!(sufs.len(), PeftMethod::all().len());
    }

    #[test]
    fn manifest_method_mapping() {
        let lin = vec!["linproj".to_string()];
        assert_eq!(
            PeftMethod::from_manifest("lora", &lin).unwrap(),
            PeftMethod::Lora(Target::LinProj)
        );
        assert_eq!(
            PeftMethod::from_manifest("dora", &["both".to_string()]).unwrap(),
            PeftMethod::Dora(Target::Both)
        );
        assert_eq!(
            PeftMethod::from_manifest("lora", &["s4w".to_string()]).unwrap(),
            PeftMethod::S4LoraProj
        );
        let s4ssm: Vec<String> =
            ["s4w", "A_log", "C"].iter().map(|s| s.to_string()).collect();
        assert_eq!(
            PeftMethod::from_manifest("lora", &s4ssm).unwrap(),
            PeftMethod::S4LoraSsm
        );
        assert_eq!(PeftMethod::from_manifest("sdtlora", &[]).unwrap(), PeftMethod::SdtLora);
        assert!(PeftMethod::from_manifest("nope", &[]).is_err());
        assert!(PeftMethod::from_manifest("lora", &["bogus".to_string()]).is_err());
    }

    #[test]
    fn method_predicates() {
        assert!(PeftMethod::Sdt.is_sdt());
        assert!(PeftMethod::SdtLora.is_sdt());
        assert!(!PeftMethod::Lora(Target::Both).is_sdt());
        assert!(PeftMethod::SdtLora.uses_lora());
        assert!(PeftMethod::Dora(Target::LinProj).uses_lora());
        assert!(!PeftMethod::BitFit.uses_lora());
        assert_eq!(PeftMethod::Lora(Target::LinProj).target(), Some(Target::LinProj));
        assert_eq!(PeftMethod::Full.target(), None);
    }

    #[test]
    fn metric_roundtrip() {
        for m in [Metric::Acc, Metric::Matthews, Metric::Rouge, Metric::BleuMeteor, Metric::Exec] {
            assert_eq!(Metric::parse(m.name()), Some(m));
        }
        assert_eq!(Metric::parse("bogus"), None);
        assert!(Metric::Rouge.generative());
        assert!(!Metric::Acc.generative());
    }
}
