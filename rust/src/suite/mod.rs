//! Typed experiment suites: the paper's experiment *matrix* as a
//! first-class object, replacing the one-cell-at-a-time
//! `Pipeline::finetune` loops the bench targets used to hand-roll.
//!
//! - [`types`] — `PeftMethod` / `Target` / `Metric` / `VariantId`: the
//!   closed vocabulary every layer dispatches on (no string matching).
//! - [`record`] — `RunRecord` + JSONL sink + table pivoting.
//! - [`spec`] — declarative JSON suite files (`suite` CLI subcommand).
//! - [`Suite`] — the staged parallel runner: shared pretrained bases are
//!   built once per architecture (stage 0), then independent fine-tune
//!   cells fan out over a scoped worker pool sharing the `Engine`'s
//!   compiled-executable cache.
//!
//! ```no_run
//! # use ssm_peft::{manifest::Manifest, runtime::Engine, suite::Suite};
//! # fn main() -> ssm_peft::error::Result<()> {
//! let engine = Engine::cpu()?;
//! let manifest = Manifest::load(ssm_peft::artifacts_dir())?;
//! let records = Suite::new(&engine, &manifest)
//!     .named("demo")
//!     .grid(&["mamba1_xs_lora_lin", "mamba1_xs_bitfit"], &["glue/rte", "dart"])
//!     .cell("mamba1_xs_sdtlora", "dart")
//!     .run(2)?;
//! # Ok(()) }
//! ```

pub mod record;
pub mod spec;
pub mod types;

pub use record::{git_describe, pivot, JsonlSink, PivotCol, RunRecord};
pub use spec::SuiteSpec;
pub use types::{Metric, PeftMethod, Target, VariantId};

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::error::Result;

use crate::config::ExperimentConfig;
use crate::coordinator::Pipeline;
use crate::manifest::Manifest;
use crate::runtime::Engine;
use crate::tensor::Tensor;

fn fnv64(s: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Deterministic per-cell seed: a pure function of the suite seed and the
/// cell coordinates, so records are reproducible regardless of worker
/// scheduling and suite composition order.
pub fn cell_seed(base: u64, variant: &str, dataset: &str) -> u64 {
    base ^ fnv64(variant) ^ fnv64(dataset).rotate_left(17)
}

/// Worker count from `SSM_PEFT_WORKERS` (via the typed knob registry),
/// else the given default.
pub fn worker_count(default: usize) -> usize {
    crate::knobs::workers(default)
}

/// The engine-independent part of a suite: named cell list + template.
/// (Unit-testable without PJRT; `Suite` binds it to an engine/manifest.)
#[derive(Debug, Clone)]
pub struct SuitePlan {
    /// Suite name (JSONL file stem).
    pub name: String,
    /// Defaults each cell starts from (`cell`/`grid` clone this).
    pub template: ExperimentConfig,
    /// Fully-resolved cell configs, in composition order.
    pub cells: Vec<ExperimentConfig>,
    /// Reuse finished cells from an existing `results/<name>.jsonl`.
    pub resume: bool,
}

impl SuitePlan {
    /// Empty plan with default template.
    pub fn new(name: &str) -> SuitePlan {
        SuitePlan {
            name: name.to_string(),
            template: ExperimentConfig::default(),
            cells: Vec::new(),
            resume: false,
        }
    }

    /// Add one (variant, dataset) cell from the template, with a derived
    /// deterministic seed.
    pub fn add_cell(&mut self, variant: &str, dataset: &str) {
        let mut cfg = self.template.clone();
        cfg.variant = variant.to_string();
        cfg.dataset = dataset.to_string();
        cfg.seed = cell_seed(self.template.seed, variant, dataset);
        self.cells.push(cfg);
    }

    /// Add the full variants × datasets grid.
    pub fn add_grid(&mut self, variants: &[&str], datasets: &[&str]) {
        for v in variants {
            for d in datasets {
                self.add_cell(v, d);
            }
        }
    }

    /// Add a fully-specified cell (seed is kept as given).
    pub fn push(&mut self, cfg: ExperimentConfig) {
        self.cells.push(cfg);
    }
}

type Ckpt = Arc<BTreeMap<String, Tensor>>;

/// Builder + parallel runner for an experiment suite.
pub struct Suite<'a> {
    engine: &'a Engine,
    manifest: &'a Manifest,
    /// The engine-independent cell list being built.
    pub plan: SuitePlan,
}

impl<'a> Suite<'a> {
    /// Empty suite bound to an engine + manifest.
    pub fn new(engine: &'a Engine, manifest: &'a Manifest) -> Suite<'a> {
        Suite { engine, manifest, plan: SuitePlan::new("suite") }
    }

    /// Bind an already-built plan (spec files) to an engine + manifest.
    pub fn from_plan(engine: &'a Engine, manifest: &'a Manifest, plan: SuitePlan) -> Suite<'a> {
        Suite { engine, manifest, plan }
    }

    /// Set the suite name (JSONL file stem).
    pub fn named(mut self, name: &str) -> Self {
        self.plan.name = name.to_string();
        self
    }

    /// Set the template config future `cell`/`grid` calls start from.
    pub fn template(mut self, cfg: ExperimentConfig) -> Self {
        self.plan.template = cfg;
        self
    }

    /// Reuse finished cells from an existing `results/<name>.jsonl`.
    pub fn resume(mut self, yes: bool) -> Self {
        self.plan.resume = yes;
        self
    }

    /// Add one (variant, dataset) cell — see [`SuitePlan::add_cell`].
    pub fn cell(mut self, variant: &str, dataset: &str) -> Self {
        self.plan.add_cell(variant, dataset);
        self
    }

    /// Add the full variants × datasets grid.
    pub fn grid(mut self, variants: &[&str], datasets: &[&str]) -> Self {
        self.plan.add_grid(variants, datasets);
        self
    }

    /// Run all cells with `par` workers. Returns one record per cell, in
    /// cell order; individual cell failures become error records rather
    /// than aborting the suite. Records stream to `results/<name>.jsonl`
    /// as cells finish.
    ///
    /// Staging: distinct (arch, pretrain_steps) pairs are resolved FIRST
    /// (training or loading the shared frozen base once, never racing),
    /// then fine-tune cells fan out over `std::thread::scope` workers that
    /// share the engine's compiled-executable cache.
    pub fn run(&self, par: usize) -> Result<Vec<RunRecord>> {
        let cells = &self.plan.cells;
        if cells.is_empty() {
            return Ok(Vec::new());
        }
        let name = self.plan.name.clone();
        let git = git_describe();

        // resume: reuse finished (ok) records keyed by variant|dataset|seed
        let resumed: BTreeMap<String, RunRecord> = if self.plan.resume {
            JsonlSink::load(&name)
                .into_iter()
                .filter(|r| r.ok())
                .map(|r| (r.key(), r))
                .collect()
        } else {
            BTreeMap::new()
        };
        let sink = Mutex::new(JsonlSink::create(&name, self.plan.resume)?);

        // ---- stage 0: shared pretrained bases, once per (arch, steps) ----
        let pipeline = Pipeline::new(self.engine, self.manifest);
        let mut bases: BTreeMap<String, std::result::Result<Ckpt, String>> = BTreeMap::new();
        for cfg in cells {
            if resumed.contains_key(&record_key(cfg)) {
                continue;
            }
            // bad cells (unparseable or unknown variant) fail in run_cell
            // with a clear error; don't build a base for them
            let Ok(vid) = VariantId::parse(&cfg.variant) else { continue };
            if !self.manifest.variants.contains_key(&cfg.variant) {
                continue;
            }
            let bkey = base_key(&vid.arch, cfg.pretrain_steps);
            if !bases.contains_key(&bkey) {
                eprintln!("[suite {name}] pretraining base {bkey}");
                let r = pipeline
                    .pretrained(&vid.arch, cfg.pretrain_steps, self.plan.template.seed)
                    .map_err(|e| format!("{e:#}"));
                bases.insert(bkey, r);
            }
        }

        // ---- stage 1: fine-tune cells on a scoped worker pool ----
        let next = AtomicUsize::new(0);
        let done = AtomicUsize::new(0);
        let results: Mutex<Vec<Option<RunRecord>>> = Mutex::new(vec![None; cells.len()]);
        let par = par.clamp(1, cells.len());
        std::thread::scope(|s| {
            for _ in 0..par {
                s.spawn(|| {
                    let p = Pipeline::new(self.engine, self.manifest);
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= cells.len() {
                            break;
                        }
                        let cfg = &cells[i];
                        let (rec, cached) = match resumed.get(&record_key(cfg)) {
                            Some(r) => (r.clone(), true),
                            None => (run_cell(&p, &name, cfg, &bases, &git), false),
                        };
                        if !cached {
                            if let Ok(mut sk) = sink.lock() {
                                sk.write(&rec).ok();
                            }
                        }
                        let n = done.fetch_add(1, Ordering::Relaxed) + 1;
                        eprintln!(
                            "[suite {name}] {n}/{} {}/{} {} ({:.1}s{})",
                            cells.len(),
                            rec.variant,
                            rec.dataset,
                            match &rec.error {
                                Some(e) => format!("FAILED: {e}"),
                                None => format!("metric={:.4}", rec.metric),
                            },
                            rec.total_s,
                            if cached { ", resumed" } else { "" },
                        );
                        // a panicked sibling must not wedge result collection
                        results.lock().unwrap_or_else(std::sync::PoisonError::into_inner)[i] =
                            Some(rec);
                    }
                });
            }
        });

        let out: Vec<RunRecord> = results
            .into_inner()
            .unwrap_or_else(std::sync::PoisonError::into_inner)
            .into_iter()
            .enumerate()
            .map(|(i, r)| {
                // every index is written by exactly one worker; if a worker
                // died anyway, surface a failed record instead of panicking
                r.unwrap_or_else(|| {
                    RunRecord::failed(
                        &name,
                        &cells[i],
                        "worker produced no record for this cell".into(),
                        0.0,
                        &git,
                    )
                })
            })
            .collect();
        Ok(out)
    }
}

fn record_key(cfg: &ExperimentConfig) -> String {
    record::cell_key(&cfg.variant, &cfg.dataset, cfg.seed)
}

fn base_key(arch: &str, steps: usize) -> String {
    format!("{arch}|{steps}")
}

/// Run one cell, folding every failure mode into an error record.
fn run_cell(
    p: &Pipeline,
    suite: &str,
    cfg: &ExperimentConfig,
    bases: &BTreeMap<String, std::result::Result<Ckpt, String>>,
    git: &str,
) -> RunRecord {
    let t0 = Instant::now();
    let vid = match VariantId::parse(&cfg.variant) {
        Ok(v) => v,
        Err(e) => {
            return RunRecord::failed(suite, cfg, format!("{e:#}"), t0.elapsed().as_secs_f64(), git)
        }
    };
    // fail typo'd variants up front with the manifest's clear error
    // (lists available names) instead of a late artifact-load failure
    if let Err(e) = p.manifest.variant(&cfg.variant) {
        return RunRecord::failed(suite, cfg, format!("{e:#}"), t0.elapsed().as_secs_f64(), git);
    }
    let base = match bases.get(&base_key(&vid.arch, cfg.pretrain_steps)) {
        Some(Ok(b)) => b,
        Some(Err(msg)) => {
            return RunRecord::failed(
                suite,
                cfg,
                format!("pretrain failed: {msg}"),
                t0.elapsed().as_secs_f64(),
                git,
            )
        }
        None => {
            return RunRecord::failed(
                suite,
                cfg,
                "no pretrained base staged".into(),
                t0.elapsed().as_secs_f64(),
                git,
            )
        }
    };
    match p.finetune_with_base(cfg, base) {
        Ok(out) => RunRecord::from_outcome(suite, cfg, &out, t0.elapsed().as_secs_f64(), git),
        Err(e) => RunRecord::failed(suite, cfg, format!("{e:#}"), t0.elapsed().as_secs_f64(), git),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_grid_expands_and_derives_seeds() {
        let mut plan = SuitePlan::new("t");
        plan.template.seed = 5;
        plan.add_grid(&["a_full", "b_full"], &["dart", "samsum"]);
        assert_eq!(plan.cells.len(), 4);
        assert_eq!(plan.cells[0].variant, "a_full");
        assert_eq!(plan.cells[0].dataset, "dart");
        assert_eq!(plan.cells[3].variant, "b_full");
        assert_eq!(plan.cells[3].dataset, "samsum");
        // deterministic: rebuilding yields identical seeds
        let mut plan2 = SuitePlan::new("t");
        plan2.template.seed = 5;
        plan2.add_grid(&["a_full", "b_full"], &["dart", "samsum"]);
        let s1: Vec<u64> = plan.cells.iter().map(|c| c.seed).collect();
        let s2: Vec<u64> = plan2.cells.iter().map(|c| c.seed).collect();
        assert_eq!(s1, s2);
        // ...and composition-order independent for a given cell
        assert_eq!(plan.cells[3].seed, cell_seed(5, "b_full", "samsum"));
    }

    #[test]
    fn cell_seed_depends_on_all_coordinates() {
        let s = cell_seed(0, "v", "d");
        assert_ne!(s, cell_seed(1, "v", "d"));
        assert_ne!(s, cell_seed(0, "w", "d"));
        assert_ne!(s, cell_seed(0, "v", "e"));
        // variant/dataset are not interchangeable (rotate breaks symmetry)
        assert_ne!(cell_seed(0, "a", "b"), cell_seed(0, "b", "a"));
    }

    #[test]
    fn worker_count_floor() {
        assert!(worker_count(2) >= 1);
    }
}
