//! Declarative suite files: a JSON description of an experiment grid that
//! the `suite` CLI subcommand (and any bench) can run. Schema documented in
//! rust/docs/suite.md.
//!
//! ```json
//! {
//!   "name": "table1",
//!   "par": 2,
//!   "resume": false,
//!   "template": {"epochs": 2, "lr": 0.003},
//!   "variants": ["mamba1_xs_lora_lin", "mamba1_xs_bitfit"],
//!   "datasets": ["glue/rte", "dart"],
//!   "cells": [
//!     {"variant": "mamba1_xs_sdtlora", "dataset": "dart",
//!      "overrides": {"sdt.warmup_batches": 8}}
//!   ]
//! }
//! ```
//!
//! `variants` × `datasets` expand as a grid; `cells` append individual
//! cells with optional per-cell overrides. Unknown keys anywhere are
//! rejected (typos fail loudly, mirroring `ExperimentConfig::set`).

use crate::{bail, err};
use crate::error::Result;

use crate::config::ExperimentConfig;
use crate::json::{self, Value};

use super::{cell_seed, SuitePlan};

/// A parsed suite file: the plan plus runner settings.
#[derive(Debug)]
pub struct SuiteSpec {
    /// The parsed cell list + template.
    pub plan: SuitePlan,
    /// Worker count for `Suite::run` (CLI `par=` overrides).
    pub par: usize,
}

const TOP_KEYS: &[&str] =
    &["name", "par", "resume", "template", "variants", "datasets", "cells"];
const CELL_KEYS: &[&str] = &["variant", "dataset", "overrides"];

fn str_list(v: &Value, key: &str) -> Result<Vec<String>> {
    let arr = v.as_arr().ok_or_else(|| err!("{key}: expected array"))?;
    arr.iter()
        .map(|x| {
            x.as_str()
                .map(String::from)
                .ok_or_else(|| err!("{key}: expected array of strings"))
        })
        .collect()
}

impl SuiteSpec {
    /// Load and parse a suite file.
    pub fn from_file(path: &str) -> Result<SuiteSpec> {
        let src = std::fs::read_to_string(path)?;
        let v = json::parse(&src).map_err(|e| err!("{path}: {e}"))?;
        Self::from_json(&v)
    }

    /// Parse a suite spec; unknown keys anywhere are rejected.
    pub fn from_json(v: &Value) -> Result<SuiteSpec> {
        let obj = match v {
            Value::Obj(m) => m,
            _ => bail!("suite spec must be an object"),
        };
        for k in obj.keys() {
            if !TOP_KEYS.contains(&k.as_str()) {
                bail!("unknown suite key {k:?} (expected one of {TOP_KEYS:?})");
            }
        }
        let name = match obj.get("name") {
            Some(n) => n.as_str().ok_or_else(|| err!("name: expected string"))?.to_string(),
            None => "suite".to_string(),
        };
        let par = obj
            .get("par")
            .map(|p| p.as_f64().ok_or_else(|| err!("par: expected number")))
            .transpose()?
            .map(|p| p as usize)
            .unwrap_or(2);
        let resume = obj
            .get("resume")
            .map(|r| r.as_bool().ok_or_else(|| err!("resume: expected bool")))
            .transpose()?
            .unwrap_or(false);
        let template = match obj.get("template") {
            Some(t) => ExperimentConfig::from_json(t)?,
            None => ExperimentConfig::default(),
        };

        let mut plan = SuitePlan::new(&name);
        plan.template = template;
        plan.resume = resume;

        let variants = obj.get("variants").map(|v| str_list(v, "variants")).transpose()?;
        let datasets = obj.get("datasets").map(|v| str_list(v, "datasets")).transpose()?;
        match (variants, datasets) {
            (Some(vs), Some(ds)) => {
                for variant in &vs {
                    for dataset in &ds {
                        plan.add_cell(variant, dataset);
                    }
                }
            }
            (None, None) => {}
            _ => bail!("variants and datasets must be given together (grid expansion)"),
        }

        if let Some(cells) = obj.get("cells") {
            let arr = cells.as_arr().ok_or_else(|| err!("cells: expected array"))?;
            for (i, cell) in arr.iter().enumerate() {
                let cobj = match cell {
                    Value::Obj(m) => m,
                    _ => bail!("cells[{i}]: expected object"),
                };
                for k in cobj.keys() {
                    if !CELL_KEYS.contains(&k.as_str()) {
                        bail!("cells[{i}]: unknown key {k:?}");
                    }
                }
                let variant = cobj
                    .get("variant")
                    .and_then(Value::as_str)
                    .ok_or_else(|| err!("cells[{i}]: missing variant"))?;
                let dataset = cobj
                    .get("dataset")
                    .and_then(Value::as_str)
                    .ok_or_else(|| err!("cells[{i}]: missing dataset"))?;
                let mut cfg = plan.template.clone();
                cfg.variant = variant.to_string();
                cfg.dataset = dataset.to_string();
                cfg.seed = cell_seed(plan.template.seed, variant, dataset);
                if let Some(ov) = cobj.get("overrides") {
                    let ovm = match ov {
                        Value::Obj(m) => m,
                        _ => bail!("cells[{i}].overrides: expected object"),
                    };
                    for (k, val) in ovm {
                        cfg.set(k, val).map_err(|e| err!("cells[{i}]: {e}"))?;
                    }
                }
                plan.push(cfg);
            }
        }

        if plan.cells.is_empty() {
            bail!("suite spec declares no cells (need variants×datasets or cells)");
        }
        Ok(SuiteSpec { plan, par: par.max(1) })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(src: &str) -> Result<SuiteSpec> {
        SuiteSpec::from_json(&json::parse(src).unwrap())
    }

    #[test]
    fn full_spec_parses() {
        let spec = parse(
            r#"{
              "name": "t1", "par": 3, "resume": true,
              "template": {"epochs": 2, "lr": 0.003, "n_train": 64},
              "variants": ["mamba1_xs_lora_lin", "mamba1_xs_bitfit"],
              "datasets": ["glue/rte", "dart"],
              "cells": [{"variant": "mamba1_xs_sdtlora", "dataset": "dart",
                         "overrides": {"sdt.warmup_batches": 8, "seed": 42}}]
            }"#,
        )
        .unwrap();
        assert_eq!(spec.plan.name, "t1");
        assert_eq!(spec.par, 3);
        assert!(spec.plan.resume);
        assert_eq!(spec.plan.cells.len(), 5); // 2×2 grid + 1 cell
        assert_eq!(spec.plan.cells[0].variant, "mamba1_xs_lora_lin");
        assert_eq!(spec.plan.cells[0].dataset, "glue/rte");
        assert_eq!(spec.plan.cells[0].epochs, 2);
        let extra = &spec.plan.cells[4];
        assert_eq!(extra.variant, "mamba1_xs_sdtlora");
        assert_eq!(extra.sdt.warmup_batches, 8);
        assert_eq!(extra.seed, 42); // explicit override beats derived seed
    }

    #[test]
    fn unknown_keys_rejected() {
        assert!(parse(r#"{"nope": 1, "variants": ["v_full"], "datasets": ["dart"]}"#).is_err());
        assert!(parse(
            r#"{"template": {"bogus_key": 1}, "variants": ["v_full"], "datasets": ["dart"]}"#
        )
        .is_err());
        assert!(parse(
            r#"{"cells": [{"variant": "v_full", "dataset": "dart", "extra": 1}]}"#
        )
        .is_err());
        assert!(parse(
            r#"{"cells": [{"variant": "v_full", "dataset": "dart",
                           "overrides": {"not_a_key": 1}}]}"#
        )
        .is_err());
    }

    #[test]
    fn grid_requires_both_axes() {
        assert!(parse(r#"{"variants": ["v_full"]}"#).is_err());
        assert!(parse(r#"{"datasets": ["dart"]}"#).is_err());
    }

    #[test]
    fn empty_spec_rejected() {
        assert!(parse(r#"{"name": "empty"}"#).is_err());
    }

    #[test]
    fn derived_seeds_are_deterministic_and_distinct() {
        let src = r#"{"variants": ["a_full", "b_full"], "datasets": ["dart", "samsum"]}"#;
        let s1 = parse(src).unwrap();
        let s2 = parse(src).unwrap();
        let seeds1: Vec<u64> = s1.plan.cells.iter().map(|c| c.seed).collect();
        let seeds2: Vec<u64> = s2.plan.cells.iter().map(|c| c.seed).collect();
        assert_eq!(seeds1, seeds2);
        let mut uniq = seeds1.clone();
        uniq.sort_unstable();
        uniq.dedup();
        assert_eq!(uniq.len(), 4, "per-cell seeds should differ: {seeds1:?}");
    }
}
