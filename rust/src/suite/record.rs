//! Structured per-cell results: [`RunRecord`] (one JSON object per finished
//! experiment cell), the append-only JSONL sink under `results/`, and the
//! pivot-table builder that regenerates the paper tables from records.
//!
//! The JSONL schema is documented in rust/docs/suite.md. Records are
//! self-describing (variant/dataset/seed key + git stamp), so a table can
//! be rebuilt — or a suite resumed — from the file alone.

use std::collections::BTreeMap;
use std::io::Write as _;
use std::path::{Path, PathBuf};

use crate::err;
use crate::error::{Context, Result};

use crate::bench::TablePrinter;
use crate::config::ExperimentConfig;
use crate::coordinator::Outcome;
use crate::json::{self, Value};

/// One experiment cell's result, as written to the JSONL stream.
#[derive(Debug, Clone)]
pub struct RunRecord {
    /// Suite name (JSONL file stem).
    pub suite: String,
    /// Artifact variant, e.g. `"mamba1_xs_sdtlora"`.
    pub variant: String,
    /// Dataset name, e.g. `"glue/rte"`.
    pub dataset: String,
    /// The cell's deterministic seed ([`super::cell_seed`]).
    pub seed: u64,
    /// Headline metric value (0.0 when the cell failed).
    pub metric: f64,
    /// All computed scores by name.
    pub scores: BTreeMap<String, f64>,
    /// Trainable-parameter budget, percent.
    pub budget_pct: f64,
    /// Learning rate picked by the grid search.
    pub chosen_lr: f32,
    /// Optimizer steps taken.
    pub steps: usize,
    /// SDT dimension-selection seconds (0 for non-SDT methods).
    pub dim_select_s: f64,
    /// Mean seconds per training epoch.
    pub epoch_s: f64,
    /// Wall-clock seconds for the whole cell (grid search + train + eval).
    pub total_s: f64,
    /// `git describe --always --dirty` at run time.
    pub git: String,
    /// Present when the cell failed; scores are empty then.
    pub error: Option<String>,
}

impl RunRecord {
    /// Build a success record from a finished cell's [`Outcome`].
    pub fn from_outcome(
        suite: &str,
        cfg: &ExperimentConfig,
        out: &Outcome,
        total_s: f64,
        git: &str,
    ) -> RunRecord {
        RunRecord {
            suite: suite.to_string(),
            variant: cfg.variant.clone(),
            dataset: cfg.dataset.clone(),
            seed: cfg.seed,
            metric: out.metric,
            scores: out.scores.clone(),
            budget_pct: out.budget_pct,
            chosen_lr: out.chosen_lr,
            steps: out.steps,
            dim_select_s: out.dim_select_s,
            epoch_s: out.epoch_s,
            total_s,
            git: git.to_string(),
            error: None,
        }
    }

    /// Build an error record for a failed cell.
    pub fn failed(
        suite: &str,
        cfg: &ExperimentConfig,
        err: String,
        total_s: f64,
        git: &str,
    ) -> RunRecord {
        RunRecord {
            suite: suite.to_string(),
            variant: cfg.variant.clone(),
            dataset: cfg.dataset.clone(),
            seed: cfg.seed,
            metric: 0.0,
            scores: BTreeMap::new(),
            budget_pct: 0.0,
            chosen_lr: 0.0,
            steps: 0,
            dim_select_s: 0.0,
            epoch_s: 0.0,
            total_s,
            git: git.to_string(),
            error: Some(err),
        }
    }

    /// True when the cell succeeded.
    pub fn ok(&self) -> bool {
        self.error.is_none()
    }

    /// Resume/dedup key: one record per (variant, dataset, seed).
    pub fn key(&self) -> String {
        cell_key(&self.variant, &self.dataset, self.seed)
    }

    /// Score lookup; an empty key means the headline metric.
    pub fn score(&self, key: &str) -> Option<f64> {
        if key.is_empty() {
            if self.ok() { Some(self.metric) } else { None }
        } else {
            self.scores.get(key).copied()
        }
    }

    /// Serialize for the JSONL stream (schema: rust/docs/suite.md).
    pub fn to_json(&self) -> Value {
        json::obj(vec![
            ("suite", json::s(&self.suite)),
            ("variant", json::s(&self.variant)),
            ("dataset", json::s(&self.dataset)),
            // stringified: derived seeds span the full u64 range, which a
            // JSON f64 number cannot round-trip (2^53 mantissa)
            ("seed", json::s(&self.seed.to_string())),
            ("metric", json::num(self.metric)),
            (
                "scores",
                Value::Obj(
                    self.scores.iter().map(|(k, v)| (k.clone(), Value::Num(*v))).collect(),
                ),
            ),
            ("budget_pct", json::num(self.budget_pct)),
            ("chosen_lr", json::num(self.chosen_lr as f64)),
            ("steps", json::num(self.steps as f64)),
            ("dim_select_s", json::num(self.dim_select_s)),
            ("epoch_s", json::num(self.epoch_s)),
            ("total_s", json::num(self.total_s)),
            ("git", json::s(&self.git)),
            (
                "error",
                match &self.error {
                    Some(e) => json::s(e),
                    None => Value::Null,
                },
            ),
        ])
    }

    /// Parse one JSONL line back into a record (resume / pivot rebuild).
    pub fn from_json(v: &Value) -> Result<RunRecord> {
        let str_of = |k: &str| {
            v.path(k).and_then(Value::as_str).map(String::from).unwrap_or_default()
        };
        let num_of = |k: &str| v.path(k).and_then(Value::as_f64).unwrap_or(0.0);
        let mut scores = BTreeMap::new();
        if let Some(Value::Obj(m)) = v.path("scores") {
            for (k, x) in m {
                if let Some(n) = x.as_f64() {
                    scores.insert(k.clone(), n);
                }
            }
        }
        if str_of("variant").is_empty() || str_of("dataset").is_empty() {
            return Err(err!("record missing variant/dataset"));
        }
        // seed is a stringified u64 (see to_json); accept a plain number
        // too for hand-written files
        let seed = v
            .path("seed")
            .and_then(Value::as_str)
            .and_then(|s| s.parse().ok())
            .or_else(|| v.path("seed").and_then(Value::as_f64).map(|n| n as u64))
            .unwrap_or(0);
        Ok(RunRecord {
            suite: str_of("suite"),
            variant: str_of("variant"),
            dataset: str_of("dataset"),
            seed,
            metric: num_of("metric"),
            scores,
            budget_pct: num_of("budget_pct"),
            chosen_lr: num_of("chosen_lr") as f32,
            steps: num_of("steps") as usize,
            dim_select_s: num_of("dim_select_s"),
            epoch_s: num_of("epoch_s"),
            total_s: num_of("total_s"),
            git: str_of("git"),
            error: v.path("error").and_then(Value::as_str).map(String::from),
        })
    }
}

/// The one definition of the (variant, dataset, seed) cell key used by
/// records AND the runner's resume lookup — keep them from drifting.
pub fn cell_key(variant: &str, dataset: &str, seed: u64) -> String {
    format!("{variant}|{dataset}|{seed}")
}

/// `git describe --always --dirty`, or "unknown" outside a work tree.
pub fn git_describe() -> String {
    std::process::Command::new("git")
        .args(["describe", "--always", "--dirty"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".to_string())
}

/// Append-only JSONL record stream (one `RunRecord` per line).
pub struct JsonlSink {
    path: PathBuf,
    file: std::fs::File,
}

impl JsonlSink {
    /// Open `results/<name>.jsonl` (append keeps prior records for resume).
    pub fn create(name: &str, append: bool) -> Result<JsonlSink> {
        Self::create_at(crate::results_dir().join(format!("{name}.jsonl")), append)
    }

    /// Open a sink at an explicit path (tests, non-default layouts).
    pub fn create_at(path: PathBuf, append: bool) -> Result<JsonlSink> {
        let file = std::fs::OpenOptions::new()
            .create(true)
            .write(true)
            .append(append)
            .truncate(!append)
            .open(&path)
            .with_context(|| format!("opening {path:?}"))?;
        Ok(JsonlSink { path, file })
    }

    /// Write one record and flush (the stream stays valid on crash).
    pub fn write(&mut self, rec: &RunRecord) -> Result<()> {
        self.write_line(&rec.to_json())
    }

    /// Append one raw JSON value as a flushed line. The serve stats stream
    /// ([`crate::serve::ServeRecord`]) shares the sink this way.
    pub fn write_line(&mut self, v: &Value) -> Result<()> {
        writeln!(self.file, "{}", json::emit(v))?;
        self.file.flush()?;
        Ok(())
    }

    /// The sink's file path.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Parse all records from `results/<name>.jsonl`; malformed lines are
    /// skipped (a crashed run may leave a torn tail line).
    pub fn load(name: &str) -> Vec<RunRecord> {
        Self::load_at(&crate::results_dir().join(format!("{name}.jsonl")))
    }

    /// Parse all records from an explicit path — see [`JsonlSink::load`].
    pub fn load_at(path: &Path) -> Vec<RunRecord> {
        let Ok(src) = std::fs::read_to_string(path) else {
            return Vec::new();
        };
        src.lines()
            .filter_map(|line| json::parse(line).ok())
            .filter_map(|v| RunRecord::from_json(&v).ok())
            .collect()
    }
}

/// One pivot-table column: a (dataset, score) pair.
#[derive(Debug, Clone)]
pub struct PivotCol {
    /// Column header in the printed table.
    pub header: String,
    /// Dataset whose records fill this column.
    pub dataset: String,
    /// Key into `RunRecord::scores`; empty = headline metric.
    pub score: String,
}

impl PivotCol {
    /// Column showing a dataset's headline metric.
    pub fn main(header: &str, dataset: &str) -> PivotCol {
        PivotCol { header: header.into(), dataset: dataset.into(), score: String::new() }
    }
    /// Column showing a named score of a dataset.
    pub fn score(header: &str, dataset: &str, score: &str) -> PivotCol {
        PivotCol { header: header.into(), dataset: dataset.into(), score: score.into() }
    }
}

/// Pivot records into a paper-style table: one row per variant (in the
/// given order, with caller-supplied label cells), one column per
/// (dataset, score), plus the parameter-budget column. Missing cells
/// render "-", failed cells "ERR".
pub fn pivot(
    records: &[RunRecord],
    label_headers: &[&str],
    rows: &[(&str, &[&str])],
    cols: &[PivotCol],
) -> TablePrinter {
    let mut headers: Vec<&str> = label_headers.to_vec();
    headers.push("params%");
    let col_headers: Vec<String> = cols.iter().map(|c| c.header.clone()).collect();
    let mut all_headers: Vec<&str> = headers.clone();
    all_headers.extend(col_headers.iter().map(String::as_str));
    let mut table = TablePrinter::new(&all_headers);

    for (variant, labels) in rows {
        let mut cells: Vec<String> = labels.iter().map(|s| s.to_string()).collect();
        let budget = records
            .iter()
            .rev()
            .find(|r| r.variant == *variant && r.ok())
            .map(|r| format!("{:.2}", r.budget_pct))
            .unwrap_or_else(|| "-".into());
        cells.push(budget);
        for col in cols {
            // prefer the latest ok record (a resumed JSONL may hold a stale
            // failed attempt before the successful re-run), else latest any
            let matches =
                |r: &&RunRecord| r.variant == *variant && r.dataset == col.dataset;
            let rec = records
                .iter()
                .rev()
                .find(|r| matches(r) && r.ok())
                .or_else(|| records.iter().rev().find(matches));
            let cell = match rec {
                None => "-".into(),
                Some(r) if !r.ok() => "ERR".into(),
                Some(r) => r
                    .score(&col.score)
                    .map(|x| format!("{x:.3}"))
                    .unwrap_or_else(|| "-".into()),
            };
            cells.push(cell);
        }
        table.row(cells);
    }
    table
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(variant: &str, dataset: &str, metric: f64) -> RunRecord {
        let mut scores = BTreeMap::new();
        scores.insert("bleu".to_string(), metric / 2.0);
        RunRecord {
            suite: "t".into(),
            variant: variant.into(),
            dataset: dataset.into(),
            // deliberately above 2^53: full-range u64 seeds must round-trip
            seed: 0xdead_beef_dead_beef,
            metric,
            scores,
            budget_pct: 1.25,
            chosen_lr: 3e-3,
            steps: 10,
            dim_select_s: 0.5,
            epoch_s: 2.0,
            total_s: 9.0,
            git: "abc123".into(),
            error: None,
        }
    }

    #[test]
    fn record_json_roundtrip() {
        let r = rec("mamba1_xs_lora_lin", "glue/rte", 0.75);
        let back = RunRecord::from_json(&r.to_json()).unwrap();
        assert_eq!(back.variant, r.variant);
        assert_eq!(back.dataset, r.dataset);
        assert_eq!(back.seed, 0xdead_beef_dead_beef, "u64 seed must not pass through f64");
        assert_eq!(back.metric, 0.75);
        assert_eq!(back.scores["bleu"], 0.375);
        assert_eq!(back.git, "abc123");
        assert!(back.ok());
        assert_eq!(back.key(), r.key());
    }

    #[test]
    fn failed_record_roundtrip() {
        let cfg = ExperimentConfig::default();
        let r = RunRecord::failed("t", &cfg, "boom".into(), 1.0, "g");
        let back = RunRecord::from_json(&r.to_json()).unwrap();
        assert!(!back.ok());
        assert_eq!(back.error.as_deref(), Some("boom"));
    }

    #[test]
    fn sink_write_and_load() {
        let path = std::env::temp_dir()
            .join(format!("suite_sink_{}.jsonl", std::process::id()));
        let mut sink = JsonlSink::create_at(path.clone(), false).unwrap();
        sink.write(&rec("v1", "d1", 0.5)).unwrap();
        sink.write(&rec("v1", "d2", 0.6)).unwrap();
        drop(sink);
        // torn tail line must not poison earlier records
        {
            use std::io::Write;
            let mut f = std::fs::OpenOptions::new().append(true).open(&path).unwrap();
            write!(f, "{{\"variant\":\"v1\",").unwrap();
        }
        let recs = JsonlSink::load_at(&path);
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[1].dataset, "d2");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn pivot_layout() {
        let mut r_err = rec("v2", "d1", 0.0);
        r_err.error = Some("x".into());
        // stale failed attempt BEFORE the ok record (resumed-file shape):
        // the ok re-run must win the cell
        let mut v1_stale = rec("v1", "d1", 0.0);
        v1_stale.error = Some("transient".into());
        let records =
            vec![v1_stale, rec("v1", "d1", 0.5), rec("v1", "d2", 0.6), r_err];
        let rows: Vec<(&str, &[&str])> =
            vec![("v1", &["Mamba", "LoRA"]), ("v2", &["Mamba", "DoRA"])];
        let cols = vec![
            PivotCol::main("d1", "d1"),
            PivotCol::score("d2(BLEU)", "d2", "bleu"),
        ];
        let t = pivot(&records, &["model", "method"], &rows, &cols);
        assert_eq!(t.headers, vec!["model", "method", "params%", "d1", "d2(BLEU)"]);
        assert_eq!(t.rows[0], vec!["Mamba", "LoRA", "1.25", "0.500", "0.300"]);
        // v2: failed on d1, absent on d2
        assert_eq!(t.rows[1], vec!["Mamba", "DoRA", "-", "ERR", "-"]);
    }

    #[test]
    fn git_describe_never_panics() {
        assert!(!git_describe().is_empty());
    }
}
