//! Optimizers, learning-rate schedules, and gradient clipping.
//!
//! The AOT `step` artifacts return raw gradients over the trainable leaves;
//! the optimizer lives here so the PEFT engine (SDT masks, LoRA+ per-group
//! learning rates) can intervene between gradient and update — exactly the
//! boundary the paper's methods need.

use crate::tensor::Tensor;

/// Linear-decay schedule with optional warmup, as used in the paper's
/// fine-tuning setup (AdamW + linear decay, Sec. C.1).
#[derive(Debug, Clone)]
pub struct Schedule {
    /// Peak learning rate.
    pub base_lr: f32,
    /// Linear warmup steps before decay starts.
    pub warmup_steps: usize,
    /// Steps the decay is stretched over.
    pub total_steps: usize,
    /// Decay shape after warmup.
    pub kind: ScheduleKind,
}

/// Decay shape of a [`Schedule`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ScheduleKind {
    /// No decay.
    Constant,
    /// Linear to zero over `total_steps`.
    Linear,
    /// Half-cosine to zero over `total_steps`.
    Cosine,
}

impl Schedule {
    /// Constant schedule at `lr`.
    pub fn constant(lr: f32) -> Self {
        Schedule { base_lr: lr, warmup_steps: 0, total_steps: 1, kind: ScheduleKind::Constant }
    }
    /// Linear decay with optional warmup (the paper's setup).
    pub fn linear(lr: f32, warmup: usize, total: usize) -> Self {
        Schedule { base_lr: lr, warmup_steps: warmup, total_steps: total.max(1),
                   kind: ScheduleKind::Linear }
    }
    /// Learning rate at a given step.
    pub fn lr_at(&self, step: usize) -> f32 {
        if self.warmup_steps > 0 && step < self.warmup_steps {
            return self.base_lr * (step + 1) as f32 / self.warmup_steps as f32;
        }
        match self.kind {
            ScheduleKind::Constant => self.base_lr,
            ScheduleKind::Linear => {
                let p = (step - self.warmup_steps) as f32
                    / (self.total_steps - self.warmup_steps).max(1) as f32;
                self.base_lr * (1.0 - p.min(1.0))
            }
            ScheduleKind::Cosine => {
                let p = (step - self.warmup_steps) as f32
                    / (self.total_steps - self.warmup_steps).max(1) as f32;
                self.base_lr * 0.5 * (1.0 + (std::f32::consts::PI * p.min(1.0)).cos())
            }
        }
    }
}

/// Global-norm gradient clipping. Returns the pre-clip norm.
pub fn clip_global_norm(grads: &mut [Tensor], max_norm: f32) -> f32 {
    let total: f64 = grads.iter().map(|g| g.sq_norm()).sum();
    let norm = total.sqrt() as f32;
    if norm > max_norm && norm > 0.0 {
        let scale = max_norm / norm;
        for g in grads.iter_mut() {
            for x in g.data.iter_mut() {
                *x *= scale;
            }
        }
    }
    norm
}

/// AdamW with decoupled weight decay (Loshchilov & Hutter).
pub struct AdamW {
    /// First-moment decay.
    pub beta1: f32,
    /// Second-moment decay.
    pub beta2: f32,
    /// Denominator fuzz.
    pub eps: f32,
    /// Decoupled weight-decay coefficient.
    pub weight_decay: f32,
    m: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    t: usize,
    /// Per-parameter LR multiplier (LoRA+ uses e.g. 16× on the B factors).
    pub lr_mult: Vec<f32>,
}

impl AdamW {
    /// Fresh optimizer state shaped like `params`.
    pub fn new(params: &[Tensor]) -> Self {
        AdamW {
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            weight_decay: 0.01,
            m: params.iter().map(|p| vec![0.0; p.numel()]).collect(),
            v: params.iter().map(|p| vec![0.0; p.numel()]).collect(),
            t: 0,
            lr_mult: vec![1.0; params.len()],
        }
    }

    /// Zero all moments (SDT revert re-starts optimization cleanly).
    pub fn reset(&mut self) {
        for m in &mut self.m {
            m.iter_mut().for_each(|x| *x = 0.0);
        }
        for v in &mut self.v {
            v.iter_mut().for_each(|x| *x = 0.0);
        }
        self.t = 0;
    }

    /// One update step: params[i] -= lr * (m̂/(√v̂+ε) + wd·p).
    pub fn step(&mut self, params: &mut [Tensor], grads: &[Tensor], lr: f32) {
        assert_eq!(params.len(), grads.len());
        self.t += 1;
        let b1t = 1.0 - self.beta1.powi(self.t as i32);
        let b2t = 1.0 - self.beta2.powi(self.t as i32);
        for i in 0..params.len() {
            let lr_i = lr * self.lr_mult[i];
            let (m, v) = (&mut self.m[i], &mut self.v[i]);
            let p = &mut params[i].data;
            let g = &grads[i].data;
            debug_assert_eq!(p.len(), g.len(), "param {i} grad shape mismatch");
            for j in 0..p.len() {
                let gj = g[j];
                // Entries that have never received gradient (SDT-masked or
                // truly untouched) are FROZEN: no decoupled decay either —
                // decaying a frozen weight would silently train it to zero.
                if gj == 0.0 && m[j] == 0.0 && v[j] == 0.0 {
                    continue;
                }
                m[j] = self.beta1 * m[j] + (1.0 - self.beta1) * gj;
                v[j] = self.beta2 * v[j] + (1.0 - self.beta2) * gj * gj;
                let mhat = m[j] / b1t;
                let vhat = v[j] / b2t;
                p[j] -= lr_i * (mhat / (vhat.sqrt() + self.eps)
                    + self.weight_decay * p[j]);
            }
        }
    }
}

/// Plain SGD (used by the synthetic Fig. 2 regression runs).
pub struct Sgd {
    /// Momentum coefficient.
    pub momentum: f32,
    vel: Vec<Vec<f32>>,
}

impl Sgd {
    /// Fresh velocity buffers shaped like `params`.
    pub fn new(params: &[Tensor], momentum: f32) -> Self {
        Sgd { momentum, vel: params.iter().map(|p| vec![0.0; p.numel()]).collect() }
    }
    /// One momentum-SGD update.
    pub fn step(&mut self, params: &mut [Tensor], grads: &[Tensor], lr: f32) {
        for i in 0..params.len() {
            let vel = &mut self.vel[i];
            let p = &mut params[i].data;
            let g = &grads[i].data;
            for j in 0..p.len() {
                vel[j] = self.momentum * vel[j] + g[j];
                p[j] -= lr * vel[j];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quad_grad(p: &Tensor) -> Tensor {
        // grad of f(p) = ||p - 3||^2 / 2
        Tensor::from_vec(&p.shape, p.data.iter().map(|x| x - 3.0).collect())
    }

    #[test]
    fn adamw_converges_on_quadratic() {
        let mut params = vec![Tensor::from_vec(&[4], vec![0.0, 10.0, -5.0, 3.0])];
        let mut opt = AdamW::new(&params);
        opt.weight_decay = 0.0;
        for _ in 0..2000 {
            let g = vec![quad_grad(&params[0])];
            opt.step(&mut params, &g, 0.05);
        }
        for &x in &params[0].data {
            assert!((x - 3.0).abs() < 1e-2, "got {x}");
        }
    }

    #[test]
    fn adamw_weight_decay_shrinks() {
        let mut params = vec![Tensor::from_vec(&[1], vec![5.0])];
        let mut opt = AdamW::new(&params);
        opt.weight_decay = 0.1;
        // tiny grads: decay dominates the trajectory
        let g = vec![Tensor::from_vec(&[1], vec![1e-12])];
        for _ in 0..10 {
            opt.step(&mut params, &g, 0.1);
        }
        assert!(params[0].data[0] < 5.0);
    }

    #[test]
    fn adamw_skips_never_touched_entries() {
        // entries with zero grad and zero moments are frozen: neither the
        // update nor decoupled decay moves them (SDT mask invariant)
        let mut params = vec![Tensor::from_vec(&[2], vec![5.0, 5.0])];
        let mut opt = AdamW::new(&params);
        opt.weight_decay = 0.1;
        let g = vec![Tensor::from_vec(&[2], vec![1.0, 0.0])];
        opt.step(&mut params, &g, 0.1);
        assert!(params[0].data[0] < 5.0);
        assert_eq!(params[0].data[1], 5.0);
    }

    #[test]
    fn lr_mult_scales_update() {
        let mut p1 = vec![Tensor::from_vec(&[1], vec![0.0]), Tensor::from_vec(&[1], vec![0.0])];
        let g = vec![Tensor::from_vec(&[1], vec![1.0]), Tensor::from_vec(&[1], vec![1.0])];
        let mut opt = AdamW::new(&p1);
        opt.weight_decay = 0.0;
        opt.lr_mult = vec![1.0, 4.0];
        opt.step(&mut p1, &g, 0.01);
        assert!((p1[1].data[0] / p1[0].data[0] - 4.0).abs() < 1e-4);
    }

    #[test]
    fn sgd_momentum_accelerates() {
        let mut pa = vec![Tensor::from_vec(&[1], vec![10.0])];
        let mut pb = vec![Tensor::from_vec(&[1], vec![10.0])];
        let mut plain = Sgd::new(&pa, 0.0);
        let mut mom = Sgd::new(&pb, 0.9);
        for _ in 0..5 {
            let ga = vec![quad_grad(&pa[0])];
            plain.step(&mut pa, &ga, 0.01);
            let gb = vec![quad_grad(&pb[0])];
            mom.step(&mut pb, &gb, 0.01);
        }
        assert!((pb[0].data[0] - 3.0).abs() < (pa[0].data[0] - 3.0).abs());
    }

    #[test]
    fn clip_reduces_norm() {
        let mut g = vec![Tensor::from_vec(&[2], vec![3.0, 4.0])];
        let pre = clip_global_norm(&mut g, 1.0);
        assert!((pre - 5.0).abs() < 1e-6);
        let post: f64 = g.iter().map(|t| t.sq_norm()).sum();
        assert!((post.sqrt() - 1.0).abs() < 1e-5);
    }

    #[test]
    fn clip_noop_below_threshold() {
        let mut g = vec![Tensor::from_vec(&[2], vec![0.3, 0.4])];
        clip_global_norm(&mut g, 1.0);
        assert_eq!(g[0].data, vec![0.3, 0.4]);
    }

    #[test]
    fn schedules() {
        let s = Schedule::linear(1.0, 10, 110);
        assert!((s.lr_at(0) - 0.1).abs() < 1e-6);
        assert!((s.lr_at(9) - 1.0).abs() < 1e-6);
        assert!((s.lr_at(60) - 0.5).abs() < 1e-6);
        assert!(s.lr_at(110) <= 1e-6);
        let c = Schedule::constant(0.3);
        assert_eq!(c.lr_at(1000), 0.3);
    }
}
