//! Optimizers, learning-rate schedules, gradient clipping, and the fused
//! parameter-arena hot path.
//!
//! The AOT `step` artifacts return raw gradients over the trainable leaves;
//! the optimizer lives here so the PEFT engine (SDT masks, LoRA+ per-group
//! learning rates) can intervene between gradient and update — exactly the
//! boundary the paper's methods need.
//!
//! Two implementations coexist:
//!
//! - **Legacy reference** ([`AdamW`], [`Sgd`], [`clip_global_norm`],
//!   `Masks::apply`): three separate scalar passes over `Vec<Tensor>`
//!   leaves. Kept as the equivalence oracle for the fused path (see
//!   `tests/fused_optimizer.rs`) and for ablation benches.
//! - **Fused arena path** ([`ParamArena`] + [`MaskPlan`] + [`FusedAdamW`] /
//!   [`FusedSgd`]): trainable leaves live in ONE contiguous f32 arena;
//!   mask, global-norm clip and the optimizer update run as a single fused
//!   pass over arena chunks, optionally fanned across a
//!   `std::thread::scope` worker pool. SDT masks compile to sparse index
//!   sets so a 99%-frozen leaf costs O(active) instead of O(numel).
//!   §Perf ledger L3 (rust/docs/performance.md).
//!
//! Determinism: chunk boundaries and the chunk-ordered f64 norm reduction
//! are fixed by the plan, not by the worker count, so 1-worker and
//! N-worker runs produce bitwise-identical parameters.

use crate::tensor::Tensor;

/// Linear-decay schedule with optional warmup, as used in the paper's
/// fine-tuning setup (AdamW + linear decay, Sec. C.1).
#[derive(Debug, Clone)]
pub struct Schedule {
    /// Peak learning rate.
    pub base_lr: f32,
    /// Linear warmup steps before decay starts.
    pub warmup_steps: usize,
    /// Steps the decay is stretched over.
    pub total_steps: usize,
    /// Decay shape after warmup.
    pub kind: ScheduleKind,
}

/// Decay shape of a [`Schedule`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ScheduleKind {
    /// No decay.
    Constant,
    /// Linear to zero over `total_steps`.
    Linear,
    /// Half-cosine to zero over `total_steps`.
    Cosine,
}

impl Schedule {
    /// Constant schedule at `lr`.
    pub fn constant(lr: f32) -> Self {
        Schedule { base_lr: lr, warmup_steps: 0, total_steps: 1, kind: ScheduleKind::Constant }
    }
    /// Linear decay with optional warmup (the paper's setup).
    pub fn linear(lr: f32, warmup: usize, total: usize) -> Self {
        Schedule { base_lr: lr, warmup_steps: warmup, total_steps: total.max(1),
                   kind: ScheduleKind::Linear }
    }
    /// Learning rate at a given step.
    pub fn lr_at(&self, step: usize) -> f32 {
        if self.warmup_steps > 0 && step < self.warmup_steps {
            return self.base_lr * (step + 1) as f32 / self.warmup_steps as f32;
        }
        match self.kind {
            ScheduleKind::Constant => self.base_lr,
            ScheduleKind::Linear => {
                let p = (step - self.warmup_steps) as f32
                    / (self.total_steps - self.warmup_steps).max(1) as f32;
                self.base_lr * (1.0 - p.min(1.0))
            }
            ScheduleKind::Cosine => {
                let p = (step - self.warmup_steps) as f32
                    / (self.total_steps - self.warmup_steps).max(1) as f32;
                self.base_lr * 0.5 * (1.0 + (std::f32::consts::PI * p.min(1.0)).cos())
            }
        }
    }
}

/// Global-norm gradient clipping. Returns the pre-clip norm.
pub fn clip_global_norm(grads: &mut [Tensor], max_norm: f32) -> f32 {
    let total: f64 = grads.iter().map(|g| g.sq_norm()).sum();
    let norm = total.sqrt() as f32;
    if norm > max_norm && norm > 0.0 {
        let scale = max_norm / norm;
        for g in grads.iter_mut() {
            for x in g.data.iter_mut() {
                *x *= scale;
            }
        }
    }
    norm
}

/// AdamW with decoupled weight decay (Loshchilov & Hutter).
pub struct AdamW {
    /// First-moment decay.
    pub beta1: f32,
    /// Second-moment decay.
    pub beta2: f32,
    /// Denominator fuzz.
    pub eps: f32,
    /// Decoupled weight-decay coefficient.
    pub weight_decay: f32,
    m: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    t: usize,
    /// Per-parameter LR multiplier (LoRA+ uses e.g. 16× on the B factors).
    pub lr_mult: Vec<f32>,
}

impl AdamW {
    /// Fresh optimizer state shaped like `params`.
    pub fn new(params: &[Tensor]) -> Self {
        AdamW {
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            weight_decay: 0.01,
            m: params.iter().map(|p| vec![0.0; p.numel()]).collect(),
            v: params.iter().map(|p| vec![0.0; p.numel()]).collect(),
            t: 0,
            lr_mult: vec![1.0; params.len()],
        }
    }

    /// Zero all moments (SDT revert re-starts optimization cleanly).
    pub fn reset(&mut self) {
        for m in &mut self.m {
            m.iter_mut().for_each(|x| *x = 0.0);
        }
        for v in &mut self.v {
            v.iter_mut().for_each(|x| *x = 0.0);
        }
        self.t = 0;
    }

    /// One update step: params[i] -= lr * (m̂/(√v̂+ε) + wd·p).
    pub fn step(&mut self, params: &mut [Tensor], grads: &[Tensor], lr: f32) {
        assert_eq!(params.len(), grads.len());
        self.t += 1;
        let b1t = 1.0 - self.beta1.powi(self.t as i32);
        let b2t = 1.0 - self.beta2.powi(self.t as i32);
        for i in 0..params.len() {
            let lr_i = lr * self.lr_mult[i];
            let (m, v) = (&mut self.m[i], &mut self.v[i]);
            let p = &mut params[i].data;
            let g = &grads[i].data;
            debug_assert_eq!(p.len(), g.len(), "param {i} grad shape mismatch");
            for j in 0..p.len() {
                let gj = g[j];
                // Entries that have never received gradient (SDT-masked or
                // truly untouched) are FROZEN: no decoupled decay either —
                // decaying a frozen weight would silently train it to zero.
                if gj == 0.0 && m[j] == 0.0 && v[j] == 0.0 {
                    continue;
                }
                m[j] = self.beta1 * m[j] + (1.0 - self.beta1) * gj;
                v[j] = self.beta2 * v[j] + (1.0 - self.beta2) * gj * gj;
                let mhat = m[j] / b1t;
                let vhat = v[j] / b2t;
                p[j] -= lr_i * (mhat / (vhat.sqrt() + self.eps)
                    + self.weight_decay * p[j]);
            }
        }
    }
}

/// Plain SGD (used by the synthetic Fig. 2 regression runs).
pub struct Sgd {
    /// Momentum coefficient.
    pub momentum: f32,
    vel: Vec<Vec<f32>>,
}

impl Sgd {
    /// Fresh velocity buffers shaped like `params`.
    pub fn new(params: &[Tensor], momentum: f32) -> Self {
        Sgd { momentum, vel: params.iter().map(|p| vec![0.0; p.numel()]).collect() }
    }
    /// One momentum-SGD update.
    pub fn step(&mut self, params: &mut [Tensor], grads: &[Tensor], lr: f32) {
        for i in 0..params.len() {
            let vel = &mut self.vel[i];
            let p = &mut params[i].data;
            let g = &grads[i].data;
            for j in 0..p.len() {
                vel[j] = self.momentum * vel[j] + g[j];
                p[j] -= lr * vel[j];
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Fused parameter-arena hot path (§Perf L3)
// ---------------------------------------------------------------------------

/// One trainable leaf's slot inside a [`ParamArena`].
#[derive(Debug, Clone)]
pub struct ArenaLeaf {
    /// Tensor shape of the leaf.
    pub shape: Vec<usize>,
    /// Element offset of the leaf inside the arena.
    pub offset: usize,
    /// Element count (`shape` product).
    pub len: usize,
}

/// All trainable leaves flattened into one contiguous f32 buffer with
/// per-leaf offsets. The fused optimizer walks the buffer in cache order;
/// the trainer re-serializes only dirty leaf ranges after each step.
#[derive(Debug, Clone)]
pub struct ParamArena {
    data: Vec<f32>,
    leaves: Vec<ArenaLeaf>,
}

impl ParamArena {
    /// Flatten tensors into an arena (leaf order preserved).
    pub fn pack(tensors: &[Tensor]) -> ParamArena {
        let total: usize = tensors.iter().map(Tensor::numel).sum();
        let mut data = Vec::with_capacity(total);
        let mut leaves = Vec::with_capacity(tensors.len());
        for t in tensors {
            leaves.push(ArenaLeaf { shape: t.shape.clone(), offset: data.len(), len: t.numel() });
            data.extend_from_slice(&t.data);
        }
        ParamArena { data, leaves }
    }

    /// Total element count across all leaves.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True when the arena holds no elements.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Number of leaves.
    pub fn n_leaves(&self) -> usize {
        self.leaves.len()
    }

    /// Leaf metadata, in pack order.
    pub fn leaves(&self) -> &[ArenaLeaf] {
        &self.leaves
    }

    /// One leaf's elements.
    pub fn leaf(&self, i: usize) -> &[f32] {
        let l = &self.leaves[i];
        &self.data[l.offset..l.offset + l.len]
    }

    /// One leaf's elements, mutably.
    pub fn leaf_mut(&mut self, i: usize) -> &mut [f32] {
        let l = &self.leaves[i];
        &mut self.data[l.offset..l.offset + l.len]
    }

    /// Copy new values into a leaf (shape/len must match).
    pub fn write_leaf(&mut self, i: usize, src: &[f32]) {
        let dst = self.leaf_mut(i);
        assert_eq!(dst.len(), src.len(), "leaf {i} length mismatch");
        dst.copy_from_slice(src);
    }

    /// Materialize one leaf as a shaped [`Tensor`] (cold paths only).
    pub fn leaf_tensor(&self, i: usize) -> Tensor {
        Tensor::from_vec(&self.leaves[i].shape, self.leaf(i).to_vec())
    }

    /// Materialize every leaf (round-trip of [`ParamArena::pack`]).
    pub fn unpack(&self) -> Vec<Tensor> {
        (0..self.leaves.len()).map(|i| self.leaf_tensor(i)).collect()
    }

    /// The flat element buffer.
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// The flat element buffer, mutably.
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }
}

/// Elements per fused-pass chunk. Chunks never cross leaf boundaries, so
/// per-chunk norm partials (and therefore the clipped result) are a pure
/// function of the plan — independent of worker count and scheduling.
pub const FUSED_CHUNK: usize = 16 * 1024;

/// Below this arena size the fused pass runs inline on the calling thread:
/// spawning scoped workers would cost more than the walk itself.
pub const FUSED_PAR_MIN: usize = 1 << 16;

/// One contiguous piece of the arena, entirely inside one leaf.
#[derive(Debug, Clone, Copy)]
pub struct Chunk {
    /// Leaf index the chunk belongs to.
    pub leaf: usize,
    /// Arena element offset of the chunk start.
    pub start: usize,
    /// Chunk length in elements.
    pub len: usize,
}

/// How the fused pass treats one leaf's gradient mask.
#[derive(Debug, Clone)]
pub enum LeafMask {
    /// No mask: every entry participates.
    Full,
    /// 0/1 mask with few active entries, compiled to sorted leaf-relative
    /// indices: the pass touches O(active) entries. Only chosen when every
    /// masked-out entry has zero optimizer moments (checked at compile
    /// time), which makes skipping them *exactly* equivalent to the dense
    /// walk.
    Sparse(Vec<u32>),
    /// Dense multiply fallback: non-binary mask values, a mostly-active
    /// mask, or non-zero moments under masked entries.
    Dense(Vec<f32>),
}

/// A compiled execution plan for the fused pass: per-leaf mask treatment
/// plus the fixed chunk decomposition of the arena.
#[derive(Debug, Clone)]
pub struct MaskPlan {
    kinds: Vec<LeafMask>,
    chunks: Vec<Chunk>,
    /// Per-chunk work estimate for load balancing: the active-index count
    /// for sparse chunks, the element count otherwise. (Partitioning only
    /// affects scheduling, never results — see the determinism contract.)
    chunk_costs: Vec<usize>,
    total: usize,
}

impl MaskPlan {
    /// Masks denser than this fraction stay on the dense path (walking the
    /// whole chunk is cheaper than indirect indexing past ~50% active).
    pub const SPARSE_MAX_FRACTION: f32 = 0.5;

    /// Plan with no masking (every leaf [`LeafMask::Full`]).
    pub fn full(arena: &ParamArena) -> MaskPlan {
        let kinds = arena.leaves().iter().map(|_| LeafMask::Full).collect();
        Self::with_kinds(kinds, arena)
    }

    /// Compile gradient masks (aligned with the arena's leaves; `None` =
    /// fully trainable) into a plan. `m`/`v` are the optimizer's current
    /// first/second moments over the arena — a leaf is eligible for the
    /// sparse path only if its masked-out entries all have zero moments,
    /// so install masks right after an optimizer reset (the SDT revert
    /// already does) to get the O(active) path.
    pub fn compile(
        masks: &[Option<Vec<f32>>],
        arena: &ParamArena,
        m: &[f32],
        v: &[f32],
    ) -> MaskPlan {
        assert_eq!(masks.len(), arena.n_leaves(), "mask/leaf count mismatch");
        let kinds = arena
            .leaves()
            .iter()
            .zip(masks.iter())
            .map(|(leaf, mask)| match mask {
                None => LeafMask::Full,
                Some(k) => {
                    assert_eq!(k.len(), leaf.len, "mask length mismatch");
                    let active: Vec<u32> = k
                        .iter()
                        .enumerate()
                        .filter(|(_, &x)| x != 0.0)
                        .map(|(j, _)| j as u32)
                        .collect();
                    let binary = k.iter().all(|&x| x == 0.0 || x == 1.0);
                    let frac = active.len() as f32 / leaf.len.max(1) as f32;
                    let cold = k.iter().enumerate().all(|(j, &x)| {
                        x != 0.0
                            || (m[leaf.offset + j] == 0.0 && v[leaf.offset + j] == 0.0)
                    });
                    if binary && cold && frac <= Self::SPARSE_MAX_FRACTION {
                        LeafMask::Sparse(active)
                    } else {
                        LeafMask::Dense(k.clone())
                    }
                }
            })
            .collect();
        Self::with_kinds(kinds, arena)
    }

    fn with_kinds(kinds: Vec<LeafMask>, arena: &ParamArena) -> MaskPlan {
        let mut chunks = Vec::new();
        let mut chunk_costs = Vec::new();
        for (i, leaf) in arena.leaves().iter().enumerate() {
            if leaf.len == 0 {
                continue;
            }
            match &kinds[i] {
                // sparse leaves stay whole: the pass touches O(active)
                // entries regardless of leaf size — weight them that way
                LeafMask::Sparse(idx) => {
                    chunks.push(Chunk { leaf: i, start: leaf.offset, len: leaf.len });
                    chunk_costs.push(idx.len());
                }
                _ => {
                    let mut at = 0;
                    while at < leaf.len {
                        let len = FUSED_CHUNK.min(leaf.len - at);
                        chunks.push(Chunk { leaf: i, start: leaf.offset + at, len });
                        chunk_costs.push(len);
                        at += len;
                    }
                }
            }
        }
        MaskPlan { kinds, chunks, chunk_costs, total: arena.len() }
    }

    /// Per-leaf mask treatments.
    pub fn kinds(&self) -> &[LeafMask] {
        &self.kinds
    }

    /// The chunk decomposition.
    pub fn chunks(&self) -> &[Chunk] {
        &self.chunks
    }

    /// True when any leaf uses the sparse index-set path.
    pub fn any_sparse(&self) -> bool {
        self.kinds.iter().any(|k| matches!(k, LeafMask::Sparse(_)))
    }
}

/// What one fused step did (clip diagnostics + literal invalidation).
#[derive(Debug, Clone)]
pub struct FusedReport {
    /// Global gradient norm before clipping (masked gradients).
    pub pre_clip_norm: f32,
    /// Scale applied by clipping (1.0 when under the threshold).
    pub clip_scale: f32,
    /// Per-leaf: true when any parameter in the leaf changed this step —
    /// exactly the leaves whose device literals must be re-serialized.
    pub dirty: Vec<bool>,
}

/// Scalar hyperparameters threaded through the fused chunk kernel.
#[derive(Clone, Copy)]
struct AdamScalars {
    b1: f32,
    b2: f32,
    eps: f32,
    wd: f32,
    b1t: f32,
    b2t: f32,
    lr_i: f32,
    scale: f32,
}

/// Worker count for the fused pass: `SSM_PEFT_FUSED_WORKERS` (read through
/// the typed knob registry), else a modest default (min(cores, 4)) — suite
/// cells already parallelize at the cell level, so the per-step pool stays
/// small by default.
pub fn fused_workers() -> usize {
    crate::knobs::fused_workers()
}

/// Contiguous chunk-index ranges with roughly equal work totals (`costs`
/// weights each chunk; sparse chunks cost their active count, not their
/// element count, so a near-free 99%-frozen leaf doesn't hog a worker).
fn partition_chunks(chunks: &[Chunk], costs: &[usize], workers: usize)
    -> Vec<std::ops::Range<usize>> {
    if chunks.is_empty() {
        return Vec::new();
    }
    debug_assert_eq!(chunks.len(), costs.len());
    let workers = workers.clamp(1, chunks.len());
    let total: usize = costs.iter().sum();
    let target = total.div_ceil(workers).max(1);
    let mut parts = Vec::with_capacity(workers);
    let mut begin = 0;
    let mut acc = 0;
    for i in 0..chunks.len() {
        acc += costs[i];
        let remaining_parts = workers - parts.len();
        let remaining_chunks = chunks.len() - (i + 1);
        if (acc >= target || remaining_chunks < remaining_parts) && parts.len() < workers - 1 {
            parts.push(begin..i + 1);
            begin = i + 1;
            acc = 0;
        }
    }
    if begin < chunks.len() {
        parts.push(begin..chunks.len());
    }
    parts
}

/// Masked squared-norm contribution of one chunk (sequential f64
/// accumulation in element order — part of the deterministic reduction).
fn chunk_sq_norm(chunk: &Chunk, kind: &LeafMask, leaf_off: usize, grads: &[f32]) -> f64 {
    let g = &grads[chunk.start..chunk.start + chunk.len];
    let mut acc = 0.0f64;
    match kind {
        LeafMask::Full => {
            for &x in g {
                acc += (x as f64) * (x as f64);
            }
        }
        LeafMask::Sparse(idx) => {
            // chunk == whole leaf for sparse kinds
            for &j in idx {
                let x = g[j as usize];
                acc += (x as f64) * (x as f64);
            }
        }
        LeafMask::Dense(mask) => {
            let mo = chunk.start - leaf_off;
            for (j, &x) in g.iter().enumerate() {
                let xm = x * mask[mo + j];
                acc += (xm as f64) * (xm as f64);
            }
        }
    }
    acc
}

/// The fused AdamW kernel for one chunk. Entry-for-entry identical to the
/// legacy `Masks::apply` → `clip_global_norm` → [`AdamW::step`] sequence
/// (same f32 rounding order, same frozen-entry skip rule). Returns true
/// when any parameter changed.
#[allow(clippy::too_many_arguments)]
fn adamw_chunk(
    kind: &LeafMask,
    leaf_off: usize,
    start: usize,
    p: &mut [f32],
    g: &[f32],
    m: &mut [f32],
    v: &mut [f32],
    hp: AdamScalars,
) -> bool {
    let mut dirty = false;
    let mut update = |j: usize, gj: f32, p: &mut [f32], m: &mut [f32], v: &mut [f32]| {
        // entries that have never received gradient (SDT-masked or truly
        // untouched) are FROZEN: no decoupled decay either (legacy rule)
        if gj == 0.0 && m[j] == 0.0 && v[j] == 0.0 {
            return;
        }
        m[j] = hp.b1 * m[j] + (1.0 - hp.b1) * gj;
        v[j] = hp.b2 * v[j] + (1.0 - hp.b2) * gj * gj;
        let mhat = m[j] / hp.b1t;
        let vhat = v[j] / hp.b2t;
        p[j] -= hp.lr_i * (mhat / (vhat.sqrt() + hp.eps) + hp.wd * p[j]);
        dirty = true;
    };
    match kind {
        LeafMask::Full => {
            for j in 0..p.len() {
                update(j, g[j] * hp.scale, p, m, v);
            }
        }
        LeafMask::Sparse(idx) => {
            for &j in idx {
                let j = j as usize;
                update(j, g[j] * hp.scale, p, m, v);
            }
        }
        LeafMask::Dense(mask) => {
            let mo = start - leaf_off;
            for j in 0..p.len() {
                update(j, g[j] * mask[mo + j] * hp.scale, p, m, v);
            }
        }
    }
    dirty
}

/// AdamW over a [`ParamArena`]: mask + global-norm clip + update as one
/// fused pass. State (`m`, `v`) is flat over the arena; `lr_mult` is per
/// leaf (LoRA+ style group learning rates).
pub struct FusedAdamW {
    /// First-moment decay.
    pub beta1: f32,
    /// Second-moment decay.
    pub beta2: f32,
    /// Denominator fuzz.
    pub eps: f32,
    /// Decoupled weight-decay coefficient.
    pub weight_decay: f32,
    /// Per-leaf LR multiplier (LoRA+ uses e.g. 16× on the B factors).
    pub lr_mult: Vec<f32>,
    m: Vec<f32>,
    v: Vec<f32>,
    t: usize,
}

impl FusedAdamW {
    /// Fresh optimizer state shaped like the arena.
    pub fn new(arena: &ParamArena) -> FusedAdamW {
        FusedAdamW {
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            weight_decay: 0.01,
            lr_mult: vec![1.0; arena.n_leaves()],
            m: vec![0.0; arena.len()],
            v: vec![0.0; arena.len()],
            t: 0,
        }
    }

    /// Zero all moments (SDT revert re-starts optimization cleanly).
    pub fn reset(&mut self) {
        self.m.iter_mut().for_each(|x| *x = 0.0);
        self.v.iter_mut().for_each(|x| *x = 0.0);
        self.t = 0;
    }

    /// Steps taken so far.
    pub fn t(&self) -> usize {
        self.t
    }

    /// Current (first, second) moments over the arena — used by
    /// [`MaskPlan::compile`] to decide sparse eligibility.
    pub fn moments(&self) -> (&[f32], &[f32]) {
        (&self.m, &self.v)
    }

    /// One fused step: masked global norm (phase A, chunk-ordered f64
    /// reduction) then clip + AdamW update (phase B), both fanned over at
    /// most `workers` scoped threads. `grads` is the raw gradient arena
    /// (masking happens on the fly; the buffer is not mutated).
    pub fn step(
        &mut self,
        arena: &mut ParamArena,
        grads: &[f32],
        plan: &MaskPlan,
        lr: f32,
        max_norm: f32,
        workers: usize,
    ) -> FusedReport {
        let n = arena.len();
        assert_eq!(grads.len(), n, "grad arena size mismatch");
        assert_eq!(self.m.len(), n, "optimizer state size mismatch");
        assert_eq!(plan.total, n, "plan compiled for a different arena");
        self.t += 1;
        let b1t = 1.0 - self.beta1.powi(self.t as i32);
        let b2t = 1.0 - self.beta2.powi(self.t as i32);
        let chunks = plan.chunks();
        let n_leaves = arena.n_leaves();
        let leaf_offs: Vec<usize> = arena.leaves().iter().map(|l| l.offset).collect();
        let workers = if n < FUSED_PAR_MIN { 1 } else { workers.max(1) };
        let parts = partition_chunks(chunks, &plan.chunk_costs, workers);

        // ---- phase A: masked global norm ---------------------------------
        let mut partials = vec![0.0f64; chunks.len()];
        if parts.len() <= 1 {
            for (ci, out) in partials.iter_mut().enumerate() {
                let c = &chunks[ci];
                *out = chunk_sq_norm(c, &plan.kinds[c.leaf], leaf_offs[c.leaf], grads);
            }
        } else {
            std::thread::scope(|sc| {
                let mut rest: &mut [f64] = &mut partials;
                for part in &parts {
                    let (mine, r) = rest.split_at_mut(part.len());
                    rest = r;
                    let part = part.clone();
                    let (kinds, leaf_offs) = (&plan.kinds, &leaf_offs);
                    sc.spawn(move || {
                        for (k, ci) in part.enumerate() {
                            let c = &chunks[ci];
                            mine[k] =
                                chunk_sq_norm(c, &kinds[c.leaf], leaf_offs[c.leaf], grads);
                        }
                    });
                }
            });
        }
        // chunk-ordered reduction: independent of worker count
        let total: f64 = partials.iter().sum();
        let pre_clip_norm = total.sqrt() as f32;
        let scale = if pre_clip_norm > max_norm && pre_clip_norm > 0.0 {
            max_norm / pre_clip_norm
        } else {
            1.0
        };

        // ---- phase B: clip + update, disjoint chunk ranges ---------------
        let base = AdamScalars {
            b1: self.beta1,
            b2: self.beta2,
            eps: self.eps,
            wd: self.weight_decay,
            b1t,
            b2t,
            lr_i: lr,
            scale,
        };
        let lr_mult = &self.lr_mult;
        let mut dirty_chunks = vec![false; chunks.len()];
        if parts.len() <= 1 {
            for (ci, d) in dirty_chunks.iter_mut().enumerate() {
                let c = &chunks[ci];
                let (s, e) = (c.start, c.start + c.len);
                let hp = AdamScalars { lr_i: lr * lr_mult[c.leaf], ..base };
                *d = adamw_chunk(
                    &plan.kinds[c.leaf],
                    leaf_offs[c.leaf],
                    c.start,
                    &mut arena.data[s..e],
                    &grads[s..e],
                    &mut self.m[s..e],
                    &mut self.v[s..e],
                    hp,
                );
            }
        } else {
            std::thread::scope(|sc| {
                let mut pd: &mut [f32] = &mut arena.data;
                let mut md: &mut [f32] = &mut self.m;
                let mut vd: &mut [f32] = &mut self.v;
                let mut dd: &mut [bool] = &mut dirty_chunks;
                let mut consumed = 0usize;
                for part in &parts {
                    let elems: usize = chunks[part.clone()].iter().map(|c| c.len).sum();
                    let (p_s, p_r) = pd.split_at_mut(elems);
                    pd = p_r;
                    let (m_s, m_r) = md.split_at_mut(elems);
                    md = m_r;
                    let (v_s, v_r) = vd.split_at_mut(elems);
                    vd = v_r;
                    let (d_s, d_r) = dd.split_at_mut(part.len());
                    dd = d_r;
                    let part_base = consumed;
                    consumed += elems;
                    let part = part.clone();
                    let (kinds, leaf_offs) = (&plan.kinds, &leaf_offs);
                    sc.spawn(move || {
                        let (mut p_s, mut m_s, mut v_s) = (p_s, m_s, v_s);
                        let mut at = part_base;
                        for (k, ci) in part.enumerate() {
                            let c = &chunks[ci];
                            debug_assert_eq!(c.start, at);
                            let (p_c, p_r) = p_s.split_at_mut(c.len);
                            p_s = p_r;
                            let (m_c, m_r) = m_s.split_at_mut(c.len);
                            m_s = m_r;
                            let (v_c, v_r) = v_s.split_at_mut(c.len);
                            v_s = v_r;
                            at += c.len;
                            let hp = AdamScalars { lr_i: lr * lr_mult[c.leaf], ..base };
                            d_s[k] = adamw_chunk(
                                &kinds[c.leaf],
                                leaf_offs[c.leaf],
                                c.start,
                                p_c,
                                &grads[c.start..c.start + c.len],
                                m_c,
                                v_c,
                                hp,
                            );
                        }
                    });
                }
            });
        }

        let mut dirty = vec![false; n_leaves];
        for (ci, &d) in dirty_chunks.iter().enumerate() {
            if d {
                dirty[chunks[ci].leaf] = true;
            }
        }
        FusedReport { pre_clip_norm, clip_scale: scale, dirty }
    }
}

/// Momentum SGD over a [`ParamArena`] (fused analogue of [`Sgd`]; no masks
/// or clipping, matching the legacy semantics — the synthetic Fig. 2 runs).
pub struct FusedSgd {
    /// Momentum coefficient.
    pub momentum: f32,
    vel: Vec<f32>,
}

impl FusedSgd {
    /// Fresh velocity buffer shaped like the arena.
    pub fn new(arena: &ParamArena, momentum: f32) -> FusedSgd {
        FusedSgd { momentum, vel: vec![0.0; arena.len()] }
    }

    /// One fused momentum-SGD update over the arena.
    pub fn step(&mut self, arena: &mut ParamArena, grads: &[f32], lr: f32, workers: usize) {
        let n = arena.len();
        assert_eq!(grads.len(), n);
        assert_eq!(self.vel.len(), n);
        let workers = if n < FUSED_PAR_MIN { 1 } else { workers.max(1) };
        fn kernel(p: &mut [f32], v: &mut [f32], g: &[f32], mom: f32, lr: f32) {
            for j in 0..p.len() {
                v[j] = mom * v[j] + g[j];
                p[j] -= lr * v[j];
            }
        }
        if workers <= 1 || n == 0 {
            kernel(&mut arena.data, &mut self.vel, grads, self.momentum, lr);
            return;
        }
        let per = n.div_ceil(workers);
        let mom = self.momentum;
        std::thread::scope(|sc| {
            let mut pd: &mut [f32] = &mut arena.data;
            let mut vd: &mut [f32] = &mut self.vel;
            let mut at = 0usize;
            while at < n {
                let take = per.min(n - at);
                let (p_s, p_r) = pd.split_at_mut(take);
                pd = p_r;
                let (v_s, v_r) = vd.split_at_mut(take);
                vd = v_r;
                let g = &grads[at..at + take];
                sc.spawn(move || kernel(p_s, v_s, g, mom, lr));
                at += take;
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quad_grad(p: &Tensor) -> Tensor {
        // grad of f(p) = ||p - 3||^2 / 2
        Tensor::from_vec(&p.shape, p.data.iter().map(|x| x - 3.0).collect())
    }

    #[test]
    fn adamw_converges_on_quadratic() {
        let mut params = vec![Tensor::from_vec(&[4], vec![0.0, 10.0, -5.0, 3.0])];
        let mut opt = AdamW::new(&params);
        opt.weight_decay = 0.0;
        for _ in 0..2000 {
            let g = vec![quad_grad(&params[0])];
            opt.step(&mut params, &g, 0.05);
        }
        for &x in &params[0].data {
            assert!((x - 3.0).abs() < 1e-2, "got {x}");
        }
    }

    #[test]
    fn adamw_weight_decay_shrinks() {
        let mut params = vec![Tensor::from_vec(&[1], vec![5.0])];
        let mut opt = AdamW::new(&params);
        opt.weight_decay = 0.1;
        // tiny grads: decay dominates the trajectory
        let g = vec![Tensor::from_vec(&[1], vec![1e-12])];
        for _ in 0..10 {
            opt.step(&mut params, &g, 0.1);
        }
        assert!(params[0].data[0] < 5.0);
    }

    #[test]
    fn adamw_skips_never_touched_entries() {
        // entries with zero grad and zero moments are frozen: neither the
        // update nor decoupled decay moves them (SDT mask invariant)
        let mut params = vec![Tensor::from_vec(&[2], vec![5.0, 5.0])];
        let mut opt = AdamW::new(&params);
        opt.weight_decay = 0.1;
        let g = vec![Tensor::from_vec(&[2], vec![1.0, 0.0])];
        opt.step(&mut params, &g, 0.1);
        assert!(params[0].data[0] < 5.0);
        assert_eq!(params[0].data[1], 5.0);
    }

    #[test]
    fn lr_mult_scales_update() {
        let mut p1 = vec![Tensor::from_vec(&[1], vec![0.0]), Tensor::from_vec(&[1], vec![0.0])];
        let g = vec![Tensor::from_vec(&[1], vec![1.0]), Tensor::from_vec(&[1], vec![1.0])];
        let mut opt = AdamW::new(&p1);
        opt.weight_decay = 0.0;
        opt.lr_mult = vec![1.0, 4.0];
        opt.step(&mut p1, &g, 0.01);
        assert!((p1[1].data[0] / p1[0].data[0] - 4.0).abs() < 1e-4);
    }

    #[test]
    fn sgd_momentum_accelerates() {
        let mut pa = vec![Tensor::from_vec(&[1], vec![10.0])];
        let mut pb = vec![Tensor::from_vec(&[1], vec![10.0])];
        let mut plain = Sgd::new(&pa, 0.0);
        let mut mom = Sgd::new(&pb, 0.9);
        for _ in 0..5 {
            let ga = vec![quad_grad(&pa[0])];
            plain.step(&mut pa, &ga, 0.01);
            let gb = vec![quad_grad(&pb[0])];
            mom.step(&mut pb, &gb, 0.01);
        }
        assert!((pb[0].data[0] - 3.0).abs() < (pa[0].data[0] - 3.0).abs());
    }

    #[test]
    fn clip_reduces_norm() {
        let mut g = vec![Tensor::from_vec(&[2], vec![3.0, 4.0])];
        let pre = clip_global_norm(&mut g, 1.0);
        assert!((pre - 5.0).abs() < 1e-6);
        let post: f64 = g.iter().map(|t| t.sq_norm()).sum();
        assert!((post.sqrt() - 1.0).abs() < 1e-5);
    }

    #[test]
    fn clip_noop_below_threshold() {
        let mut g = vec![Tensor::from_vec(&[2], vec![0.3, 0.4])];
        clip_global_norm(&mut g, 1.0);
        assert_eq!(g[0].data, vec![0.3, 0.4]);
    }

    #[test]
    fn arena_pack_unpack_roundtrip() {
        let ts = vec![
            Tensor::from_vec(&[2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]),
            Tensor::from_vec(&[4], vec![7.0, 8.0, 9.0, 10.0]),
            Tensor::scalar(11.0),
        ];
        let arena = ParamArena::pack(&ts);
        assert_eq!(arena.len(), 11);
        assert_eq!(arena.n_leaves(), 3);
        assert_eq!(arena.leaf(1), &[7.0, 8.0, 9.0, 10.0]);
        assert_eq!(arena.leaves()[1].offset, 6);
        assert_eq!(arena.unpack(), ts);
    }

    #[test]
    fn arena_write_leaf() {
        let ts = vec![Tensor::zeros(&[2]), Tensor::zeros(&[3])];
        let mut arena = ParamArena::pack(&ts);
        arena.write_leaf(1, &[1.0, 2.0, 3.0]);
        assert_eq!(arena.leaf(0), &[0.0, 0.0]);
        assert_eq!(arena.leaf_tensor(1).data, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn plan_compiles_sparse_dense_full() {
        let ts = vec![Tensor::zeros(&[100]), Tensor::zeros(&[10]), Tensor::zeros(&[10])];
        let arena = ParamArena::pack(&ts);
        let opt = FusedAdamW::new(&arena);
        let (m, v) = opt.moments();
        let mut sparse = vec![0.0f32; 100];
        sparse[3] = 1.0;
        sparse[77] = 1.0;
        let dense = vec![0.5f32; 10]; // non-binary → dense fallback
        let masks = vec![Some(sparse), Some(dense), None];
        let plan = MaskPlan::compile(&masks, &arena, m, v);
        assert!(matches!(&plan.kinds()[0], LeafMask::Sparse(idx) if idx == &vec![3, 77]));
        assert!(matches!(plan.kinds()[1], LeafMask::Dense(_)));
        assert!(matches!(plan.kinds()[2], LeafMask::Full));
        assert!(plan.any_sparse());
        // chunks cover the arena contiguously
        let mut at = 0;
        for c in plan.chunks() {
            assert_eq!(c.start, at);
            at += c.len;
        }
        assert_eq!(at, arena.len());
    }

    #[test]
    fn plan_falls_back_to_dense_when_moments_warm() {
        // a masked-out entry with non-zero moments must keep the dense
        // walk (legacy semantics keep decaying such entries)
        let ts = vec![Tensor::zeros(&[8])];
        let mut arena = ParamArena::pack(&ts);
        let mut opt = FusedAdamW::new(&arena);
        let plan = MaskPlan::full(&arena);
        let grads = vec![1.0f32; 8];
        opt.step(&mut arena, &grads, &plan, 0.01, 1.0, 1);
        let mut mask = vec![0.0f32; 8];
        mask[0] = 1.0;
        let (m, v) = opt.moments();
        let plan2 = MaskPlan::compile(&[Some(mask)], &arena, m, v);
        assert!(matches!(plan2.kinds()[0], LeafMask::Dense(_)));
    }

    #[test]
    fn partition_covers_all_chunks_in_order() {
        let ts = vec![Tensor::zeros(&[40_000]), Tensor::zeros(&[5]), Tensor::zeros(&[20_000])];
        let arena = ParamArena::pack(&ts);
        let plan = MaskPlan::full(&arena);
        for workers in [1, 2, 3, 7, 100] {
            let parts = partition_chunks(plan.chunks(), &plan.chunk_costs, workers);
            assert!(parts.len() <= workers.min(plan.chunks().len()));
            let mut next = 0;
            for p in &parts {
                assert_eq!(p.start, next, "parts must be contiguous");
                assert!(!p.is_empty());
                next = p.end;
            }
            assert_eq!(next, plan.chunks().len(), "parts must cover every chunk");
        }
    }

    #[test]
    fn partition_weights_sparse_chunks_by_active_count() {
        // a huge 2-entry-active sparse leaf must not claim a worker by
        // itself while the dense work crowds onto the rest
        let ts = vec![Tensor::zeros(&[200_000]), Tensor::zeros(&[40_000])];
        let arena = ParamArena::pack(&ts);
        let opt = FusedAdamW::new(&arena);
        let (m, v) = opt.moments();
        let mut sparse = vec![0.0f32; 200_000];
        sparse[0] = 1.0;
        sparse[12345] = 1.0;
        let plan = MaskPlan::compile(&[Some(sparse), None], &arena, m, v);
        // sparse leaf = 1 chunk of cost 2; dense leaf = 3 chunks
        assert_eq!(plan.chunk_costs[0], 2);
        let parts = partition_chunks(plan.chunks(), &plan.chunk_costs, 2);
        assert_eq!(parts.len(), 2);
        // the near-free sparse chunk shares a part with dense work
        assert!(parts[0].len() > 1, "sparse chunk must not get its own worker: {parts:?}");
    }

    #[test]
    fn fused_report_marks_only_touched_leaves_dirty() {
        let ts = vec![Tensor::zeros(&[4]), Tensor::zeros(&[4])];
        let mut arena = ParamArena::pack(&ts);
        let mut opt = FusedAdamW::new(&arena);
        let (m, v) = (opt.moments().0.to_vec(), opt.moments().1.to_vec());
        // leaf 0 fully masked out, leaf 1 trainable
        let plan =
            MaskPlan::compile(&[Some(vec![0.0; 4]), None], &arena, &m, &v);
        let grads = vec![1.0f32; 8];
        let rep = opt.step(&mut arena, &grads, &plan, 0.01, 1e9, 1);
        assert_eq!(rep.dirty, vec![false, true]);
        assert!(arena.leaf(0).iter().all(|&x| x == 0.0), "masked leaf untouched");
        assert!(arena.leaf(1).iter().all(|&x| x != 0.0), "trainable leaf moved");
        assert!(rep.pre_clip_norm > 0.0);
        assert_eq!(rep.clip_scale, 1.0);
    }

    #[test]
    fn schedules() {
        let s = Schedule::linear(1.0, 10, 110);
        assert!((s.lr_at(0) - 0.1).abs() < 1e-6);
        assert!((s.lr_at(9) - 1.0).abs() < 1e-6);
        assert!((s.lr_at(60) - 0.5).abs() < 1e-6);
        assert!(s.lr_at(110) <= 1e-6);
        let c = Schedule::constant(0.3);
        assert_eq!(c.lr_at(1000), 0.3);
    }
}
