//! Evaluation metrics, implemented from their published definitions:
//! accuracy, Matthews correlation (CoLA), ROUGE-1/2/L (SAMSum), BLEU and a
//! METEOR-lite (DART), execution-match accuracy hooks (Spider analogue),
//! and MSE (synthetic Fig. 2).

use std::collections::HashMap;

/// Plain classification accuracy.
pub fn accuracy(pred: &[usize], gold: &[usize]) -> f64 {
    assert_eq!(pred.len(), gold.len());
    if pred.is_empty() {
        return 0.0;
    }
    let hit = pred.iter().zip(gold).filter(|(p, g)| p == g).count();
    hit as f64 / pred.len() as f64
}

/// Matthews correlation coefficient for binary labels (GLUE CoLA metric).
pub fn matthews_corr(pred: &[usize], gold: &[usize]) -> f64 {
    let (mut tp, mut tn, mut fp, mut fne) = (0f64, 0f64, 0f64, 0f64);
    for (&p, &g) in pred.iter().zip(gold) {
        match (p, g) {
            (1, 1) => tp += 1.0,
            (0, 0) => tn += 1.0,
            (1, 0) => fp += 1.0,
            (0, 1) => fne += 1.0,
            _ => {}
        }
    }
    let denom = ((tp + fp) * (tp + fne) * (tn + fp) * (tn + fne)).sqrt();
    if denom == 0.0 {
        0.0
    } else {
        (tp * tn - fp * fne) / denom
    }
}

fn ngrams(tokens: &[u32], n: usize) -> HashMap<Vec<u32>, usize> {
    let mut m = HashMap::new();
    if tokens.len() >= n {
        for w in tokens.windows(n) {
            *m.entry(w.to_vec()).or_insert(0) += 1;
        }
    }
    m
}

/// ROUGE-N recall-oriented F1 (as reported by the standard rouge package).
pub fn rouge_n(pred: &[u32], gold: &[u32], n: usize) -> f64 {
    let pg = ngrams(pred, n);
    let gg = ngrams(gold, n);
    let overlap: usize = gg
        .iter()
        .map(|(k, &c)| c.min(pg.get(k).copied().unwrap_or(0)))
        .sum();
    let p_total: usize = pg.values().sum();
    let g_total: usize = gg.values().sum();
    if p_total == 0 || g_total == 0 || overlap == 0 {
        return 0.0;
    }
    let p = overlap as f64 / p_total as f64;
    let r = overlap as f64 / g_total as f64;
    2.0 * p * r / (p + r)
}

/// Longest common subsequence length (for ROUGE-L).
fn lcs(a: &[u32], b: &[u32]) -> usize {
    let mut dp = vec![0usize; b.len() + 1];
    for &x in a {
        let mut prev = 0;
        for (j, &y) in b.iter().enumerate() {
            let cur = dp[j + 1];
            dp[j + 1] = if x == y { prev + 1 } else { dp[j + 1].max(dp[j]) };
            prev = cur;
        }
    }
    dp[b.len()]
}

/// ROUGE-L F1 based on LCS.
pub fn rouge_l(pred: &[u32], gold: &[u32]) -> f64 {
    if pred.is_empty() || gold.is_empty() {
        return 0.0;
    }
    let l = lcs(pred, gold) as f64;
    if l == 0.0 {
        return 0.0;
    }
    let p = l / pred.len() as f64;
    let r = l / gold.len() as f64;
    2.0 * p * r / (p + r)
}

/// Corpus BLEU-4 with brevity penalty (Papineni et al., 2002), with +1
/// smoothing on higher-order precisions (standard "smooth1").
pub fn bleu(preds: &[Vec<u32>], golds: &[Vec<u32>]) -> f64 {
    assert_eq!(preds.len(), golds.len());
    let max_n = 4;
    let mut match_n = vec![0usize; max_n];
    let mut total_n = vec![0usize; max_n];
    let (mut pred_len, mut gold_len) = (0usize, 0usize);
    for (p, g) in preds.iter().zip(golds) {
        pred_len += p.len();
        gold_len += g.len();
        for n in 1..=max_n {
            let pg = ngrams(p, n);
            let gg = ngrams(g, n);
            for (k, &c) in pg.iter() {
                match_n[n - 1] += c.min(gg.get(k).copied().unwrap_or(0));
            }
            total_n[n - 1] += pg.values().sum::<usize>();
        }
    }
    if total_n[0] == 0 {
        return 0.0;
    }
    let mut log_p = 0.0;
    for n in 0..max_n {
        let (m, t) = if n == 0 {
            (match_n[0] as f64, total_n[0] as f64)
        } else {
            (match_n[n] as f64 + 1.0, total_n[n] as f64 + 1.0)
        };
        if m == 0.0 || t == 0.0 {
            return 0.0;
        }
        log_p += (m / t).ln() / max_n as f64;
    }
    let bp = if pred_len >= gold_len || pred_len == 0 {
        1.0
    } else {
        (1.0 - gold_len as f64 / pred_len as f64).exp()
    };
    bp * log_p.exp()
}

/// METEOR-lite: unigram F-mean (recall-weighted 9:1 as in METEOR) with a
/// fragmentation penalty from the number of matched chunks. Uses exact
/// matches only (no stemming/synonyms — byte-token tasks don't need them).
pub fn meteor(pred: &[u32], gold: &[u32]) -> f64 {
    if pred.is_empty() || gold.is_empty() {
        return 0.0;
    }
    // greedy alignment: for each pred position, match first unused gold occurrence
    let mut used = vec![false; gold.len()];
    let mut align: Vec<Option<usize>> = vec![None; pred.len()];
    for (i, &t) in pred.iter().enumerate() {
        for (j, &gtok) in gold.iter().enumerate() {
            if !used[j] && gtok == t {
                used[j] = true;
                align[i] = Some(j);
                break;
            }
        }
    }
    let m = align.iter().flatten().count() as f64;
    if m == 0.0 {
        return 0.0;
    }
    let p = m / pred.len() as f64;
    let r = m / gold.len() as f64;
    let fmean = 10.0 * p * r / (r + 9.0 * p);
    // chunks: maximal runs of adjacent-in-both matches
    let mut chunks = 0.0;
    let mut prev: Option<usize> = None;
    for a in align.iter() {
        match (a, prev) {
            (Some(j), Some(pj)) if *j == pj + 1 => {}
            (Some(_), _) => chunks += 1.0,
            (None, _) => {}
        }
        prev = *a;
    }
    let penalty = 0.5 * (chunks / m).powi(3);
    fmean * (1.0 - penalty)
}

/// Mean squared error between two equal-length slices.
pub fn mse(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    if a.is_empty() {
        return 0.0;
    }
    a.iter()
        .zip(b)
        .map(|(&x, &y)| ((x - y) as f64).powi(2))
        .sum::<f64>()
        / a.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_basic() {
        assert_eq!(accuracy(&[1, 2, 3], &[1, 0, 3]), 2.0 / 3.0);
        assert_eq!(accuracy(&[], &[]), 0.0);
    }

    #[test]
    fn matthews_perfect_and_inverse() {
        assert!((matthews_corr(&[1, 0, 1, 0], &[1, 0, 1, 0]) - 1.0).abs() < 1e-12);
        assert!((matthews_corr(&[0, 1, 0, 1], &[1, 0, 1, 0]) + 1.0).abs() < 1e-12);
        assert_eq!(matthews_corr(&[1, 1, 1, 1], &[1, 0, 1, 0]), 0.0);
    }

    #[test]
    fn rouge1_identical_is_one() {
        let s = vec![1, 2, 3, 4];
        assert!((rouge_n(&s, &s, 1) - 1.0).abs() < 1e-12);
        assert!((rouge_n(&s, &s, 2) - 1.0).abs() < 1e-12);
        assert!((rouge_l(&s, &s) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn rouge_disjoint_is_zero() {
        assert_eq!(rouge_n(&[1, 2], &[3, 4], 1), 0.0);
        assert_eq!(rouge_l(&[1, 2], &[3, 4]), 0.0);
    }

    #[test]
    fn rouge_l_order_sensitivity() {
        // same unigrams, scrambled order: R1 stays 1, RL drops
        let gold = vec![1, 2, 3, 4, 5];
        let scrambled = vec![5, 4, 3, 2, 1];
        assert!((rouge_n(&scrambled, &gold, 1) - 1.0).abs() < 1e-12);
        assert!(rouge_l(&scrambled, &gold) < 0.5);
    }

    #[test]
    fn bleu_identical_is_one() {
        let c = vec![vec![1, 2, 3, 4, 5, 6]];
        assert!((bleu(&c, &c) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn bleu_partial_and_brevity() {
        let pred = vec![vec![1, 2, 3]];
        let gold = vec![vec![1, 2, 3, 4, 5, 6]];
        let b = bleu(&pred, &gold);
        assert!(b > 0.0 && b < 1.0);
        // longer hypothesis with garbage scores lower than exact
        let pred2 = vec![vec![1, 2, 3, 9, 9, 9]];
        assert!(bleu(&pred2, &gold) < 1.0);
    }

    #[test]
    fn meteor_identity_and_fragmentation() {
        let gold = vec![1, 2, 3, 4, 5, 6];
        let m_same = meteor(&gold, &gold);
        assert!(m_same > 0.99, "{m_same}");
        // same tokens but fragmented order should score lower
        let frag = vec![2, 1, 4, 3, 6, 5];
        assert!(meteor(&frag, &gold) < m_same);
        assert_eq!(meteor(&[9, 9], &gold), 0.0);
    }

    #[test]
    fn mse_known() {
        assert_eq!(mse(&[1.0, 2.0], &[1.0, 4.0]), 2.0);
    }
}
