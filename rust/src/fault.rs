//! Deterministic, seeded fault injection for the serving stack.
//!
//! Robustness claims are only as good as the failures they were tested
//! against, so the crate injects its own: a [`FaultPlan`] decides — purely
//! from a seed and per-site check counters, never from wall-clock time or
//! real I/O flakiness — whether a given operation "fails" on this
//! particular attempt. The same seed always yields the same fault
//! schedule, which keeps the fault-matrix suite (rust/tests/
//! fault_injection.rs) reproducible and the module inside the
//! determinism lint's scope.
//!
//! Faults are keyed by [`FaultSite`] — the six operation classes whose
//! real-world failures the serve layer must survive:
//!
//! | site | models |
//! |------|--------|
//! | [`FaultSite::ExecRun`] | a failed accelerator dispatch mid-decode |
//! | [`FaultSite::AdapterLoad`] | a corrupt or missing adapter checkpoint |
//! | [`FaultSite::ArtifactRead`] | unreadable AOT artifacts / manifest |
//! | [`FaultSite::StateReadback`] | a failed device→host state readback |
//! | [`FaultSite::StatePersist`] | a failed session-state record write |
//! | [`FaultSite::StateLoad`] | a failed session-state record read |
//!
//! Production pays a no-op: the hooks hold an `Option<Arc<dyn
//! FaultInject>>` that is `None` unless the fault knobs are set (see
//! [`FaultPlan::from_env`]), so the hot path's only cost is a branch on a
//! `None`. Sites check in with [`FaultInject::check`]; a `Err` return is
//! injected as a classified [`Error`] that then exercises the *real*
//! retry/rollback/quarantine machinery downstream.
//!
//! Knobs (registered in [`crate::knobs`]): `SSM_PEFT_FAULT_SEED` seeds
//! the schedule; `SSM_PEFT_FAULT_EXEC`, `SSM_PEFT_FAULT_ADAPTER_LOAD`,
//! `SSM_PEFT_FAULT_ARTIFACT_READ`, `SSM_PEFT_FAULT_STATE_READBACK`,
//! `SSM_PEFT_FAULT_STATE_PERSIST` and `SSM_PEFT_FAULT_STATE_LOAD` set
//! per-site fault rates in [0, 1].

use std::collections::BTreeSet;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::error::{Error, ErrorKind, Result};

/// One operation class where a fault can be injected.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultSite {
    /// A compiled-executable dispatch (decode step, prefill chunk).
    ExecRun,
    /// Loading an adapter delta into the registry.
    AdapterLoad,
    /// Reading AOT artifacts / manifest bytes (merged-lane model load).
    ArtifactRead,
    /// Device→host state readback (checkpoint capture).
    StateReadback,
    /// Writing a session-state record to the durable store
    /// ([`crate::serve::SessionStore`]).
    StatePersist,
    /// Reading a session-state record back from the durable store.
    StateLoad,
}

/// Number of fault sites (the width of every per-site array).
pub const SITES: usize = 6;

impl FaultSite {
    /// Every site, in a fixed order ([`Self::index`] indexes this).
    pub const ALL: [FaultSite; SITES] = [
        FaultSite::ExecRun,
        FaultSite::AdapterLoad,
        FaultSite::ArtifactRead,
        FaultSite::StateReadback,
        FaultSite::StatePersist,
        FaultSite::StateLoad,
    ];

    /// Stable dense index into per-site arrays.
    pub fn index(self) -> usize {
        match self {
            FaultSite::ExecRun => 0,
            FaultSite::AdapterLoad => 1,
            FaultSite::ArtifactRead => 2,
            FaultSite::StateReadback => 3,
            FaultSite::StatePersist => 4,
            FaultSite::StateLoad => 5,
        }
    }

    /// Stable label used in injected error messages and telemetry.
    pub fn label(self) -> &'static str {
        match self {
            FaultSite::ExecRun => "exec_run",
            FaultSite::AdapterLoad => "adapter_load",
            FaultSite::ArtifactRead => "artifact_read",
            FaultSite::StateReadback => "state_readback",
            FaultSite::StatePersist => "state_persist",
            FaultSite::StateLoad => "state_load",
        }
    }
}

/// The hook fallible operations consult before doing real work.
///
/// Implementations must be deterministic given their own configuration:
/// the nth [`check`](Self::check) at a given site always gives the same
/// answer, regardless of threads, wall-clock time, or machine.
pub trait FaultInject: Send + Sync {
    /// Called at a fault site immediately before the real operation.
    /// `Ok(())` lets the operation proceed; `Err` is the injected fault.
    fn check(&self, site: FaultSite) -> Result<()>;
}

/// The production implementation: never injects.
///
/// Exists so tests can thread "faults disabled" explicitly; the serve
/// wiring itself prefers `None` over `Some(NoFaults)` to keep the hot
/// path's no-fault cost to a branch.
#[derive(Debug, Default, Clone, Copy)]
pub struct NoFaults;

impl FaultInject for NoFaults {
    fn check(&self, _site: FaultSite) -> Result<()> {
        Ok(())
    }
}

/// A deterministic seeded fault schedule.
///
/// Each site keeps a check counter; check `n` at site `s` faults when
/// either `n` is in the site's explicit [`with_fault_at`](
/// Self::with_fault_at) set, or the site's rate is non-zero and the
/// splitmix64 hash of `(seed, s, n)` maps below the rate. Both paths are
/// pure functions of the plan's configuration and the check index.
pub struct FaultPlan {
    seed: u64,
    kind: ErrorKind,
    rate: [f64; SITES],
    at: [BTreeSet<u64>; SITES],
    counters: [AtomicU64; SITES],
    injected: [AtomicU64; SITES],
}

impl FaultPlan {
    /// An empty plan (no rates, no explicit faults) with the given seed.
    pub fn seeded(seed: u64) -> FaultPlan {
        FaultPlan {
            seed,
            kind: ErrorKind::Runtime,
            rate: [0.0; SITES],
            at: std::array::from_fn(|_| BTreeSet::new()),
            counters: std::array::from_fn(|_| AtomicU64::new(0)),
            injected: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }

    /// Set a site's fault rate in [0, 1] (builder style).
    pub fn with_rate(mut self, site: FaultSite, rate: f64) -> FaultPlan {
        self.rate[site.index()] = rate.clamp(0.0, 1.0);
        self
    }

    /// Force a fault on exactly the `n`th check (0-based) at a site —
    /// the precision tool for byte-identity tests that need ONE fault at
    /// a known point.
    pub fn with_fault_at(mut self, site: FaultSite, n: u64) -> FaultPlan {
        self.at[site.index()].insert(n);
        self
    }

    /// Classify injected errors as `kind` (default [`ErrorKind::Runtime`],
    /// which the retry policy treats as transient).
    pub fn with_kind(mut self, kind: ErrorKind) -> FaultPlan {
        self.kind = kind;
        self
    }

    /// Build a plan from the fault knobs, or `None` when every rate is 0
    /// (the production case: callers then skip installing any hook).
    pub fn from_env() -> Option<FaultPlan> {
        let rates = crate::knobs::fault_rates();
        if rates.iter().all(|&r| r <= 0.0) {
            return None;
        }
        let mut plan = FaultPlan::seeded(crate::knobs::fault_seed());
        for (i, &r) in rates.iter().enumerate() {
            plan.rate[i] = f64::from(r).clamp(0.0, 1.0);
        }
        Some(plan)
    }

    /// How many times a site has checked in.
    pub fn checks(&self, site: FaultSite) -> u64 {
        self.counters[site.index()].load(Ordering::Relaxed)
    }

    /// How many faults a site has injected.
    pub fn injected(&self, site: FaultSite) -> u64 {
        self.injected[site.index()].load(Ordering::Relaxed)
    }

    /// Publish every site's check/inject counters into a metrics registry
    /// as `fault.<site label>.checks` / `fault.<site label>.injected`
    /// (instrument names: rust/docs/observability.md § Registry).
    pub fn publish(&self, m: &crate::obs::Metrics) {
        for site in FaultSite::ALL {
            let label = site.label();
            m.counter(&format!("fault.{label}.checks")).set(self.checks(site));
            m.counter(&format!("fault.{label}.injected")).set(self.injected(site));
        }
    }

    /// Would check `n` at `site` fault? Pure; does not advance counters.
    fn hits(&self, site: FaultSite, n: u64) -> bool {
        let i = site.index();
        if self.at[i].contains(&n) {
            return true;
        }
        let rate = self.rate[i];
        rate > 0.0 && unit(splitmix64(self.seed ^ mix(i as u64, n))) < rate
    }
}

impl FaultInject for FaultPlan {
    fn check(&self, site: FaultSite) -> Result<()> {
        let i = site.index();
        let n = self.counters[i].fetch_add(1, Ordering::Relaxed);
        if self.hits(site, n) {
            self.injected[i].fetch_add(1, Ordering::Relaxed);
            return Err(Error::new(
                self.kind,
                format!("injected fault at {} (check #{n})", site.label()),
            ));
        }
        Ok(())
    }
}

/// splitmix64: the standard 64-bit finalizer — deterministic, seedable,
/// and good enough to turn (seed, site, n) into an i.i.d.-looking stream.
fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^ (x >> 31)
}

/// Combine site index and check index into one well-spread word.
fn mix(site: u64, n: u64) -> u64 {
    splitmix64(site.wrapping_mul(0x517c_c1b7_2722_0a95).wrapping_add(n))
}

/// Map a hash to the unit interval [0, 1).
fn unit(x: u64) -> f64 {
    (x >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_seed_same_schedule() {
        let mk = || FaultPlan::seeded(42).with_rate(FaultSite::ExecRun, 0.3);
        let (a, b) = (mk(), mk());
        let sched = |p: &FaultPlan| -> Vec<bool> {
            (0..200).map(|_| p.check(FaultSite::ExecRun).is_err()).collect()
        };
        assert_eq!(sched(&a), sched(&b));
        assert!(a.injected(FaultSite::ExecRun) > 0, "rate 0.3 over 200 checks");
    }

    #[test]
    fn different_seeds_differ() {
        let a = FaultPlan::seeded(1).with_rate(FaultSite::ExecRun, 0.5);
        let b = FaultPlan::seeded(2).with_rate(FaultSite::ExecRun, 0.5);
        let sched = |p: &FaultPlan| -> Vec<bool> {
            (0..128).map(|_| p.check(FaultSite::ExecRun).is_err()).collect()
        };
        assert_ne!(sched(&a), sched(&b));
    }

    #[test]
    fn explicit_fault_at_fires_exactly_once() {
        let p = FaultPlan::seeded(7).with_fault_at(FaultSite::AdapterLoad, 2);
        let hits: Vec<bool> =
            (0..6).map(|_| p.check(FaultSite::AdapterLoad).is_err()).collect();
        assert_eq!(hits, vec![false, false, true, false, false, false]);
        assert_eq!(p.injected(FaultSite::AdapterLoad), 1);
        assert_eq!(p.checks(FaultSite::AdapterLoad), 6);
    }

    #[test]
    fn sites_have_independent_counters() {
        let p = FaultPlan::seeded(9).with_fault_at(FaultSite::ExecRun, 0);
        assert!(p.check(FaultSite::ExecRun).is_err());
        // other sites are untouched by ExecRun's schedule
        assert!(p.check(FaultSite::ArtifactRead).is_ok());
        assert!(p.check(FaultSite::StateReadback).is_ok());
        assert_eq!(p.checks(FaultSite::ExecRun), 1);
        assert_eq!(p.checks(FaultSite::ArtifactRead), 1);
    }

    #[test]
    fn injected_error_is_classified_and_labeled() {
        let p = FaultPlan::seeded(1)
            .with_fault_at(FaultSite::StateReadback, 0)
            .with_kind(ErrorKind::Io);
        let e = p.check(FaultSite::StateReadback).unwrap_err();
        assert_eq!(e.kind(), ErrorKind::Io);
        assert!(format!("{e}").contains("state_readback"), "{e}");
    }

    #[test]
    fn rate_extremes() {
        let never = FaultPlan::seeded(3);
        assert!((0..64).all(|_| never.check(FaultSite::ExecRun).is_ok()));
        let always = FaultPlan::seeded(3).with_rate(FaultSite::ExecRun, 1.0);
        assert!((0..64).all(|_| always.check(FaultSite::ExecRun).is_err()));
    }

    #[test]
    fn rate_roughly_matches_over_many_checks() {
        let p = FaultPlan::seeded(0xF00D).with_rate(FaultSite::ExecRun, 0.25);
        let n = 4000u64;
        for _ in 0..n {
            let _ = p.check(FaultSite::ExecRun);
        }
        let frac = p.injected(FaultSite::ExecRun) as f64 / n as f64;
        assert!((frac - 0.25).abs() < 0.05, "observed fault rate {frac}");
    }

    #[test]
    fn no_faults_is_a_noop() {
        let nf = NoFaults;
        assert!((0..8).all(|_| nf.check(FaultSite::ExecRun).is_ok()));
    }

    #[test]
    fn site_labels_and_indices_are_stable() {
        for (i, s) in FaultSite::ALL.iter().enumerate() {
            assert_eq!(s.index(), i);
            assert!(!s.label().is_empty());
        }
    }

    #[test]
    fn session_sites_are_registered_and_independent() {
        // the PR-9 session sites append after the original four, so every
        // pre-existing seeded schedule stays byte-for-byte stable
        assert_eq!(FaultSite::ALL.len(), SITES);
        assert_eq!(FaultSite::StatePersist.index(), 4);
        assert_eq!(FaultSite::StateLoad.index(), 5);
        assert_eq!(FaultSite::StatePersist.label(), "state_persist");
        assert_eq!(FaultSite::StateLoad.label(), "state_load");
        let p = FaultPlan::seeded(11).with_fault_at(FaultSite::StatePersist, 0);
        assert!(p.check(FaultSite::StatePersist).is_err());
        assert!(p.check(FaultSite::StateLoad).is_ok());
        assert_eq!(p.injected(FaultSite::StatePersist), 1);
        assert_eq!(p.injected(FaultSite::StateLoad), 0);
    }
}
