//! Experiment coordinator: the paper's per-cell pipeline as staged jobs.
//!
//!   pretrain (stand-in for the public checkpoints; cached in-process
//!   behind a OnceLock map + atomically-written checkpoint file)
//!     → [SDT only] warmup on a data subset + dimension selection + revert
//!     → LR grid search (short runs, paper Sec. C.1)
//!     → fine-tune with early stopping on val loss
//!     → evaluate (classification fwd / generation decode / regression)
//!
//! All method/metric dispatch is typed ([`crate::suite::PeftMethod`],
//! [`crate::suite::Metric`], [`crate::suite::VariantId`]); multi-cell
//! scheduling lives in [`crate::suite::Suite`], which drives
//! [`Pipeline::finetune_with_base`] from a worker pool.

use std::collections::{BTreeMap, HashMap};
use std::sync::{Arc, Mutex, OnceLock};

use crate::error::Result;

use crate::config::ExperimentConfig;
use crate::data::{tasks, BatchIter, Dataset};
use crate::eval::{self, Generator};
use crate::manifest::Manifest;
use crate::peft::{self, select_dimensions, Budget, Criterion};
use crate::runtime::Engine;
use crate::suite::VariantId;
use crate::tensor::{Rng, Tensor};
use crate::train::{checkpoint, TrainConfig, Trainer};

/// All scores from one experiment.
#[derive(Debug, Clone)]
pub struct Outcome {
    /// Artifact variant that was fine-tuned.
    pub variant: String,
    /// Dataset name.
    pub dataset: String,
    /// main metric value (acc / matthews / R-L / BLEU / exec acc)
    pub metric: f64,
    /// all computed scores by name
    pub scores: BTreeMap<String, f64>,
    /// Trainable-parameter budget, percent.
    pub budget_pct: f64,
    /// Learning rate picked by the grid search.
    pub chosen_lr: f32,
    /// Optimizer steps taken.
    pub steps: usize,
    /// (step, loss) training curve.
    pub history: Vec<(usize, f32)>,
    /// wall-clock seconds spent in dimension selection (SDT only)
    pub dim_select_s: f64,
    /// wall-clock seconds per training epoch (mean)
    pub epoch_s: f64,
}

/// The per-experiment pipeline bound to an engine + manifest.
pub struct Pipeline<'a> {
    /// Shared PJRT engine (compiled-executable cache).
    pub engine: &'a Engine,
    /// Artifact manifest.
    pub manifest: &'a Manifest,
}

type Ckpt = Arc<BTreeMap<String, Tensor>>;

/// Process-wide pretrained-base cache, keyed like the checkpoint file
/// (`arch|steps`): concurrent suite workers and repeated `finetune` calls
/// share one in-memory copy instead of re-reading (or racing to write)
/// the checkpoint file.
fn pretrain_cache() -> &'static Mutex<HashMap<String, Ckpt>> {
    static CACHE: OnceLock<Mutex<HashMap<String, Ckpt>>> = OnceLock::new();
    CACHE.get_or_init(|| Mutex::new(HashMap::new()))
}

impl<'a> Pipeline<'a> {
    /// Bind a pipeline to an engine + manifest.
    pub fn new(engine: &'a Engine, manifest: &'a Manifest) -> Self {
        Pipeline { engine, manifest }
    }

    /// Pretrain (or load cached) the frozen base model for an architecture.
    /// Stand-in for the paper's pretrained checkpoints — see DESIGN.md
    /// §Substitutions. The seed only matters the first time a given
    /// (arch, steps) base is built; afterwards the cached copy is shared.
    pub fn pretrained(&self, arch: &str, steps: usize, seed: u64) -> Result<Ckpt> {
        let key = format!("{arch}|{steps}");
        let lock = |m: &'static Mutex<HashMap<String, Ckpt>>| {
            // a panicked builder must not wedge the shared base cache
            m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
        };
        if let Some(hit) = lock(pretrain_cache()).get(&key) {
            return Ok(hit.clone());
        }
        let map = Arc::new(self.pretrain_uncached(arch, steps, seed)?);
        // racing builders both insert equivalent maps; first one wins
        let mut cache = lock(pretrain_cache());
        Ok(cache.entry(key).or_insert(map).clone())
    }

    fn pretrain_uncached(&self, arch: &str, steps: usize, seed: u64)
        -> Result<BTreeMap<String, Tensor>> {
        let ckpt_path = crate::results_dir().join(format!("pretrained_{arch}_{steps}.ckpt"));
        if ckpt_path.exists() {
            return checkpoint::load(&ckpt_path);
        }
        let variant = format!("{arch}_full");
        let cfg = TrainConfig { lr: 3e-3, schedule_total: steps.max(1), ..Default::default() };
        let mut tr = Trainer::new(self.engine, self.manifest, &variant, &cfg)?;
        let mut rng = Rng::new(seed ^ 0xbeef);
        if tr.variant.reg {
            // regression archs need no pretraining (random init = "frozen")
            let map = tr.params_map();
            save_atomic(&map, &ckpt_path)?;
            return Ok(map);
        }
        let corpus = tasks::pretrain_corpus(seed, 1 << 17);
        let (b, l) = (tr.variant.batch_b, tr.variant.batch_l);
        for s in 0..steps {
            let batch = crate::data::make_lm_batch(&corpus, &mut rng, b, l);
            let loss = tr.step(&batch)?;
            if s % 50 == 0 {
                eprintln!("[pretrain {arch}] step {s}/{steps} loss {loss:.4}");
            }
        }
        let map = tr.params_map();
        save_atomic(&map, &ckpt_path)?;
        Ok(map)
    }

    /// SDT stage: warmup on a subset, select dimensions, revert, mask.
    /// Returns selection wall-clock seconds.
    fn sdt_stage(&self, tr: &mut Trainer, ds: &Dataset, cfg: &ExperimentConfig)
        -> Result<f64> {
        let t0 = std::time::Instant::now();
        let before = tr.train_map();
        let snap = tr.snapshot_train();
        let mut rng = Rng::new(cfg.seed ^ 0x5d7);
        let (b, l) = (tr.variant.batch_b, tr.variant.batch_l);
        let mut grad_acc: BTreeMap<String, Tensor> = BTreeMap::new();
        let mut it = BatchIter::new(&ds.train, &mut rng, b, l);
        for _ in 0..cfg.sdt.warmup_batches {
            let Some((batch, _)) = it.next() else { break };
            tr.step(&batch)?;
            if cfg.sdt.criterion == Criterion::GradMagnitude {
                for (meta, g) in tr.variant.train_params.clone().iter()
                    .zip(tr.last_grads())
                {
                    let e = grad_acc
                        .entry(meta.name.clone())
                        .or_insert_with(|| Tensor::zeros(&g.shape));
                    for (a, &x) in e.data.iter_mut().zip(&g.data) {
                        *a += x.abs();
                    }
                }
            }
        }
        let after = if cfg.sdt.criterion == Criterion::GradMagnitude {
            // |grad| accumulation plays the role of the post-warmup snapshot
            let mut m = before.clone();
            for (k, v) in &grad_acc {
                // log-space: selection exponentiates, so take ln(1+acc)
                let Some(t) = m.get_mut(k) else { continue };
                for (x, &a) in t.data.iter_mut().zip(&v.data) {
                    *x += (1.0 + a).ln();
                }
            }
            m
        } else {
            tr.train_map()
        };
        let (masks, _sel) = select_dimensions(&tr.variant, &before, &after, &cfg.sdt);
        // restore first: the optimizer reset makes the mask plan compile to
        // sparse index sets (moments are zero under the frozen entries)
        tr.restore_train(snap);
        tr.set_masks(masks);
        Ok(t0.elapsed().as_secs_f64())
    }

    fn run_epochs(&self, tr: &mut Trainer, ds: &Dataset, cfg: &ExperimentConfig,
                  epochs: usize, seed_tag: u64) -> Result<(f64, f64)> {
        let (b, l) = (tr.variant.batch_b, tr.variant.batch_l);
        let mut best_val = f64::INFINITY;
        let mut best_params: Option<Vec<Tensor>> = None;
        let mut epoch_times = Vec::new();
        for ep in 0..epochs {
            let t0 = std::time::Instant::now();
            let mut rng = Rng::new(cfg.seed ^ seed_tag ^ (ep as u64 + 1));
            let it = BatchIter::new(&ds.train, &mut rng, b, l);
            let cap = if cfg.max_batches_per_epoch == 0 {
                usize::MAX
            } else {
                cfg.max_batches_per_epoch
            };
            for (batch, _) in it.take(cap) {
                tr.step(&batch)?;
            }
            epoch_times.push(t0.elapsed().as_secs_f64());
            // refresh the literal cache once so the eval batches below
            // reuse it instead of re-serializing dirty leaves per call
            tr.sync_device()?;
            let val = eval::eval_split_loss(tr, &ds.val, cfg.seed ^ 0x7a1)?;
            if val < best_val {
                best_val = val;
                best_params = Some(tr.snapshot_train());
            }
        }
        if let Some(p) = best_params {
            tr.set_train_params(p); // early stopping: keep best epoch
        }
        Ok((best_val, crate::tensor::mean(&epoch_times)))
    }

    /// LR grid search: short runs on a training subset, pick best val loss.
    fn pick_lr(&self, ds: &Dataset, cfg: &ExperimentConfig,
               base: &BTreeMap<String, Tensor>) -> Result<f32> {
        if cfg.lr_grid.len() == 1 {
            return Ok(cfg.lr_grid[0]);
        }
        let mut best = (f64::INFINITY, cfg.lr_grid[0]);
        for &lr in &cfg.lr_grid {
            let tcfg = TrainConfig {
                lr,
                weight_decay: cfg.weight_decay,
                schedule_total: 8,
                ..Default::default()
            };
            let mut tr = Trainer::new(self.engine, self.manifest, &cfg.variant, &tcfg)?;
            tr.load_base(base);
            let mut sub = Dataset {
                name: ds.name.clone(),
                train: ds.train.iter().take(8 * tr.variant.batch_b).cloned().collect(),
                val: ds.val.clone(),
                test: vec![],
                metric: ds.metric,
            };
            sub.val.truncate(4 * tr.variant.batch_b);
            let (val, _) = self.run_epochs(&mut tr, &sub, cfg, 1, 0x99)?;
            if val < best.0 {
                best = (val, lr);
            }
        }
        Ok(best.1)
    }

    /// Full experiment: resolves the variant's architecture, builds (or
    /// reuses) the shared pretrained base, then runs
    /// [`Pipeline::finetune_with_base`].
    pub fn finetune(&self, cfg: &ExperimentConfig) -> Result<Outcome> {
        let vid = VariantId::parse(&cfg.variant)?;
        let base = self.pretrained(&vid.arch, cfg.pretrain_steps, cfg.seed)?;
        self.finetune_with_base(cfg, &base)
    }

    /// Fine-tune + evaluate one experiment cell against an already-built
    /// pretrained base (the suite runner stages bases once per arch and
    /// fans cells out over workers). Returns scores on the test split.
    pub fn finetune_with_base(&self, cfg: &ExperimentConfig,
                              base: &BTreeMap<String, Tensor>) -> Result<Outcome> {
        let vid = VariantId::parse(&cfg.variant)?;
        let ds = tasks::by_name(&cfg.dataset, cfg.seed, cfg.n_train)?;
        let lr = self.pick_lr(&ds, cfg, base)?;

        let steps_per_epoch = if cfg.max_batches_per_epoch > 0 {
            cfg.max_batches_per_epoch
        } else {
            cfg.n_train / 8
        };
        let tcfg = TrainConfig {
            lr,
            weight_decay: cfg.weight_decay,
            schedule_total: (cfg.epochs * steps_per_epoch).max(1),
            ..Default::default()
        };
        let mut tr = Trainer::new(self.engine, self.manifest, &cfg.variant, &tcfg)?;
        tr.load_base(base);

        let dim_select_s = if vid.method.is_sdt() {
            self.sdt_stage(&mut tr, &ds, cfg)?
        } else {
            0.0
        };

        let (_best_val, epoch_s) = self.run_epochs(&mut tr, &ds, cfg, cfg.epochs, 0x7a11)?;
        tr.sync_device()?; // early-stopping restore dirtied the leaf cache

        // ---- evaluation ------------------------------------------------------
        let budget = Budget::of(&tr.variant, Some(tr.masks()));
        let mut scores = BTreeMap::new();
        let metric;
        if ds.metric.generative() {
            let mut merged = tr.params_map();
            let mut peft_meta = tr.variant.peft.clone();
            if cfg.alpha > 0 {
                peft_meta.alpha = cfg.alpha;
            }
            peft::merge_lora(&mut merged, &peft_meta);
            let gen = Generator::new(self.engine, self.manifest, &vid.decode_variant(),
                                     &merged)?;
            let h0 = if merged.keys().any(|k| k.ends_with(".h0")) {
                Some(&merged)
            } else {
                None
            };
            let g = if cfg.beam > 1 {
                eval::eval_generation_beam(&gen, &ds, &ds.test, cfg.beam,
                                           cfg.gen_max_new, cfg.seed, h0)?
            } else {
                eval::eval_generation(&gen, &ds, &ds.test, cfg.gen_max_new,
                                      cfg.seed, h0)?
            };
            scores.insert("rouge1".into(), g.rouge1);
            scores.insert("rouge2".into(), g.rouge2);
            scores.insert("rougeL".into(), g.rougel);
            scores.insert("bleu".into(), g.bleu);
            scores.insert("meteor".into(), g.meteor);
            scores.insert("exec".into(), g.exec_acc);
            metric = ds.metric.main_gen_score(&g);
        } else {
            let m = eval::eval_classification(&tr, &ds.test, ds.metric)?;
            scores.insert(ds.metric.name().to_string(), m);
            metric = m;
        }

        Ok(Outcome {
            variant: cfg.variant.clone(),
            dataset: cfg.dataset.clone(),
            metric,
            scores,
            budget_pct: budget.percent(),
            chosen_lr: lr,
            steps: tr.step_count,
            history: tr.history.clone(),
            dim_select_s,
            epoch_s,
        })
    }

    /// Synthetic Fig. 2 data: random inputs through the 1-layer target model
    /// (`s4reg_t_full` with its random init) to produce regression targets.
    pub fn synthetic_s4_data(&self, seed: u64, n_batches: usize, seqlen: usize)
        -> Result<(Vec<Tensor>, Vec<Tensor>)> {
        let tgt = Trainer::new(self.engine, self.manifest, "s4reg_t_full",
                               &TrainConfig::default())?;
        let (b, d) = (tgt.variant.batch_b, tgt.variant.arch.d_model);
        crate::ensure!(
            seqlen == tgt.variant.batch_l,
            "s4reg artifacts are shape-specialized to L={}, got {seqlen}",
            tgt.variant.batch_l
        );
        let mut rng = Rng::new(seed ^ 0xf162);
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for _ in 0..n_batches {
            let data: Vec<f32> = (0..b * seqlen * d)
                .map(|_| rng.below(10) as f32) // ints 0..9 as in the paper
                .collect();
            let x = Tensor::from_vec(&[b, seqlen, d], data);
            let y = tgt.forward_reg(&x)?;
            xs.push(x);
            ys.push(y);
        }
        Ok((xs, ys))
    }
}

/// Write a checkpoint atomically (unique tmp file + rename) so concurrent
/// builders — other processes AND racing threads in this one — never
/// publish a torn file; each writes its own tmp, last rename wins whole.
fn save_atomic(map: &BTreeMap<String, Tensor>, path: &std::path::Path) -> Result<()> {
    static SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
    let n = SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    let tmp = path.with_extension(format!("tmp.{}.{n}", std::process::id()));
    checkpoint::save(map, &tmp)?;
    std::fs::rename(&tmp, path)?;
    Ok(())
}

/// Save an outcome's loss curve as CSV (results/<name>.csv).
pub fn save_history(name: &str, history: &[(usize, f32)]) {
    let mut s = String::from("step,loss\n");
    for (st, l) in history {
        s.push_str(&format!("{st},{l}\n"));
    }
    std::fs::write(crate::results_dir().join(name), s).ok();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn save_history_writes() {
        save_history("test_hist.csv", &[(1, 0.5), (2, 0.25)]);
        let p = crate::results_dir().join("test_hist.csv");
        let s = std::fs::read_to_string(&p).unwrap();
        assert!(s.contains("2,0.25"));
        std::fs::remove_file(p).ok();
    }
}
