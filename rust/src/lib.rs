//! # ssm-peft
//!
//! Reproduction of **“Parameter-Efficient Fine-Tuning of State Space Models”**
//! (ICML 2025) as a three-layer Rust + JAX + Pallas system.
//!
//! This crate is Layer 3: the fine-tuning coordinator. It loads AOT-compiled
//! HLO artifacts (produced once by `python -m compile.aot` from the JAX/Pallas
//! layers) and runs the paper's full experimental pipeline — pretraining,
//! PEFT benchmarking, SDT dimension selection, fine-tuning, generation-based
//! evaluation — with Python never on the training path.
//!
//! Module map (see rust/docs/architecture.md for the paper↔module index):
//! - [`runtime`] — PJRT CPU client, artifact loading/compile cache
//! - [`manifest`] — the Python↔Rust artifact contract
//! - [`tensor`], [`json`] — dependency-free substrates
//! - [`optim`] — AdamW/SGD, LR schedules, gradient clipping, and the
//!   fused [`optim::ParamArena`] hot path (rust/docs/performance.md)
//! - [`peft`] — PEFT engine: budgets, masks, **SDT dimension selection**
//! - [`data`] — synthetic analogues of GLUE/DART/SAMSum/Spider/CIFAR/CelebA
//! - [`metrics`] — accuracy, Matthews, ROUGE-1/2/L, BLEU, METEOR-lite, MSE
//! - [`train`] — the training engine (epochs, early stopping, checkpoints)
//! - [`eval`] — the shared generation core: the [`eval::StepDecode`]
//!   stepwise interface, the [`eval::ChunkPrefill`] sequence-level prompt
//!   ingestion, the literal-resident [`eval::DecodeState`], plus
//!   greedy/beam strategies over them
//! - [`coordinator`] — the per-experiment pipeline (pretrain → SDT → tune)
//! - [`suite`] — typed experiment API (`PeftMethod`/`Metric`/`VariantId`)
//!   + the parallel suite runner + JSONL `RunRecord` streams
//! - [`serve`] — online multi-adapter generation: LRU adapter registry,
//!   continuous-batching scheduler, `serve` CLI loop (stdin/TCP)
//! - [`obs`] — serving observability: metrics registry, span tracing
//!   behind the [`obs::Clock`] trait (rust/docs/observability.md)
//! - [`bench`] — timing harness used by `cargo bench` targets + the
//!   `bench hotpath` telemetry ([`bench::hotpath`]) + the `bench serving`
//!   load harness ([`bench::serving`])
//! - [`error`] — the crate-wide [`error::Error`]/[`error::Result`] taxonomy
//! - [`fault`] — deterministic seeded fault injection for the serve stack
//!   (rust/docs/robustness.md)
//! - [`knobs`] — the typed `SSM_PEFT_*` environment-knob registry
//! - [`lint`] — repolint, the first-party static-analysis pass (`lint` CLI)
//! - [`xla`] — in-tree PJRT facade (host-side literals + device stub)

#![warn(missing_docs)]

pub mod bench;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod error;
pub mod eval;
pub mod fault;
pub mod json;
pub mod knobs;
pub mod lint;
pub mod manifest;
pub mod metrics;
pub mod obs;
pub mod optim;
pub mod peft;
pub mod runtime;
pub mod serve;
pub mod suite;
pub mod tensor;
pub mod train;
pub mod xla;

/// Crate version (mirrors Cargo.toml).
pub const VERSION: &str = env!("CARGO_PKG_VERSION");

/// Default artifacts directory (overridable via `SSM_PEFT_ARTIFACTS`,
/// read through [`knobs::artifacts_override`]).
pub fn artifacts_dir() -> std::path::PathBuf {
    crate::knobs::artifacts_override().unwrap_or_else(|| {
        // works from repo root and from target/ subprocesses
        let here = std::path::Path::new("artifacts");
        if here.exists() {
            here.to_path_buf()
        } else {
            std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
        }
    })
}

/// Results directory for bench/experiment CSV+JSONL output. Overridable
/// via `SSM_PEFT_RESULTS` (through [`knobs::results_override`]) so parallel
/// suite runs and CI can isolate their output.
pub fn results_dir() -> std::path::PathBuf {
    let d = crate::knobs::results_override().unwrap_or_else(|| {
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("results")
    });
    std::fs::create_dir_all(&d).ok();
    d
}
