//! The no-panic allowlist ledger: the *only* sanctioned panicking sites in
//! library code, each with a justification and an exact count.
//!
//! Enforcement is exact-match in both directions:
//!
//! - more hits than the ledger says → **growth** (a new panic site slipped
//!   in) → lint failure;
//! - fewer hits → **stale ledger** (a site was fixed; shrink the entry) →
//!   lint failure, so the ledger can only ratchet down deliberately.
//!
//! The burn-down history lives in `rust/docs/linting.md`. The self-check
//! test (`rust/tests/repolint_selfcheck.rs`) pins the total at
//! [`MAX_ENTRIES`] so the ledger cannot quietly grow back.

use super::rules::Rule;

/// Hard ceiling on ledger size (issue acceptance bound is 10; we sit far
/// below it).
pub const MAX_ENTRIES: usize = 10;

/// One sanctioned (file, rule) bucket with its exact expected hit count.
#[derive(Debug, Clone, Copy)]
pub struct Entry {
    /// Workspace-relative path.
    pub file: &'static str,
    /// The waived rule.
    pub rule: Rule,
    /// Exact number of sanctioned hits in that file.
    pub count: usize,
    /// Why these sites are allowed to stay.
    pub justification: &'static str,
}

/// The ledger. The pre-refactor tree carried 62 violations; everything
/// else was fixed at the source (see the burn-down table in
/// rust/docs/linting.md).
pub const ALLOWLIST: &[Entry] = &[
    Entry {
        file: "rust/src/tensor.rs",
        rule: Rule::NoPanic,
        count: 1,
        justification: "Tensor::row() on a rank-0 tensor is a programmer error in \
                        per-element hot loops; returning Result here would put a \
                        branch in the innermost decode path. Shapes are validated \
                        at construction.",
    },
    Entry {
        file: "rust/src/train/mod.rs",
        rule: Rule::NoPanic,
        count: 1,
        justification: "Trainer::refresh_frozen_lits serializes shape-validated \
                        tensors, which cannot fail; load_base (its only caller) \
                        is used by ~15 bench/example sites that would all have \
                        to plumb an impossible error.",
    },
];

/// Look up the ledger entry for a (file, rule) bucket.
pub fn entry(file: &str, rule: Rule) -> Option<&'static Entry> {
    ALLOWLIST.iter().find(|e| e.file == file && e.rule == rule)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ledger_is_small_and_justified() {
        assert!(ALLOWLIST.len() <= MAX_ENTRIES);
        for e in ALLOWLIST {
            assert!(e.count >= 1, "{}: zero-count entry is dead weight", e.file);
            assert!(
                e.justification.len() > 20,
                "{}: justification must say why, not just that",
                e.file
            );
        }
    }

    #[test]
    fn buckets_are_unique() {
        for (i, a) in ALLOWLIST.iter().enumerate() {
            for b in &ALLOWLIST[i + 1..] {
                assert!(
                    !(a.file == b.file && a.rule == b.rule),
                    "duplicate bucket {} / {}",
                    a.file,
                    a.rule
                );
            }
        }
    }

    #[test]
    fn lookup_matches_bucket() {
        assert!(entry("rust/src/tensor.rs", Rule::NoPanic).is_some());
        assert!(entry("rust/src/tensor.rs", Rule::Determinism).is_none());
    }
}
