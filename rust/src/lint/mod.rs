//! repolint: the first-party static-analysis pass (`ssm-peft lint`).
//!
//! Zero-dependency by construction: a lightweight tokenizer
//! ([`lexer`]) feeds four rules ([`rules`]) over every `.rs` file in the
//! workspace, an exact-count allowlist ledger ([`allowlist`]) holds the few
//! sanctioned exceptions, and this module drives the walk plus the
//! cross-file contracts:
//!
//! - every `SSM_PEFT_*` name mentioned anywhere in non-test code must be
//!   registered in [`crate::knobs::KNOBS`];
//! - every registered knob must be documented by name in `rust/docs/`;
//! - the `BENCH_hotpath.json` schema constant
//!   ([`crate::bench::hotpath::BENCH_HOTPATH_SCHEMA`]) must match the
//!   schema shown in `rust/docs/performance.md`, and the
//!   `BENCH_serving.json` constant
//!   ([`crate::bench::serving::BENCH_SERVING_SCHEMA`]) must match
//!   `rust/docs/observability.md`.
//!
//! Run with `cargo run --release -- lint`; rule catalogue and waiver
//! etiquette live in `rust/docs/linting.md`.

pub mod allowlist;
pub mod lexer;
pub mod rules;

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::error::{Context, Result};
use rules::{Rule, UnsafeSite, Violation};

/// Directories scanned, relative to the workspace root.
const SCAN_DIRS: &[&str] = &["rust/src", "rust/benches", "rust/tests", "examples"];

/// Path fragments excluded from the walk: fixtures violate rules on
/// purpose, and `target/` is build output.
const EXCLUDE_FRAGMENTS: &[&str] = &["lint_fixtures", "/target/"];

/// Everything one lint run produced.
#[derive(Debug)]
pub struct LintReport {
    /// Rule violations (after allowlist subtraction).
    pub violations: Vec<Violation>,
    /// Hits absorbed by the allowlist ledger (count).
    pub allowlisted: usize,
    /// Ledger/contract drift: growth, stale entries, undocumented knobs,
    /// schema-pin mismatches.
    pub drift: Vec<String>,
    /// Every `unsafe` site found (annotated ones included) — the inventory.
    pub unsafe_sites: Vec<UnsafeSite>,
    /// Number of `.rs` files scanned.
    pub files_scanned: usize,
}

impl LintReport {
    /// Whether the tree is clean (no violations, no drift).
    pub fn ok(&self) -> bool {
        self.violations.is_empty() && self.drift.is_empty()
    }

    /// Human-readable report (what the CLI prints).
    pub fn render(&self) -> String {
        let mut out = String::new();
        for v in &self.violations {
            out.push_str(&format!("{v}\n"));
        }
        for d in &self.drift {
            out.push_str(&format!("drift: {d}\n"));
        }
        out.push_str(&format!(
            "repolint: {} file(s), {} violation(s), {} drift, {} allowlisted, {} unsafe site(s)\n",
            self.files_scanned,
            self.violations.len(),
            self.drift.len(),
            self.allowlisted,
            self.unsafe_sites.len()
        ));
        out
    }
}

/// The workspace root (parent of the `rust/` crate directory).
pub fn workspace_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(Path::to_path_buf)
        .unwrap_or_else(|| PathBuf::from("."))
}

/// Run the full lint pass rooted at `root` (see [`workspace_root`]).
pub fn run(root: &Path) -> Result<LintReport> {
    let files = collect_files(root)?;
    let mut raw_violations: Vec<Violation> = Vec::new();
    let mut unsafe_sites: Vec<UnsafeSite> = Vec::new();
    let mut mentions: BTreeMap<String, Vec<String>> = BTreeMap::new();

    for path in &files {
        let rel = rel_path(root, path);
        let src = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let scan = lexer::scan(&src);
        let (v, u) = rules::check_file(&rel, &scan);
        raw_violations.extend(v);
        unsafe_sites.extend(u);
        // knob mentions, skipping #[cfg(test)] spans (tests may name
        // deliberately-unregistered knobs to probe the registry)
        for (idx, raw_line) in src.split('\n').enumerate() {
            if scan.in_test(idx + 1) {
                continue;
            }
            for name in rules::knob_mentions(raw_line) {
                mentions.entry(name).or_default().push(format!("{rel}:{}", idx + 1));
            }
        }
    }

    let (mut violations, allowlisted, mut drift) = apply_allowlist(raw_violations);
    knob_docs_check(root, &mut drift);
    knob_registry_check(&mentions, &mut violations);
    schema_pin_check(root, &mut drift);

    Ok(LintReport { violations, allowlisted, drift, unsafe_sites, files_scanned: files.len() })
}

/// Subtract the allowlist from raw violations with exact-count semantics.
/// Returns (remaining violations, absorbed count, drift messages).
fn apply_allowlist(raw: Vec<Violation>) -> (Vec<Violation>, usize, Vec<String>) {
    let mut counts: BTreeMap<(String, &'static str), usize> = BTreeMap::new();
    for v in &raw {
        *counts.entry((v.file.clone(), v.rule.name())).or_default() += 1;
    }
    let mut drift = Vec::new();
    let mut allowlisted = 0usize;
    let mut remaining = Vec::new();
    for v in raw {
        match allowlist::entry(&v.file, v.rule) {
            Some(_) => allowlisted += 1,
            None => remaining.push(v),
        }
    }
    for e in allowlist::ALLOWLIST {
        let actual =
            counts.get(&(e.file.to_string(), e.rule.name())).copied().unwrap_or(0);
        if actual > e.count {
            drift.push(format!(
                "{}: [{}] {} hit(s), ledger allows {} — new panic site? fix it or \
                 (rarely) grow the ledger with a justification",
                e.file, e.rule, actual, e.count
            ));
        } else if actual < e.count {
            drift.push(format!(
                "{}: [{}] {} hit(s), ledger expects {} — stale entry; ratchet the \
                 ledger down in rust/src/lint/allowlist.rs and rust/docs/linting.md",
                e.file, e.rule, actual, e.count
            ));
        }
    }
    (remaining, allowlisted, drift)
}

/// Every registered knob must be documented by name under `rust/docs/`,
/// and the docs must not reference unregistered knobs.
fn knob_docs_check(root: &Path, drift: &mut Vec<String>) {
    let docs = read_docs(root);
    for k in crate::knobs::KNOBS {
        if !docs.iter().any(|(_, text)| text.contains(k.name)) {
            drift.push(format!(
                "knob {} is registered but not documented in rust/docs/",
                k.name
            ));
        }
    }
    // and docs must not reference unregistered knobs (doc rot)
    for (file, text) in &docs {
        for name in rules::knob_mentions(text) {
            if crate::knobs::lookup(&name).is_none() {
                drift.push(format!("{file}: documents unregistered knob {name}"));
            }
        }
    }
}

/// Every `SSM_PEFT_*` mention in non-test code must be a registered knob.
fn knob_registry_check(
    mentions: &BTreeMap<String, Vec<String>>,
    violations: &mut Vec<Violation>,
) {
    for (name, sites) in mentions {
        if crate::knobs::lookup(name).is_none() {
            for site in sites {
                let (file, line) = split_site(site);
                violations.push(Violation {
                    file,
                    line,
                    rule: Rule::KnobRegistry,
                    msg: format!("unregistered knob {name} (add it to crate::knobs::KNOBS)"),
                });
            }
        }
    }
}

/// Emitted-JSON schema constants must match their docs: one pin per
/// (constant, doc) pair, so a schema bump without a docs update is drift.
fn schema_pin_check(root: &Path, drift: &mut Vec<String>) {
    let pins: &[(u32, &str, &str)] = &[
        (crate::bench::hotpath::BENCH_HOTPATH_SCHEMA, "rust/docs/performance.md",
         "BENCH_hotpath.json"),
        (crate::bench::serving::BENCH_SERVING_SCHEMA, "rust/docs/observability.md",
         "BENCH_serving.json"),
    ];
    for (schema, doc, artifact) in pins {
        let pin = format!("\"schema\": {schema}");
        let path = root.join(doc);
        match std::fs::read_to_string(&path) {
            Ok(text) if text.contains(&pin) => {}
            Ok(_) => drift.push(format!(
                "{doc} does not show `{pin}` — {artifact} schema constant and docs \
                 have diverged"
            )),
            Err(e) => drift.push(format!("cannot read {}: {e}", path.display())),
        }
    }
}

/// Walk the scan dirs, collecting `.rs` files in deterministic order.
fn collect_files(root: &Path) -> Result<Vec<PathBuf>> {
    let mut out = Vec::new();
    for dir in SCAN_DIRS {
        let d = root.join(dir);
        if d.is_dir() {
            walk(&d, &mut out)?;
        }
    }
    out.sort();
    Ok(out)
}

fn walk(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    let rd = std::fs::read_dir(dir)
        .with_context(|| format!("listing {}", dir.display()))?;
    for entry in rd {
        let entry = entry.with_context(|| format!("listing {}", dir.display()))?;
        let path = entry.path();
        let lossy = path.to_string_lossy().replace('\\', "/");
        if EXCLUDE_FRAGMENTS.iter().any(|f| lossy.contains(f)) {
            continue;
        }
        if path.is_dir() {
            walk(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Workspace-relative display path with forward slashes.
fn rel_path(root: &Path, path: &Path) -> String {
    path.strip_prefix(root)
        .unwrap_or(path)
        .to_string_lossy()
        .replace('\\', "/")
}

/// All `rust/docs/*.md` files as (relative name, contents).
fn read_docs(root: &Path) -> Vec<(String, String)> {
    let mut out = Vec::new();
    let dir = root.join("rust/docs");
    let Ok(rd) = std::fs::read_dir(&dir) else {
        return out;
    };
    let mut paths: Vec<PathBuf> = rd.flatten().map(|e| e.path()).collect();
    paths.sort();
    for p in paths {
        if p.extension().is_some_and(|e| e == "md") {
            if let Ok(text) = std::fs::read_to_string(&p) {
                out.push((format!("rust/docs/{}", rel_path(&dir, &p)), text));
            }
        }
    }
    out
}

/// Parse a `file:line` site string back into parts.
fn split_site(site: &str) -> (String, usize) {
    match site.rsplit_once(':') {
        Some((f, l)) => (f.to_string(), l.parse().unwrap_or(0)),
        None => (site.to_string(), 0),
    }
}

/// Render the unsafe inventory as a markdown report (written to
/// `results/LINT_unsafe.md` by the CLI).
pub fn render_unsafe_inventory(sites: &[UnsafeSite]) -> String {
    let mut out = String::from(
        "# Unsafe inventory\n\nGenerated by `cargo run --release -- lint`. \
         Every `unsafe` site in the workspace with its SAFETY justification.\n\n\
         | site | code | justification |\n|---|---|---|\n",
    );
    for s in sites {
        out.push_str(&format!(
            "| `{}:{}` | `{}` | {} |\n",
            s.file,
            s.line,
            s.excerpt.replace('|', "\\|"),
            if s.justification.is_empty() {
                "**MISSING**".to_string()
            } else {
                s.justification.replace('|', "\\|")
            }
        ));
    }
    out.push_str(&format!("\n{} site(s).\n", sites.len()));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allowlist_exact_match_absorbs() {
        let v = |file: &str, line: usize| Violation {
            file: file.into(),
            line,
            rule: Rule::NoPanic,
            msg: ".unwrap() in library code".into(),
        };
        // exactly the ledgered count for tensor.rs: absorbed, no drift
        let (rem, allowed, drift) = apply_allowlist(vec![v("rust/src/tensor.rs", 42)]);
        assert!(rem.is_empty());
        assert_eq!(allowed, 1);
        assert!(drift.is_empty(), "{drift:?}");
    }

    #[test]
    fn allowlist_growth_and_stale_are_drift() {
        let v = |line: usize| Violation {
            file: "rust/src/tensor.rs".into(),
            line,
            rule: Rule::NoPanic,
            msg: ".unwrap() in library code".into(),
        };
        let (_, _, drift) = apply_allowlist(vec![v(1), v(2)]);
        assert_eq!(drift.len(), 1);
        assert!(drift[0].contains("new panic site"), "{}", drift[0]);

        let (_, _, drift) = apply_allowlist(vec![]);
        assert_eq!(drift.len(), 1);
        assert!(drift[0].contains("stale entry"), "{}", drift[0]);
    }

    #[test]
    fn unledgered_violations_pass_through() {
        let raw = vec![Violation {
            file: "rust/src/json.rs".into(),
            line: 3,
            rule: Rule::NoPanic,
            msg: ".unwrap() in library code".into(),
        }];
        let (rem, allowed, _) = apply_allowlist(raw);
        assert_eq!(rem.len(), 1);
        assert_eq!(allowed, 0);
    }

    #[test]
    fn unregistered_knob_mention_is_violation() {
        let mut mentions = BTreeMap::new();
        mentions.insert(
            "SSM_PEFT_BOGUS".to_string(),
            vec!["rust/src/lib.rs:10".to_string()],
        );
        let mut violations = Vec::new();
        knob_registry_check(&mentions, &mut violations);
        assert_eq!(violations.len(), 1);
        assert_eq!(violations[0].file, "rust/src/lib.rs");
        assert_eq!(violations[0].line, 10);
    }

    #[test]
    fn registered_knob_mentions_pass() {
        let mut mentions = BTreeMap::new();
        for k in crate::knobs::KNOBS {
            mentions.insert(k.name.to_string(), vec!["rust/src/knobs.rs:1".to_string()]);
        }
        let mut violations = Vec::new();
        knob_registry_check(&mentions, &mut violations);
        assert!(violations.is_empty(), "{violations:?}");
    }

    #[test]
    fn inventory_marks_missing_justifications() {
        let sites = vec![
            UnsafeSite {
                file: "a.rs".into(),
                line: 1,
                excerpt: "unsafe { x }".into(),
                justification: "SAFETY: fine.".into(),
            },
            UnsafeSite {
                file: "b.rs".into(),
                line: 2,
                excerpt: "unsafe { y }".into(),
                justification: String::new(),
            },
        ];
        let md = render_unsafe_inventory(&sites);
        assert!(md.contains("SAFETY: fine."));
        assert!(md.contains("**MISSING**"));
        assert!(md.contains("2 site(s)"));
    }

    #[test]
    fn report_render_and_ok() {
        let r = LintReport {
            violations: vec![],
            allowlisted: 1,
            drift: vec![],
            unsafe_sites: vec![],
            files_scanned: 3,
        };
        assert!(r.ok());
        assert!(r.render().contains("3 file(s), 0 violation(s)"));
    }
}
