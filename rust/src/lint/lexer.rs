//! The repolint tokenizer: a lightweight Rust lexer that separates code
//! from comments and string contents, so the rules never match inside a
//! string literal or a doc comment.
//!
//! Output model (shared by every rule):
//! - `code`: the source with comment text and string *interiors* replaced
//!   by spaces (newlines kept), so line positions are stable and brace
//!   matching sees only real braces;
//! - `comments`: per-line comment text (SAFETY annotations, lint waivers);
//! - `test_spans`: line ranges of `#[cfg(test)]` items (the no-panic rule
//!   exempts test code).
//!
//! Handled: line comments, nested block comments, string literals with
//! escapes, raw strings (`r"…"`, `r#"…"#`), byte strings (`b"…"`,
//! `br#"…"#`), char literals vs. lifetimes, raw identifiers (`r#type`).

use std::collections::BTreeMap;

/// Lexed view of one source file. See the [module docs](self).
#[derive(Debug)]
pub struct Scan {
    /// Source with comments and string interiors blanked (newlines kept).
    pub code: String,
    /// Comment text by 1-based line (multi-line block comments contribute
    /// one entry per line they span).
    pub comments: BTreeMap<usize, String>,
    /// 1-based inclusive line spans of `#[cfg(test)]` items.
    pub test_spans: Vec<(usize, usize)>,
}

impl Scan {
    /// The blanked code of one 1-based line ("" past EOF).
    pub fn code_line(&self, line: usize) -> &str {
        self.code.split('\n').nth(line.saturating_sub(1)).unwrap_or("")
    }

    /// Whether a line falls inside a `#[cfg(test)]` item.
    pub fn in_test(&self, line: usize) -> bool {
        self.test_spans.iter().any(|&(a, b)| a <= line && line <= b)
    }

    /// Comment text attached to a line (empty when none).
    pub fn comment(&self, line: usize) -> &str {
        self.comments.get(&line).map(String::as_str).unwrap_or("")
    }
}

/// A detected string literal start: escape behavior + interior start index.
struct StrStart {
    /// Raw strings ignore backslash escapes.
    raw: bool,
    /// `#` count for the closing delimiter.
    hashes: usize,
    /// Index of the first interior char (past the opening quote).
    body: usize,
}

/// Lex one file. Never fails: unterminated constructs extend to EOF, which
/// matches how rustc would report them anyway (the real compiler gates CI).
pub fn scan(src: &str) -> Scan {
    let cs: Vec<char> = src.chars().collect();
    let n = cs.len();
    let mut out = String::with_capacity(src.len());
    let mut comments: BTreeMap<usize, String> = BTreeMap::new();
    let mut line = 1usize;
    let mut i = 0usize;

    let record = |map: &mut BTreeMap<usize, String>, line: usize, text: &str| {
        map.entry(line).or_default().push_str(text);
    };
    let blank = |out: &mut String, k: usize| out.extend(std::iter::repeat(' ').take(k));

    while i < n {
        let c = cs[i];
        let next = cs.get(i + 1).copied();
        if c == '/' && next == Some('/') {
            // line comment (incl. /// and //!)
            let start = i;
            while i < n && cs[i] != '\n' {
                i += 1;
            }
            let text: String = cs[start..i].iter().collect();
            record(&mut comments, line, &text);
            blank(&mut out, i - start);
        } else if c == '/' && next == Some('*') {
            // nested block comment; record each spanned line's text
            let mut depth = 1usize;
            let mut j = i + 2;
            let mut seg_start = i;
            let mut seg_line = line;
            while j < n && depth > 0 {
                if cs[j] == '/' && cs.get(j + 1) == Some(&'*') {
                    depth += 1;
                    j += 2;
                } else if cs[j] == '*' && cs.get(j + 1) == Some(&'/') {
                    depth -= 1;
                    j += 2;
                } else {
                    if cs[j] == '\n' {
                        let text: String = cs[seg_start..j].iter().collect();
                        record(&mut comments, seg_line, &text);
                        line += 1;
                        seg_line = line;
                        seg_start = j + 1;
                    }
                    j += 1;
                }
            }
            let text: String = cs[seg_start..j].iter().collect();
            record(&mut comments, seg_line, &text);
            for &ch in &cs[i..j] {
                out.push(if ch == '\n' { '\n' } else { ' ' });
            }
            i = j;
        } else if let Some(s) = string_start(&cs, i) {
            let close: String = std::iter::once('"')
                .chain(std::iter::repeat('#').take(s.hashes))
                .collect();
            let (end, nl) = find_close(&cs, s.body, &close, !s.raw);
            for &ch in &cs[i..s.body] {
                out.push(ch); // prefix + opening quote stay
            }
            for &ch in &cs[s.body..end] {
                out.push(if ch == '\n' { '\n' } else { ' ' });
            }
            let stop = (end + close.len()).min(n);
            for &ch in &cs[end..stop] {
                out.push(ch);
            }
            line += nl;
            i = stop;
        } else if c == 'b' && next == Some('\'') {
            // byte char literal b'x' / b'\''
            let end = char_lit_end(&cs, i + 1);
            out.push('b');
            out.push('\'');
            blank(&mut out, end.saturating_sub(i + 2));
            if end < n {
                out.push('\'');
            }
            i = (end + 1).min(n);
        } else if c == '\'' && is_char_literal(&cs, i) {
            let end = char_lit_end(&cs, i);
            out.push('\'');
            blank(&mut out, end.saturating_sub(i + 1));
            if end < n {
                out.push('\'');
            }
            i = (end + 1).min(n);
        } else {
            if c == '\n' {
                line += 1;
            }
            out.push(c);
            i += 1;
        }
    }

    let test_spans = find_test_spans(&out);
    Scan { code: out, comments, test_spans }
}

/// Detect a string literal opening at `i`: `"`, `r"`, `r#"`, `b"`, `br#"`.
/// Returns `None` when `i` starts something else (identifier, raw ident,
/// byte char, …).
fn string_start(cs: &[char], i: usize) -> Option<StrStart> {
    match cs[i] {
        '"' => Some(StrStart { raw: false, hashes: 0, body: i + 1 }),
        'r' | 'b' if !prev_is_ident(cs, i) => {
            let mut j = i;
            let mut raw = false;
            if cs[j] == 'b' {
                j += 1;
            }
            if cs.get(j) == Some(&'r') {
                raw = true;
                j += 1;
            }
            let mut hashes = 0usize;
            while cs.get(j) == Some(&'#') {
                hashes += 1;
                j += 1;
            }
            if cs.get(j) != Some(&'"') {
                return None; // r#type, plain ident, b'x', …
            }
            if !raw && hashes > 0 {
                return None; // b#" is not a string
            }
            Some(StrStart { raw, hashes, body: j + 1 })
        }
        _ => None,
    }
}

/// Whether the char before `i` continues an identifier (so `r`/`b` here is
/// the tail of a name like `attr`, not a string prefix).
fn prev_is_ident(cs: &[char], i: usize) -> bool {
    i > 0 && (cs[i - 1].is_alphanumeric() || cs[i - 1] == '_')
}

/// Find the closing delimiter of a string whose interior starts at `from`.
/// Returns (index of the close delimiter, newline count inside).
fn find_close(cs: &[char], from: usize, close: &str, escapes: bool) -> (usize, usize) {
    let close_cs: Vec<char> = close.chars().collect();
    let mut lines = 0usize;
    let mut i = from;
    while i < cs.len() {
        if escapes && cs[i] == '\\' {
            i += 2;
            continue;
        }
        if cs[i] == close_cs[0] && cs[i..].starts_with(&close_cs[..]) {
            return (i, lines);
        }
        if cs[i] == '\n' {
            lines += 1;
        }
        i += 1;
    }
    (cs.len(), lines)
}

/// Whether `'` at `i` opens a char literal (vs. a lifetime).
fn is_char_literal(cs: &[char], i: usize) -> bool {
    match cs.get(i + 1) {
        Some('\\') => true,
        Some(_) => cs.get(i + 2) == Some(&'\''),
        None => false,
    }
}

/// Index of the closing `'` of a char literal whose opening quote is at `i`.
fn char_lit_end(cs: &[char], i: usize) -> usize {
    let mut j = i + 1;
    if cs.get(j) == Some(&'\\') {
        j += 2;
    } else {
        j += 1;
    }
    while j < cs.len() && cs[j] != '\'' {
        j += 1;
    }
    j
}

/// Line spans of `#[cfg(test)]` items, by brace matching on blanked code.
/// The marker must be written literally (`#[cfg(test)]`), which rustfmt
/// normalizes to anyway.
fn find_test_spans(code: &str) -> Vec<(usize, usize)> {
    let lines: Vec<&str> = code.split('\n').collect();
    let mut spans = Vec::new();
    for (idx, ln) in lines.iter().enumerate() {
        if !ln.contains("#[cfg(test)]") {
            continue;
        }
        let mut depth = 0i64;
        let mut started = false;
        let mut closed = false;
        for (j, l) in lines.iter().enumerate().skip(idx) {
            for ch in l.chars() {
                match ch {
                    '{' => {
                        depth += 1;
                        started = true;
                    }
                    '}' => depth -= 1,
                    _ => {}
                }
            }
            if started && depth <= 0 {
                spans.push((idx + 1, j + 1));
                closed = true;
                break;
            }
        }
        if !closed {
            // unbalanced braces: treat the rest of the file as test code
            spans.push((idx + 1, lines.len()));
        }
    }
    spans
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn blanks_line_and_block_comments() {
        let s = scan("let a = 1; // unwrap() here\n/* panic! */ let b = 2;\n");
        assert!(!s.code.contains("unwrap"));
        assert!(!s.code.contains("panic"));
        assert!(s.comment(1).contains("unwrap() here"));
        assert!(s.comment(2).contains("panic!"));
        assert!(s.code.contains("let a = 1;"));
        assert!(s.code.contains("let b = 2;"));
    }

    #[test]
    fn nested_block_comment() {
        let s = scan("/* outer /* inner */ still comment */ let x = 1;\n");
        assert!(s.code.contains("let x = 1;"));
        assert!(!s.code.contains("inner"));
    }

    #[test]
    fn blanks_string_interiors_keeps_quotes() {
        let s = scan("let s = \"call .unwrap() now\"; let t = 1;\n");
        assert!(!s.code.contains("unwrap"));
        assert!(s.code.contains('"'));
        assert!(s.code.contains("let t = 1;"));
    }

    #[test]
    fn raw_and_byte_strings() {
        let s = scan("let a = r#\"panic! \"quoted\" todo!\"#; let b = b\"panic!\";\n");
        assert!(!s.code.contains("panic"));
        assert!(!s.code.contains("todo"));
        assert!(s.code.contains("let b ="));
    }

    #[test]
    fn escaped_quote_in_string() {
        let s = scan("let a = \"x\\\"y.unwrap()z\"; let done = 1;\n");
        assert!(!s.code.contains("unwrap"));
        assert!(s.code.contains("let done = 1;"));
    }

    #[test]
    fn char_literals_and_lifetimes() {
        let s = scan("fn f<'a>(x: &'a str) -> char { let q = '\"'; let n = '\\n'; q }\n");
        assert!(s.code.contains("fn f<'a>(x: &'a str)"));
        // the quote inside the char literal must not open a string
        assert!(s.code.contains("let n ="));
    }

    #[test]
    fn byte_char_quote_does_not_open_string() {
        let s = scan("let q = b'\"'; let after = 1; // note\n");
        assert!(s.code.contains("let after = 1;"));
        assert!(s.comment(1).contains("note"));
    }

    #[test]
    fn raw_identifier_not_a_string() {
        let s = scan("let r#type = 1; let after = 2;\n");
        assert!(s.code.contains("let after = 2;"));
    }

    #[test]
    fn multiline_string_keeps_line_count() {
        let src = "let a = \"line1\nline2\nline3\";\nlet b = 1; // note\n";
        let s = scan(src);
        assert_eq!(s.code.matches('\n').count(), src.matches('\n').count());
        assert!(s.comment(4).contains("note"), "comment lands on the right line");
    }

    #[test]
    fn test_spans_cover_mod() {
        let src = "fn a() {}\n#[cfg(test)]\nmod tests {\n    fn b() {}\n}\nfn c() {}\n";
        let s = scan(src);
        assert_eq!(s.test_spans, vec![(2, 5)]);
        assert!(s.in_test(4));
        assert!(!s.in_test(6));
    }

    #[test]
    fn cfg_test_inside_string_ignored() {
        let s = scan("let a = \"#[cfg(test)]\";\nfn real() {}\n");
        assert!(s.test_spans.is_empty());
    }
}
