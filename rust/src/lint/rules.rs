//! The repolint rules, operating on a [`lexer::Scan`](super::lexer::Scan).
//!
//! Four rules (kebab names are what reports and waivers use):
//!
//! | rule             | scope                      | requirement                              |
//! |------------------|----------------------------|------------------------------------------|
//! | `unsafe-safety`  | every `.rs` file           | `unsafe` carries a `// SAFETY:` comment  |
//! | `no-panic`       | `rust/src`, non-test code  | no `.unwrap()` / `.expect(` / `panic!` / `todo!` / `unimplemented!` |
//! | `determinism`    | suite-record + optimizer + trainer + obs files | no `Instant` / `SystemTime` / `HashMap`  |
//! | `knob-registry`  | `rust/src` minus `knobs.rs`| no direct `env::var` reads               |
//!
//! A site can be waived with `// lint: allow(<rule>)` on the same line or
//! the line above; waivers are for *annotated telemetry sites and similar
//! deliberate exceptions*, and the self-check test pins their count.

use super::lexer::Scan;

/// Rule identifiers (kebab-case in display, reports and waiver comments).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Rule {
    /// `unsafe` without a `// SAFETY:` justification.
    UnsafeSafety,
    /// Panicking call in non-test library code.
    NoPanic,
    /// Nondeterminism source in a determinism-scoped file.
    Determinism,
    /// Raw `env::var` read outside the knob registry.
    KnobRegistry,
}

impl Rule {
    /// The kebab-case name used in reports and `lint: allow(...)` waivers.
    pub fn name(self) -> &'static str {
        match self {
            Rule::UnsafeSafety => "unsafe-safety",
            Rule::NoPanic => "no-panic",
            Rule::Determinism => "determinism",
            Rule::KnobRegistry => "knob-registry",
        }
    }
}

impl std::fmt::Display for Rule {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// One rule hit at a source location.
#[derive(Debug, Clone)]
pub struct Violation {
    /// Workspace-relative path.
    pub file: String,
    /// 1-based line.
    pub line: usize,
    /// Which rule fired.
    pub rule: Rule,
    /// What matched (short excerpt).
    pub msg: String,
}

impl std::fmt::Display for Violation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.msg)
    }
}

/// One `unsafe` site, for the generated inventory report.
#[derive(Debug, Clone)]
pub struct UnsafeSite {
    /// Workspace-relative path.
    pub file: String,
    /// 1-based line of the `unsafe` keyword.
    pub line: usize,
    /// The code line (trimmed).
    pub excerpt: String,
    /// First line of the attached `SAFETY:` comment ("" when missing).
    pub justification: String,
}

/// Files the determinism rule covers: the fused-optimizer step, the
/// training loop that feeds suite records, the record writer itself, the
/// fault-injection schedule (whose whole contract is seeded
/// reproducibility), and the observability layer — spans, the metrics
/// registry, and the clock abstraction itself, where the only sanctioned
/// wall-time read lives behind a waiver. (Workspace-relative paths.)
pub const DETERMINISM_SCOPE: &[&str] = &[
    "rust/src/optim.rs",
    "rust/src/train/mod.rs",
    "rust/src/suite/record.rs",
    "rust/src/fault.rs",
    "rust/src/obs/clock.rs",
    "rust/src/obs/span.rs",
    "rust/src/obs/mod.rs",
];

/// Scope flags for one file, derived from its workspace-relative path.
#[derive(Debug, Clone, Copy)]
pub struct FileScope {
    /// Under `rust/src/` (no-panic and knob-registry apply).
    pub lib_src: bool,
    /// Listed in [`DETERMINISM_SCOPE`].
    pub determinism: bool,
    /// Is the knob registry itself (exempt from knob-registry).
    pub knob_registry: bool,
}

impl FileScope {
    /// Classify a workspace-relative path.
    pub fn of(rel: &str) -> FileScope {
        FileScope {
            lib_src: rel.starts_with("rust/src/"),
            determinism: DETERMINISM_SCOPE.contains(&rel),
            knob_registry: rel == "rust/src/knobs.rs",
        }
    }
}

/// Run every rule over one lexed file. Returns the violations and the
/// file's `unsafe` inventory (annotated sites included).
pub fn check_file(rel: &str, scan: &Scan) -> (Vec<Violation>, Vec<UnsafeSite>) {
    let scope = FileScope::of(rel);
    let mut violations = Vec::new();
    let mut unsafe_sites = Vec::new();
    let code_lines: Vec<&str> = scan.code.split('\n').collect();

    for (idx, ln) in code_lines.iter().enumerate() {
        let line = idx + 1;
        let in_test = scan.in_test(line);

        for (off, word) in idents(ln) {
            match word {
                "unsafe" => {
                    let justification = safety_comment(scan, &code_lines, line);
                    unsafe_sites.push(UnsafeSite {
                        file: rel.to_string(),
                        line,
                        excerpt: ln.trim().to_string(),
                        justification: justification.clone().unwrap_or_default(),
                    });
                    if justification.is_none() && !waived(scan, Rule::UnsafeSafety, line) {
                        violations.push(Violation {
                            file: rel.to_string(),
                            line,
                            rule: Rule::UnsafeSafety,
                            msg: format!("`unsafe` without a SAFETY: comment: {}", ln.trim()),
                        });
                    }
                }
                "unwrap" | "expect"
                    if scope.lib_src && !in_test && is_method_call(ln, off, word) =>
                {
                    if !waived(scan, Rule::NoPanic, line) {
                        violations.push(Violation {
                            file: rel.to_string(),
                            line,
                            rule: Rule::NoPanic,
                            msg: format!(".{word}() in library code"),
                        });
                    }
                }
                "panic" | "todo" | "unimplemented"
                    if scope.lib_src && !in_test && is_macro_call(ln, off, word) =>
                {
                    if !waived(scan, Rule::NoPanic, line) {
                        violations.push(Violation {
                            file: rel.to_string(),
                            line,
                            rule: Rule::NoPanic,
                            msg: format!("{word}! in library code"),
                        });
                    }
                }
                "Instant" | "SystemTime" | "HashMap" if scope.determinism && !in_test => {
                    if !waived(scan, Rule::Determinism, line) {
                        violations.push(Violation {
                            file: rel.to_string(),
                            line,
                            rule: Rule::Determinism,
                            msg: format!("{word} in determinism-scoped file"),
                        });
                    }
                }
                "var"
                    if scope.lib_src
                        && !scope.knob_registry
                        && !in_test
                        && is_env_var(ln, off) =>
                {
                    if !waived(scan, Rule::KnobRegistry, line) {
                        violations.push(Violation {
                            file: rel.to_string(),
                            line,
                            rule: Rule::KnobRegistry,
                            msg: "env::var outside the knob registry (crate::knobs)".into(),
                        });
                    }
                }
                _ => {}
            }
        }
    }
    (violations, unsafe_sites)
}

/// Extract every `SSM_PEFT_*` name mentioned in the *raw* source (string
/// literals included — that's where the names live).
pub fn knob_mentions(raw_src: &str) -> Vec<String> {
    let mut out = Vec::new();
    let bytes = raw_src.as_bytes();
    let pat = b"SSM_PEFT_";
    let mut i = 0;
    while i + pat.len() <= bytes.len() {
        if &bytes[i..i + pat.len()] == pat {
            // must not be the tail of a longer identifier
            if i > 0 && (bytes[i - 1].is_ascii_alphanumeric() || bytes[i - 1] == b'_') {
                i += 1;
                continue;
            }
            let mut j = i + pat.len();
            while j < bytes.len()
                && (bytes[j].is_ascii_uppercase() || bytes[j].is_ascii_digit() || bytes[j] == b'_')
            {
                j += 1;
            }
            if j > i + pat.len() {
                out.push(raw_src[i..j].trim_end_matches('_').to_string());
            }
            i = j;
        } else {
            i += 1;
        }
    }
    out.sort();
    out.dedup();
    out
}

/// Whether a `lint: allow(<rule>)` waiver covers this line (same line or
/// the line above).
pub fn waived(scan: &Scan, rule: Rule, line: usize) -> bool {
    let needle = format!("lint: allow({})", rule.name());
    scan.comment(line).contains(&needle)
        || (line > 1 && scan.comment(line - 1).contains(&needle))
}

/// Find the `SAFETY:` comment attached to an `unsafe` at `line`: on the
/// line itself, or scanning upward over blank lines, comment-only lines,
/// attributes, and sibling `unsafe impl … {}` one-liners (so one block
/// comment can justify both `Send` and `Sync`).
fn safety_comment(scan: &Scan, code_lines: &[&str], line: usize) -> Option<String> {
    let extract = |c: &str| {
        c.find("SAFETY:").map(|p| c[p..].lines().next().unwrap_or("").trim().to_string())
    };
    if let Some(j) = extract(scan.comment(line)) {
        return Some(j);
    }
    let mut l = line.saturating_sub(1);
    while l >= 1 {
        let comment = scan.comment(l);
        if let Some(j) = extract(comment) {
            return Some(j);
        }
        // blank and comment-only lines have empty blanked code; attributes
        // and sibling `unsafe impl … {}` one-liners are also transparent
        let code = code_lines.get(l - 1).copied().unwrap_or("").trim();
        let passable = code.is_empty()
            || code.starts_with("#[")
            || (code.starts_with("unsafe impl ") && code.ends_with("{}"));
        if !passable {
            return None; // a real code line breaks the chain
        }
        l -= 1;
    }
    None
}

/// Identifier tokens of one line as `(byte_offset, word)`.
fn idents(line: &str) -> Vec<(usize, &str)> {
    let bytes = line.as_bytes();
    let mut out = Vec::new();
    let mut i = 0;
    while i < bytes.len() {
        let c = bytes[i];
        if c.is_ascii_alphabetic() || c == b'_' {
            let start = i;
            while i < bytes.len()
                && (bytes[i].is_ascii_alphanumeric() || bytes[i] == b'_')
            {
                i += 1;
            }
            out.push((start, &line[start..i]));
        } else {
            i += 1;
        }
    }
    out
}

/// Whether the word at `off` is called as a method: preceded (modulo
/// whitespace) by `.` and followed by `(`. Word-level tokenization already
/// excludes `unwrap_or*` / `expect_err`.
fn is_method_call(line: &str, off: usize, word: &str) -> bool {
    let before = line[..off].trim_end();
    let after = line[off + word.len()..].trim_start();
    before.ends_with('.') && after.starts_with('(')
}

/// Whether the word at `off` is a macro invocation (`word!`).
fn is_macro_call(line: &str, off: usize, word: &str) -> bool {
    line[off + word.len()..].trim_start().starts_with('!')
}

/// Whether the `var` at `off` is an `env::var` path (covers `std::env::var`
/// and a `use std::env;` + `env::var` split).
fn is_env_var(line: &str, off: usize) -> bool {
    let before = line[..off].trim_end();
    before.ends_with("env::")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lint::lexer::scan;

    fn check(rel: &str, src: &str) -> Vec<Violation> {
        check_file(rel, &scan(src)).0
    }

    #[test]
    fn flags_unwrap_and_expect_not_variants() {
        let v = check(
            "rust/src/x.rs",
            "fn f(o: Option<u32>) -> u32 {\n    let a = o.unwrap();\n    let b = o.expect(\"x\");\n    let c = o.unwrap_or(0);\n    let d = o.unwrap_or_else(|| 0);\n    a + b + c + d\n}\n",
        );
        assert_eq!(v.len(), 2, "{v:?}");
        assert!(v.iter().all(|x| x.rule == Rule::NoPanic));
        assert_eq!(v[0].line, 2);
        assert_eq!(v[1].line, 3);
    }

    #[test]
    fn flags_panic_macros_not_names() {
        let v = check(
            "rust/src/x.rs",
            "fn f() {\n    panic!(\"boom\");\n    let panic = 1; let _ = panic;\n}\nfn todo_list() {}\n",
        );
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].line, 2);
    }

    #[test]
    fn test_code_and_non_src_exempt_from_no_panic() {
        let in_test = "#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() { Some(1).unwrap(); }\n}\n";
        assert!(check("rust/src/x.rs", in_test).is_empty());
        let bench = "fn main() { Some(1).unwrap(); }\n";
        assert!(check("rust/benches/b.rs", bench).is_empty());
    }

    #[test]
    fn unsafe_requires_safety_comment() {
        let bad = "fn f() {\n    let p = unsafe { std::mem::transmute::<u32, i32>(1) };\n    let _ = p;\n}\n";
        let v = check("rust/src/x.rs", bad);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].rule, Rule::UnsafeSafety);

        let good = "fn f() {\n    // SAFETY: u32 and i32 have identical layout.\n    let p = unsafe { std::mem::transmute::<u32, i32>(1) };\n    let _ = p;\n}\n";
        assert!(check("rust/src/x.rs", good).is_empty());
    }

    #[test]
    fn safety_scan_passes_over_sibling_unsafe_impls() {
        let src = "struct E;\n// SAFETY: E owns its data; no shared mutability.\nunsafe impl Send for E {}\nunsafe impl Sync for E {}\n";
        let (v, sites) = check_file("rust/src/x.rs", &scan(src));
        assert!(v.is_empty(), "{v:?}");
        assert_eq!(sites.len(), 2);
        assert!(sites[1].justification.starts_with("SAFETY:"));
    }

    #[test]
    fn safety_chain_broken_by_code_line() {
        let src = "// SAFETY: stale comment.\nfn other() {}\nfn f() { let _ = unsafe { std::mem::transmute::<u32, i32>(1) }; }\n";
        let v = check("rust/src/x.rs", src);
        assert_eq!(v.len(), 1, "{v:?}");
    }

    #[test]
    fn determinism_scoped_by_file() {
        let src = "use std::time::Instant;\nuse std::collections::HashMap;\n";
        assert_eq!(check("rust/src/optim.rs", src).len(), 2);
        assert!(check("rust/src/tensor.rs", src).is_empty());
    }

    #[test]
    fn waiver_on_line_above_or_same_line() {
        let src = "// lint: allow(determinism) telemetry only\nlet t = Instant::now();\nlet u = Instant::now(); // lint: allow(determinism)\nlet bad = Instant::now();\n";
        let v = check("rust/src/optim.rs", src);
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].line, 4);
    }

    #[test]
    fn env_var_outside_knobs_flagged() {
        let src = "fn f() -> Option<String> { std::env::var(\"SSM_PEFT_X\").ok() }\n";
        let v = check("rust/src/lib.rs", src);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].rule, Rule::KnobRegistry);
        assert!(check("rust/src/knobs.rs", src).is_empty());
        // a local fn named var is fine
        assert!(check("rust/src/lib.rs", "fn f() { var(1); }\nfn var(_x: u32) {}\n").is_empty());
    }

    #[test]
    fn knob_mention_extraction() {
        let src = "let a = std::env::var(\"SSM_PEFT_WORKERS\");\n// mentions SSM_PEFT_BENCH_SCALE and SSM_PEFT_WORKERS again\n";
        let names = knob_mentions(src);
        assert_eq!(names, vec!["SSM_PEFT_BENCH_SCALE", "SSM_PEFT_WORKERS"]);
    }

    #[test]
    fn strings_do_not_trigger_rules() {
        let src = "fn f() -> &'static str { \"call .unwrap() or panic! now\" }\n";
        assert!(check("rust/src/x.rs", src).is_empty());
    }
}
