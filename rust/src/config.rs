//! Experiment configuration: JSON config files + `key=value` CLI overrides
//! + grid expansion (the paper's LR sweep, Sec. C.1).

use std::collections::BTreeMap;

use crate::err;
use crate::error::Result;

use crate::json::{self, Value};
use crate::peft::{Criterion, SdtConfig};

/// One fine-tuning experiment.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    /// artifact variant, e.g. "mamba1_xs_sdtlora"
    pub variant: String,
    /// dataset name, e.g. "glue/rte", "dart", "spider"
    pub dataset: String,
    /// Training-set size the dataset generator produces.
    pub n_train: usize,
    /// Fine-tuning epochs (early stopping keeps the best one).
    pub epochs: usize,
    /// candidate learning rates; >1 entries trigger a short grid search
    pub lr_grid: Vec<f32>,
    /// Experiment seed (data generation, shuffles, warmups).
    pub seed: u64,
    /// SDT selection settings (used when the method is SDT/SDT-LoRA).
    pub sdt: SdtConfig,
    /// LoRA merge alpha override; 0 = use the manifest's per-variant alpha
    /// (scale = alpha / rank, python/compile/peft.py::make_eff)
    pub alpha: usize,
    /// generation eval settings
    pub gen_max_new: usize,
    /// beam width for generation eval; 1 = greedy
    pub beam: usize,
    /// pretraining steps for the frozen base model
    pub pretrain_steps: usize,
    /// AdamW decoupled weight decay.
    pub weight_decay: f32,
    /// cap on train batches per epoch (CPU budget guard; 0 = no cap)
    pub max_batches_per_epoch: usize,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            variant: "mamba1_xs_lora_lin".into(),
            dataset: "glue/rte".into(),
            n_train: 256,
            epochs: 3,
            lr_grid: vec![1e-3],
            seed: 0,
            sdt: SdtConfig::default(),
            alpha: 0,
            gen_max_new: 48,
            beam: 1,
            pretrain_steps: 300,
            weight_decay: 0.01,
            max_batches_per_epoch: 24,
        }
    }
}

impl ExperimentConfig {
    /// Parse a config from a JSON object; unknown keys are rejected.
    pub fn from_json(v: &Value) -> Result<Self> {
        let mut c = ExperimentConfig::default();
        let obj = match v {
            Value::Obj(m) => m,
            _ => return Err(err!("config must be an object")),
        };
        for (k, val) in obj {
            c.set(k, val)?;
        }
        Ok(c)
    }

    /// Load a JSON config file.
    pub fn from_file(path: &str) -> Result<Self> {
        let src = std::fs::read_to_string(path)?;
        let v = json::parse(&src).map_err(|e| err!("{path}: {e}"))?;
        Self::from_json(&v)
    }

    /// Apply one key (JSON value), shared by file/CLI paths.
    pub fn set(&mut self, key: &str, val: &Value) -> Result<()> {
        let f = |v: &Value| v.as_f64().ok_or_else(|| err!("{key}: expected number"));
        match key {
            "variant" => self.variant = req_str(val, key)?,
            "dataset" => self.dataset = req_str(val, key)?,
            "n_train" => self.n_train = f(val)? as usize,
            "epochs" => self.epochs = f(val)? as usize,
            "seed" => self.seed = f(val)? as u64,
            "alpha" => self.alpha = f(val)? as usize,
            "gen_max_new" => self.gen_max_new = f(val)? as usize,
            "beam" => self.beam = f(val)? as usize,
            "pretrain_steps" => self.pretrain_steps = f(val)? as usize,
            "weight_decay" => self.weight_decay = f(val)? as f32,
            "max_batches_per_epoch" => self.max_batches_per_epoch = f(val)? as usize,
            "lr" => self.lr_grid = vec![f(val)? as f32],
            "lr_grid" => {
                self.lr_grid = val
                    .as_arr()
                    .ok_or_else(|| err!("lr_grid: expected array"))?
                    .iter()
                    .filter_map(Value::as_f64)
                    .map(|x| x as f32)
                    .collect()
            }
            "sdt.channel_freeze" => self.sdt.channel_freeze = f(val)? as f32,
            "sdt.state_freeze" => self.sdt.state_freeze = f(val)? as f32,
            "sdt.warmup_batches" => self.sdt.warmup_batches = f(val)? as usize,
            "sdt.warmup_lr" => self.sdt.warmup_lr = f(val)? as f32,
            "sdt.prune_frac" => self.sdt.prune_frac = f(val)? as f32,
            "sdt.criterion" => {
                self.sdt.criterion = match req_str(val, key)?.as_str() {
                    "abar" => Criterion::AbarChange,
                    "grad" => Criterion::GradMagnitude,
                    "random" => Criterion::Random,
                    other => return Err(err!("unknown criterion {other}")),
                }
            }
            _ => return Err(err!("unknown config key {key:?}")),
        }
        Ok(())
    }

    /// Apply `key=value` CLI overrides (values parsed as JSON when possible,
    /// else taken as strings).
    pub fn apply_overrides(&mut self, kvs: &BTreeMap<String, String>) -> Result<()> {
        for (k, v) in kvs {
            let val = json::parse(v).unwrap_or_else(|_| Value::Str(v.clone()));
            self.set(k, &val)?;
        }
        Ok(())
    }
}

fn req_str(v: &Value, key: &str) -> Result<String> {
    v.as_str()
        .map(String::from)
        .ok_or_else(|| err!("{key}: expected string"))
}

/// Split argv into (key=value overrides, positional args).
pub fn parse_args(args: &[String]) -> (BTreeMap<String, String>, Vec<String>) {
    let mut kvs = BTreeMap::new();
    let mut pos = Vec::new();
    for a in args {
        if let Some((k, v)) = a.split_once('=') {
            kvs.insert(k.to_string(), v.to_string());
        } else {
            pos.push(a.clone());
        }
    }
    (kvs, pos)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_and_overrides() {
        let mut c = ExperimentConfig::default();
        let mut kv = BTreeMap::new();
        kv.insert("variant".to_string(), "mamba1_xs_sdt".to_string());
        kv.insert("lr".to_string(), "0.01".to_string());
        kv.insert("sdt.state_freeze".to_string(), "0.75".to_string());
        kv.insert("sdt.criterion".to_string(), "random".to_string());
        c.apply_overrides(&kv).unwrap();
        assert_eq!(c.variant, "mamba1_xs_sdt");
        assert_eq!(c.lr_grid, vec![0.01]);
        assert_eq!(c.sdt.state_freeze, 0.75);
        assert_eq!(c.sdt.criterion, Criterion::Random);
    }

    #[test]
    fn from_json_full() {
        let v = json::parse(
            r#"{"variant":"x","dataset":"dart","epochs":5,"lr_grid":[0.1,0.01]}"#,
        )
        .unwrap();
        let c = ExperimentConfig::from_json(&v).unwrap();
        assert_eq!(c.dataset, "dart");
        assert_eq!(c.epochs, 5);
        assert_eq!(c.lr_grid.len(), 2);
    }

    #[test]
    fn unknown_key_rejected() {
        let v = json::parse(r#"{"nope":1}"#).unwrap();
        assert!(ExperimentConfig::from_json(&v).is_err());
    }

    #[test]
    fn parse_args_split() {
        let args = vec!["finetune".to_string(), "lr=0.1".to_string(), "x".to_string()];
        let (kv, pos) = parse_args(&args);
        assert_eq!(kv["lr"], "0.1");
        assert_eq!(pos, vec!["finetune", "x"]);
    }
}
