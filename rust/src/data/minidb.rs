//! Mini in-memory relational store + query evaluator.
//!
//! The Spider benchmark measures *execution accuracy*: the predicted SQL and
//! the gold SQL are run against the database and their result sets compared.
//! Our Spider analogue does the real thing at small scale: tasks carry a
//! generated table, the model emits a query string, and this evaluator
//! executes both queries so the metric is genuine execution match — not
//! string match.
//!
//! Query grammar (uppercase keywords, single table):
//!   GET <col> FROM <table> [WHERE <col> IS <val>] [COUNT]

use std::collections::BTreeMap;

/// A single table: named columns over string values.
#[derive(Debug, Clone)]
pub struct Table {
    /// Table name.
    pub name: String,
    /// Column names.
    pub columns: Vec<String>,
    /// Row-major cell values.
    pub rows: Vec<Vec<String>>,
}

impl Table {
    /// Index of a column by name.
    pub fn col_index(&self, col: &str) -> Option<usize> {
        self.columns.iter().position(|c| c == col)
    }

    /// Render the schema as prompt context: "table(colA,colB,colC)".
    pub fn schema_str(&self) -> String {
        format!("{}({})", self.name, self.columns.join(","))
    }
}

/// Parsed query.
#[derive(Debug, Clone, PartialEq)]
pub struct Query {
    /// Selected column (or "*").
    pub select: String,
    /// Table name the query targets.
    pub table: String,
    /// Optional WHERE (column, value) equality filter.
    pub filter: Option<(String, String)>,
    /// COUNT aggregation instead of value list.
    pub count: bool,
}

/// Parse the mini query grammar; returns None on malformed input (a
/// malformed model prediction simply scores 0, like real Spider).
pub fn parse_query(q: &str) -> Option<Query> {
    let toks: Vec<&str> = q.split_whitespace().collect();
    if toks.len() < 4 || toks[0] != "GET" || toks[2] != "FROM" {
        return None;
    }
    let select = toks[1].to_string();
    let table = toks[3].to_string();
    let mut filter = None;
    let mut count = false;
    let mut i = 4;
    while i < toks.len() {
        match toks[i] {
            "WHERE" if i + 3 < toks.len() && toks[i + 2] == "IS" => {
                filter = Some((toks[i + 1].to_string(), toks[i + 3].to_string()));
                i += 4;
            }
            "COUNT" => {
                count = true;
                i += 1;
            }
            _ => return None,
        }
    }
    Some(Query { select, table, filter, count })
}

/// Execute a query; result is a sorted multiset of output strings
/// (order-insensitive comparison, like Spider's evaluator).
pub fn execute(table: &Table, q: &Query) -> Option<Vec<String>> {
    if q.table != table.name {
        return None;
    }
    let sel = table.col_index(&q.select)?;
    let flt = match &q.filter {
        Some((c, v)) => Some((table.col_index(c)?, v.clone())),
        None => None,
    };
    let mut out: Vec<String> = table
        .rows
        .iter()
        .filter(|r| flt.as_ref().map_or(true, |(ci, v)| &r[*ci] == v))
        .map(|r| r[sel].clone())
        .collect();
    if q.count {
        return Some(vec![out.len().to_string()]);
    }
    out.sort();
    Some(out)
}

/// Execution-accuracy comparison of a predicted query string vs gold.
pub fn exec_match(table: &Table, pred: &str, gold: &str) -> bool {
    let (Some(pq), Some(gq)) = (parse_query(pred), parse_query(gold)) else {
        return false;
    };
    match (execute(table, &pq), execute(table, &gq)) {
        (Some(a), Some(b)) => a == b,
        _ => false,
    }
}

/// Deterministic value pools used by the task generator.
pub fn value_pool() -> BTreeMap<&'static str, Vec<&'static str>> {
    let mut m = BTreeMap::new();
    m.insert("city", vec!["rome", "oslo", "lima", "baku", "kiev"]);
    m.insert("team", vec!["red", "blue", "gold", "jade"]);
    m.insert("year", vec!["1999", "2005", "2012", "2020"]);
    m.insert("size", vec!["s", "m", "l"]);
    m
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table() -> Table {
        Table {
            name: "t".into(),
            columns: vec!["city".into(), "team".into()],
            rows: vec![
                vec!["rome".into(), "red".into()],
                vec!["oslo".into(), "red".into()],
                vec!["rome".into(), "blue".into()],
            ],
        }
    }

    #[test]
    fn parse_roundtrip() {
        let q = parse_query("GET city FROM t WHERE team IS red").unwrap();
        assert_eq!(q.select, "city");
        assert_eq!(q.filter, Some(("team".into(), "red".into())));
        assert!(!q.count);
        assert!(parse_query("SELECT x").is_none());
        assert!(parse_query("GET a FROM t WHERE b ISNT c").is_none());
    }

    #[test]
    fn execute_filter_and_count() {
        let t = table();
        let q = parse_query("GET city FROM t WHERE team IS red").unwrap();
        assert_eq!(execute(&t, &q).unwrap(), vec!["oslo", "rome"]);
        let qc = parse_query("GET city FROM t COUNT").unwrap();
        assert_eq!(execute(&t, &qc).unwrap(), vec!["3"]);
    }

    #[test]
    fn exec_match_semantics_not_strings() {
        let t = table();
        // different filter but same result multiset -> exec match true
        assert!(exec_match(&t,
            "GET team FROM t WHERE city IS oslo",
            "GET team FROM t WHERE city IS oslo"));
        // malformed pred -> false
        assert!(!exec_match(&t, "garbage", "GET city FROM t"));
        // wrong column -> false
        assert!(!exec_match(&t, "GET team FROM t", "GET city FROM t"));
    }

    #[test]
    fn unknown_column_is_none() {
        let t = table();
        let q = parse_query("GET nope FROM t").unwrap();
        assert!(execute(&t, &q).is_none());
    }
}
