//! Synthetic task generators: the six dataset analogues (DESIGN.md
//! §Substitutions) plus the pretraining corpus.
//!
//! Every task's labels are computed from the input by a small latent program
//! so fine-tuning progress is real signal, and every generator is
//! deterministic in its seed. Text tasks share a small word lexicon so the
//! (byte-level) pretrained LM transfers.

use super::minidb::{self, Table};
use super::{Dataset, Example};
use crate::bail;
use crate::error::Result;
use crate::suite::Metric;
use crate::tensor::Rng;

const WORDS: &[&str] = &[
    "cat", "dog", "sun", "map", "tree", "bird", "rock", "wave", "star", "leaf",
    "wind", "fish", "moon", "sand", "rain", "fire", "cloud", "seed", "wolf", "bear",
];
const POS_WORDS: &[&str] = &["good", "fine", "warm", "kind", "calm", "glad"];
const NEG_WORDS: &[&str] = &["bad", "cold", "grim", "sad", "harsh", "dark"];
const VERBS: &[&str] = &["meets", "calls", "helps", "asks", "joins", "warns"];
const NAMES: &[&str] = &["ann", "bob", "cem", "dia", "eli", "fay"];

fn pick_words(rng: &mut Rng, n: usize) -> Vec<&'static str> {
    (0..n).map(|_| *rng.choice(WORDS)).collect()
}

fn join(words: &[&str]) -> Vec<u8> {
    words.join(" ").into_bytes()
}

/// Generic split builder.
fn splits(
    mut gen: impl FnMut(&mut Rng) -> Example,
    seed: u64,
    n_train: usize,
    n_val: usize,
    n_test: usize,
) -> (Vec<Example>, Vec<Example>, Vec<Example>) {
    let mut rng = Rng::new(seed);
    let train = (0..n_train).map(|_| gen(&mut rng)).collect();
    let val = (0..n_val).map(|_| gen(&mut rng)).collect();
    let test = (0..n_test).map(|_| gen(&mut rng)).collect();
    (train, val, test)
}

fn cls(prompt: Vec<u8>, label: usize, label_bytes: &[u8]) -> Example {
    Example { prompt, target: vec![], label: Some(label), label_bytes: label_bytes.to_vec() }
}

fn genr(prompt: Vec<u8>, target: Vec<u8>) -> Example {
    Example { prompt, target, label: None, label_bytes: vec![] }
}

// ---------------------------------------------------------------------------
// GLUE analogue: seven classification subtasks
// ---------------------------------------------------------------------------

/// RTE-like entailment: hypothesis words ⊆ premise words → entail.
fn gen_rte(rng: &mut Rng) -> Example {
    let premise = pick_words(rng, 6);
    let entail = rng.uniform() < 0.5;
    let hypothesis: Vec<&str> = if entail {
        (0..3).map(|_| *rng.choice(&premise)).collect()
    } else {
        let mut h = vec![*rng.choice(&premise), *rng.choice(&premise)];
        loop {
            let w = *rng.choice(WORDS);
            if !premise.contains(&w) {
                h.push(w);
                break;
            }
        }
        h
    };
    let mut p = join(&premise);
    p.extend(b" ; ");
    p.extend(join(&hypothesis));
    cls(p, entail as usize, b"01")
}

/// MRPC-like paraphrase: second sentence is a shuffle of the first or not.
fn gen_mrpc(rng: &mut Rng) -> Example {
    let s1 = pick_words(rng, 5);
    let para = rng.uniform() < 0.5;
    let s2: Vec<&str> = if para {
        let mut s = s1.clone();
        rng.shuffle(&mut s);
        s
    } else {
        let mut s = pick_words(rng, 5);
        // ensure different multiset
        // s1 is drawn from WORDS, so the position lookup can only miss if
        // the lexicon changes; fall back to index 0 rather than panic
        let pos = WORDS.iter().position(|w| *w == s1[0]).unwrap_or(0);
        s[0] = WORDS[(pos + 1) % WORDS.len()];
        s
    };
    let mut p = join(&s1);
    p.extend(b" ; ");
    p.extend(join(&s2));
    cls(p, para as usize, b"01")
}

/// CoLA-like acceptability: grammar is "name verb name"-chains; corruption
/// swaps a verb into a name slot.
fn gen_cola(rng: &mut Rng) -> Example {
    let n = 2 + rng.below(2);
    let mut toks: Vec<&str> = Vec::new();
    for i in 0..n {
        if i > 0 {
            toks.push("and");
        }
        toks.push(*rng.choice(NAMES));
        toks.push(*rng.choice(VERBS));
        toks.push(*rng.choice(NAMES));
    }
    let ok = rng.uniform() < 0.5;
    if !ok {
        let slot = rng.below(toks.len());
        toks[slot] = *rng.choice(VERBS);
    }
    cls(join(&toks), ok as usize, b"01")
}

/// SST-2-like sentiment: majority lexicon polarity.
fn gen_sst2(rng: &mut Rng) -> Example {
    let pos = rng.uniform() < 0.5;
    let (maj, min) = if pos { (POS_WORDS, NEG_WORDS) } else { (NEG_WORDS, POS_WORDS) };
    let mut toks: Vec<&str> = Vec::new();
    for _ in 0..3 {
        toks.push(*rng.choice(maj));
        toks.push(*rng.choice(WORDS));
    }
    toks.push(*rng.choice(min));
    let mut t2 = toks.clone();
    rng.shuffle(&mut t2);
    cls(join(&t2), pos as usize, b"01")
}

/// QNLI-like: does the sentence contain the question's key word?
fn gen_qnli(rng: &mut Rng) -> Example {
    let key = *rng.choice(WORDS);
    let sent = pick_words(rng, 6);
    let contains = sent.contains(&key);
    let mut p = format!("where {key} ?").into_bytes();
    p.extend(b" ; ");
    p.extend(join(&sent));
    cls(p, contains as usize, b"01")
}

/// QQP-like duplicate detection: same word multiset?
fn gen_qqp(rng: &mut Rng) -> Example {
    let q1 = pick_words(rng, 4);
    let dup = rng.uniform() < 0.5;
    let q2: Vec<&str> = if dup {
        let mut s = q1.clone();
        rng.shuffle(&mut s);
        s
    } else {
        let mut s = q1.clone();
        s[rng.below(4)] = *rng.choice(WORDS);
        rng.shuffle(&mut s);
        s
    };
    // relabel by the actual program (mutation may be identity)
    let mut a = q1.clone();
    let mut b = q2.clone();
    a.sort();
    b.sort();
    let label = (a == b) as usize;
    let mut p = join(&q1);
    p.extend(b" ; ");
    p.extend(join(&q2));
    cls(p, label, b"01")
}

/// MNLI-like 3-class: word-overlap bands (0: contradict, 1: neutral, 2: entail).
fn gen_mnli(rng: &mut Rng) -> Example {
    let premise = pick_words(rng, 6);
    let k = rng.below(4); // 0..3 shared words
    let mut hyp: Vec<&str> = (0..k).map(|i| premise[i]).collect();
    while hyp.len() < 4 {
        let w = *rng.choice(WORDS);
        if !premise.contains(&w) {
            hyp.push(w);
        }
    }
    let mut h2 = hyp.clone();
    rng.shuffle(&mut h2);
    let shared = h2.iter().filter(|w| premise.contains(*w)).count();
    let label = match shared {
        0 => 0,
        1 | 2 => 1,
        _ => 2,
    };
    let mut p = join(&premise);
    p.extend(b" ; ");
    p.extend(join(&h2));
    cls(p, label, b"012")
}

/// GLUE subtasks the generator supports (`glue/<sub>` dataset names).
pub const GLUE_SUBTASKS: &[&str] = &["rte", "mrpc", "cola", "sst2", "qnli", "qqp", "mnli"];

/// GLUE analogue: sentence-pair/classification tasks with latent-rule
/// labels; CoLA scores Matthews, the rest accuracy. A typo'd subtask (from
/// a suite config cell) is an error, not a panic — suite workers must
/// degrade the cell, not the process.
pub fn glue(sub: &str, seed: u64, n_train: usize) -> Result<Dataset> {
    let gen: fn(&mut Rng) -> Example = match sub {
        "rte" => gen_rte,
        "mrpc" => gen_mrpc,
        "cola" => gen_cola,
        "sst2" => gen_sst2,
        "qnli" => gen_qnli,
        "qqp" => gen_qqp,
        "mnli" => gen_mnli,
        _ => bail!("unknown GLUE subtask {sub:?} (have: {GLUE_SUBTASKS:?})"),
    };
    let (train, val, test) = splits(gen, seed ^ fnv(sub), n_train, 96, 96);
    Ok(Dataset {
        name: format!("glue/{sub}"),
        train, val, test,
        metric: if sub == "cola" { Metric::Matthews } else { Metric::Acc },
    })
}

// ---------------------------------------------------------------------------
// DART analogue: record-to-text
// ---------------------------------------------------------------------------

fn gen_dart(rng: &mut Rng) -> Example {
    let keys = ["name", "team", "city"];
    let vals = [*rng.choice(NAMES), *rng.choice(&["red", "blue", "gold", "jade"]),
                *rng.choice(&["rome", "oslo", "lima", "baku"])];
    let n = 2 + rng.below(2);
    let mut rec = String::new();
    let mut text = String::new();
    for i in 0..n {
        if i > 0 {
            rec.push('|');
            text.push(' ');
        }
        rec.push_str(&format!("{}={}", keys[i], vals[i]));
        text.push_str(&format!("the {} is {} .", keys[i], vals[i]));
    }
    genr(rec.into_bytes(), text.into_bytes())
}

/// DART analogue: record-to-text generation (BLEU + METEOR).
pub fn dart(seed: u64, n_train: usize) -> Dataset {
    let (train, val, test) = splits(gen_dart, seed ^ fnv("dart"), n_train, 64, 64);
    Dataset { name: "dart".into(), train, val, test, metric: Metric::BleuMeteor }
}

// ---------------------------------------------------------------------------
// SAMSum analogue: dialogue summarization
// ---------------------------------------------------------------------------

fn gen_samsum(rng: &mut Rng) -> Example {
    let a = *rng.choice(NAMES);
    let mut b = *rng.choice(NAMES);
    while b == a {
        b = *rng.choice(NAMES);
    }
    let v1 = *rng.choice(VERBS);
    let v2 = *rng.choice(VERBS);
    let filler1 = pick_words(rng, 3).join(" ");
    let filler2 = pick_words(rng, 3).join(" ");
    let dialog = format!("{a}: i {v1} {b} {filler1}\n{b}: ok i {v2} {a} {filler2}");
    let summary = format!("{a} {v1} {b} and {b} {v2} {a}");
    genr(dialog.into_bytes(), summary.into_bytes())
}

/// SAMSum analogue: dialogue summarization (ROUGE).
pub fn samsum(seed: u64, n_train: usize) -> Dataset {
    let (train, val, test) = splits(gen_samsum, seed ^ fnv("samsum"), n_train, 64, 64);
    Dataset { name: "samsum".into(), train, val, test, metric: Metric::Rouge }
}

// ---------------------------------------------------------------------------
// Spider analogue: text-to-query with real execution accuracy
// ---------------------------------------------------------------------------

/// The shared task table (also used by eval's exec-match metric).
pub fn spider_table(seed: u64) -> Table {
    let mut rng = Rng::new(seed ^ fnv("spider_table"));
    let pool = minidb::value_pool();
    let columns: Vec<String> = pool.keys().map(|s| s.to_string()).collect();
    let rows = (0..12)
        .map(|_| {
            columns
                .iter()
                .map(|c| pool[c.as_str()][rng.below(pool[c.as_str()].len())].to_string())
                .collect()
        })
        .collect();
    Table { name: "t".into(), columns, rows }
}

fn gen_spider(rng: &mut Rng, table: &Table) -> Example {
    let sel = &table.columns[rng.below(table.columns.len())];
    let use_where = rng.uniform() < 0.7;
    let (question, query) = if use_where {
        let fc = &table.columns[rng.below(table.columns.len())];
        let row = &table.rows[rng.below(table.rows.len())];
        // fc was drawn from table.columns, so col_index always finds it
        let fv = &row[table.col_index(fc).unwrap_or(0)];
        (
            format!("which {sel} has {fc} {fv} ? schema {}", table.schema_str()),
            format!("GET {sel} FROM t WHERE {fc} IS {fv}"),
        )
    } else {
        (
            format!("list all {sel} . schema {}", table.schema_str()),
            format!("GET {sel} FROM t"),
        )
    };
    genr(question.into_bytes(), query.into_bytes())
}

/// Spider analogue: text-to-query with genuine execution-match scoring
/// against the mini database ([`crate::data::minidb`]).
pub fn spider(seed: u64, n_train: usize) -> Dataset {
    let table = spider_table(seed);
    let mut rng = Rng::new(seed ^ fnv("spider"));
    let mut gen = |rng: &mut Rng| gen_spider(rng, &table);
    let train = (0..n_train).map(|_| gen(&mut rng)).collect();
    let val = (0..64).map(|_| gen(&mut rng)).collect();
    let test = (0..64).map(|_| gen(&mut rng)).collect();
    Dataset { name: "spider".into(), train, val, test, metric: Metric::Exec }
}

// ---------------------------------------------------------------------------
// CIFAR-10 / CelebA analogues: pixel-sequence classification
// ---------------------------------------------------------------------------

/// 8×8 grayscale patterns, 10 classes; pixels quantized to 16 levels and
/// emitted as bytes 'a'..'p' (keeps the byte-LM vocabulary dense).
fn gen_cifar(rng: &mut Rng) -> Example {
    let class = rng.below(10);
    let n = 8;
    let mut img = vec![0.0f32; n * n];
    for y in 0..n {
        for x in 0..n {
            let (fy, fx) = (y as f32 / n as f32, x as f32 / n as f32);
            let v = match class {
                0 => fx,                                   // horizontal gradient
                1 => fy,                                   // vertical gradient
                2 => ((x / 2 + y / 2) % 2) as f32,         // checker
                3 => ((x / 2) % 2) as f32,                 // v-stripes
                4 => ((y / 2) % 2) as f32,                 // h-stripes
                5 => 1.0 - ((fx - 0.5).abs() + (fy - 0.5).abs()), // diamond
                6 => (((fx - 0.5).powi(2) + (fy - 0.5).powi(2)).sqrt() < 0.3) as i32 as f32,
                7 => (fx + fy) / 2.0,                      // diagonal gradient
                8 => ((x + y) % 2) as f32,                 // fine checker
                _ => (x == y) as i32 as f32,               // diagonal line
            };
            img[y * n + x] = v + 0.15 * rng.normal();
        }
    }
    let bytes: Vec<u8> = img
        .iter()
        .map(|&v| b'a' + (v.clamp(0.0, 0.999) * 16.0) as u8)
        .collect();
    cls(bytes, class, b"0123456789")
}

/// CelebA-like binary attribute: is the bright blob in the left half?
fn gen_celeba(rng: &mut Rng) -> Example {
    let n = 8;
    let left = rng.uniform() < 0.5;
    let cx = if left { 1 + rng.below(2) } else { 5 + rng.below(2) } as f32;
    let cy = (2 + rng.below(4)) as f32;
    let mut bytes = Vec::with_capacity(n * n);
    for y in 0..n {
        for x in 0..n {
            let d = ((x as f32 - cx).powi(2) + (y as f32 - cy).powi(2)).sqrt();
            let v = (-d / 2.0).exp() + 0.1 * rng.normal();
            bytes.push(b'a' + (v.clamp(0.0, 0.999) * 16.0) as u8);
        }
    }
    cls(bytes, left as usize, b"01")
}

/// CIFAR-10 analogue: byte-grid "images" classified by a latent rule.
pub fn cifar(seed: u64, n_train: usize) -> Dataset {
    let (train, val, test) = splits(gen_cifar, seed ^ fnv("cifar"), n_train, 96, 96);
    Dataset { name: "cifar10".into(), train, val, test, metric: Metric::Acc }
}

/// CelebA analogue: attribute classification over byte grids.
pub fn celeba(seed: u64, n_train: usize) -> Dataset {
    let (train, val, test) = splits(gen_celeba, seed ^ fnv("celeba"), n_train, 96, 96);
    Dataset { name: "celeba".into(), train, val, test, metric: Metric::Acc }
}

/// Pretraining corpus: concatenated samples from all text generators, so the
/// "pretrained" frozen model has seen the lexicon and formats (the stand-in
/// for the paper's web-scale pretrained checkpoints).
pub fn pretrain_corpus(seed: u64, approx_bytes: usize) -> Vec<u8> {
    let mut rng = Rng::new(seed ^ fnv("corpus"));
    let table = spider_table(seed);
    let mut out = Vec::with_capacity(approx_bytes + 256);
    while out.len() < approx_bytes {
        let ex = match rng.below(6) {
            0 => gen_rte(&mut rng),
            1 => gen_dart(&mut rng),
            2 => gen_samsum(&mut rng),
            3 => gen_spider(&mut rng, &table),
            4 => gen_sst2(&mut rng),
            _ => gen_cola(&mut rng),
        };
        out.extend(&ex.prompt);
        out.push(b' ');
        out.extend(&ex.target);
        if let Some(l) = ex.label {
            out.push(ex.label_bytes[l]);
        }
        out.push(b'\n');
    }
    out
}

fn fnv(s: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Dataset registry by name (the config system's `dataset` field). Unknown
/// names error so a bad suite config degrades one cell, not the process.
pub fn by_name(name: &str, seed: u64, n_train: usize) -> Result<Dataset> {
    Ok(match name {
        "dart" => dart(seed, n_train),
        "samsum" => samsum(seed, n_train),
        "spider" => spider(seed, n_train),
        "cifar10" => cifar(seed, n_train),
        "celeba" => celeba(seed, n_train),
        g if g.starts_with("glue/") => glue(&g[5..], seed, n_train)?,
        _ => bail!("unknown dataset {name:?} (see rust/docs/suite.md)"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::minidb::exec_match;

    #[test]
    fn generators_deterministic() {
        let d1 = glue("rte", 7, 32).unwrap();
        let d2 = glue("rte", 7, 32).unwrap();
        assert_eq!(d1.train[0].prompt, d2.train[0].prompt);
        assert_eq!(d1.train[0].label, d2.train[0].label);
        let d3 = glue("rte", 8, 32).unwrap();
        assert_ne!(d3.train[0].prompt, d1.train[0].prompt);
    }

    #[test]
    fn glue_labels_balanced_and_valid() {
        for sub in GLUE_SUBTASKS {
            let d = glue(sub, 3, 200).unwrap();
            let n_classes = d.train[0].label_bytes.len();
            let mut counts = vec![0usize; n_classes];
            for ex in &d.train {
                counts[ex.label.unwrap()] += 1;
            }
            // no class should be empty, majority class < 90%
            assert!(counts.iter().all(|&c| c > 0), "{sub}: {counts:?}");
            assert!(*counts.iter().max().unwrap() < 180, "{sub}: {counts:?}");
        }
    }

    #[test]
    fn rte_program_is_consistent() {
        let d = glue("rte", 11, 100).unwrap();
        for ex in &d.train {
            let s = String::from_utf8(ex.prompt.clone()).unwrap();
            let (prem, hyp) = s.split_once(" ; ").unwrap();
            let pw: Vec<&str> = prem.split(' ').collect();
            let subset = hyp.split(' ').all(|w| pw.contains(&w));
            assert_eq!(subset, ex.label == Some(1));
        }
    }

    #[test]
    fn spider_gold_queries_execute() {
        let d = spider(5, 64);
        let t = spider_table(5);
        for ex in d.train.iter().take(32) {
            let q = String::from_utf8(ex.target.clone()).unwrap();
            assert!(exec_match(&t, &q, &q), "gold query must exec-match itself: {q}");
        }
    }

    #[test]
    fn dart_target_mentions_values() {
        let d = dart(9, 32);
        for ex in &d.train {
            let rec = String::from_utf8(ex.prompt.clone()).unwrap();
            let txt = String::from_utf8(ex.target.clone()).unwrap();
            for kv in rec.split('|') {
                let (_, v) = kv.split_once('=').unwrap();
                assert!(txt.contains(v), "{txt} missing {v}");
            }
        }
    }

    #[test]
    fn cifar_pixels_in_alphabet() {
        let d = cifar(1, 16);
        for ex in &d.train {
            assert_eq!(ex.prompt.len(), 64);
            assert!(ex.prompt.iter().all(|&b| (b'a'..=b'p').contains(&b)));
        }
    }

    #[test]
    fn corpus_has_requested_size() {
        let c = pretrain_corpus(1, 4096);
        assert!(c.len() >= 4096);
        assert!(c.len() < 4096 + 512);
    }
}
