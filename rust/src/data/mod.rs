//! Data pipeline: synthetic analogues of the paper's six datasets, byte
//! tokenizer, batching, and splits.
//!
//! The paper fine-tunes on GLUE / DART / SAMSum / Spider / CIFAR-10 / CelebA.
//! Those require network access + pretrained checkpoints; this testbed has
//! neither, so each dataset is replaced by a generator producing a task with
//! the same *shape* whose labels are computed from the input by a small
//! latent program (DESIGN.md §Substitutions). Fine-tuning quality is then
//! measurable with the paper's own metrics and methods rank the same way.
//!
//! Tokenization is byte-level: vocab = 256 bytes + BOS(256) + PAD(257),
//! matching the AOT models' embedding table.

pub mod minidb;
pub mod tasks;

use crate::tensor::{IntTensor, Rng, Tensor};

/// Beginning-of-sequence token id.
pub const BOS: i32 = 256;
/// Padding token id (also fed to idle decode rows).
pub const PAD: i32 = 257;
/// Model vocabulary: 256 bytes + BOS + PAD.
pub const VOCAB: usize = 258;

/// One supervised example.
#[derive(Debug, Clone)]
pub struct Example {
    /// Input text (prompt / sentence pair / record / pixels).
    pub prompt: Vec<u8>,
    /// Generation target (empty for classification).
    pub target: Vec<u8>,
    /// Classification label (None for generation tasks).
    pub label: Option<usize>,
    /// Candidate label bytes for classification scoring (e.g. b"01").
    pub label_bytes: Vec<u8>,
}

/// A generated dataset with fixed splits.
#[derive(Debug)]
pub struct Dataset {
    /// Dataset name (tasks::by_name key).
    pub name: String,
    /// Training split.
    pub train: Vec<Example>,
    /// Validation split (early stopping).
    pub val: Vec<Example>,
    /// Held-out test split.
    pub test: Vec<Example>,
    /// headline evaluation metric; generation-based vs classification
    /// follows from it (`Metric::generative`)
    pub metric: crate::suite::Metric,
}

/// An encoded batch ready for the `step`/`fwd` artifacts.
#[derive(Debug, Clone)]
pub struct Batch {
    /// Input token ids (B, L).
    pub tokens: IntTensor,
    /// Next-token targets (B, L).
    pub targets: IntTensor,
    /// Loss mask over target positions (B, L).
    pub mask: Tensor,
    /// position of the label logit per row (classification eval)
    pub label_pos: Vec<usize>,
}

/// Encode one example into (seq, loss_start): seq = BOS + prompt + target.
fn encode(ex: &Example) -> (Vec<i32>, usize) {
    let mut seq = Vec::with_capacity(2 + ex.prompt.len() + ex.target.len() + 2);
    seq.push(BOS);
    seq.extend(ex.prompt.iter().map(|&b| b as i32));
    let loss_start = seq.len();
    if let Some(lbl) = ex.label {
        seq.push(ex.label_bytes[lbl] as i32);
    } else {
        seq.extend(ex.target.iter().map(|&b| b as i32));
    }
    (seq, loss_start)
}

/// Build a (B, L) batch from examples. Sequences are truncated from the
/// LEFT of the prompt when too long (the label/target end must survive) and
/// padded with PAD. Loss mask covers only the target positions.
pub fn make_batch(examples: &[&Example], bsz: usize, seqlen: usize) -> Batch {
    let mut tokens = vec![PAD; bsz * seqlen];
    let mut targets = vec![PAD; bsz * seqlen];
    let mut mask = vec![0.0f32; bsz * seqlen];
    let mut label_pos = vec![0usize; bsz];
    for (r, ex) in examples.iter().enumerate().take(bsz) {
        let (mut seq, mut loss_start) = encode(ex);
        if seq.len() > seqlen + 1 {
            // keep BOS, drop from prompt front
            let excess = seq.len() - (seqlen + 1);
            let keep_from = 1 + excess.min(loss_start.saturating_sub(1));
            let mut cut: Vec<i32> = vec![BOS];
            cut.extend_from_slice(&seq[keep_from..]);
            loss_start -= keep_from - 1;
            seq = cut;
            if seq.len() > seqlen + 1 {
                seq.truncate(seqlen + 1); // truncate target tail as last resort
            }
        }
        let n = seq.len() - 1; // predict next token
        for t in 0..n {
            tokens[r * seqlen + t] = seq[t];
            targets[r * seqlen + t] = seq[t + 1];
            if t + 1 >= loss_start {
                mask[r * seqlen + t] = 1.0;
            }
        }
        label_pos[r] = loss_start - 1; // logits at this position predict label
    }
    Batch {
        tokens: IntTensor::from_vec(&[bsz, seqlen], tokens),
        targets: IntTensor::from_vec(&[bsz, seqlen], targets),
        mask: Tensor::from_vec(&[bsz, seqlen], mask),
        label_pos,
    }
}

/// Language-model batch over a raw corpus window (pretraining): mask covers
/// every non-pad position.
pub fn make_lm_batch(corpus: &[u8], rng: &mut Rng, bsz: usize, seqlen: usize) -> Batch {
    let mut tokens = vec![PAD; bsz * seqlen];
    let mut targets = vec![PAD; bsz * seqlen];
    let mut mask = vec![0.0f32; bsz * seqlen];
    for r in 0..bsz {
        let start = rng.below(corpus.len().saturating_sub(seqlen + 2).max(1));
        tokens[r * seqlen] = BOS;
        targets[r * seqlen] = corpus[start] as i32;
        mask[r * seqlen] = 1.0;
        for t in 1..seqlen {
            tokens[r * seqlen + t] = corpus[start + t - 1] as i32;
            targets[r * seqlen + t] = corpus[start + t] as i32;
            mask[r * seqlen + t] = 1.0;
        }
    }
    Batch {
        tokens: IntTensor::from_vec(&[bsz, seqlen], tokens),
        targets: IntTensor::from_vec(&[bsz, seqlen], targets),
        mask: Tensor::from_vec(&[bsz, seqlen], mask),
        label_pos: vec![0; bsz],
    }
}

/// Deterministic batched iteration order over a split.
pub struct BatchIter<'a> {
    examples: Vec<&'a Example>,
    bsz: usize,
    seqlen: usize,
    pos: usize,
}

impl<'a> BatchIter<'a> {
    /// Shuffled batched iteration over a split.
    pub fn new(split: &'a [Example], rng: &mut Rng, bsz: usize, seqlen: usize) -> Self {
        let mut examples: Vec<&Example> = split.iter().collect();
        rng.shuffle(&mut examples);
        BatchIter { examples, bsz, seqlen, pos: 0 }
    }
    /// Full batches this iterator will yield.
    pub fn n_batches(&self) -> usize {
        self.examples.len() / self.bsz
    }
}

impl<'a> Iterator for BatchIter<'a> {
    type Item = (Batch, Vec<&'a Example>);
    fn next(&mut self) -> Option<Self::Item> {
        if self.pos + self.bsz > self.examples.len() {
            return None;
        }
        let exs = &self.examples[self.pos..self.pos + self.bsz];
        self.pos += self.bsz;
        Some((make_batch(exs, self.bsz, self.seqlen), exs.to_vec()))
    }
}

/// Split generated text into whitespace words and map to stable u32 ids
/// (for ROUGE/BLEU/METEOR computation on byte output).
pub fn words_to_ids(text: &[u8]) -> Vec<u32> {
    let mut ids = Vec::new();
    for w in text.split(|&b| b == b' ' || b == b'\n') {
        if w.is_empty() {
            continue;
        }
        // FNV-1a
        let mut h: u32 = 0x811c9dc5;
        for &b in w {
            h ^= b as u32;
            h = h.wrapping_mul(0x01000193);
        }
        ids.push(h);
    }
    ids
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ex_cls() -> Example {
        Example {
            prompt: b"ab cd".to_vec(),
            target: vec![],
            label: Some(1),
            label_bytes: b"01".to_vec(),
        }
    }

    #[test]
    fn batch_classification_mask_and_label_pos() {
        let ex = ex_cls();
        let b = make_batch(&[&ex], 1, 10);
        // seq = BOS a b ' ' c d '1'  -> tokens len 6 before label
        assert_eq!(b.tokens.data[0], BOS);
        let lp = b.label_pos[0];
        assert_eq!(b.targets.data[lp], b'1' as i32);
        assert_eq!(b.mask.data[lp], 1.0);
        // only one supervised position
        assert_eq!(b.mask.data.iter().filter(|&&m| m == 1.0).count(), 1);
    }

    #[test]
    fn batch_generation_mask_covers_target() {
        let ex = Example {
            prompt: b"q".to_vec(),
            target: b"xyz".to_vec(),
            label: None,
            label_bytes: vec![],
        };
        let b = make_batch(&[&ex], 1, 8);
        assert_eq!(b.mask.data.iter().filter(|&&m| m == 1.0).count(), 3);
        // last supervised target is 'z'
        let last = b
            .mask
            .data
            .iter()
            .enumerate()
            .filter(|(_, &m)| m == 1.0)
            .map(|(i, _)| i)
            .max()
            .unwrap();
        assert_eq!(b.targets.data[last], b'z' as i32);
    }

    #[test]
    fn batch_truncates_prompt_front_keeps_label() {
        let ex = Example {
            prompt: vec![b'a'; 50],
            target: vec![],
            label: Some(0),
            label_bytes: b"01".to_vec(),
        };
        let b = make_batch(&[&ex], 1, 16);
        let lp = b.label_pos[0];
        assert!(lp < 16);
        assert_eq!(b.targets.data[lp], b'0' as i32);
        assert_eq!(b.mask.data[lp], 1.0);
    }

    #[test]
    fn lm_batch_full_mask() {
        let corpus: Vec<u8> = (0..100u8).collect();
        let mut rng = Rng::new(0);
        let b = make_lm_batch(&corpus, &mut rng, 2, 16);
        assert!(b.mask.data.iter().all(|&m| m == 1.0));
        // targets shifted by one wrt tokens
        assert_eq!(b.tokens.data[1] + 1, b.targets.data[1]);
    }

    #[test]
    fn words_ids_stable_and_order_sensitive() {
        let a = words_to_ids(b"the cat sat");
        let b = words_to_ids(b"the cat sat");
        let c = words_to_ids(b"sat cat the");
        assert_eq!(a, b);
        assert_eq!(a.len(), 3);
        assert_ne!(a, c);
        let mut a2 = a.clone();
        let mut c2 = c.clone();
        a2.sort();
        c2.sort();
        assert_eq!(a2, c2);
    }

    #[test]
    fn batch_iter_counts() {
        let exs: Vec<Example> = (0..10).map(|_| ex_cls()).collect();
        let mut rng = Rng::new(1);
        let it = BatchIter::new(&exs, &mut rng, 4, 12);
        assert_eq!(it.n_batches(), 2);
        assert_eq!(it.count(), 2);
    }
}
