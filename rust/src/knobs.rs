//! The typed registry of every `SSM_PEFT_*` environment knob.
//!
//! This module is the **only** place in the crate allowed to call
//! `std::env::var` (enforced twice: clippy's `disallowed-methods` and the
//! repolint knob-registry rule). Every knob is declared once in [`KNOBS`]
//! with its type, default and doc line; the lint cross-checks that
//!
//! - every `SSM_PEFT_*` string anywhere in the source is a registered name,
//! - every registered knob is documented in `rust/docs/` by name.
//!
//! Adding a knob therefore means adding a [`Knob`] row, a typed accessor,
//! and a docs mention — or the build fails.
//!
//! Malformed values are never silently dropped: typed accessors warn once
//! per knob on stderr and fall back to the default, and [`validate`]
//! returns a typed parse error naming every offender (the serve CLI runs
//! it at startup).

/// Value type of a knob (how the raw string is parsed).
///
/// Malformed values are **never silently ignored**: the typed accessors
/// log a once-per-knob warning and fall back to the default, and
/// [`validate`] turns the same condition into a typed
/// [`ErrorKind::Parse`](crate::error::ErrorKind) error for callers that
/// want hard failure at startup.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KnobKind {
    /// Parsed with `usize::from_str`.
    Usize,
    /// Parsed with `f32::from_str`.
    Float,
    /// Used verbatim as a filesystem path (any non-empty string).
    Path,
}

impl KnobKind {
    /// Human-readable name for warnings and errors.
    pub fn label(self) -> &'static str {
        match self {
            KnobKind::Usize => "unsigned integer",
            KnobKind::Float => "float",
            KnobKind::Path => "path",
        }
    }

    /// Validate a raw string against this kind. The parse itself — no env
    /// access — so every kind gets a direct unit test.
    pub fn check(self, raw: &str) -> crate::error::Result<()> {
        let ok = match self {
            KnobKind::Usize => raw.parse::<usize>().is_ok(),
            KnobKind::Float => raw.parse::<f32>().map(|v| v.is_finite()).unwrap_or(false),
            KnobKind::Path => !raw.is_empty(),
        };
        if ok {
            Ok(())
        } else {
            Err(crate::error::Error::new(
                crate::error::ErrorKind::Parse,
                format!("malformed knob value {raw:?} (expected {})", self.label()),
            ))
        }
    }
}

/// One registered environment knob.
#[derive(Debug, Clone, Copy)]
pub struct Knob {
    /// Full environment-variable name (`SSM_PEFT_*`).
    pub name: &'static str,
    /// Value type.
    pub kind: KnobKind,
    /// Human-readable default (what applies when the variable is unset).
    pub default: &'static str,
    /// One-line description (mirrored in the docs).
    pub doc: &'static str,
}

/// Every environment knob the workspace reads, in one table.
pub const KNOBS: &[Knob] = &[
    Knob {
        name: "SSM_PEFT_ARTIFACTS",
        kind: KnobKind::Path,
        default: "<crate>/artifacts (or ./artifacts when present)",
        doc: "Override the AOT artifacts directory (manifest.json + HLO files).",
    },
    Knob {
        name: "SSM_PEFT_RESULTS",
        kind: KnobKind::Path,
        default: "<crate>/results",
        doc: "Override the results directory (JSONL records, BENCH_*.json).",
    },
    Knob {
        name: "SSM_PEFT_WORKERS",
        kind: KnobKind::Usize,
        default: "per-call default (suite CLI uses 2)",
        doc: "Suite worker threads for parallel fine-tune cells.",
    },
    Knob {
        name: "SSM_PEFT_FUSED_WORKERS",
        kind: KnobKind::Usize,
        default: "min(available cores, 4)",
        doc: "Worker threads inside one fused-optimizer step.",
    },
    Knob {
        name: "SSM_PEFT_BENCH_SCALE",
        kind: KnobKind::Float,
        default: "1.0",
        doc: "Scales bench iteration counts and synthetic model size (0.1 = CI tiny mode).",
    },
    Knob {
        name: "SSM_PEFT_MAX_TICKS",
        kind: KnobKind::Usize,
        default: "0 (unlimited)",
        doc: "Scheduler run_to_completion tick budget; active rows drain as failed past it.",
    },
    Knob {
        name: "SSM_PEFT_FAULT_SEED",
        kind: KnobKind::Usize,
        default: "0",
        doc: "Seed for the deterministic fault-injection schedule (fault module).",
    },
    Knob {
        name: "SSM_PEFT_FAULT_EXEC",
        kind: KnobKind::Float,
        default: "0.0",
        doc: "Injected fault rate [0,1] for executable dispatches (decode/prefill steps).",
    },
    Knob {
        name: "SSM_PEFT_FAULT_ADAPTER_LOAD",
        kind: KnobKind::Float,
        default: "0.0",
        doc: "Injected fault rate [0,1] for adapter loads into the registry.",
    },
    Knob {
        name: "SSM_PEFT_FAULT_ARTIFACT_READ",
        kind: KnobKind::Float,
        default: "0.0",
        doc: "Injected fault rate [0,1] for artifact/manifest reads (merged-lane loads).",
    },
    Knob {
        name: "SSM_PEFT_FAULT_STATE_READBACK",
        kind: KnobKind::Float,
        default: "0.0",
        doc: "Injected fault rate [0,1] for device-to-host state readbacks (checkpoints).",
    },
    Knob {
        name: "SSM_PEFT_FAULT_STATE_PERSIST",
        kind: KnobKind::Float,
        default: "0.0",
        doc: "Injected fault rate [0,1] for session-state record writes (session store).",
    },
    Knob {
        name: "SSM_PEFT_FAULT_STATE_LOAD",
        kind: KnobKind::Float,
        default: "0.0",
        doc: "Injected fault rate [0,1] for session-state record reads (session store).",
    },
    Knob {
        name: "SSM_PEFT_SESSIONS_DIR",
        kind: KnobKind::Path,
        default: "unset (session spill tier disabled; in-memory tier only)",
        doc: "Spill directory for durable per-session state records (serve sessions).",
    },
    Knob {
        name: "SSM_PEFT_SESSIONS_CAP",
        kind: KnobKind::Usize,
        default: "64",
        doc: "In-memory LRU capacity (entries) of the serve session-state store.",
    },
    Knob {
        name: "SSM_PEFT_OBS_TRACE_CAP",
        kind: KnobKind::Usize,
        default: "256",
        doc: "Capacity of the scheduler's ring of recent request traces.",
    },
    Knob {
        name: "SSM_PEFT_OBS_IDLE_BACKOFF_US",
        kind: KnobKind::Usize,
        default: "2000",
        doc: "Max serve-loop parked sleep between idle ticks, in microseconds (0 = spin).",
    },
    Knob {
        name: "SSM_PEFT_SERVING_SEED",
        kind: KnobKind::Usize,
        default: "0",
        doc: "Seed for the bench serving load generator (arrivals, lengths, adapter skew).",
    },
];

/// Registry lookup by full name.
pub fn lookup(name: &str) -> Option<&'static Knob> {
    KNOBS.iter().find(|k| k.name == name)
}

/// The single raw environment read. Debug builds refuse unregistered
/// names so a new knob cannot bypass the table even before the lint runs.
#[allow(clippy::disallowed_methods)] // the one sanctioned env::var site
fn raw(name: &str) -> Option<String> {
    debug_assert!(lookup(name).is_some(), "unregistered knob {name}");
    std::env::var(name).ok()
}

/// Warn exactly once per knob about a malformed value. Silent fallback
/// hid real operator typos (`SSM_PEFT_MAX_TICKS=abc` just vanished);
/// once-per-knob keeps a hot accessor from spamming stderr.
fn warn_malformed(name: &'static str, raw_value: &str, kind: KnobKind) {
    use std::sync::Mutex;
    static WARNED: Mutex<Vec<&'static str>> = Mutex::new(Vec::new());
    let mut warned = WARNED.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
    if !warned.contains(&name) {
        warned.push(name);
        eprintln!(
            "warning: ignoring malformed {name}={raw_value:?} \
             (expected {}); using the default",
            kind.label()
        );
    }
}

/// Parse a set knob strictly: a malformed value warns once and yields
/// `None` (the caller's default applies), never a silently-wrong parse.
fn parsed<T: std::str::FromStr>(name: &'static str, kind: KnobKind) -> Option<T> {
    let raw_value = raw(name)?;
    match raw_value.parse::<T>() {
        Ok(v) if kind.check(&raw_value).is_ok() => Some(v),
        _ => {
            warn_malformed(name, &raw_value, kind);
            None
        }
    }
}

/// Validate every *set* `SSM_PEFT_*` variable against its registered
/// kind. Returns a typed [`ErrorKind::Parse`](crate::error::ErrorKind)
/// error naming every offender — the hard-failure counterpart to the
/// accessors' warn-once-and-default behavior (the serve CLI calls this at
/// startup so a typo'd knob cannot ride along unnoticed).
pub fn validate() -> crate::error::Result<()> {
    let mut bad = Vec::new();
    for k in KNOBS {
        if let Some(raw_value) = raw(k.name) {
            if k.kind.check(&raw_value).is_err() {
                bad.push(format!("{}={raw_value:?} (expected {})", k.name, k.kind.label()));
            }
        }
    }
    if bad.is_empty() {
        Ok(())
    } else {
        Err(crate::error::Error::new(
            crate::error::ErrorKind::Parse,
            format!("malformed environment knob(s): {}", bad.join(", ")),
        ))
    }
}

/// `SSM_PEFT_ARTIFACTS`: artifacts directory override.
pub fn artifacts_override() -> Option<std::path::PathBuf> {
    raw("SSM_PEFT_ARTIFACTS").map(std::path::PathBuf::from)
}

/// `SSM_PEFT_RESULTS`: results directory override.
pub fn results_override() -> Option<std::path::PathBuf> {
    raw("SSM_PEFT_RESULTS").map(std::path::PathBuf::from)
}

/// `SSM_PEFT_WORKERS`: suite worker threads, else the caller's default;
/// floored at 1.
pub fn workers(default: usize) -> usize {
    parsed("SSM_PEFT_WORKERS", KnobKind::Usize).unwrap_or(default).max(1)
}

/// `SSM_PEFT_FUSED_WORKERS`: per-step fused-optimizer worker threads,
/// else min(available cores, 4); floored at 1.
pub fn fused_workers() -> usize {
    parsed("SSM_PEFT_FUSED_WORKERS", KnobKind::Usize)
        .unwrap_or_else(|| {
            std::thread::available_parallelism().map(|n| n.get().min(4)).unwrap_or(1)
        })
        .max(1)
}

/// `SSM_PEFT_BENCH_SCALE`: bench scale factor, default 1.0.
pub fn bench_scale() -> f32 {
    parsed("SSM_PEFT_BENCH_SCALE", KnobKind::Float).unwrap_or(1.0)
}

/// `SSM_PEFT_MAX_TICKS`: scheduler run-to-completion tick budget,
/// default 0 = unlimited.
pub fn max_ticks() -> usize {
    parsed("SSM_PEFT_MAX_TICKS", KnobKind::Usize).unwrap_or(0)
}

/// `SSM_PEFT_FAULT_SEED`: fault-injection schedule seed, default 0.
pub fn fault_seed() -> u64 {
    parsed("SSM_PEFT_FAULT_SEED", KnobKind::Usize).unwrap_or(0)
}

/// `SSM_PEFT_SESSIONS_DIR`: spill directory for the serve session-state
/// store; unset = in-memory tier only (no durable records).
pub fn sessions_dir() -> Option<std::path::PathBuf> {
    raw("SSM_PEFT_SESSIONS_DIR").map(std::path::PathBuf::from)
}

/// `SSM_PEFT_SESSIONS_CAP`: in-memory LRU capacity of the session-state
/// store, default 64; floored at 1.
pub fn sessions_cap() -> usize {
    parsed("SSM_PEFT_SESSIONS_CAP", KnobKind::Usize).unwrap_or(64).max(1)
}

/// `SSM_PEFT_OBS_TRACE_CAP`: capacity of the scheduler's trace ring,
/// default 256; floored at 1.
pub fn obs_trace_cap() -> usize {
    parsed("SSM_PEFT_OBS_TRACE_CAP", KnobKind::Usize).unwrap_or(256).max(1)
}

/// `SSM_PEFT_OBS_IDLE_BACKOFF_US`: the serve loop's max parked sleep
/// between unproductive ticks, in microseconds; default 2000, 0 disables
/// parking (busy-spin, the pre-backoff behavior).
pub fn obs_idle_backoff_us() -> u64 {
    parsed::<usize>("SSM_PEFT_OBS_IDLE_BACKOFF_US", KnobKind::Usize)
        .unwrap_or(2000) as u64
}

/// `SSM_PEFT_SERVING_SEED`: seed for the `bench serving` load generator,
/// default 0.
pub fn serving_seed() -> u64 {
    parsed::<usize>("SSM_PEFT_SERVING_SEED", KnobKind::Usize).unwrap_or(0) as u64
}

/// Per-site injected fault rates, in [`crate::fault::FaultSite::ALL`]
/// order: `SSM_PEFT_FAULT_EXEC`, `SSM_PEFT_FAULT_ADAPTER_LOAD`,
/// `SSM_PEFT_FAULT_ARTIFACT_READ`, `SSM_PEFT_FAULT_STATE_READBACK`,
/// `SSM_PEFT_FAULT_STATE_PERSIST`, `SSM_PEFT_FAULT_STATE_LOAD`.
/// All default 0.0 (faults off).
pub fn fault_rates() -> [f32; crate::fault::SITES] {
    let get = |name: &'static str| -> f32 {
        parsed(name, KnobKind::Float).unwrap_or(0.0)
    };
    [
        get("SSM_PEFT_FAULT_EXEC"),
        get("SSM_PEFT_FAULT_ADAPTER_LOAD"),
        get("SSM_PEFT_FAULT_ARTIFACT_READ"),
        get("SSM_PEFT_FAULT_STATE_READBACK"),
        get("SSM_PEFT_FAULT_STATE_PERSIST"),
        get("SSM_PEFT_FAULT_STATE_LOAD"),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_knob_is_well_formed() {
        assert!(!KNOBS.is_empty());
        for k in KNOBS {
            assert!(k.name.starts_with("SSM_PEFT_"), "{}", k.name);
            assert!(!k.doc.is_empty(), "{} missing doc", k.name);
            assert!(!k.default.is_empty(), "{} missing default", k.name);
        }
    }

    #[test]
    fn names_are_unique() {
        let mut names: Vec<&str> = KNOBS.iter().map(|k| k.name).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), KNOBS.len());
    }

    #[test]
    fn lookup_finds_registered_only() {
        assert!(lookup("SSM_PEFT_WORKERS").is_some());
        assert!(lookup("SSM_PEFT_NOPE").is_none());
    }

    #[test]
    fn fault_knobs_registered_and_default_off() {
        assert!(lookup("SSM_PEFT_MAX_TICKS").is_some());
        assert!(lookup("SSM_PEFT_FAULT_SEED").is_some());
        assert!(lookup("SSM_PEFT_FAULT_STATE_PERSIST").is_some());
        assert!(lookup("SSM_PEFT_FAULT_STATE_LOAD").is_some());
        assert_eq!(fault_rates().len(), crate::fault::SITES);
        for r in fault_rates() {
            assert!(r.is_finite());
        }
    }

    #[test]
    fn session_knobs_registered() {
        assert!(lookup("SSM_PEFT_SESSIONS_DIR").is_some());
        assert!(lookup("SSM_PEFT_SESSIONS_CAP").is_some());
        assert!(sessions_cap() >= 1);
    }

    #[test]
    fn obs_and_serving_knobs_registered() {
        assert!(lookup("SSM_PEFT_OBS_TRACE_CAP").is_some());
        assert!(lookup("SSM_PEFT_OBS_IDLE_BACKOFF_US").is_some());
        assert!(lookup("SSM_PEFT_SERVING_SEED").is_some());
        assert!(obs_trace_cap() >= 1);
        let _ = obs_idle_backoff_us(); // 0 is a valid (spin) setting
        let _ = serving_seed();
    }

    #[test]
    fn typed_accessors_apply_floors() {
        // unset (or set) either way, floors hold
        assert!(workers(0) >= 1);
        assert!(fused_workers() >= 1);
        assert!(bench_scale() > 0.0 || bench_scale() <= 0.0); // parses to a float
    }

    // one strict-parse unit test per KnobKind — the parse is a pure
    // function (KnobKind::check), so no env mutation races here

    #[test]
    fn usize_kind_rejects_malformed() {
        assert!(KnobKind::Usize.check("42").is_ok());
        for bad in ["abc", "-3", "1.5", ""] {
            let e = KnobKind::Usize.check(bad).unwrap_err();
            assert_eq!(e.kind(), crate::error::ErrorKind::Parse, "{bad:?}");
        }
    }

    #[test]
    fn float_kind_rejects_malformed() {
        assert!(KnobKind::Float.check("0.25").is_ok());
        assert!(KnobKind::Float.check("2").is_ok());
        for bad in ["abc", "", "NaN", "inf"] {
            let e = KnobKind::Float.check(bad).unwrap_err();
            assert_eq!(e.kind(), crate::error::ErrorKind::Parse, "{bad:?}");
        }
    }

    #[test]
    fn path_kind_rejects_only_empty() {
        assert!(KnobKind::Path.check("/tmp/x").is_ok());
        assert!(KnobKind::Path.check("relative/dir").is_ok());
        let e = KnobKind::Path.check("").unwrap_err();
        assert_eq!(e.kind(), crate::error::ErrorKind::Parse);
    }

    #[test]
    fn malformed_env_value_warns_and_defaults_and_validate_rejects() {
        // the one env-mutating test: uses a knob nothing else reads in
        // unit tests, and restores it before returning
        std::env::set_var("SSM_PEFT_SESSIONS_CAP", "not-a-number");
        assert_eq!(sessions_cap(), 64, "malformed value must fall back to default");
        let e = validate().unwrap_err();
        assert_eq!(e.kind(), crate::error::ErrorKind::Parse);
        assert!(format!("{e}").contains("SSM_PEFT_SESSIONS_CAP"), "{e}");
        std::env::set_var("SSM_PEFT_SESSIONS_CAP", "8");
        assert_eq!(sessions_cap(), 8);
        std::env::remove_var("SSM_PEFT_SESSIONS_CAP");
        assert_eq!(sessions_cap(), 64);
    }
}
