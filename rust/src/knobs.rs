//! The typed registry of every `SSM_PEFT_*` environment knob.
//!
//! This module is the **only** place in the crate allowed to call
//! `std::env::var` (enforced twice: clippy's `disallowed-methods` and the
//! repolint knob-registry rule). Every knob is declared once in [`KNOBS`]
//! with its type, default and doc line; the lint cross-checks that
//!
//! - every `SSM_PEFT_*` string anywhere in the source is a registered name,
//! - every registered knob is documented in `rust/docs/` by name.
//!
//! Adding a knob therefore means adding a [`Knob`] row, a typed accessor,
//! and a docs mention — or the build fails.

/// Value type of a knob (how the raw string is parsed).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KnobKind {
    /// Parsed with `usize::from_str`; invalid values fall back to default.
    Usize,
    /// Parsed with `f32::from_str`; invalid values fall back to default.
    Float,
    /// Used verbatim as a filesystem path.
    Path,
}

/// One registered environment knob.
#[derive(Debug, Clone, Copy)]
pub struct Knob {
    /// Full environment-variable name (`SSM_PEFT_*`).
    pub name: &'static str,
    /// Value type.
    pub kind: KnobKind,
    /// Human-readable default (what applies when the variable is unset).
    pub default: &'static str,
    /// One-line description (mirrored in the docs).
    pub doc: &'static str,
}

/// Every environment knob the workspace reads, in one table.
pub const KNOBS: &[Knob] = &[
    Knob {
        name: "SSM_PEFT_ARTIFACTS",
        kind: KnobKind::Path,
        default: "<crate>/artifacts (or ./artifacts when present)",
        doc: "Override the AOT artifacts directory (manifest.json + HLO files).",
    },
    Knob {
        name: "SSM_PEFT_RESULTS",
        kind: KnobKind::Path,
        default: "<crate>/results",
        doc: "Override the results directory (JSONL records, BENCH_*.json).",
    },
    Knob {
        name: "SSM_PEFT_WORKERS",
        kind: KnobKind::Usize,
        default: "per-call default (suite CLI uses 2)",
        doc: "Suite worker threads for parallel fine-tune cells.",
    },
    Knob {
        name: "SSM_PEFT_FUSED_WORKERS",
        kind: KnobKind::Usize,
        default: "min(available cores, 4)",
        doc: "Worker threads inside one fused-optimizer step.",
    },
    Knob {
        name: "SSM_PEFT_BENCH_SCALE",
        kind: KnobKind::Float,
        default: "1.0",
        doc: "Scales bench iteration counts and synthetic model size (0.1 = CI tiny mode).",
    },
    Knob {
        name: "SSM_PEFT_MAX_TICKS",
        kind: KnobKind::Usize,
        default: "0 (unlimited)",
        doc: "Scheduler run_to_completion tick budget; active rows drain as failed past it.",
    },
    Knob {
        name: "SSM_PEFT_FAULT_SEED",
        kind: KnobKind::Usize,
        default: "0",
        doc: "Seed for the deterministic fault-injection schedule (fault module).",
    },
    Knob {
        name: "SSM_PEFT_FAULT_EXEC",
        kind: KnobKind::Float,
        default: "0.0",
        doc: "Injected fault rate [0,1] for executable dispatches (decode/prefill steps).",
    },
    Knob {
        name: "SSM_PEFT_FAULT_ADAPTER_LOAD",
        kind: KnobKind::Float,
        default: "0.0",
        doc: "Injected fault rate [0,1] for adapter loads into the registry.",
    },
    Knob {
        name: "SSM_PEFT_FAULT_ARTIFACT_READ",
        kind: KnobKind::Float,
        default: "0.0",
        doc: "Injected fault rate [0,1] for artifact/manifest reads (merged-lane loads).",
    },
    Knob {
        name: "SSM_PEFT_FAULT_STATE_READBACK",
        kind: KnobKind::Float,
        default: "0.0",
        doc: "Injected fault rate [0,1] for device-to-host state readbacks (checkpoints).",
    },
];

/// Registry lookup by full name.
pub fn lookup(name: &str) -> Option<&'static Knob> {
    KNOBS.iter().find(|k| k.name == name)
}

/// The single raw environment read. Debug builds refuse unregistered
/// names so a new knob cannot bypass the table even before the lint runs.
#[allow(clippy::disallowed_methods)] // the one sanctioned env::var site
fn raw(name: &str) -> Option<String> {
    debug_assert!(lookup(name).is_some(), "unregistered knob {name}");
    std::env::var(name).ok()
}

/// `SSM_PEFT_ARTIFACTS`: artifacts directory override.
pub fn artifacts_override() -> Option<std::path::PathBuf> {
    raw("SSM_PEFT_ARTIFACTS").map(std::path::PathBuf::from)
}

/// `SSM_PEFT_RESULTS`: results directory override.
pub fn results_override() -> Option<std::path::PathBuf> {
    raw("SSM_PEFT_RESULTS").map(std::path::PathBuf::from)
}

/// `SSM_PEFT_WORKERS`: suite worker threads, else the caller's default;
/// floored at 1.
pub fn workers(default: usize) -> usize {
    raw("SSM_PEFT_WORKERS")
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
        .max(1)
}

/// `SSM_PEFT_FUSED_WORKERS`: per-step fused-optimizer worker threads,
/// else min(available cores, 4); floored at 1.
pub fn fused_workers() -> usize {
    raw("SSM_PEFT_FUSED_WORKERS")
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| {
            std::thread::available_parallelism().map(|n| n.get().min(4)).unwrap_or(1)
        })
        .max(1)
}

/// `SSM_PEFT_BENCH_SCALE`: bench scale factor, default 1.0.
pub fn bench_scale() -> f32 {
    raw("SSM_PEFT_BENCH_SCALE").and_then(|s| s.parse().ok()).unwrap_or(1.0)
}

/// `SSM_PEFT_MAX_TICKS`: scheduler run-to-completion tick budget,
/// default 0 = unlimited.
pub fn max_ticks() -> usize {
    raw("SSM_PEFT_MAX_TICKS").and_then(|s| s.parse().ok()).unwrap_or(0)
}

/// `SSM_PEFT_FAULT_SEED`: fault-injection schedule seed, default 0.
pub fn fault_seed() -> u64 {
    raw("SSM_PEFT_FAULT_SEED").and_then(|s| s.parse().ok()).unwrap_or(0)
}

/// Per-site injected fault rates, in [`crate::fault::FaultSite::ALL`]
/// order: `SSM_PEFT_FAULT_EXEC`, `SSM_PEFT_FAULT_ADAPTER_LOAD`,
/// `SSM_PEFT_FAULT_ARTIFACT_READ`, `SSM_PEFT_FAULT_STATE_READBACK`.
/// All default 0.0 (faults off).
pub fn fault_rates() -> [f32; 4] {
    let get = |name: &str| -> f32 {
        raw(name).and_then(|s| s.parse().ok()).unwrap_or(0.0)
    };
    [
        get("SSM_PEFT_FAULT_EXEC"),
        get("SSM_PEFT_FAULT_ADAPTER_LOAD"),
        get("SSM_PEFT_FAULT_ARTIFACT_READ"),
        get("SSM_PEFT_FAULT_STATE_READBACK"),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_knob_is_well_formed() {
        assert!(!KNOBS.is_empty());
        for k in KNOBS {
            assert!(k.name.starts_with("SSM_PEFT_"), "{}", k.name);
            assert!(!k.doc.is_empty(), "{} missing doc", k.name);
            assert!(!k.default.is_empty(), "{} missing default", k.name);
        }
    }

    #[test]
    fn names_are_unique() {
        let mut names: Vec<&str> = KNOBS.iter().map(|k| k.name).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), KNOBS.len());
    }

    #[test]
    fn lookup_finds_registered_only() {
        assert!(lookup("SSM_PEFT_WORKERS").is_some());
        assert!(lookup("SSM_PEFT_NOPE").is_none());
    }

    #[test]
    fn fault_knobs_registered_and_default_off() {
        assert!(lookup("SSM_PEFT_MAX_TICKS").is_some());
        assert!(lookup("SSM_PEFT_FAULT_SEED").is_some());
        for r in fault_rates() {
            assert!(r.is_finite());
        }
    }

    #[test]
    fn typed_accessors_apply_floors() {
        // unset (or set) either way, floors hold
        assert!(workers(0) >= 1);
        assert!(fused_workers() >= 1);
        assert!(bench_scale() > 0.0 || bench_scale() <= 0.0); // parses to a float
    }
}
