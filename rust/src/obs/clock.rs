//! The clock contract behind span tracing.
//!
//! Every timestamp in `obs` is a `u64` nanosecond count since the clock's
//! origin, read through the [`Clock`] trait. Production code runs on
//! [`WallClock`] (monotonic wall time); tests and `bench serving` run on
//! [`VirtualClock`], which only moves when the driver advances it — so a
//! traced run is a pure function of its inputs and its emitted JSON is
//! byte-identical run to run (rust/docs/observability.md § Clock contract).
//!
//! `WallClock` is the single non-deterministic corner of the module, which
//! is why the repolint determinism waivers below are scoped to exactly the
//! lines that touch the OS clock.

use std::sync::atomic::{AtomicU64, Ordering};

/// Nanoseconds one [`VirtualClock`] tick advances (1 ms). One scheduler
/// tick under the virtual clock models a 1 ms decode step.
pub const TICK_NS: u64 = 1_000_000;

/// A monotonic nanosecond clock. `now_ns` must never decrease between
/// calls on the same instance; 0 is the clock's origin.
pub trait Clock: Send + Sync {
    /// Nanoseconds since this clock's origin.
    fn now_ns(&self) -> u64;
}

/// Production clock: monotonic wall time since construction.
pub struct WallClock {
    // lint: allow(determinism)
    origin: std::time::Instant,
}

impl WallClock {
    /// A clock whose origin is "now".
    pub fn new() -> WallClock {
        // lint: allow(determinism)
        WallClock { origin: std::time::Instant::now() }
    }
}

impl Default for WallClock {
    fn default() -> Self {
        WallClock::new()
    }
}

impl Clock for WallClock {
    fn now_ns(&self) -> u64 {
        // u64 holds ~584 years of nanoseconds; saturate rather than wrap
        u64::try_from(self.origin.elapsed().as_nanos()).unwrap_or(u64::MAX)
    }
}

/// Deterministic clock for tests and `bench serving`: virtual time that
/// only moves when the driver calls [`VirtualClock::advance_ticks`] /
/// [`VirtualClock::advance_ns`]. Reads are lock-free atomic loads, so the
/// clock can be shared (`Arc`) between a driver and a scheduler without
/// perturbing the traced run.
pub struct VirtualClock {
    now: AtomicU64,
}

impl VirtualClock {
    /// A virtual clock at origin (0 ns).
    pub fn new() -> VirtualClock {
        VirtualClock { now: AtomicU64::new(0) }
    }
    /// Advance by `n` ticks of [`TICK_NS`] each.
    pub fn advance_ticks(&self, n: u64) {
        self.now.fetch_add(n.saturating_mul(TICK_NS), Ordering::Relaxed);
    }
    /// Advance by raw nanoseconds.
    pub fn advance_ns(&self, ns: u64) {
        self.now.fetch_add(ns, Ordering::Relaxed);
    }
}

impl Default for VirtualClock {
    fn default() -> Self {
        VirtualClock::new()
    }
}

impl Clock for VirtualClock {
    fn now_ns(&self) -> u64 {
        self.now.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wall_clock_is_monotonic() {
        let c = WallClock::new();
        let a = c.now_ns();
        let b = c.now_ns();
        assert!(b >= a, "monotonic reads: {b} < {a}");
    }

    #[test]
    fn virtual_clock_moves_only_when_advanced() {
        let c = VirtualClock::new();
        assert_eq!(c.now_ns(), 0);
        assert_eq!(c.now_ns(), 0, "reads do not advance virtual time");
        c.advance_ticks(3);
        assert_eq!(c.now_ns(), 3 * TICK_NS);
        c.advance_ns(7);
        assert_eq!(c.now_ns(), 3 * TICK_NS + 7);
    }

    #[test]
    fn virtual_clock_shared_through_trait_object() {
        let c: std::sync::Arc<VirtualClock> = std::sync::Arc::new(VirtualClock::new());
        let dynref: std::sync::Arc<dyn Clock> = c.clone();
        c.advance_ticks(1);
        assert_eq!(dynref.now_ns(), TICK_NS);
    }
}
