//! Per-request span timelines and the bounded ring of recent traces.
//!
//! A [`Span`] is the preallocated timeline embedded in a scheduler slot:
//! recording a phase transition is a plain `u64` store into a field that
//! already exists, so the decode hot path allocates nothing per step. When
//! a request retires, the span plus its identity/outcome is frozen into a
//! [`Trace`] (one `String` clone, once per request) and pushed into the
//! scheduler's [`TraceRing`], where the `"cmd":"stats"` wire request and
//! `bench serving` read it back (rust/docs/observability.md § Spans).

use std::collections::VecDeque;

use crate::json::{self, Value};

/// Nanosecond stamps for one request's lifecycle, in clock order:
/// `enqueued → admitted (prefill starts) → first_token → retired`.
/// A stamp of 0 means the phase was never reached (except `enqueued_ns`,
/// which may legitimately be 0 at a virtual clock's origin).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Span {
    /// When the request entered the admission queue.
    pub enqueued_ns: u64,
    /// When a lane admitted it (prefill begins immediately after).
    pub admitted_ns: u64,
    /// When the first output byte was emitted (0 = no output).
    pub first_token_ns: u64,
    /// When the slot retired (finish, failure, or drain).
    pub retired_ns: u64,
    /// Came back through the queue after a shared-batch demotion.
    pub demoted: bool,
    /// Session state was resurrected from the store (no re-prefill).
    pub resurrected: bool,
}

impl Span {
    /// A span for a request admitted `admitted_ns` after being queued at
    /// `enqueued_ns`; later stamps start unset.
    pub fn started(enqueued_ns: u64, admitted_ns: u64) -> Span {
        Span { enqueued_ns, admitted_ns, ..Span::default() }
    }
    /// Queue-phase duration (submit → admit).
    pub fn queued_ns(&self) -> u64 {
        self.admitted_ns.saturating_sub(self.enqueued_ns)
    }
    /// Time to first token (submit → first output byte); 0 if no output.
    pub fn ttft_ns(&self) -> u64 {
        if self.first_token_ns == 0 {
            0
        } else {
            self.first_token_ns.saturating_sub(self.enqueued_ns)
        }
    }
    /// Resident decode time after the first token; 0 if no output.
    pub fn decode_ns(&self) -> u64 {
        if self.first_token_ns == 0 {
            0
        } else {
            self.retired_ns.saturating_sub(self.first_token_ns)
        }
    }
    /// Submit → retire.
    pub fn total_ns(&self) -> u64 {
        self.retired_ns.saturating_sub(self.enqueued_ns)
    }
}

/// A retired request's frozen timeline plus identity and outcome — the
/// unit the [`TraceRing`] stores and `"cmd":"stats"` returns.
#[derive(Debug, Clone, PartialEq)]
pub struct Trace {
    /// Scheduler-assigned request id.
    pub id: u64,
    /// Adapter the request ran under.
    pub adapter: String,
    /// Prompt length in bytes.
    pub prompt_len: usize,
    /// Output bytes produced.
    pub new_tokens: usize,
    /// Decode steps this request was resident for.
    pub steps: u64,
    /// Admission attempts beyond the first (retry cascade).
    pub retries: u32,
    /// Finish label (`FinishReason::label`).
    pub finish: &'static str,
    /// The phase timeline.
    pub span: Span,
}

impl Trace {
    /// Serialize for `"cmd":"stats"` replies and `METRICS_serve.json`
    /// (schema: rust/docs/observability.md § Trace records).
    pub fn to_json(&self) -> Value {
        json::obj(vec![
            ("id", json::num(self.id as f64)),
            ("adapter", json::s(&self.adapter)),
            ("prompt_len", json::num(self.prompt_len as f64)),
            ("new_tokens", json::num(self.new_tokens as f64)),
            ("steps", json::num(self.steps as f64)),
            ("retries", json::num(f64::from(self.retries))),
            ("finish", json::s(self.finish)),
            ("demoted", Value::Bool(self.span.demoted)),
            ("resurrected", Value::Bool(self.span.resurrected)),
            ("enqueued_ns", json::num(self.span.enqueued_ns as f64)),
            ("admitted_ns", json::num(self.span.admitted_ns as f64)),
            ("first_token_ns", json::num(self.span.first_token_ns as f64)),
            ("retired_ns", json::num(self.span.retired_ns as f64)),
            ("queued_ns", json::num(self.span.queued_ns() as f64)),
            ("ttft_ns", json::num(self.span.ttft_ns() as f64)),
            ("decode_ns", json::num(self.span.decode_ns() as f64)),
            ("total_ns", json::num(self.span.total_ns() as f64)),
        ])
    }
}

/// A bounded ring of the most recent [`Trace`]s. Pushes past capacity
/// evict the oldest; `pushed()` counts every push ever, so a reader can
/// hold a cursor and fetch only what arrived since its last visit
/// ([`TraceRing::since`]).
pub struct TraceRing {
    cap: usize,
    buf: VecDeque<Trace>,
    pushed: u64,
}

impl TraceRing {
    /// A ring holding at most `cap` traces (clamped to ≥ 1).
    pub fn new(cap: usize) -> TraceRing {
        let cap = cap.max(1);
        TraceRing { cap, buf: VecDeque::with_capacity(cap), pushed: 0 }
    }
    /// Append, evicting the oldest past capacity.
    pub fn push(&mut self, t: Trace) {
        if self.buf.len() == self.cap {
            self.buf.pop_front();
        }
        self.buf.push_back(t);
        self.pushed += 1;
    }
    /// Traces currently resident.
    pub fn len(&self) -> usize {
        self.buf.len()
    }
    /// True when nothing has been retained.
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }
    /// Ring capacity.
    pub fn capacity(&self) -> usize {
        self.cap
    }
    /// Total pushes ever (the cursor space for [`TraceRing::since`]).
    pub fn pushed(&self) -> u64 {
        self.pushed
    }
    /// Oldest → newest.
    pub fn iter(&self) -> impl Iterator<Item = &Trace> {
        self.buf.iter()
    }
    /// The traces pushed after cursor `cursor` (a previous [`pushed`]
    /// reading) that are still resident; evicted ones are gone. Pass the
    /// current `pushed()` back as the next cursor.
    ///
    /// [`pushed`]: TraceRing::pushed
    pub fn since(&self, cursor: u64) -> impl Iterator<Item = &Trace> {
        let fresh = self.pushed.saturating_sub(cursor).min(self.buf.len() as u64) as usize;
        self.buf.iter().skip(self.buf.len() - fresh)
    }
    /// Serialize the resident traces oldest → newest.
    pub fn to_json(&self) -> Value {
        Value::Arr(self.buf.iter().map(Trace::to_json).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(id: u64) -> Trace {
        Trace {
            id,
            adapter: "a".into(),
            prompt_len: 4,
            new_tokens: 2,
            steps: 6,
            retries: 0,
            finish: "stop",
            span: Span {
                enqueued_ns: 10,
                admitted_ns: 30,
                first_token_ns: 70,
                retired_ns: 100,
                demoted: false,
                resurrected: false,
            },
        }
    }

    #[test]
    fn span_phase_durations() {
        let sp = t(1).span;
        assert_eq!(sp.queued_ns(), 20);
        assert_eq!(sp.ttft_ns(), 60);
        assert_eq!(sp.decode_ns(), 30);
        assert_eq!(sp.total_ns(), 90);
        let none = Span::started(5, 9);
        assert_eq!(none.queued_ns(), 4);
        assert_eq!(none.ttft_ns(), 0, "no first token → no TTFT");
        assert_eq!(none.decode_ns(), 0);
    }

    #[test]
    fn ring_evicts_oldest_and_counts_pushes() {
        let mut r = TraceRing::new(3);
        for id in 0..5 {
            r.push(t(id));
        }
        assert_eq!(r.len(), 3);
        assert_eq!(r.pushed(), 5);
        let ids: Vec<u64> = r.iter().map(|x| x.id).collect();
        assert_eq!(ids, vec![2, 3, 4], "oldest evicted first");
    }

    #[test]
    fn since_cursor_returns_only_fresh_traces() {
        let mut r = TraceRing::new(4);
        r.push(t(0));
        r.push(t(1));
        let cursor = r.pushed();
        assert_eq!(r.since(cursor).count(), 0);
        r.push(t(2));
        r.push(t(3));
        let fresh: Vec<u64> = r.since(cursor).map(|x| x.id).collect();
        assert_eq!(fresh, vec![2, 3]);
        // cursor older than anything resident: clamped to what survives
        let mut small = TraceRing::new(2);
        for id in 0..6 {
            small.push(t(id));
        }
        let all: Vec<u64> = small.since(0).map(|x| x.id).collect();
        assert_eq!(all, vec![4, 5], "evicted traces are not resurrected");
    }

    #[test]
    fn trace_json_round_trips() {
        let v = t(9).to_json();
        assert_eq!(v.path("id").unwrap().as_usize(), Some(9));
        assert_eq!(v.path("finish").unwrap().as_str(), Some("stop"));
        assert_eq!(v.path("ttft_ns").unwrap().as_usize(), Some(60));
        assert_eq!(v.path("demoted").unwrap().as_bool(), Some(false));
        let back = crate::json::parse(&crate::json::emit(&v)).unwrap();
        assert_eq!(back.path("queued_ns").unwrap().as_usize(), Some(20));
    }
}
