//! Serving observability: a lock-light metrics registry, per-request span
//! tracing behind a [`Clock`] trait, and the shared rate-guard helper.
//!
//! Design contract (rust/docs/observability.md):
//!
//! - **Registry** ([`Metrics`]): named counters, gauges, and fixed-log2-
//!   bucket histograms. Registration takes a short mutex once per name;
//!   the returned handles ([`Counter`], [`Gauge`], [`Hist`]) are plain
//!   `Arc`'d atomics, so recording on a hot path is a relaxed atomic op —
//!   no lock, no allocation. Snapshots serialize every instrument in
//!   `BTreeMap` key order, so two registries with the same contents emit
//!   identical JSON.
//! - **Histograms** ([`Histogram`]): 64 deterministic log2 buckets
//!   (bucket 0 = {0}, bucket i = [2^(i−1), 2^i), top bucket open). Bucket
//!   edges are a pure function of the value, so merges are associative and
//!   parallel recording is order-independent.
//! - **Spans** ([`Span`], [`Trace`], [`TraceRing`]): see [`span`].
//! - **Clocks** ([`WallClock`], [`VirtualClock`]): see [`clock`]. This
//!   module sits in repolint's determinism scope; only the wall-clock
//!   lines carry waivers.

pub mod clock;
pub mod span;

pub use clock::{Clock, VirtualClock, WallClock, TICK_NS};
pub use span::{Span, Trace, TraceRing};

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::json::{self, Value};

/// `count / elapsed_s` with zero, negative, or non-finite elapsed time
/// clamped to a rate of 0.0 — the single shared guard for every
/// throughput/rate computation (`Response::tok_per_s`, `bench serving`
/// aggregation, snapshot summaries), so the div-zero class can't reappear
/// per call site.
pub fn rate_per_s(count: f64, elapsed_s: f64) -> f64 {
    if elapsed_s > 0.0 && elapsed_s.is_finite() {
        count / elapsed_s
    } else {
        0.0
    }
}

/// Number of log2 buckets in a [`Histogram`].
pub const HIST_BUCKETS: usize = 64;

/// A fixed-bucket log2 histogram over `u64` samples. Buckets are
/// deterministic: bucket 0 holds exactly {0}, bucket `i` (1 ≤ i < 63)
/// holds [2^(i−1), 2^i), and bucket 63 is open-ended from 2^62. All
/// recording is relaxed-atomic, so histograms can be shared across
/// threads; merge order never changes the result.
pub struct Histogram {
    count: AtomicU64,
    sum: AtomicU64,
    min: AtomicU64,
    max: AtomicU64,
    buckets: [AtomicU64; HIST_BUCKETS],
}

impl Histogram {
    /// An empty histogram.
    pub fn new() -> Histogram {
        Histogram {
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            min: AtomicU64::new(u64::MAX),
            max: AtomicU64::new(0),
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }
    /// The bucket index `v` lands in (pure; see the type docs).
    pub fn bucket_of(v: u64) -> usize {
        if v == 0 {
            0
        } else {
            (64 - v.leading_zeros() as usize).min(HIST_BUCKETS - 1)
        }
    }
    /// `[lo, hi)` bounds of bucket `i`; the top bucket reports
    /// `hi = u64::MAX` (open-ended).
    pub fn bucket_bounds(i: usize) -> (u64, u64) {
        if i == 0 {
            (0, 1)
        } else if i >= HIST_BUCKETS - 1 {
            (1u64 << 62, u64::MAX)
        } else {
            (1u64 << (i - 1), 1u64 << i)
        }
    }
    /// Record one sample.
    pub fn record(&self, v: u64) {
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.min.fetch_min(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
        self.buckets[Self::bucket_of(v)].fetch_add(1, Ordering::Relaxed);
    }
    /// Fold another histogram's samples in. Associative and commutative:
    /// `(a ⊕ b) ⊕ c` and `a ⊕ (b ⊕ c)` snapshot identically.
    pub fn merge_from(&self, other: &Histogram) {
        self.count.fetch_add(other.count.load(Ordering::Relaxed), Ordering::Relaxed);
        self.sum.fetch_add(other.sum.load(Ordering::Relaxed), Ordering::Relaxed);
        self.min.fetch_min(other.min.load(Ordering::Relaxed), Ordering::Relaxed);
        self.max.fetch_max(other.max.load(Ordering::Relaxed), Ordering::Relaxed);
        for (mine, theirs) in self.buckets.iter().zip(other.buckets.iter()) {
            mine.fetch_add(theirs.load(Ordering::Relaxed), Ordering::Relaxed);
        }
    }
    /// Samples recorded.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }
    /// Sum of all samples.
    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }
    /// Smallest sample (0 when empty).
    pub fn min(&self) -> u64 {
        let m = self.min.load(Ordering::Relaxed);
        if m == u64::MAX {
            0
        } else {
            m
        }
    }
    /// Largest sample (0 when empty).
    pub fn max(&self) -> u64 {
        self.max.load(Ordering::Relaxed)
    }
    /// Bucket-resolution quantile: the inclusive upper edge of the bucket
    /// containing the q-th sample, clamped to the observed max (exact for
    /// the distributions the log2 edges can represent; `bench serving`
    /// computes exact percentiles from raw samples instead).
    pub fn quantile(&self, q: f64) -> u64 {
        let count = self.count();
        if count == 0 {
            return 0;
        }
        let rank = ((q.clamp(0.0, 1.0) * count as f64).ceil() as u64).clamp(1, count);
        let mut cum = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            cum += b.load(Ordering::Relaxed);
            if cum >= rank {
                let (_, hi) = Self::bucket_bounds(i);
                return hi.saturating_sub(1).min(self.max());
            }
        }
        self.max()
    }
    /// Snapshot: count/sum/min/max, p50/p95/p99, and the non-empty
    /// buckets as `[lower_edge, count]` pairs in edge order.
    pub fn to_json(&self) -> Value {
        let mut buckets = Vec::new();
        for (i, b) in self.buckets.iter().enumerate() {
            let c = b.load(Ordering::Relaxed);
            if c > 0 {
                buckets.push(Value::Arr(vec![
                    json::num(Self::bucket_bounds(i).0 as f64),
                    json::num(c as f64),
                ]));
            }
        }
        json::obj(vec![
            ("count", json::num(self.count() as f64)),
            ("sum", json::num(self.sum() as f64)),
            ("min", json::num(self.min() as f64)),
            ("max", json::num(self.max() as f64)),
            ("p50", json::num(self.quantile(0.50) as f64)),
            ("p95", json::num(self.quantile(0.95) as f64)),
            ("p99", json::num(self.quantile(0.99) as f64)),
            ("buckets", Value::Arr(buckets)),
        ])
    }
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram::new()
    }
}

/// A monotonically increasing counter handle (relaxed atomic; clone-cheap).
#[derive(Clone)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    /// Add 1.
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }
    /// Add `n`.
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }
    /// Overwrite with an externally tracked absolute value (used when
    /// republishing pre-existing counters into the registry).
    pub fn set(&self, n: u64) {
        self.0.store(n, Ordering::Relaxed);
    }
    /// Current value.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A last-value-wins gauge handle (relaxed atomic; clone-cheap).
#[derive(Clone)]
pub struct Gauge(Arc<AtomicU64>);

impl Gauge {
    /// Overwrite the gauge.
    pub fn set(&self, n: u64) {
        self.0.store(n, Ordering::Relaxed);
    }
    /// Current value.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A histogram handle (clone-cheap; see [`Histogram`]).
#[derive(Clone)]
pub struct Hist(Arc<Histogram>);

impl Hist {
    /// Record one sample.
    pub fn record(&self, v: u64) {
        self.0.record(v);
    }
    /// The shared histogram.
    pub fn inner(&self) -> &Histogram {
        &self.0
    }
}

#[derive(Default)]
struct Tables {
    counters: BTreeMap<String, Arc<AtomicU64>>,
    gauges: BTreeMap<String, Arc<AtomicU64>>,
    hists: BTreeMap<String, Arc<Histogram>>,
}

/// The metrics registry: named instruments, registered under a short
/// mutex, recorded lock-free through their handles, snapshotted to
/// key-ordered JSON. Two registries fed the same values emit identical
/// snapshots (`BTreeMap` ordering end to end).
pub struct Metrics {
    tables: Mutex<Tables>,
}

impl Metrics {
    /// An empty registry.
    pub fn new() -> Metrics {
        Metrics { tables: Mutex::new(Tables::default()) }
    }
    fn lock(&self) -> std::sync::MutexGuard<'_, Tables> {
        self.tables.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
    }
    /// The counter named `name`, created on first use. Same name → same
    /// underlying atomic, from any thread.
    pub fn counter(&self, name: &str) -> Counter {
        Counter(self.lock().counters.entry(name.to_string()).or_default().clone())
    }
    /// The gauge named `name`, created on first use.
    pub fn gauge(&self, name: &str) -> Gauge {
        Gauge(self.lock().gauges.entry(name.to_string()).or_default().clone())
    }
    /// The histogram named `name`, created on first use.
    pub fn histogram(&self, name: &str) -> Hist {
        Hist(self.lock().hists.entry(name.to_string()).or_default().clone())
    }
    /// Snapshot every instrument as
    /// `{"counters": {...}, "gauges": {...}, "histograms": {...}}`,
    /// keys sorted (deterministic emission).
    pub fn snapshot(&self) -> Value {
        let t = self.lock();
        let counters = Value::Obj(
            t.counters
                .iter()
                .map(|(k, v)| (k.clone(), json::num(v.load(Ordering::Relaxed) as f64)))
                .collect(),
        );
        let gauges = Value::Obj(
            t.gauges
                .iter()
                .map(|(k, v)| (k.clone(), json::num(v.load(Ordering::Relaxed) as f64)))
                .collect(),
        );
        let hists =
            Value::Obj(t.hists.iter().map(|(k, h)| (k.clone(), h.to_json())).collect());
        json::obj(vec![
            ("counters", counters),
            ("gauges", gauges),
            ("histograms", hists),
        ])
    }
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    #[test]
    fn rate_guard_clamps_degenerate_elapsed() {
        assert_eq!(rate_per_s(10.0, 2.0), 5.0);
        assert_eq!(rate_per_s(10.0, 0.0), 0.0, "zero elapsed");
        assert_eq!(rate_per_s(10.0, -1.0), 0.0, "negative elapsed");
        assert_eq!(rate_per_s(10.0, f64::NAN), 0.0, "NaN elapsed");
        assert_eq!(rate_per_s(10.0, f64::INFINITY), 0.0, "infinite elapsed");
        assert_eq!(rate_per_s(0.0, 5.0), 0.0);
    }

    #[test]
    fn bucket_edges_are_deterministic_and_cover_u64() {
        // property: every sample lands in exactly the bucket whose bounds
        // contain it, across seeded random draws and the edge values
        let mut rng = Rng::new(41);
        let mut samples: Vec<u64> = (0..2000).map(|_| rng.next_u64()).collect();
        samples.extend([0, 1, 2, 3, 4, u64::MAX, u64::MAX - 1]);
        for i in 0..63 {
            samples.push(1u64 << i);
            samples.push((1u64 << i) + 1);
            samples.push((1u64 << i) - 1);
        }
        for &v in &samples {
            let b = Histogram::bucket_of(v);
            let (lo, hi) = Histogram::bucket_bounds(b);
            assert!(v >= lo, "{v} below bucket {b} lower edge {lo}");
            if b < HIST_BUCKETS - 1 {
                assert!(v < hi, "{v} at/above bucket {b} upper edge {hi}");
            }
        }
        // edges partition: bucket i's hi is bucket i+1's lo (below the top)
        for i in 1..HIST_BUCKETS - 2 {
            assert_eq!(Histogram::bucket_bounds(i).1, Histogram::bucket_bounds(i + 1).0);
        }
    }

    #[test]
    fn histogram_merge_is_associative() {
        let mut rng = Rng::new(17);
        let parts: Vec<Vec<u64>> =
            (0..3).map(|_| (0..500).map(|_| rng.next_u64() >> (rng.next_u64() % 40)).collect()).collect();
        let fill = |vals: &[u64]| {
            let h = Histogram::new();
            for &v in vals {
                h.record(v);
            }
            h
        };
        // (a ⊕ b) ⊕ c
        let left = fill(&parts[0]);
        left.merge_from(&fill(&parts[1]));
        left.merge_from(&fill(&parts[2]));
        // a ⊕ (b ⊕ c)
        let bc = fill(&parts[1]);
        bc.merge_from(&fill(&parts[2]));
        let right = fill(&parts[0]);
        right.merge_from(&bc);
        // flat recording of everything
        let flat = fill(&parts.concat());
        assert_eq!(json::emit(&left.to_json()), json::emit(&right.to_json()));
        assert_eq!(json::emit(&left.to_json()), json::emit(&flat.to_json()));
    }

    #[test]
    fn histogram_summary_and_quantiles() {
        let h = Histogram::new();
        assert_eq!(h.quantile(0.5), 0, "empty histogram");
        assert_eq!(h.min(), 0);
        for v in [1u64, 2, 3, 100, 1000] {
            h.record(v);
        }
        assert_eq!(h.count(), 5);
        assert_eq!(h.sum(), 1106);
        assert_eq!(h.min(), 1);
        assert_eq!(h.max(), 1000);
        assert!(h.quantile(0.5) >= 3, "median at least the 3rd sample's bucket");
        assert_eq!(h.quantile(1.0), 1000, "p100 clamps to the observed max");
        let v = h.to_json();
        assert_eq!(v.path("count").unwrap().as_usize(), Some(5));
        assert!(!v.path("buckets").unwrap().as_arr().unwrap().is_empty());
    }

    #[test]
    fn registry_handles_share_state_and_snapshot_is_ordered() {
        let m = Metrics::new();
        let c1 = m.counter("sched.decode_steps");
        let c2 = m.counter("sched.decode_steps");
        c1.inc();
        c2.add(4);
        assert_eq!(c1.get(), 5, "same name, same atomic");
        m.gauge("sched.idle_ticks").set(7);
        m.histogram("serve.ttft_ns").record(1500);
        let snap = json::emit(&m.snapshot());
        let again = json::emit(&m.snapshot());
        assert_eq!(snap, again, "snapshots are stable");
        let v = json::parse(&snap).unwrap();
        // instrument names contain dots, so index with get(), not path()
        let counters = v.path("counters").unwrap();
        assert_eq!(counters.get("sched.decode_steps").unwrap().as_usize(), Some(5));
        let gauges = v.path("gauges").unwrap();
        assert_eq!(gauges.get("sched.idle_ticks").unwrap().as_usize(), Some(7));
        let hists = v.path("histograms").unwrap();
        assert_eq!(
            hists.get("serve.ttft_ns").unwrap().get("count").unwrap().as_usize(),
            Some(1)
        );
        // two registries fed identically emit identical bytes
        let m2 = Metrics::new();
        m2.counter("sched.decode_steps").add(5);
        m2.gauge("sched.idle_ticks").set(7);
        m2.histogram("serve.ttft_ns").record(1500);
        assert_eq!(snap, json::emit(&m2.snapshot()));
    }
}
