//! Minimal JSON parser/emitter.
//!
//! The offline build environment vendors only the `xla` crate's dependency
//! closure (no `serde`/`serde_json`), so the coordinator ships its own JSON
//! support. It covers the full JSON grammar (objects, arrays, strings with
//! escapes, numbers, bools, null) — enough for the AOT manifest, experiment
//! configs, and result files, all of which we also emit.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value. Objects use a BTreeMap for deterministic iteration
/// (result files diff cleanly run-to-run).
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (f64 — see the RunRecord seed caveat).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Value>),
    /// An object (sorted keys → deterministic emission).
    Obj(BTreeMap<String, Value>),
}

impl Value {
    /// Object field lookup (None for non-objects).
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }
    /// The string payload, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    /// The numeric payload, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }
    /// The number truncated to usize, if this is a number.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    /// The boolean payload, if this is a bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    /// The element slice, if this is an array.
    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }
    /// Convenience: `v.path("files.step")`.
    pub fn path(&self, dotted: &str) -> Option<&Value> {
        let mut cur = self;
        for part in dotted.split('.') {
            cur = cur.get(part)?;
        }
        Some(cur)
    }
}

/// Parse a complete JSON document (trailing data is an error).
pub fn parse(src: &str) -> Result<Value, String> {
    let mut p = Parser { b: src.as_bytes(), i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        return Err(format!("trailing data at byte {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && (self.b[self.i] as char).is_ascii_whitespace() {
            self.i += 1;
        }
    }
    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }
    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }
    fn value(&mut self) -> Result<Value, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(_) => self.number(),
            None => Err("unexpected end of input".into()),
        }
    }
    fn lit(&mut self, s: &str, v: Value) -> Result<Value, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }
    fn number(&mut self) -> Result<Value, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Value::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }
    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or("bad escape")?;
                    self.i += 1;
                    match c {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| "bad \\u")?;
                            let cp = u32::from_str_radix(hex, 16).map_err(|_| "bad \\u")?;
                            self.i += 4;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(format!("bad escape \\{}", c as char)),
                    }
                }
                Some(_) => {
                    // copy one UTF-8 scalar
                    let s = std::str::from_utf8(&self.b[self.i..]).map_err(|_| "bad utf8")?;
                    let Some(ch) = s.chars().next() else {
                        return Err("bad utf8".into());
                    };
                    out.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }
    fn array(&mut self) -> Result<Value, String> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Value::Arr(items));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }
    fn object(&mut self) -> Result<Value, String> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            map.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Value::Obj(map));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

/// Serialize a value to compact JSON.
pub fn emit(v: &Value) -> String {
    let mut s = String::new();
    write_val(v, &mut s);
    s
}

fn write_val(v: &Value, out: &mut String) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Value::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                let _ = write!(out, "{}", *n as i64);
            } else {
                let _ = write!(out, "{n}");
            }
        }
        Value::Str(s) => {
            out.push('"');
            for c in s.chars() {
                match c {
                    '"' => out.push_str("\\\""),
                    '\\' => out.push_str("\\\\"),
                    '\n' => out.push_str("\\n"),
                    '\t' => out.push_str("\\t"),
                    '\r' => out.push_str("\\r"),
                    c if (c as u32) < 0x20 => {
                        let _ = write!(out, "\\u{:04x}", c as u32);
                    }
                    c => out.push(c),
                }
            }
            out.push('"');
        }
        Value::Arr(a) => {
            out.push('[');
            for (i, x) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_val(x, out);
            }
            out.push(']');
        }
        Value::Obj(m) => {
            out.push('{');
            for (i, (k, x)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_val(&Value::Str(k.clone()), out);
                out.push(':');
                write_val(x, out);
            }
            out.push('}');
        }
    }
}

/// Builder helpers for emitting result files.
pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
    Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}
/// Shorthand: a JSON number.
pub fn num(n: f64) -> Value {
    Value::Num(n)
}
/// Shorthand: a JSON string.
pub fn s(v: &str) -> Value {
    Value::Str(v.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse(" null ").unwrap(), Value::Null);
        assert_eq!(parse("-1.5e2").unwrap(), Value::Num(-150.0));
        assert_eq!(parse("\"a\\nb\"").unwrap(), Value::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.path("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.path("a").unwrap().as_arr().unwrap()[2].path("b").unwrap().as_str(),
            Some("x")
        );
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"name":"m1","shape":[2,3],"ok":true,"x":1.25,"s":"q\"t"}"#;
        let v = parse(src).unwrap();
        let v2 = parse(&emit(&v)).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(parse("\"\\u0041\"").unwrap(), Value::Str("A".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("1 2").is_err());
    }

    #[test]
    fn deep_path() {
        let v = parse(r#"{"a":{"b":{"c":7}}}"#).unwrap();
        assert_eq!(v.path("a.b.c").unwrap().as_f64(), Some(7.0));
    }
}
