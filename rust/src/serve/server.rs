//! The `serve` front end: a line-delimited JSON request loop over
//! stdin/stdout and/or TCP, driving the continuous-batching
//! [`Scheduler`] against an [`AdapterRegistry`], with per-request
//! latency/throughput stats streamed as RunRecord-style JSONL.
//!
//! Request/response schema and a worked example live in
//! `rust/docs/serving.md`. One request per line in; one response per line
//! out (to the connection that sent the request); one stats record per
//! line appended to `results/<name>.jsonl`.

use std::collections::HashMap;
use std::io::{BufRead, Write as _};
use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};

use crate::{bail, err};
use crate::error::{Context, Result};

use crate::coordinator::Pipeline;
use crate::eval::{AdapterStepDecode, DecodeCore};
use crate::json::{self, Value};
use crate::manifest::Manifest;
use crate::runtime::Engine;
use crate::suite::{git_describe, JsonlSink};

use super::registry::{AdapterRegistry, ManifestSource};
use super::scheduler::{
    LaneModel, Request, Response, Scheduler, ServeFactory, ServeModel,
};

/// `serve` subcommand configuration (CLI `key=value` overrides — see
/// [`ServeOptions::from_kvs`]).
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Architecture of the staged base (every adapter must target it).
    pub arch: String,
    /// Pretraining steps used to stage (or load) the shared base.
    pub pretrain_steps: usize,
    /// Adapter LRU cache capacity ([`AdapterRegistry`]).
    pub cache_cap: usize,
    /// Max simultaneously materialized scheduler lanes.
    pub max_lanes: usize,
    /// TCP listen address (e.g. "127.0.0.1:7878"); `None` = no TCP.
    pub addr: Option<String>,
    /// Serve the stdin/stdout request loop.
    pub stdin: bool,
    /// Default `max_new` when a request omits it.
    pub default_max_new: usize,
    /// Stats stream name: records append to `results/<name>.jsonl`.
    pub stats_name: String,
    /// Directory searched for `<variant>.ckpt` trained adapters.
    pub adapter_dir: Option<PathBuf>,
    /// Default per-request deadline in scheduler ticks (0 = none), used
    /// when a request omits `deadline`.
    pub deadline: usize,
    /// Spill directory for the durable session store (`None` = memory-only
    /// tier; default: the `SSM_PEFT_SESSIONS_DIR` knob).
    pub sessions_dir: Option<PathBuf>,
    /// In-memory session LRU capacity (default: the
    /// `SSM_PEFT_SESSIONS_CAP` knob).
    pub sessions_cap: usize,
    /// Scheduler ticks a quarantined adapter waits before the circuit
    /// breaker goes half-open and admits one probation trial load
    /// (0 = operator-only reinstatement).
    pub probation_ticks: u32,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            arch: "mamba1_xs".into(),
            pretrain_steps: 300,
            cache_cap: 4,
            max_lanes: 4,
            addr: None,
            stdin: true,
            default_max_new: 48,
            stats_name: "serve".into(),
            adapter_dir: None,
            deadline: 0,
            sessions_dir: crate::knobs::sessions_dir(),
            sessions_cap: crate::knobs::sessions_cap(),
            probation_ticks: crate::serve::registry::DEFAULT_PROBATION_TICKS,
        }
    }
}

impl ServeOptions {
    /// Parse CLI `key=value` overrides: `arch`, `pretrain_steps`, `addr`,
    /// `stdin` (0/1), `cache`, `lanes`, `max_new`, `name`, `adapter_dir`,
    /// `deadline`, `sessions_dir`, `sessions_cap`, `probation_ticks`.
    pub fn from_kvs(kvs: &std::collections::BTreeMap<String, String>) -> Result<ServeOptions> {
        let mut o = ServeOptions::default();
        for (k, v) in kvs {
            match k.as_str() {
                "arch" => o.arch = v.clone(),
                "pretrain_steps" => o.pretrain_steps = v.parse().context("pretrain_steps")?,
                "addr" => o.addr = Some(v.clone()),
                "stdin" => o.stdin = v != "0" && v != "false",
                "cache" => o.cache_cap = v.parse().context("cache")?,
                "lanes" => o.max_lanes = v.parse().context("lanes")?,
                "max_new" => o.default_max_new = v.parse().context("max_new")?,
                "name" => o.stats_name = v.clone(),
                "adapter_dir" => o.adapter_dir = Some(PathBuf::from(v)),
                "deadline" => o.deadline = v.parse().context("deadline")?,
                "sessions_dir" => o.sessions_dir = Some(PathBuf::from(v)),
                "sessions_cap" => o.sessions_cap = v.parse().context("sessions_cap")?,
                "probation_ticks" => {
                    o.probation_ticks = v.parse().context("probation_ticks")?
                }
                other => bail!("unknown serve option {other:?}"),
            }
        }
        if !o.stdin && o.addr.is_none() {
            bail!("serve needs stdin=1 or addr=<host:port> (or both)");
        }
        Ok(o)
    }
}

/// Where a response line goes back to.
#[derive(Clone)]
enum Sink {
    Stdout,
    Tcp(Arc<Mutex<TcpStream>>),
}

impl Sink {
    fn send(&self, line: &str) {
        match self {
            Sink::Stdout => {
                let mut out = std::io::stdout().lock();
                let _ = writeln!(out, "{line}");
                let _ = out.flush();
            }
            Sink::Tcp(conn) => {
                if let Ok(mut c) = conn.lock() {
                    let _ = writeln!(c, "{line}");
                    let _ = c.flush();
                }
            }
        }
    }
}

/// A parsed request line (client id not yet bound to a scheduler id).
struct WireRequest {
    client_id: Value,
    adapter: String,
    prompt: Vec<u8>,
    max_new: usize,
    stop_byte: u8,
    beam: usize,
    /// Per-request deadline override in ticks; `None` falls back to
    /// [`ServeOptions::deadline`].
    deadline: Option<usize>,
    /// Durable session id: the conversation this request continues. The
    /// prompt must carry the FULL history (prior turns' prompt + output +
    /// new bytes) — the stored state only proves it can skip the prefix
    /// it already absorbed (rust/docs/serving.md § Sessions).
    session: Option<String>,
}

const REQUEST_KEYS: &[&str] =
    &["id", "adapter", "prompt", "max_new", "stop", "beam", "deadline", "session"];

/// Allowed keys of a `{"cmd": ...}` control line.
const COMMAND_KEYS: &[&str] = &["cmd", "id"];

/// Detect a control line. `None` = not a command (a normal generation
/// request, or not JSON — both handled by [`parse_request`]).
/// `Some(Ok(client_id))` = a well-formed `{"cmd": "stats"}` line;
/// `Some(Err(_))` = a command with an unknown `cmd` or extra keys —
/// rejected loudly, mirroring the request contract
/// (rust/docs/serving.md § Stats).
fn parse_stats_command(line: &str) -> Option<Result<Value>> {
    let v = json::parse(line).ok()?;
    let obj = match &v {
        Value::Obj(m) => m,
        _ => return None,
    };
    let cmd = obj.get("cmd")?.clone();
    let parsed = (|| {
        let cmd = cmd.as_str().ok_or_else(|| err!("cmd: expected string"))?;
        if cmd != "stats" {
            bail!("unknown cmd {cmd:?} (expected \"stats\")");
        }
        for k in obj.keys() {
            if !COMMAND_KEYS.contains(&k.as_str()) {
                bail!("unknown command key {k:?} (expected one of {COMMAND_KEYS:?})");
            }
        }
        Ok(obj.get("id").cloned().unwrap_or(Value::Null))
    })();
    Some(parsed)
}

fn parse_request(line: &str, default_max_new: usize) -> Result<WireRequest> {
    let v = json::parse(line).map_err(|e| err!("bad request JSON: {e}"))?;
    let obj = match &v {
        Value::Obj(m) => m,
        _ => bail!("request must be a JSON object"),
    };
    for k in obj.keys() {
        if !REQUEST_KEYS.contains(&k.as_str()) {
            bail!("unknown request key {k:?} (expected one of {REQUEST_KEYS:?})");
        }
    }
    let adapter = obj
        .get("adapter")
        .and_then(Value::as_str)
        .ok_or_else(|| err!("request missing \"adapter\" (string)"))?
        .to_string();
    let prompt = obj
        .get("prompt")
        .and_then(Value::as_str)
        .ok_or_else(|| err!("request missing \"prompt\" (string)"))?
        .as_bytes()
        .to_vec();
    let max_new = match obj.get("max_new") {
        Some(n) => n.as_usize().ok_or_else(|| err!("max_new: expected number"))?,
        None => default_max_new,
    };
    let stop_byte = match obj.get("stop") {
        None => b'\n',
        Some(s) => {
            let s = s.as_str().ok_or_else(|| err!("stop: expected 1-byte string"))?;
            match s.as_bytes() {
                [b] => *b,
                _ => bail!("stop: expected exactly one byte, got {s:?}"),
            }
        }
    };
    let beam = match obj.get("beam") {
        Some(n) => n.as_usize().ok_or_else(|| err!("beam: expected number"))?.max(1),
        None => 1,
    };
    let deadline = match obj.get("deadline") {
        Some(n) => Some(n.as_usize().ok_or_else(|| err!("deadline: expected number"))?),
        None => None,
    };
    let session = match obj.get("session") {
        None | Some(Value::Null) => None,
        Some(s) => {
            let s = s.as_str().ok_or_else(|| err!("session: expected string"))?;
            if s.is_empty() {
                bail!("session: expected a non-empty id");
            }
            Some(s.to_string())
        }
    };
    Ok(WireRequest {
        client_id: obj.get("id").cloned().unwrap_or(Value::Null),
        adapter,
        prompt,
        max_new,
        stop_byte,
        beam,
        deadline,
        session,
    })
}

/// The response line sent back to the client.
fn response_json(resp: &Response, client_id: &Value) -> Value {
    json::obj(vec![
        ("id", client_id.clone()),
        ("adapter", json::s(&resp.adapter)),
        (
            "session",
            match &resp.session {
                Some(s) => json::s(s),
                None => Value::Null,
            },
        ),
        ("output", json::s(&String::from_utf8_lossy(&resp.output))),
        ("prompt_len", json::num(resp.prompt_len as f64)),
        ("new_tokens", json::num(resp.output.len() as f64)),
        ("queued_s", json::num(resp.queued_s)),
        ("total_s", json::num(resp.total_s)),
        ("tok_per_s", json::num(resp.tok_per_s())),
        ("steps", json::num(resp.steps as f64)),
        ("retries", json::num(resp.retries as f64)),
        ("finish", json::s(resp.finish.label())),
        (
            "error",
            match &resp.error {
                Some(e) => json::s(e),
                None => Value::Null,
            },
        ),
    ])
}

/// One per-request stats record in the `results/<name>.jsonl` stream —
/// RunRecord-style: self-describing, one JSON object per line, git-stamped
/// (schema: rust/docs/serving.md).
pub struct ServeRecord<'a> {
    /// Stats stream name ([`ServeOptions::stats_name`]).
    pub serve: &'a str,
    /// The finished request.
    pub resp: &'a Response,
    /// `git describe` stamp.
    pub git: &'a str,
}

impl ServeRecord<'_> {
    /// Serialize for the JSONL stream.
    pub fn to_json(&self) -> Value {
        json::obj(vec![
            ("serve", json::s(self.serve)),
            ("id", json::num(self.resp.id as f64)),
            ("adapter", json::s(&self.resp.adapter)),
            (
                "session",
                match &self.resp.session {
                    Some(s) => json::s(s),
                    None => Value::Null,
                },
            ),
            ("prompt_len", json::num(self.resp.prompt_len as f64)),
            ("new_tokens", json::num(self.resp.output.len() as f64)),
            ("queued_s", json::num(self.resp.queued_s)),
            ("total_s", json::num(self.resp.total_s)),
            ("tok_per_s", json::num(self.resp.tok_per_s())),
            ("steps", json::num(self.resp.steps as f64)),
            ("retries", json::num(self.resp.retries as f64)),
            ("finish", json::s(self.resp.finish.label())),
            (
                "error",
                match &self.resp.error {
                    Some(e) => json::s(e),
                    None => Value::Null,
                },
            ),
            ("git", json::s(self.git)),
        ])
    }
}

/// Run the serving loop until every request source closes (stdin EOF with
/// no TCP listener) — with a TCP listener the loop runs until killed.
///
/// Stages the shared pretrained base once, then serves adapters through
/// the LRU registry and the continuous-batching scheduler. Every response
/// goes back to its originating connection; every finished request appends
/// a [`ServeRecord`] to `results/<stats_name>.jsonl`.
pub fn run(engine: &Engine, manifest: &Manifest, opts: &ServeOptions) -> Result<()> {
    // fail fast on malformed SSM_PEFT_* values instead of serving with
    // silently defaulted knobs (the accessors also warn once per knob)
    crate::knobs::validate()?;
    let pipeline = Pipeline::new(engine, manifest);
    eprintln!("[serve] staging base {} ({} steps)", opts.arch, opts.pretrain_steps);
    let base = pipeline.pretrained(&opts.arch, opts.pretrain_steps, 0)?;
    let source = ManifestSource {
        manifest,
        base_arch: opts.arch.clone(),
        base: base.clone(),
        adapter_dir: opts.adapter_dir.clone(),
    };
    // seeded fault injection, active only when the fault knobs ask for it
    // (rust/docs/robustness.md); production runs with `None` everywhere
    let fault_plan = crate::fault::FaultPlan::from_env().map(Arc::new);
    if fault_plan.is_some() {
        eprintln!("[serve] fault injection active (seeded from the fault knobs)");
    }
    let mut registry = AdapterRegistry::new(source, opts.cache_cap);
    if let Some(p) = &fault_plan {
        registry.set_fault_inject(p.clone());
    }
    registry.set_probation_ticks(opts.probation_ticks);
    let registry = registry;
    // the unmerged multi-adapter core: ONE executable bound to the plain
    // base, stepping a mixed-adapter batch with per-row deltas. When it
    // can't be built (e.g. unknown decode variant) every adapter falls
    // back to merged per-adapter lanes.
    let decode_variant = format!("{}_full", opts.arch);
    let shared_core: Option<Arc<DecodeCore>> =
        match DecodeCore::new_unmerged(engine, manifest, &decode_variant, base.clone()) {
            Ok(mut core) => {
                eprintln!(
                    "[serve] unmerged multi-adapter decode ready ({})",
                    if core.has_adapter_artifact() {
                        "decode_adapters artifact"
                    } else {
                        "grouped host fallback"
                    }
                );
                if let Some(p) = &fault_plan {
                    core.set_fault_inject(p.clone());
                }
                Some(Arc::new(core))
            }
            Err(e) => {
                eprintln!("[serve] unmerged decode unavailable ({e:#}); merged lanes only");
                None
            }
        };
    let factory: ServeFactory = Box::new(|adapter: &str| {
        let a = registry.get(adapter)?;
        if let (Some(core), Some(delta)) = (&shared_core, &a.delta) {
            // pin for the lifetime of the scheduler's hold on this delta;
            // released through the on_release hook below
            registry.pin(adapter);
            let model: Arc<dyn AdapterStepDecode> = core.clone();
            return Ok(ServeModel::Shared {
                model,
                delta: Some(delta.clone()),
                h0: a.h0.clone(),
            });
        }
        // unrepresentable delta (or no unmerged core): merge on demand
        let params = registry.load_merged(adapter)?;
        let core = DecodeCore::new(engine, manifest, &a.decode_variant, &params)?;
        Ok(ServeModel::Merged(LaneModel { model: Arc::new(core), h0: a.h0.clone() }))
    });
    let mut sched = Scheduler::new(factory, opts.max_lanes);
    sched.on_release(Box::new(|adapter: &str| registry.unpin(adapter)));
    // every scheduler tick ages open circuits toward half-open probation
    sched.on_tick(Box::new(|| registry.note_tick()));
    if let Some(p) = &fault_plan {
        sched.set_fault_inject(p.clone());
    }
    // terminal per-adapter step failures feed the registry's circuit
    // breaker; past the threshold the adapter is rejected at admission
    sched.on_adapter_failure(Box::new(|adapter: &str, _kind| {
        if registry.record_failure(adapter) {
            eprintln!("[serve] adapter {adapter:?} quarantined after repeated failures");
        }
    }));
    // demotion target for shared-batch rows after a terminal shared step
    // failure: a dedicated merged lane (rung two of the cascade)
    sched.set_merged_fallback(Box::new(|adapter: &str| {
        let a = registry.get(adapter)?;
        let params = registry.load_merged(adapter)?;
        let core = DecodeCore::new(engine, manifest, &a.decode_variant, &params)?;
        Ok(LaneModel { model: Arc::new(core), h0: a.h0.clone() })
    }));
    // the durable session store: memory LRU + optional spill dir, with a
    // startup recovery scan that quarantines anything corrupt
    let sessions = {
        let mut store = super::sessions::SessionStore::new(opts.sessions_cap);
        if let Some(dir) = &opts.sessions_dir {
            store = store.with_dir(dir);
        }
        if let Some(p) = &fault_plan {
            store = store.with_faults(p.clone());
        }
        Arc::new(store)
    };
    if let Some(dir) = &opts.sessions_dir {
        let rep = sessions.recover();
        eprintln!(
            "[serve] session store at {} ({} records recovered, {} quarantined, \
             {} temp files swept)",
            dir.display(), rep.valid, rep.quarantined, rep.removed_tmp,
        );
    }
    sched.set_session_store(sessions.clone());

    let (tx, rx) = mpsc::channel::<(String, Sink)>();
    if opts.stdin {
        let txs = tx.clone();
        std::thread::spawn(move || {
            let stdin = std::io::stdin();
            for line in stdin.lock().lines() {
                let Ok(line) = line else { break };
                if line.trim().is_empty() {
                    continue;
                }
                if txs.send((line, Sink::Stdout)).is_err() {
                    break;
                }
            }
        });
    }
    if let Some(addr) = &opts.addr {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        eprintln!("[serve] listening on {addr}");
        let txa = tx.clone();
        std::thread::spawn(move || {
            for conn in listener.incoming() {
                let Ok(conn) = conn else { continue };
                let tx = txa.clone();
                std::thread::spawn(move || {
                    let Ok(read_half) = conn.try_clone() else { return };
                    let sink = Sink::Tcp(Arc::new(Mutex::new(conn)));
                    for line in std::io::BufReader::new(read_half).lines() {
                        let Ok(line) = line else { break };
                        if line.trim().is_empty() {
                            continue;
                        }
                        if tx.send((line, sink.clone())).is_err() {
                            break;
                        }
                    }
                });
            }
        });
    }
    drop(tx); // the loop below exits once every reader thread is gone

    let git = git_describe();
    let mut stats = JsonlSink::create(&opts.stats_name, true)?;
    let mut inflight: HashMap<u64, (Value, Sink)> = HashMap::new();
    let mut next_id = 1u64;
    let mut served = 0usize;
    // the observability registry: scheduler/registry/session/fault counters
    // republished on demand, latency histograms fed from retired traces
    // (rust/docs/observability.md). With no stats consumer the only hot-
    // path cost is the span stamps the scheduler records anyway.
    let metrics = crate::obs::Metrics::new();
    let ttft_hist = metrics.histogram("serve.ttft_ns");
    let itl_hist = metrics.histogram("serve.itl_ns");
    let queued_hist = metrics.histogram("serve.queued_ns");
    let mut trace_cursor = 0u64;
    let publish_all = |sched: &Scheduler| {
        sched.publish_metrics(&metrics);
        registry.stats().publish(&metrics);
        sessions.stats().publish(&metrics);
        if let Some(p) = &fault_plan {
            p.publish(&metrics);
        }
        if let Some(core) = &shared_core {
            core.publish_metrics(&metrics);
        }
    };
    let mut ingest = |line: String, sink: Sink,
                      sched: &mut Scheduler, inflight: &mut HashMap<u64, (Value, Sink)>| {
        if let Some(cmd) = parse_stats_command(&line) {
            match cmd {
                Ok(client_id) => {
                    publish_all(sched);
                    let v = json::obj(vec![
                        ("id", client_id),
                        ("stats", metrics.snapshot()),
                        ("traces", sched.traces().to_json()),
                    ]);
                    sink.send(&json::emit(&v));
                }
                Err(e) => {
                    let v = json::obj(vec![
                        ("error", json::s(&format!("{e:#}"))),
                        ("finish", json::s("error")),
                    ]);
                    sink.send(&json::emit(&v));
                }
            }
            return;
        }
        match parse_request(&line, opts.default_max_new) {
            Ok(w) => {
                let id = next_id;
                next_id += 1;
                inflight.insert(id, (w.client_id, sink));
                sched.submit(Request {
                    id,
                    adapter: w.adapter,
                    prompt: w.prompt,
                    max_new: w.max_new,
                    stop_byte: w.stop_byte,
                    beam: w.beam,
                    deadline: w.deadline.unwrap_or(opts.deadline),
                    session: w.session,
                });
            }
            Err(e) => {
                let v = json::obj(vec![
                    ("error", json::s(&format!("{e:#}"))),
                    ("finish", json::s("error")),
                ]);
                sink.send(&json::emit(&v));
            }
        }
    };

    // parked backoff between unproductive ticks: a scheduler that has
    // work resident but makes no progress (lane cooldowns, probation
    // windows) used to busy-spin here; now it sleeps a bounded,
    // exponentially growing interval instead. Arriving requests are
    // still admitted on the very next tick after the sleep.
    let backoff_cap_us = crate::knobs::obs_idle_backoff_us();
    let mut idle_streak = 0u32;
    loop {
        if sched.is_idle() {
            // nothing to decode: block for the next request (or exit when
            // every source has hung up)
            match rx.recv() {
                Ok((line, sink)) => ingest(line, sink, &mut sched, &mut inflight),
                Err(_) => break,
            }
        } else if sched.last_tick_idle() && backoff_cap_us > 0 {
            idle_streak = idle_streak.saturating_add(1);
            let us = (1u64 << idle_streak.min(10)).min(backoff_cap_us);
            std::thread::sleep(std::time::Duration::from_micros(us));
        }
        while let Ok((line, sink)) = rx.try_recv() {
            ingest(line, sink, &mut sched, &mut inflight);
        }
        for resp in sched.tick() {
            let (client_id, sink) = inflight
                .remove(&resp.id)
                .unwrap_or((Value::Null, Sink::Stdout));
            sink.send(&json::emit(&response_json(&resp, &client_id)));
            stats
                .write_line(&ServeRecord { serve: &opts.stats_name, resp: &resp, git: &git }
                    .to_json())
                .ok();
            served += 1;
            eprintln!(
                "[serve] #{} {} {} {}B->{}B {:.3}s ({:.1} tok/s, {} queued, {} active)",
                resp.id,
                resp.adapter,
                resp.finish.label(),
                resp.prompt_len,
                resp.output.len(),
                resp.total_s,
                resp.tok_per_s(),
                sched.queued(),
                sched.active(),
            );
        }
        if !sched.last_tick_idle() {
            idle_streak = 0;
        }
        // fold this tick's retired traces into the latency histograms
        // (cursor-based: each trace is recorded exactly once)
        for t in sched.traces().since(trace_cursor) {
            queued_hist.record(t.span.queued_ns());
            if t.span.first_token_ns > 0 {
                ttft_hist.record(t.span.ttft_ns());
                if t.new_tokens >= 2 {
                    itl_hist.record(t.span.decode_ns() / (t.new_tokens as u64 - 1));
                }
            }
        }
        trace_cursor = sched.traces().pushed();
    }
    // graceful drain (stdin EOF / every source hung up): retire whatever
    // is still in flight — retirement persists its session snapshot —
    // then flush every resident session to a durable record
    let (rest, flushed, flush_failed) = sched.drain();
    for resp in rest {
        let (client_id, sink) = inflight
            .remove(&resp.id)
            .unwrap_or((Value::Null, Sink::Stdout));
        sink.send(&json::emit(&response_json(&resp, &client_id)));
        stats
            .write_line(&ServeRecord { serve: &opts.stats_name, resp: &resp, git: &git }
                .to_json())
            .ok();
        served += 1;
    }
    // final metrics dump: fold the drained traces in, republish every
    // producer, and write the whole snapshot + trace ring to
    // results/METRICS_serve.json (schema: rust/docs/observability.md)
    for t in sched.traces().since(trace_cursor) {
        queued_hist.record(t.span.queued_ns());
        if t.span.first_token_ns > 0 {
            ttft_hist.record(t.span.ttft_ns());
            if t.new_tokens >= 2 {
                itl_hist.record(t.span.decode_ns() / (t.new_tokens as u64 - 1));
            }
        }
    }
    publish_all(&sched);
    let dump = json::obj(vec![
        ("schema", json::num(1.0)),
        ("serve", json::s(&opts.stats_name)),
        ("git", json::s(&git)),
        ("metrics", metrics.snapshot()),
        ("traces", sched.traces().to_json()),
    ]);
    let metrics_path = crate::results_dir().join("METRICS_serve.json");
    match std::fs::write(&metrics_path, json::emit(&dump)) {
        Ok(()) => eprintln!("[serve] metrics written to {}", metrics_path.display()),
        Err(e) => eprintln!(
            "[serve] warning: failed to write {}: {e}",
            metrics_path.display()
        ),
    }
    let st = registry.stats();
    eprintln!(
        "[serve] done: {served} requests, {} decode steps / {} ticks \
         (max admit wait {} ticks), {} prefill chunks ({} prompt tokens); \
         adapter cache {} hits / {} misses / {} evictions, {:.1} KB resident",
        sched.decode_steps, sched.ticks, sched.max_admit_wait_ticks,
        sched.prefill_dispatches, sched.prefill_tokens, st.hits, st.misses,
        st.evictions, st.resident_bytes as f64 / 1024.0,
    );
    let ss = sessions.stats();
    if opts.sessions_dir.is_some()
        || ss.hits + ss.misses + sched.session_persists + sched.session_fallbacks > 0
    {
        eprintln!(
            "[serve] sessions: {} resurrected / {} fell back to prefill, \
             {} persisted ({} failures), store {} hits / {} misses / {} spills, \
             {} quarantined; drain flushed {} ({} failures)",
            sched.session_resurrections, sched.session_fallbacks,
            sched.session_persists, sched.session_persist_failures,
            ss.hits, ss.misses, ss.spills, ss.quarantined, flushed, flush_failed,
        );
    }
    if sched.step_faults + sched.deadline_failures + st.quarantined as u64 > 0 {
        eprintln!(
            "[serve] resilience: {} step faults ({} retried in place, {} rows \
             demoted), {} deadline failures, {} adapters quarantined \
             ({} probation trials, {} reinstated), {} pins outstanding",
            sched.step_faults, sched.step_retries, sched.demotions,
            sched.deadline_failures, st.quarantined, st.probations,
            st.reinstated, st.pins,
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::scheduler::FinishReason;

    #[test]
    fn parse_request_full_and_defaults() {
        let w = parse_request(
            r#"{"id": 7, "adapter": "a_lora_lin", "prompt": "hi", "max_new": 5,
                "stop": "\n", "beam": 2, "deadline": 12}"#,
            48,
        )
        .unwrap();
        assert_eq!(w.adapter, "a_lora_lin");
        assert_eq!(w.prompt, b"hi");
        assert_eq!(w.max_new, 5);
        assert_eq!(w.stop_byte, b'\n');
        assert_eq!(w.beam, 2);
        assert_eq!(w.deadline, Some(12));
        assert_eq!(w.client_id, Value::Num(7.0));

        let w = parse_request(r#"{"adapter": "a", "prompt": "x"}"#, 48).unwrap();
        assert_eq!(w.max_new, 48);
        assert_eq!(w.stop_byte, b'\n');
        assert_eq!(w.beam, 1);
        assert_eq!(w.deadline, None, "falls back to the serve-level default");
        assert_eq!(w.client_id, Value::Null);
        assert_eq!(w.session, None, "stateless by default");
    }

    #[test]
    fn parse_request_session_contract() {
        let w = parse_request(
            r#"{"adapter": "a", "prompt": "x", "session": "chat-42"}"#,
            8,
        )
        .unwrap();
        assert_eq!(w.session.as_deref(), Some("chat-42"));
        let w = parse_request(r#"{"adapter": "a", "prompt": "x", "session": null}"#, 8)
            .unwrap();
        assert_eq!(w.session, None, "explicit null = stateless");
        assert!(
            parse_request(r#"{"adapter": "a", "prompt": "x", "session": 7}"#, 8).is_err(),
            "non-string session id rejected"
        );
        assert!(
            parse_request(r#"{"adapter": "a", "prompt": "x", "session": ""}"#, 8)
                .is_err(),
            "empty session id rejected"
        );
    }

    #[test]
    fn parse_request_rejects_bad_input() {
        assert!(parse_request("not json", 8).is_err());
        assert!(parse_request(r#"{"prompt": "x"}"#, 8).is_err(), "missing adapter");
        assert!(parse_request(r#"{"adapter": "a"}"#, 8).is_err(), "missing prompt");
        assert!(
            parse_request(r#"{"adapter": "a", "prompt": "x", "nope": 1}"#, 8).is_err(),
            "unknown keys fail loudly"
        );
        assert!(
            parse_request(r#"{"adapter": "a", "prompt": "x", "stop": "ab"}"#, 8).is_err(),
            "multi-byte stop rejected"
        );
    }

    #[test]
    fn response_and_record_json_shape() {
        let resp = Response {
            id: 3,
            adapter: "a_lora_lin".into(),
            output: b"out".to_vec(),
            prompt_len: 2,
            queued_s: 0.5,
            total_s: 1.0,
            steps: 6,
            finish: FinishReason::Stop,
            error: None,
            retries: 1,
            session: Some("chat-42".into()),
        };
        let v = response_json(&resp, &Value::Str("req-1".into()));
        assert_eq!(v.path("id").unwrap().as_str(), Some("req-1"));
        assert_eq!(v.path("session").unwrap().as_str(), Some("chat-42"));
        assert_eq!(v.path("output").unwrap().as_str(), Some("out"));
        assert_eq!(v.path("new_tokens").unwrap().as_usize(), Some(3));
        assert_eq!(v.path("finish").unwrap().as_str(), Some("stop"));
        assert_eq!(v.path("retries").unwrap().as_usize(), Some(1));
        assert_eq!(v.path("error"), Some(&Value::Null));
        // 3 bytes over 0.5s of slot occupancy (total 1.0 minus 0.5 queued)
        assert_eq!(v.path("tok_per_s").unwrap().as_f64(), Some(6.0));

        let rec = ServeRecord { serve: "s", resp: &resp, git: "g1" }.to_json();
        assert_eq!(rec.path("serve").unwrap().as_str(), Some("s"));
        assert_eq!(rec.path("git").unwrap().as_str(), Some("g1"));
        assert_eq!(rec.path("id").unwrap().as_usize(), Some(3));
        assert_eq!(rec.path("session").unwrap().as_str(), Some("chat-42"));
        // round-trips through the emitter
        let back = json::parse(&json::emit(&rec)).unwrap();
        assert_eq!(back.path("adapter").unwrap().as_str(), Some("a_lora_lin"));
    }

    #[test]
    fn stats_command_contract() {
        // normal requests and non-JSON lines are not commands
        assert!(parse_stats_command(r#"{"adapter": "a", "prompt": "x"}"#).is_none());
        assert!(parse_stats_command("not json").is_none());
        // well-formed stats command, with and without a client id
        let id = parse_stats_command(r#"{"cmd": "stats", "id": 3}"#)
            .expect("is a command")
            .expect("is well-formed");
        assert_eq!(id, Value::Num(3.0));
        let id = parse_stats_command(r#"{"cmd": "stats"}"#).unwrap().unwrap();
        assert_eq!(id, Value::Null);
        // unknown-key rejection is preserved on the command path
        assert!(
            parse_stats_command(r#"{"cmd": "stats", "nope": 1}"#).unwrap().is_err(),
            "unknown command keys fail loudly"
        );
        assert!(
            parse_stats_command(r#"{"cmd": "reset"}"#).unwrap().is_err(),
            "unknown cmd fails loudly"
        );
        assert!(
            parse_stats_command(r#"{"cmd": 7}"#).unwrap().is_err(),
            "non-string cmd fails loudly"
        );
    }

    #[test]
    fn stats_reply_shape_round_trips() {
        // the reply the ingest path sends for {"cmd":"stats"}: metrics
        // snapshot + trace ring, keyed by the echoed client id
        let m = crate::obs::Metrics::new();
        m.counter("sched.ticks").set(4);
        m.histogram("serve.ttft_ns").record(2_000_000);
        let mut ring = crate::obs::TraceRing::new(4);
        ring.push(crate::obs::Trace {
            id: 1,
            adapter: "a".into(),
            prompt_len: 2,
            new_tokens: 3,
            steps: 5,
            retries: 0,
            finish: "stop",
            span: crate::obs::Span::started(0, 1_000_000),
        });
        let v = json::obj(vec![
            ("id", Value::Num(9.0)),
            ("stats", m.snapshot()),
            ("traces", ring.to_json()),
        ]);
        let back = json::parse(&json::emit(&v)).unwrap();
        assert_eq!(back.path("id").unwrap().as_usize(), Some(9));
        let counters = back.path("stats").unwrap().path("counters").unwrap();
        assert_eq!(counters.get("sched.ticks").unwrap().as_usize(), Some(4));
        let traces = back.path("traces").unwrap().as_arr().unwrap();
        assert_eq!(traces.len(), 1);
        assert_eq!(traces[0].get("adapter").unwrap().as_str(), Some("a"));
    }

    #[test]
    fn serve_options_parse_and_validate() {
        let mut kv = std::collections::BTreeMap::new();
        kv.insert("arch".to_string(), "mamba2_xs".to_string());
        kv.insert("cache".to_string(), "2".to_string());
        kv.insert("addr".to_string(), "127.0.0.1:0".to_string());
        kv.insert("stdin".to_string(), "0".to_string());
        kv.insert("deadline".to_string(), "64".to_string());
        kv.insert("sessions_dir".to_string(), "/tmp/sess".to_string());
        kv.insert("sessions_cap".to_string(), "16".to_string());
        let o = ServeOptions::from_kvs(&kv).unwrap();
        assert_eq!(o.arch, "mamba2_xs");
        assert_eq!(o.cache_cap, 2);
        assert!(!o.stdin);
        assert_eq!(o.addr.as_deref(), Some("127.0.0.1:0"));
        assert_eq!(o.deadline, 64);
        assert_eq!(o.sessions_dir.as_deref(), Some(std::path::Path::new("/tmp/sess")));
        assert_eq!(o.sessions_cap, 16);

        let mut bad = std::collections::BTreeMap::new();
        bad.insert("stdin".to_string(), "0".to_string());
        assert!(ServeOptions::from_kvs(&bad).is_err(), "no request source");
        let mut unk = std::collections::BTreeMap::new();
        unk.insert("bogus".to_string(), "1".to_string());
        assert!(ServeOptions::from_kvs(&unk).is_err());
    }
}
