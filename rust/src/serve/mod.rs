//! Online multi-adapter generation: serve many fine-tuned variants
//! concurrently from ONE staged pretrained base.
//!
//! The paper's headline recipe (SDT on SSM modules + LoRA on projections)
//! produces many *small* per-task adapters over a shared backbone, and an
//! SSM's recurrent state is O(1) per sequence — no KV cache growth. This
//! module turns those two properties into a serving path:
//!
//! - [`registry`] — [`AdapterRegistry`]: lazily materialized, LRU-capped
//!   cache of **raw adapter deltas** (LoRA factors + SDT sparse offsets +
//!   trained `h0`, KBs per adapter instead of whole-model merged copies),
//!   with pinning so in-flight adapters survive eviction and an on-demand
//!   merged materialization ([`AdapterRegistry::load_merged`]) for the
//!   fallback path.
//! - [`scheduler`] — [`Scheduler`]: continuous batching over the stepwise
//!   decode executable; requests are admitted into and retired from batch
//!   rows **between any two decode steps**, with per-request stop bytes,
//!   `max_new` limits, and greedy or beam decoding. Adapters served as
//!   deltas share ONE mixed batch (a single
//!   [`crate::eval::AdapterStepDecode::step_rows`] dispatch per tick);
//!   adapters the delta path can't represent fall back to per-adapter
//!   merged lanes.
//! - [`sessions`] — [`SessionStore`]: durable per-session `(conv, ssm)`
//!   snapshots (in-memory LRU over checksummed spill-to-disk records),
//!   so a returning conversation resumes via
//!   [`crate::eval::DecodeState::splice_row_from`] with **zero** prefill
//!   dispatches; corrupt or torn records are quarantined and the session
//!   degrades to full-history chunked prefill instead.
//! - [`server`] — the `serve` CLI subcommand: line-delimited JSON over
//!   stdin/stdout and TCP, per-request latency/throughput stats streamed
//!   as RunRecord-style JSONL into `results/`; stdin EOF triggers a
//!   graceful drain that retires in-flight rows and flushes resident
//!   sessions.
//!
//! The decode strategies themselves live in [`crate::eval`]
//! ([`crate::eval::greedy_decode`], [`crate::eval::beam_search`], both
//! over the [`crate::eval::StepDecode`] trait) so the offline suite and
//! this server share one generation core.
//!
//! Schema + worked examples: `rust/docs/serving.md`.

pub mod registry;
pub mod scheduler;
pub mod server;
pub mod sessions;

pub use registry::{Adapter, AdapterRegistry, AdapterSource, ManifestSource, RegistryStats};
pub use sessions::{RecoveryReport, SessionSnapshot, SessionStats, SessionStore};
pub use scheduler::{
    FinishReason, LaneModel, Request, Response, RetireHook, Scheduler, ServeFactory,
    ServeModel,
};
pub use server::{run, ServeOptions, ServeRecord};
