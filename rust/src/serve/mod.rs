//! Online multi-adapter generation: serve many fine-tuned variants
//! concurrently from ONE staged pretrained base.
//!
//! The paper's headline recipe (SDT on SSM modules + LoRA on projections)
//! produces many *small* per-task adapters over a shared backbone, and an
//! SSM's recurrent state is O(1) per sequence — no KV cache growth. This
//! module turns those two properties into a serving path:
//!
//! - [`registry`] — [`AdapterRegistry`]: lazily materialized, LRU-capped
//!   cache of decode-ready parameter sets (base + trained deltas, LoRA
//!   folded via [`crate::peft::merge_lora`], trained `h0` split out).
//! - [`scheduler`] — [`Scheduler`]: continuous batching over the stepwise
//!   decode executable; requests are admitted into and retired from batch
//!   rows **between any two decode steps**, with per-request stop bytes,
//!   `max_new` limits, and greedy or beam decoding.
//! - [`server`] — the `serve` CLI subcommand: line-delimited JSON over
//!   stdin/stdout and TCP, per-request latency/throughput stats streamed
//!   as RunRecord-style JSONL into `results/`.
//!
//! The decode strategies themselves live in [`crate::eval`]
//! ([`crate::eval::greedy_decode`], [`crate::eval::beam_search`], both
//! over the [`crate::eval::StepDecode`] trait) so the offline suite and
//! this server share one generation core.
//!
//! Schema + worked examples: `rust/docs/serving.md`.

pub mod registry;
pub mod scheduler;
pub mod server;

pub use registry::{Adapter, AdapterRegistry, AdapterSource, ManifestSource, RegistryStats};
pub use scheduler::{
    FinishReason, LaneFactory, LaneModel, Request, Response, Scheduler,
};
pub use server::{run, ServeOptions, ServeRecord};
