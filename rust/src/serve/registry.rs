//! Adapter registry: lazily materialized, LRU-capped cache of unmerged
//! adapter deltas — one per fine-tuned variant served from the shared base.
//!
//! An adapter is held as its raw [`AdapterDelta`] (LoRA factor pairs, SDT
//! sparse trained values, h0 seeds) — KBs per adapter — instead of a
//! merged whole-model parameter copy; the unmerged decode path
//! ([`crate::eval::AdapterStepDecode`]) binds deltas per batch row at step
//! time. Materializing is still the expensive step (read the variant's
//! parameter layout, overlay the staged pretrained base and any trained
//! checkpoint, diff against the base), so the registry does it once per
//! adapter, hands out `Arc<Adapter>` clones, and evicts the least recently
//! used entry when the cap is exceeded. Adapters referenced by in-flight
//! scheduler rows are [pinned](AdapterRegistry::pin): the LRU pass skips
//! them (temporarily exceeding the cap when everything is pinned) so an
//! active request can never have its adapter dropped underneath it.
//!
//! Adapters the delta form cannot represent (DoRA's column renorm,
//! prompt/prefix virtual tokens, dense updates like full FT or BitFit)
//! load with `delta: None`; the serve layer falls back to a dedicated
//! merged core via [`AdapterRegistry::load_merged`], which bypasses the
//! cache entirely.
//!
//! The loading policy lives behind the [`AdapterSource`] trait so the LRU
//! machinery is unit-testable without artifacts; [`ManifestSource`] is the
//! real policy used by the `serve` subcommand.

use std::collections::BTreeMap;
use std::collections::VecDeque;
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::bail;
use crate::error::{Context, Error, ErrorKind, Result};
use crate::fault::{FaultInject, FaultSite};

use crate::eval::{AdapterDelta, LoraOp, SparseOffset};
use crate::manifest::{Manifest, PeftMeta};
use crate::peft::{self, Budget};
use crate::suite::{PeftMethod, VariantId};
use crate::tensor::Tensor;
use crate::train::checkpoint;

/// A decode-ready adapter: the unmerged delta (when representable) plus
/// serving metadata for one fine-tuned variant.
pub struct Adapter {
    /// Adapter id as requested (variant name, optionally `@ckpt-path`).
    pub name: String,
    /// The decode-capable variant the adapter targets
    /// (`<arch>_full` — see [`VariantId::decode_variant`]).
    pub decode_variant: String,
    /// The adapter's unmerged delta against the shared base; `None` when
    /// the method cannot be represented unmerged (DoRA, prompt/prefix,
    /// dense updates) and serving must fall back to
    /// [`AdapterRegistry::load_merged`].
    pub delta: Option<Arc<AdapterDelta>>,
    /// Trained initial states (`layers.{i}.h0`), present for
    /// initial-state-tuning adapters; seeds each admitted request's SSM
    /// state ([`crate::eval::StateDims::init_states`]).
    pub h0: Option<Arc<BTreeMap<String, Tensor>>>,
    /// Trainable-parameter budget of the source variant, percent (the
    /// paper's "# Params (%)" column — reported in serve stats).
    pub budget_pct: f64,
}

impl Adapter {
    /// Bytes this adapter keeps resident: delta-sized (rank × targets +
    /// sparse nnz + h0), NOT a whole-model copy. The delta already counts
    /// its own h0 tensors, so the standalone `h0` map (same content) is
    /// counted only for delta-less adapters.
    pub fn resident_bytes(&self) -> usize {
        match &self.delta {
            Some(d) => d.resident_bytes(),
            None => self.h0.as_ref().map_or(0, |m| {
                m.values().map(|t| t.numel() * std::mem::size_of::<f32>()).sum()
            }),
        }
    }
}

/// Where adapters come from: maps an adapter id to a materialized
/// [`Adapter`]. Closures implement it, so tests can count loads.
pub trait AdapterSource {
    /// Materialize the adapter for `name` (expensive; called on cache miss).
    fn load(&self, name: &str) -> Result<Adapter>;

    /// Materialize the full merged parameter map for `name` — the serving
    /// fallback for adapters whose [`Adapter::delta`] is `None`. Never
    /// cached by the registry (a merged map is whole-model-sized); callers
    /// bind it into a dedicated core. Sources that cannot merge (test
    /// closures) inherit this refusal.
    fn load_merged(&self, name: &str) -> Result<BTreeMap<String, Tensor>> {
        bail!("adapter source cannot materialize merged parameters for {name:?}")
    }
}

impl<F: Fn(&str) -> Result<Adapter>> AdapterSource for F {
    fn load(&self, name: &str) -> Result<Adapter> {
        self(name)
    }
}

/// Cache counters (counts monotone; read via [`AdapterRegistry::stats`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RegistryStats {
    /// Requests served from cache.
    pub hits: usize,
    /// Requests that materialized a new adapter.
    pub misses: usize,
    /// Adapters dropped by the LRU policy.
    pub evictions: usize,
    /// Adapters currently resident.
    pub resident: usize,
    /// Bytes the resident adapters keep ([`Adapter::resident_bytes`]) —
    /// delta-sized accounting, demonstrating KBs/adapter instead of
    /// whole-model copies.
    pub resident_bytes: usize,
    /// Adapters currently quarantined by the circuit breaker.
    pub quarantined: usize,
    /// Half-open trial loads attempted (probation probes).
    pub probations: usize,
    /// Trial loads that succeeded and closed the circuit.
    pub reinstated: usize,
    /// Outstanding pin count across all adapters. Zero whenever the
    /// scheduler is idle — a non-zero value then is a leaked pin.
    pub pins: usize,
}

impl RegistryStats {
    /// Publish this snapshot into a metrics registry under `registry.*`
    /// (instrument names: rust/docs/observability.md § Registry).
    pub fn publish(&self, m: &crate::obs::Metrics) {
        m.counter("registry.hits").set(self.hits as u64);
        m.counter("registry.misses").set(self.misses as u64);
        m.counter("registry.evictions").set(self.evictions as u64);
        m.counter("registry.probations").set(self.probations as u64);
        m.counter("registry.reinstated").set(self.reinstated as u64);
        m.gauge("registry.resident").set(self.resident as u64);
        m.gauge("registry.resident_bytes").set(self.resident_bytes as u64);
        m.gauge("registry.quarantined").set(self.quarantined as u64);
        m.gauge("registry.pins").set(self.pins as u64);
    }
}

/// Circuit state for one quarantined adapter.
struct Quarantine {
    /// Scheduler ticks observed since the circuit (re-)opened
    /// ([`AdapterRegistry::note_tick`]).
    ticks: u32,
    /// Probation: the next [`AdapterRegistry::get`] runs one trial load.
    half_open: bool,
}

struct Inner {
    map: BTreeMap<String, Arc<Adapter>>,
    /// Recency order, least recently used first.
    order: VecDeque<String>,
    /// Pin counts: adapters referenced by in-flight scheduler rows. The
    /// eviction pass skips pinned names (exceeding `cap` when necessary).
    pins: BTreeMap<String, usize>,
    /// Terminal failures per adapter ([`AdapterRegistry::record_failure`]).
    failures: BTreeMap<String, u32>,
    /// Adapters past the failure threshold: [`AdapterRegistry::get`]
    /// rejects them until a probation trial succeeds or an operator
    /// [`AdapterRegistry::reinstate`]s.
    quarantined: BTreeMap<String, Quarantine>,
}

/// LRU-capped adapter cache. `get` is the only entry point: hit moves the
/// adapter to most-recently-used; miss materializes through the
/// [`AdapterSource`] and evicts the least recently used entry past `cap`.
pub struct AdapterRegistry<S> {
    source: S,
    cap: usize,
    inner: Mutex<Inner>,
    hits: AtomicUsize,
    misses: AtomicUsize,
    evictions: AtomicUsize,
    /// Terminal failures before an adapter is quarantined.
    quarantine_threshold: u32,
    /// Ticks an open circuit waits before going half-open (0 = probation
    /// disabled: only an operator [`AdapterRegistry::reinstate`] closes it).
    probation_ticks: u32,
    probations: AtomicUsize,
    reinstated: AtomicUsize,
    /// Fault-injection hook for the adapter-load and artifact-read sites
    /// (`None` in production: a no-op).
    faults: Option<Arc<dyn FaultInject>>,
}

/// Terminal failures before [`AdapterRegistry::record_failure`] opens the
/// circuit for an adapter (overridable per registry).
pub const DEFAULT_QUARANTINE_THRESHOLD: u32 = 3;

/// Scheduler ticks an open circuit waits before the breaker goes
/// half-open and admits one probation trial load (overridable per
/// registry; 0 disables automatic probation).
pub const DEFAULT_PROBATION_TICKS: u32 = 256;

impl<S: AdapterSource> AdapterRegistry<S> {
    /// New registry holding at most `cap` materialized adapters (min 1).
    pub fn new(source: S, cap: usize) -> AdapterRegistry<S> {
        AdapterRegistry {
            source,
            cap: cap.max(1),
            inner: Mutex::new(Inner {
                map: BTreeMap::new(),
                order: VecDeque::new(),
                pins: BTreeMap::new(),
                failures: BTreeMap::new(),
                quarantined: BTreeMap::new(),
            }),
            hits: AtomicUsize::new(0),
            misses: AtomicUsize::new(0),
            evictions: AtomicUsize::new(0),
            quarantine_threshold: DEFAULT_QUARANTINE_THRESHOLD,
            probation_ticks: DEFAULT_PROBATION_TICKS,
            probations: AtomicUsize::new(0),
            reinstated: AtomicUsize::new(0),
            faults: None,
        }
    }

    /// Override the circuit-breaker threshold (min 1).
    pub fn set_quarantine_threshold(&mut self, threshold: u32) {
        self.quarantine_threshold = threshold.max(1);
    }

    /// Override how many [`AdapterRegistry::note_tick`]s an open circuit
    /// waits before going half-open (0 disables automatic probation).
    pub fn set_probation_ticks(&mut self, ticks: u32) {
        self.probation_ticks = ticks;
    }

    /// Advance the probation clock by one scheduler tick: every open
    /// circuit ages, and one that has waited [`probation
    /// ticks`](AdapterRegistry::set_probation_ticks) goes half-open — the
    /// next [`AdapterRegistry::get`] for that adapter runs a single trial
    /// load instead of rejecting.
    pub fn note_tick(&self) {
        if self.probation_ticks == 0 {
            return;
        }
        let mut inner =
            self.inner.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        for q in inner.quarantined.values_mut() {
            if !q.half_open {
                q.ticks = q.ticks.saturating_add(1);
                if q.ticks >= self.probation_ticks {
                    q.half_open = true;
                }
            }
        }
    }

    /// Install the fault-injection hook (adapter-load + artifact-read
    /// sites).
    pub fn set_fault_inject(&mut self, faults: Arc<dyn FaultInject>) {
        self.faults = Some(faults);
    }

    /// Count one terminal failure against `name`; returns `true` when this
    /// call crossed the threshold and quarantined the adapter. The cached
    /// delta is dropped so a later [`AdapterRegistry::reinstate`] reloads
    /// from scratch.
    pub fn record_failure(&self, name: &str) -> bool {
        let mut inner = self.inner.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        let n = inner.failures.entry(name.to_string()).or_insert(0);
        *n += 1;
        if *n >= self.quarantine_threshold && !inner.quarantined.contains_key(name) {
            inner
                .quarantined
                .insert(name.to_string(), Quarantine { ticks: 0, half_open: false });
            inner.map.remove(name);
            inner.order.retain(|k| k != name);
            return true;
        }
        false
    }

    /// Whether the circuit breaker currently rejects `name`.
    pub fn is_quarantined(&self, name: &str) -> bool {
        self.inner
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner)
            .quarantined
            .contains_key(name)
    }

    /// Whether `name`'s circuit is half-open (the next get runs a trial).
    pub fn is_half_open(&self, name: &str) -> bool {
        self.inner
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner)
            .quarantined
            .get(name)
            .is_some_and(|q| q.half_open)
    }

    /// Close the circuit for `name` immediately: clear its failure count
    /// and admit it again (operator action; the automatic path is the
    /// half-open probation driven by [`AdapterRegistry::note_tick`]).
    pub fn reinstate(&self, name: &str) {
        let mut inner = self.inner.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        inner.failures.remove(name);
        inner.quarantined.remove(name);
    }

    /// Fetch (materializing on first use) the adapter for `name`.
    pub fn get(&self, name: &str) -> Result<Arc<Adapter>> {
        let mut trial = false;
        {
            let mut inner = self.inner.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
            if let Some(q) = inner.quarantined.get_mut(name) {
                if !q.half_open {
                    return Err(Error::new(
                        ErrorKind::Request,
                        format!("adapter {name:?} is quarantined after repeated failures"),
                    ));
                }
                // half-open: admit exactly ONE trial load. Re-open the
                // circuit first so concurrent gets keep rejecting while
                // the probe runs; success removes the entry below.
                q.half_open = false;
                q.ticks = 0;
                trial = true;
                self.probations.fetch_add(1, Ordering::Relaxed);
            }
            if let Some(a) = inner.map.get(name).cloned() {
                // refresh recency
                inner.order.retain(|k| k != name);
                inner.order.push_back(name.to_string());
                self.hits.fetch_add(1, Ordering::Relaxed);
                return Ok(a);
            }
        }
        // materialize outside the lock: a slow load must not block stats
        // readers; the serve loop admits sequentially so duplicate loads
        // don't arise in practice (and would only waste work, not break)
        let loaded = match &self.faults {
            Some(f) => f
                .check(FaultSite::AdapterLoad)
                .with_context(|| format!("loading adapter {name:?}"))
                .and_then(|()| self.source.load(name)),
            None => self.source.load(name),
        };
        let adapter = match loaded {
            Ok(a) => Arc::new(a),
            Err(e) => {
                return Err(if trial {
                    // failed probe: the circuit stays open (entry already
                    // reset above) and the probation clock restarts
                    e.context(format!("probation trial for adapter {name:?} failed"))
                } else {
                    e
                });
            }
        };
        if trial {
            // the probe passed: close the circuit and forget the failures
            let mut inner = self.inner.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
            inner.quarantined.remove(name);
            inner.failures.remove(name);
            self.reinstated.fetch_add(1, Ordering::Relaxed);
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let mut inner = self.inner.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        if !inner.map.contains_key(name) {
            inner.map.insert(name.to_string(), adapter.clone());
            inner.order.push_back(name.to_string());
            // LRU pass, skipping pinned victims: an adapter bound to an
            // in-flight row must stay resident, so the cache may run over
            // cap until pins are released
            let mut skipped: Vec<String> = Vec::new();
            while inner.map.len() > self.cap {
                let Some(victim) = inner.order.pop_front() else { break };
                if inner.pins.get(&victim).copied().unwrap_or(0) > 0 {
                    skipped.push(victim);
                    continue;
                }
                inner.map.remove(&victim);
                self.evictions.fetch_add(1, Ordering::Relaxed);
            }
            for k in skipped.into_iter().rev() {
                inner.order.push_front(k); // preserve recency of survivors
            }
        }
        Ok(adapter)
    }

    /// Materialize the merged whole-model parameter map for `name`,
    /// bypassing the delta cache — the serving fallback for adapters whose
    /// [`Adapter::delta`] is `None`.
    pub fn load_merged(&self, name: &str) -> Result<BTreeMap<String, Tensor>> {
        if self.is_quarantined(name) {
            return Err(Error::new(
                ErrorKind::Request,
                format!("adapter {name:?} is quarantined after repeated failures"),
            ));
        }
        if let Some(f) = &self.faults {
            f.check(FaultSite::ArtifactRead)
                .with_context(|| format!("reading merged parameters for {name:?}"))?;
        }
        self.source.load_merged(name)
    }

    /// Pin `name`: an in-flight scheduler row references this adapter, so
    /// the LRU pass must not drop it. Pins count and nest; pair each with
    /// one [`AdapterRegistry::unpin`] when the row retires.
    pub fn pin(&self, name: &str) {
        let mut inner = self.inner.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        *inner.pins.entry(name.to_string()).or_insert(0) += 1;
    }

    /// Release one pin on `name`; at zero the adapter becomes evictable
    /// again on the next cache insertion. An unpin without a matching pin
    /// is a release-protocol bug (the scheduler must report each factory
    /// `Shared` result exactly once): debug builds assert, release builds
    /// treat it as a no-op.
    pub fn unpin(&self, name: &str) {
        let mut inner = self.inner.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        debug_assert!(
            inner.pins.get(name).copied().unwrap_or(0) > 0,
            "unpin without a matching pin: {name:?}"
        );
        if let Some(n) = inner.pins.get_mut(name) {
            *n = n.saturating_sub(1);
            if *n == 0 {
                inner.pins.remove(name);
            }
        }
    }

    /// Whether `name` is currently resident (does not touch recency).
    pub fn contains(&self, name: &str) -> bool {
        self.inner.lock().unwrap_or_else(std::sync::PoisonError::into_inner).map.contains_key(name)
    }

    /// Cache counters snapshot.
    pub fn stats(&self) -> RegistryStats {
        let inner = self.inner.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        RegistryStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            resident: inner.map.len(),
            resident_bytes: inner.map.values().map(|a| a.resident_bytes()).sum(),
            quarantined: inner.quarantined.len(),
            probations: self.probations.load(Ordering::Relaxed),
            reinstated: self.reinstated.load(Ordering::Relaxed),
            pins: inner.pins.values().sum(),
        }
    }
}

/// The real adapter source: manifest layout + staged pretrained base +
/// optional trained checkpoints.
///
/// Adapter ids are variant names (`mamba1_xs_lora_lin`), optionally with an
/// explicit trained checkpoint: `mamba1_xs_lora_lin@results/rte.ckpt`.
/// Without `@`, `adapter_dir/<variant>.ckpt` is used when present;
/// otherwise the variant's fresh initialization serves (LoRA deltas start
/// at zero, so an untrained adapter behaves as the base model).
pub struct ManifestSource<'a> {
    /// Artifact manifest (parameter layouts, PEFT metadata).
    pub manifest: &'a Manifest,
    /// Architecture the staged base was pretrained for (e.g. "mamba1_xs");
    /// adapters of other architectures are rejected.
    pub base_arch: String,
    /// The staged pretrained base checkpoint shared by every adapter.
    pub base: Arc<BTreeMap<String, Tensor>>,
    /// Directory searched for `<variant>.ckpt` trained-adapter files.
    pub adapter_dir: Option<PathBuf>,
}

/// Sparse-diff density cap: a leaf whose changed-entry index set would
/// cost more than 1/8 of the dense tensor (usize index + f32 value per
/// entry vs f32 per element) is "dense" — representing it sparsely saves
/// nothing, so the whole adapter falls back to the merged path. Covers
/// full FT and BitFit (every bias entry trained).
const SPARSE_DENSITY_CAP: usize = 8;

/// Distill an adapter's raw (pre-merge) parameter map into an
/// [`AdapterDelta`] against the shared base, or `None` when the adapter
/// cannot be represented unmerged:
///
/// - DoRA (post-merge column renorm is not base + delta), prompt/prefix
///   (virtual tokens change sequence geometry), and add-scan (extra state
///   dims) are structurally unrepresentable;
/// - a non-adapter leaf missing from the base (or shape-mismatched) has
///   nowhere to delta against;
/// - a leaf with more than `1/SPARSE_DENSITY_CAP` of its entries changed
///   is dense — merged serving is strictly better.
///
/// Changed entries are detected bitwise and stored as TRAINED VALUES
/// (replacement), so [`AdapterDelta::apply`] reproduces the merged map
/// bit-for-bit. This works because every variant of one architecture
/// shares the base initialization (same seed, PEFT only adds leaves), so
/// after the base overlay only checkpoint-trained entries differ.
pub fn delta_from_params(base: &BTreeMap<String, Tensor>,
                         raw: &BTreeMap<String, Tensor>,
                         meta: &PeftMeta) -> Option<AdapterDelta> {
    match meta.method {
        PeftMethod::Dora(_) | PeftMethod::Prompt | PeftMethod::Prefix
        | PeftMethod::AddScan => return None,
        _ => {}
    }
    let mut lora: Vec<LoraOp> = Vec::new();
    let mut sparse: Vec<SparseOffset> = Vec::new();
    let mut h0: BTreeMap<String, Tensor> = BTreeMap::new();
    for (k, t) in raw {
        if let Some(target) = k.strip_suffix(".lora_a") {
            let b = raw.get(&format!("{target}.lora_b"))?;
            if !base.contains_key(target) {
                return None;
            }
            lora.push(LoraOp {
                target: target.to_string(),
                a: t.clone(),
                b: b.clone(),
            });
        } else if k.ends_with(".lora_b") {
            // consumed by its `.lora_a` partner above
        } else if k.ends_with(".dora_m") {
            return None; // belt and braces: method check already bailed
        } else if k.ends_with(".h0") {
            h0.insert(k.clone(), t.clone());
        } else {
            let bt = base.get(k)?;
            if bt.shape != t.shape {
                return None;
            }
            let idx: Vec<usize> = (0..t.data.len())
                .filter(|&i| t.data[i].to_bits() != bt.data[i].to_bits())
                .collect();
            if idx.len() * SPARSE_DENSITY_CAP > t.numel().max(1) {
                return None;
            }
            if !idx.is_empty() {
                let val = idx.iter().map(|&i| t.data[i]).collect();
                sparse.push(SparseOffset { param: k.clone(), idx, val });
            }
        }
    }
    Some(AdapterDelta { meta: meta.clone(), lora, sparse, h0 })
}

impl ManifestSource<'_> {
    fn resolve_ckpt(&self, variant: &str, explicit: Option<&str>) -> Option<PathBuf> {
        if let Some(p) = explicit {
            return Some(PathBuf::from(p));
        }
        let p = self.adapter_dir.as_ref()?.join(format!("{variant}.ckpt"));
        p.exists().then_some(p)
    }

    /// The raw pre-merge parameter map both serving paths start from:
    /// fresh init for every leaf, staged pretrained base overlaid, then
    /// trained checkpoint weights. Returns the variant name alongside.
    fn raw_params(&self, name: &str) -> Result<(String, BTreeMap<String, Tensor>)> {
        let (vname, ckpt) = match name.split_once('@') {
            Some((v, p)) => (v, Some(p)),
            None => (name, None),
        };
        let vid = VariantId::parse(vname)?;
        if vid.arch != self.base_arch {
            bail!(
                "adapter {vname:?} targets arch {:?} but the staged base is {:?}",
                vid.arch, self.base_arch
            );
        }
        let variant = self.manifest.variant(vname)?;
        // fresh init for every leaf (incl. adapter-only ones) ...
        let mut params = self.manifest.load_params(variant)?;
        // ... then the staged pretrained backbone wherever names align ...
        for (k, t) in self.base.iter() {
            if let Some(slot) = params.get_mut(k) {
                if slot.shape == t.shape {
                    *slot = t.clone();
                }
            }
        }
        // ... then trained adapter weights, if a checkpoint exists
        if let Some(path) = self.resolve_ckpt(vname, ckpt) {
            let trained = checkpoint::load(&path)
                .with_context(|| format!("loading adapter checkpoint {path:?}"))?;
            let total = trained.len();
            let mut applied = 0usize;
            for (k, t) in trained {
                if let Some(slot) = params.get_mut(&k) {
                    if slot.shape == t.shape {
                        *slot = t;
                        applied += 1;
                    }
                }
            }
            // a checkpoint that contributes nothing means a wrong file or
            // a drifted layout — serving silently-untrained weights as the
            // requested adapter would be worse than refusing
            if applied == 0 {
                bail!(
                    "adapter checkpoint {path:?} matched none of {vname}'s \
                     parameters ({total} tensors, all skipped by name/shape)"
                );
            }
            if applied < total {
                eprintln!(
                    "[serve] warning: adapter {name}: {}/{total} checkpoint \
                     tensors skipped (name/shape mismatch vs {vname})",
                    total - applied,
                );
            }
        }
        Ok((vname.to_string(), params))
    }
}

impl AdapterSource for ManifestSource<'_> {
    fn load(&self, name: &str) -> Result<Adapter> {
        let (vname, params) = self.raw_params(name)?;
        let variant = self.manifest.variant(&vname)?;
        let vid = VariantId::parse(&vname)?;
        let budget_pct = Budget::of(variant, None).percent();
        let delta = delta_from_params(&self.base, &params, &variant.peft);
        let h0_map: BTreeMap<String, Tensor> = params
            .iter()
            .filter(|(k, _)| k.ends_with(".h0"))
            .map(|(k, v)| (k.clone(), v.clone()))
            .collect();
        let h0 = (!h0_map.is_empty()).then(|| Arc::new(h0_map));
        Ok(Adapter {
            name: name.to_string(),
            decode_variant: vid.decode_variant(),
            delta: delta.map(Arc::new),
            h0,
            budget_pct,
        })
    }

    /// The old merged-copy construction, now the fallback for delta-less
    /// adapters: raw map + [`crate::peft::merge_lora`]. The `.h0` leaves
    /// stay in the map (the decode argument order ignores extras).
    fn load_merged(&self, name: &str) -> Result<BTreeMap<String, Tensor>> {
        let (vname, mut params) = self.raw_params(name)?;
        let variant = self.manifest.variant(&vname)?;
        peft::merge_lora(&mut params, &variant.peft);
        Ok(params)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    use crate::suite::Target;

    fn dummy(name: &str) -> Adapter {
        Adapter {
            name: name.to_string(),
            decode_variant: "a_full".into(),
            delta: None,
            h0: None,
            budget_pct: 1.0,
        }
    }

    fn counting_source(loads: Arc<AtomicUsize>)
        -> impl Fn(&str) -> Result<Adapter> {
        move |name: &str| {
            loads.fetch_add(1, Ordering::Relaxed);
            if name == "bad" {
                bail!("no such adapter");
            }
            Ok(dummy(name))
        }
    }

    #[test]
    fn lru_evicts_least_recently_used() {
        let loads = Arc::new(AtomicUsize::new(0));
        let reg = AdapterRegistry::new(counting_source(loads.clone()), 2);
        reg.get("a").unwrap();
        reg.get("b").unwrap();
        reg.get("a").unwrap(); // refresh a → b is now LRU
        reg.get("c").unwrap(); // evicts b
        assert!(reg.contains("a"));
        assert!(reg.contains("c"));
        assert!(!reg.contains("b"), "b was least recently used");
        assert_eq!(loads.load(Ordering::Relaxed), 3);
        // b comes back only via a re-load
        reg.get("b").unwrap();
        assert_eq!(loads.load(Ordering::Relaxed), 4);
        let st = reg.stats();
        assert_eq!(st.misses, 4);
        assert_eq!(st.hits, 1);
        assert_eq!(st.evictions, 2, "c evicted b, then b evicted a");
        assert_eq!(st.resident, 2);
    }

    #[test]
    fn hits_do_not_reload() {
        let loads = Arc::new(AtomicUsize::new(0));
        let reg = AdapterRegistry::new(counting_source(loads.clone()), 4);
        let a1 = reg.get("a").unwrap();
        let a2 = reg.get("a").unwrap();
        assert!(Arc::ptr_eq(&a1, &a2), "hit returns the shared Arc");
        assert_eq!(loads.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn load_errors_propagate_and_cache_nothing() {
        let loads = Arc::new(AtomicUsize::new(0));
        let reg = AdapterRegistry::new(counting_source(loads.clone()), 2);
        assert!(reg.get("bad").is_err());
        assert!(!reg.contains("bad"));
        assert_eq!(reg.stats().resident, 0);
    }

    #[test]
    fn cap_floor_is_one() {
        let loads = Arc::new(AtomicUsize::new(0));
        let reg = AdapterRegistry::new(counting_source(loads), 0);
        reg.get("a").unwrap();
        reg.get("b").unwrap();
        assert_eq!(reg.stats().resident, 1);
    }

    #[test]
    fn pinned_adapter_survives_eviction() {
        let loads = Arc::new(AtomicUsize::new(0));
        let reg = AdapterRegistry::new(counting_source(loads.clone()), 2);
        reg.get("a").unwrap();
        reg.pin("a"); // an in-flight row holds a
        reg.get("b").unwrap();
        reg.get("c").unwrap(); // over cap: a is LRU but pinned → b goes
        assert!(reg.contains("a"), "pinned adapter must not be evicted");
        assert!(!reg.contains("b"), "eviction falls through to the next LRU");
        assert!(reg.contains("c"));
        assert_eq!(reg.stats().evictions, 1);
        // once released, a is evictable again (and still the LRU)
        reg.unpin("a");
        reg.get("d").unwrap();
        assert!(!reg.contains("a"), "unpinned adapter evicts normally");
        assert!(reg.contains("c") && reg.contains("d"));
        assert_eq!(loads.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn all_pinned_exceeds_cap_without_evicting() {
        let loads = Arc::new(AtomicUsize::new(0));
        let reg = AdapterRegistry::new(counting_source(loads), 1);
        reg.get("a").unwrap();
        reg.pin("a");
        reg.get("b").unwrap();
        reg.pin("b");
        let st = reg.stats();
        assert_eq!(st.resident, 2, "pins force the cache over cap");
        assert_eq!(st.evictions, 0);
        // pins nest: two pins need two releases
        reg.pin("a");
        reg.unpin("a");
        reg.unpin("b");
        reg.get("c").unwrap(); // b unpinned → evictable; a still pinned
        assert!(reg.contains("a") && reg.contains("c"));
        assert!(!reg.contains("b"));
    }

    fn base_map() -> BTreeMap<String, Tensor> {
        BTreeMap::from([
            ("w".to_string(),
             Tensor::from_vec(&[2, 2], vec![0.1, 0.2, 0.3, 0.4])),
            ("v".to_string(),
             Tensor::from_vec(&[8], (0..8).map(|i| i as f32).collect())),
        ])
    }

    fn lora_meta() -> PeftMeta {
        PeftMeta {
            method: PeftMethod::Lora(Target::LinProj),
            rank: 1,
            alpha: 1,
            targets: vec!["w".to_string()],
            n_tokens: 0,
        }
    }

    #[test]
    fn delta_from_params_roundtrips_bitwise() {
        // raw = base + trained lora leaves + one trained sparse entry; the
        // distilled delta applied to the base must equal raw + merge_lora
        // bit-for-bit (the demotion-gate equivalence)
        let base = base_map();
        let mut raw = base.clone();
        raw.insert("w.lora_a".to_string(),
                   Tensor::from_vec(&[2, 1], vec![0.5, -0.25]));
        raw.insert("w.lora_b".to_string(),
                   Tensor::from_vec(&[1, 2], vec![0.125, 8.0]));
        raw.get_mut("v").unwrap().data[3] = 17.5;
        raw.insert("layers.0.h0".to_string(), Tensor::from_vec(&[1], vec![2.5]));
        let meta = lora_meta();
        let delta = delta_from_params(&base, &raw, &meta)
            .expect("lora + sparse adapter is representable");
        assert_eq!(delta.lora.len(), 1);
        assert_eq!(delta.sparse.len(), 1);
        assert_eq!(delta.sparse[0].idx, vec![3]);
        assert_eq!(delta.sparse[0].val[0].to_bits(), 17.5f32.to_bits());
        assert_eq!(delta.h0.len(), 1);

        let got = delta.apply(&base).unwrap();
        let mut want = raw;
        crate::peft::merge_lora(&mut want, &meta);
        assert_eq!(got.keys().collect::<Vec<_>>(), want.keys().collect::<Vec<_>>());
        for (k, t) in &want {
            let g: Vec<u32> = got[k].data.iter().map(|x| x.to_bits()).collect();
            let w: Vec<u32> = t.data.iter().map(|x| x.to_bits()).collect();
            assert_eq!(g, w, "param {k}");
        }
    }

    #[test]
    fn delta_from_params_rejects_unrepresentable() {
        let base = base_map();
        // dense change: every entry of v trained → merged fallback
        let mut dense = base.clone();
        for x in &mut dense.get_mut("v").unwrap().data {
            *x += 1.0;
        }
        assert!(delta_from_params(&base, &dense, &lora_meta()).is_none());
        // structurally unrepresentable methods bail regardless of content
        let mut meta = lora_meta();
        meta.method = PeftMethod::Prompt;
        assert!(delta_from_params(&base, &base.clone(), &meta).is_none());
        meta.method = PeftMethod::Dora(Target::LinProj);
        assert!(delta_from_params(&base, &base.clone(), &meta).is_none());
        // a raw leaf the base lacks has nowhere to delta against
        let mut extra = base.clone();
        extra.insert("mystery".to_string(), Tensor::zeros(&[4]));
        assert!(delta_from_params(&base, &extra, &lora_meta()).is_none());
        // lora_a without its lora_b partner is malformed
        let mut widowed = base.clone();
        widowed.insert("w.lora_a".to_string(), Tensor::zeros(&[2, 1]));
        assert!(delta_from_params(&base, &widowed, &lora_meta()).is_none());
        // the identity adapter is representable and empty
        let id = delta_from_params(&base, &base.clone(), &lora_meta()).unwrap();
        assert!(id.lora.is_empty() && id.sparse.is_empty() && id.h0.is_empty());
        assert_eq!(id.resident_bytes(), 0);
    }

    #[test]
    fn registry_accounts_delta_bytes_not_model_copies() {
        let base = base_map();
        let mut raw = base.clone();
        raw.get_mut("v").unwrap().data[1] = 99.0;
        let delta = delta_from_params(&base, &raw, &lora_meta()).unwrap();
        let delta_bytes = delta.resident_bytes();
        let model_bytes: usize = base.values()
            .map(|t| t.numel() * std::mem::size_of::<f32>())
            .sum();
        assert!(delta_bytes < model_bytes,
                "delta ({delta_bytes} B) must undercut a full copy ({model_bytes} B)");
        let source = move |name: &str| -> Result<Adapter> {
            Ok(Adapter {
                name: name.to_string(),
                decode_variant: "a_full".into(),
                delta: Some(Arc::new(delta_from_params(&base, &raw, &lora_meta())
                    .context("delta")?)),
                h0: None,
                budget_pct: 1.0,
            })
        };
        let reg = AdapterRegistry::new(source, 4);
        reg.get("x").unwrap();
        reg.get("y").unwrap();
        assert_eq!(reg.stats().resident_bytes, 2 * delta_bytes);
        // and the closure source refuses merged materialization by default
        assert!(reg.load_merged("x").is_err());
    }

    #[test]
    fn circuit_breaker_quarantines_after_threshold() {
        let loads = Arc::new(AtomicUsize::new(0));
        let reg = AdapterRegistry::new(counting_source(loads.clone()), 4);
        reg.get("a").unwrap();
        assert!(!reg.record_failure("a"));
        assert!(!reg.record_failure("a"));
        assert!(!reg.is_quarantined("a"));
        assert!(reg.get("a").is_ok(), "below threshold: still served");
        assert!(reg.record_failure("a"), "third failure opens the circuit");
        assert!(reg.is_quarantined("a"));
        assert!(!reg.contains("a"), "quarantine drops the cached delta");
        let e = reg.get("a").unwrap_err();
        assert_eq!(e.kind(), ErrorKind::Request);
        assert!(format!("{e}").contains("quarantined"), "{e}");
        assert!(reg.load_merged("a").is_err(), "merged path rejects too");
        assert_eq!(reg.stats().quarantined, 1);
        // repeated failures don't "re-open" an open circuit
        assert!(!reg.record_failure("a"));
        // other adapters are unaffected
        reg.get("b").unwrap();
        // operator reinstatement closes the circuit and reloads
        let before = loads.load(Ordering::Relaxed);
        reg.reinstate("a");
        assert!(!reg.is_quarantined("a"));
        reg.get("a").unwrap();
        assert_eq!(loads.load(Ordering::Relaxed), before + 1, "fresh load");
        assert_eq!(reg.stats().quarantined, 0);
    }

    #[test]
    fn half_open_probation_reinstates_on_trial_success() {
        let loads = Arc::new(AtomicUsize::new(0));
        let failing = Arc::new(std::sync::atomic::AtomicBool::new(true));
        let (l2, f2) = (loads.clone(), failing.clone());
        let source = move |name: &str| -> Result<Adapter> {
            l2.fetch_add(1, Ordering::Relaxed);
            if f2.load(Ordering::Relaxed) {
                bail!("adapter store offline");
            }
            Ok(dummy(name))
        };
        let mut reg = AdapterRegistry::new(source, 4);
        reg.set_quarantine_threshold(1);
        reg.set_probation_ticks(3);
        assert!(reg.record_failure("a"), "threshold 1: first failure opens");
        assert!(reg.get("a").is_err());
        reg.note_tick();
        reg.note_tick();
        assert!(!reg.is_half_open("a"), "2 of 3 ticks: still open");
        assert_eq!(reg.get("a").unwrap_err().kind(), ErrorKind::Request);
        reg.note_tick();
        assert!(reg.is_half_open("a"), "3rd tick arms the probe");
        // trial load fails (source still down) → re-opened, clock reset
        let e = reg.get("a").unwrap_err();
        assert!(format!("{e}").contains("probation trial"), "{e}");
        assert!(reg.is_quarantined("a") && !reg.is_half_open("a"));
        assert_eq!(
            reg.get("a").unwrap_err().kind(),
            ErrorKind::Request,
            "one probe per window: the circuit re-opened"
        );
        assert_eq!(loads.load(Ordering::Relaxed), 1, "exactly one trial load");
        // wait out a full window again; this time the source has recovered
        failing.store(false, Ordering::Relaxed);
        for _ in 0..3 {
            reg.note_tick();
        }
        let a = reg.get("a").expect("trial success closes the circuit");
        assert_eq!(a.name, "a");
        assert!(!reg.is_quarantined("a"));
        let st = reg.stats();
        assert_eq!((st.probations, st.reinstated, st.quarantined), (2, 1, 0));
        // reinstatement cleared the failure count: the breaker re-arms
        assert!(reg.record_failure("a"), "fresh failures re-open from zero");
    }

    #[test]
    fn probation_zero_keeps_the_circuit_operator_only() {
        let loads = Arc::new(AtomicUsize::new(0));
        let mut reg = AdapterRegistry::new(counting_source(loads), 4);
        reg.set_quarantine_threshold(1);
        reg.set_probation_ticks(0);
        assert!(reg.record_failure("a"));
        for _ in 0..1000 {
            reg.note_tick();
        }
        assert!(reg.is_quarantined("a") && !reg.is_half_open("a"));
        assert!(reg.get("a").is_err(), "no automatic probation when disabled");
        reg.reinstate("a");
        reg.get("a").expect("operator reinstatement still works");
    }

    #[test]
    fn injected_load_faults_are_classified() {
        use crate::fault::FaultPlan;
        let loads = Arc::new(AtomicUsize::new(0));
        let plan = Arc::new(
            FaultPlan::seeded(3)
                .with_fault_at(FaultSite::AdapterLoad, 0)
                .with_fault_at(FaultSite::ArtifactRead, 0),
        );
        let mut reg = AdapterRegistry::new(counting_source(loads.clone()), 4);
        reg.set_fault_inject(plan.clone());
        let e = reg.get("a").unwrap_err();
        assert_eq!(e.kind(), ErrorKind::Runtime, "plan's default kind survives");
        assert_eq!(loads.load(Ordering::Relaxed), 0,
                   "fault fires before the source loads");
        assert!(!reg.contains("a"), "failed load caches nothing");
        assert!(reg.load_merged("a").unwrap_err().kind() == ErrorKind::Runtime);
        // the next checks pass (single-shot faults) — the cache recovers
        reg.get("a").unwrap();
        assert_eq!(plan.injected(FaultSite::AdapterLoad), 1);
        assert_eq!(plan.injected(FaultSite::ArtifactRead), 1);
    }

    #[test]
    fn pin_balance_survives_churn_with_injected_errors() {
        // seeded property: interleaved get/pin/unpin churn where loads
        // randomly fail — every successful get is pinned once and unpinned
        // once, so the outstanding pin count must come back to zero
        use crate::fault::FaultPlan;
        use crate::tensor::Rng;
        let loads = Arc::new(AtomicUsize::new(0));
        let plan =
            Arc::new(FaultPlan::seeded(42).with_rate(FaultSite::AdapterLoad, 0.3));
        let mut reg = AdapterRegistry::new(counting_source(loads), 2);
        reg.set_fault_inject(plan);
        let mut rng = Rng::new(99);
        let names = ["a", "b", "c", "d", "bad"];
        let mut held: Vec<String> = Vec::new();
        for _ in 0..200 {
            let name = names[(rng.next_u64() % names.len() as u64) as usize];
            if rng.next_u64() % 2 == 0 || held.is_empty() {
                if reg.get(name).is_ok() {
                    reg.pin(name);
                    held.push(name.to_string());
                }
            } else {
                let i = (rng.next_u64() % held.len() as u64) as usize;
                let name = held.swap_remove(i);
                reg.unpin(&name);
            }
        }
        for name in held.drain(..) {
            reg.unpin(&name);
        }
        assert_eq!(reg.stats().pins, 0, "every pin released exactly once");
    }

    #[test]
    #[should_panic(expected = "unpin without a matching pin")]
    #[cfg(debug_assertions)]
    fn unbalanced_unpin_asserts_in_debug() {
        let loads = Arc::new(AtomicUsize::new(0));
        let reg = AdapterRegistry::new(counting_source(loads), 2);
        reg.get("a").unwrap();
        reg.unpin("a");
    }
}
