//! Adapter registry: lazily materialized, LRU-capped cache of decode-ready
//! parameter sets — one per fine-tuned variant served from the shared base.
//!
//! Materializing an adapter is the expensive step (read the variant's
//! parameter layout, overlay the staged pretrained base and any trained
//! checkpoint, fold LoRA/DoRA factors with [`crate::peft::merge_lora`],
//! split out trained initial states). The registry does it once per
//! adapter, hands out `Arc<Adapter>` clones, and evicts the least recently
//! used entry when the cap is exceeded. Evicted adapters that are still
//! bound to an active scheduler lane stay alive through their `Arc` until
//! the lane retires.
//!
//! The loading policy lives behind the [`AdapterSource`] trait so the LRU
//! machinery is unit-testable without artifacts; [`ManifestSource`] is the
//! real policy used by the `serve` subcommand.

use std::collections::BTreeMap;
use std::collections::VecDeque;
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::bail;
use crate::error::{Context, Result};

use crate::manifest::Manifest;
use crate::peft::{self, Budget};
use crate::suite::VariantId;
use crate::tensor::Tensor;
use crate::train::checkpoint;

/// A decode-ready adapter: merged parameters for one fine-tuned variant.
pub struct Adapter {
    /// Adapter id as requested (variant name, optionally `@ckpt-path`).
    pub name: String,
    /// The decode-capable variant the merged parameters target
    /// (`<arch>_full` — see [`VariantId::decode_variant`]).
    pub decode_variant: String,
    /// Merged parameter map: base weights with LoRA/DoRA deltas folded in.
    pub params: BTreeMap<String, Tensor>,
    /// Trained initial states (`layers.{i}.h0`), present for
    /// initial-state-tuning adapters; seeds each admitted request's SSM
    /// state ([`crate::eval::StateDims::init_states`]).
    pub h0: Option<Arc<BTreeMap<String, Tensor>>>,
    /// Trainable-parameter budget of the source variant, percent (the
    /// paper's "# Params (%)" column — reported in serve stats).
    pub budget_pct: f64,
}

/// Where adapters come from: maps an adapter id to a materialized
/// [`Adapter`]. Closures implement it, so tests can count loads.
pub trait AdapterSource {
    /// Materialize the adapter for `name` (expensive; called on cache miss).
    fn load(&self, name: &str) -> Result<Adapter>;
}

impl<F: Fn(&str) -> Result<Adapter>> AdapterSource for F {
    fn load(&self, name: &str) -> Result<Adapter> {
        self(name)
    }
}

/// Cache counters (all monotone; read via [`AdapterRegistry::stats`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RegistryStats {
    /// Requests served from cache.
    pub hits: usize,
    /// Requests that materialized a new adapter.
    pub misses: usize,
    /// Adapters dropped by the LRU policy.
    pub evictions: usize,
    /// Adapters currently resident.
    pub resident: usize,
}

struct Inner {
    map: BTreeMap<String, Arc<Adapter>>,
    /// Recency order, least recently used first.
    order: VecDeque<String>,
}

/// LRU-capped adapter cache. `get` is the only entry point: hit moves the
/// adapter to most-recently-used; miss materializes through the
/// [`AdapterSource`] and evicts the least recently used entry past `cap`.
pub struct AdapterRegistry<S> {
    source: S,
    cap: usize,
    inner: Mutex<Inner>,
    hits: AtomicUsize,
    misses: AtomicUsize,
    evictions: AtomicUsize,
}

impl<S: AdapterSource> AdapterRegistry<S> {
    /// New registry holding at most `cap` materialized adapters (min 1).
    pub fn new(source: S, cap: usize) -> AdapterRegistry<S> {
        AdapterRegistry {
            source,
            cap: cap.max(1),
            inner: Mutex::new(Inner { map: BTreeMap::new(), order: VecDeque::new() }),
            hits: AtomicUsize::new(0),
            misses: AtomicUsize::new(0),
            evictions: AtomicUsize::new(0),
        }
    }

    /// Fetch (materializing on first use) the adapter for `name`.
    pub fn get(&self, name: &str) -> Result<Arc<Adapter>> {
        {
            let mut inner = self.inner.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
            if let Some(a) = inner.map.get(name).cloned() {
                // refresh recency
                inner.order.retain(|k| k != name);
                inner.order.push_back(name.to_string());
                self.hits.fetch_add(1, Ordering::Relaxed);
                return Ok(a);
            }
        }
        // materialize outside the lock: a slow load must not block stats
        // readers; the serve loop admits sequentially so duplicate loads
        // don't arise in practice (and would only waste work, not break)
        let adapter = Arc::new(self.source.load(name)?);
        self.misses.fetch_add(1, Ordering::Relaxed);
        let mut inner = self.inner.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        if !inner.map.contains_key(name) {
            inner.map.insert(name.to_string(), adapter.clone());
            inner.order.push_back(name.to_string());
            while inner.map.len() > self.cap {
                if let Some(victim) = inner.order.pop_front() {
                    inner.map.remove(&victim);
                    self.evictions.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
        Ok(adapter)
    }

    /// Whether `name` is currently resident (does not touch recency).
    pub fn contains(&self, name: &str) -> bool {
        self.inner.lock().unwrap_or_else(std::sync::PoisonError::into_inner).map.contains_key(name)
    }

    /// Cache counters snapshot.
    pub fn stats(&self) -> RegistryStats {
        RegistryStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            resident: self.inner.lock().unwrap_or_else(std::sync::PoisonError::into_inner).map.len(),
        }
    }
}

/// The real adapter source: manifest layout + staged pretrained base +
/// optional trained checkpoints.
///
/// Adapter ids are variant names (`mamba1_xs_lora_lin`), optionally with an
/// explicit trained checkpoint: `mamba1_xs_lora_lin@results/rte.ckpt`.
/// Without `@`, `adapter_dir/<variant>.ckpt` is used when present;
/// otherwise the variant's fresh initialization serves (LoRA deltas start
/// at zero, so an untrained adapter behaves as the base model).
pub struct ManifestSource<'a> {
    /// Artifact manifest (parameter layouts, PEFT metadata).
    pub manifest: &'a Manifest,
    /// Architecture the staged base was pretrained for (e.g. "mamba1_xs");
    /// adapters of other architectures are rejected.
    pub base_arch: String,
    /// The staged pretrained base checkpoint shared by every adapter.
    pub base: Arc<BTreeMap<String, Tensor>>,
    /// Directory searched for `<variant>.ckpt` trained-adapter files.
    pub adapter_dir: Option<PathBuf>,
}

impl ManifestSource<'_> {
    fn resolve_ckpt(&self, variant: &str, explicit: Option<&str>) -> Option<PathBuf> {
        if let Some(p) = explicit {
            return Some(PathBuf::from(p));
        }
        let p = self.adapter_dir.as_ref()?.join(format!("{variant}.ckpt"));
        p.exists().then_some(p)
    }
}

impl AdapterSource for ManifestSource<'_> {
    fn load(&self, name: &str) -> Result<Adapter> {
        let (vname, ckpt) = match name.split_once('@') {
            Some((v, p)) => (v, Some(p)),
            None => (name, None),
        };
        let vid = VariantId::parse(vname)?;
        if vid.arch != self.base_arch {
            bail!(
                "adapter {vname:?} targets arch {:?} but the staged base is {:?}",
                vid.arch, self.base_arch
            );
        }
        let variant = self.manifest.variant(vname)?;
        // fresh init for every leaf (incl. adapter-only ones) ...
        let mut params = self.manifest.load_params(variant)?;
        // ... then the staged pretrained backbone wherever names align ...
        for (k, t) in self.base.iter() {
            if let Some(slot) = params.get_mut(k) {
                if slot.shape == t.shape {
                    *slot = t.clone();
                }
            }
        }
        // ... then trained adapter weights, if a checkpoint exists
        if let Some(path) = self.resolve_ckpt(vname, ckpt) {
            let trained = checkpoint::load(&path)
                .with_context(|| format!("loading adapter checkpoint {path:?}"))?;
            let total = trained.len();
            let mut applied = 0usize;
            for (k, t) in trained {
                if let Some(slot) = params.get_mut(&k) {
                    if slot.shape == t.shape {
                        *slot = t;
                        applied += 1;
                    }
                }
            }
            // a checkpoint that contributes nothing means a wrong file or
            // a drifted layout — serving silently-untrained weights as the
            // requested adapter would be worse than refusing
            if applied == 0 {
                bail!(
                    "adapter checkpoint {path:?} matched none of {vname}'s \
                     parameters ({total} tensors, all skipped by name/shape)"
                );
            }
            if applied < total {
                eprintln!(
                    "[serve] warning: adapter {name}: {}/{total} checkpoint \
                     tensors skipped (name/shape mismatch vs {vname})",
                    total - applied,
                );
            }
        }
        let budget_pct = Budget::of(variant, None).percent();
        peft::merge_lora(&mut params, &variant.peft);
        let h0_map: BTreeMap<String, Tensor> = params
            .iter()
            .filter(|(k, _)| k.ends_with(".h0"))
            .map(|(k, v)| (k.clone(), v.clone()))
            .collect();
        let h0 = (!h0_map.is_empty()).then(|| Arc::new(h0_map));
        Ok(Adapter {
            name: name.to_string(),
            decode_variant: vid.decode_variant(),
            params,
            h0,
            budget_pct,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dummy(name: &str) -> Adapter {
        Adapter {
            name: name.to_string(),
            decode_variant: "a_full".into(),
            params: BTreeMap::new(),
            h0: None,
            budget_pct: 1.0,
        }
    }

    fn counting_source(loads: Arc<AtomicUsize>)
        -> impl Fn(&str) -> Result<Adapter> {
        move |name: &str| {
            loads.fetch_add(1, Ordering::Relaxed);
            if name == "bad" {
                bail!("no such adapter");
            }
            Ok(dummy(name))
        }
    }

    #[test]
    fn lru_evicts_least_recently_used() {
        let loads = Arc::new(AtomicUsize::new(0));
        let reg = AdapterRegistry::new(counting_source(loads.clone()), 2);
        reg.get("a").unwrap();
        reg.get("b").unwrap();
        reg.get("a").unwrap(); // refresh a → b is now LRU
        reg.get("c").unwrap(); // evicts b
        assert!(reg.contains("a"));
        assert!(reg.contains("c"));
        assert!(!reg.contains("b"), "b was least recently used");
        assert_eq!(loads.load(Ordering::Relaxed), 3);
        // b comes back only via a re-load
        reg.get("b").unwrap();
        assert_eq!(loads.load(Ordering::Relaxed), 4);
        let st = reg.stats();
        assert_eq!(st.misses, 4);
        assert_eq!(st.hits, 1);
        assert_eq!(st.evictions, 2, "c evicted b, then b evicted a");
        assert_eq!(st.resident, 2);
    }

    #[test]
    fn hits_do_not_reload() {
        let loads = Arc::new(AtomicUsize::new(0));
        let reg = AdapterRegistry::new(counting_source(loads.clone()), 4);
        let a1 = reg.get("a").unwrap();
        let a2 = reg.get("a").unwrap();
        assert!(Arc::ptr_eq(&a1, &a2), "hit returns the shared Arc");
        assert_eq!(loads.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn load_errors_propagate_and_cache_nothing() {
        let loads = Arc::new(AtomicUsize::new(0));
        let reg = AdapterRegistry::new(counting_source(loads.clone()), 2);
        assert!(reg.get("bad").is_err());
        assert!(!reg.contains("bad"));
        assert_eq!(reg.stats().resident, 0);
    }

    #[test]
    fn cap_floor_is_one() {
        let loads = Arc::new(AtomicUsize::new(0));
        let reg = AdapterRegistry::new(counting_source(loads), 0);
        reg.get("a").unwrap();
        reg.get("b").unwrap();
        assert_eq!(reg.stats().resident, 1);
    }
}
