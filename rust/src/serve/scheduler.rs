//! Continuous-batching scheduler: packs decode steps from independent
//! requests into the batch dimension of the stepwise decode executable.
//!
//! The key property it exploits is the SSM's **O(1) per-sequence state**:
//! unlike a KV cache, a row's recurrent state has a fixed size, so a batch
//! row can be retired and re-seeded for a new request between any two
//! decode steps — admission and retirement happen *per step*, not per
//! batch.
//!
//! Layout: the scheduler runs two kinds of batches, chosen by what the
//! [`ServeFactory`] hands back per adapter:
//!
//! - **Merged lanes** — one *lane* per adapter. A lane owns a decode model
//!   (the adapter's merged parameters bound to the shared executable — see
//!   [`crate::serve::AdapterRegistry::load_merged`]), the batched recurrent
//!   state (a [`crate::eval::DecodeState`], literal-resident between
//!   admissions), and `arch_b` request slots. When the lane's model
//!   supports [`crate::eval::ChunkPrefill`], admission is
//!   **prefill-then-admit** (§Perf L5): the newly staged requests' prompt
//!   prefixes are scanned out-of-band on a scratch state — batched across
//!   waiters admitted to the same lane, ceil(P/C) dispatches instead of
//!   P — and each finished row is spliced into the lane's live
//!   [`DecodeState`].
//! - **The shared unmerged lane** — ONE batch for *all* adapters the
//!   factory maps to [`ServeModel::Shared`]. Each row carries its own
//!   [`crate::eval::AdapterDelta`] (LoRA factors + SDT sparse offsets) and
//!   the whole mixed batch advances with a single
//!   [`crate::eval::AdapterStepDecode::step_rows`] dispatch per tick,
//!   instead of one dispatch per resident adapter. Per-adapter lanes
//!   collapse into this batch whenever the serving stack has an unmerged
//!   decode core (`server.rs` falls back to merged lanes when it doesn't).
//!   Prompt ingestion on the shared lane is step-wise — the byte
//!   equivalence contract of `step_rows` covers prefill tokens too.
//!
//! Each [`Scheduler::tick`]:
//!
//! 1. **admits** queued requests into free slots (FIFO; a request whose
//!    batch is full waits without blocking requests for other adapters —
//!    that is the backpressure contract),
//! 2. runs **one decode step per active batch** (merged lanes round-robin
//!    by iteration order, then the shared lane; every slot in a batch
//!    advances together),
//! 3. **retires** finished rows (stop byte, `max_new`, or error) into
//!    [`Response`]s, freeing their slots for the next admission.
//!
//! Shared-lane rows hold adapter resources (registry pins) that must be
//! returned: the scheduler reports every [`ServeModel::Shared`] factory
//! result back through the [`RetireHook`] exactly once — on retirement,
//! failure, requeue after a full batch, or beam completion.
//!
//! Beam requests (`beam > 1`) need the whole batch dimension for their
//! beams, so they run as a dedicated synchronous [`crate::eval::beam_search`]
//! pass at admission time instead of sharing a batch; shared-model beams
//! run over a [`crate::eval::PinnedAdapter`] view.
//!
//! **Fault tolerance** (rust/docs/robustness.md): every request moves
//! through a hardened lifecycle. Per-request deadlines are enforced in
//! ticks by a watchdog at the top of every [`Scheduler::tick`]; step
//! errors are classified by [`ErrorKind::is_transient`] and transient
//! ones retry in place — the batch state is captured with
//! [`DecodeState::checkpoint`] before each fault-guarded step and rolled
//! back on failure, with a deterministic exponential tick backoff between
//! attempts. When the SHARED batch exhausts its step-retry budget (or
//! hits a terminal error), its rows are not failed: they demote to
//! per-adapter merged lanes via the [`Scheduler::set_merged_fallback`]
//! hook, where the one faulty adapter fails alone (and is reported
//! through [`Scheduler::on_adapter_failure`] toward quarantine) while the
//! innocent rows re-decode to their exact solo bytes. Only when every
//! rung of that cascade is gone does a request retire with a typed
//! [`FinishReason::Failed`].
//!
//! **Sessions** (rust/docs/robustness.md § Sessions): when a
//! [`SessionStore`] is installed ([`Scheduler::set_session_store`]), a
//! request carrying a [`Request::session`] id tries to *resurrect* its
//! conversation at admission: the stored `(conv, ssm)` row — the SSM's
//! O(1) summary of the entire history — is spliced into the freshly
//! admitted slot ([`DecodeState::splice_row_from`]) and the slot
//! fast-forwards past the absorbed prefix, skipping prefill entirely.
//! Retirement snapshots the row's state back into the store (the
//! [`DecodeState::row_snapshot`] readback) tagged with the absorbed
//! token count and a digest of the absorbed byte history, so a resumed
//! turn can prove it continues the exact same conversation. EVERY
//! session-layer failure — load fault, corrupt record, stale digest,
//! geometry drift — degrades the request to ordinary full-history
//! prefill (counted in [`Scheduler::session_fallbacks`]), never a
//! wrong state.

use std::collections::{BTreeMap, VecDeque};
use std::sync::Arc;

use crate::error::{ErrorKind, Result};

use crate::data::{BOS, PAD};
use crate::eval::{
    beam_search, AdapterRow, AdapterStepDecode, DecodeState, PinnedAdapter, StepDecode,
};
use crate::obs::{Clock, Span, Trace, TraceRing, WallClock};
use crate::serve::sessions::{history_digest, SessionSnapshot, SessionStore};
use crate::tensor::{argmax, IntTensor, Tensor};

/// One generation request.
#[derive(Debug, Clone)]
pub struct Request {
    /// Server-assigned id, echoed in the [`Response`].
    pub id: u64,
    /// Adapter id (see [`crate::serve::ManifestSource`] for the syntax).
    pub adapter: String,
    /// Prompt bytes.
    pub prompt: Vec<u8>,
    /// Cap on generated bytes.
    pub max_new: usize,
    /// Generation stops when this byte is produced (not emitted).
    pub stop_byte: u8,
    /// Beam width; 1 = greedy (continuously batched), >1 = a dedicated
    /// beam-search pass.
    pub beam: usize,
    /// Deadline in scheduler ticks from submission (0 = none). A request
    /// still queued or decoding this many ticks after submission retires
    /// with [`FinishReason::Failed`] (`ErrorKind::Exhausted`).
    pub deadline: usize,
    /// Durable conversation id (`None` = stateless request). With a
    /// [`SessionStore`] installed, admission resurrects this session's
    /// stored state (skipping prefill when the stored history is a
    /// prefix of [`Request::prompt`]) and retirement snapshots the
    /// row's state back under this id.
    pub session: Option<String>,
}

/// Why a request finished.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    /// The stop byte was generated.
    Stop,
    /// `max_new` bytes were generated.
    Length,
    /// The request failed with an unclassified error (see
    /// [`Response::error`]).
    Error,
    /// The request failed with a classified error: the lifecycle layer
    /// exhausted its retries/fallbacks, a deadline or budget expired
    /// (`ErrorKind::Exhausted`), or the adapter was rejected
    /// (`ErrorKind::Request`, e.g. quarantined).
    Failed {
        /// Classification of the terminal error.
        kind: ErrorKind,
    },
}

impl FinishReason {
    /// Wire label (serving JSON `finish` field).
    pub fn label(self) -> &'static str {
        match self {
            FinishReason::Stop => "stop",
            FinishReason::Length => "length",
            FinishReason::Error => "error",
            FinishReason::Failed { kind } => match kind {
                ErrorKind::Io => "failed:io",
                ErrorKind::Parse => "failed:parse",
                ErrorKind::Request => "failed:request",
                ErrorKind::Runtime => "failed:runtime",
                ErrorKind::Invariant => "failed:invariant",
                ErrorKind::Exhausted => "failed:exhausted",
                _ => "failed",
            },
        }
    }
}

/// A finished request with its latency/throughput accounting.
#[derive(Debug, Clone)]
pub struct Response {
    /// Echo of [`Request::id`].
    pub id: u64,
    /// Echo of [`Request::adapter`].
    pub adapter: String,
    /// Generated bytes (stop byte excluded).
    pub output: Vec<u8>,
    /// Prompt length in bytes.
    pub prompt_len: usize,
    /// Seconds spent queued before admission.
    pub queued_s: f64,
    /// Seconds from submission to completion.
    pub total_s: f64,
    /// Decode steps this request occupied a slot for (prefill + decode).
    pub steps: u64,
    /// Why the request finished.
    pub finish: FinishReason,
    /// Failure message when the request did not finish cleanly.
    pub error: Option<String>,
    /// How many times this request was requeued by the lifecycle layer
    /// (transient factory retries + shared-batch demotions) before it
    /// finished.
    pub retries: u64,
    /// Echo of [`Request::session`].
    pub session: Option<String>,
}

impl Response {
    /// Generated bytes per second over the request's slot occupancy
    /// (queue wait excluded — `total_s` includes it, so a backpressured
    /// request must not look slower than the lane actually ran it).
    pub fn tok_per_s(&self) -> f64 {
        // [`crate::obs::rate_per_s`] clamps zero/negative occupancy to 0.0
        crate::obs::rate_per_s(self.output.len() as f64, self.total_s - self.queued_s)
    }
}

/// A merged-parameter decode model bound to one adapter, plus that
/// adapter's trained initial state (if any).
pub struct LaneModel {
    /// Stepwise decode model (parameters already bound).
    pub model: Arc<dyn StepDecode>,
    /// `layers.{i}.h0` seed applied to every admitted request's SSM state.
    pub h0: Option<Arc<BTreeMap<String, Tensor>>>,
}

/// What a [`ServeFactory`] returns for an adapter: either a dedicated
/// merged-parameter lane, or a row assignment on the shared unmerged
/// batch.
pub enum ServeModel {
    /// Whole-model merged parameters: the adapter gets its own lane
    /// (legacy path; also the fallback for deltas the unmerged core
    /// cannot represent).
    Merged(LaneModel),
    /// A per-row delta over the shared base: the request joins the one
    /// mixed-adapter batch. The scheduler reports this result back through
    /// the [`RetireHook`] exactly once when the delta is released.
    Shared {
        /// The shared unmerged decode core (same `Arc` for every adapter).
        model: Arc<dyn AdapterStepDecode>,
        /// This adapter's delta; `None` = serve the plain base.
        delta: AdapterRow,
        /// `layers.{i}.h0` seed applied to the admitted request's row.
        h0: Option<Arc<BTreeMap<String, Tensor>>>,
    },
}

/// Builds the decode resources for an adapter on first use. The serve
/// path closes over the adapter registry + engine; tests hand out mocks.
pub type ServeFactory<'a> = Box<dyn Fn(&str) -> Result<ServeModel> + 'a>;

/// Called (with the adapter name) exactly once per [`ServeModel::Shared`]
/// factory result when the scheduler lets go of it — row retired or
/// failed, requeued because the shared batch was full, or a beam pass
/// completed. The server uses it to unpin registry entries.
pub type RetireHook<'a> = Box<dyn Fn(&str) + 'a>;

struct Slot {
    req: Request,
    /// Decode steps taken for this slot (tokens consumed, incl. BOS).
    t: usize,
    out: Vec<u8>,
    /// Preallocated span timeline (obs): phase stamps are plain `u64`
    /// stores into this field — zero per-step allocation.
    span: Span,
    /// The tick the request was submitted on (deadline watchdog input).
    submit_tick: u64,
    /// Requeues the request went through before this admission.
    attempts: u32,
}

struct Lane {
    model: Arc<dyn StepDecode>,
    h0: Option<Arc<BTreeMap<String, Tensor>>>,
    /// Batched recurrent state. Stays literal-resident while the lane's
    /// slot population is unchanged; admission touches exactly the
    /// recycled row and pays one host sync (§Perf L4).
    state: DecodeState,
    cur: IntTensor,
    slots: Vec<Option<Slot>>,
    /// Rows staged this tick and awaiting out-of-band prefill (only
    /// populated when the model supports chunked prefill).
    pending_prefill: Vec<usize>,
    /// Consecutive failed step attempts (reset on a successful step).
    attempts: u32,
    /// Ticks left to sit out before the next step attempt (deterministic
    /// exponential backoff: `1 << (attempts - 1)`).
    cooldown: u64,
}

impl Lane {
    fn new(lm: LaneModel) -> Lane {
        let b = lm.model.arch_b();
        let state = lm.model.new_state(lm.h0.as_deref());
        Lane {
            model: lm.model,
            h0: lm.h0,
            state,
            cur: IntTensor::from_vec(&[b], vec![PAD; b]),
            slots: (0..b).map(|_| None).collect(),
            pending_prefill: Vec::new(),
            attempts: 0,
            cooldown: 0,
        }
    }

    fn active(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    fn free_slot(&self) -> Option<usize> {
        self.slots.iter().position(Option::is_none)
    }

    /// Seed the recycled row and install the request; returns the row
    /// index. Hands the request back on failure so the scheduler can
    /// retire it as an error. The slot is staged exactly as the
    /// step-wise path expects (`t = 0`, `cur = BOS`); a following
    /// [`Lane::flush_prefill`] — or a session resurrection — may
    /// fast-forward it past its prompt prefix.
    fn admit(&mut self, req: Request, span: Span, submit_tick: u64,
             attempts: u32)
        -> std::result::Result<usize, (Request, crate::error::Error)> {
        let Some(r) = self.free_slot() else {
            // caller checked capacity; surface the broken invariant as a
            // per-request failure instead of killing the lane thread
            return Err((req, crate::err!("scheduler invariant: admit without a free slot")));
        };
        let b = self.model.arch_b();
        if let Err(e) = self.state.reset_row(&self.model.dims(), b, r, self.h0.as_deref()) {
            return Err((req, e));
        }
        self.cur.data[r] = BOS;
        self.slots[r] = Some(Slot {
            req,
            t: 0,
            out: Vec::new(),
            span,
            submit_tick,
            attempts,
        });
        if self.model.chunk_prefill().is_some() {
            self.pending_prefill.push(r);
        }
        Ok(r)
    }

    /// Out-of-band chunked prefill for the rows staged this tick
    /// (§Perf L5). Each pending request's coverable stream — BOS plus all
    /// but its LAST prompt byte — is scanned through the prefill
    /// executables on a scratch state, batched across waiters (one
    /// dispatch advances every pending row by C tokens). Finished rows
    /// are spliced into the lane's live state and their slots fast-
    /// forwarded (`t`, `cur`), so the lane's own step consumes the final
    /// prompt byte and emits the first generated token exactly like the
    /// step-wise path. Rows whose prompts are shorter than the smallest
    /// chunk width — and everything left after a prefill error — simply
    /// stay staged and ingest their prompt step-wise (graceful fallback).
    /// Returns `(chunk dispatches, prompt tokens fast-forwarded)`.
    fn flush_prefill(&mut self) -> (u64, u64) {
        let rows = std::mem::take(&mut self.pending_prefill);
        let Some(pf) = self.model.chunk_prefill() else { return (0, 0) };
        let widths = pf.chunk_widths().to_vec();
        let Some(&wmin) = widths.first() else { return (0, 0) };
        let b = self.model.arch_b();
        let dims = self.model.dims();
        // (row, coverable stream length): rows below the smallest chunk
        // width never enter the batch, so they don't cap the others
        let mut active: Vec<(usize, usize)> = rows
            .iter()
            .filter_map(|&r| {
                let j = self.slots[r].as_ref().map_or(0, |s| s.req.prompt.len());
                (j >= wmin).then_some((r, j))
            })
            .collect();
        if active.is_empty() {
            return (0, 0);
        }
        // Copy scratch row `r` (advanced `c` tokens) into the lane state
        // and fast-forward its slot. A failed splice leaves the slot
        // staged at t = 0: step-wise fallback. Returns the tokens covered.
        // (A free fn, not a method: the caller holds a borrow of
        // `self.model` through `pf`, so only disjoint fields may be touched.)
        fn splice(state: &mut DecodeState, scratch: &mut DecodeState,
                  slots: &mut [Option<Slot>], cur: &mut IntTensor,
                  dims: &crate::eval::StateDims, b: usize, r: usize, c: usize)
            -> u64 {
            if state.splice_row_from(dims, b, scratch, r, r).is_err() {
                return 0;
            }
            let Some(slot) = slots[r].as_mut() else { return 0 };
            slot.t = c;
            cur.data[r] = slot.req.prompt[c - 1] as i32;
            c as u64
        }

        let mut scratch = self.model.new_state(self.h0.as_deref());
        let mut pos = 0usize;
        let mut dispatches = 0u64;
        let mut covered = 0u64;
        loop {
            let rem = active.iter().map(|&(_, j)| j - pos).min().unwrap_or(0);
            let Some(&w) = widths.iter().rev().find(|&&w| w <= rem) else { break };
            let mut toks = IntTensor::from_vec(&[b, w], vec![PAD; b * w]);
            for &(r, _) in &active {
                let Some(slot) = self.slots[r].as_ref() else { continue };
                for i in 0..w {
                    let t = pos + i;
                    toks.data[r * w + i] =
                        if t == 0 { BOS } else { slot.req.prompt[t - 1] as i32 };
                }
            }
            if pf.prefill_chunk(&toks, &mut scratch).is_err() {
                break; // scratch is still consistent at `pos`; fall back
            }
            dispatches += 1;
            pos += w;
            // splice fully-covered rows NOW: later chunks keep scanning the
            // scratch batch (their token slots degrade to PAD), so a row's
            // state must be copied out the moment its coverage completes
            let mut i = 0;
            while i < active.len() {
                if active[i].1 == pos {
                    let (r, _) = active.remove(i);
                    covered += splice(&mut self.state, &mut scratch,
                                      &mut self.slots, &mut self.cur, &dims, b,
                                      r, pos);
                } else {
                    i += 1;
                }
            }
            if active.is_empty() {
                break;
            }
        }
        if pos > 0 {
            // partially covered rows splice too; the lane ingests the tail
            for (r, _) in active {
                covered += splice(&mut self.state, &mut scratch, &mut self.slots,
                                  &mut self.cur, &dims, b, r, pos);
            }
        }
        (dispatches, covered)
    }

    /// One decode step for every occupied slot; returns retired rows.
    fn step(&mut self, now_ns: u64) -> Result<Vec<Retired>> {
        let logits = self.model.step(&self.cur, &mut self.state)?;
        Ok(advance_rows(&logits, &mut self.slots, &mut self.cur, now_ns))
    }
}

/// The shared unmerged batch: one [`DecodeState`] whose rows each carry
/// their own [`AdapterRow`] delta. All resident adapters advance with a
/// single `step_rows` dispatch per tick. No chunked prefill here — the
/// per-row byte-equivalence contract of `step_rows` covers the step-wise
/// prompt ingestion too, and [`PinnedAdapter`] deliberately exposes no
/// chunk path (see eval.rs).
struct SharedLane {
    model: Arc<dyn AdapterStepDecode>,
    state: DecodeState,
    cur: IntTensor,
    slots: Vec<Option<Slot>>,
    /// Per-row adapter assignment, kept in lockstep with `slots`.
    rows: Vec<AdapterRow>,
    /// Consecutive failed step attempts (reset on a successful step).
    attempts: u32,
    /// Ticks left to sit out before the next step attempt.
    cooldown: u64,
}

impl SharedLane {
    fn new(model: Arc<dyn AdapterStepDecode>) -> SharedLane {
        let b = model.arch_b();
        let state = model.new_state(None);
        SharedLane {
            model,
            state,
            cur: IntTensor::from_vec(&[b], vec![PAD; b]),
            slots: (0..b).map(|_| None).collect(),
            rows: vec![None; b],
            attempts: 0,
            cooldown: 0,
        }
    }

    fn active(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    fn free_slot(&self) -> Option<usize> {
        self.slots.iter().position(Option::is_none)
    }

    /// Seed the recycled row with this adapter's `h0`, bind its delta, and
    /// install the request; returns the row index. Hands the request back
    /// on failure.
    fn admit(&mut self, req: Request, span: Span, submit_tick: u64,
             attempts: u32, delta: AdapterRow,
             h0: Option<Arc<BTreeMap<String, Tensor>>>)
        -> std::result::Result<usize, (Request, crate::error::Error)> {
        let Some(r) = self.free_slot() else {
            return Err((req, crate::err!(
                "scheduler invariant: shared admit without a free slot")));
        };
        let b = self.model.arch_b();
        if let Err(e) = self.state.reset_row(&self.model.dims(), b, r, h0.as_deref()) {
            return Err((req, e));
        }
        self.cur.data[r] = BOS;
        self.rows[r] = delta;
        self.slots[r] = Some(Slot {
            req,
            t: 0,
            out: Vec::new(),
            span,
            submit_tick,
            attempts,
        });
        Ok(r)
    }

    /// One mixed-adapter decode step; retired rows drop their delta so the
    /// next admission starts clean (and the delta's `Arc` can be freed).
    fn step(&mut self, now_ns: u64) -> Result<Vec<Retired>> {
        let logits = self.model.step_rows(&self.cur, &mut self.state, &self.rows)?;
        let retired = advance_rows(&logits, &mut self.slots, &mut self.cur, now_ns);
        for r in 0..self.slots.len() {
            if self.slots[r].is_none() {
                self.rows[r] = None;
            }
        }
        Ok(retired)
    }
}

/// A row retired by [`advance_rows`]: the response plus the bookkeeping
/// the session layer needs to snapshot the row's state — which row it
/// was, and (for session-tagged requests) the id, absorbed token count,
/// and history digest at the moment of retirement. The state snapshot
/// itself is taken by the scheduler right after the step, while the
/// lane's [`DecodeState`] still holds the retired row untouched.
struct Retired {
    row: usize,
    /// `(session id, absorbed tokens incl. BOS, history digest)`;
    /// `None` for stateless requests.
    tag: Option<(String, u64, u64)>,
    response: Response,
    /// The request's frozen span timeline, pushed into the scheduler's
    /// [`TraceRing`] alongside the response.
    trace: Trace,
}

/// The shared retire loop: feed one step's logits to every occupied slot,
/// advance prompts, emit greedy tokens, retire finished rows. Used by both
/// merged lanes and the shared unmerged lane so the two paths cannot drift
/// in stop/`max_new`/accounting semantics.
fn advance_rows(logits: &Tensor, slots: &mut [Option<Slot>], cur: &mut IntTensor,
                now_ns: u64)
    -> Vec<Retired> {
    let v = logits.shape[1];
    let mut retired = Vec::new();
    for r in 0..slots.len() {
        let (next, finished) = {
            let Some(slot) = slots[r].as_mut() else { continue };
            let t = slot.t;
            slot.t += 1;
            if t < slot.req.prompt.len() {
                (slot.req.prompt[t] as i32, None) // still prefilling
            } else if slot.out.len() >= slot.req.max_new {
                // zero-budget request: finishes on its first decode step
                (PAD, Some(FinishReason::Length))
            } else {
                let row = &logits.data[r * v..r * v + 256];
                let tok = argmax(row) as u8;
                if tok == slot.req.stop_byte {
                    (PAD, Some(FinishReason::Stop))
                } else {
                    slot.out.push(tok);
                    if slot.span.first_token_ns == 0 {
                        // TTFT stamp: a plain store into the preallocated
                        // span — the hot path allocates nothing here
                        slot.span.first_token_ns = now_ns;
                    }
                    if slot.out.len() >= slot.req.max_new {
                        (PAD, Some(FinishReason::Length))
                    } else {
                        (tok as i32, None)
                    }
                }
            }
        };
        if let Some(reason) = finished {
            if let Some(slot) = slots[r].take() {
                // capture the session tag BEFORE the slot is consumed:
                // the state has absorbed `slot.t` tokens (BOS included),
                // i.e. the first `slot.t - 1` bytes of prompt ++ out
                let tag = slot.req.session.clone().map(|sid| {
                    let h = slot.t.saturating_sub(1);
                    (sid, slot.t as u64,
                     history_digest(&slot.req.prompt, &slot.out, h))
                });
                let (response, trace) = finish(slot, reason, now_ns);
                retired.push(Retired { row: r, tag, response, trace });
            }
        }
        cur.data[r] = next;
    }
    retired
}

/// Nanosecond difference as non-negative seconds.
fn secs_between(start_ns: u64, end_ns: u64) -> f64 {
    end_ns.saturating_sub(start_ns) as f64 * 1e-9
}

fn finish(slot: Slot, finish: FinishReason, now_ns: u64) -> (Response, Trace) {
    let mut span = slot.span;
    span.retired_ns = now_ns;
    let response = Response {
        id: slot.req.id,
        session: slot.req.session.clone(),
        adapter: slot.req.adapter,
        prompt_len: slot.req.prompt.len(),
        output: slot.out,
        queued_s: secs_between(span.enqueued_ns, span.admitted_ns),
        total_s: secs_between(span.enqueued_ns, now_ns),
        steps: slot.t as u64,
        finish,
        error: None,
        retries: slot.attempts as u64,
    };
    let trace = Trace {
        id: response.id,
        adapter: response.adapter.clone(),
        prompt_len: response.prompt_len,
        new_tokens: response.output.len(),
        steps: response.steps,
        retries: slot.attempts,
        finish: response.finish.label(),
        span,
    };
    (response, trace)
}

/// The classification boundary between the legacy and typed failure
/// labels: [`ErrorKind::Other`] keeps [`FinishReason::Error`] (the
/// pre-taxonomy wire label), every classified kind gets the typed
/// [`FinishReason::Failed`].
fn failed_reason(kind: ErrorKind) -> FinishReason {
    if kind == ErrorKind::Other {
        FinishReason::Error
    } else {
        FinishReason::Failed { kind }
    }
}

/// Retire an un-admitted request as failed, classified by the error kind.
/// Never-admitted requests have no span timeline — traces cover admitted
/// requests only (rust/docs/observability.md § Spans).
fn fail_err(req: Request, enqueued_ns: u64, e: &crate::error::Error, retries: u64,
            now_ns: u64)
    -> Response {
    let waited = secs_between(enqueued_ns, now_ns);
    Response {
        id: req.id,
        session: req.session.clone(),
        adapter: req.adapter,
        prompt_len: req.prompt.len(),
        output: Vec::new(),
        queued_s: waited,
        total_s: waited,
        steps: 0,
        finish: failed_reason(e.kind()),
        error: Some(format!("{e:#}")),
        retries,
    }
}

fn fail(req: Request, enqueued_ns: u64, msg: String, now_ns: u64) -> Response {
    fail_err(req, enqueued_ns, &crate::error::Error::msg(msg), 0, now_ns)
}

/// Retire an in-flight slot as failed, keeping its queue/occupancy
/// accounting (unlike [`fail_err`], the request was admitted and consumed
/// `slot.t` steps before the error). Returns the response plus the
/// failure-annotated trace.
fn slot_failed(slot: Slot, e: &crate::error::Error, now_ns: u64) -> (Response, Trace) {
    let mut span = slot.span;
    span.retired_ns = now_ns;
    let response = Response {
        id: slot.req.id,
        session: slot.req.session.clone(),
        adapter: slot.req.adapter,
        prompt_len: slot.req.prompt.len(),
        output: Vec::new(),
        queued_s: secs_between(span.enqueued_ns, span.admitted_ns),
        total_s: secs_between(span.enqueued_ns, now_ns),
        steps: slot.t as u64,
        finish: failed_reason(e.kind()),
        error: Some(format!("{e:#}")),
        retries: slot.attempts as u64,
    };
    let trace = Trace {
        id: response.id,
        adapter: response.adapter.clone(),
        prompt_len: response.prompt_len,
        new_tokens: 0,
        steps: response.steps,
        retries: slot.attempts,
        finish: response.finish.label(),
        span,
    };
    (response, trace)
}

/// Outcome of a session resurrection attempt on a freshly admitted row.
enum Resume {
    /// The stored state was spliced in and the slot fast-forwarded past
    /// the absorbed history: zero prefill work for this request.
    Resumed,
    /// No session id, or a clean store miss: ordinary prefill.
    Miss,
    /// The session layer failed (load fault, corrupt/quarantined record,
    /// stale digest, geometry drift, splice error): the slot stays
    /// staged at `t = 0` and the request re-prefills its full history —
    /// degraded, never wrong.
    Fallback,
}

/// Try to resurrect a freshly admitted row from the session store. The
/// row was just staged by `admit` (`t = 0`, `cur = BOS`, state reset);
/// on success it is fast-forwarded to the snapshot's absorbed history
/// and `cur` holds the next unconsumed prompt byte, exactly as
/// [`Lane::flush_prefill`]'s splice would leave it. Any failure leaves
/// the staged slot untouched (prefill fallback).
fn try_resume_row(
    store: &SessionStore,
    dims: &crate::eval::StateDims,
    b: usize,
    r: usize,
    state: &mut DecodeState,
    cur: &mut IntTensor,
    slots: &mut [Option<Slot>],
) -> Resume {
    let Some(slot) = slots.get_mut(r).and_then(Option::as_mut) else {
        return Resume::Miss;
    };
    let Some(sid) = slot.req.session.clone() else { return Resume::Miss };
    let snap = match store.load(&sid) {
        Ok(Some(s)) => s,
        Ok(None) => return Resume::Miss,
        Err(_) => return Resume::Fallback, // injected fault / quarantined record
    };
    let consumed = snap.consumed as usize;
    // the snapshot absorbed `consumed` tokens = BOS + the first
    // `consumed - 1` bytes of its transcript; it resumes THIS request
    // only if that transcript is a strict byte prefix of the new prompt
    // (proved by the digest) under the same state geometry
    let h = consumed.wrapping_sub(1);
    if snap.dims != *dims
        || consumed == 0
        || h >= slot.req.prompt.len()
        || history_digest(&slot.req.prompt, &[], h) != snap.history_hash
    {
        return Resume::Fallback;
    }
    let mut src = match DecodeState::with_row(dims, b, r, &snap.conv, &snap.ssm) {
        Ok(s) => s,
        Err(_) => return Resume::Fallback,
    };
    if state.splice_row_from(dims, b, &mut src, r, r).is_err() {
        return Resume::Fallback;
    }
    slot.t = consumed;
    cur.data[r] = slot.req.prompt[h] as i32;
    slot.span.resurrected = true;
    Resume::Resumed
}

/// Session resurrection on a merged lane: on success the row also leaves
/// the pending-prefill set (it has nothing left to prefill).
fn resume_merged_row(store: &SessionStore, lane: &mut Lane, r: usize) -> Resume {
    let dims = lane.model.dims();
    let b = lane.model.arch_b();
    let res = try_resume_row(store, &dims, b, r, &mut lane.state, &mut lane.cur,
                             &mut lane.slots);
    if matches!(res, Resume::Resumed) {
        lane.pending_prefill.retain(|&p| p != r);
    }
    res
}

/// Session resurrection on the shared unmerged lane (no chunked prefill
/// there — resumption skips the step-wise prompt ingestion instead).
fn resume_shared_row(store: &SessionStore, sl: &mut SharedLane, r: usize) -> Resume {
    let dims = sl.model.dims();
    let b = sl.model.arch_b();
    try_resume_row(store, &dims, b, r, &mut sl.state, &mut sl.cur, &mut sl.slots)
}

/// Outcome of trying to place a request on the shared lane — computed
/// while the lane is mutably borrowed, acted on (release hook, requeue)
/// afterwards.
enum SharedAdmit {
    Admitted(usize),
    Failed(Request, crate::error::Error),
    Full(Request),
}

/// A queued request plus its lifecycle bookkeeping.
struct QueueEntry {
    req: Request,
    /// Clock stamp at submission ([`Clock::now_ns`]).
    enqueued_ns: u64,
    /// Tick the request was submitted on (deadline + fairness input).
    submit_tick: u64,
    /// Requeues so far (transient factory errors, shared-batch demotions).
    attempts: u32,
    /// Demoted off the shared batch after a step failure: admit through
    /// the merged fallback instead of the factory's Shared mapping.
    demoted: bool,
}

/// Consecutive in-place step retries per batch before the failure is
/// treated as terminal for that batch (shared lanes then demote their
/// rows; merged lanes fail theirs).
const STEP_RETRY_BUDGET: u32 = 2;

/// Requeues per request (transient factory errors + demotions) before it
/// retires with `ErrorKind::Exhausted`.
const REQUEST_RETRY_BUDGET: u32 = 3;

/// The continuous-batching scheduler (see the module docs for the tick
/// contract). Single-threaded by design: the serve loop alternates
/// [`Scheduler::submit`] and [`Scheduler::tick`].
pub struct Scheduler<'a> {
    factory: ServeFactory<'a>,
    lanes: BTreeMap<String, Lane>,
    /// The one mixed-adapter batch; created on the first
    /// [`ServeModel::Shared`] factory result.
    shared: Option<SharedLane>,
    retire_hook: Option<RetireHook<'a>>,
    queue: VecDeque<QueueEntry>,
    /// Cap on simultaneously materialized merged lanes; idle lanes are
    /// recycled to admit new adapters once the cap is hit. (The shared
    /// lane is not counted — it is one batch regardless of adapter count.)
    max_lanes: usize,
    /// Fault-injection hook. Besides gating the `StateReadback` site,
    /// its presence enables per-step checkpointing — production (`None`)
    /// pays neither the readback nor the snapshot.
    faults: Option<Arc<dyn crate::fault::FaultInject>>,
    /// [`Scheduler::run_to_completion`] tick budget (0 = unlimited;
    /// seeded from the max-ticks knob).
    max_run_ticks: usize,
    /// Notified with `(adapter, kind)` on every terminal per-adapter step
    /// failure — the server feeds this to the registry's circuit breaker.
    on_failure: Option<Box<dyn Fn(&str, ErrorKind) + 'a>>,
    /// Builds a dedicated merged lane for a shared-batch adapter — the
    /// demotion target after a shared step failure.
    merged_fallback: Option<Box<dyn Fn(&str) -> Result<LaneModel> + 'a>>,
    /// Durable session-state store (see [`crate::serve::SessionStore`]);
    /// `None` = stateless serving, zero session overhead.
    sessions: Option<Arc<SessionStore>>,
    /// Called once at the top of every [`Scheduler::tick`] — the server
    /// uses it to advance the registry circuit breaker's probation clock.
    tick_hook: Option<Box<dyn Fn() + 'a>>,
    /// The clock every span stamp reads ([`WallClock`] by default;
    /// [`Scheduler::set_clock`] installs a [`crate::obs::VirtualClock`]
    /// for deterministic traced runs). ONE read per tick, threaded to
    /// every stamp taken during it.
    clock: Arc<dyn Clock>,
    /// Ring of recently retired request traces (admitted requests only;
    /// never-admitted failures carry no timeline).
    traces: TraceRing,
    /// True when the previous tick made no progress (no admission, no
    /// decode step, no prefill dispatch, nothing retired) — the server's
    /// idle-backoff signal.
    last_tick_idle: bool,
    /// Ticks that made no progress (published as the `sched.idle_ticks`
    /// gauge).
    pub idle_ticks: u64,
    /// Successful slot admissions (merged lanes + the shared batch).
    pub admissions: u64,
    /// Total decode steps executed (across all lanes; the shared lane
    /// counts ONE step per tick however many adapters its rows mix).
    pub decode_steps: u64,
    /// Total tick() calls.
    pub ticks: u64,
    /// Chunked-prefill dispatches issued by prefill-then-admit (§Perf L5).
    pub prefill_dispatches: u64,
    /// Prompt tokens ingested by chunked prefill (i.e. lane decode steps
    /// the admitted requests skipped).
    pub prefill_tokens: u64,
    /// Fairness telemetry: the most ticks any admitted request spent
    /// queued before getting a slot. Adapter-skewed loads must not starve
    /// the minority adapter — FIFO admission bounds this by queue depth,
    /// and tests pin it.
    pub max_admit_wait_ticks: u64,
    /// Batch step errors observed (before retry/demotion handling).
    pub step_faults: u64,
    /// In-place step retries taken after a rollback (transient errors).
    pub step_retries: u64,
    /// Rows demoted from the shared batch to merged lanes after a
    /// terminal shared step failure.
    pub demotions: u64,
    /// Requests retired by the deadline watchdog.
    pub deadline_failures: u64,
    /// Session-tagged rows resurrected from the store at admission
    /// (prefill skipped entirely).
    pub session_resurrections: u64,
    /// Session-tagged rows that degraded to full-history prefill (load
    /// fault, corrupt record, stale digest, geometry drift).
    pub session_fallbacks: u64,
    /// Session snapshots persisted at retirement.
    pub session_persists: u64,
    /// Retirement snapshots that failed to persist (injected fault,
    /// readback error, geometry guard) — the session re-prefills next
    /// turn.
    pub session_persist_failures: u64,
}

impl<'a> Scheduler<'a> {
    /// New scheduler; `max_lanes` bounds per-adapter decode-state memory
    /// (min 1).
    pub fn new(factory: ServeFactory<'a>, max_lanes: usize) -> Scheduler<'a> {
        Scheduler {
            factory,
            lanes: BTreeMap::new(),
            shared: None,
            retire_hook: None,
            queue: VecDeque::new(),
            max_lanes: max_lanes.max(1),
            faults: None,
            max_run_ticks: crate::knobs::max_ticks(),
            on_failure: None,
            merged_fallback: None,
            sessions: None,
            tick_hook: None,
            clock: Arc::new(WallClock::new()),
            traces: TraceRing::new(crate::knobs::obs_trace_cap()),
            last_tick_idle: false,
            idle_ticks: 0,
            admissions: 0,
            decode_steps: 0,
            ticks: 0,
            prefill_dispatches: 0,
            prefill_tokens: 0,
            max_admit_wait_ticks: 0,
            step_faults: 0,
            step_retries: 0,
            demotions: 0,
            deadline_failures: 0,
            session_resurrections: 0,
            session_fallbacks: 0,
            session_persists: 0,
            session_persist_failures: 0,
        }
    }

    /// Install the durable session-state store: session-tagged requests
    /// resurrect at admission and snapshot at retirement from now on.
    pub fn set_session_store(&mut self, store: Arc<SessionStore>) {
        self.sessions = Some(store);
    }

    /// The installed session store, if any.
    pub fn session_store(&self) -> Option<&Arc<SessionStore>> {
        self.sessions.as_ref()
    }

    /// Install the clock span stamps read (default: [`WallClock`]).
    /// Tests and `bench serving` install a [`crate::obs::VirtualClock`]
    /// so traced runs are byte-identical run to run.
    pub fn set_clock(&mut self, clock: Arc<dyn Clock>) {
        self.clock = clock;
    }

    /// Resize the trace ring (default: the `SSM_PEFT_OBS_TRACE_CAP` knob).
    /// Existing traces are dropped.
    pub fn set_trace_capacity(&mut self, cap: usize) {
        self.traces = TraceRing::new(cap);
    }

    /// The ring of recently retired request traces.
    pub fn traces(&self) -> &TraceRing {
        &self.traces
    }

    /// Did the previous [`Scheduler::tick`] make no progress? The serve
    /// loop uses this to park with a bounded backoff instead of busy-
    /// spinning through unproductive ticks.
    pub fn last_tick_idle(&self) -> bool {
        self.last_tick_idle
    }

    /// Publish the scheduler's counters/gauges into a metrics registry
    /// (instrument names: rust/docs/observability.md § Registry).
    pub fn publish_metrics(&self, m: &crate::obs::Metrics) {
        m.counter("sched.ticks").set(self.ticks);
        m.counter("sched.decode_steps").set(self.decode_steps);
        m.counter("sched.admissions").set(self.admissions);
        m.counter("sched.prefill_dispatches").set(self.prefill_dispatches);
        m.counter("sched.prefill_tokens").set(self.prefill_tokens);
        m.counter("sched.step_faults").set(self.step_faults);
        m.counter("sched.step_retries").set(self.step_retries);
        m.counter("sched.demotions").set(self.demotions);
        m.counter("sched.deadline_failures").set(self.deadline_failures);
        m.counter("sched.session_resurrections").set(self.session_resurrections);
        m.counter("sched.session_fallbacks").set(self.session_fallbacks);
        m.counter("sched.session_persists").set(self.session_persists);
        m.counter("sched.session_persist_failures")
            .set(self.session_persist_failures);
        m.counter("sched.traces_recorded").set(self.traces.pushed());
        m.gauge("sched.max_admit_wait_ticks").set(self.max_admit_wait_ticks);
        m.gauge("sched.idle_ticks").set(self.idle_ticks);
        m.gauge("sched.queued").set(self.queue.len() as u64);
        m.gauge("sched.active").set(self.active() as u64);
    }

    /// Install the [`RetireHook`] (shared-delta release notifications).
    pub fn on_release(&mut self, hook: RetireHook<'a>) {
        self.retire_hook = Some(hook);
    }

    /// Install the fault-injection hook. This also enables per-step
    /// checkpoint/rollback (the recovery machinery the injected faults
    /// exercise); without it, step errors keep their pre-fault-layer
    /// terminal handling.
    pub fn set_fault_inject(&mut self, faults: Arc<dyn crate::fault::FaultInject>) {
        self.faults = Some(faults);
    }

    /// Override the [`Scheduler::run_to_completion`] tick budget
    /// (0 = unlimited). Defaults to the max-ticks knob.
    pub fn set_max_run_ticks(&mut self, ticks: usize) {
        self.max_run_ticks = ticks;
    }

    /// Install the terminal-failure listener `(adapter, kind)` — fed to
    /// the adapter registry's circuit breaker by the server.
    pub fn on_adapter_failure(&mut self, hook: Box<dyn Fn(&str, ErrorKind) + 'a>) {
        self.on_failure = Some(hook);
    }

    /// Install the per-tick listener, called once at the top of every
    /// [`Scheduler::tick`] — the server drives the registry circuit
    /// breaker's half-open probation clock with it.
    pub fn on_tick(&mut self, hook: Box<dyn Fn() + 'a>) {
        self.tick_hook = Some(hook);
    }

    /// Install the demotion target: builds a dedicated merged lane for an
    /// adapter whose shared-batch residency failed. Without it, a
    /// terminal shared step failure fails every row (the pre-cascade
    /// behavior).
    pub fn set_merged_fallback(
        &mut self,
        hook: Box<dyn Fn(&str) -> Result<LaneModel> + 'a>,
    ) {
        self.merged_fallback = Some(hook);
    }

    fn release(&self, adapter: &str) {
        if let Some(hook) = &self.retire_hook {
            hook(adapter);
        }
    }

    /// Enqueue a request (admitted on a following [`Scheduler::tick`]).
    pub fn submit(&mut self, req: Request) {
        self.queue.push_back(QueueEntry {
            req,
            enqueued_ns: self.clock.now_ns(),
            submit_tick: self.ticks,
            attempts: 0,
            demoted: false,
        });
    }

    /// Queued (not yet admitted) request count.
    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    /// Requests currently occupying decode slots (merged lanes + the
    /// shared mixed-adapter batch).
    pub fn active(&self) -> usize {
        self.lanes.values().map(Lane::active).sum::<usize>()
            + self.shared.as_ref().map_or(0, SharedLane::active)
    }

    /// True when nothing is queued or decoding.
    pub fn is_idle(&self) -> bool {
        self.queue.is_empty() && self.active() == 0
    }

    /// Could a NEW merged lane be materialized right now (cap not hit, or
    /// an idle lane is recyclable)?
    fn merged_capacity(&self) -> bool {
        self.lanes.len() < self.max_lanes
            || self.lanes.values().any(|l| l.active() == 0)
    }

    /// Admission pass: walk the queue in FIFO order, placing each request
    /// into a free slot of its adapter's merged lane or of the shared
    /// batch (whichever the factory maps the adapter to). Requests that
    /// don't fit stay queued (in order) without blocking later requests
    /// for other adapters. Beam requests run to completion here (dedicated
    /// pass).
    fn admit(&mut self, out: &mut Vec<Response>, now: u64) {
        let store = self.sessions.clone();
        let mut still_queued = VecDeque::new();
        while let Some(entry) = self.queue.pop_front() {
            let QueueEntry { req, enqueued_ns: enq, submit_tick, attempts, demoted } =
                entry;
            if req.beam > 1 {
                match self.run_beam(&req) {
                    Ok(bytes) => {
                        // a beam pass runs synchronously: re-read the
                        // clock so total_s covers the pass itself
                        let done = self.clock.now_ns();
                        let n = (req.prompt.len() + bytes.len() + 1) as u64;
                        let stopped = bytes.len() < req.max_new;
                        out.push(Response {
                            id: req.id,
                            session: req.session.clone(),
                            adapter: req.adapter,
                            prompt_len: req.prompt.len(),
                            output: bytes,
                            queued_s: secs_between(enq, done),
                            total_s: secs_between(enq, done),
                            steps: n,
                            finish: if stopped {
                                FinishReason::Stop
                            } else {
                                FinishReason::Length
                            },
                            error: None,
                            retries: 0,
                        });
                    }
                    Err(e) => {
                        let done = self.clock.now_ns();
                        out.push(fail_err(req, enq, &e, 0, done));
                    }
                }
                continue;
            }
            let wait = self.ticks.saturating_sub(submit_tick);
            let mut span = Span::started(enq, now);
            span.demoted = demoted;
            // 1) a merged lane already exists for this adapter
            if self.lanes.contains_key(&req.adapter) {
                let Some(lane) = self.lanes.get_mut(&req.adapter) else { continue };
                if lane.free_slot().is_some() {
                    match lane.admit(req, span, submit_tick, attempts) {
                        Err((req, e)) => {
                            out.push(fail(req, enq, format!("admit failed: {e:#}"),
                                          now));
                        }
                        Ok(r) => {
                            self.admissions += 1;
                            self.max_admit_wait_ticks =
                                self.max_admit_wait_ticks.max(wait);
                            if let Some(store) = &store {
                                match resume_merged_row(store, lane, r) {
                                    Resume::Resumed => self.session_resurrections += 1,
                                    Resume::Fallback => self.session_fallbacks += 1,
                                    Resume::Miss => {}
                                }
                            }
                        }
                    }
                } else {
                    still_queued.push_back(QueueEntry {
                        req, enqueued_ns: enq, submit_tick, attempts, demoted,
                    }); // backpressure
                }
                continue;
            }
            // 2) unknown adapter. Before the shared lane exists, a full
            // merged-lane table means "no capacity yet" — requeue without
            // consulting the factory (the pre-shared contract).
            if self.shared.is_none() && !self.merged_capacity() {
                still_queued.push_back(QueueEntry {
                    req, enqueued_ns: enq, submit_tick, attempts, demoted,
                });
                continue;
            }
            if attempts > REQUEST_RETRY_BUDGET {
                let e = crate::error::Error::new(
                    ErrorKind::Exhausted,
                    format!("request retry budget ({REQUEST_RETRY_BUDGET}) exhausted"),
                );
                out.push(fail_err(req, enq, &e, attempts as u64, now));
                continue;
            }
            // A demoted request bypasses the factory's Shared mapping: its
            // shared-batch residency already failed, so it takes the next
            // rung of the cascade — a dedicated merged lane.
            let made = if demoted {
                match &self.merged_fallback {
                    Some(make) => make(&req.adapter).map(ServeModel::Merged),
                    None => Err(crate::error::Error::new(
                        ErrorKind::Runtime,
                        "no merged fallback for demoted request",
                    )),
                }
            } else {
                (self.factory)(&req.adapter)
            };
            match made {
                Err(e) => {
                    // transient build errors are worth a bounded requeue;
                    // terminal ones (and spent budgets) retire the request
                    if e.kind().is_transient() && attempts < REQUEST_RETRY_BUDGET {
                        still_queued.push_back(QueueEntry {
                            req,
                            enqueued_ns: enq,
                            submit_tick,
                            attempts: attempts + 1,
                            demoted,
                        });
                    } else {
                        out.push(fail_err(req, enq, &e, attempts as u64, now));
                    }
                }
                Ok(ServeModel::Merged(lm)) => {
                    if self.lanes.len() >= self.max_lanes {
                        let idle = self
                            .lanes
                            .iter()
                            .find(|(_, l)| l.active() == 0)
                            .map(|(k, _)| k.clone());
                        match idle {
                            Some(k) => {
                                self.lanes.remove(&k);
                            }
                            None => {
                                // every merged lane busy: wait (drops the
                                // just-built LaneModel — only reachable
                                // when the shared lane exists alongside a
                                // full merged-lane table)
                                still_queued.push_back(QueueEntry {
                                    req, enqueued_ns: enq, submit_tick, attempts,
                                    demoted,
                                });
                                continue;
                            }
                        }
                    }
                    let lane = self
                        .lanes
                        .entry(req.adapter.clone())
                        .or_insert_with(|| Lane::new(lm));
                    match lane.admit(req, span, submit_tick, attempts) {
                        Err((req, e)) => {
                            out.push(fail(req, enq, format!("admit failed: {e:#}"),
                                          now));
                        }
                        Ok(r) => {
                            self.admissions += 1;
                            self.max_admit_wait_ticks =
                                self.max_admit_wait_ticks.max(wait);
                            if let Some(store) = &store {
                                match resume_merged_row(store, lane, r) {
                                    Resume::Resumed => self.session_resurrections += 1,
                                    Resume::Fallback => self.session_fallbacks += 1,
                                    Resume::Miss => {}
                                }
                            }
                        }
                    }
                }
                Ok(ServeModel::Shared { model, delta, h0 }) => {
                    if self.shared.is_none() {
                        self.shared = Some(SharedLane::new(model));
                    }
                    let adapter = req.adapter.clone();
                    let placed = match self.shared.as_mut() {
                        Some(sl) if sl.free_slot().is_some() => {
                            match sl.admit(req, span, submit_tick, attempts, delta, h0)
                            {
                                Ok(r) => SharedAdmit::Admitted(r),
                                Err((req, e)) => SharedAdmit::Failed(req, e),
                            }
                        }
                        _ => SharedAdmit::Full(req),
                    };
                    match placed {
                        SharedAdmit::Admitted(r) => {
                            self.admissions += 1;
                            self.max_admit_wait_ticks =
                                self.max_admit_wait_ticks.max(wait);
                            if let (Some(store), Some(sl)) =
                                (&store, self.shared.as_mut())
                            {
                                match resume_shared_row(store, sl, r) {
                                    Resume::Resumed => self.session_resurrections += 1,
                                    Resume::Fallback => self.session_fallbacks += 1,
                                    Resume::Miss => {}
                                }
                            }
                        }
                        SharedAdmit::Failed(req, e) => {
                            // the delta never made it onto a row
                            self.release(&adapter);
                            out.push(fail(
                                req, enq, format!("admit failed: {e:#}"), now,
                            ));
                        }
                        SharedAdmit::Full(req) => {
                            self.release(&adapter);
                            still_queued.push_back(QueueEntry {
                                req, enqueued_ns: enq, submit_tick, attempts,
                                demoted,
                            });
                        }
                    }
                }
            }
        }
        self.queue = still_queued;
        // prefill-then-admit: scan the staged requests' prompts out-of-band
        // (batched per lane) and splice the finished states in (§Perf L5)
        for lane in self.lanes.values_mut() {
            if !lane.pending_prefill.is_empty() {
                let (dispatches, tokens) = lane.flush_prefill();
                self.prefill_dispatches += dispatches;
                self.prefill_tokens += tokens;
            }
        }
    }

    fn run_beam(&mut self, req: &Request) -> Result<Vec<u8>> {
        // a beam pass needs the whole batch dimension; reuse the adapter's
        // cached merged lane model when one exists (beam_search allocates
        // its own state tensors, so the lane's in-flight rows are
        // untouched), otherwise build one-off resources via the factory.
        if let Some(lane) = self.lanes.get(&req.adapter) {
            let (model, h0) = (lane.model.clone(), lane.h0.clone());
            return beam_search(
                model.as_ref(),
                &req.prompt,
                req.beam,
                req.max_new,
                req.stop_byte,
                h0.as_deref(),
            );
        }
        let made = (self.factory)(&req.adapter)?;
        match made {
            ServeModel::Merged(lm) => beam_search(
                lm.model.as_ref(),
                &req.prompt,
                req.beam,
                req.max_new,
                req.stop_byte,
                lm.h0.as_deref(),
            ),
            ServeModel::Shared { model, delta, h0 } => {
                // pin the delta across every row of the shared core for the
                // duration of the pass, then release it
                let pinned = PinnedAdapter::new(model, delta);
                let res = beam_search(
                    &pinned,
                    &req.prompt,
                    req.beam,
                    req.max_new,
                    req.stop_byte,
                    h0.as_deref(),
                );
                self.release(&req.adapter);
                res
            }
        }
    }

    /// Deadline watchdog: retire every queued or resident request whose
    /// tick budget expired, before admission or decode spends work on it.
    /// `deadline == 0` means no deadline (the default).
    fn enforce_deadlines(&mut self, out: &mut Vec<Response>, now: u64) {
        let ticks = self.ticks;
        let expired = |deadline: usize, submit: u64| {
            deadline > 0 && ticks.saturating_sub(submit) >= deadline as u64
        };
        let budget_err = |deadline: usize| {
            crate::error::Error::new(
                ErrorKind::Exhausted,
                format!("deadline of {deadline} ticks exceeded"),
            )
        };
        // queued requests (rotation keeps FIFO order for survivors)
        for _ in 0..self.queue.len() {
            let Some(entry) = self.queue.pop_front() else { break };
            if expired(entry.req.deadline, entry.submit_tick) {
                self.deadline_failures += 1;
                let e = budget_err(entry.req.deadline);
                out.push(fail_err(entry.req, entry.enqueued_ns, &e,
                                  entry.attempts as u64, now));
            } else {
                self.queue.push_back(entry);
            }
        }
        // merged-lane rows
        for lane in self.lanes.values_mut() {
            for slot in &mut lane.slots {
                if slot.as_ref().is_some_and(|s| expired(s.req.deadline, s.submit_tick)) {
                    if let Some(s) = slot.take() {
                        self.deadline_failures += 1;
                        let deadline = s.req.deadline;
                        let (resp, trace) =
                            slot_failed(s, &budget_err(deadline), now);
                        self.traces.push(trace);
                        out.push(resp);
                    }
                }
            }
        }
        // shared rows: drop the delta with the slot, release pins after
        // the lane borrow ends
        let mut released = Vec::new();
        if let Some(sl) = self.shared.as_mut() {
            for r in 0..sl.slots.len() {
                if sl.slots[r]
                    .as_ref()
                    .is_some_and(|s| expired(s.req.deadline, s.submit_tick))
                {
                    if let Some(s) = sl.slots[r].take() {
                        sl.rows[r] = None;
                        self.deadline_failures += 1;
                        released.push(s.req.adapter.clone());
                        let deadline = s.req.deadline;
                        let (resp, trace) =
                            slot_failed(s, &budget_err(deadline), now);
                        self.traces.push(trace);
                        out.push(resp);
                    }
                }
            }
        }
        for a in &released {
            self.release(a);
        }
    }

    /// One scheduler step: deadline sweep → admit → one decode step per
    /// active batch → retire. Returns the requests that finished during
    /// this tick. A batch step error is retried in place (bounded, after a
    /// checkpoint rollback) when transient, demoted row-by-row to merged
    /// lanes (shared batch, when a fallback is installed), and otherwise
    /// retires every request of that batch as failed.
    pub fn tick(&mut self) -> Vec<Response> {
        if let Some(hook) = &self.tick_hook {
            hook();
        }
        // one clock read per tick: every span stamped this tick shares it,
        // so tracing adds no per-row clock syscalls to the hot path
        let now = self.clock.now_ns();
        let steps_before = self.decode_steps;
        let prefill_before = self.prefill_dispatches;
        let admissions_before = self.admissions;
        let store = self.sessions.clone();
        let mut out = Vec::new();
        self.enforce_deadlines(&mut out, now);
        self.admit(&mut out, now);
        let adapters: Vec<String> = self
            .lanes
            .iter()
            .filter(|(_, l)| l.active() > 0)
            .map(|(k, _)| k.clone())
            .collect();
        for a in adapters {
            // keys were collected from `self.lanes` just above
            let Some(lane) = self.lanes.get_mut(&a) else { continue };
            if lane.cooldown > 0 {
                lane.cooldown -= 1; // backoff: sit this tick out
                continue;
            }
            // checkpoint only when fault injection is installed —
            // production pays neither the readback nor the snapshot
            let ck = match &self.faults {
                Some(f) => f
                    .check(crate::fault::FaultSite::StateReadback)
                    .and_then(|()| lane.state.checkpoint())
                    .ok(),
                None => None,
            };
            match lane.step(now) {
                Ok(retired) => {
                    self.decode_steps += 1;
                    lane.attempts = 0;
                    // snapshot session-tagged rows NOW, while the lane's
                    // state still holds each retired row untouched
                    let dims = lane.model.dims();
                    let b = lane.model.arch_b();
                    for t in retired {
                        if let (Some(store), Some((sid, consumed, digest))) =
                            (&store, &t.tag)
                        {
                            let persisted = lane
                                .state
                                .row_snapshot(&dims, b, t.row)
                                .and_then(|(conv, ssm)| {
                                    store.persist(sid, SessionSnapshot {
                                        dims,
                                        consumed: *consumed,
                                        history_hash: *digest,
                                        conv,
                                        ssm,
                                    })
                                });
                            match persisted {
                                Ok(()) => self.session_persists += 1,
                                Err(_) => self.session_persist_failures += 1,
                            }
                        }
                        self.traces.push(t.trace);
                        out.push(t.response);
                    }
                }
                Err(e) => {
                    self.step_faults += 1;
                    let rolled =
                        ck.as_ref().is_some_and(|c| lane.state.rollback(c).is_ok());
                    if rolled
                        && e.kind().is_transient()
                        && lane.attempts < STEP_RETRY_BUDGET
                    {
                        lane.attempts += 1;
                        lane.cooldown = 1 << (lane.attempts - 1);
                        self.step_retries += 1;
                    } else {
                        // terminal: the lane's state is unreliable (or its
                        // retry budget is spent) — notify the circuit
                        // breaker, fail its requests, and drop it (a later
                        // request re-creates it)
                        if let Some(hook) = &self.on_failure {
                            hook(&a, e.kind());
                        }
                        let e = e.context("decode step failed");
                        for slot in lane.slots.iter_mut().filter_map(Option::take) {
                            let (resp, trace) = slot_failed(slot, &e, now);
                            self.traces.push(trace);
                            out.push(resp);
                        }
                        self.lanes.remove(&a);
                    }
                }
            }
        }
        // the shared mixed-adapter batch: one step_rows dispatch advances
        // every resident adapter together
        let shared_res = match self.shared.as_mut() {
            Some(sl) if sl.active() > 0 => {
                if sl.cooldown > 0 {
                    sl.cooldown -= 1;
                    None
                } else {
                    let ck = match &self.faults {
                        Some(f) => f
                            .check(crate::fault::FaultSite::StateReadback)
                            .and_then(|()| sl.state.checkpoint())
                            .ok(),
                        None => None,
                    };
                    let res = sl.step(now);
                    let rolled = res.is_err()
                        && ck.as_ref().is_some_and(|c| sl.state.rollback(c).is_ok());
                    Some((res, rolled))
                }
            }
            _ => None,
        };
        match shared_res {
            Some((Ok(retired), _)) => {
                self.decode_steps += 1;
                // snapshot session-tagged rows while the shared state
                // still holds them, then release pins outside the borrow
                if let Some(sl) = self.shared.as_mut() {
                    sl.attempts = 0;
                    let dims = sl.model.dims();
                    let b = sl.model.arch_b();
                    for t in &retired {
                        if let (Some(store), Some((sid, consumed, digest))) =
                            (&store, &t.tag)
                        {
                            let persisted = sl
                                .state
                                .row_snapshot(&dims, b, t.row)
                                .and_then(|(conv, ssm)| {
                                    store.persist(sid, SessionSnapshot {
                                        dims,
                                        consumed: *consumed,
                                        history_hash: *digest,
                                        conv,
                                        ssm,
                                    })
                                });
                            match persisted {
                                Ok(()) => self.session_persists += 1,
                                Err(_) => self.session_persist_failures += 1,
                            }
                        }
                    }
                }
                for t in retired {
                    self.release(&t.response.adapter);
                    self.traces.push(t.trace);
                    out.push(t.response);
                }
            }
            Some((Err(e), rolled)) => {
                self.step_faults += 1;
                let attempts = self.shared.as_ref().map_or(0, |sl| sl.attempts);
                if rolled && e.kind().is_transient() && attempts < STEP_RETRY_BUDGET {
                    if let Some(sl) = self.shared.as_mut() {
                        sl.attempts += 1;
                        sl.cooldown = 1 << (sl.attempts - 1);
                    }
                    self.step_retries += 1;
                } else if self.merged_fallback.is_some() {
                    // demote every resident row to a dedicated merged
                    // lane: the batch can't tell WHICH adapter poisoned
                    // the step, so each row re-runs alone — the faulty
                    // adapter then fails by itself instead of taking the
                    // innocent rows with it (and its terminal failure is
                    // what reaches the circuit breaker). NOT notifying
                    // on_failure here is deliberate. Rows requeue at the
                    // FRONT in row order, keeping admission FIFO-fair.
                    if let Some(mut sl) = self.shared.take() {
                        let mut slots = Vec::new();
                        for r in 0..sl.slots.len() {
                            if let Some(slot) = sl.slots[r].take() {
                                sl.rows[r] = None;
                                slots.push(slot);
                            }
                        }
                        self.demotions += slots.len() as u64;
                        for slot in slots.into_iter().rev() {
                            self.release(&slot.req.adapter);
                            self.queue.push_front(QueueEntry {
                                enqueued_ns: slot.span.enqueued_ns,
                                req: slot.req,
                                submit_tick: slot.submit_tick,
                                attempts: slot.attempts + 1,
                                demoted: true,
                            });
                        }
                    }
                } else {
                    // terminal with no fallback: fail + release every row
                    // and drop the batch (re-created on the next Shared
                    // factory result)
                    let e = e.context("shared decode step failed");
                    if let Some(mut sl) = self.shared.take() {
                        for slot in sl.slots.iter_mut().filter_map(Option::take) {
                            let adapter = slot.req.adapter.clone();
                            let (resp, trace) = slot_failed(slot, &e, now);
                            self.traces.push(trace);
                            out.push(resp);
                            self.release(&adapter);
                        }
                    }
                }
            }
            None => {}
        }
        // a tick that produced nothing and moved nothing is idle — the
        // server's parked backoff (rust/docs/observability.md § Idle
        // backoff) keys off this instead of busy-spinning
        let idle = out.is_empty()
            && self.decode_steps == steps_before
            && self.prefill_dispatches == prefill_before
            && self.admissions == admissions_before;
        self.last_tick_idle = idle;
        if idle {
            self.idle_ticks += 1;
        }
        self.ticks += 1;
        out
    }

    /// Tick until idle; returns every response produced. A non-zero
    /// max-tick budget (the max-ticks knob, or
    /// [`Scheduler::set_max_run_ticks`]) bounds the loop: when it runs
    /// out, everything still queued or resident drains as
    /// [`ErrorKind::Exhausted`] failures instead of hanging the caller.
    pub fn run_to_completion(&mut self) -> Vec<Response> {
        let mut out = Vec::new();
        let mut spent = 0usize;
        while !self.is_idle() {
            if self.max_run_ticks > 0 && spent >= self.max_run_ticks {
                self.drain_failed(&mut out);
                break;
            }
            out.append(&mut self.tick());
            spent += 1;
        }
        out
    }

    /// Graceful drain (the server's stdin-EOF / shutdown path): run every
    /// queued and in-flight request to completion — retirement persists
    /// their session snapshots as usual — then flush the store's memory
    /// tier to durable records. Returns the retired responses plus
    /// `(sessions flushed, flush failures)`.
    pub fn drain(&mut self) -> (Vec<Response>, u64, u64) {
        let out = self.run_to_completion();
        let (flushed, failed) = match &self.sessions {
            Some(s) => s.flush_all(),
            None => (0, 0),
        };
        (out, flushed, failed)
    }

    /// The max-tick budget ran out: fail everything still queued or
    /// resident (shared rows release their pins) and drop the batches.
    fn drain_failed(&mut self, out: &mut Vec<Response>) {
        let now = self.clock.now_ns();
        let e = crate::error::Error::new(
            ErrorKind::Exhausted,
            format!("scheduler tick budget ({}) exhausted", self.max_run_ticks),
        );
        while let Some(entry) = self.queue.pop_front() {
            out.push(fail_err(entry.req, entry.enqueued_ns, &e,
                              entry.attempts as u64, now));
        }
        for (_, mut lane) in std::mem::take(&mut self.lanes) {
            for slot in lane.slots.iter_mut().filter_map(Option::take) {
                let (resp, trace) = slot_failed(slot, &e, now);
                self.traces.push(trace);
                out.push(resp);
            }
        }
        if let Some(mut sl) = self.shared.take() {
            for slot in sl.slots.iter_mut().filter_map(Option::take) {
                let adapter = slot.req.adapter.clone();
                let (resp, trace) = slot_failed(slot, &e, now);
                self.traces.push(trace);
                out.push(resp);
                self.release(&adapter);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::testing::{mock_delta, Accum, AccumAdapters, Counter};
    use std::cell::Cell;
    use std::rc::Rc;
    use std::sync::atomic::Ordering;

    fn counter_factory(b: usize) -> ServeFactory<'static> {
        Box::new(move |_adapter: &str| {
            Ok(ServeModel::Merged(LaneModel { model: Arc::new(Counter::new(b)), h0: None }))
        })
    }

    /// Factory handing out ONE shared [`Accum`] so tests can read its
    /// step/chunk counters after the scheduler ran.
    fn accum_factory(model: Arc<Accum>) -> ServeFactory<'static> {
        Box::new(move |_adapter: &str| {
            Ok(ServeModel::Merged(LaneModel { model: model.clone(), h0: None }))
        })
    }

    /// Merged-lane factory with a model-wide hash offset: the "merged
    /// adapter weights" baseline the shared-lane tests compare against.
    fn merged_off_factory(off: f32) -> ServeFactory<'static> {
        Box::new(move |_adapter: &str| {
            Ok(ServeModel::Merged(LaneModel {
                model: Arc::new(Accum::with_off(1, &[], off)),
                h0: None,
            }))
        })
    }

    /// Run one request alone on a dedicated merged lane with hash offset
    /// `off` — the ground truth for a shared-lane row carrying
    /// `mock_delta(off)`.
    fn solo_merged(off: f32, prompt: Vec<u8>, max_new: usize) -> Response {
        let mut s = Scheduler::new(merged_off_factory(off), 1);
        s.submit(req(9, "solo", prompt, max_new, 255));
        s.run_to_completion().pop().expect("solo run retires")
    }

    fn req(id: u64, adapter: &str, prompt: Vec<u8>, max_new: usize, stop: u8) -> Request {
        Request {
            id,
            adapter: adapter.into(),
            prompt,
            max_new,
            stop_byte: stop,
            beam: 1,
            deadline: 0,
            session: None,
        }
    }

    /// Same as [`req`] but tagged with a durable session id.
    fn sreq(id: u64, adapter: &str, sid: &str, prompt: Vec<u8>, max_new: usize)
        -> Request {
        Request { session: Some(sid.into()), ..req(id, adapter, prompt, max_new, 255) }
    }

    #[test]
    fn single_request_round_trip() {
        let mut s = Scheduler::new(counter_factory(2), 4);
        s.submit(req(1, "a", vec![10], 8, 13));
        let resps = s.run_to_completion();
        assert_eq!(resps.len(), 1);
        assert_eq!(resps[0].output, vec![11, 12]); // 13 is the stop byte
        assert_eq!(resps[0].finish, FinishReason::Stop);
        assert_eq!(resps[0].id, 1);
        assert!(s.is_idle());
    }

    #[test]
    fn per_request_stop_bytes() {
        // same lane, different stop bytes: each row honors its own
        let mut s = Scheduler::new(counter_factory(2), 4);
        s.submit(req(1, "a", vec![10], 16, 13));
        s.submit(req(2, "a", vec![10], 16, 15));
        let mut resps = s.run_to_completion();
        resps.sort_by_key(|r| r.id);
        assert_eq!(resps[0].output, vec![11, 12]);
        assert_eq!(resps[1].output, vec![11, 12, 13, 14]);
        assert_eq!(resps[1].finish, FinishReason::Stop);
    }

    #[test]
    fn max_new_caps_generation() {
        let mut s = Scheduler::new(counter_factory(1), 1);
        s.submit(req(1, "a", vec![10], 3, 0 /* never produced */));
        let resps = s.run_to_completion();
        assert_eq!(resps[0].output, vec![11, 12, 13]);
        assert_eq!(resps[0].finish, FinishReason::Length);
    }

    #[test]
    fn mid_stream_admission_completes_while_earlier_decodes() {
        // acceptance: a request admitted mid-stream finishes while an
        // earlier one is still decoding
        let mut s = Scheduler::new(counter_factory(2), 4);
        s.submit(req(1, "a", vec![10], 60, 0)); // long: 60 new tokens
        for _ in 0..5 {
            s.tick();
        }
        assert_eq!(s.active(), 1, "first request is mid-decode");
        s.submit(req(2, "a", vec![100], 2, 0)); // short: 2 new tokens
        let mut short_done = None;
        for _ in 0..10 {
            for r in s.tick() {
                assert_eq!(r.id, 2, "short request retires first");
                short_done = Some(r);
            }
            if short_done.is_some() {
                break;
            }
        }
        let short = short_done.expect("short request finished");
        assert_eq!(short.output, vec![101, 102]);
        assert_eq!(s.active(), 1, "long request STILL decoding after short retired");
        let rest = s.run_to_completion();
        assert_eq!(rest.len(), 1);
        assert_eq!(rest[0].id, 1);
        assert_eq!(rest[0].output.len(), 60);
    }

    #[test]
    fn full_batch_backpressure() {
        // batch width 2, three requests: the third waits for a free slot
        let mut s = Scheduler::new(counter_factory(2), 4);
        s.submit(req(1, "a", vec![10], 4, 0));
        s.submit(req(2, "a", vec![10], 8, 0));
        s.submit(req(3, "a", vec![10], 4, 0));
        s.tick();
        assert_eq!(s.active(), 2, "only two slots");
        assert_eq!(s.queued(), 1, "third request backpressured");
        let resps = s.run_to_completion();
        assert_eq!(resps.len(), 3);
        assert_eq!(s.queued(), 0);
        // the third request was admitted only after the first retired,
        // and still produced the right bytes from its recycled slot
        let r3 = resps.iter().find(|r| r.id == 3).unwrap();
        assert_eq!(r3.output, vec![11, 12, 13, 14]);
        assert!(r3.queued_s >= 0.0);
    }

    #[test]
    fn two_adapters_interleave() {
        // one lane per adapter; both make progress tick by tick
        let mut s = Scheduler::new(counter_factory(1), 4);
        s.submit(req(1, "alpha", vec![10], 3, 0));
        s.submit(req(2, "beta", vec![50], 3, 0));
        s.tick();
        assert_eq!(s.active(), 2, "both adapters admitted in one tick");
        let mut resps = s.run_to_completion();
        resps.sort_by_key(|r| r.id);
        assert_eq!(resps[0].output, vec![11, 12, 13]);
        assert_eq!(resps[1].output, vec![51, 52, 53]);
        assert_eq!(resps[0].adapter, "alpha");
        assert_eq!(resps[1].adapter, "beta");
    }

    #[test]
    fn lane_cap_waits_for_idle_lane() {
        // max_lanes 1: the second adapter's request waits until the first
        // lane drains, then recycles it
        let mut s = Scheduler::new(counter_factory(1), 1);
        s.submit(req(1, "alpha", vec![10], 2, 0));
        s.submit(req(2, "beta", vec![50], 2, 0));
        s.tick();
        assert_eq!(s.active(), 1);
        assert_eq!(s.queued(), 1, "beta waits for the single lane");
        let mut resps = s.run_to_completion();
        resps.sort_by_key(|r| r.id);
        assert_eq!(resps.len(), 2);
        assert_eq!(resps[1].output, vec![51, 52]);
    }

    #[test]
    fn factory_error_fails_request_not_scheduler() {
        let factory: ServeFactory<'static> = Box::new(|adapter: &str| {
            if adapter == "missing" {
                crate::bail!("unknown adapter");
            }
            Ok(ServeModel::Merged(LaneModel { model: Arc::new(Counter::new(1)), h0: None }))
        });
        let mut s = Scheduler::new(factory, 4);
        s.submit(req(1, "missing", vec![1], 4, 0));
        s.submit(req(2, "ok", vec![10], 2, 0));
        let mut resps = s.run_to_completion();
        resps.sort_by_key(|r| r.id);
        assert_eq!(resps[0].finish, FinishReason::Error);
        assert!(resps[0].error.as_deref().unwrap().contains("unknown adapter"));
        assert_eq!(resps[1].output, vec![11, 12]);
    }

    #[test]
    fn beam_request_runs_as_dedicated_pass() {
        let mut s = Scheduler::new(counter_factory(3), 4);
        let mut r = req(1, "a", vec![10], 4, 13);
        r.beam = 3;
        s.submit(r);
        let resps = s.run_to_completion();
        assert_eq!(resps.len(), 1);
        assert_eq!(resps[0].output, vec![11, 12], "beam agrees with greedy here");
        assert_eq!(resps[0].finish, FinishReason::Stop);
    }

    #[test]
    fn tok_per_s_excludes_queue_time() {
        // regression: tok_per_s divided by total_s (incl. queue wait)
        // while documented as "over the request's slot occupancy"
        let resp = Response {
            id: 1,
            adapter: "a".into(),
            output: vec![1, 2, 3],
            prompt_len: 4,
            queued_s: 0.5,
            total_s: 2.0,
            steps: 9,
            finish: FinishReason::Length,
            error: None,
            retries: 0,
            session: None,
        };
        assert!((resp.tok_per_s() - 2.0).abs() < 1e-12, "3 bytes / 1.5s occupancy");
        let degenerate = Response { queued_s: 2.0, ..resp };
        assert_eq!(degenerate.tok_per_s(), 0.0, "zero occupancy guarded");
    }

    #[test]
    fn prefill_then_admit_matches_stepwise() {
        // acceptance: chunked admission produces byte-identical output,
        // skips exactly the covered prompt steps, and issues ceil-plan
        // chunk dispatches
        let prompt: Vec<u8> = (0..37).map(|i| (i * 7 + 1) as u8).collect();
        let plain = Arc::new(Accum::new(2, &[]));
        let mut s0 = Scheduler::new(accum_factory(plain.clone()), 2);
        s0.submit(req(1, "a", prompt.clone(), 5, 255));
        let r0 = s0.run_to_completion();
        assert_eq!(s0.prefill_dispatches, 0, "no chunk support, no prefill");

        let chunked = Arc::new(Accum::new(2, &[8]));
        let mut s1 = Scheduler::new(accum_factory(chunked.clone()), 2);
        s1.submit(req(1, "a", prompt.clone(), 5, 255));
        let r1 = s1.run_to_completion();

        assert_eq!(r1[0].output, r0[0].output, "prefill must not change bytes");
        assert_eq!(r1[0].steps, r0[0].steps, "consumed-token accounting unchanged");
        // coverable prefix = 37 prompt tokens → four 8-chunks cover 32
        assert_eq!(s1.prefill_dispatches, 4);
        assert_eq!(s1.prefill_tokens, 32);
        assert_eq!(
            chunked.steps.load(Ordering::Relaxed),
            plain.steps.load(Ordering::Relaxed) - 32,
            "every prefilled token skips one lane step"
        );
    }

    #[test]
    fn prefill_admission_mid_stream_leaves_inflight_rows_intact() {
        // request A decodes for a while, then B is admitted with a long
        // prompt through the out-of-band prefill; both must produce the
        // same bytes they produce when run alone
        let solo = |prompt: Vec<u8>, max_new: usize| {
            let m = Arc::new(Accum::new(2, &[8]));
            let mut s = Scheduler::new(accum_factory(m), 2);
            s.submit(req(9, "a", prompt, max_new, 255));
            s.run_to_completion().pop().unwrap()
        };
        let pa = vec![10u8, 20, 30];
        let pb: Vec<u8> = (0..20).map(|i| (i * 3 + 2) as u8).collect();
        let want_a = solo(pa.clone(), 30);
        let want_b = solo(pb.clone(), 4);
        assert_eq!(want_b.finish, FinishReason::Stop, "B hits its stop byte");

        let model = Arc::new(Accum::new(2, &[8]));
        let mut s = Scheduler::new(accum_factory(model), 2);
        s.submit(req(1, "a", pa, 30, 255));
        for _ in 0..6 {
            s.tick();
        }
        assert_eq!(s.active(), 1, "A is mid-decode");
        let pre = s.prefill_dispatches;
        s.submit(req(2, "a", pb, 4, 255));
        let mut resps = s.run_to_completion();
        resps.sort_by_key(|r| r.id);
        assert_eq!(resps.len(), 2);
        assert_eq!(resps[0].output, want_a.output, "in-flight A undisturbed");
        assert_eq!(resps[1].output, want_b.output, "B correct after splice");
        assert_eq!(resps[1].finish, want_b.finish);
        assert_eq!(s.prefill_dispatches - pre, 2, "B's 20-byte prompt → two 8-chunks");
        assert_eq!(s.prefill_tokens, 16, "A's 3-byte prompt below chunk width");
    }

    #[test]
    fn prefill_unequal_prompts_splice_at_their_own_boundaries() {
        // regression: a row whose coverage completes mid-batch must be
        // spliced BEFORE later chunks scan the scratch state with its
        // token slots degraded to PAD — otherwise its state is polluted
        // and its first generated byte changes
        let pa: Vec<u8> = (0..8).map(|i| (i * 9 + 4) as u8).collect();
        let pb: Vec<u8> = (0..24).map(|i| (i * 5 + 7) as u8).collect();
        let solo = |prompt: Vec<u8>| {
            let m = Arc::new(Accum::new(2, &[8]));
            let mut s = Scheduler::new(accum_factory(m), 2);
            s.submit(req(9, "a", prompt, 3, 255));
            s.run_to_completion().pop().unwrap().output
        };
        let (want_a, want_b) = (solo(pa.clone()), solo(pb.clone()));
        let model = Arc::new(Accum::new(2, &[8]));
        let mut s = Scheduler::new(accum_factory(model), 2);
        s.submit(req(1, "a", pa, 3, 255));
        s.submit(req(2, "a", pb, 3, 255));
        let mut resps = s.run_to_completion();
        resps.sort_by_key(|r| r.id);
        assert_eq!(resps[0].output, want_a, "short row spliced at its boundary");
        assert_eq!(resps[1].output, want_b, "long row covered past the short one");
        // one 8-chunk covers A fully; two more cover B's remaining 16
        assert_eq!(s.prefill_dispatches, 3);
        assert_eq!(s.prefill_tokens, 8 + 24);
    }

    #[test]
    fn prefill_batches_across_waiters_on_one_lane() {
        // two requests admitted in the same tick share the chunked scan:
        // their common prefix is covered by ONE dispatch per chunk
        let p1: Vec<u8> = (0..17).map(|i| i as u8).collect();
        let p2: Vec<u8> = (0..17).map(|i| (i + 100) as u8).collect();
        let model = Arc::new(Accum::new(2, &[8]));
        let mut s = Scheduler::new(accum_factory(model), 2);
        let solo = |prompt: Vec<u8>| {
            let m = Arc::new(Accum::new(2, &[8]));
            let mut s = Scheduler::new(accum_factory(m), 2);
            s.submit(req(9, "a", prompt, 3, 255));
            s.run_to_completion().pop().unwrap().output
        };
        let (want1, want2) = (solo(p1.clone()), solo(p2.clone()));
        s.submit(req(1, "a", p1, 3, 255));
        s.submit(req(2, "a", p2, 3, 255));
        let mut resps = s.run_to_completion();
        resps.sort_by_key(|r| r.id);
        assert_eq!(resps[0].output, want1);
        assert_eq!(resps[1].output, want2);
        assert_eq!(s.prefill_dispatches, 2, "both 17-byte prompts share two 8-chunks");
        assert_eq!(s.prefill_tokens, 32, "16 covered tokens per request");
    }

    #[test]
    fn zero_budget_request_finishes_immediately() {
        let mut s = Scheduler::new(counter_factory(1), 1);
        s.submit(req(1, "a", vec![10], 0, 0));
        let resps = s.run_to_completion();
        assert_eq!(resps[0].output, Vec::<u8>::new());
        assert_eq!(resps[0].finish, FinishReason::Length);
    }

    // ---- shared unmerged lane -------------------------------------------

    /// Shared-lane factory over one [`AccumAdapters`]: adapter names map
    /// to per-row mock deltas ("base" = plain base, no delta).
    fn shared_factory(model: Arc<AccumAdapters>) -> ServeFactory<'static> {
        Box::new(move |adapter: &str| {
            let delta = match adapter {
                "base" => None,
                "five" => Some(mock_delta(5.0)),
                "nine" => Some(mock_delta(9.0)),
                other => crate::bail!("unknown adapter {other:?}"),
            };
            Ok(ServeModel::Shared { model: model.clone(), delta, h0: None })
        })
    }

    #[test]
    fn shared_lane_mixes_adapters_and_matches_solo_merged() {
        // THE tentpole contract at the scheduler level: two different
        // adapters share one continuous batch, each row's bytes are
        // identical to a dedicated merged lane, and the whole mixed batch
        // costs ONE dispatch per tick instead of one per adapter
        let model = Arc::new(AccumAdapters::new(2));
        let mut s = Scheduler::new(shared_factory(model.clone()), 4);
        s.submit(req(1, "five", vec![10, 20], 3, 255));
        s.submit(req(2, "nine", vec![40], 3, 255));
        let mut resps = s.run_to_completion();
        resps.sort_by_key(|r| r.id);
        assert_eq!(resps.len(), 2);
        let want1 = solo_merged(5.0, vec![10, 20], 3);
        let want2 = solo_merged(9.0, vec![40], 3);
        assert_ne!(want1.output, want2.output, "offsets must matter");
        assert_eq!(resps[0].output, want1.output, "row 0 == its merged solo run");
        assert_eq!(resps[1].output, want2.output, "row 1 == its merged solo run");
        assert_eq!(resps[0].steps, want1.steps, "slot accounting unchanged");
        assert_eq!(resps[1].steps, want2.steps);
        // collapsed-dispatch pin: the longer request needs 5 slot steps
        // (2 prompt + 3 generated), the shorter 4 — but the mixed batch
        // advances BOTH per dispatch: 5 total, not 5 + 4 on two lanes
        assert_eq!(want1.steps.max(want2.steps), 5);
        assert_eq!(model.steps.load(Ordering::Relaxed), 5);
        assert_eq!(s.decode_steps, 5);
    }

    #[test]
    fn shared_lane_fairness_under_adapter_skew() {
        // satellite: 3 requests for a hot adapter + 1 for a cold one on a
        // width-2 shared batch. FIFO admission means the cold request
        // waits exactly one 3-step wave behind the hot backlog — pinned
        // via max_admit_wait_ticks — and every factory Shared result is
        // released exactly once (retire or requeue).
        let model = Arc::new(AccumAdapters::new(2));
        let made = Rc::new(Cell::new(0u64));
        let released = Rc::new(Cell::new(0u64));
        let (m2, mc) = (model.clone(), made.clone());
        let factory: ServeFactory = Box::new(move |adapter: &str| {
            let delta = match adapter {
                "hot" => Some(mock_delta(3.0)),
                "cold" => Some(mock_delta(4.0)),
                other => crate::bail!("unknown adapter {other:?}"),
            };
            mc.set(mc.get() + 1);
            Ok(ServeModel::Shared { model: m2.clone(), delta, h0: None })
        });
        let mut s = Scheduler::new(factory, 4);
        let rc = released.clone();
        s.on_release(Box::new(move |_adapter: &str| rc.set(rc.get() + 1)));
        for id in 1..=3 {
            s.submit(req(id, "hot", vec![10], 2, 255));
        }
        s.submit(req(4, "cold", vec![10], 2, 255));
        let mut resps = s.run_to_completion();
        resps.sort_by_key(|r| r.id);
        assert_eq!(resps.len(), 4);
        for r in &resps {
            assert!(r.error.is_none(), "request {} failed: {:?}", r.id, r.error);
            assert_eq!(r.steps, 3, "1 prompt byte + 2 generated tokens");
        }
        // first wave (hot, hot) retires during tick 3; the third hot AND
        // the cold request admit together at tick 4 → max wait 3 ticks.
        // The cold adapter is NOT starved behind the entire hot backlog.
        assert_eq!(s.max_admit_wait_ticks, 3);
        // exact dispatch pins: two 3-step waves on the one shared batch
        assert_eq!(model.steps.load(Ordering::Relaxed), 6);
        assert_eq!(s.decode_steps, 6);
        // release balance: 4 retire releases + 6 full-batch requeue
        // releases (2 waiters × ticks 1-3) = every factory result returned
        assert_eq!(made.get(), 10);
        assert_eq!(released.get(), made.get());
    }

    #[test]
    fn shared_lane_random_churn_stays_byte_identical() {
        // randomized property: random adapter per row, admissions and
        // retirements interleaved with decoding, rows recycled across
        // adapters — every response byte-identical to its solo merged run
        use crate::tensor::Rng;
        const NAMES: [&str; 5] = ["base", "a2", "a3", "a5", "a7"];
        const OFFS: [f32; 5] = [0.0, 2.0, 3.0, 5.0, 7.0];
        let model = Arc::new(AccumAdapters::new(3));
        let m2 = model.clone();
        let factory: ServeFactory = Box::new(move |adapter: &str| {
            let i = NAMES
                .iter()
                .position(|n| *n == adapter)
                .ok_or_else(|| crate::err!("unknown adapter {adapter:?}"))?;
            let delta = (i > 0).then(|| mock_delta(OFFS[i]));
            Ok(ServeModel::Shared { model: m2.clone(), delta, h0: None })
        });
        let mut s = Scheduler::new(factory, 4);
        let mut rng = Rng::new(1234);
        let mut expected: Vec<(u64, Vec<u8>)> = Vec::new();
        let mut got: Vec<Response> = Vec::new();
        for id in 0..24u64 {
            let i = (rng.next_u64() % NAMES.len() as u64) as usize;
            let plen = 1 + (rng.next_u64() % 3) as usize;
            let prompt: Vec<u8> =
                (0..plen).map(|_| (rng.next_u64() % 200 + 1) as u8).collect();
            let max_new = 1 + (rng.next_u64() % 4) as usize;
            expected.push((id, solo_merged(OFFS[i], prompt.clone(), max_new).output));
            s.submit(req(id, NAMES[i], prompt, max_new, 255));
            got.append(&mut s.tick()); // admission interleaves with decode
        }
        got.append(&mut s.run_to_completion());
        assert_eq!(got.len(), expected.len());
        got.sort_by_key(|r| r.id);
        for (r, (id, want)) in got.iter().zip(&expected) {
            assert_eq!(r.id, *id);
            assert!(r.error.is_none(), "request {} failed: {:?}", r.id, r.error);
            assert_eq!(&r.output, want,
                       "request {} diverged from its solo merged run", r.id);
        }
    }

    #[test]
    fn merged_and_shared_lanes_coexist() {
        // an adapter the unmerged core can't represent falls back to a
        // merged lane; both batch kinds advance in the same ticks
        let model = Arc::new(AccumAdapters::new(2));
        let m2 = model.clone();
        let factory: ServeFactory = Box::new(move |adapter: &str| match adapter {
            "merged" => Ok(ServeModel::Merged(LaneModel {
                model: Arc::new(Accum::with_off(1, &[], 7.0)),
                h0: None,
            })),
            "five" => Ok(ServeModel::Shared {
                model: m2.clone(),
                delta: Some(mock_delta(5.0)),
                h0: None,
            }),
            other => crate::bail!("unknown adapter {other:?}"),
        });
        let mut s = Scheduler::new(factory, 4);
        s.submit(req(1, "merged", vec![10], 3, 255));
        s.submit(req(2, "five", vec![10], 3, 255));
        s.tick();
        assert_eq!(s.active(), 2, "one merged lane + one shared row");
        let mut resps = s.run_to_completion();
        resps.sort_by_key(|r| r.id);
        assert_eq!(resps[0].output, solo_merged(7.0, vec![10], 3).output);
        assert_eq!(resps[1].output, solo_merged(5.0, vec![10], 3).output);
    }

    #[test]
    fn shared_beam_runs_pinned_and_releases() {
        // a beam request for a shared-model adapter runs over a
        // PinnedAdapter view and returns its delta through the hook
        let released = Rc::new(Cell::new(0u64));
        let model = Arc::new(AccumAdapters::new(3));
        let m2 = model.clone();
        let factory: ServeFactory = Box::new(move |_adapter: &str| {
            Ok(ServeModel::Shared {
                model: m2.clone(),
                delta: Some(mock_delta(5.0)),
                h0: None,
            })
        });
        let mut s = Scheduler::new(factory, 4);
        let rc = released.clone();
        s.on_release(Box::new(move |_a: &str| rc.set(rc.get() + 1)));
        let mut r = req(1, "five", vec![10], 3, 255);
        r.beam = 3;
        s.submit(r);
        let resps = s.run_to_completion();
        assert_eq!(resps.len(), 1);
        assert!(resps[0].error.is_none(), "{:?}", resps[0].error);
        // the mock's logits are one-hot, so beam == greedy == merged solo
        assert_eq!(resps[0].output, solo_merged(5.0, vec![10], 3).output);
        assert_eq!(released.get(), 1, "beam releases its pinned delta");
    }

    use crate::fault::{FaultInject, FaultPlan, FaultSite};

    /// A [`StepDecode`] that consults a [`FaultPlan`] before every step —
    /// the mock analogue of the exec-run fault site. The check precedes
    /// the state update, so a faulted step leaves the state untouched
    /// (exactly like a failed dispatch).
    struct Flaky {
        inner: Accum,
        plan: Arc<FaultPlan>,
    }

    impl StepDecode for Flaky {
        fn arch_b(&self) -> usize {
            self.inner.arch_b()
        }
        fn dims(&self) -> crate::eval::StateDims {
            self.inner.dims()
        }
        fn step(&self, tokens: &IntTensor, state: &mut DecodeState) -> Result<Tensor> {
            self.plan.check(FaultSite::ExecRun)?;
            self.inner.step(tokens, state)
        }
    }

    /// Shared-batch analogue of [`Flaky`].
    struct FlakyShared {
        inner: AccumAdapters,
        plan: Arc<FaultPlan>,
    }

    impl StepDecode for FlakyShared {
        fn arch_b(&self) -> usize {
            self.inner.arch_b()
        }
        fn dims(&self) -> crate::eval::StateDims {
            self.inner.dims()
        }
        fn step(&self, tokens: &IntTensor, state: &mut DecodeState) -> Result<Tensor> {
            self.plan.check(FaultSite::ExecRun)?;
            self.inner.step(tokens, state)
        }
    }

    impl AdapterStepDecode for FlakyShared {
        fn step_rows(&self, tokens: &IntTensor, state: &mut DecodeState,
                     rows: &[AdapterRow]) -> Result<Tensor> {
            self.plan.check(FaultSite::ExecRun)?;
            self.inner.step_rows(tokens, state, rows)
        }
    }

    #[test]
    fn transient_step_fault_retries_byte_identical() {
        // a transient exec failure mid-decode rolls back to the tick's
        // checkpoint and retries in place: same bytes as the fault-free
        // run, one fault + one retry on the counters
        let want = solo_merged(0.0, vec![10, 20], 3);
        let plan =
            Arc::new(FaultPlan::seeded(7).with_fault_at(FaultSite::ExecRun, 2));
        let p2 = plan.clone();
        let factory: ServeFactory = Box::new(move |_a: &str| {
            Ok(ServeModel::Merged(LaneModel {
                model: Arc::new(Flaky {
                    inner: Accum::with_off(1, &[], 0.0),
                    plan: p2.clone(),
                }),
                h0: None,
            }))
        });
        let mut s = Scheduler::new(factory, 4);
        s.set_fault_inject(plan.clone());
        s.submit(req(1, "a", vec![10, 20], 3, 255));
        let resps = s.run_to_completion();
        assert_eq!(resps.len(), 1);
        assert!(resps[0].error.is_none(), "{:?}", resps[0].error);
        assert_eq!(resps[0].output, want.output, "retried run byte-identical");
        assert_eq!(s.step_faults, 1);
        assert_eq!(s.step_retries, 1);
        assert_eq!(plan.injected(FaultSite::ExecRun), 1);
        assert!(s.is_idle());
    }

    #[test]
    fn terminal_step_fault_fails_lane_and_notifies_breaker() {
        // a non-transient step failure retires the lane's requests with
        // the typed reason and reports the adapter to the failure hook
        let plan = Arc::new(
            FaultPlan::seeded(7)
                .with_fault_at(FaultSite::ExecRun, 1)
                .with_kind(ErrorKind::Invariant),
        );
        let p2 = plan.clone();
        let factory: ServeFactory = Box::new(move |_a: &str| {
            Ok(ServeModel::Merged(LaneModel {
                model: Arc::new(Flaky {
                    inner: Accum::with_off(1, &[], 0.0),
                    plan: p2.clone(),
                }),
                h0: None,
            }))
        });
        let failures = Rc::new(std::cell::RefCell::new(Vec::new()));
        let f2 = failures.clone();
        let mut s = Scheduler::new(factory, 4);
        s.set_fault_inject(plan);
        s.on_adapter_failure(Box::new(move |a: &str, k: ErrorKind| {
            f2.borrow_mut().push((a.to_string(), k));
        }));
        s.submit(req(1, "bad", vec![10], 4, 255));
        let resps = s.run_to_completion();
        assert_eq!(resps.len(), 1);
        assert_eq!(resps[0].finish,
                   FinishReason::Failed { kind: ErrorKind::Invariant });
        assert_eq!(resps[0].steps, 1, "one good step before the fault");
        assert_eq!(failures.borrow().as_slice(),
                   &[("bad".to_string(), ErrorKind::Invariant)]);
        assert!(s.is_idle());
    }

    #[test]
    fn shared_step_fault_demotes_rows_to_merged_fallback() {
        // terminal shared-step failure with a fallback installed: every
        // row demotes to a dedicated merged lane and re-runs
        // byte-identically, pins stay balanced, and nothing reaches the
        // failure hook (the solo re-run decides who was at fault)
        let plan = Arc::new(
            FaultPlan::seeded(11)
                .with_fault_at(FaultSite::ExecRun, 1)
                .with_kind(ErrorKind::Invariant),
        );
        let model = Arc::new(FlakyShared {
            inner: AccumAdapters::new(2),
            plan: plan.clone(),
        });
        let made = Rc::new(Cell::new(0u64));
        let released = Rc::new(Cell::new(0u64));
        let (m2, mc) = (model, made.clone());
        let factory: ServeFactory = Box::new(move |adapter: &str| {
            let delta = match adapter {
                "five" => Some(mock_delta(5.0)),
                "nine" => Some(mock_delta(9.0)),
                other => crate::bail!("unknown adapter {other:?}"),
            };
            mc.set(mc.get() + 1);
            Ok(ServeModel::Shared { model: m2.clone(), delta, h0: None })
        });
        let mut s = Scheduler::new(factory, 4);
        let rc = released.clone();
        s.on_release(Box::new(move |_a: &str| rc.set(rc.get() + 1)));
        s.set_fault_inject(plan.clone());
        s.set_merged_fallback(Box::new(|adapter: &str| {
            let off = if adapter == "five" { 5.0 } else { 9.0 };
            Ok(LaneModel { model: Arc::new(Accum::with_off(1, &[], off)), h0: None })
        }));
        s.submit(req(1, "five", vec![10, 20], 3, 255));
        s.submit(req(2, "nine", vec![40], 3, 255));
        let mut resps = s.run_to_completion();
        resps.sort_by_key(|r| r.id);
        assert_eq!(resps.len(), 2);
        for r in &resps {
            assert!(r.error.is_none(), "request {} failed: {:?}", r.id, r.error);
            assert_eq!(r.retries, 1, "one demotion requeue");
        }
        assert_eq!(resps[0].output, solo_merged(5.0, vec![10, 20], 3).output);
        assert_eq!(resps[1].output, solo_merged(9.0, vec![40], 3).output);
        assert_eq!(s.step_faults, 1);
        assert_eq!(s.demotions, 2);
        assert_eq!(plan.injected(FaultSite::ExecRun), 1);
        assert_eq!(released.get(), made.get(), "every pinned delta released");
        assert!(s.is_idle());
    }

    #[test]
    fn deadline_watchdog_retires_queued_and_resident_requests() {
        // resident: a request that cannot finish inside its tick budget
        let mut s = Scheduler::new(counter_factory(1), 4);
        let mut r = req(1, "a", vec![10], 50, 0);
        r.deadline = 3;
        s.submit(r);
        let resps = s.run_to_completion();
        assert_eq!(resps.len(), 1);
        assert_eq!(resps[0].finish,
                   FinishReason::Failed { kind: ErrorKind::Exhausted });
        assert!(resps[0].steps > 0, "was decoding when the watchdog fired");
        assert_eq!(s.deadline_failures, 1);
        assert!(s.is_idle());

        // queued: a single-slot lane keeps the second request waiting
        // past its deadline
        let mut s = Scheduler::new(counter_factory(1), 4);
        s.submit(req(1, "a", vec![10], 30, 0));
        let mut r = req(2, "a", vec![10], 4, 0);
        r.deadline = 2;
        s.submit(r);
        let mut resps = s.run_to_completion();
        resps.sort_by_key(|r| r.id);
        assert_eq!(resps.len(), 2);
        assert_eq!(resps[0].finish, FinishReason::Length, "winner unaffected");
        assert_eq!(resps[1].finish,
                   FinishReason::Failed { kind: ErrorKind::Exhausted });
        assert_eq!(resps[1].steps, 0, "never admitted");
        assert_eq!(s.deadline_failures, 1);
    }

    #[test]
    fn max_tick_budget_drains_as_exhausted() {
        let mut s = Scheduler::new(counter_factory(1), 4);
        s.set_max_run_ticks(3);
        s.submit(req(1, "a", vec![10], 1000, 0));
        let resps = s.run_to_completion();
        assert_eq!(resps.len(), 1);
        assert_eq!(resps[0].finish,
                   FinishReason::Failed { kind: ErrorKind::Exhausted });
        assert!(resps[0].error.as_deref().unwrap_or("").contains("tick budget"),
                "{:?}", resps[0].error);
        assert!(s.is_idle(), "drained, not hung");
    }

    #[test]
    fn transient_factory_error_requeues_bounded() {
        // an I/O-flavored adapter-load failure retries on the next tick
        // and the response records the requeue
        let calls = Rc::new(Cell::new(0u32));
        let c2 = calls.clone();
        let factory: ServeFactory = Box::new(move |_a: &str| {
            c2.set(c2.get() + 1);
            if c2.get() == 1 {
                return Err(crate::error::Error::new(ErrorKind::Io,
                                                    "flaky adapter load"));
            }
            Ok(ServeModel::Merged(LaneModel {
                model: Arc::new(Counter::new(1)),
                h0: None,
            }))
        });
        let mut s = Scheduler::new(factory, 4);
        s.submit(req(1, "a", vec![10], 2, 255));
        let resps = s.run_to_completion();
        assert_eq!(resps.len(), 1);
        assert!(resps[0].error.is_none(), "{:?}", resps[0].error);
        assert_eq!(resps[0].retries, 1);
        assert_eq!(calls.get(), 2);
    }

    #[test]
    fn factory_retry_budget_exhausts_typed() {
        // a persistently failing transient load stops after the request
        // retry budget and retires with the classified kind
        let calls = Rc::new(Cell::new(0u32));
        let c2 = calls.clone();
        let factory: ServeFactory = Box::new(move |_a: &str| {
            c2.set(c2.get() + 1);
            Err(crate::error::Error::new(ErrorKind::Io, "load keeps failing"))
        });
        let mut s = Scheduler::new(factory, 4);
        s.submit(req(1, "a", vec![10], 2, 255));
        let resps = s.run_to_completion();
        assert_eq!(resps.len(), 1);
        assert_eq!(resps[0].finish, FinishReason::Failed { kind: ErrorKind::Io });
        assert_eq!(resps[0].retries, REQUEST_RETRY_BUDGET as u64);
        assert_eq!(calls.get(), REQUEST_RETRY_BUDGET + 1);
        assert!(s.is_idle());
    }

    // ---- durable sessions -----------------------------------------------

    use crate::fault::{FaultPlan, FaultSite};

    /// Turn-2 prompt for a resumed conversation: the full turn-1
    /// transcript (prompt ++ output) plus new user bytes.
    fn next_turn(prompt1: &[u8], out1: &[u8], new: &[u8]) -> Vec<u8> {
        let mut p = prompt1.to_vec();
        p.extend_from_slice(out1);
        p.extend_from_slice(new);
        p
    }

    #[test]
    fn session_resume_skips_prefill_and_matches_full_replay() {
        // THE acceptance pin: the resumed turn's bytes are identical to
        // replaying the full history through chunked prefill, with ZERO
        // prefill dispatches and only the unabsorbed suffix stepped
        let store = Arc::new(SessionStore::new(8));
        let model = Arc::new(Accum::new(1, &[4, 8]));
        let mut s = Scheduler::new(accum_factory(model.clone()), 2);
        s.set_session_store(store.clone());
        let prompt1: Vec<u8> = (0..17).map(|i| (i * 3 + 5) as u8).collect();
        s.submit(sreq(1, "a", "chat-1", prompt1.clone(), 4));
        let r1 = s.run_to_completion().pop().expect("turn 1 retires");
        assert!(r1.error.is_none(), "{:?}", r1.error);
        assert_eq!(s.session_persists, 1);
        assert_eq!(r1.session.as_deref(), Some("chat-1"));

        let prompt2 = next_turn(&prompt1, &r1.output, &[71, 72, 73]);
        // ground truth: a fresh model replays the full history
        let ref_model = Arc::new(Accum::new(1, &[4, 8]));
        let mut s_ref = Scheduler::new(accum_factory(ref_model), 2);
        s_ref.submit(req(2, "a", prompt2.clone(), 4, 255));
        let want = s_ref.run_to_completion().pop().expect("replay retires");

        let chunks_before = model.chunks.load(Ordering::Relaxed);
        let steps_before = model.steps.load(Ordering::Relaxed);
        s.submit(sreq(2, "a", "chat-1", prompt2.clone(), 4));
        let r2 = s.run_to_completion().pop().expect("turn 2 retires");
        assert_eq!(r2.output, want.output, "resume must be byte-identical");
        assert_eq!(r2.steps, want.steps, "absolute token accounting unchanged");
        assert_eq!(s.session_resurrections, 1);
        assert_eq!(s.session_fallbacks, 0);
        assert_eq!(model.chunks.load(Ordering::Relaxed), chunks_before,
                   "zero prefill dispatches on resume");
        // only the unabsorbed tail was stepped: the absolute token count
        // (want.steps) minus what the snapshot already absorbed (r1.steps)
        assert_eq!(model.steps.load(Ordering::Relaxed) - steps_before,
                   want.steps - r1.steps);
        assert_eq!(s.session_persists, 2, "turn 2 re-persisted the session");
    }

    #[test]
    fn shared_lane_session_resume_matches_solo() {
        // resurrection works on the mixed-adapter batch too, and the
        // resumed row still matches its dedicated merged solo run
        let store = Arc::new(SessionStore::new(8));
        let model = Arc::new(AccumAdapters::new(2));
        let mut s = Scheduler::new(shared_factory(model.clone()), 4);
        s.set_session_store(store);
        s.submit(sreq(1, "five", "conv", vec![10, 20, 30], 3));
        let r1 = s.run_to_completion().pop().expect("turn 1 retires");
        assert_eq!(s.session_persists, 1);
        let prompt2 = next_turn(&[10, 20, 30], &r1.output, &[42]);
        let want = solo_merged(5.0, prompt2.clone(), 3);
        let steps_before = model.steps.load(Ordering::Relaxed);
        s.submit(sreq(2, "five", "conv", prompt2.clone(), 3));
        let r2 = s.run_to_completion().pop().expect("turn 2 retires");
        assert_eq!(r2.output, want.output, "shared-lane resume is byte-identical");
        assert_eq!(s.session_resurrections, 1);
        assert_eq!(model.steps.load(Ordering::Relaxed) - steps_before,
                   want.steps - r1.steps, "only the unabsorbed tail stepped");
    }

    #[test]
    fn session_load_fault_degrades_to_full_prefill() {
        // a saturated state_load fault can slow a session down, never
        // change its bytes: every turn falls back to full-history prefill
        let plan =
            Arc::new(FaultPlan::seeded(11).with_rate(FaultSite::StateLoad, 1.0));
        let store = Arc::new(SessionStore::new(8).with_faults(plan));
        let model = Arc::new(Accum::new(1, &[4]));
        let mut s = Scheduler::new(accum_factory(model.clone()), 2);
        s.set_session_store(store);
        let prompt1: Vec<u8> = (0..9).map(|i| (i + 2) as u8).collect();
        s.submit(sreq(1, "a", "hurt", prompt1.clone(), 3));
        let r1 = s.run_to_completion().pop().expect("turn 1 retires");
        assert!(r1.error.is_none(), "{:?}", r1.error);
        assert_eq!(s.session_fallbacks, 1, "turn 1's load attempt already faulted");

        let prompt2 = next_turn(&prompt1, &r1.output, &[99]);
        let ref_model = Arc::new(Accum::new(1, &[4]));
        let mut s_ref = Scheduler::new(accum_factory(ref_model), 2);
        s_ref.submit(req(2, "a", prompt2.clone(), 3, 255));
        let want = s_ref.run_to_completion().pop().expect("replay retires");
        s.submit(sreq(2, "a", "hurt", prompt2.clone(), 3));
        let r2 = s.run_to_completion().pop().expect("turn 2 retires");
        assert!(r2.error.is_none(), "{:?}", r2.error);
        assert_eq!(r2.output, want.output, "degraded, never wrong");
        assert_eq!(s.session_resurrections, 0);
        assert_eq!(s.session_fallbacks, 2);
    }

    #[test]
    fn persist_fault_counts_and_next_turn_reprefills() {
        let plan =
            Arc::new(FaultPlan::seeded(7).with_rate(FaultSite::StatePersist, 1.0));
        let store = Arc::new(SessionStore::new(8).with_faults(plan));
        let model = Arc::new(Accum::new(1, &[]));
        let mut s = Scheduler::new(accum_factory(model.clone()), 2);
        s.set_session_store(store);
        let prompt1 = vec![10u8, 20, 30];
        s.submit(sreq(1, "a", "lossy", prompt1.clone(), 3));
        let r1 = s.run_to_completion().pop().expect("turn 1 retires");
        assert!(r1.error.is_none(), "{:?}", r1.error);
        assert_eq!(s.session_persists, 0);
        assert_eq!(s.session_persist_failures, 1, "typed telemetry, not an error");
        let prompt2 = next_turn(&prompt1, &r1.output, &[40]);
        let ref_model = Arc::new(Accum::new(1, &[]));
        let mut s_ref = Scheduler::new(accum_factory(ref_model), 2);
        s_ref.submit(req(2, "a", prompt2.clone(), 3, 255));
        let want = s_ref.run_to_completion().pop().expect("replay retires");
        s.submit(sreq(2, "a", "lossy", prompt2.clone(), 3));
        let r2 = s.run_to_completion().pop().expect("turn 2 retires");
        assert_eq!(r2.output, want.output, "unpersisted session re-prefills");
        assert_eq!(s.session_resurrections, 0, "nothing persisted, nothing resumed");
    }

    #[test]
    fn stale_session_digest_falls_back_to_prefill() {
        // a session id reused with an UNRELATED prompt must not splice the
        // old conversation's state into the new one
        let store = Arc::new(SessionStore::new(8));
        let model = Arc::new(Accum::new(1, &[]));
        let mut s = Scheduler::new(accum_factory(model.clone()), 2);
        s.set_session_store(store);
        s.submit(sreq(1, "a", "reused", vec![10, 20, 30, 40], 3));
        s.run_to_completion().pop().expect("turn 1 retires");
        let fresh_prompt = vec![200u8, 201, 202, 203, 204];
        let ref_model = Arc::new(Accum::new(1, &[]));
        let mut s_ref = Scheduler::new(accum_factory(ref_model), 2);
        s_ref.submit(req(2, "a", fresh_prompt.clone(), 3, 255));
        let want = s_ref.run_to_completion().pop().expect("ref retires");
        s.submit(sreq(2, "a", "reused", fresh_prompt.clone(), 3));
        let r2 = s.run_to_completion().pop().expect("turn 2 retires");
        assert_eq!(r2.output, want.output, "stale snapshot must not be spliced");
        assert_eq!(s.session_resurrections, 0);
        assert_eq!(s.session_fallbacks, 1);
    }

    #[test]
    fn drain_then_restart_resumes_from_disk() {
        // the graceful-drain contract end to end: drain flushes resident
        // sessions to durable records; a NEW scheduler + NEW store over
        // the same dir (a process restart) resumes with zero prefill
        let dir = std::env::temp_dir()
            .join(format!("ssm-peft-sched-drain-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let prompt1: Vec<u8> = (0..13).map(|i| (i * 5 + 1) as u8).collect();
        let r1 = {
            let store = Arc::new(SessionStore::new(8).with_dir(&dir));
            let model = Arc::new(Accum::new(1, &[4]));
            let mut s = Scheduler::new(accum_factory(model), 2);
            s.set_session_store(store);
            s.submit(sreq(1, "a", "durable", prompt1.clone(), 3));
            let (mut resps, flushed, failed) = s.drain();
            assert_eq!((flushed, failed), (1, 0));
            resps.pop().expect("turn 1 retires")
        }; // "crash": scheduler, store, and model all dropped
        let store = Arc::new(SessionStore::new(8).with_dir(&dir));
        assert_eq!(store.recover().valid, 1, "the drained record survives");
        let model = Arc::new(Accum::new(1, &[4]));
        let mut s = Scheduler::new(accum_factory(model.clone()), 2);
        s.set_session_store(store);
        let prompt2 = next_turn(&prompt1, &r1.output, &[50, 60]);
        let ref_model = Arc::new(Accum::new(1, &[4]));
        let mut s_ref = Scheduler::new(accum_factory(ref_model), 2);
        s_ref.submit(req(2, "a", prompt2.clone(), 3, 255));
        let want = s_ref.run_to_completion().pop().expect("replay retires");
        let chunks_before = model.chunks.load(Ordering::Relaxed);
        s.submit(sreq(2, "a", "durable", prompt2.clone(), 3));
        let r2 = s.run_to_completion().pop().expect("turn 2 retires");
        assert_eq!(r2.output, want.output, "disk-resumed turn is byte-identical");
        assert_eq!(s.session_resurrections, 1);
        assert_eq!(model.chunks.load(Ordering::Relaxed), chunks_before,
                   "zero prefill dispatches after restart");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn tick_hook_drives_circuit_breaker_probation() {
        use crate::serve::registry::{Adapter, AdapterRegistry};
        use std::sync::atomic::AtomicBool;
        // adapter source that is down at first and recovers mid-run
        let down = Arc::new(AtomicBool::new(true));
        let d2 = down.clone();
        let source = move |name: &str| -> Result<Adapter> {
            if d2.load(Ordering::Relaxed) {
                crate::bail!("adapter artifacts unreachable");
            }
            Ok(Adapter {
                name: name.to_string(),
                decode_variant: "a_full".into(),
                delta: None,
                h0: None,
                budget_pct: 1.0,
            })
        };
        let mut reg = AdapterRegistry::new(source, 4);
        reg.set_quarantine_threshold(1);
        reg.set_probation_ticks(3);
        let reg = reg;
        assert!(reg.record_failure("flaky"), "one failure opens the circuit");
        let factory: ServeFactory = Box::new(|adapter: &str| {
            reg.get(adapter)?; // the registry gates admission
            Ok(ServeModel::Merged(LaneModel {
                model: Arc::new(Counter::new(2)),
                h0: None,
            }))
        });
        let mut s = Scheduler::new(factory, 2);
        s.on_tick(Box::new(|| reg.note_tick()));
        // open circuit: the request is rejected at admission
        s.submit(req(1, "flaky", vec![10], 2, 0));
        let r = s.run_to_completion().pop().expect("rejection retires");
        assert_eq!(r.finish, FinishReason::Failed);
        assert!(r.error.as_deref().unwrap_or("").contains("quarantined"), "{r:?}");
        // idle scheduler ticks age the circuit through the tick hook
        let mut ticks = 0;
        while !reg.is_half_open("flaky") {
            s.tick();
            ticks += 1;
            assert!(ticks < 10, "probation window never armed");
        }
        // half-open but the source is still down: the one trial load
        // fails, re-opens the circuit, and the request retires failed
        s.submit(req(2, "flaky", vec![10], 2, 0));
        let r = s.run_to_completion().pop().expect("failed trial retires");
        assert_eq!(r.finish, FinishReason::Failed);
        assert!(reg.is_quarantined("flaky") && !reg.is_half_open("flaky"));
        assert_eq!(reg.stats().probations, 1, "exactly one probe per window");
        // next window: the source has recovered, so the trial passes and
        // the very same request decodes normally
        down.store(false, Ordering::Relaxed);
        let mut ticks = 0;
        while !reg.is_half_open("flaky") {
            s.tick();
            ticks += 1;
            assert!(ticks < 10, "second probation window never armed");
        }
        s.submit(req(3, "flaky", vec![10], 2, 0));
        let r = s.run_to_completion().pop().expect("reinstated adapter serves");
        assert!(r.error.is_none(), "{:?}", r.error);
        assert_eq!(r.output, vec![11, 12]);
        assert!(!reg.is_quarantined("flaky"));
        let st = reg.stats();
        assert_eq!((st.probations, st.reinstated), (2, 1));
    }

    #[test]
    fn idle_tick_is_flagged_and_queued_request_admits_next_tick() {
        // regression for the parked-backoff serve loop: an unproductive
        // tick must raise the idle flag, and a request arriving while
        // parked must be admitted by the very next tick — backoff can
        // delay polling, never admission.
        let mut s = Scheduler::new(counter_factory(2), 2);
        assert!(!s.last_tick_idle(), "fresh scheduler has not ticked");
        s.tick();
        s.tick();
        assert!(s.last_tick_idle());
        assert_eq!(s.idle_ticks, 2);
        s.submit(req(1, "a", vec![10], 2, 0));
        s.tick();
        assert!(!s.last_tick_idle(), "admission tick is not idle");
        assert_eq!(s.admissions, 1);
        let out = s.run_to_completion();
        assert_eq!(out.len(), 1);
        assert_eq!(s.idle_ticks, 2, "productive ticks never count as idle");
        assert_eq!(s.traces().len(), 1, "the retired request left a trace");
    }

    #[test]
    fn virtual_clock_traces_are_byte_identical_across_runs() {
        // acceptance: under a VirtualClock the span timeline is a pure
        // function of the tick sequence, so the emitted trace JSON is
        // byte-identical run to run
        let run = || {
            let clock = Arc::new(crate::obs::VirtualClock::new());
            let mut s = Scheduler::new(counter_factory(2), 2);
            s.set_clock(clock.clone());
            s.submit(req(1, "a", vec![10, 20], 3, 0));
            s.submit(req(2, "b", vec![30], 2, 0));
            let mut out = Vec::new();
            while !s.is_idle() {
                clock.advance_ticks(1);
                out.append(&mut s.tick());
            }
            assert_eq!(out.len(), 2);
            crate::json::emit(&s.traces().to_json())
        };
        let a = run();
        assert_eq!(a, run(), "trace JSON must not vary across runs");
        // the timeline is in whole virtual ticks and well-ordered
        let v = crate::json::parse(&a).expect("trace json parses");
        for t in v.as_arr().expect("trace array") {
            let ns = |k: &str| t.get(k).and_then(|x| x.as_usize()).expect(k) as u64;
            assert_eq!(ns("enqueued_ns") % crate::obs::TICK_NS, 0);
            assert!(ns("admitted_ns") >= ns("enqueued_ns"));
            assert!(ns("first_token_ns") >= ns("admitted_ns"));
            assert!(ns("retired_ns") >= ns("first_token_ns"));
            assert!(ns("ttft_ns") > 0, "first token was produced");
        }
    }

    #[test]
    fn tracing_is_dispatch_neutral_across_clocks() {
        // acceptance pin: with no stats consumer attached, tracing adds
        // zero model dispatches — the same workload under the wall clock
        // and the virtual clock issues identical step/chunk counts and
        // byte-identical outputs (only timestamps differ)
        let run = |virt: bool| {
            let model = Arc::new(Accum::new(2, &[]));
            let mut s = Scheduler::new(accum_factory(model.clone()), 2);
            if virt {
                s.set_clock(Arc::new(crate::obs::VirtualClock::new()));
            }
            for id in 0..4u64 {
                let adapter = if id % 2 == 0 { "a" } else { "b" };
                s.submit(req(id, adapter, vec![id as u8 + 1; 3], 4, 255));
            }
            let mut out = s.run_to_completion();
            out.sort_by_key(|r| r.id);
            let bytes: Vec<Vec<u8>> = out.iter().map(|r| r.output.clone()).collect();
            (
                bytes,
                model.steps.load(Ordering::Relaxed),
                model.chunks.load(Ordering::Relaxed),
                s.traces().len(),
            )
        };
        let wall = run(false);
        let virt = run(true);
        assert_eq!(wall, virt, "clock choice changes timestamps only");
        assert_eq!(wall.3, 4, "every retired request leaves a trace");
    }

    #[test]
    fn publish_metrics_mirrors_scheduler_counters() {
        let m = crate::obs::Metrics::new();
        let mut s = Scheduler::new(counter_factory(2), 2);
        s.submit(req(1, "a", vec![10], 2, 0));
        let out = s.run_to_completion();
        assert_eq!(out.len(), 1);
        s.publish_metrics(&m);
        let snap = m.snapshot();
        let counters = snap.path("counters").expect("counters section");
        let c = |k: &str| counters.get(k).and_then(|v| v.as_usize()).expect(k);
        assert_eq!(c("sched.admissions"), 1);
        assert_eq!(c("sched.traces_recorded"), 1);
        assert_eq!(c("sched.decode_steps"), s.decode_steps as usize);
        assert_eq!(c("sched.ticks"), s.ticks as usize);
        let gauges = snap.path("gauges").expect("gauges section");
        assert_eq!(gauges.get("sched.queued").and_then(|v| v.as_usize()), Some(0));
    }
}
