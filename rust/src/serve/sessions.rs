//! Durable per-session state store: crash-safe O(1) conversation resume.
//!
//! An SSM conversation's entire history is a fixed-size `(conv, ssm)`
//! state (the O(1) decode property the paper's SDT/LoRA adapters ride),
//! so persisting a few-KB snapshot per session buys zero re-prefill
//! multi-turn chat at any history length. This module is the robustness
//! half of that bargain: the store must survive crashes, torn writes,
//! corrupt records, and full disks **without ever serving a wrong
//! state** — every failure degrades to full-history chunked prefill
//! (rust/docs/robustness.md § Sessions).
//!
//! Two tiers:
//!
//! - an in-memory LRU tier ([`SessionStore::new`] sets its capacity) that
//!   serves the hot path with zero I/O;
//! - a spill-to-disk tier ([`SessionStore::with_dir`]) of one record per
//!   session — checksummed, versioned, geometry-tagged, written via
//!   temp-file + atomic rename so a crash can tear a *temp* file but
//!   never a committed record.
//!
//! Safety invariants:
//!
//! - a record is only ever trusted after its FNV-1a checksum, magic,
//!   version, geometry tag, and payload lengths all validate — anything
//!   else is quarantined to `<name>.corrupt` (never deleted, so an
//!   operator can inspect it) and the session re-prefills;
//! - the resume-side prefix digest ([`history_digest`]) ties a snapshot
//!   to the exact byte history it absorbed, so a stale or foreign
//!   snapshot can never silently splice into the wrong conversation;
//! - the [`FaultSite::StatePersist`] / [`FaultSite::StateLoad`] hooks
//!   inject write/read failures (knobs `SSM_PEFT_FAULT_STATE_PERSIST`,
//!   `SSM_PEFT_FAULT_STATE_LOAD`); transient ones get a bounded in-place
//!   retry, terminal ones surface as typed errors the scheduler turns
//!   into a re-prefill fallback.
//!
//! Knobs: `SSM_PEFT_SESSIONS_DIR` (spill directory; unset = memory-only)
//! and `SSM_PEFT_SESSIONS_CAP` (LRU entries) — both registered in
//! [`crate::knobs`].

use std::collections::{BTreeMap, VecDeque};
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::error::{Error, ErrorKind, Result};
use crate::eval::StateDims;
use crate::fault::{FaultInject, FaultSite};

/// Record magic (first 8 bytes of every spilled session record).
pub const SESSION_MAGIC: [u8; 8] = *b"SSMSESS1";

/// Record format version; bump on any layout change so old binaries
/// quarantine new records instead of misreading them.
pub const SESSION_RECORD_VERSION: u32 = 1;

/// Bounded attempts for a persist/load guarded by the fault hooks: one
/// in-place retry for transient failures, then degrade.
const SESSION_IO_ATTEMPTS: u32 = 2;

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// FNV-1a over `bytes`, continuing from hash state `h`.
pub fn fnv1a_extend(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= u64::from(b);
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// FNV-1a of `bytes` from the standard offset basis.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    fnv1a_extend(FNV_OFFSET, bytes)
}

/// Digest of the first `absorbed` history bytes of a conversation whose
/// transcript so far is `prompt` followed by `out`. This is what ties a
/// snapshot to its exact byte history: at resume time the new request's
/// prompt must reproduce the digest over the absorbed prefix, or the
/// snapshot is treated as a miss and the request re-prefills.
pub fn history_digest(prompt: &[u8], out: &[u8], absorbed: usize) -> u64 {
    let n = absorbed.min(prompt.len());
    let rest = absorbed.saturating_sub(n).min(out.len());
    fnv1a_extend(fnv1a(&prompt[..n]), &out[..rest])
}

/// One session's resumable state: the per-row `(conv, ssm)` buffers plus
/// the bookkeeping that makes splicing them back *safe* — how many tokens
/// the state absorbed (BOS included) and the digest of the absorbed byte
/// history.
#[derive(Debug, Clone, PartialEq)]
pub struct SessionSnapshot {
    /// State geometry the buffers were captured under.
    pub dims: StateDims,
    /// Tokens the state has absorbed, BOS included (the resumed slot's
    /// `t`); the absorbed *byte* history is `consumed - 1` bytes long.
    pub consumed: u64,
    /// [`history_digest`] over the absorbed byte history.
    pub history_hash: u64,
    /// One row's conv state across every layer (`n_layer *
    /// (d_conv-1) * d_inner` floats).
    pub conv: Vec<f32>,
    /// One row's SSM state across every layer (`n_layer * d_inner *
    /// d_state` floats).
    pub ssm: Vec<f32>,
}

fn push_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn push_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn parse_err(msg: &str) -> Error {
    Error::new(ErrorKind::Parse, format!("session record: {msg}"))
}

fn take<'a>(bytes: &'a [u8], at: &mut usize, n: usize) -> Result<&'a [u8]> {
    let end = at
        .checked_add(n)
        .filter(|&e| e <= bytes.len())
        .ok_or_else(|| parse_err("truncated"))?;
    let s = &bytes[*at..end];
    *at = end;
    Ok(s)
}

fn take_u32(bytes: &[u8], at: &mut usize) -> Result<u32> {
    let s = take(bytes, at, 4)?;
    let mut buf = [0u8; 4];
    buf.copy_from_slice(s);
    Ok(u32::from_le_bytes(buf))
}

fn take_u64(bytes: &[u8], at: &mut usize) -> Result<u64> {
    let s = take(bytes, at, 8)?;
    let mut buf = [0u8; 8];
    buf.copy_from_slice(s);
    Ok(u64::from_le_bytes(buf))
}

impl SessionSnapshot {
    /// Serialize to the on-disk record layout: magic, version, geometry
    /// tag, `consumed`, history digest, payload lengths, f32-LE payloads,
    /// and a trailing FNV-1a checksum over everything before it.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(68 + 4 * (self.conv.len() + self.ssm.len()));
        out.extend_from_slice(&SESSION_MAGIC);
        push_u32(&mut out, SESSION_RECORD_VERSION);
        push_u32(&mut out, self.dims.n_layer as u32);
        push_u32(&mut out, self.dims.d_conv as u32);
        push_u32(&mut out, self.dims.d_inner as u32);
        push_u32(&mut out, self.dims.d_state as u32);
        push_u64(&mut out, self.consumed);
        push_u64(&mut out, self.history_hash);
        push_u64(&mut out, self.conv.len() as u64);
        push_u64(&mut out, self.ssm.len() as u64);
        for v in self.conv.iter().chain(self.ssm.iter()) {
            out.extend_from_slice(&v.to_le_bytes());
        }
        let sum = fnv1a(&out);
        push_u64(&mut out, sum);
        out
    }

    /// Parse and fully validate a record. Every defect — truncation,
    /// checksum mismatch, bad magic/version, inconsistent geometry or
    /// lengths, trailing garbage — is a typed
    /// [`ErrorKind::Parse`] error; a record that decodes is
    /// byte-for-byte the one that was written.
    pub fn decode(bytes: &[u8]) -> Result<SessionSnapshot> {
        if bytes.len() < 8 {
            return Err(parse_err("truncated"));
        }
        let (body, tail) = bytes.split_at(bytes.len() - 8);
        let mut sumbuf = [0u8; 8];
        sumbuf.copy_from_slice(tail);
        if fnv1a(body) != u64::from_le_bytes(sumbuf) {
            return Err(parse_err("checksum mismatch"));
        }
        let mut at = 0usize;
        if take(body, &mut at, 8)? != SESSION_MAGIC {
            return Err(parse_err("bad magic"));
        }
        let version = take_u32(body, &mut at)?;
        if version != SESSION_RECORD_VERSION {
            return Err(parse_err(&format!("unsupported version {version}")));
        }
        let dims = StateDims {
            n_layer: take_u32(body, &mut at)? as usize,
            d_conv: take_u32(body, &mut at)? as usize,
            d_inner: take_u32(body, &mut at)? as usize,
            d_state: take_u32(body, &mut at)? as usize,
        };
        if dims.n_layer == 0 || dims.d_conv < 2 || dims.d_inner == 0 || dims.d_state == 0 {
            return Err(parse_err("degenerate geometry tag"));
        }
        let consumed = take_u64(body, &mut at)?;
        let history_hash = take_u64(body, &mut at)?;
        let conv_len = take_u64(body, &mut at)? as usize;
        let ssm_len = take_u64(body, &mut at)? as usize;
        if conv_len != dims.n_layer * dims.conv_per_row()
            || ssm_len != dims.n_layer * dims.ssm_per_row()
        {
            return Err(parse_err("payload lengths disagree with geometry tag"));
        }
        let mut read_f32s = |n: usize| -> Result<Vec<f32>> {
            let raw = take(body, &mut at, 4 * n)?;
            Ok(raw
                .chunks_exact(4)
                .map(|c| {
                    let mut b = [0u8; 4];
                    b.copy_from_slice(c);
                    f32::from_le_bytes(b)
                })
                .collect())
        };
        let conv = read_f32s(conv_len)?;
        let ssm = read_f32s(ssm_len)?;
        if at != body.len() {
            return Err(parse_err("trailing garbage"));
        }
        Ok(SessionSnapshot { dims, consumed, history_hash, conv, ssm })
    }

    fn approx_bytes(&self) -> usize {
        68 + 4 * (self.conv.len() + self.ssm.len())
    }
}

/// Counters the store accumulates over its lifetime (monotonic; read via
/// [`SessionStore::stats`]).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct SessionStats {
    /// Loads served from memory or a valid disk record.
    pub hits: u64,
    /// Loads that found nothing (clean miss → re-prefill).
    pub misses: u64,
    /// Entries currently resident in the memory tier.
    pub resident: usize,
    /// Approximate bytes resident in the memory tier.
    pub resident_bytes: usize,
    /// LRU evictions spilled to a durable record.
    pub spills: u64,
    /// Corrupt/mismatched records quarantined to `*.corrupt`.
    pub quarantined: u64,
    /// Persist-side failures (injected faults, full disks, lost spills).
    pub persist_failures: u64,
    /// Load-side failures (injected faults, unreadable files).
    pub load_failures: u64,
}

impl SessionStats {
    /// Publish this snapshot into a metrics registry under `sessions.*`
    /// (instrument names: rust/docs/observability.md § Registry).
    pub fn publish(&self, m: &crate::obs::Metrics) {
        m.counter("sessions.hits").set(self.hits);
        m.counter("sessions.misses").set(self.misses);
        m.counter("sessions.spills").set(self.spills);
        m.counter("sessions.quarantined").set(self.quarantined);
        m.counter("sessions.persist_failures").set(self.persist_failures);
        m.counter("sessions.load_failures").set(self.load_failures);
        m.gauge("sessions.resident").set(self.resident as u64);
        m.gauge("sessions.resident_bytes").set(self.resident_bytes as u64);
    }
}

/// What the startup recovery scan found (see [`SessionStore::recover`]).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct RecoveryReport {
    /// Records that validated end to end and remain loadable.
    pub valid: usize,
    /// Records quarantined to `*.corrupt` (torn, corrupt, or mismatched).
    pub quarantined: usize,
    /// Leftover temp files from interrupted writes, removed.
    pub removed_tmp: usize,
}

struct Tier {
    map: BTreeMap<String, SessionSnapshot>,
    /// LRU order, coldest at the front. Kept in lockstep with `map`.
    order: VecDeque<String>,
}

impl Tier {
    fn touch(&mut self, id: &str) {
        if let Some(pos) = self.order.iter().position(|k| k == id) {
            self.order.remove(pos);
        }
        self.order.push_back(id.to_string());
    }
}

/// The two-tier durable session-state store. Thread-safe (the serve loop
/// is single-threaded, but the registry precedent holds: internal
/// locking, atomic counters, callers share it via `Arc`).
pub struct SessionStore {
    cap: usize,
    dir: Option<PathBuf>,
    dims: Option<StateDims>,
    faults: Option<Arc<dyn FaultInject>>,
    tier: Mutex<Tier>,
    hits: AtomicU64,
    misses: AtomicU64,
    spills: AtomicU64,
    quarantined: AtomicU64,
    persist_failures: AtomicU64,
    load_failures: AtomicU64,
}

impl SessionStore {
    /// Memory-only store holding at most `cap` sessions (floored at 1).
    pub fn new(cap: usize) -> SessionStore {
        SessionStore {
            cap: cap.max(1),
            dir: None,
            dims: None,
            faults: None,
            tier: Mutex::new(Tier { map: BTreeMap::new(), order: VecDeque::new() }),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            spills: AtomicU64::new(0),
            quarantined: AtomicU64::new(0),
            persist_failures: AtomicU64::new(0),
            load_failures: AtomicU64::new(0),
        }
    }

    /// Add the spill-to-disk tier rooted at `dir` (created on first use).
    pub fn with_dir(mut self, dir: impl Into<PathBuf>) -> SessionStore {
        self.dir = Some(dir.into());
        self
    }

    /// Pin the expected state geometry: records tagged with any other
    /// geometry are quarantined at load/recovery instead of spliced.
    pub fn with_dims(mut self, dims: StateDims) -> SessionStore {
        self.dims = Some(dims);
        self
    }

    /// Install the fault-injection hook gating the
    /// [`FaultSite::StatePersist`] / [`FaultSite::StateLoad`] sites.
    pub fn with_faults(mut self, faults: Arc<dyn FaultInject>) -> SessionStore {
        self.faults = Some(faults);
        self
    }

    /// The spill directory, when the disk tier is configured.
    pub fn dir(&self) -> Option<&Path> {
        self.dir.as_deref()
    }

    /// The durable record path for a session id (`None` without a disk
    /// tier). Ids are sanitized into the filename and disambiguated by a
    /// digest suffix, so hostile ids cannot traverse out of the dir.
    pub fn record_path(&self, id: &str) -> Option<PathBuf> {
        let dir = self.dir.as_ref()?;
        let safe: String = id
            .chars()
            .take(48)
            .map(|c| if c.is_ascii_alphanumeric() || "._-".contains(c) { c } else { '_' })
            .collect();
        Some(dir.join(format!("{safe}-{:016x}.session", fnv1a(id.as_bytes()))))
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Tier> {
        self.tier.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
    }

    /// Consult the fault hook with a bounded in-place retry: transient
    /// injected failures get [`SESSION_IO_ATTEMPTS`] tries, terminal ones
    /// surface immediately.
    fn guard(&self, site: FaultSite) -> Result<()> {
        let Some(f) = &self.faults else { return Ok(()) };
        let mut last: Option<Error> = None;
        for _ in 0..SESSION_IO_ATTEMPTS {
            match f.check(site) {
                Ok(()) => return Ok(()),
                Err(e) => {
                    let transient = e.kind().is_transient();
                    last = Some(e);
                    if !transient {
                        break;
                    }
                }
            }
        }
        Err(last.unwrap_or_else(|| crate::err!("fault guard invariant")))
    }

    /// Store (or refresh) a session's snapshot in the memory tier; LRU
    /// evictions spill to the disk tier. A returned error means the
    /// snapshot was NOT stored (the session will re-prefill next turn) —
    /// never a partial or silently-wrong record.
    pub fn persist(&self, id: &str, snap: SessionSnapshot) -> Result<()> {
        if let Err(e) = self.guard(FaultSite::StatePersist) {
            self.persist_failures.fetch_add(1, Ordering::Relaxed);
            return Err(e.context("session persist"));
        }
        if let Some(d) = &self.dims {
            if snap.dims != *d {
                self.persist_failures.fetch_add(1, Ordering::Relaxed);
                return Err(Error::new(
                    ErrorKind::Invariant,
                    "session snapshot geometry disagrees with the store's",
                ));
            }
        }
        if snap.conv.len() != snap.dims.n_layer * snap.dims.conv_per_row()
            || snap.ssm.len() != snap.dims.n_layer * snap.dims.ssm_per_row()
        {
            self.persist_failures.fetch_add(1, Ordering::Relaxed);
            return Err(Error::new(
                ErrorKind::Invariant,
                "session snapshot payload disagrees with its geometry tag",
            ));
        }
        let mut evicted: Vec<(String, SessionSnapshot)> = Vec::new();
        {
            let mut tier = self.lock();
            tier.map.insert(id.to_string(), snap);
            tier.touch(id);
            while tier.map.len() > self.cap {
                let Some(cold) = tier.order.pop_front() else { break };
                if let Some(s) = tier.map.remove(&cold) {
                    evicted.push((cold, s));
                }
            }
        }
        for (eid, esnap) in evicted {
            match self.write_record(&eid, &esnap) {
                Ok(()) => {
                    self.spills.fetch_add(1, Ordering::Relaxed);
                }
                Err(_) => {
                    // no disk tier, or the write failed: the evicted
                    // session is lost and will re-prefill — degraded,
                    // never wrong
                    self.persist_failures.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
        Ok(())
    }

    /// Fetch a session's snapshot: memory tier first, then a validated
    /// disk record (promoted back into memory). `Ok(None)` is a clean
    /// miss; `Err` is a load failure or a quarantined corrupt record —
    /// either way the caller re-prefills.
    pub fn load(&self, id: &str) -> Result<Option<SessionSnapshot>> {
        if let Err(e) = self.guard(FaultSite::StateLoad) {
            self.load_failures.fetch_add(1, Ordering::Relaxed);
            return Err(e.context("session load"));
        }
        {
            let mut tier = self.lock();
            if let Some(snap) = tier.map.get(id).cloned() {
                tier.touch(id);
                self.hits.fetch_add(1, Ordering::Relaxed);
                return Ok(Some(snap));
            }
        }
        let Some(path) = self.record_path(id) else {
            self.misses.fetch_add(1, Ordering::Relaxed);
            return Ok(None);
        };
        let bytes = match std::fs::read(&path) {
            Ok(b) => b,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                return Ok(None);
            }
            Err(e) => {
                self.load_failures.fetch_add(1, Ordering::Relaxed);
                return Err(Error::new(ErrorKind::Io, format!("session record read: {e}")));
            }
        };
        let snap = match self.validate(&bytes) {
            Ok(s) => s,
            Err(e) => {
                // corrupt / truncated / wrong-geometry: quarantine the
                // file so it is never trusted again, and degrade
                self.quarantine(&path);
                return Err(e);
            }
        };
        // promote back into the memory tier (same LRU/spill rules)
        let mut evicted: Vec<(String, SessionSnapshot)> = Vec::new();
        {
            let mut tier = self.lock();
            tier.map.insert(id.to_string(), snap.clone());
            tier.touch(id);
            while tier.map.len() > self.cap {
                let Some(cold) = tier.order.pop_front() else { break };
                if let Some(s) = tier.map.remove(&cold) {
                    evicted.push((cold, s));
                }
            }
        }
        for (eid, esnap) in evicted {
            match self.write_record(&eid, &esnap) {
                Ok(()) => {
                    self.spills.fetch_add(1, Ordering::Relaxed);
                }
                Err(_) => {
                    self.persist_failures.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
        self.hits.fetch_add(1, Ordering::Relaxed);
        Ok(Some(snap))
    }

    /// Decode + geometry-check a record's bytes.
    fn validate(&self, bytes: &[u8]) -> Result<SessionSnapshot> {
        let snap = SessionSnapshot::decode(bytes)?;
        if let Some(d) = &self.dims {
            if snap.dims != *d {
                return Err(parse_err("geometry tag disagrees with the serving model"));
            }
        }
        Ok(snap)
    }

    fn quarantine(&self, path: &Path) {
        self.quarantined.fetch_add(1, Ordering::Relaxed);
        let target = PathBuf::from(format!("{}.corrupt", path.display()));
        if std::fs::rename(path, &target).is_err() {
            // quarantine-by-rename failed (e.g. read-only dir): removal
            // is the fallback; if even that fails the checksum still
            // protects every future load
            let _ = std::fs::remove_file(path);
        }
    }

    /// Write one durable record: temp file + `sync_all` + atomic rename.
    fn write_record(&self, id: &str, snap: &SessionSnapshot) -> Result<()> {
        let path = self
            .record_path(id)
            .ok_or_else(|| Error::new(ErrorKind::Io, "session store has no spill dir"))?;
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)
                .map_err(|e| Error::new(ErrorKind::Io, format!("session spill dir: {e}")))?;
        }
        let tmp = PathBuf::from(format!("{}.tmp", path.display()));
        let bytes = snap.encode();
        let write = || -> std::io::Result<()> {
            let mut f = std::fs::File::create(&tmp)?;
            f.write_all(&bytes)?;
            f.sync_all()?;
            std::fs::rename(&tmp, &path)
        };
        write().map_err(|e| {
            let _ = std::fs::remove_file(&tmp);
            Error::new(ErrorKind::Io, format!("session record write: {e}"))
        })
    }

    /// Flush every memory-resident session to a durable record (the
    /// graceful-drain path). Returns `(flushed, failures)`; entries stay
    /// resident either way.
    pub fn flush_all(&self) -> (u64, u64) {
        if self.dir.is_none() {
            return (0, 0);
        }
        let entries: Vec<(String, SessionSnapshot)> = {
            let tier = self.lock();
            tier.map.iter().map(|(k, v)| (k.clone(), v.clone())).collect()
        };
        let mut flushed = 0u64;
        let mut failures = 0u64;
        for (id, snap) in entries {
            let guarded = self
                .guard(FaultSite::StatePersist)
                .and_then(|()| self.write_record(&id, &snap));
            match guarded {
                Ok(()) => flushed += 1,
                Err(_) => {
                    failures += 1;
                    self.persist_failures.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
        (flushed, failures)
    }

    /// Startup recovery scan: validate every committed record under the
    /// spill dir, quarantine everything that does not hold up
    /// (`*.corrupt`), and sweep interrupted temp files. Never fails —
    /// an unreadable dir just reports zero — and never loads state.
    pub fn recover(&self) -> RecoveryReport {
        let mut report = RecoveryReport::default();
        let Some(dir) = &self.dir else { return report };
        let _ = std::fs::create_dir_all(dir);
        let Ok(entries) = std::fs::read_dir(dir) else { return report };
        let mut paths: Vec<PathBuf> = entries
            .filter_map(|e| e.ok().map(|e| e.path()))
            .collect();
        paths.sort();
        for path in paths {
            let name = path.file_name().and_then(|n| n.to_str()).unwrap_or("");
            if name.ends_with(".tmp") {
                if std::fs::remove_file(&path).is_ok() {
                    report.removed_tmp += 1;
                }
                continue;
            }
            if !name.ends_with(".session") {
                continue; // `.corrupt` and foreign files are left alone
            }
            let ok = std::fs::read(&path)
                .map_err(|e| Error::new(ErrorKind::Io, format!("recovery read: {e}")))
                .and_then(|bytes| self.validate(&bytes));
            match ok {
                Ok(_) => report.valid += 1,
                Err(_) => {
                    self.quarantine(&path);
                    report.quarantined += 1;
                }
            }
        }
        report
    }

    /// Sessions currently resident in the memory tier.
    pub fn resident(&self) -> usize {
        self.lock().map.len()
    }

    /// Lifetime counters (see [`SessionStats`]).
    pub fn stats(&self) -> SessionStats {
        let (resident, resident_bytes) = {
            let tier = self.lock();
            (tier.map.len(), tier.map.values().map(SessionSnapshot::approx_bytes).sum())
        };
        SessionStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            resident,
            resident_bytes,
            spills: self.spills.load(Ordering::Relaxed),
            quarantined: self.quarantined.load(Ordering::Relaxed),
            persist_failures: self.persist_failures.load(Ordering::Relaxed),
            load_failures: self.load_failures.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fault::FaultPlan;

    fn dims() -> StateDims {
        StateDims { n_layer: 2, d_conv: 3, d_inner: 2, d_state: 2 }
    }

    fn snap(seed: f32) -> SessionSnapshot {
        let d = dims();
        SessionSnapshot {
            dims: d,
            consumed: 7,
            history_hash: history_digest(&[1, 2, 3, 4, 5, 6], &[], 6),
            conv: (0..d.n_layer * d.conv_per_row()).map(|i| seed + i as f32).collect(),
            ssm: (0..d.n_layer * d.ssm_per_row()).map(|i| seed - i as f32).collect(),
        }
    }

    fn tdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("ssm-peft-sessions-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn record_roundtrip_is_lossless() {
        let s = snap(3.5);
        let back = SessionSnapshot::decode(&s.encode()).unwrap();
        assert_eq!(back, s);
    }

    #[test]
    fn every_single_byte_flip_is_detected() {
        // the pin the ISSUE asks for: no single corrupted byte anywhere in
        // the record — header, geometry tag, payload, or checksum — may
        // decode into a state
        let bytes = snap(1.0).encode();
        for i in 0..bytes.len() {
            let mut bad = bytes.clone();
            bad[i] ^= 0x40;
            assert!(
                SessionSnapshot::decode(&bad).is_err(),
                "byte flip at offset {i} decoded silently"
            );
        }
        // truncation at every length is detected too
        for n in 0..bytes.len() {
            assert!(
                SessionSnapshot::decode(&bytes[..n]).is_err(),
                "truncation to {n} bytes decoded silently"
            );
        }
    }

    #[test]
    fn memory_tier_hit_and_clean_miss() {
        let store = SessionStore::new(4).with_dims(dims());
        assert!(store.load("nope").unwrap().is_none());
        store.persist("a", snap(1.0)).unwrap();
        assert_eq!(store.load("a").unwrap().unwrap(), snap(1.0));
        let st = store.stats();
        assert_eq!((st.hits, st.misses, st.resident), (1, 1, 1));
    }

    #[test]
    fn lru_eviction_spills_and_loads_back() {
        let dir = tdir("lru");
        let store = SessionStore::new(2).with_dir(&dir).with_dims(dims());
        store.persist("a", snap(1.0)).unwrap();
        store.persist("b", snap(2.0)).unwrap();
        store.persist("c", snap(3.0)).unwrap(); // evicts "a" → disk
        assert_eq!(store.stats().spills, 1);
        assert_eq!(store.resident(), 2);
        assert!(store.record_path("a").unwrap().exists());
        // "a" promotes back from disk (evicting the coldest resident)
        let back = store.load("a").unwrap().unwrap();
        assert_eq!(back, snap(1.0));
        assert_eq!(store.stats().hits, 1);
    }

    #[test]
    fn eviction_without_disk_tier_is_a_counted_loss() {
        let store = SessionStore::new(1).with_dims(dims());
        store.persist("a", snap(1.0)).unwrap();
        store.persist("b", snap(2.0)).unwrap(); // "a" has nowhere to go
        assert_eq!(store.stats().persist_failures, 1);
        assert!(store.load("a").unwrap().is_none(), "lost session must be a miss");
    }

    #[test]
    fn corrupt_disk_record_is_quarantined_not_loaded() {
        let dir = tdir("corrupt");
        let store = SessionStore::new(1).with_dir(&dir).with_dims(dims());
        store.persist("a", snap(1.0)).unwrap();
        store.persist("b", snap(2.0)).unwrap(); // spill "a"
        let path = store.record_path("a").unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x01; // single bit flip in the payload
        std::fs::write(&path, &bytes).unwrap();

        let e = store.load("a").unwrap_err();
        assert_eq!(e.kind(), ErrorKind::Parse, "{e}");
        assert!(!path.exists(), "corrupt record left in place");
        let corrupt = PathBuf::from(format!("{}.corrupt", path.display()));
        assert!(corrupt.exists(), "corrupt record not quarantined");
        assert_eq!(store.stats().quarantined, 1);
        // the quarantined id is a clean miss from now on
        assert!(store.load("a").unwrap().is_none());
    }

    #[test]
    fn geometry_mismatch_is_quarantined() {
        let dir = tdir("geom");
        let writer = SessionStore::new(1).with_dir(&dir).with_dims(dims());
        writer.persist("a", snap(1.0)).unwrap();
        writer.persist("b", snap(2.0)).unwrap(); // spill "a"
        let other = StateDims { n_layer: 1, d_conv: 2, d_inner: 1, d_state: 1 };
        let reader = SessionStore::new(4).with_dir(&dir).with_dims(other);
        let e = reader.load("a").unwrap_err();
        assert_eq!(e.kind(), ErrorKind::Parse, "{e}");
        assert_eq!(reader.stats().quarantined, 1);
    }

    #[test]
    fn recovery_scan_classifies_every_file() {
        let dir = tdir("recover");
        let store = SessionStore::new(1).with_dir(&dir).with_dims(dims());
        store.persist("good", snap(1.0)).unwrap();
        store.persist("evictor", snap(2.0)).unwrap(); // spill "good"
        // a torn write: committed record truncated mid-payload
        let torn = dir.join("torn-0000000000000000.session");
        std::fs::write(&torn, &snap(3.0).encode()[..20]).unwrap();
        // an interrupted temp file
        std::fs::write(dir.join("x.session.tmp"), b"partial").unwrap();

        let fresh = SessionStore::new(4).with_dir(&dir).with_dims(dims());
        let report = fresh.recover();
        assert_eq!(report.valid, 1);
        assert_eq!(report.quarantined, 1);
        assert_eq!(report.removed_tmp, 1);
        assert!(!torn.exists());
        assert!(PathBuf::from(format!("{}.corrupt", torn.display())).exists());
        // the surviving record still loads
        assert_eq!(fresh.load("good").unwrap().unwrap(), snap(1.0));
    }

    #[test]
    fn injected_transient_persist_fault_retries_in_place() {
        // exactly one injected fault: the bounded retry absorbs it
        let plan = Arc::new(FaultPlan::seeded(3).with_fault_at(FaultSite::StatePersist, 0));
        let store = SessionStore::new(4).with_dims(dims()).with_faults(plan.clone());
        store.persist("a", snap(1.0)).unwrap();
        assert_eq!(plan.checks(FaultSite::StatePersist), 2);
        assert_eq!(store.stats().persist_failures, 0);
    }

    #[test]
    fn saturated_fault_rates_degrade_typed() {
        let plan = Arc::new(
            FaultPlan::seeded(4)
                .with_rate(FaultSite::StatePersist, 1.0)
                .with_rate(FaultSite::StateLoad, 1.0),
        );
        let store = SessionStore::new(4).with_dims(dims()).with_faults(plan);
        let pe = store.persist("a", snap(1.0)).unwrap_err();
        assert_eq!(pe.kind(), ErrorKind::Runtime);
        let le = store.load("a").unwrap_err();
        assert_eq!(le.kind(), ErrorKind::Runtime);
        let st = store.stats();
        assert_eq!((st.persist_failures, st.load_failures), (1, 1));
    }

    #[test]
    fn full_spill_dir_fails_persist_side_only() {
        // point the spill tier at a FILE: every record write fails the way
        // a full/unwritable disk does, and the failure is counted, typed,
        // and non-fatal
        let dir = tdir("full");
        let blocker = dir.join("blocked");
        std::fs::write(&blocker, b"not a dir").unwrap();
        let store = SessionStore::new(1).with_dir(&blocker).with_dims(dims());
        store.persist("a", snap(1.0)).unwrap();
        store.persist("b", snap(2.0)).unwrap(); // spill of "a" fails
        assert_eq!(store.stats().persist_failures, 1);
        let (flushed, failures) = store.flush_all();
        assert_eq!(flushed, 0);
        assert!(failures > 0);
    }

    #[test]
    fn flush_all_makes_every_resident_session_durable() {
        let dir = tdir("flush");
        let store = SessionStore::new(8).with_dir(&dir).with_dims(dims());
        store.persist("a", snap(1.0)).unwrap();
        store.persist("b", snap(2.0)).unwrap();
        let (flushed, failures) = store.flush_all();
        assert_eq!((flushed, failures), (2, 0));
        let fresh = SessionStore::new(8).with_dir(&dir).with_dims(dims());
        assert_eq!(fresh.recover().valid, 2);
        assert_eq!(fresh.load("a").unwrap().unwrap(), snap(1.0));
        assert_eq!(fresh.load("b").unwrap().unwrap(), snap(2.0));
    }

    #[test]
    fn hostile_session_ids_stay_inside_the_dir() {
        let dir = tdir("hostile");
        let store = SessionStore::new(4).with_dir(&dir).with_dims(dims());
        for id in ["../../etc/passwd", "a/b/c", "..", "x y!@#"] {
            let p = store.record_path(id).unwrap();
            assert!(p.starts_with(&dir), "{id:?} escaped: {}", p.display());
            store.persist(id, snap(1.0)).unwrap();
        }
        let (flushed, failures) = store.flush_all();
        assert_eq!(failures, 0, "hostile ids must still spill cleanly");
        assert_eq!(flushed, 4);
    }

    #[test]
    fn history_digest_pins_exact_prefixes() {
        let prompt = [10u8, 20, 30];
        let out = [40u8, 50];
        // absorbed shorter than, equal to, and past the prompt
        let d2 = history_digest(&prompt, &out, 2);
        let d3 = history_digest(&prompt, &out, 3);
        let d4 = history_digest(&prompt, &out, 4);
        assert_ne!(d2, d3);
        assert_ne!(d3, d4);
        // the digest over prompt++out equals the digest over the
        // concatenation presented as one prompt (the replay contract)
        let full = [10u8, 20, 30, 40, 50];
        assert_eq!(history_digest(&full, &[], 4), d4);
    }
}
